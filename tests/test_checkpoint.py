"""Checkpoint store/manager: atomicity, rotation, restart, elastic restore."""
import os
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import (CheckpointManager, CheckpointPolicy,
                                      _flatten_opt, _unflatten_opt)
from repro.checkpoint.store import CheckpointStore, config_hash


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"layers/w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
            "embed/tokens": jnp.asarray(rng.standard_normal((16, 4)),
                                        jnp.float32)}


def test_save_restore_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = _tree()
    store.save(10, t, meta={"config_hash": "abc"})
    assert store.steps() == [10]
    back = store.restore(10)
    for k in t:
        np.testing.assert_array_equal(np.asarray(t[k]), back[k])


def test_async_save_and_wait(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save_async(5, _tree())
    store.wait()
    assert store.latest_step() == 5


def test_rotation_keeps_latest(tmp_path):
    store = CheckpointStore(str(tmp_path))
    for s in (1, 2, 3, 4, 5):
        store.save(s, _tree())
    store.rotate(keep=2)
    assert store.steps() == [4, 5]


def test_atomic_publish_no_tmp_visible(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, _tree())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_manager_restart_cycle(tmp_path):
    mgr = CheckpointManager(str(tmp_path),
                            CheckpointPolicy(every_steps=2, keep=2,
                                             async_save=False))
    params = _tree(1)
    opt = {"step": jnp.asarray(4, jnp.int32),
           "m": _tree(2), "v": _tree(3)}
    meta = {"config_hash": config_hash("cfg")}
    assert mgr.step_hook(4, params, opt, meta)
    got = mgr.maybe_restore("cfg")
    assert got is not None
    step, p2, o2 = got
    assert step == 4
    np.testing.assert_array_equal(np.asarray(params["layers/w"]),
                                  p2["layers/w"])
    np.testing.assert_array_equal(np.asarray(opt["m"]["layers/w"]),
                                  o2["m"]["layers/w"])
    assert int(o2["step"]) == 4


def test_manager_rejects_config_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path),
                            CheckpointPolicy(every_steps=1,
                                             async_save=False))
    mgr.step_hook(1, _tree(), {"step": jnp.asarray(1)},
                  {"config_hash": config_hash("cfgA")})
    with pytest.raises(ValueError):
        mgr.maybe_restore("cfgB")


def test_opt_flatten_roundtrip_with_tuples():
    opt = {"step": jnp.asarray(3), "f": {"w": (jnp.ones((2,)),
                                               jnp.zeros((3,)))}}
    flat = _flatten_opt(opt)
    back = _unflatten_opt(flat)
    assert isinstance(back["f"]["w"], tuple)
    np.testing.assert_array_equal(np.asarray(back["f"]["w"][0]),
                                  np.ones((2,)))


def test_elastic_restore_resharding(tmp_path):
    """Restore with explicit shardings (single-device here) exercises the
    re-shard path used after a slice-down re-mesh."""
    store = CheckpointStore(str(tmp_path))
    t = _tree()
    store.save(1, t)
    sh = {k: jax.sharding.SingleDeviceSharding(jax.devices()[0])
          for k in t}
    back = store.restore(1, shardings=sh)
    for k in t:
        assert isinstance(back[k], jax.Array)
        np.testing.assert_array_equal(np.asarray(t[k]), np.asarray(back[k]))


def test_training_restart_bitwise(tmp_path):
    """checkpoint/restart + counter-based data => identical continuation."""
    from dataclasses import replace
    from repro.configs.base import get_plan, get_reduced
    from repro.data.pipeline import DataConfig, make_batch
    from repro.models import lm as M
    from repro.train.steps import make_train_step

    cfg = get_reduced("olmoe-1b-7b")
    plan = replace(get_plan("olmoe-1b-7b", "default"), microbatches=1)
    step, init_opt = make_train_step(cfg, plan)
    step = jax.jit(step)
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = init_opt(params)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=7)

    # run 4 steps straight
    pa, oa = params, opt
    for i in range(4):
        batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, i).items()}
        pa, oa, _ = step(pa, oa, batch)

    # run 2 steps, checkpoint, restore, run 2 more from the same stream
    store = CheckpointStore(str(tmp_path))
    pb, ob = params, opt
    for i in range(2):
        batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, i).items()}
        pb, ob, _ = step(pb, ob, batch)
    store.save(2, {f"params/{k}": v for k, v in pb.items()})
    restored = store.restore(2)
    pb2 = {k[len("params/"):]: jnp.asarray(v) for k, v in restored.items()}
    for i in range(2, 4):
        batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, i).items()}
        pb2, ob, _ = step(pb2, ob, batch)

    for k in pa:
        np.testing.assert_allclose(np.asarray(pa[k]), np.asarray(pb2[k]),
                                   rtol=1e-6, atol=1e-7)


# -- crash mid-save ----------------------------------------------------------

def test_crash_mid_save_leaves_prior_checkpoint_intact(tmp_path):
    """A simulated crash mid-save (staged .tmp dir with a partial shard
    set and no published rename) is invisible to readers: latest_step()
    still returns the previous intact checkpoint."""
    store = CheckpointStore(str(tmp_path))
    store.save(1, _tree())
    tmp = tmp_path / "step_2.tmp"
    tmp.mkdir()
    np.save(tmp / "layers__w.npy", np.zeros((8, 8), np.float32))
    # crash "after" the manifest too — still staged, never renamed
    (tmp / "manifest.json").write_text('{"step": 2, "meta": {}, "leav')
    assert store.steps() == [1]
    assert store.latest_step() == 1
    back = store.restore(1)
    np.testing.assert_array_equal(np.asarray(_tree()["layers/w"]),
                                  back["layers/w"])


def test_async_save_thread_crash_keeps_prior_step(tmp_path, monkeypatch):
    """The async save thread dying mid-write must not publish a torn
    checkpoint: the .tmp directory stays unpublished and a later save of
    the same step recovers (restages over the leftover .tmp)."""
    store = CheckpointStore(str(tmp_path))
    store.save(3, _tree())

    real_save = np.save
    calls = {"n": 0}

    def dying_save(path, arr, *a, **k):
        calls["n"] += 1
        if calls["n"] == 2:            # die mid-shard-set
            raise OSError("injected: disk gone")
        return real_save(path, arr, *a, **k)

    monkeypatch.setattr(np, "save", dying_save)
    seen = []
    monkeypatch.setattr(threading, "excepthook",
                        lambda args: seen.append(args.exc_type))
    store.save_async(4, _tree(1))
    store.wait()
    assert seen == [OSError]           # the thread died where injected
    assert store.latest_step() == 3    # torn step 4 never published
    assert (tmp_path / "step_4.tmp").exists()
    assert not (tmp_path / "step_4").exists()

    monkeypatch.setattr(np, "save", real_save)
    store.save(4, _tree(1))            # recovery: re-save restages .tmp
    assert store.latest_step() == 4
    for k, v in store.restore(4).items():
        np.testing.assert_array_equal(np.asarray(_tree(1)[k]), v)
