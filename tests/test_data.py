"""Data pipeline: determinism, sharding, prefetch."""
import numpy as np

from repro.data.pipeline import DataConfig, Prefetcher, make_batch


def test_batches_are_pure_functions():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=3)
    a = make_batch(cfg, step=7)
    b = make_batch(cfg, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = make_batch(cfg, step=8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_shards_differ_and_shapes():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    s0 = make_batch(cfg, 0, shard=0, num_shards=4)
    s1 = make_batch(cfg, 0, shard=1, num_shards=4)
    assert s0["tokens"].shape == (2, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=2)
    b = make_batch(cfg, 0)
    # tokens/labels come from one stream shifted by one
    assert b["tokens"].shape == b["labels"].shape
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()


def test_microbatch_reshape():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8, microbatches=2)
    b = make_batch(cfg, 0)
    assert b["tokens"].shape == (2, 4, 8)


def test_modality_stubs():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2, frames=5,
                     d_model=16, patches=3)
    b = make_batch(cfg, 0)
    assert b["frames"].shape == (2, 5, 16)
    assert b["patches"].shape == (2, 3, 16)


def test_prefetcher_streams_in_order():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    pf = Prefetcher(cfg, start_step=5, prefetch=2)
    try:
        s, b = next(pf)
        assert s == 5
        ref = make_batch(cfg, 5)
        np.testing.assert_array_equal(b["tokens"], ref["tokens"])
        s2, _ = next(pf)
        assert s2 == 6
    finally:
        pf.close()
