"""The roofline analyzer itself: trip counts, dot flops, collective math."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_shape
from repro.launch.roofline import V5E, roofline_terms


def test_parse_shape():
    assert parse_shape("bf16[16,512]") == (8192, 16384)
    assert parse_shape("f32[2,3,4]{2,1,0}") == (24, 96)
    assert parse_shape("(f32[4], s32[2])")[0] == 6
    assert parse_shape("pred[]") == (1, 1)


@pytest.mark.xfail(
    strict=False,
    reason="env: this container's jax returns a list (not a dict) from "
           "compiled.cost_analysis(); known environment failure, see "
           "TESTING.md")
def test_scan_trip_counts_in_flops():
    """cost_analysis misses scan trips; our analyzer must not."""
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    xla_flops = compiled.cost_analysis()["flops"]
    ours = analyze(compiled.as_text()).flops
    want = 10 * 2 * 64 ** 3
    assert abs(ours - want) / want < 0.01
    assert xla_flops < ours / 5  # XLA counted the body once


def test_nested_scan_multipliers():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ c2), None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return c

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    st = analyze(jax.jit(f).lower(x).compile().as_text())
    want = 3 * 5 * 2 * 32 ** 3
    assert abs(st.flops - want) / want < 0.01


def test_sliced_param_access_not_overcounted():
    """dynamic-slice of stacked params inside a scan must count slice
    bytes, not the whole (L, ...) array per iteration."""
    L, D = 20, 64

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        c, _ = jax.lax.scan(body, x, w)
        return c

    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    st = analyze(jax.jit(f).lower(x, w).compile().as_text())
    # upper bound: L x (one slice r/w + carry traffic + dot operands)
    per_iter_ub = 8 * D * D * 4
    assert st.hbm_bytes < L * per_iter_ub, st.hbm_bytes


def test_roofline_terms_and_bound():
    class S:
        flops = 197e12          # exactly 1 s of compute
        hbm_bytes = 819e9 / 2   # 0.5 s
        collective_bytes = 50e9 * 2  # 2 s
    t = roofline_terms(S, 256, V5E)
    assert t["bound"] == "collective"
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["roofline_fraction"] == pytest.approx(0.5)
    assert t["step_lower_bound_s"] == pytest.approx(2.0)


def test_dryrun_artifacts_have_corrected_collectives():
    from benchmarks.roofline_table import load_cells
    cells = load_cells("single_pod_16x16")
    if not cells:
        pytest.skip("no dry-run artifacts")
    for c in cells:
        raw = c["hlo"].get("collective_bytes_raw", 0)
        cor = c["hlo"]["collective_bytes"]
        if raw:
            assert cor <= raw + 1e-6
