"""Multi-process ClusterExecutor: placement fidelity, bit-identity vs the
per-task executor, XFER endpoints, strategy selection, IPC calibration."""
import numpy as np
import pytest

from repro.core import (ClusteredMatrix as CM, CMMEngine, TimeModel,
                        analytic_time_model, c5_9xlarge)
from repro.core.machine import hetero_spec
from repro.exec import EXECUTORS, make_executor
from repro.exec.cluster import ClusterExecutor, predict_cluster_makespan
from repro.exec.local import LocalExecutor

TM = analytic_time_model()

#: fat links + tiny latency make HEFT spread work across nodes (comm is
#: nearly free), so placements genuinely exercise the multi-node path
FAST_NET = dict(link_bw=1e12, latency=1e-6)


def _plan(expr, tile, spec):
    eng = CMMEngine(spec, TM, plan_cache=False)
    return eng.plan(expr, tile=tile)


def _synth(n=64):
    A = CM.rand(n, n, seed=0)
    B = CM.rand(n, n, seed=1)
    C = CM.rand(n, n, seed=2)
    D = CM.rand(n, n, seed=3)
    return (A @ B) + (C @ D)


# -- heterogeneous placement: the acceptance-criteria test ------------------

def test_hetero_3node_placement_is_executed_for_real():
    """On a heterogeneous >=3-node spec (unequal worker counts and speeds),
    every task must run in the worker process of its HEFT-assigned node,
    with real inter-process tile transfers."""
    spec = hetero_spec((3, 2, 1), slowdown=(1.0, 1.2, 1.5), **FAST_NET)
    plan = _plan(_synth(), tile=16, spec=spec)
    nodes_used = {p.node for p in plan.schedule.placements.values()}
    assert len(nodes_used) >= 2, "HEFT should spread this plan across nodes"

    out_local = LocalExecutor().execute(plan)
    ex = ClusterExecutor()
    out_cluster = ex.execute(plan)
    assert out_cluster.dtype == out_local.dtype
    assert np.array_equal(out_local, out_cluster)

    sched_nodes = {tid: p.node for tid, p in plan.schedule.placements.items()}
    assert ex.stats["exec_nodes"] == sched_nodes, \
        "every task must execute on its HEFT-assigned node process"
    assert len(set(ex.stats["node_pids"].values())) == 3, \
        "one distinct worker process per node"
    assert ex.stats["xfers"] > 0 and ex.stats["xfer_bytes"] > 0
    assert ex.stats["workers"] == 3 + 2 + 1


def test_cluster_refcounting_frees_all_buffers():
    spec = hetero_spec((2, 1), **FAST_NET)
    plan = _plan(_synth(48), tile=16, spec=spec)
    ex = ClusterExecutor()
    out = ex.execute(plan)
    ref = LocalExecutor().execute(plan)
    assert np.array_equal(out, ref)
    # every segment was freed: result tiles are released after the gather
    assert ex.stats["cur_buffer_bytes"] == 0
    assert ex.stats["buffers_freed"] > 0
    assert ex.stats["peak_buffer_bytes"] > 0

    ex_keep = ClusterExecutor(free_buffers=False)
    out_keep = ex_keep.execute(plan)
    assert np.array_equal(out, out_keep)
    assert ex_keep.stats["cur_buffer_bytes"] > 0


def test_cluster_input_leaves_and_plan_cache_rebind():
    """INPUT data is shipped to the worker processes; a plan-cache hit must
    rebind fresh leaves (different data) through the same schedule."""
    rng = np.random.default_rng(0)
    spec = hetero_spec((2, 1), **FAST_NET)
    eng = CMMEngine(spec, TM)
    a1, b1 = rng.standard_normal((48, 48)), rng.standard_normal((48, 48))
    e1 = (CM.from_array(a1) @ CM.from_array(b1)) + CM.from_array(a1)
    out1 = eng.run(e1, tile=16, executor="cluster")
    np.testing.assert_allclose(out1, a1 @ b1 + a1, rtol=1e-12, atol=1e-12)

    a2, b2 = rng.standard_normal((48, 48)), rng.standard_normal((48, 48))
    e2 = (CM.from_array(a2) @ CM.from_array(b2)) + CM.from_array(a2)
    plan2 = eng.plan(e2, tile=16)
    assert plan2.cache_hit
    out2 = ClusterExecutor().execute(plan2)
    np.testing.assert_allclose(out2, a2 @ b2 + a2, rtol=1e-12, atol=1e-12)


# -- schedule endpoints exposed to executors --------------------------------

def test_schedule_node_tasks_and_xfer_endpoints():
    spec = hetero_spec((3, 2, 1), **FAST_NET)
    plan = _plan(_synth(), tile=16, spec=spec)
    g = plan.program.graph
    sched = plan.schedule

    by_node = sched.node_tasks()
    flat = [tid for tids in by_node.values() for tid in tids]
    assert sorted(flat) == sorted(sched.placements)          # exact partition
    for n, tids in by_node.items():
        assert all(sched.placements[t].node == n for t in tids)
        starts = [sched.placements[t].start for t in tids]
        assert starts == sorted(starts)                      # dispatch order

    xfers = sched.xfers(g)
    assert xfers, "multi-node synth must move tiles across nodes"
    seen = set()
    for (p, src, dst, nbytes) in xfers:
        assert sched.placements[p].node == src
        assert src != dst and nbytes > 0
        assert (p, dst) not in seen, "one XFER per version per destination"
        seen.add((p, dst))


# -- executor registry (satellite fix) --------------------------------------

def test_executor_registry_single_source_of_truth():
    assert {"local", "kernel", "batched", "batched-pallas",
            "cluster"} <= set(EXECUTORS)
    assert isinstance(make_executor("cluster"), ClusterExecutor)
    assert isinstance(make_executor("local"), LocalExecutor)
    with pytest.raises(ValueError, match="unknown executor"):
        make_executor("no-such-backend")
    eng = CMMEngine(c5_9xlarge(1), TM)
    with pytest.raises(ValueError, match="unknown executor"):
        eng.run(_synth(16), tile=8, executor="no-such-backend")


# -- strategy selection: process-dispatch/IPC terms -------------------------

def test_predict_cluster_makespan_prices_ipc_terms():
    spec = hetero_spec((2, 1), **FAST_NET)
    plan = _plan(_synth(48), tile=16, spec=spec)
    cheap = TimeModel.from_json(TM.to_json())
    cheap.process_dispatch_overhead = 1e-6
    dear = TimeModel.from_json(TM.to_json())
    dear.process_dispatch_overhead = 5e-3
    g, sched = plan.program.graph, plan.schedule
    t_cheap = predict_cluster_makespan(g, sched, spec, cheap)
    t_dear = predict_cluster_makespan(g, sched, spec, dear)
    assert t_dear > t_cheap


def test_predict_wave_makespan_uses_hetero_worker_counts():
    """A hetero spec with 1-worker nodes must not be priced at the
    ClusterSpec default ``worker_procs=3`` (auto-selection mispricing)."""
    from repro.exec.batched import predict_wave_makespan
    spec1 = hetero_spec((1, 1), **FAST_NET)
    spec3 = hetero_spec((3, 3), **FAST_NET)
    plan = _plan(_synth(48), tile=16, spec=spec1)
    g = plan.program.graph
    t1 = predict_wave_makespan(g, spec1, TM, waves=plan.waves,
                               dtypes=plan.program.dtypes)
    t3 = predict_wave_makespan(g, spec3, TM, waves=plan.waves,
                               dtypes=plan.program.dtypes)
    assert t1 > t3


def test_engine_auto_can_select_cluster():
    expr = _synth(48)
    # expensive in-process dispatch + slow network model, near-free process
    # dispatch and fat IPC -> the cluster strategy wins
    tm_c = TimeModel.from_json(TM.to_json())
    tm_c.dispatch_overhead = 5e-3
    tm_c.batch_dispatch_overhead = 10.0
    tm_c.process_dispatch_overhead = 1e-7
    tm_c.ipc_bandwidth = 1e12
    tm_c.ipc_latency = 1e-7
    eng = CMMEngine(hetero_spec((2, 1), **FAST_NET), tm_c, plan_cache=False)
    plan = eng.plan(expr, tile=16)
    assert plan.cluster_makespan is not None
    assert plan.cluster_makespan < plan.sim.makespan
    assert plan.best_executor == "cluster"
    assert plan.best_predicted_makespan == plan.cluster_makespan
    out = eng.run(expr, plan=plan, executor="auto", validate=True)
    assert eng.last_exec_stats["executor"] == "cluster"
    assert out.shape == (48, 48)

    # prohibitive process dispatch -> cluster never chosen
    tm_l = TimeModel.from_json(TM.to_json())
    tm_l.process_dispatch_overhead = 10.0
    eng_l = CMMEngine(hetero_spec((2, 1), **FAST_NET), tm_l,
                      plan_cache=False)
    plan_l = eng_l.plan(expr, tile=16)
    assert plan_l.best_executor != "cluster"


def test_single_node_plans_skip_cluster_prediction():
    eng = CMMEngine(c5_9xlarge(1), TM, plan_cache=False)
    plan = eng.plan(_synth(32), tile=16)
    assert plan.cluster_makespan is None
    assert plan.best_executor in ("local", "batched")


def test_timemodel_json_roundtrip_ipc_terms():
    tm = TimeModel.from_json(TM.to_json())
    tm.process_dispatch_overhead = 1.5e-4
    tm.ipc_bandwidth = 3e9
    tm.ipc_latency = 7e-5
    rt = TimeModel.from_json(tm.to_json())
    assert rt.process_dispatch_overhead == 1.5e-4
    assert rt.ipc_bandwidth == 3e9
    assert rt.ipc_latency == 7e-5


def test_calibrate_ipc_fits_positive_terms():
    from repro.core.profiler import calibrate_ipc
    tm = TimeModel.from_json(TM.to_json())
    disp, bw = calibrate_ipc(tm, nbytes=1 << 20, reps=2)
    assert 1e-6 <= disp <= 5e-2
    assert 1e8 <= bw <= 1e12
    assert tm.process_dispatch_overhead == disp
    assert tm.ipc_latency == disp
    assert tm.ipc_bandwidth == bw


# -- hypothesis property: cluster <-> local bit-identity --------------------

try:
    from hypothesis import given, settings, strategies as st
    from test_batched import _rand_expr          # FUSED / transposed-matmul
    HAVE_HYP = True                              # / f32-f64 strategies
except ImportError:                     # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:
    HET_SPEC = hetero_spec((2, 1), **FAST_NET)

    @given(st.data())
    @settings(max_examples=8, deadline=None)
    def test_cluster_bit_identical_property(data):
        """Over randomized expression DAGs (FUSED regions, transposed
        matmuls, f32/f64), the multi-process executor is bit-identical to
        the per-task executor, and — when every matmul k-chain fits one
        tile — to ``eager()`` too (same policy as the batched property)."""
        dtype = data.draw(st.sampled_from([np.float64, np.float32]))
        tile = data.draw(st.integers(4, 12))
        m = data.draw(st.integers(2, 16))
        n = data.draw(st.integers(2, 16))
        depth = data.draw(st.integers(1, 2))
        expr = _rand_expr(data.draw, depth, m, n, dtype, max_inner=tile)
        plan = _plan(expr, tile=tile, spec=HET_SPEC)
        out_local = LocalExecutor().execute(plan)
        ex = ClusterExecutor()
        out_cluster = ex.execute(plan)
        assert out_cluster.dtype == out_local.dtype
        assert np.array_equal(out_local, out_cluster), \
            "cluster executor diverged from per-task executor"
        assert np.array_equal(out_cluster, expr.eager()), \
            "cluster executor diverged from the eager oracle"
        sched_nodes = {tid: p.node
                       for tid, p in plan.schedule.placements.items()}
        assert ex.stats["exec_nodes"] == sched_nodes

    @given(st.data())
    @settings(max_examples=4, deadline=None)
    def test_cluster_matches_per_task_with_long_k_chains(data):
        """Multi-k-tile accumulate chains (possibly migrating between
        nodes mid-chain): still bitwise vs the per-task executor, oracle
        at tolerance (tiling re-associates the GEMM reduction)."""
        dtype = data.draw(st.sampled_from([np.float64, np.float32]))
        tile = data.draw(st.integers(3, 6))
        k = data.draw(st.integers(tile + 1, 3 * tile))
        m = data.draw(st.integers(2, 10))
        n = data.draw(st.integers(2, 10))
        expr = (CM.rand(m, k, seed=0, dtype=dtype) @
                CM.rand(k, n, seed=1, dtype=dtype)).relu() + \
            CM.rand(m, n, seed=2, dtype=dtype)
        plan = _plan(expr, tile=tile, spec=HET_SPEC)
        out_local = LocalExecutor().execute(plan)
        out_cluster = ClusterExecutor().execute(plan)
        assert np.array_equal(out_local, out_cluster)
        tol = 1e-4 if dtype == np.float32 else 1e-9
        np.testing.assert_allclose(out_cluster, expr.eager(),
                                   rtol=tol, atol=tol)
