"""Fault-injection tier: the elastic runtime under membership churn.

Determinism contract (see TESTING.md): every task kind is a deterministic
NumPy call, so duplicated, resurrected and re-routed executions produce
bit-identical tiles — results under kill/join/straggle chaos must equal
``LocalExecutor`` exactly.  These tests SIGKILL real worker processes and
spawn real joiners; they are marked ``chaos`` (and the paper-suite sweep
additionally ``slow``).
"""
import numpy as np
import pytest

from repro.core import (ClusteredMatrix as CM, CMMEngine, TimeModel,
                        analytic_time_model, c5_9xlarge)
from repro.core.heft import Placement, replan_frontier
from repro.core.machine import hetero_spec
from repro.core.simulator import (churn_adjusted_makespan,
                                  predict_recovery_cost)
from repro.exec import EXECUTORS, make_executor
from repro.exec.elastic import ChaosEvent, ElasticClusterExecutor
from repro.exec.local import LocalExecutor
from repro.runtime.membership import (DEATH, RECOVER, STRAGGLE,
                                      MembershipConfig, MembershipService)

TM = analytic_time_model()
FAST_NET = dict(link_bw=1e12, latency=1e-6)


def _plan(expr, tile, spec):
    eng = CMMEngine(spec, TM, plan_cache=False)
    return eng.plan(expr, tile=tile)


def _synth(n=64):
    A = CM.rand(n, n, seed=0)
    B = CM.rand(n, n, seed=1)
    C = CM.rand(n, n, seed=2)
    D = CM.rand(n, n, seed=3)
    return (A @ B) + (C @ D)


# -- ClusterSpec membership deltas ------------------------------------------

def test_spec_without_node_drains_in_place():
    spec = hetero_spec((3, 2, 1))
    dead = spec.without_node(1)
    assert dead.n_nodes == 3                      # indices stay stable
    assert dead.workers_at(1) == 0
    assert dead.workers_at(0) == 3 and dead.workers_at(2) == 1
    assert dead.alive_nodes() == (0, 2)
    assert dead.total_workers() == 4
    with pytest.raises(ValueError, match="master"):
        spec.without_node(spec.master)
    with pytest.raises(ValueError, match="no node"):
        spec.without_node(7)


def test_spec_with_node_appends():
    spec = hetero_spec((2, 1), slowdown=(1.0, 1.5))
    grown = spec.with_node(4, slowdown=2.0)
    assert grown.n_nodes == 3
    assert grown.workers_at(2) == 4
    assert grown.node_slowdown(2) == 2.0
    assert grown.node_slowdown(1) == 1.5          # existing entries kept
    assert grown.alive_nodes() == (0, 1, 2)
    with pytest.raises(ValueError):
        spec.with_node(0)
    # homogeneous specs materialise their per-node tuples on first delta
    homog = c5_9xlarge(2).with_node()
    assert homog.workers_at(2) == homog.worker_procs


def test_spec_with_slowdown_replaces_one_entry():
    spec = hetero_spec((2, 2))
    slow = spec.with_slowdown(1, 3.0)
    assert slow.node_slowdown(1) == 3.0
    assert slow.node_slowdown(0) == 1.0
    assert slow.workers_at(1) == 2


# -- membership service ------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_membership_heartbeat_timeout_death_once():
    clk = _Clock()
    cfg = MembershipConfig(heartbeat_timeout_s=1.0)
    ms = MembershipService(range(3), cfg=cfg, clock=clk)
    clk.t = 0.5
    ms.heartbeat(0)
    ms.heartbeat(1)
    clk.t = 1.2
    evs = ms.poll()
    assert {e.node for e in evs if e.kind == DEATH} == {2}
    assert ms.alive_nodes() == [0, 1]
    assert ms.poll() == []                        # DEATH fires exactly once
    clk.t = 3.0
    with pytest.raises(RuntimeError, match="master"):
        ms.poll()                                 # master staleness is fatal


def test_membership_process_exit_beats_heartbeat():
    ms = MembershipService(range(2), clock=_Clock())
    evs = ms.poll({0: True, 1: False})
    assert [e.node for e in evs] == [1]
    assert "exited" in evs[0].reason


def test_membership_master_death_is_fatal():
    ms = MembershipService(range(2), master=0, clock=_Clock())
    with pytest.raises(RuntimeError, match="master"):
        ms.mark_dead(0)


def test_membership_straggler_patience_and_rearm():
    clk = _Clock()
    cfg = MembershipConfig(straggler_factor=2.0, straggler_patience=3,
                           straggler_poll_interval_s=1.0,
                           straggler_min_tasks=1)
    ms = MembershipService(range(3), cfg=cfg, clock=clk)
    for _ in range(8):
        ms.record_task(0, 0.01)
        ms.record_task(1, 0.01)
        ms.record_task(2, 0.10)                   # 10x the median
    evs = []
    for i in range(4):
        clk.t += 1.0
        evs += ms.poll()
    stragglers = [e for e in evs if e.kind == STRAGGLE]
    assert [e.node for e in stragglers] == [2]    # patience, then fire once
    # recovery emits RECOVER (lifts the re-planning penalty) + re-arms
    for _ in range(40):
        ms.record_task(2, 0.01)
    clk.t += 1.0
    rec = ms.poll()
    assert [e.node for e in rec if e.kind == RECOVER] == [2]
    assert [e for e in rec if e.kind == STRAGGLE] == []
    for _ in range(40):
        ms.record_task(2, 0.5)
    evs = []
    for i in range(4):
        clk.t += 1.0
        evs += ms.poll()
    assert [e.node for e in evs if e.kind == STRAGGLE] == [2]


def test_membership_straggler_detected_on_two_node_fleet():
    """Lower-middle median: on 2 nodes the straggler must be compared
    against the healthy node, not against itself."""
    clk = _Clock()
    cfg = MembershipConfig(straggler_factor=2.0, straggler_patience=2,
                           straggler_poll_interval_s=1.0,
                           straggler_min_tasks=1)
    ms = MembershipService(range(2), cfg=cfg, clock=clk)
    for _ in range(8):
        ms.record_task(0, 0.01)
        ms.record_task(1, 0.20)
    evs = []
    for _ in range(3):
        clk.t += 1.0
        evs += ms.poll()
    assert [e.node for e in evs if e.kind == STRAGGLE] == [1]


def test_membership_straggler_needs_min_tasks():
    clk = _Clock()
    cfg = MembershipConfig(straggler_factor=2.0, straggler_patience=1,
                           straggler_poll_interval_s=0.1,
                           straggler_min_tasks=5)
    ms = MembershipService(range(2), cfg=cfg, clock=clk)
    ms.record_task(0, 0.01)
    ms.record_task(1, 1.0)                        # one noisy sample
    clk.t += 1.0
    assert ms.poll() == []


def test_membership_join():
    ms = MembershipService(range(2), clock=_Clock())
    ev = ms.add_node(2)
    assert ev.kind == "join" and ev.node == 2
    assert ms.alive_nodes() == [0, 1, 2]


# -- replan_frontier ---------------------------------------------------------

def _split_by_start(sched, frac=0.4):
    """First ``frac`` of tasks (by scheduled start) as the done set."""
    order = sorted(sched.placements, key=lambda t: (sched.placements[t].start,
                                                    t))
    cut = max(1, int(len(order) * frac))
    done = {tid: sched.placements[tid] for tid in order[:cut]}
    frontier = order[cut:]
    return done, frontier


def test_replan_frontier_death_keeps_done_and_avoids_dead_node():
    spec = hetero_spec((3, 2, 1), **FAST_NET)
    plan = _plan(_synth(), tile=16, spec=spec)
    g, sched = plan.program.graph, plan.schedule
    done, frontier = _split_by_start(sched)
    drained = spec.without_node(1)
    new = replan_frontier(g, drained, TM, done, frontier)
    # completed placements are immutable
    for tid, p in done.items():
        assert new.placements[tid] == p
    # every frontier task re-placed, never on the dead node
    for tid in frontier:
        assert new.placements[tid].node != 1
        assert new.placements[tid].node in drained.alive_nodes()
    assert set(new.placements) == set(sched.placements)


def test_replan_frontier_join_can_use_new_node():
    spec = hetero_spec((1, 1), **FAST_NET)
    plan = _plan(_synth(), tile=16, spec=spec)
    g, sched = plan.program.graph, plan.schedule
    done, frontier = _split_by_start(sched, frac=0.2)
    grown = spec.with_node(3)
    new = replan_frontier(g, grown, TM, done, frontier)
    nodes_used = {new.placements[tid].node for tid in frontier}
    assert 2 in nodes_used, "a fat joining node should attract work"
    for tid, p in done.items():
        assert new.placements[tid] == p


def test_replan_frontier_rejects_overlap_and_drained_master():
    spec = hetero_spec((2, 1), **FAST_NET)
    plan = _plan(_synth(48), tile=16, spec=spec)
    g, sched = plan.program.graph, plan.schedule
    done, frontier = _split_by_start(sched)
    some_done = next(iter(done))
    with pytest.raises(ValueError, match="both done and in the frontier"):
        replan_frontier(g, spec, TM, done, frontier + [some_done])
    import dataclasses
    all_drained = dataclasses.replace(spec, node_workers=(0, 1), master=0)
    with pytest.raises(ValueError, match="master"):
        replan_frontier(g, all_drained, TM, done, frontier)


# -- churn pricing -----------------------------------------------------------

def test_predict_recovery_cost_scales_with_lost_work():
    spec = hetero_spec((2, 2), **FAST_NET)
    plan = _plan(_synth(), tile=16, spec=spec)
    g, sched = plan.program.graph, plan.schedule
    c1 = predict_recovery_cost(g, sched, spec, TM, 1)
    assert c1 >= TM.respawn_overhead
    lone = hetero_spec((2,), **FAST_NET)
    assert predict_recovery_cost(g, sched, lone, TM, 0) == float("inf")


def test_churn_adjusted_makespan_prices_mtbf():
    spec = hetero_spec((2, 2), **FAST_NET)
    plan = _plan(_synth(), tile=16, spec=spec)
    g, sched = plan.program.graph, plan.schedule
    base = sched.makespan
    assert churn_adjusted_makespan(g, sched, spec, TM) == base  # mtbf=inf
    risky = TimeModel.from_json(TM.to_json())
    risky.node_mtbf = base                       # ~certain failure
    adj = churn_adjusted_makespan(g, sched, spec, risky)
    assert adj > base
    safer = TimeModel.from_json(TM.to_json())
    safer.node_mtbf = base * 1e6
    assert base < churn_adjusted_makespan(g, sched, spec, safer) < adj


def test_timemodel_json_roundtrips_churn_terms():
    tm = TimeModel.from_json(TM.to_json())
    tm.node_mtbf = 3600.0
    tm.respawn_overhead = 0.25
    rt = TimeModel.from_json(tm.to_json())
    assert rt.node_mtbf == 3600.0
    assert rt.respawn_overhead == 0.25
    assert TimeModel.from_json(TM.to_json()).node_mtbf == float("inf")


# -- satellite: memoized predictions must track TimeModel recalibration -----

def test_cluster_prediction_tracks_timemodel_mutation():
    """``plan.cluster_makespan`` must not return a stale verdict after
    ``calibrate_ipc``-style in-place mutation of the TimeModel."""
    tm = TimeModel.from_json(TM.to_json())
    tm.process_dispatch_overhead = 1e-6
    eng = CMMEngine(hetero_spec((2, 1), **FAST_NET), tm, plan_cache=False)
    plan = eng.plan(_synth(48), tile=16)
    cheap = plan.cluster_makespan
    tm.process_dispatch_overhead = 5e-2          # what calibrate_ipc does
    dear = plan.cluster_makespan
    assert dear > cheap, "memo must invalidate on TimeModel change"
    assert plan.elastic_makespan == dear         # mtbf=inf: same number


def test_plan_cache_invalidated_by_recalibration():
    tm = TimeModel.from_json(TM.to_json())
    eng = CMMEngine(hetero_spec((2, 1), **FAST_NET), tm)
    expr = _synth(48)
    eng.plan(expr, tile=16)
    p2 = eng.plan(expr, tile=16)
    assert p2.cache_hit
    tm.ipc_bandwidth *= 2                        # recalibration
    p3 = eng.plan(expr, tile=16)
    assert not p3.cache_hit, "recalibrated TimeModel must miss the cache"


# -- engine integration ------------------------------------------------------

def test_elastic_registered_and_engine_runs_it():
    assert "elastic" in EXECUTORS
    assert isinstance(make_executor("elastic"), ElasticClusterExecutor)
    spec = hetero_spec((2, 1), **FAST_NET)
    eng = CMMEngine(spec, TM, plan_cache=False)
    expr = _synth(48)
    out = eng.run(expr, tile=16, executor="elastic")
    plan = eng.plan(expr, tile=16)
    assert np.array_equal(out, LocalExecutor().execute(plan))
    assert eng.last_exec_stats["deaths"] == 0
    assert eng.last_exec_stats["executor"] == "elastic"


def test_engine_elastic_auto_prices_churn():
    expr = _synth(48)
    tm = TimeModel.from_json(TM.to_json())
    tm.dispatch_overhead = 5e-3                  # in-process is expensive
    tm.batch_dispatch_overhead = 10.0
    tm.process_dispatch_overhead = 1e-7
    tm.ipc_bandwidth = 1e12
    tm.ipc_latency = 1e-7
    spec = hetero_spec((2, 1), **FAST_NET)
    eng = CMMEngine(spec, tm, plan_cache=False, elastic=True)
    plan = eng.plan(expr, tile=16)
    # reliable cluster: the elastic strategy wins and runs elastically
    assert eng.choose_executor(plan) == "elastic"
    out = eng.run(expr, plan=plan, executor="auto", validate=True)
    assert eng.last_exec_stats["executor"] == "elastic"
    assert out.shape == (48, 48)
    # an unreliable cluster tips auto back to an in-process strategy
    tm.node_mtbf = 1e-3
    tm.respawn_overhead = 1e3
    plan2 = eng.plan(expr, tile=16)
    assert plan2.elastic_makespan > plan2.cluster_makespan
    assert eng.choose_executor(plan2) != "elastic"


# -- fault-injected execution: the acceptance bar ---------------------------

HET_SPEC = hetero_spec((3, 2, 1), slowdown=(1.0, 1.2, 1.5), **FAST_NET)


@pytest.mark.chaos
def test_kill_one_node_mid_run_bitwise():
    plan = _plan(_synth(), tile=16, spec=HET_SPEC)
    ref = LocalExecutor().execute(plan)
    kill_at = len(plan.program.graph) // 3
    ex = ElasticClusterExecutor(
        timemodel=TM, chaos=[ChaosEvent(after_done=kill_at, kill_node=1)])
    out = ex.execute(plan)
    assert out.dtype == ref.dtype
    assert np.array_equal(ref, out)
    st = ex.stats
    assert st["deaths"] == 1
    assert st["replans"] >= 1
    assert st["nodes_final"] == 2
    # every task has exactly one winning completion node and the run
    # finished without node 1's worker
    assert set(st["exec_nodes"]) == set(plan.program.graph.tasks)


def test_chaos_kill_node_must_be_in_range():
    plan = _plan(_synth(48), tile=16, spec=hetero_spec((2, 1), **FAST_NET))
    ex = ElasticClusterExecutor(
        timemodel=TM, chaos=[ChaosEvent(after_done=1, kill_node=7)])
    with pytest.raises(ValueError, match="kill_node=7"):
        ex.execute(plan)
    with pytest.raises(ValueError, match="master"):
        ElasticClusterExecutor(
            timemodel=TM,
            chaos=[ChaosEvent(after_done=1, kill_node=0)]).execute(plan)


@pytest.mark.chaos
def test_kill_of_later_joining_node_is_deferred_not_dropped():
    """A kill aimed at a node that only exists after a join must stay
    armed until the join has spawned it, then actually fire."""
    spec = hetero_spec((1, 1), **FAST_NET)
    plan = _plan(_synth(), tile=8, spec=spec)
    ref = LocalExecutor().execute(plan)
    ex = ElasticClusterExecutor(
        timemodel=TM,
        chaos=[ChaosEvent(after_done=1, kill_node=2),     # before the join
               ChaosEvent(after_done=6, join_workers=2)])
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    assert ex.stats["joins"] == 1
    assert ex.stats["deaths"] == 1, \
        "the deferred kill must fire once its target exists"


@pytest.mark.chaos
def test_min_nodes_floor_aborts_run():
    plan = _plan(_synth(48), tile=16, spec=hetero_spec((2, 2), **FAST_NET))
    ex = ElasticClusterExecutor(
        timemodel=TM, timeout=60,
        membership=MembershipConfig(min_nodes=2),
        chaos=[ChaosEvent(after_done=5, kill_node=1)])
    with pytest.raises(RuntimeError, match="min_nodes=2"):
        ex.execute(plan)


@pytest.mark.chaos
def test_stall_watchdog_fires_despite_heartbeats():
    """A wedged run (here: an unsatisfiable dependency cycle spliced into
    the graph) must trip the stall timeout even though idle-but-alive
    workers keep heartbeating — heartbeats are liveness, not progress."""
    from repro.core.graph import TaskKind
    from repro.core.heft import Placement
    spec = hetero_spec((2, 1), **FAST_NET)
    plan = _plan(_synth(48), tile=16, spec=spec)
    g = plan.program.graph
    some = next(iter(g.tasks.values()))
    t1 = g.add(TaskKind.ADD, (some.out, some.out), some.out)
    t2 = g.add(TaskKind.ADD, (some.out, some.out), some.out)
    g.add_edge(t1.tid, t2.tid)
    g.add_edge(t2.tid, t1.tid)           # cycle: neither can ever start
    plan.schedule.placements[t1.tid] = Placement(0, 0, 1e9, 1e9)
    plan.schedule.placements[t2.tid] = Placement(0, 0, 1e9, 1e9)
    ex = ElasticClusterExecutor(
        timemodel=TM, timeout=3.0,
        # straggler detection stays off: on a loaded host the idle wedged
        # run can trip a STRAGGLE sweep first, and the resulting replan
        # chokes on the deliberately-cyclic graph before the watchdog
        membership=MembershipConfig(heartbeat_interval_s=0.05,
                                    straggler_min_tasks=1 << 30))
    with pytest.raises(RuntimeError, match="stalled"):
        ex.execute(plan)


@pytest.mark.chaos
def test_kill_respawn_readmits_node():
    plan = _plan(_synth(), tile=16, spec=HET_SPEC)
    ref = LocalExecutor().execute(plan)
    ex = ElasticClusterExecutor(
        timemodel=TM, respawn_dead=True,
        chaos=[ChaosEvent(after_done=12, kill_node=2)])
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    assert ex.stats["deaths"] == 1
    assert ex.stats["respawns"] == 1
    assert ex.stats["nodes_final"] == 3


@pytest.mark.chaos
def test_join_node_mid_run_bitwise_and_used():
    spec = hetero_spec((1, 1), **FAST_NET)
    plan = _plan(_synth(), tile=8, spec=spec)   # 8x8 grid: plenty of work
    ref = LocalExecutor().execute(plan)
    ex = ElasticClusterExecutor(
        timemodel=TM,
        chaos=[ChaosEvent(after_done=10, join_workers=3)])
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    st = ex.stats
    assert st["joins"] == 1
    assert st["nodes_final"] == 3
    assert 2 in set(st["exec_nodes"].values()), \
        "the joining node must actually execute re-planned work"


@pytest.mark.chaos
def test_straggler_speculation_bitwise():
    plan = _plan(_synth(), tile=16, spec=HET_SPEC)
    ref = LocalExecutor().execute(plan)
    ex = ElasticClusterExecutor(
        timemodel=TM,
        chaos=[ChaosEvent(after_done=3, throttle_node=1,
                          throttle_seconds=0.05),
               ChaosEvent(after_done=10, flag_straggler=1)])
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    st = ex.stats
    assert st["straggles"] >= 1
    assert st["replans"] >= 1
    # first-writer-wins: duplicates may or may not land, but every task
    # completed exactly once in the winner bookkeeping
    assert len(st["exec_nodes"]) == len(plan.program.graph)


# -- hypothesis properties: churn never changes bits -------------------------

try:
    from hypothesis import given, settings, strategies as st
    from test_batched import _rand_expr          # FUSED / transposed-matmul
    HAVE_HYP = True                              # / f32-f64 strategies
except ImportError:                     # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:
    @pytest.mark.chaos
    @given(st.data())
    @settings(max_examples=5, deadline=None)
    def test_kill_mid_run_bit_identical_property(data):
        """Over randomized expression DAGs (the paper-suite strategies
        reused from tests/test_cluster.py), SIGKILLing one worker process
        mid-run leaves the result bit-identical to ``LocalExecutor``."""
        dtype = data.draw(st.sampled_from([np.float64, np.float32]))
        tile = data.draw(st.integers(4, 12))
        m = data.draw(st.integers(2, 16))
        n = data.draw(st.integers(2, 16))
        depth = data.draw(st.integers(1, 2))
        expr = _rand_expr(data.draw, depth, m, n, dtype, max_inner=tile)
        plan = _plan(expr, tile=tile, spec=HET_SPEC)
        total = len(plan.program.graph)
        kill_at = data.draw(st.integers(1, max(1, total - 2)))
        ref = LocalExecutor().execute(plan)
        ex = ElasticClusterExecutor(
            timemodel=TM,
            chaos=[ChaosEvent(after_done=kill_at, kill_node=1)])
        out = ex.execute(plan)
        assert out.dtype == ref.dtype
        assert np.array_equal(ref, out), \
            "elastic executor diverged after mid-run node death"
        assert ex.stats["deaths"] == 1

    @pytest.mark.chaos
    @given(st.data())
    @settings(max_examples=4, deadline=None)
    def test_join_mid_run_bit_identical_property(data):
        dtype = data.draw(st.sampled_from([np.float64, np.float32]))
        tile = data.draw(st.integers(4, 12))
        m = data.draw(st.integers(2, 16))
        n = data.draw(st.integers(2, 16))
        depth = data.draw(st.integers(1, 2))
        expr = _rand_expr(data.draw, depth, m, n, dtype, max_inner=tile)
        spec = hetero_spec((2, 1), **FAST_NET)
        plan = _plan(expr, tile=tile, spec=spec)
        total = len(plan.program.graph)
        join_at = data.draw(st.integers(0, max(0, total - 2)))
        ref = LocalExecutor().execute(plan)
        ex = ElasticClusterExecutor(
            timemodel=TM,
            chaos=[ChaosEvent(after_done=join_at, join_workers=2)])
        out = ex.execute(plan)
        assert out.dtype == ref.dtype
        assert np.array_equal(ref, out), \
            "elastic executor diverged after mid-run node join"
        assert ex.stats["joins"] == 1

    @pytest.mark.chaos
    @given(st.data())
    @settings(max_examples=3, deadline=None)
    def test_kill_with_long_k_chains_property(data):
        """Accumulate chains that migrate across nodes mid-chain survive
        a node death: bitwise vs per-task executor, oracle at the
        documented multi-k-tile tolerance."""
        dtype = data.draw(st.sampled_from([np.float64, np.float32]))
        tile = data.draw(st.integers(3, 6))
        k = data.draw(st.integers(tile + 1, 3 * tile))
        m = data.draw(st.integers(2, 10))
        n = data.draw(st.integers(2, 10))
        expr = (CM.rand(m, k, seed=0, dtype=dtype) @
                CM.rand(k, n, seed=1, dtype=dtype)).relu() + \
            CM.rand(m, n, seed=2, dtype=dtype)
        plan = _plan(expr, tile=tile, spec=HET_SPEC)
        total = len(plan.program.graph)
        kill_at = data.draw(st.integers(1, max(1, total - 2)))
        ref = LocalExecutor().execute(plan)
        ex = ElasticClusterExecutor(
            timemodel=TM,
            chaos=[ChaosEvent(after_done=kill_at, kill_node=1)])
        out = ex.execute(plan)
        assert np.array_equal(ref, out)
        tol = 1e-4 if dtype == np.float32 else 1e-9
        np.testing.assert_allclose(out, expr.eager(), rtol=tol, atol=tol)


# -- acceptance: every paper workload survives a mid-run SIGKILL -------------

@pytest.mark.slow
@pytest.mark.chaos
def test_paper_suite_kill_one_node_bitwise():
    """On the heterogeneous 3-node spec, killing a node mid-run yields
    results bitwise-identical to ``LocalExecutor`` for every paper-suite
    workload (the PR's acceptance criterion)."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    from cmm_suite import BENCHMARKS
    spec = hetero_spec((3, 2, 1), **FAST_NET)
    eng = CMMEngine(spec, TM, plan_cache=False)
    for name in sorted(BENCHMARKS):
        expr = BENCHMARKS[name](48)
        plan = eng.plan(expr, tile=16)
        ref = LocalExecutor().execute(plan)
        kill_at = max(1, len(plan.program.graph) // 3)
        ex = ElasticClusterExecutor(
            timemodel=TM,
            chaos=[ChaosEvent(after_done=kill_at, kill_node=1)])
        out = ex.execute(plan)
        assert out.dtype == ref.dtype, name
        assert np.array_equal(ref, out), \
            f"{name}: elastic result diverged after node death"
        assert ex.stats["deaths"] == 1, name
