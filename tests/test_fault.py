"""Fault tolerance: monitor, restart policy, elastic re-mesh math,
straggler economics via the CMM simulator."""
import numpy as np

from repro.configs.base import ParallelPlan
from repro.runtime.elastic import make_elastic_mesh, rebalance_microbatches
from repro.runtime.fault import (FaultConfig, FleetMonitor, RestartDecision,
                                 decide)


class Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_healthy_fleet_continues():
    clk = Clock()
    m = FleetMonitor(4, FaultConfig(), clock=clk)
    for p in range(4):
        m.heartbeat(p, 1.0)
    d = decide(m)
    assert d.action == "continue" and len(d.pods) == 4


def test_heartbeat_timeout_triggers_remesh():
    clk = Clock()
    m = FleetMonitor(4, FaultConfig(heartbeat_timeout_s=10), clock=clk)
    clk.t = 5
    for p in (0, 1, 2):
        m.heartbeat(p)
    clk.t = 20
    for p in (0, 1, 2):
        m.heartbeat(p)
    d = decide(m)
    assert d.action == "remesh"
    assert d.pods == [0, 1, 2]


def test_explicit_failure():
    m = FleetMonitor(2, FaultConfig(min_pods=1))
    m.mark_failed(1)
    d = decide(m)
    assert d.action == "remesh" and d.pods == [0]


def test_abort_when_too_few_survivors():
    m = FleetMonitor(2, FaultConfig(min_pods=2))
    m.mark_failed(0)
    assert decide(m).action == "abort"


def test_straggler_detection_and_drop():
    cfg = FaultConfig(straggler_factor=1.5, straggler_patience=3)
    m = FleetMonitor(4, cfg)
    d = None
    for step in range(5):   # patience accrues across decision rounds
        for p in range(4):
            m.heartbeat(p, 1.0 if p else 4.0)   # pod 0 is 4x slower
        d = decide(m)
    assert d.action == "remesh"
    assert 0 not in d.pods


def test_straggler_economics_via_simulator():
    """Dropping a 4x straggler from 4 nodes should beat keeping it
    (quantified with the CMM machine-model simulator)."""
    from repro.core import (ClusteredMatrix as CM, CMMEngine,
                            analytic_time_model)
    from repro.core.machine import ClusterSpec
    n = 256
    expr = (CM.rand(n, n, seed=0) @ CM.rand(n, n, seed=1)) + \
        (CM.rand(n, n, seed=2) @ CM.rand(n, n, seed=3))
    tm = analytic_time_model()
    with_straggler = CMMEngine(
        ClusterSpec(n_nodes=4, slowdown=(4.0, 1.0, 1.0, 1.0)), tm,
        tile=n // 4).plan(expr).predicted_makespan
    without = CMMEngine(ClusterSpec(n_nodes=3), tm,
                        tile=n // 4).plan(expr).predicted_makespan
    assert without < with_straggler * 1.2


def test_elastic_mesh_shapes():
    mesh = make_elastic_mesh(1, model_parallel=1)
    assert mesh.shape["data"] == 1 and mesh.shape["model"] == 1


def test_rebalance_microbatches_preserves_global_batch():
    plan = ParallelPlan(microbatches=4)
    out = rebalance_microbatches(plan, global_batch=256, old_dp=32,
                                 new_dp=16)
    assert out.microbatches == 8
    assert (256 // 16) % out.microbatches == 0


def test_restart_budget():
    m = FleetMonitor(3, FaultConfig(max_restarts=1, min_pods=1))
    m.mark_failed(2)
    assert decide(m).action == "remesh"
    m.mark_failed(1)
    assert decide(m).action == "abort"


def test_monitor_default_config_not_shared():
    """Regression: ``FleetMonitor(n)`` used a mutable default
    (``cfg=FaultConfig()`` evaluated once at def time), so mutating one
    monitor's config leaked into every other default-constructed
    monitor."""
    a = FleetMonitor(2)
    b = FleetMonitor(2)
    assert a.cfg is not b.cfg
    a.cfg.heartbeat_timeout_s = 0.001
    assert b.cfg.heartbeat_timeout_s == FaultConfig().heartbeat_timeout_s
    # an explicit config is still honoured by reference
    shared = FaultConfig(min_pods=3)
    assert FleetMonitor(4, shared).cfg is shared
