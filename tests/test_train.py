"""Training semantics: loss decreases, microbatch equivalence, optimizers,
gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from dataclasses import replace

from repro.configs.base import ParallelPlan, get_plan, get_reduced
from repro.data.pipeline import DataConfig, make_batch
from repro.models import lm as M
from repro.optim.adamw import OptConfig
from repro.optim import compress as C
from repro.train.steps import TrainHParams, make_train_step


def _setup(arch="qwen3-8b", mb=1, **plan_kw):
    cfg = get_reduced(arch)
    plan = replace(get_plan(arch, "default"), microbatches=mb, **plan_kw)
    hp = TrainHParams(opt=OptConfig(lr=5e-3, warmup=5, decay_steps=100))
    step, init_opt = make_train_step(cfg, plan, hp=hp)
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, plan, jax.jit(step), init_opt, params


def test_loss_decreases_over_steps():
    cfg, plan, step, init_opt, params = _setup()
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=1)
    opt = init_opt(params)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_microbatch_equivalence():
    """mb=2 grad accumulation ~ mb=1 on the same global batch."""
    cfg, plan1, step1, init1, params = _setup(mb=1)
    _, plan2, step2, init2, _ = _setup(mb=2)
    dcfg1 = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=3)
    dcfg2 = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=3,
                       microbatches=2)
    b1 = {k: jnp.asarray(v) for k, v in make_batch(dcfg1, 0).items()}
    b2 = {k: jnp.asarray(v) for k, v in make_batch(dcfg2, 0).items()}
    p1, _, m1 = step1(params, init1(params), b1)
    p2, _, m2 = step2(params, init2(params), b2)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for k in p1:
        np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]),
                                   rtol=2e-4, atol=2e-5)


def test_adafactor_runs_and_learns():
    cfg, plan, step, init_opt, params = _setup(optimizer="adafactor")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=2)
    opt = init_opt(params)
    losses = []
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_adafactor_state_is_factored():
    cfg, plan, step, init_opt, params = _setup(optimizer="adafactor")
    opt = init_opt(params)
    p_bytes = sum(v.size * 4 for v in params.values())
    f_bytes = sum(np.prod(x.shape) * 4
                  for r_c in opt["f"].values() for x in r_c)
    assert f_bytes < 0.25 * p_bytes  # factored: far below one moment


def test_grad_compression_roundtrip():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32),
         "b": jnp.asarray(rng.standard_normal((16,)), jnp.float32)}
    qs, err = C.compress_tree(g, C.init_errors(g))
    deq = C.decompress_tree(qs)
    for k in g:
        rel = np.abs(np.asarray(deq[k]) - np.asarray(g[k])).max() / \
            np.abs(np.asarray(g[k])).max()
        assert rel < 0.02  # int8 quantisation error bound
        np.testing.assert_allclose(
            np.asarray(g[k]), np.asarray(deq[k]) + np.asarray(err[k]),
            rtol=1e-5, atol=1e-6)  # error feedback is exact


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_error_feedback_buffers_match_param_width(dtype):
    """Error-feedback buffers allocate at the parameter's error width:
    f32 stays f32, half-width trees carry half-width residuals instead
    of silently doubling optimiser memory (the old behaviour allocated
    f32 unconditionally).  Feedback still accumulates in f32 and stays
    exact at the stored width."""
    rng = np.random.default_rng(2)
    g = {"w": jnp.asarray(rng.standard_normal((32, 32)), dtype),
         "b": jnp.asarray(rng.standard_normal((8,)), dtype)}
    errs = C.init_errors(g)
    want = jnp.float32 if dtype == jnp.float32 else dtype
    for k in g:
        assert errs[k].dtype == jnp.dtype(want), \
            f"{k}: error buffer dtype {errs[k].dtype} != {want}"
        assert errs[k].shape == g[k].shape
        assert not np.any(np.asarray(errs[k], np.float32))
    qs, new_err = C.compress_tree(g, errs)
    deq = C.decompress_tree(qs)
    for k in g:
        assert new_err[k].dtype == jnp.dtype(want)
        # feedback identity at the stored width: g ≈ deq + err within
        # the error buffer's own precision
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(g[k], np.float32),
            np.asarray(deq[k], np.float32)
            + np.asarray(new_err[k], np.float32),
            rtol=tol, atol=tol)


def test_compressed_training_still_learns():
    cfg, plan, step, init_opt, params = _setup(compress_grads=True)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=4)
    opt = init_opt(params)
    losses = []
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_grad_clip_bounds_update():
    cfg, plan, step, init_opt, params = _setup()
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=5)
    batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, 0).items()}
    _, _, m = step(params, init_opt(params), batch)
    assert float(m["grad_norm"]) > 0


def test_lr_schedule():
    from repro.optim.adamw import lr_at
    cfg = OptConfig(lr=1e-3, warmup=10, decay_steps=100, min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.asarray(1))) < 1e-3 * 0.2
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(lr_at(cfg, jnp.asarray(1000))) == pytest.approx(1e-4,
                                                                 rel=1e-2)
