"""Plan-cache keying and simulation-driven tuning regressions."""
import numpy as np

from repro.core import (ClusteredMatrix as CM, CMMEngine,
                        analytic_time_model, c5_9xlarge, tune_tile)

TM = analytic_time_model()


def _expr(n=96):
    return (CM.rand(n, n, seed=0) @ CM.rand(n, n, seed=1)) + \
        CM.rand(n, n, seed=2)


def test_plan_cache_key_includes_tile():
    """Satellite regression: two tiles of the same structure must MISS the
    structural plan cache against each other (distinct tiled programs), and
    each must HIT on a same-tile replan."""
    eng = CMMEngine(c5_9xlarge(2), TM, plan_cache=True)
    p16 = eng.plan(_expr(), tile=16)
    p32 = eng.plan(_expr(), tile=32)
    assert not p16.cache_hit and not p32.cache_hit
    assert len(p16.program.graph) != len(p32.program.graph)
    assert eng.plan_cache_misses == 2 and eng.plan_cache_hits == 0

    h16 = eng.plan(_expr(), tile=16)
    h32 = eng.plan(_expr(), tile=32)
    assert h16.cache_hit and h32.cache_hit
    assert len(h16.program.graph) == len(p16.program.graph)
    assert len(h32.program.graph) == len(p32.program.graph)
    # normalized tile forms share one cache slot
    assert eng.plan(_expr(), tile=(16, 16)).cache_hit


def test_plan_cache_hit_carries_strategy_metadata():
    eng = CMMEngine(c5_9xlarge(1), TM, plan_cache=True)
    p1 = eng.plan(_expr(), tile=16)
    p2 = eng.plan(_expr(), tile=16)
    assert p2.cache_hit
    assert p2.waves == p1.waves
    assert p2.batched_makespan == p1.batched_makespan
    assert p2.best_predicted_makespan == p1.best_predicted_makespan


def test_tune_tile_gets_distinct_plans_per_candidate():
    """Satellite: the §3.3 loop must cost each candidate on its OWN tiled
    program, not on a cache hit from a previous candidate."""
    eng = CMMEngine(c5_9xlarge(2), TM, plan_cache=True)
    root = _expr(120)
    cands = [12, 24, 60]
    result = tune_tile(eng, root, candidates=cands)
    assert sorted(c for c, _ in result.scores) == sorted(cands)
    # distinct tiles -> distinct task graphs -> distinct predicted costs
    costs = [s for _, s in result.scores]
    assert len(set(costs)) == len(costs), \
        "identical costs across tiles suggests plan-cache collisions"
    # and re-tuning hits the cache without changing the answer
    again = tune_tile(eng, root, candidates=cands)
    assert again.best == result.best
    assert eng.plan_cache_hits >= len(cands)


def test_engine_autotune_tile_consistent():
    eng = CMMEngine(c5_9xlarge(2), TM, plan_cache=True)
    root = _expr(120)
    best, scores = eng.autotune_tile(root, candidates=[12, 24, 60])
    assert best in scores
    assert scores[best] == min(scores.values())
    # scores come from each candidate's cheapest predicted strategy
    for c, s in scores.items():
        assert s == eng.plan(root, tile=c).best_predicted_makespan
