"""Memory-pressure tier: bounded arenas, tiered spill, admission control.

The contract (ISSUE: out-of-core tiles): give every node a byte budget
(``ClusterSpec.mem_bytes`` / ``node_mem``) and every memory-consuming
path must *survive* it — cold tiles spill to a CRC-checked disk tier and
fault back in transparently, so a bounded run is **bitwise identical**
to the unbounded oracle at the same tile size.  Plans whose minimum
working set cannot fit are re-planned at a smaller tile or rejected with
a structured ``MemoryBudgetExceeded`` naming the offending node — never
an OOM kill.  ``mem_squeeze``/``alloc_fail`` chaos drives the recovery
path on real worker processes.
"""
import numpy as np
import pytest

from repro.core import (ClusteredMatrix as CM, CMMEngine, TimeModel,
                        analytic_time_model)
from repro.core.cache import NodeCache
from repro.core.heft import min_resident_floor, peak_node_bytes
from repro.core.machine import MemoryBudgetExceeded, hetero_spec
from repro.core.session import CMMSession
from repro.core.simulator import predict_spill_seconds
from repro.exec.cluster import ClusterExecutor
from repro.exec.elastic import ChaosEvent, ElasticClusterExecutor
from repro.runtime.spill import (SpillCorrupt, SpillMiss, TileSpillStore,
                                 run_spill_dir)

TM = analytic_time_model()
FAST_NET = dict(link_bw=1e12, latency=1e-6)
SPEC3 = hetero_spec((3, 2, 1), **FAST_NET)

#: working set of the standard (A @ B) + A conformance program below
N = 96
WS = 3 * N * N * 8


def _expr(n=N):
    A = CM.rand(n, n, seed=0)
    B = CM.rand(n, n, seed=1)
    return (A @ B) + A


def _plan(spec, tile=16, expr=None):
    eng = CMMEngine(spec, TM, plan_cache=False)
    return eng.plan(expr if expr is not None else _expr(), tile=tile)


def _bounded_spec(budget=WS // 3):
    return hetero_spec((3, 2, 1), mem_bytes=float(budget), **FAST_NET)


# -- ClusterSpec budget accessors -------------------------------------------

def test_spec_mem_accessors():
    s = hetero_spec((2, 1), **FAST_NET)
    assert s.mem_at(0) is None
    b = _bounded_spec(1 << 20)
    assert b.mem_at(0) == 1 << 20 and b.mem_at(2) == 1 << 20
    sq = b.with_mem(1, 4096)
    assert sq.mem_at(1) == 4096 and sq.mem_at(0) == 1 << 20
    lifted = sq.with_mem(1, None)
    assert lifted.mem_at(1) == 1 << 20   # falls back to mem_bytes
    with pytest.raises(ValueError):
        b.with_mem(7, 1)
    # a joined node falls beyond node_mem and inherits mem_bytes
    j = sq.with_node(2)
    assert j.mem_at(j.n_nodes - 1) == 1 << 20


def test_memory_budget_exceeded_is_structured():
    e = MemoryBudgetExceeded(2, 4096, 1024)
    assert e.node == 2 and e.needed_bytes == 4096 and e.budget_bytes == 1024
    assert "node 2" in str(e) and "4096" in str(e)


# -- NodeCache: incremental byte totals + pinning ---------------------------

def test_nodecache_running_totals_match_recount():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    op = st.tuples(st.sampled_from(["put", "invalidate", "pin", "unpin"]),
                   st.integers(0, 2),          # node
                   st.integers(0, 7),          # key
                   st.integers(0, 4096))       # nbytes

    @given(ops=st.lists(op, max_size=60),
           cap=st.one_of(st.none(), st.integers(1, 8192)))
    @settings(max_examples=60, deadline=None)
    def run(ops, cap):
        c = NodeCache(3, capacity_bytes=cap)
        for (kind, node, key, nbytes) in ops:
            if kind == "put":
                c.put(node, key, nbytes)
            elif kind == "invalidate":
                c.invalidate(key)
            elif kind == "pin":
                c.pin(key)
            else:
                c.unpin(key)
            for n in range(3):
                assert c.bytes_at(n) == sum(c._c[n].values()), \
                    "running total drifted from the table"
                if cap is not None:
                    # over-capacity is only allowed for pinned entries or
                    # a single (fresh) entry that alone exceeds capacity
                    if c.bytes_at(n) > cap:
                        unpinned = [k for k in c._c[n] if not c.pinned(k)]
                        assert len(unpinned) <= 1 or all(
                            c.pinned(k) for k in list(c._c[n])[:-1])
        cl = c.clone()
        for n in range(3):
            assert cl.bytes_at(n) == c.bytes_at(n)

    run()


def test_nodecache_pin_exempts_from_eviction():
    c = NodeCache(1, capacity_bytes=100)
    c.put(0, "keep", 60)
    c.pin("keep")
    for i in range(8):
        c.put(0, f"junk{i}", 60)
    assert c.peek(0, "keep"), "pinned entry was evicted"
    c.unpin("keep")
    c.put(0, "more", 60)
    assert not c.peek(0, "keep"), "unpinned cold entry should evict"
    assert c.bytes_at(0) == sum(c._c[0].values())


# -- spill store: CRC round-trip --------------------------------------------

def test_spill_store_roundtrip_bitwise(tmp_path):
    st_ = TileSpillStore(str(tmp_path / "s"), "t")
    rng = np.random.default_rng(0)
    a = rng.standard_normal((17, 13))
    st_.spill("k", a)
    assert "k" in st_ and st_.live_files == 1
    back = st_.fault_in("k")
    assert np.array_equal(a, back) and a.dtype == back.dtype
    assert "k" not in st_          # fault-in consumes the entry
    with pytest.raises(SpillMiss):
        st_.fault_in("k")
    assert st_.destroy() == 0


def test_spill_store_crc_detects_corruption(tmp_path):
    st_ = TileSpillStore(str(tmp_path / "s"), "t")
    st_.spill("k", np.arange(64, dtype=np.float64))
    st_.corrupt("k")
    with pytest.raises(SpillCorrupt):
        st_.fault_in("k")


# -- pricing: TimeModel + simulator + admission -----------------------------

def test_timemodel_spill_write_bandwidth_roundtrips():
    import json
    tm = TimeModel.from_json(TM.to_json())
    assert tm.spill_write_bandwidth == TM.spill_write_bandwidth
    d = json.loads(TM.to_json())
    del d["spill_write_bandwidth"]     # legacy calibration files
    assert TimeModel.from_json(json.dumps(d)).spill_write_bandwidth == 1e9


def test_predict_spill_seconds_monotone():
    assert predict_spill_seconds(0, TM) == 0.0
    a = predict_spill_seconds(1 << 20, TM)
    b = predict_spill_seconds(1 << 24, TM)
    assert 0.0 < a < b


def test_peak_node_bytes_sanity():
    plan = _plan(hetero_spec((3, 2, 1), **FAST_NET))
    peaks = peak_node_bytes(plan.program.graph, plan.schedule)
    assert peaks and all(v >= 0 for v in peaks.values())
    tile_bytes = 16 * 16 * 8
    assert max(peaks.values()) >= tile_bytes
    for n in peaks:
        floor = min_resident_floor(plan.program.graph, plan.schedule, n)
        assert 0 <= floor <= peaks[n]


def test_admission_annotates_spill_price():
    eng = CMMEngine(_bounded_spec(WS // 3), TM, plan_cache=False)
    plan = eng.plan(_expr(), tile=16)
    assert plan.peak_bytes, "admission must record per-node peaks"
    assert plan.spill_bytes > 0 and plan.spill_seconds > 0.0
    # a generous budget prices to zero spill
    eng2 = CMMEngine(_bounded_spec(1 << 30), TM, plan_cache=False)
    plan2 = eng2.plan(_expr(), tile=16)
    assert plan2.spill_bytes == 0 and plan2.spill_seconds == 0.0


def test_admission_rejects_unsatisfiable_budget():
    eng = CMMEngine(_bounded_spec(10), TM, plan_cache=False)
    with pytest.raises(MemoryBudgetExceeded) as ei:
        eng.plan(_expr(32), tile=16)
    e = ei.value
    assert isinstance(e.node, int) and 0 <= e.node < 3
    assert e.needed_bytes > e.budget_bytes == 10


def test_admission_replans_smaller_tile_out_of_core():
    # one ADDMUL working set at tile 16 is 3*2048 = 6144 bytes > 4000,
    # so the plan must shrink until its floor fits the budget
    eng = CMMEngine(_bounded_spec(4000), TM, plan_cache=False)
    plan = eng.plan(_expr(64), tile=16)
    assert eng.plan_shrinks >= 1
    assert plan.tile < (16, 16)
    # bit-identity holds at the CHOSEN tile (a different tile size has a
    # different FP accumulation order, so eager is compared approximately)
    out = eng.run(_expr(64), tile=16)
    oracle = CMMEngine(SPEC3, TM, plan_cache=False)
    assert np.array_equal(out, oracle.run(_expr(64), tile=plan.tile))
    np.testing.assert_allclose(out, _expr(64).eager())


# -- bounded-arena bit-identity on real worker processes --------------------

@pytest.mark.slow
@pytest.mark.mempressure
def test_cluster_bounded_bitwise_vs_unbounded():
    """Acceptance: footprint >= 2x per-node budget completes bitwise
    equal to the unbounded oracle on the static cluster executor."""
    ref = ClusterExecutor().execute(_plan(SPEC3))
    ex = ClusterExecutor()
    out = ex.execute(_plan(_bounded_spec(WS // 3)))
    assert np.array_equal(ref, out)
    assert ex.stats["spill_writes"] > 0, "budget never exercised the spill"
    assert ex.stats["faults"] > 0
    assert ex.stats["leaked_spill_files"] == 0
    assert ex.stats["live_buffers"] == 0


@pytest.mark.mempressure
def test_cluster_bounded_xfer_heavy_chain_bitwise():
    """Regression: two matmul chains sharing a leaf plus a fused
    elementwise tail generate enough cross-node XFER traffic that,
    under a ws/3 budget, the source arenas cycle their whole LRU inside
    the master->consumer dispatch window.  Without source-side
    hold/release leases the name-based XFER retries livelock (the acked
    segment is re-evicted before the destination attaches, every
    time)."""
    A = CM.rand(N, N, seed=2)
    B = CM.rand(N, N, seed=3)
    expr = (A @ B + A.T @ B) * 2.0 - B
    ref = ClusterExecutor().execute(_plan(SPEC3, expr=expr))
    ex = ClusterExecutor()
    out = ex.execute(_plan(_bounded_spec(WS // 3), expr=expr))
    assert np.array_equal(ref, out)
    assert ex.stats["spill_writes"] > 0, "budget never exercised the spill"
    assert ex.stats["leaked_spill_files"] == 0
    assert ex.stats["live_buffers"] == 0


@pytest.mark.slow
@pytest.mark.mempressure
def test_elastic_bounded_bitwise_vs_unbounded():
    ref = ElasticClusterExecutor(timemodel=TM).execute(_plan(SPEC3))
    ex = ElasticClusterExecutor(timemodel=TM)
    out = ex.execute(_plan(_bounded_spec(WS // 3)))
    assert np.array_equal(ref, out)
    assert ex.stats["spill_writes"] > 0
    assert ex.stats["leaked_spill_files"] == 0
    assert ex.stats["tiles_lost"] == 0


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.mempressure
def test_elastic_mem_squeeze_midrun_bitwise():
    """Shrinking a node's budget mid-run forces eviction; the run stays
    bitwise correct and current_spec reflects the squeeze."""
    ref = ElasticClusterExecutor(timemodel=TM).execute(_plan(SPEC3))
    ex = ElasticClusterExecutor(
        timemodel=TM,
        chaos=(ChaosEvent(after_done=5, mem_squeeze=1,
                          squeeze_bytes=WS // 6),))
    out = ex.execute(_plan(SPEC3))
    assert np.array_equal(ref, out)
    assert ex.stats["squeezes"] == 1
    assert ex.stats["evictions"] > 0
    assert ex.current_spec.mem_at(1) == WS // 6


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.mempressure
def test_elastic_alloc_fail_retries_bitwise():
    """An injected allocation failure rides the bounded task/XFER retry
    path — the master recovers, never crashes."""
    ref = ElasticClusterExecutor(timemodel=TM).execute(_plan(SPEC3))
    ex = ElasticClusterExecutor(
        timemodel=TM,
        chaos=(ChaosEvent(after_done=3, alloc_fail=0, alloc_fail_nth=2),))
    out = ex.execute(_plan(SPEC3))
    assert np.array_equal(ref, out)
    assert ex.stats["task_retries"] + ex.stats["xfer_retries"] >= 1


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.mempressure
def test_elastic_squeeze_to_nothing_is_structured_error():
    """A squeeze below one tile's working set can never be survived —
    the run must fail with MemoryBudgetExceeded naming the node, not an
    OOM kill or a hang."""
    ex = ElasticClusterExecutor(
        timemodel=TM, timeout=120.0,
        chaos=(ChaosEvent(after_done=2, mem_squeeze=1,
                          squeeze_bytes=64),))
    with pytest.raises(MemoryBudgetExceeded) as ei:
        ex.execute(_plan(SPEC3))
    assert ei.value.node == 1


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.mempressure
def test_elastic_kill_composes_with_bounded_arena():
    """Spill/fault-in composes with the existing kill chaos: lineage
    recovery under a budget stays bitwise."""
    ref = ElasticClusterExecutor(timemodel=TM).execute(_plan(SPEC3))
    ex = ElasticClusterExecutor(
        timemodel=TM, chaos=(ChaosEvent(after_done=6, kill_node=2),))
    out = ex.execute(_plan(_bounded_spec(WS // 2)))
    assert np.array_equal(ref, out)
    assert ex.stats["deaths"] == 1


# -- sessions: persisted tiles under a budget -------------------------------

def _power_refs(n, k, tile):
    P = CM.rand(n, n, seed=0)
    u = CM.rand(n, 1, seed=1)
    e = u
    for _ in range(k):
        e = P @ e
    eng = CMMEngine(SPEC3, TM)
    return eng.run(e, tile=tile)


@pytest.mark.slow
@pytest.mark.mempressure
def test_session_cluster_bounded_bitwise_and_clean_close():
    ref = _power_refs(64, 3, 16)
    eng = CMMEngine(_bounded_spec(WS // 3), TM)
    s = CMMSession(eng, executor="cluster", tile=16)
    P = s.persist(CM.rand(64, 64, seed=0))
    u = s.persist(CM.rand(64, 1, seed=1))
    for _ in range(3):
        u = s.persist(P @ u)
    got = u.to_numpy()
    assert np.array_equal(got, ref)
    audit = s.close()
    assert audit["spill"]["leaked_spill_files"] == 0
    for node, st_ in audit["arena"].items():
        assert st_["live_buffers"] == 0
        assert st_["retained"] == 0
        assert st_.get("spill_files", 0) == 0


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.mempressure
def test_session_elastic_squeeze_persisted_workload():
    """A mid-run squeeze in session mode: results stay bitwise, the
    session re-plans follow-up runs against the squeezed current_spec,
    and close() audits clean (no leaked spill files)."""
    ref = _power_refs(64, 3, 16)
    eng = CMMEngine(_bounded_spec(WS), TM)
    s = CMMSession(eng, executor="elastic", tile=16)
    s._exec.chaos = (ChaosEvent(after_done=4, mem_squeeze=1,
                                squeeze_bytes=WS // 4),)
    P = s.persist(CM.rand(64, 64, seed=0))
    u = s.persist(CM.rand(64, 1, seed=1))
    for _ in range(3):
        u = s.persist(P @ u)
    got = u.to_numpy()
    assert np.array_equal(got, ref)
    audit = s.close()
    assert audit["spill"]["leaked_spill_files"] == 0
    for node, st_ in audit["arena"].items():
        assert st_["live_buffers"] == 0
        assert st_.get("spill_files", 0) == 0


@pytest.mark.slow
@pytest.mark.mempressure
def test_spill_dir_removed_after_oneshot_run():
    import os
    ex = ClusterExecutor()
    ex.execute(_plan(_bounded_spec(WS // 3)))
    assert ex.stats["spill_writes"] > 0
    assert ex.stats["leaked_spill_files"] == 0
    # the run-scoped spill directory itself is reaped
    root = os.path.dirname(run_spill_dir("probe"))
    if os.path.isdir(root):
        leftovers = [d for d in os.listdir(root)
                     if os.listdir(os.path.join(root, d))]
        assert not any(f"cmm{os.getpid()}_" in d for d in leftovers)
