"""Discrete-event simulator: resource limits, cache, zero-comm bound."""
import numpy as np

from repro.core import (ClusteredMatrix as CM, CMMEngine,
                        analytic_time_model, c5_9xlarge, simulate)
from repro.core.graph import TaskKind


def _plan(nodes=4, n=96, tile=24):
    A = CM.rand(n, n, seed=0)
    B = CM.rand(n, n, seed=1)
    expr = (A @ B) + (B @ A)
    eng = CMMEngine(c5_9xlarge(nodes), analytic_time_model(), tile=tile)
    return eng, eng.plan(expr)


def test_sim_deterministic():
    eng, plan = _plan()
    r1 = simulate(plan.program.graph, plan.schedule, eng.spec, eng.timemodel)
    r2 = simulate(plan.program.graph, plan.schedule, eng.spec, eng.timemodel)
    assert r1.makespan == r2.makespan
    assert len(r1.intervals) == len(r2.intervals)


def test_all_tasks_simulated_once():
    eng, plan = _plan()
    r = simulate(plan.program.graph, plan.schedule, eng.spec, eng.timemodel)
    assert len(r.intervals) == len(plan.program.graph)


def test_worker_capacity_respected():
    eng, plan = _plan()
    r = simulate(plan.program.graph, plan.schedule, eng.spec, eng.timemodel)
    events = []
    for iv in r.intervals:
        if iv.slot < 0:   # calloc is async (not a worker occupant)
            continue
        events.append((iv.start, 1, iv.node))
        events.append((iv.end, -1, iv.node))
    # ends release their slot before coincident starts claim it
    events.sort(key=lambda e: (e[0], e[1]))
    load = {}
    for t, d, node in events:
        load[node] = load.get(node, 0) + d
        assert load[node] <= eng.spec.worker_procs + 1e-9


def test_comm_capacity_respected():
    eng, plan = _plan(nodes=4)
    r = simulate(plan.program.graph, plan.schedule, eng.spec, eng.timemodel)
    events = []
    for tr in r.transfers:
        if tr.end <= tr.start:
            continue
        events.append((tr.start, 1, tr.src))
        events.append((tr.end, -1, tr.src))
        events.append((tr.start, 1, tr.dst))
        events.append((tr.end, -1, tr.dst))
    events.sort(key=lambda e: (e[0], e[1]))
    load = {}
    for t, d, node in events:
        load[node] = load.get(node, 0) + d
        assert load[node] <= eng.spec.comm_procs(node)


def test_zero_comm_is_lower_bound():
    eng, plan = _plan(nodes=4)
    with_comm = simulate(plan.program.graph, plan.schedule, eng.spec,
                         eng.timemodel)
    zero = simulate(plan.program.graph, plan.schedule, eng.spec,
                    eng.timemodel, zero_comm=True)
    assert zero.makespan <= with_comm.makespan + 1e-12


def test_deps_respected_in_sim():
    eng, plan = _plan()
    g = plan.program.graph
    r = simulate(g, plan.schedule, eng.spec, eng.timemodel)
    start = {iv.tid: iv.start for iv in r.intervals}
    end = {iv.tid: iv.end for iv in r.intervals}
    for t in g:
        for p in t.preds:
            assert end[p] <= start[t.tid] + 1e-9


def test_cache_absorbs_repeat_transfers():
    eng, plan = _plan(nodes=4)
    r = simulate(plan.program.graph, plan.schedule, eng.spec, eng.timemodel)
    seen = set()
    for tr in r.transfers:
        key = (tr.key, tr.dst)
        assert key not in seen, "same tile version transferred twice"
        seen.add(key)


def test_gantt_renders():
    eng, plan = _plan(nodes=2)
    txt = plan.sim.gantt(60)
    assert "n0.w0" in txt and "|" in txt


def test_stats_by_kind():
    eng, plan = _plan()
    stats = plan.sim.stats_by_kind()
    assert "addmul" in stats and stats["addmul"][0] > 0
