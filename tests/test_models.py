"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from dataclasses import replace

from repro.configs.base import (ARCH_IDS, SHAPES, cells, get_config,
                                get_plan, get_reduced)
from repro.models import lm as M
from repro.train.steps import make_train_step


def _batch(cfg, mb, b, s, seed=0):
    rng = np.random.default_rng(seed)
    lead = (mb, b) if mb > 1 else (b,)
    out = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, lead + (s,)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, lead + (s,)),
                              jnp.int32),
        "mask": jnp.ones(lead + (s,), jnp.float32),
    }
    if cfg.enc_dec:
        out["frames"] = jnp.asarray(
            rng.standard_normal(lead + (cfg.enc_frames, cfg.d_model)),
            jnp.float32)
    if cfg.vision_patches:
        out["patches"] = jnp.asarray(
            rng.standard_normal(lead + (cfg.vision_patches, cfg.d_model)),
            jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_reduced(arch)
    plan = replace(get_plan(arch, "default"), microbatches=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    step, init_opt = make_train_step(cfg, plan)
    opt = init_opt(params)
    batch = _batch(cfg, 2, 2, 32)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    for k, v in p2.items():
        assert v.shape == params[k].shape
        assert np.isfinite(np.asarray(v, np.float32)).all(), k


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_forward_shapes(arch):
    cfg = get_reduced(arch)
    plan = get_plan(arch, "default")
    res = M.Resolver(plan, None)
    params = M.init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    rng = np.random.default_rng(0)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    kw = {}
    if cfg.enc_dec:
        kw["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_frames, cfg.d_model)),
            jnp.float32)
    if cfg.vision_patches:
        kw["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_patches, cfg.d_model)),
            jnp.float32)
    logits, aux, prefix = M.forward(cfg, plan, res, params, toks, **kw)
    want_s = S + prefix if not cfg.vision_patches else logits.shape[1]
    assert logits.shape[0] == B
    assert logits.shape[-1] == cfg.vocab_padded()
    assert np.isfinite(np.asarray(logits)).all()
    if cfg.is_moe:
        assert float(aux) > 0  # load-balance loss present


def test_param_counts_match_instantiated():
    """param_counts() (used for 6ND) ~ matches actual param tree size."""
    for arch in ["qwen3-8b", "olmoe-1b-7b", "xlstm-1.3b", "hymba-1.5b"]:
        cfg = get_reduced(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        actual = M.param_count(params)
        est = cfg.param_counts()["total"]
        # estimate ignores padding/norm minutiae; must be within 25 %
        assert abs(actual - est) / actual < 0.25, (arch, actual, est)


def test_full_config_param_counts():
    """Full configs land near their nameplate sizes."""
    checks = {
        "qwen3-8b": (8e9, 0.25),
        "qwen2.5-32b": (32e9, 0.25),
        "nemotron-4-340b": (340e9, 0.15),
        "qwen3-moe-235b-a22b": (235e9, 0.15),
    }
    for arch, (want, tol) in checks.items():
        n = get_config(arch).param_counts()["total"]
        assert abs(n - want) / want < tol, (arch, n)


def test_moe_active_params_smaller():
    cfg = get_config("qwen3-moe-235b-a22b")
    c = cfg.param_counts()
    assert c["active"] < 0.2 * c["total"]


def test_long_context_gating():
    assert "long_500k" in cells("xlstm-1.3b")
    assert "long_500k" in cells("hymba-1.5b")
    assert "long_500k" not in cells("qwen3-8b")
    assert "long_500k" not in cells("whisper-large-v3")
    for arch in ARCH_IDS:
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(cells(arch))


def test_resolver_divisibility_rule():
    plan = get_plan("qwen3-8b", "train_4k")
    devs = np.array(jax.devices() * 16)[:16].reshape(2, 8)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    res = M.Resolver(plan, mesh)
    # 20 not divisible by 8 -> dropped
    assert res.spec(("heads",), (20,))[0] is None
    # 64 divisible by 8 -> sharded
    assert res.spec(("heads",), (64,))[0] == "model"
    assert ("heads", 20, ("model",)) in res.dropped
