"""Fused matmul epilogues: fusion pass, tiling, costing, all executors.

The tentpole invariant: attaching a single-consumer elementwise chain to
its MATMUL as an epilogue program must change *nothing* about the
numbers on the strict-precision numpy backends — the epilogued plan runs
the identical ``eval_fused`` instruction sequence on the identical
accumulated C tiles, just without materialising the intermediate, so
fused and unfused executions are bitwise equal (f64 and f32).  The
Pallas legs accumulate in f32 VMEM and are validated at tolerance, like
the pre-existing plain addmul kernel; the bf16 mixed-precision leg is
opt-in and gated by a documented allclose tolerance (TESTING.md).
"""
import numpy as np
import pytest

from repro.core import (ClusteredMatrix as CM, CMMEngine,
                        analytic_time_model, c5_9xlarge)
from repro.core.fusion import (eval_fused, fused_flops, fused_op_count,
                               optimize, optimize_many)
from repro.core.graph import TaskKind, matmul_epilogue, matmul_flags
from repro.core.lazy import Op
from repro.core.tiling import tile_expression_many
from repro.core.timemodel import CostCache

TM = analytic_time_model()


def _engine(nodes=2, **kw):
    return CMMEngine(c5_9xlarge(nodes), TM, **kw)


def _chain(dtype=np.float64, m=48, k=64, n=32):
    A = CM.rand(m, k, seed=1, dtype=dtype)
    B = CM.rand(k, n, seed=2, dtype=dtype)
    C = CM.rand(m, n, seed=3, dtype=dtype)
    return ((A @ B) + C).relu() * 2.0


# -- the fusion pass ----------------------------------------------------------

def test_epilogue_folds_chain_into_matmul():
    expr = _chain()
    opt, rep = optimize(expr)
    assert opt.op is Op.MATMUL
    epi = matmul_epilogue(opt.payload)
    assert epi is not None
    assert rep.epilogues_fused == 1
    # relu, scale, add -> 3 fused ops riding the matmul
    assert rep.epilogue_ops == fused_op_count(epi) == 3
    # slot 0 is the accumulator; C is the one extra parent
    assert len(opt.parents) == 3


def test_epilogue_respects_multi_consumer_matmul():
    A = CM.rand(16, 16, seed=1)
    B = CM.rand(16, 16, seed=2)
    M = A @ B
    expr = M.relu() + M.ewise("tanh")      # M feeds two separate regions
    opt, rep = optimize(expr)
    # elementwise fusion first merges both consumers into ONE region with
    # M as a single deduped external -> M becomes single-consumer and the
    # whole thing legally rides the matmul
    assert rep.epilogues_fused == 1
    out = _engine().run(expr, tile=8)
    np.testing.assert_array_equal(
        out, _engine(fuse_epilogue=False).run(expr, tile=8))


def test_epilogue_preserves_transpose_flags():
    A = CM.rand(64, 48, seed=4)
    B = CM.rand(64, 32, seed=5)
    expr = (A.T @ B).relu()
    opt, _ = optimize(expr)
    assert matmul_flags(opt.payload) == (True, False)
    assert matmul_epilogue(opt.payload) is not None


def test_second_matmul_stays_materialized_extra():
    A = CM.rand(16, 16, seed=1)
    B = CM.rand(16, 16, seed=2)
    C = CM.rand(16, 16, seed=3)
    expr = (A @ B) + (A @ C)               # two matmuls, one consumer
    opt, rep = optimize(expr)
    assert rep.epilogues_fused == 1
    # exactly one matmul became the anchor; the other is an extra parent
    assert sum(1 for p in opt.parents if p.op is Op.MATMUL) == 1


# -- tiling + costing ---------------------------------------------------------

def test_epilogue_rides_last_chain_task_only():
    roots, _ = optimize_many([_chain(m=32, k=48, n=32)])
    g = tile_expression_many(roots, (16, 16)).graph
    g.validate()
    tasks = list(g.tasks.values())
    epis = [t for t in tasks if t.kind is TaskKind.ADDMUL
            and matmul_epilogue(t.payload)]
    plain = [t for t in tasks if t.kind is TaskKind.ADDMUL
             and not matmul_epilogue(t.payload)]
    # 2x2 output grid, 3-step k-chains: 4 chain tails carry the epilogue
    assert len(epis) == 4 and len(plain) == 8
    assert all(len(t.ins) == 3 for t in epis)          # C tile wired in
    assert not any(t.kind is TaskKind.FUSED for t in tasks)


def test_fused_plan_has_strictly_fewer_tasks():
    r1, _ = optimize_many([_chain()])
    r0, _ = optimize_many([_chain()], fuse_epilogue=False)
    g1 = tile_expression_many(r1, (16, 16)).graph
    g0 = tile_expression_many(r0, (16, 16)).graph
    assert len(g1.tasks) < len(g0.tasks)


def test_epilogue_is_priced_into_addmul():
    roots, _ = optimize_many([_chain(m=32, k=48, n=32)])
    g = tile_expression_many(roots, (16, 16)).graph
    tasks = list(g.tasks.values())
    epi = next(t for t in tasks if t.kind is TaskKind.ADDMUL
               and matmul_epilogue(t.payload))
    plain = next(t for t in tasks if t.kind is TaskKind.ADDMUL
                 and not matmul_epilogue(t.payload))
    assert epi.flops > plain.flops
    assert TM.kernel_time(epi) > TM.kernel_time(plain)
    # memoized costing must key epilogued and plain signatures apart
    assert CostCache.signature(epi) != CostCache.signature(plain)


# -- executors: strict-precision bit identity ---------------------------------

@pytest.mark.parametrize("executor", ["local", "batched", "cluster"])
@pytest.mark.parametrize("dtype", [np.float64, np.float32])
def test_fused_bitwise_identical_to_unfused(executor, dtype):
    un = _engine(fuse_epilogue=False).run(_chain(dtype), tile=16,
                                          executor=executor)
    fu = _engine(fuse_epilogue=True).run(_chain(dtype), tile=16,
                                         executor=executor)
    np.testing.assert_array_equal(fu, un)
    assert fu.dtype == un.dtype == dtype


def test_mixed_dtype_chain_promotes_like_unfused():
    A = CM.rand(32, 32, seed=6, dtype=np.float32)
    B = CM.rand(32, 32, seed=7, dtype=np.float32)
    C = CM.rand(32, 32, seed=8, dtype=np.float64)
    expr = ((A @ B) + C).relu()
    un = _engine(fuse_epilogue=False).run(expr, tile=16)
    fu = _engine(fuse_epilogue=True).run(expr, tile=16)
    np.testing.assert_array_equal(fu, un)
    assert fu.dtype == np.float64


# -- Pallas legs (f32 VMEM accumulate: tolerance, not bitwise) ----------------

def test_pallas_kernel_epilogue_matches_numpy():
    kops = pytest.importorskip("repro.kernels.ops")
    rng = np.random.default_rng(0)
    c = rng.standard_normal((16, 16))
    a = rng.standard_normal((16, 48))
    b = rng.standard_normal((48, 16))
    d = rng.standard_normal((16, 16))
    prog = (("in", 0), ("in", 1), ("add", 0, 1),
            ("ewise", "relu", 2), ("scale", "mul", 2.0, 3))
    out = np.asarray(kops.addmul(c, a, b, epilogue=prog, extras=[d]))
    ref = np.maximum((c + a @ b) + d, 0.0) * 2.0
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_wave_pallas_epilogue_matches_numpy_backend():
    pytest.importorskip("jax")
    fu = _engine(fuse_epilogue=True).run(
        _chain(m=32, k=48, n=32), tile=16, executor="batched-pallas")
    ref = _engine(fuse_epilogue=True).run(
        _chain(m=32, k=48, n=32), tile=16, executor="batched")
    assert fu.dtype == ref.dtype
    np.testing.assert_allclose(fu, ref, rtol=1e-5, atol=1e-5)


def test_mixed_precision_is_optin_and_within_tolerance():
    pytest.importorskip("ml_dtypes")
    from repro.exec.batched import WaveExecutor
    eng = _engine()
    plan = eng.plan(_chain(), tile=16)
    out = WaveExecutor(backend="numpy", precision="mixed").execute(plan)
    assert out.dtype.name == "bfloat16"
    ref = _chain().eager()
    # documented bf16 tolerance (TESTING.md numerics tiers)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float64), ref,
                               rtol=2e-2, atol=2e-2)
    with pytest.raises(ValueError):
        WaveExecutor(precision="fast")


# -- eval_fused scratch reuse across mixed dtypes (satellite) -----------------

def test_eval_fused_scratch_reuse_mixed_dtypes():
    """Recycled scratch buffers must never leak across dtype boundaries:
    a f32 temp cannot be reused as the out= of a f64 ufunc (and inputs
    are never recycled at all)."""
    rng = np.random.default_rng(1)
    x64 = rng.standard_normal((8, 8))
    x32 = rng.standard_normal((8, 8)).astype(np.float32)
    prog = (("in", 0),                    # f64
            ("in", 1),                    # f32
            ("ewise", "relu", 0),         # f64 temp
            ("ewise", "tanh", 1),         # f32 temp
            ("add", 2, 3),                # promotes -> f64
            ("ewise", "exp", 4))
    in0, in1 = x64.copy(), x32.copy()
    out = eval_fused(prog, [in0, in1])
    ref = np.exp(np.maximum(x64, 0.0) + np.tanh(x32))
    np.testing.assert_array_equal(out, ref)
    assert out.dtype == np.float64
    # inputs were not written by the interpreter's buffer recycling
    np.testing.assert_array_equal(in0, x64)
    np.testing.assert_array_equal(in1, x32)


def test_eval_fused_reuse_disabled_for_int_inputs():
    x = np.arange(16).reshape(4, 4)       # int64: sin promotes to f64
    prog = (("in", 0), ("ewise", "sin", 0), ("ewise", "cos", 1))
    np.testing.assert_array_equal(eval_fused(prog, [x]),
                                  np.cos(np.sin(x)))


# -- fused_flops vs analytic counts (satellite; randomized programs) ----------

def _random_prog(rng, n_inputs):
    """A random well-formed FUSED program over ``n_inputs`` inputs."""
    instrs = [("in", i) for i in range(n_inputs)]
    ewise = ["sin", "cos", "exp", "tanh", "abs", "relu", "sqrt"]
    for _ in range(rng.integers(1, 8)):
        kind = rng.choice(["ewise", "scale", "add", "sub", "ewmul"])
        i = int(rng.integers(0, len(instrs)))
        j = int(rng.integers(0, len(instrs)))
        if kind == "ewise":
            instrs.append(("ewise", str(rng.choice(ewise)), i))
        elif kind == "scale":
            instrs.append(("scale", "mul", float(rng.uniform(0.5, 2)), i))
        else:
            instrs.append((kind, i, j))
    return tuple(instrs)


def _analytic_flops(prog, m, n):
    """Independent recount: 4 flops/elem per transcendental pass, 1 for
    arithmetic — the task_work/tiling convention."""
    total = 0
    for ins in prog:
        if ins[0] == "ewise":
            total += 4 * m * n
        elif ins[0] in ("scale", "add", "sub", "ewmul"):
            total += m * n
    return total


def test_fused_flops_matches_analytic_on_random_programs():
    rng = np.random.default_rng(7)
    for _ in range(50):
        prog = _random_prog(rng, int(rng.integers(1, 4)))
        m, n = int(rng.integers(1, 64)), int(rng.integers(1, 64))
        assert fused_flops(prog, m, n) == _analytic_flops(prog, m, n)


try:
    import hypothesis  # noqa: F401
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 10_000), m=st.integers(1, 128),
           n=st.integers(1, 128))
    @settings(max_examples=60, deadline=None)
    def test_fused_flops_matches_analytic_hypothesis(seed, m, n):
        rng = np.random.default_rng(seed)
        prog = _random_prog(rng, int(rng.integers(1, 4)))
        assert fused_flops(prog, m, n) == _analytic_flops(prog, m, n)

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_eval_fused_random_programs_match_reference(seed):
        """eval_fused with scratch recycling == naive interpretation."""
        rng = np.random.default_rng(seed)
        n_in = int(rng.integers(1, 4))
        prog = _random_prog(rng, n_in)
        dts = [rng.choice([np.float32, np.float64]) for _ in range(n_in)]
        xs = [rng.uniform(0.1, 2.0, (6, 5)).astype(dt) for dt in dts]
        from repro.core.lazy import EWISE_FNS, apply_scale
        vals = []
        for ins in prog:
            if ins[0] == "in":
                vals.append(xs[ins[1]])
            elif ins[0] == "ewise":
                vals.append(EWISE_FNS[ins[1]](vals[ins[2]]))
            elif ins[0] == "scale":
                vals.append(apply_scale(ins[1], vals[ins[3]], ins[2]))
            elif ins[0] == "add":
                vals.append(vals[ins[1]] + vals[ins[2]])
            elif ins[0] == "sub":
                vals.append(vals[ins[1]] - vals[ins[2]])
            elif ins[0] == "ewmul":
                vals.append(vals[ins[1]] * vals[ins[2]])
        out = eval_fused(prog, xs)
        np.testing.assert_array_equal(out, vals[-1])
        assert out.dtype == vals[-1].dtype
