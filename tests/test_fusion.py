"""Fusion optimizer + plan cache + zero-copy runtime.

Every rewrite pass is validated against ``eager()`` on mixed-op DAGs with
ragged tiles; CSE must not fuse multi-consumer nodes; the plan cache must
hit on structure and still compute with the *new* leaf data.
"""
import numpy as np
import pytest

from repro.core import (ClusteredMatrix as CM, CMMEngine,
                        analytic_time_model, c5_9xlarge)
from repro.core.fusion import (eval_fused, fuse_elementwise, optimize,
                               structural_signature, FusionReport)
from repro.core.graph import TaskKind
from repro.core.lazy import Op, leaf_slice, materialize_leaf, random_slice
from repro.exec.local import LocalExecutor

TM = analytic_time_model()


def _engine(nodes=2, tile=None, **kw):
    return CMMEngine(c5_9xlarge(nodes), TM, tile=tile, **kw)


def _check(expr, tile, nodes=2, **kw):
    eng = _engine(nodes, **kw)
    out = eng.run(expr, tile=tile)
    np.testing.assert_allclose(out, expr.eager(), rtol=1e-8, atol=1e-8)
    return eng


# -- elementwise fusion -------------------------------------------------------

def test_chain_fuses_to_one_task_per_tile():
    A = CM.rand(12, 12, seed=0)
    B = CM.rand(12, 12, seed=1)
    C = CM.rand(12, 12, seed=2)
    expr = ((A @ B).relu() * 2.0 + C).ewise("tanh")
    # epilogue fusion disabled: the chain stays a standalone FUSED region
    # (with it on, the whole chain rides the matmul — see test_epilogue.py)
    opt, rep = optimize(expr, fuse_epilogue=False)
    assert opt.op is Op.FUSED
    assert rep.fused_regions == 1 and rep.fused_ops == 4
    eng = _engine(fuse_epilogue=False)
    plan = eng.plan(expr, tile=5)          # ragged 12/5 grid
    counts = plan.program.graph.counts()
    assert counts.get("fused") == 9        # 3x3 tiles, one task each
    assert "ewise" not in counts and "scale" not in counts \
        and "add" not in counts
    _check(expr, tile=5, fuse_epilogue=False)


def test_fusion_reduces_task_count_2x_on_ewise_chain():
    A = CM.rand(16, 16, seed=0)
    C = CM.rand(16, 16, seed=1)
    e = A
    for _ in range(6):
        e = (e * 1.01 + 0.5).relu().hadamard(C)
    eng_f = _engine(fuse=True)
    eng_n = _engine(fuse=False)
    nf = len(eng_f.plan(e, tile=8).program.graph)
    nn = len(eng_n.plan(e, tile=8).program.graph)
    assert nn >= 2 * nf
    _check(e, tile=8)


def test_multi_consumer_node_not_inlined():
    """CSE/fusion must keep a shared subexpression as a real buffer."""
    A = CM.rand(10, 10, seed=0)
    S = (A * 3.0).relu()                  # used twice below
    expr = S.hadamard(S) + (S * 0.5)
    opt, rep = optimize(expr)
    # S's region is separate from the consumer region: S appears as an
    # external input (an Op node), not inlined into the root FUSED program
    assert opt.op is Op.FUSED
    shared = [p for p in opt.parents if p.op in (Op.FUSED, Op.EWISE)]
    assert len(shared) == 1
    _check(expr, tile=4)


def test_fused_ragged_and_mixed_dags():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((11, 7))
    b = rng.standard_normal((7, 13))
    c = rng.standard_normal((11, 13))
    A, B, C = CM.from_array(a), CM.from_array(b), CM.from_array(c)
    expr = (((A @ B) - C) * 0.25).ewise("sin") + (C * 2.0)
    for tile in (3, 4, 5, 11):
        _check(expr, tile=tile)


def test_fused_float32_dtype():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((9, 9)).astype(np.float32)
    A = CM.from_array(a)
    expr = (A @ A).relu() * 2.0
    eng = _engine()
    out = eng.run(expr, tile=4)
    assert out.dtype == np.float32        # CALLOC in expression dtype
    np.testing.assert_allclose(out, expr.eager(), rtol=1e-5, atol=1e-5)


def test_eval_fused_matches_naive():
    prog = (("in", 0), ("in", 1),
            ("add", 0, 1), ("scale", "mul", 2.0, 2),
            ("ewise", "tanh", 3), ("sub", 4, 0), ("ewmul", 5, 5))
    rng = np.random.default_rng(1)
    x, y = rng.standard_normal((6, 4)), rng.standard_normal((6, 4))
    got = eval_fused(prog, [x, y])
    want = (np.tanh((x + y) * 2.0) - x) ** 2
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)
    # inputs must not be clobbered by buffer reuse
    np.testing.assert_array_equal(x, rng.__class__(np.random.PCG64(1))
                                  .standard_normal((6, 4)))


# -- CSE / identity / transpose folds ----------------------------------------

def test_cse_merges_shared_structure():
    A = CM.rand(8, 8, seed=0)
    B = CM.rand(8, 8, seed=1)
    expr = (A @ B) + (A @ B)              # two distinct MATMUL nodes
    opt, rep = optimize(expr, fuse_epilogue=False)
    assert rep.cse_merged >= 1
    assert opt.parents[0] is opt.parents[1] or opt.op is Op.SCALE \
        or len({id(p) for p in opt.parents}) == 1
    _check(expr, tile=3)


def test_cse_distinguishes_different_seeds():
    A = CM.rand(8, 8, seed=0)
    B = CM.rand(8, 8, seed=1)             # same structure, different data
    expr = (A @ A) + (B @ B)
    opt, rep = optimize(expr)
    assert rep.cse_merged == 0
    _check(expr, tile=4)


@pytest.mark.parametrize("build", [
    lambda A: A + CM.zeros(10, 6),
    lambda A: CM.zeros(10, 6) + A,
    lambda A: A - CM.zeros(10, 6),
    lambda A: A @ CM.eye(6),
    lambda A: CM.eye(10) @ A,
    lambda A: A * 1.0,
    lambda A: A / 1.0,
    lambda A: A.T.T,
])
def test_identity_folds(build):
    A = CM.rand(10, 6, seed=5)
    expr = build(A)
    opt, rep = optimize(expr)
    assert opt is A
    _check(expr, tile=4)


def test_identity_fold_keeps_dtype_promotion():
    """float32 + float64 zeros promotes — folding must NOT change dtype."""
    a32 = CM.from_array(np.ones((4, 4), np.float32))
    expr = a32 + CM.zeros(4, 4)           # float64 zeros
    opt, _ = optimize(expr)
    assert opt.dtype == np.float64        # fold suppressed
    _check(expr, tile=2)


def test_transpose_folds_into_matmul():
    A = CM.rand(11, 7, seed=0)
    B = CM.rand(11, 13, seed=1)
    expr = A.T @ B
    eng = _engine()
    plan = eng.plan(expr, tile=4)
    counts = plan.program.graph.counts()
    assert "transpose" not in counts
    _check(expr, tile=4)
    # both flags + ragged tiles
    expr2 = (A.T @ B).T @ (A.T @ B)
    _check(expr2, tile=5)


def test_transpose_flag_costing_dims():
    A = CM.rand(8, 4, seed=0)
    B = CM.rand(8, 6, seed=1)
    eng = _engine()
    plan = eng.plan(A.T @ B, tile=4)
    for t in plan.program.graph:
        if t.kind is TaskKind.ADDMUL:
            m, n, k = t.dims()
            assert (m, k) == t.out.shape
            plan.program.graph.validate()


# -- canonical per-tile RNG ---------------------------------------------------

def test_random_slice_bit_identical_to_full():
    full = materialize_leaf(CM.rand(300, 150, seed=9))
    got = random_slice(9, (300, 150), np.float64, 17, 203, 40, 150)
    np.testing.assert_array_equal(got, full[17:203, 40:150])


def test_leaf_slice_eye_and_input_views():
    I = CM.eye(7)
    np.testing.assert_array_equal(leaf_slice(I, 2, 6, 0, 5),
                                  np.eye(7)[2:6, 0:5])
    a = np.arange(12.0).reshape(3, 4)
    v = leaf_slice(CM.from_array(a), 1, 3, 1, 4)
    assert v.base is not None and np.shares_memory(v, a)  # zero-copy view
    np.testing.assert_array_equal(v, a[1:3, 1:4])


def test_compute_matches_eager_across_tile_sizes():
    R = CM.rand(33, 21, seed=7)
    expr = (R @ R.T) * 0.5 + R @ R.T
    for tile in (4, 7, 16, 33):
        _check(expr, tile=tile)


# -- plan cache ---------------------------------------------------------------

def _iter_expr(seed):
    X = CM.rand(24, 24, seed=seed)
    v = CM.rand(24, 1, seed=seed + 1)
    return (X @ X) @ v + v


def test_plan_cache_hits_on_same_structure():
    eng = _engine(tile=8)
    p1 = eng.plan(_iter_expr(0))
    p2 = eng.plan(_iter_expr(100))        # new nodes, same structure
    assert not p1.cache_hit and p2.cache_hit
    assert eng.plan_cache_hits == 1 and eng.plan_cache_misses == 1
    assert p2.schedule is p1.schedule     # reused plan artefacts


def test_plan_cache_miss_on_different_structure():
    eng = _engine(tile=8)
    eng.plan(_iter_expr(0))
    X = CM.rand(24, 24, seed=0)
    p = eng.plan((X @ X) @ X)             # different shape structure
    assert not p.cache_hit


def test_plan_cache_miss_on_different_tile():
    eng = _engine()
    eng.plan(_iter_expr(0), tile=8)
    p = eng.plan(_iter_expr(0), tile=12)
    assert not p.cache_hit


def test_plan_cache_hit_computes_new_data():
    """The rebound plan must produce the NEW expression's values."""
    eng = _engine(tile=8)
    e1, e2 = _iter_expr(0), _iter_expr(42)
    out1 = eng.run(e1, plan=eng.plan(e1))
    p2 = eng.plan(e2)
    assert p2.cache_hit
    out2 = eng.run(e2, plan=p2)
    np.testing.assert_allclose(out1, e1.eager(), rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(out2, e2.eager(), rtol=1e-8, atol=1e-8)
    assert not np.allclose(out1, out2)    # genuinely different data


def test_structural_signature_ignores_input_values():
    a = CM.from_array(np.ones((5, 5)))
    b = CM.from_array(np.full((5, 5), 3.0))
    assert structural_signature(a @ a) == structural_signature(b @ b)
    c = CM.from_array(np.ones((5, 6)))
    assert structural_signature(a @ a) != structural_signature(c @ c.T)


# -- zero-copy runtime --------------------------------------------------------

def test_refcounted_buffers_bound_peak_memory():
    A = CM.rand(64, 64, seed=0)
    e = A
    for _ in range(8):
        e = (e * 1.001 + 0.1).relu()
    eng = _engine(1, fuse=False)          # unfused: many intermediates
    plan = eng.plan(e, tile=16)
    ex_free = LocalExecutor(workers=2)
    out_free = ex_free.execute(plan)
    ex_keep = LocalExecutor(workers=2, free_buffers=False)
    out_keep = ex_keep.execute(plan)
    np.testing.assert_allclose(out_free, out_keep, rtol=0, atol=0)
    np.testing.assert_allclose(out_free, e.eager(), rtol=1e-8, atol=1e-8)
    assert ex_free.stats["buffers_freed"] > 0
    assert ex_free.stats["peak_buffer_bytes"] < \
        ex_keep.stats["peak_buffer_bytes"]


def test_workers_default_from_plan_spec():
    eng = CMMEngine(c5_9xlarge(2), TM, tile=8)
    plan = eng.plan(_iter_expr(0))
    ex = LocalExecutor()
    ex.execute(plan)
    assert ex.stats["workers"] == 2 * eng.spec.worker_procs
    ex2 = LocalExecutor(workers=3)
    ex2.execute(plan)
    assert ex2.stats["workers"] == 3
