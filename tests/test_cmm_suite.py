"""Paper-suite conformance harness (see TESTING.md).

Every paper workload (``benchmarks/cmm_suite.py``: Markov, K-Means, Hill,
Leontief, DFT, Synth, Reachability, Hits) x executor backend (``local``,
``batched``, ``cluster``) x two tile sizes is checked against the eager
NumPy oracle:

* **executor x executor: bitwise.**  All backends issue the same NumPy
  kernels per tile in the same dependency order, so ``local``,
  ``batched`` and the multi-process ``cluster`` results must be
  ``np.array_equal`` (dtype included) — any divergence is a real bug.
* **vs the eager oracle: documented tolerance.**  Both tile sizes split
  the matmul inner dimension into multi-tile k-chains, which re-associates
  the GEMM reduction relative to one big BLAS call; that is the *only*
  sanctioned deviation, bounded at 1e-8/1e-10 in f64 (bitwise oracle
  identity for single-k-tile plans is asserted in
  ``tests/test_batched.py`` / ``tests/test_cluster.py`` property tests).

The cluster leg runs on a heterogeneous 3-node spec (3/2/1 workers) and
asserts every task executed in the worker process of its HEFT-assigned
node — the schedule is exercised for real, not just simulated.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
from cmm_suite import BENCHMARKS  # noqa: E402

from repro.core import CMMEngine, analytic_time_model  # noqa: E402
from repro.core.machine import hetero_spec             # noqa: E402
from repro.exec import make_executor                   # noqa: E402

pytestmark = pytest.mark.slow

TM = analytic_time_model()
SUITE_N = 48
#: two tile sizes: 24 -> 2x2 grid (aligned), 16 -> 3x3 grid (longer
#: k-chains, more cross-node traffic)
TILES = (24, 16)
#: heterogeneous cluster: unequal worker counts per node; near-free links
#: so HEFT spreads placements and the cluster leg really crosses nodes
SPEC = hetero_spec((3, 2, 1), link_bw=1e12, latency=1e-6)

_PLANS = {}


def _conformance_plan(workload: str, tile: int):
    """One plan per (workload, tile), shared by every backend leg so the
    executors are compared on the *same* schedule."""
    key = (workload, tile)
    if key not in _PLANS:
        expr = BENCHMARKS[workload](SUITE_N)
        eng = CMMEngine(SPEC, TM, plan_cache=False)
        _PLANS[key] = (expr, eng.plan(expr, tile=tile))
    return _PLANS[key]


@pytest.mark.parametrize("tile", TILES)
@pytest.mark.parametrize("workload", sorted(BENCHMARKS))
def test_conformance(workload, tile):
    expr, plan = _conformance_plan(workload, tile)
    oracle = expr.eager()

    out = {}
    execs = {}
    for backend in ("local", "batched", "cluster"):
        ex = make_executor(backend)
        out[backend] = ex.execute(plan)
        execs[backend] = ex

    # the documented-tolerance oracle check (k-chain re-association only)
    np.testing.assert_allclose(out["local"], oracle, rtol=1e-8, atol=1e-10)

    # executor x executor: bitwise, dtype included
    for backend in ("batched", "cluster"):
        assert out[backend].dtype == out["local"].dtype, backend
        assert np.array_equal(out["local"], out[backend]), \
            f"{backend} executor diverged bitwise from local on {workload}"

    # cluster leg: the HEFT placement was executed, not simulated
    sched_nodes = {tid: p.node
                   for tid, p in plan.schedule.placements.items()}
    st = execs["cluster"].stats
    assert st["exec_nodes"] == sched_nodes
    assert st["tasks_run"] == len(plan.program.graph)


@pytest.mark.parametrize("workload", sorted(BENCHMARKS))
def test_conformance_compressed_wire(workload):
    """Network-tier conformance leg: with the zlib wire codec FORCED on
    every cross-node transfer, the cluster backend must stay bitwise
    identical to local/eager — the tile path admits lossless codecs only
    (TESTING.md network tier), so compression must never show up in the
    numbers, only in the wire-byte counters."""
    expr, plan = _conformance_plan(workload, 16)
    oracle = expr.eager()
    local = make_executor("local").execute(plan)
    np.testing.assert_allclose(local, oracle, rtol=1e-8, atol=1e-10)
    ex = make_executor("cluster", wire_codec="zlib")
    out = ex.execute(plan)
    assert out.dtype == local.dtype
    assert np.array_equal(local, out), \
        f"compressed wire diverged bitwise from local on {workload}"
    if ex.stats["xfers"] > 0:
        assert ex.stats["xfers_compressed"] > 0
    assert ex.stats["stale_leases"] == 0


def test_suite_spreads_across_heterogeneous_nodes():
    """At least one workload/tile must genuinely use all three nodes —
    otherwise the conformance run would not exercise XFERs at all."""
    spread = set()
    xfers = 0
    for workload in sorted(BENCHMARKS):
        for tile in TILES:
            _, plan = _conformance_plan(workload, tile)
            spread |= {p.node for p in plan.schedule.placements.values()}
            xfers += len(plan.schedule.xfers(plan.program.graph))
    assert spread == {0, 1, 2}
    assert xfers > 0
