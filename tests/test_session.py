"""Session engine: resident distributed tiles across compute() calls.

Bit-identity contract (TESTING.md): a persisted k-step chain — persist
each step, feed the handle forward — must be **bitwise identical** to the
equivalent one-shot expression on the same backend, and (when every
matmul k-chain fits one tile) to the eager oracle, across the
``local``/``batched``/``cluster`` executors on the heterogeneous 3-node
spec.  Residency changes *where data lives between runs*, never what is
computed.
"""
import numpy as np
import pytest

from repro.core import ClusteredMatrix as CM, CMMEngine, analytic_time_model
from repro.core.graph import TaskKind
from repro.core.machine import hetero_spec, local_spec
from repro.core.session import CMMSession, ResidentMatrix

TM = analytic_time_model()
#: the conformance spec: unequal worker counts, near-free links so HEFT
#: spreads placements and resident tiles genuinely live on several nodes
SPEC3 = hetero_spec((3, 2, 1), link_bw=1e12, latency=1e-6)


def _engine(spec=None, **kw):
    return CMMEngine(spec or local_spec(1), TM, **kw)


def _power_iter_oneshot(n, k, tile, eng, executor="local"):
    P = CM.rand(n, n, seed=0)
    u = CM.rand(n, 1, seed=1)
    e = u
    for _ in range(k):
        e = P @ e
    return eng.run(e, tile=tile, executor=executor)


# -- basics -----------------------------------------------------------------

def test_persist_returns_resident_leaf():
    with CMMSession(_engine(), tile=16) as s:
        A = s.persist(CM.rand(32, 32, seed=0), name="A")
        assert isinstance(A, ResidentMatrix)
        assert A.shape == (32, 32)
        assert A.handle.grid == (2, 2)
        assert set(A.handle.home.values()) == {0}
        np.testing.assert_array_equal(A.to_numpy(),
                                      CM.rand(32, 32, seed=0).eager())


def test_session_power_iteration_bitwise_vs_oneshot():
    n, k, tile = 48, 4, 16
    eng = _engine()
    with CMMSession(eng, tile=tile) as s:
        P = s.persist(CM.rand(n, n, seed=0))
        u = s.persist(CM.rand(n, 1, seed=1))
        for _ in range(k):
            u = s.persist(P @ u)
        got = u.to_numpy()
    ref = _power_iter_oneshot(n, k, tile, _engine())
    assert np.array_equal(got, ref)


def test_resident_graph_has_no_fill_or_takecopy_for_residents():
    eng = _engine()
    with CMMSession(eng, tile=16) as s:
        P = s.persist(CM.rand(32, 32, seed=0))
        u = s.persist(CM.rand(32, 1, seed=1))
        s.persist(P @ u)
        st = s.stats["last_exec"]
        # the persisted step ran RESIDENT binds instead of FILLs, and no
        # TAKECOPY gather at all
        plan = eng.plan_many([P @ u], tile=16, persist=(0,))
        counts = plan.program.graph.counts()
        assert counts.get("resident", 0) == 4 + 2   # P (2x2) + u (2x1) tiles
        assert "fill" not in counts
        assert "takecopy" not in counts
        assert st["gather_bytes"] == 0


def test_session_fewer_tasks_and_zero_gather_than_oneshot():
    n, tile = 48, 16
    eng = _engine()
    P1 = CM.rand(n, n, seed=0)
    u1 = CM.rand(n, 1, seed=1)
    oneshot_plan = eng.plan(P1 @ u1, tile=tile)
    oneshot_tasks = len(oneshot_plan.program.graph)
    with CMMSession(eng, tile=tile) as s:
        P = s.persist(CM.rand(n, n, seed=0))
        u = s.persist(CM.rand(n, 1, seed=1))
        s.persist(P @ u)
        step_tasks = s.stats["last_exec"]["tasks_run"]
        assert step_tasks < oneshot_tasks
        assert s.stats["last_exec"]["gather_bytes"] == 0


def test_session_plan_cache_hits_across_steps():
    """Each persisted step has the same structure + residency layout, so
    the second and later steps must hit the structural plan cache."""
    eng = _engine()
    with CMMSession(eng, tile=16) as s:
        P = s.persist(CM.rand(48, 48, seed=0))
        u = s.persist(CM.rand(48, 1, seed=1))
        u = s.persist(P @ u)
        misses0 = eng.plan_cache_misses
        hits0 = eng.plan_cache_hits
        for _ in range(3):
            u = s.persist(P @ u)
        assert eng.plan_cache_misses == misses0
        assert eng.plan_cache_hits == hits0 + 3


def test_compute_many_shared_cse():
    """Two roots sharing a subexpression plan as ONE program: the shared
    matmul is computed once (shared CSE), and both results are exact."""
    A = CM.rand(32, 32, seed=0)
    B = CM.rand(32, 32, seed=1)
    AB = A @ B
    r1 = AB + A
    r2 = AB - B
    eng = _engine()
    with CMMSession(eng, tile=16) as s:
        out1, out2 = s.compute_many([r1, r2])
    np.testing.assert_allclose(out1, r1.eager(), rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(out2, r2.eager(), rtol=1e-8, atol=1e-8)
    plan = eng.plan_many([r1, r2], tile=16)
    merged = len(plan.program.graph)
    sep = len(eng.plan(r1, tile=16).program.graph) + \
        len(eng.plan(r2, tile=16).program.graph)
    assert merged < sep


def test_tile_mismatch_falls_back_to_gather():
    """A handle persisted at one tile size re-enters a differently-tiled
    program as a gathered INPUT leaf — correct, just not zero-cost."""
    with CMMSession(_engine(), tile=16) as s:
        A = s.persist(CM.rand(32, 32, seed=0))
        out = s.compute(A + A, tile=8)
    ref = CM.rand(32, 32, seed=0).eager()
    np.testing.assert_array_equal(out, ref + ref)


@pytest.mark.parametrize("executor", ["local", "batched"])
def test_persisted_handle_is_a_snapshot(executor):
    """A resident handle owns its memory: mutating the user array after
    persisting an INPUT-rooted expression must not change the handle
    (view-backed tiles are copied at retention)."""
    eng = _engine()
    with CMMSession(eng, executor=executor, tile=16) as s:
        a = np.ones((32, 32))
        P = s.persist(CM.from_array(a))
        a[:] = 99.0
        assert np.all(P.to_numpy() == 1.0)


def test_free_and_foreign_handle_errors():
    s1 = CMMSession(_engine(), tile=16)
    s2 = CMMSession(_engine(), tile=16)
    A = s1.persist(CM.rand(16, 16, seed=0))
    with pytest.raises(ValueError, match="does not belong"):
        s2.compute(A + 1.0)
    A.free()
    with pytest.raises(ValueError, match="freed"):
        s1.compute(A + 1.0)
    s1.close()
    s2.close()


def test_engine_run_unchanged_one_shot():
    """compute() stays a thin one-shot wrapper: no session, no residency."""
    expr = (CM.rand(32, 32, seed=0) @ CM.rand(32, 32, seed=1)) * 0.5
    out = _engine().run(expr, tile=16)
    np.testing.assert_allclose(out, expr.eager(), rtol=1e-8, atol=1e-8)


# -- batched backend --------------------------------------------------------

def test_session_batched_bitwise_vs_oneshot():
    n, k, tile = 48, 3, 16
    eng = _engine()
    with CMMSession(eng, executor="batched", tile=tile) as s:
        P = s.persist(CM.rand(n, n, seed=0))
        u = s.persist(CM.rand(n, 1, seed=1))
        for _ in range(k):
            u = s.persist(P @ u)
        got = u.to_numpy()
    ref = _power_iter_oneshot(n, k, tile, _engine(), executor="batched")
    assert np.array_equal(got, ref)


# -- cluster backend: resident tiles in worker shm arenas -------------------

@pytest.mark.slow
def test_session_cluster_three_runs_no_arena_leaks():
    """Acceptance: the long-lived cluster executor survives >= 3
    consecutive session runs; after every run the worker arenas hold
    exactly the retained tiles (refcount audit), and close() audits
    clean."""
    eng = _engine(SPEC3)
    s = CMMSession(eng, executor="cluster", tile=16)
    P = s.persist(CM.rand(48, 48, seed=0))
    u = s.persist(CM.rand(48, 1, seed=1))
    for _ in range(3):
        u = s.persist(P @ u)
        st = s.stats["last_exec"]
        assert st["live_buffers"] == 0, "arena leak: stray run buffers"
        assert st["cur_buffer_bytes"] == 0
        assert st["retained_tiles"] == 3       # this step's u tiles (3x1)
    got = u.to_numpy()
    ref = _power_iter_oneshot(48, 3, 16, _engine(SPEC3))
    assert np.array_equal(got, ref)
    audit = s.close()
    for node, st in audit["arena"].items():
        assert st["live_buffers"] == 0, f"node {node} leaked buffers"
        assert st["retained"] == 0, f"node {node} leaked retained tiles"


@pytest.mark.slow
def test_session_cluster_resident_tiles_stay_remote():
    """Resident tiles of a spread computation live on several nodes and
    re-enter pinned there — consuming them gathers nothing to master."""
    eng = _engine(SPEC3)
    with CMMSession(eng, executor="cluster", tile=16) as s:
        A = s.persist(CM.rand(96, 96, seed=0) @ CM.rand(96, 96, seed=1))
        assert len(set(A.handle.home.values())) > 1, \
            "expected resident tiles spread across nodes"
        out = s.compute(A + A)
        a = (CM.rand(96, 96, seed=0) @ CM.rand(96, 96, seed=1))
        ref = eng.run(a + a, tile=16)
        assert np.array_equal(out, ref)


# -- hypothesis: persisted chains vs one-shot vs oracle ---------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                     # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:
    # reuse the randomized-DAG strategies from the wave-executor tests
    from test_batched import _rand_expr, SAFE_EWISE

    def _chain_steps(draw, k, m, dtype, max_inner):
        """k step-builders f_i: each combines the fed-forward matrix with
        a fresh random sub-DAG (drawn with test_batched's strategy)."""
        steps = []
        for i in range(k):
            kind = draw(st.sampled_from(
                ["matmul_l", "matmul_r", "add", "ewmul", "scale", "ewise"]))
            sub = _rand_expr(draw, draw(st.integers(0, 1)), m, m, dtype,
                             max_inner)
            if kind == "matmul_l":
                steps.append(lambda x, s=sub: s @ x)
            elif kind == "matmul_r":
                steps.append(lambda x, s=sub: x @ s)
            elif kind == "add":
                steps.append(lambda x, s=sub: x + s)
            elif kind == "ewmul":
                steps.append(lambda x, s=sub: x.hadamard(s))
            elif kind == "scale":
                c = draw(st.sampled_from([0.5, -1.5, 2.0]))
                steps.append(lambda x, c=c: x * c)
            else:
                fn = draw(st.sampled_from(SAFE_EWISE))
                steps.append(lambda x, fn=fn: x.ewise(fn))
        return steps

    def _run_chain_property(data, executor, spec):
        dtype = data.draw(st.sampled_from([np.float64, np.float32]))
        m = data.draw(st.integers(2, 12))
        tile = data.draw(st.integers(m, 16))   # single-k-tile matmuls:
        k = data.draw(st.integers(2, 3))       # oracle stays bitwise
        steps = _chain_steps(data.draw, k, m, dtype, max_inner=tile)
        x0 = CM.rand(m, m, seed=data.draw(st.integers(0, 50)), dtype=dtype)

        # one-shot equivalent on the same backend
        e = x0
        for f in steps:
            e = f(e)
        eng_ref = _engine(spec)
        ref = eng_ref.run(e, tile=tile, executor=executor)

        eng = _engine(spec)
        with CMMSession(eng, executor=executor, tile=tile) as s:
            cur = s.persist(x0)
            for f in steps:
                cur = s.persist(f(cur))
            got = cur.to_numpy()
        assert got.dtype == ref.dtype
        assert np.array_equal(got, ref), \
            f"persisted chain diverged from one-shot on {executor}"
        eager = e.eager()
        assert np.array_equal(got, eager), \
            f"persisted chain diverged from the eager oracle on {executor}"

    @given(st.data())
    @settings(max_examples=15, deadline=None)
    def test_chain_bitwise_local(data):
        _run_chain_property(data, "local", SPEC3)

    @given(st.data())
    @settings(max_examples=15, deadline=None)
    def test_chain_bitwise_batched(data):
        _run_chain_property(data, "batched", SPEC3)

    @pytest.mark.slow
    @given(st.data())
    @settings(max_examples=5, deadline=None)
    def test_chain_bitwise_cluster(data):
        _run_chain_property(data, "cluster", SPEC3)


# -- elastic backend: lost resident tiles recompute from lineage ------------

@pytest.mark.chaos
def test_elastic_session_recomputes_lost_resident_from_lineage():
    """Acceptance: SIGKILL the node holding resident tiles mid-run; the
    session re-derives the handle from lineage on the survivors and the
    retried run is bit-identical."""
    from repro.exec.elastic import ChaosEvent
    spec = hetero_spec((2, 2), link_bw=1e12, latency=1e-6)
    eng = _engine(spec)
    s = CMMSession(eng, executor="elastic", tile=16)
    try:
        A = s.persist(CM.rand(96, 96, seed=0) @ CM.rand(96, 96, seed=1),
                      name="A")
        assert 1 in set(A.handle.home.values()), \
            "expected resident tiles on the victim node"
        s._exec.chaos = (ChaosEvent(after_done=3, kill_node=1),)
        out = s.compute(A @ A)
        s._exec.chaos = ()
        assert s.stats.get("recomputed_handles", 0) >= 1
        assert eng.spec.alive_nodes() == (0,)      # membership synced
        assert set(A.handle.home.values()) == {0}  # re-homed on survivor
        a = CM.rand(96, 96, seed=0) @ CM.rand(96, 96, seed=1)
        ref = _engine(spec).run(a @ a, tile=16)
        assert np.array_equal(out, ref)
        # the session keeps working after recovery
        out2 = s.compute(A + A)
        ref2 = _engine(spec).run(a + a, tile=16)
        assert np.array_equal(out2, ref2)
    finally:
        audit = s.close()
    for node, stx in (audit.get("arena") or {}).items():
        assert stx["live_buffers"] == 0
        assert stx["retained"] == 0


@pytest.mark.chaos
def test_elastic_session_marks_unused_handles_lost():
    """A handle NOT referenced by the failing run still loses its tiles
    when its home node dies; the session marks it lost after the run and
    the next use re-derives it from lineage."""
    from repro.exec.elastic import ChaosEvent
    spec = hetero_spec((2, 2), link_bw=1e12, latency=1e-6)
    eng = _engine(spec)
    with CMMSession(eng, executor="elastic", tile=16) as s:
        Q = s.persist(CM.rand(96, 96, seed=2) @ CM.rand(96, 96, seed=3),
                      name="Q")
        assert 1 in set(Q.handle.home.values())
        R = s.persist(CM.rand(48, 48, seed=4))
        s._exec.chaos = (ChaosEvent(after_done=2, kill_node=1),)
        s.compute(R + R)                   # does not read Q
        s._exec.chaos = ()
        assert Q.handle.lost
        q = Q.to_numpy()                   # lineage recompute on survivors
        ref = _engine(spec).run(
            CM.rand(96, 96, seed=2) @ CM.rand(96, 96, seed=3), tile=16)
        assert np.array_equal(q, ref)


@pytest.mark.chaos
def test_elastic_session_three_runs_bitwise():
    """Elastic session without churn: >= 3 consecutive runs over resident
    tiles, bitwise vs the one-shot path, clean audit."""
    spec = hetero_spec((2, 2), link_bw=1e12, latency=1e-6)
    eng = _engine(spec)
    with CMMSession(eng, executor="elastic", tile=16) as s:
        P = s.persist(CM.rand(48, 48, seed=0))
        u = s.persist(CM.rand(48, 1, seed=1))
        for _ in range(3):
            u = s.persist(P @ u)
        got = u.to_numpy()
    ref = _power_iter_oneshot(48, 3, 16, _engine(spec))
    assert np.array_equal(got, ref)
