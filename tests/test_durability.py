"""Durable sessions: tile checkpoint store, crash recovery, resume.

The oracle for every resume test is the bit-identity contract: whatever
mix of reload-from-disk and recompute-from-lineage the restore chooses,
the resumed session's matrices are bitwise equal to the uninterrupted
run — including after SIGKILL of the master and every worker
mid-``compute()`` (the ``chaos``-marked subprocess test).
"""
import glob
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

from repro.core.engine import CMMEngine
from repro.core.lazy import ClusteredMatrix as CM
from repro.core.machine import hetero_spec, local_spec
from repro.core.session import (CMMSession, ResidentTilesLost,
                                SessionUnrecoverable)
from repro.core.simulator import (predict_checkpoint_overhead,
                                  predict_recovery_cost,
                                  predict_reload_seconds)
from repro.core.timemodel import TimeModel, analytic_time_model
from repro.runtime.durability import (ShardCorrupt, TileCheckpointStore,
                                      pickle_expr, unpickle_expr)

TM = analytic_time_model()
SPEC3 = hetero_spec((3, 2, 1), link_bw=1e12, latency=1e-6)
SPEC2 = hetero_spec((2, 2), link_bw=1e12, latency=1e-6)


def _engine(spec=None, **kw):
    return CMMEngine(spec or local_spec(1), TM, **kw)


def _fresh(hid, arr, tile=(2, 2), lineage=None):
    """A minimal fresh-entry dict for TileCheckpointStore.save."""
    from repro.core.tiling import grid_of, tile_slices
    gm, gn = grid_of(arr.shape, tile)
    rows, cols = tile_slices(arr.shape[0], tile[0]), \
        tile_slices(arr.shape[1], tile[1])
    tiles = {(i, j): arr[rows[i][0]:rows[i][1], cols[j][0]:cols[j][1]]
             for i in range(gm) for j in range(gn)}
    return {"shape": arr.shape, "dtype": arr.dtype, "tile": tile,
            "grid": (gm, gn), "name": f"h{hid}", "lineage": lineage,
            "tiles": tiles}


# -- store unit tests --------------------------------------------------------

def test_store_roundtrip(tmp_path):
    st = TileCheckpointStore(str(tmp_path))
    a = np.arange(16, dtype=np.float64).reshape(4, 4)
    man = st.save(1, {7: _fresh(7, a)})
    assert st.snaps() == [1]
    got = np.empty_like(a)
    for i in range(2):
        for j in range(2):
            got[2 * i:2 * i + 2, 2 * j:2 * j + 2] = st.load_tile(man, 7, i, j)
    np.testing.assert_array_equal(got, a)
    assert st.handle_bytes(man, 7) == a.nbytes


def test_store_incremental_carry(tmp_path):
    """A carried handle's shards stay in the older snap_ directory —
    nothing is rewritten, the new manifest references across."""
    st = TileCheckpointStore(str(tmp_path))
    a = np.ones((4, 4))
    b = np.full((4, 4), 2.0)
    st.save(1, {1: _fresh(1, a)})
    man2 = st.save(2, {2: _fresh(2, b)}, carry=[1])
    assert man2["handles"]["1"]["tiles"]["0,0"]["path"].startswith("snap_1/")
    assert man2["handles"]["2"]["tiles"]["0,0"]["path"].startswith("snap_2/")
    np.testing.assert_array_equal(
        st.load_tile(man2, 1, 0, 0), np.ones((2, 2)))
    with pytest.raises(KeyError):
        st.save(3, {}, carry=[99])


def test_store_rotate_keeps_referenced_dirs(tmp_path):
    st = TileCheckpointStore(str(tmp_path))
    st.save(1, {1: _fresh(1, np.ones((4, 4)))})
    for s in (2, 3, 4, 5):
        st.save(s, {}, carry=[1])       # all carry from snap_1
    st.rotate(keep=2)
    assert 1 in st.snaps()              # still referenced by kept manifests
    assert 2 not in st.snaps() and 3 not in st.snaps()
    man = st.latest_intact()
    assert man["step"] == 5
    np.testing.assert_array_equal(st.load_tile(man, 1, 0, 0), np.ones((2, 2)))


def test_store_tmp_dir_invisible_and_fallback(tmp_path):
    """A crash mid-save leaves a .tmp dir readers never look at; a
    manifest referencing missing shards is not intact either way."""
    st = TileCheckpointStore(str(tmp_path))
    st.save(1, {1: _fresh(1, np.ones((4, 4)))})
    os.makedirs(tmp_path / "snap_2.tmp")
    (tmp_path / "snap_2.tmp" / "manifest.json").write_text("{trunc")
    assert st.snaps() == [1]
    # a published-looking snap with a torn shard set: skipped by intact
    st.save(3, {2: _fresh(2, np.zeros((4, 4)))}, carry=[1])
    os.unlink(glob.glob(str(tmp_path / "snap_3" / "h2_*.npy"))[0])
    assert st.latest_intact()["step"] == 1


def test_store_crc_detects_corruption(tmp_path):
    st = TileCheckpointStore(str(tmp_path))
    man = st.save(1, {1: _fresh(1, np.ones((4, 4)))})
    path = st.corrupt_shard(1)
    assert os.path.exists(path)
    with pytest.raises(ShardCorrupt):
        st.load_tile(man, 1, 0, 0)


def test_store_async_write_error_is_swallowed(tmp_path, monkeypatch):
    """A failed async write never raises into the compute path: it lands
    in write_errors and the previous snapshot stays the newest intact."""
    st = TileCheckpointStore(str(tmp_path))
    st.save(1, {1: _fresh(1, np.ones((4, 4)))})
    monkeypatch.setattr(np, "save",
                        lambda *a, **k: (_ for _ in ()).throw(OSError("disk")))
    st.save_async(2, {2: _fresh(2, np.zeros((4, 4)))})
    st.wait()
    assert st.write_errors
    assert st.latest_intact()["step"] == 1


def test_lineage_pickle_helpers_roundtrip():
    expr = CM.rand(8, 8, seed=0) @ CM.rand(8, 8, seed=1)
    back = unpickle_expr(pickle_expr(expr))
    assert back.shape == expr.shape and back.op is expr.op


# -- session durability (fast, local backend) --------------------------------

def test_durable_session_resume_bitwise(tmp_path):
    """Persist a chain, flush, resume in a fresh session: bit-identical
    under every restore policy."""
    with CMMSession(_engine(), tile=16,
                    checkpoint_dir=str(tmp_path)) as s:
        A = s.persist(CM.rand(48, 48, seed=0), name="A")
        u = s.persist(CM.rand(48, 1, seed=1), name="u")
        for i in range(3):
            u = s.persist(A @ u, name=f"u{i}")
        ref = u.to_numpy()
        s.flush_checkpoints()
    for policy in ("price", "reload", "recompute"):
        with CMMSession.resume(str(tmp_path), _engine(), tile=16,
                               policy=policy) as s2:
            got = s2.resident("u2").to_numpy()
            assert np.array_equal(got, ref), policy
            rep = s2.stats["resume"]
            assert sorted(rep["reloaded"] + rep["recomputed"]) == \
                sorted(int(h) for h in rep["reloaded"] + rep["recomputed"])
            if policy == "reload":
                assert not rep["recomputed"]
            if policy == "recompute":
                assert not rep["reloaded"]


def test_resumed_session_continues_computing(tmp_path):
    """A resumed session is a full session: the restored handles re-enter
    new expressions and further persists checkpoint again."""
    with CMMSession(_engine(), tile=16,
                    checkpoint_dir=str(tmp_path)) as s:
        s.persist(CM.rand(32, 32, seed=0), name="P")
        s.flush_checkpoints()
    with CMMSession.resume(str(tmp_path), _engine(), tile=16) as s2:
        P = s2.resident("P")
        Q = s2.persist(P @ P, name="Q")
        ref = Q.to_numpy()
        s2.flush_checkpoints()
    with CMMSession.resume(str(tmp_path), _engine(), tile=16) as s3:
        assert np.array_equal(s3.resident("Q").to_numpy(), ref)


def test_freed_handle_does_not_resurrect(tmp_path):
    with CMMSession(_engine(), tile=16,
                    checkpoint_dir=str(tmp_path)) as s:
        P = s.persist(CM.rand(32, 32, seed=0), name="P")
        s.persist(CM.rand(32, 32, seed=1), name="Q")
        P.free()                       # publishes a snapshot without P
        s.flush_checkpoints()
    with CMMSession.resume(str(tmp_path), _engine(), tile=16) as s2:
        with pytest.raises(KeyError):
            s2.resident("P")
        s2.resident("Q")


def test_corrupt_shard_degrades_to_lineage_recompute(tmp_path):
    with CMMSession(_engine(), tile=16,
                    checkpoint_dir=str(tmp_path)) as s:
        A = s.persist(CM.rand(48, 48, seed=0), name="A")
        u = s.persist(A @ CM.rand(48, 1, seed=1), name="u0")
        ref = u.to_numpy()
        hid = u.handle.hid
        s.flush_checkpoints()
    TileCheckpointStore(str(tmp_path)).corrupt_shard(hid)
    with CMMSession.resume(str(tmp_path), _engine(), tile=16,
                           policy="reload") as s2:
        rep = s2.stats["resume"]
        assert rep["corrupt_shards"] >= 1
        assert hid in rep["recomputed"]         # degraded, not failed
        assert np.array_equal(s2.resident("u0").to_numpy(), ref)


def test_corrupt_shard_without_lineage_is_unrecoverable(tmp_path):
    st = TileCheckpointStore(str(tmp_path))
    st.save(1, {1: _fresh(1, np.ones((4, 4)), lineage=None)})
    st.corrupt_shard(1)
    with pytest.raises(SessionUnrecoverable) as ei:
        CMMSession.resume(str(tmp_path), _engine(), tile=2)
    assert ei.value.hids == (1,)


def test_resume_without_checkpoint_raises(tmp_path):
    with pytest.raises(RuntimeError, match="no intact checkpoint"):
        CMMSession.resume(str(tmp_path), _engine(), tile=16)
    with pytest.raises(ValueError, match="policy"):
        CMMSession.resume(str(tmp_path), _engine(), tile=16, policy="bogus")


def test_resume_falls_back_to_prior_intact_snapshot(tmp_path):
    """A torn newest snapshot (crash mid-save) is skipped: resume restores
    the previous intact one and the session continues from there."""
    with CMMSession(_engine(), tile=16,
                    checkpoint_dir=str(tmp_path)) as s:
        s.persist(CM.rand(32, 32, seed=0), name="P")
        s.flush_checkpoints()
        ref = s.resident("P").to_numpy()
        s.persist(CM.rand(32, 32, seed=1), name="R")
        s.flush_checkpoints()
    st = TileCheckpointStore(str(tmp_path))
    newest = st.snaps()[-1]
    for f in glob.glob(str(tmp_path / f"snap_{newest}" / "*.npy")):
        os.unlink(f)                   # tear the newest snapshot
    with CMMSession.resume(str(tmp_path), _engine(), tile=16) as s2:
        assert s2.stats["resume"]["step"] < newest
        assert np.array_equal(s2.resident("P").to_numpy(), ref)


def test_checkpoint_every_batches_snapshots(tmp_path):
    with CMMSession(_engine(), tile=16, checkpoint_dir=str(tmp_path),
                    checkpoint_every=3) as s:
        for i in range(3):
            s.persist(CM.rand(16, 16, seed=i), name=f"m{i}")
        s.flush_checkpoints()
    st = TileCheckpointStore(str(tmp_path))
    # one snapshot from the batch of 3 persists (+ the explicit flush)
    assert len(st.snaps()) <= 2
    man = st.latest_intact()
    assert len(man["handles"]) == 3


def test_bounded_retry_raises_session_unrecoverable(monkeypatch):
    """Satellite: the lost-tiles retry loop is bounded — a loss the
    executor can never repair surfaces as SessionUnrecoverable carrying
    the lost hids, after max_retries + 1 attempts with backoff."""
    s = CMMSession(_engine(), tile=16, max_retries=2, retry_backoff_s=0.0)
    try:
        attempts = []

        def boom(*a, **k):
            attempts.append(1)
            raise ResidentTilesLost((41,), "injected loss")

        monkeypatch.setattr(s.engine, "execute_plan", boom)
        with pytest.raises(SessionUnrecoverable) as ei:
            s.compute(CM.rand(16, 16, seed=0))
        assert ei.value.hids == (41,)
        assert isinstance(ei.value.__cause__, ResidentTilesLost)
        assert len(attempts) == 3          # max_retries + 1
    finally:
        monkeypatch.undo()
        s.close()


# -- pricing: TimeModel fields and simulator legs ----------------------------

def test_timemodel_durability_fields_roundtrip():
    tm = TimeModel.from_json(TM.to_json())
    tm.spill_read_bandwidth = 123.0
    tm.checkpoint_write_overhead = 0.25
    rt = TimeModel.from_json(tm.to_json())
    assert rt.spill_read_bandwidth == 123.0
    assert rt.checkpoint_write_overhead == 0.25
    # old serialized models (without the fields) still load
    import json
    d = json.loads(TM.to_json())
    d.pop("spill_read_bandwidth"), d.pop("checkpoint_write_overhead")
    old = TimeModel.from_json(json.dumps(d))
    assert old.spill_read_bandwidth > 0


def test_predict_reload_and_overhead():
    tm = TimeModel.from_json(TM.to_json())
    tm.spill_read_bandwidth = 1e6
    assert predict_reload_seconds(2e6, tm) == pytest.approx(2.0)
    assert predict_checkpoint_overhead(2e6, tm) == \
        pytest.approx(2.0 + tm.checkpoint_write_overhead)


def test_predict_recovery_cost_caps_at_reload(tmp_path):
    """With checkpointed bytes available the recovery estimate is capped
    by the reload leg — recompute is only charged when it is cheaper."""
    eng = _engine(hetero_spec((2, 2), link_bw=1e9, latency=1e-4))
    plan = eng.plan_many([CM.rand(96, 96, seed=0) @ CM.rand(96, 96, seed=1)],
                         tile=32)
    g, sched, spec = plan.program.graph, plan.schedule, eng.spec
    tm = TimeModel.from_json(TM.to_json())
    slow = predict_recovery_cost(g, sched, spec, tm, 1)
    tm.spill_read_bandwidth = 1e30          # reload is ~free
    fast = predict_recovery_cost(g, sched, spec, tm, 1,
                                 checkpoint_bytes=96 * 96 * 8)
    assert fast <= slow
    assert fast >= tm.respawn_overhead


# -- chaos tier: cluster backends, full-cluster kill -------------------------

_CHILD = r"""
import sys
from repro.core.session import CMMSession
from repro.core.lazy import ClusteredMatrix as CM
from repro.core.engine import CMMEngine
from repro.core.timemodel import analytic_time_model
from repro.core.machine import hetero_spec
from repro.exec.elastic import ChaosEvent

d = sys.argv[1]
spec = hetero_spec((3, 2, 1), link_bw=1e12, latency=1e-6)
s = CMMSession(CMMEngine(spec, analytic_time_model()), executor="elastic",
               tile=16, checkpoint_dir=d)
A = s.persist(CM.rand(48, 48, seed=0), name="A")
u = s.persist(CM.rand(48, 1, seed=1), name="u")
u = s.persist(A @ u, name="u0")
u = s.persist(A @ u, name="u1")
s.flush_checkpoints()
print("flushed", flush=True)
s._exec.chaos = [ChaosEvent(after_done=2, kill_master=True)]
s.persist(A @ u, name="u2")     # SIGKILLed mid-compute, never returns
print("UNREACHABLE", flush=True)
"""


@pytest.mark.chaos
def test_full_cluster_sigkill_then_resume_bitwise(tmp_path):
    """Acceptance oracle: SIGKILL master + every worker mid-compute()
    (ChaosEvent(kill_master=True)), resume() on a DIFFERENT ClusterSpec,
    continue the chain — bitwise equal to the uninterrupted run."""
    child = tmp_path / "child.py"
    child.write_text(_CHILD)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    p = subprocess.run([sys.executable, str(child), str(tmp_path)],
                       env=env, capture_output=True, text=True, timeout=240)
    assert p.returncode == -signal.SIGKILL, (p.returncode, p.stderr[-800:])
    assert "flushed" in p.stdout
    assert "UNREACHABLE" not in p.stdout
    # reap shared memory the killed cluster may have stranded
    for f in glob.glob("/dev/shm/cmm*"):
        try:
            os.unlink(f)
        except OSError:
            pass

    with CMMSession.resume(str(tmp_path), _engine(SPEC2),
                           executor="elastic", tile=16) as s:
        rep = s.stats["resume"]
        assert sorted(rep["reloaded"] + rep["recomputed"])
        got = s.compute(s.resident("A") @ s.resident("u1"))
    ref = _power_chain_ref(48, 3)
    assert np.array_equal(got, ref)


def _power_chain_ref(n, k):
    P, v = CM.rand(n, n, seed=0), CM.rand(n, 1, seed=1)
    e = v
    for _ in range(k):
        e = P @ e
    return _engine().run(e, tile=16)


@pytest.mark.chaos
def test_elastic_resume_onto_different_spec_bitwise(tmp_path):
    """Durable elastic session on a 3-node cluster, resumed onto a 2-node
    cluster: tiles re-home into the new arenas, bytes unchanged."""
    with CMMSession(_engine(SPEC3), executor="elastic", tile=16,
                    checkpoint_dir=str(tmp_path)) as s:
        A = s.persist(CM.rand(48, 48, seed=0), name="A")
        u = s.persist(CM.rand(48, 1, seed=1), name="u")
        u = s.persist(A @ u, name="u0")
        ref = u.to_numpy()
        s.flush_checkpoints()
    with CMMSession.resume(str(tmp_path), _engine(SPEC2),
                           executor="elastic", tile=16,
                           policy="reload") as s2:
        h = s2.resident("u0").handle
        assert set(h.home.values()) <= set(SPEC2.alive_nodes())
        assert np.array_equal(s2.resident("u0").to_numpy(), ref)


@pytest.mark.chaos
def test_chaos_corrupt_tile_degrades_on_resume(tmp_path):
    """ChaosEvent(corrupt_tile=hid) flips a byte in the newest on-disk
    shard mid-run; the next resume detects the CRC mismatch and degrades
    that handle to lineage recompute — no wrong bytes survive."""
    from repro.exec.elastic import ChaosEvent
    with CMMSession(_engine(SPEC2), executor="elastic", tile=16,
                    checkpoint_dir=str(tmp_path)) as s:
        A = s.persist(CM.rand(48, 48, seed=0), name="A")
        u = s.persist(A @ CM.rand(48, 1, seed=1), name="u0")
        ref = u.to_numpy()
        s.flush_checkpoints()
        s._exec.chaos = [ChaosEvent(after_done=1,
                                    corrupt_tile=u.handle.hid)]
        s.compute(A + A)               # fires the corruption mid-run
        s._exec.chaos = ()
        hid = u.handle.hid
    with CMMSession.resume(str(tmp_path), _engine(), tile=16,
                           policy="reload") as s2:
        rep = s2.stats["resume"]
        assert rep["corrupt_shards"] >= 1 and hid in rep["recomputed"]
        assert np.array_equal(s2.resident("u0").to_numpy(), ref)


def test_chaos_corrupt_tile_requires_durable_session():
    from repro.exec.elastic import ChaosEvent
    with CMMSession(_engine(SPEC2), executor="elastic", tile=16) as s:
        s._exec.chaos = [ChaosEvent(after_done=0, corrupt_tile=1)]
        with pytest.raises(ValueError, match="durable session"):
            s.compute(CM.rand(32, 32, seed=0))
        s._exec.chaos = ()


@pytest.mark.chaos
def test_chaos_dropped_xfer_retries_and_stays_bitwise():
    """ChaosEvent(drop_xfer=N) poisons the next N transfer dispatches;
    the hardened path retries with backoff (possibly from another
    holder) and the result is still bitwise correct — no hang, no wrong
    bytes."""
    from repro.exec.elastic import ChaosEvent
    with CMMSession(_engine(SPEC3), executor="elastic", tile=16) as s:
        s._exec.chaos = [ChaosEvent(after_done=1, drop_xfer=2)]
        A = s.persist(CM.rand(96, 96, seed=0), name="A")
        B = s.persist(CM.rand(96, 96, seed=2), name="B")
        got = s.compute(A @ B)
        s._exec.chaos = ()
        st = s.stats["last_exec"]
        assert st["chaos_dropped_xfers"] >= 1
        assert st["xfer_retries"] >= 1
    ref = _engine().run(CM.rand(96, 96, seed=0) @ CM.rand(96, 96, seed=2),
                        tile=16)
    assert np.array_equal(got, ref)


@pytest.mark.chaos
def test_durable_session_survives_node_death_and_checkpoints(tmp_path):
    """Node death inside a durable session: lineage recompute re-homes
    the handle AND the next snapshot captures the re-homed tiles, so a
    later resume sees the post-recovery state."""
    from repro.exec.elastic import ChaosEvent
    with CMMSession(_engine(SPEC2), executor="elastic", tile=16,
                    checkpoint_dir=str(tmp_path)) as s:
        A = s.persist(CM.rand(96, 96, seed=0) @ CM.rand(96, 96, seed=1),
                      name="A")
        s.flush_checkpoints()
        s._exec.chaos = (ChaosEvent(after_done=3, kill_node=1),)
        out = s.compute(A @ A)
        s._exec.chaos = ()
        ref_handle = A.to_numpy()
        s.flush_checkpoints()
    a = CM.rand(96, 96, seed=0) @ CM.rand(96, 96, seed=1)
    assert np.array_equal(out, _engine(SPEC2).run(a @ a, tile=16))
    with CMMSession.resume(str(tmp_path), _engine(), tile=16) as s2:
        assert np.array_equal(s2.resident("A").to_numpy(), ref_handle)
