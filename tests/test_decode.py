"""Serving path: prefill + decode must reproduce the training forward."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from dataclasses import replace

from repro.configs.base import ARCH_IDS, get_plan, get_reduced
from repro.models import lm as M
from repro.train.steps import make_decode_step, make_prefill_step


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_reduced(arch)
    if cfg.is_moe:
        # capacity-drop ordering differs with sequence length; remove drops
        cfg = replace(cfg, moe_capacity=8.0)
    plan = get_plan(arch, "default")
    res = M.Resolver(plan, None)
    params = M.init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    rng = np.random.default_rng(1)
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    kw = {}
    if cfg.enc_dec:
        kw["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_frames, cfg.d_model)),
            jnp.float32)
    if cfg.vision_patches:
        kw["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_patches, cfg.d_model)),
            jnp.float32)

    logits_full, _, prefix = M.forward(cfg, plan, res, params, toks,
                                       mode="train", **kw)
    pre = make_prefill_step(cfg, plan,
                            max_len=S + 4 + (cfg.vision_patches or 0))
    cache, lg_pre, tok = jax.jit(pre)(params, {"tokens": toks[:, :S], **kw})
    dec = make_decode_step(cfg, plan)
    cache2, lg_dec, tok2 = jax.jit(dec)(params, cache, toks[:, S:S + 1])

    np.testing.assert_allclose(
        np.asarray(lg_pre), np.asarray(logits_full)[:, prefix + S - 1],
        rtol=1e-2, atol=6e-3)
    np.testing.assert_allclose(
        np.asarray(lg_dec), np.asarray(logits_full)[:, prefix + S],
        rtol=1e-2, atol=6e-3)
    # continued decoding stays finite and advances the cache position
    for _ in range(3):
        cache2, lg_dec, tok2 = jax.jit(dec)(params, cache2, tok2)
    assert np.isfinite(np.asarray(lg_dec)).all()
    assert int(cache2["pos"]) == S + 4 + (prefix or 0) - 0 if not prefix \
        else int(cache2["pos"]) > S


def test_hymba_ring_cache_matches_window_attention():
    """Sliding-window ring buffer == full-cache attention masked to W."""
    cfg = get_reduced("hymba-1.5b")   # window 16
    plan = get_plan("hymba-1.5b", "default")
    res = M.Resolver(plan, None)
    params = M.init_params(cfg, jax.random.PRNGKey(3), jnp.float32)
    rng = np.random.default_rng(5)
    B, S = 1, 40   # > 2x window, exercises wraparound
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 4)), jnp.int32)
    logits_full, _, _ = M.forward(cfg, plan, res, params, toks)
    pre = make_prefill_step(cfg, plan, max_len=S + 8)
    cache, lg, tok = jax.jit(pre)(params, {"tokens": toks[:, :S]})
    dec = make_decode_step(cfg, plan)
    for i in range(4):
        cache, lg, _ = jax.jit(dec)(params, cache, toks[:, S + i:S + i + 1])
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full)[:, S + i],
            rtol=2e-2, atol=1e-2)


def test_xlstm_decode_state_is_constant_memory():
    cfg = get_reduced("xlstm-1.3b")
    plan = get_plan("xlstm-1.3b", "default")
    from repro.models.decode import cache_spec
    c16 = cache_spec(cfg, plan, 4, 16)
    c4096 = cache_spec(cfg, plan, 4, 4096)
    sz16 = sum(np.prod(v.shape) for v in c16.values())
    sz4096 = sum(np.prod(v.shape) for v in c4096.values())
    assert sz16 == sz4096  # no KV cache: O(1) in context length


def test_greedy_decode_deterministic():
    cfg = get_reduced("qwen3-8b")
    plan = get_plan("qwen3-8b", "default")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    pre = make_prefill_step(cfg, plan, max_len=24)
    dec = make_decode_step(cfg, plan)

    def rollout():
        cache, lg, tok = jax.jit(pre)(params, {"tokens": toks})
        out = [tok]
        for _ in range(8):
            cache, lg, tok = jax.jit(dec)(params, cache, tok)
            out.append(tok)
        return np.concatenate([np.asarray(t) for t in out], 1)

    a, b = rollout(), rollout()
    np.testing.assert_array_equal(a, b)
