"""Analytic roofline model: work counts, peaks, audit, span report.

The roofline is the analytic cross-check on the *fitted* TimeModel: task
FLOP/byte counts are closed-form, node peaks are derived from the fitted
polynomials' marginal rates, and the span-joined report flags nodes far
below the ceiling as straggler priors (the drift report's complement —
it still fires when the fitted model has absorbed a node's slowdown).
"""
import numpy as np
import pytest

from repro.core import (ClusteredMatrix as CM, CMMEngine,
                        analytic_time_model, c5_9xlarge)
from repro.core.fusion import fused_flops, optimize_many
from repro.core.graph import Task, TaskKind, TileRef, matmul_epilogue
from repro.core.machine import hetero_spec
from repro.core.roofline import (TaskWork, audit_timemodel, node_peaks,
                                 roofline_report, roofline_time, task_work,
                                 wave_roofline)
from repro.core.tiling import tile_expression_many

TM = analytic_time_model()


def _task(kind, ins, out, payload=None):
    return Task(0, kind, ins, out, payload=payload)


def _ref(shape, t=0):
    return TileRef(t, 0, 0, shape)


# -- work counts --------------------------------------------------------------

def test_addmul_work_counts():
    t = _task(TaskKind.ADDMUL, (_ref((16, 32)), _ref((32, 8), 1)),
              _ref((16, 8), 2), payload=(False, False))
    w = task_work(t)
    assert w.flops == 2 * 16 * 32 * 8
    assert w.bytes == (16 * 32 + 32 * 8 + 2 * 16 * 8) * 8
    assert w.intensity == w.flops / w.bytes


def test_epilogued_addmul_adds_epilogue_work():
    prog = (("in", 0), ("in", 1), ("add", 0, 1), ("ewise", "relu", 2))
    payload = ("epi", (False, False), prog)
    t = _task(TaskKind.ADDMUL,
              (_ref((16, 32)), _ref((32, 8), 1), _ref((16, 8), 3)),
              _ref((16, 8), 2), payload=payload)
    w = task_work(t)
    plain = task_work(_task(TaskKind.ADDMUL,
                            (_ref((16, 32)), _ref((32, 8), 1)),
                            _ref((16, 8), 2), payload=(False, False)))
    assert w.flops == plain.flops + fused_flops(prog, 16, 8)
    assert w.bytes == plain.bytes + 16 * 8 * 8   # one extra operand read


def test_ewise_and_fused_work_counts():
    e = task_work(_task(TaskKind.EWISE, (_ref((8, 8)),), _ref((8, 8), 1),
                        payload="exp"))
    assert e.flops == 4 * 64 and e.bytes == 2 * 64 * 8
    a = task_work(_task(TaskKind.ADD, (_ref((8, 8)), _ref((8, 8), 1)),
                        _ref((8, 8), 2)))
    assert a.flops == 64 and a.bytes == 3 * 64 * 8
    assert task_work(_task(TaskKind.TAKECOPY, (), _ref((8, 8)))).flops == 0


def test_itemsize_scales_bytes_not_flops():
    t = _task(TaskKind.EWISE, (_ref((8, 8)),), _ref((8, 8), 1),
              payload="exp")
    assert task_work(t, itemsize=4).bytes == task_work(t).bytes // 2
    assert task_work(t, itemsize=4).flops == task_work(t).flops


# -- peaks + roofline time ----------------------------------------------------

def test_node_peaks_match_analytic_model_constants():
    # the analytic model IS a roofline: 5.5 GFLOP/s, 10 GB/s
    p = node_peaks(TM)[0]
    assert p.flops_per_s == pytest.approx(5.5e9, rel=1e-6)
    assert p.bytes_per_s == pytest.approx(10e9, rel=1e-6)


def test_node_peaks_scale_with_machine_slowdown():
    spec = hetero_spec((1, 1), slowdown=(1.0, 2.0))  # node 1 2x slower
    p0, p1 = node_peaks(TM, spec)
    assert p0.flops_per_s == pytest.approx(2 * p1.flops_per_s, rel=1e-6)


def test_roofline_time_picks_binding_roof():
    peak = node_peaks(TM)[0]
    compute = TaskWork(flops=10 ** 9, bytes=8)
    memory = TaskWork(flops=8, bytes=10 ** 9)
    assert roofline_time(compute, peak) == pytest.approx(1e9 / peak.flops_per_s)
    assert roofline_time(memory, peak) == pytest.approx(1e9 / peak.bytes_per_s)


# -- audit + waves ------------------------------------------------------------

def _graph(tile=(16, 16)):
    A = CM.rand(64, 64, seed=1)
    B = CM.rand(64, 64, seed=2)
    C = CM.rand(64, 64, seed=3)
    roots, _ = optimize_many([((A @ B) + C).relu()])
    return tile_expression_many(roots, tile).graph


def test_audit_one_row_per_signature():
    g = _graph()
    rows = audit_timemodel(g, TM)
    # addmul/calloc/fill, with addmul split by epilogue signature
    assert len(rows) == 4
    addmuls = [r for r in rows if r.kind == "addmul"]
    # plain chain steps and epilogued tails audit as separate rows
    assert len(addmuls) == 2
    assert sum(r.count for r in rows) == \
        sum(1 for t in g if t.kind not in
            (TaskKind.SEND, TaskKind.RECV, TaskKind.TAKECOPY,
             TaskKind.RESIDENT))
    for r in rows:
        assert r.roofline_s > 0 and r.ratio > 0
        assert r.bound in ("compute", "memory")
    # the analytic model prices matmul AT the roofline (plus launch
    # constant), so the fitted-vs-bound ratio must stay sane, >= ~1
    assert all(r.ratio > 0.99 for r in addmuls)


def test_wave_fractions_bounded():
    from repro.exec.batched import build_waves
    g = _graph()
    waves = build_waves(g)
    rows = wave_roofline(g, waves, TM)
    assert len(rows) == len(waves)
    for r in rows:
        if r["fraction"] is not None:
            assert 0.0 <= r["fraction"] <= 1.0 + 1e-9


def test_engine_roofline_audit_hook():
    eng = CMMEngine(c5_9xlarge(2), TM)
    plan = eng.plan(((CM.rand(32, 32, seed=1) @ CM.rand(32, 32, seed=2))
                     + CM.rand(32, 32, seed=3)).relu(), tile=16)
    rows = eng.roofline_audit(plan)
    assert rows and any(r.kind == "addmul" for r in rows)
    assert [w["wave"] for w in plan.roofline_waves(TM)] \
        == list(range(len(plan.waves)))


# -- span-joined report -------------------------------------------------------

class _Span:
    def __init__(self, node, tid, dur):
        self.cat = "EXEC"
        self.node = node
        self.dur = dur
        self.args = {"tid": tid}


def test_roofline_report_flags_only_throttled_node():
    """Planned heterogeneity cancels in per-node peaks; an *unplanned*
    4x throttle on node 1 is the only below-band outlier."""
    spec = hetero_spec((1, 1, 1, 1),      # nodes 2,3 planned 2x slower
                       slowdown=(1.0, 1.0, 2.0, 2.0))
    eng = CMMEngine(spec, TM)
    plan = eng.plan(((CM.rand(64, 64, seed=1) @ CM.rand(64, 64, seed=2))
                     + CM.rand(64, 64, seed=3)).relu(), tile=16)
    g = plan.program.graph
    peaks = {p.node: p for p in node_peaks(TM, spec)}
    spans = []
    for i, t in enumerate(g):
        if t.kind not in (TaskKind.ADDMUL, TaskKind.MATMUL):
            continue
        node = i % 4
        base = roofline_time(task_work(t), peaks[node]) / 0.8
        dur = base * (4.0 if node == 1 else 1.0)   # unplanned throttle
        spans.append(_Span(node, t.tid, dur))
    rep = roofline_report(spans, plan, tm=TM, band=2.0)
    assert rep.below_band == [1]
    assert rep.node(1).flagged and not rep.node(2).flagged
    assert rep.node(0).fraction == pytest.approx(0.8, rel=1e-6)
    assert "BELOW ROOFLINE BAND" in rep.summary()
    d = rep.as_dict()
    assert d["below_band"] == [1] and len(d["peaks"]) == 4


def test_roofline_report_no_spans_degrades():
    eng = CMMEngine(c5_9xlarge(2), TM)
    plan = eng.plan((CM.rand(16, 16, seed=1) @ CM.rand(16, 16, seed=2)),
                    tile=8)
    rep = roofline_report([], plan, tm=TM)
    assert rep.below_band == [] and rep.fleet_fraction is None
    assert all(nr.fraction is None for nr in rep.nodes)


def test_engine_roofline_report_hook_end_to_end():
    eng = CMMEngine(c5_9xlarge(2), TM)
    out = eng.run(((CM.rand(64, 64, seed=1) @ CM.rand(64, 64, seed=2))
                   + CM.rand(64, 64, seed=3)).relu(), tile=32,
                  executor="local")
    assert out is not None
    rep = eng.roofline_report()
    assert any(nr.samples > 0 for nr in rep.nodes)
    assert rep.fleet_fraction is not None
