"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (ClusteredMatrix as CM, CMMEngine,
                        analytic_time_model, c5_9xlarge, simulate,
                        tile_expression)
from repro.core.graph import TaskKind
from repro.core.heft import heft_schedule
from repro.core.tiling import assemble, tile_slices
from repro.core.graph import TileRef

TM = analytic_time_model()


@given(m=st.integers(1, 40), n=st.integers(1, 40),
       tm_=st.integers(1, 40), tn=st.integers(1, 40))
@settings(max_examples=40, deadline=None)
def test_tile_slices_partition(m, n, tm_, tn):
    """Tiling covers every index exactly once (Listing 1)."""
    rows = tile_slices(m, tm_)
    assert rows[0][0] == 0 and rows[-1][1] == m
    for (a, b), (c, d) in zip(rows, rows[1:]):
        assert b == c and a < b
    cols = tile_slices(n, tn)
    assert cols[-1][1] == n


@given(m=st.integers(2, 24), k=st.integers(2, 24), n=st.integers(2, 24),
       tile=st.integers(1, 25), seed=st.integers(0, 5))
@settings(max_examples=25, deadline=None)
def test_tiled_matmul_matches_numpy(m, k, n, tile, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k))
    b = rng.standard_normal((k, n))
    expr = CM.from_array(a) @ CM.from_array(b)
    out = expr.compute(tile=tile)
    np.testing.assert_allclose(out, a @ b, rtol=1e-9, atol=1e-9)


def _random_expr(draw, depth, m, n, seed):
    """Recursively build a random well-shaped expression."""
    if depth == 0:
        return CM.rand(m, n, seed=draw(st.integers(0, 100)))
    kind = draw(st.sampled_from(["add", "sub", "matmul", "scale", "ewise",
                                 "transpose"]))
    if kind == "matmul":
        k = draw(st.integers(1, 12))
        a = _random_expr(draw, depth - 1, m, k, seed)
        b = _random_expr(draw, depth - 1, k, n, seed)
        return a @ b
    if kind in ("add", "sub"):
        a = _random_expr(draw, depth - 1, m, n, seed)
        b = _random_expr(draw, depth - 1, m, n, seed)
        return a + b if kind == "add" else a - b
    if kind == "scale":
        return _random_expr(draw, depth - 1, m, n, seed) * 1.5
    if kind == "transpose":
        return _random_expr(draw, depth - 1, n, m, seed).T
    return _random_expr(draw, depth - 1, m, n, seed).ewise("tanh")


@given(st.data())
@settings(max_examples=20, deadline=None)
def test_random_expression_tiled_equals_eager(data):
    m = data.draw(st.integers(2, 10))
    n = data.draw(st.integers(2, 10))
    depth = data.draw(st.integers(1, 3))
    tile = data.draw(st.integers(1, 12))
    expr = _random_expr(data.draw, depth, m, n, 0)
    out = expr.compute(tile=tile)
    np.testing.assert_allclose(out, expr.eager(), rtol=1e-8, atol=1e-8)


@given(nodes=st.integers(1, 6), tile=st.integers(4, 32),
       n=st.integers(8, 48))
@settings(max_examples=20, deadline=None)
def test_heft_schedule_always_valid(nodes, tile, n):
    expr = (CM.rand(n, n, seed=0) @ CM.rand(n, n, seed=1)) + \
        CM.rand(n, n, seed=2)
    prog = tile_expression(expr, tile)
    spec = c5_9xlarge(nodes)
    sched = heft_schedule(prog.graph, spec, TM,
                          fill_origin={k: "local" for k in prog.leaf_nodes})
    g = prog.graph
    assert set(sched.placements) == set(g.tasks)
    for t in g:
        for p in t.preds:
            assert sched.placements[p].finish <= \
                sched.placements[t.tid].start + 1e-9
    # simulation agrees the schedule is executable
    r = simulate(g, sched, spec, TM)
    assert len(r.intervals) == len(g)
    zc = simulate(g, sched, spec, TM, zero_comm=True)
    assert zc.makespan <= r.makespan + 1e-12


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_gla_equals_recurrence(seed):
    import jax.numpy as jnp
    from repro.models.ssm import chunkwise_gla, gla_decode_step
    rng = np.random.default_rng(seed)
    B, S, H, dk, dv = 1, 32, 2, 4, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, dv)), jnp.float32)
    la = jnp.asarray(-np.abs(rng.standard_normal((B, S, H))) * 0.2,
                     jnp.float32)
    y, (Sf, nf) = chunkwise_gla(q, k, v, la, chunk=8)
    st_ = jnp.zeros((B, H, dk, dv))
    nm = jnp.zeros((B, H, dk))
    ys = []
    for t in range(S):
        yt, st_, nm = gla_decode_step(st_, nm, q[:, t], k[:, t], v[:, t],
                                      la[:, t])
        ys.append(yt)
    ydec = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ydec),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(Sf), np.asarray(st_),
                               rtol=5e-4, atol=5e-4)


@given(b=st.integers(1, 64), mb=st.integers(1, 8), old=st.integers(1, 32),
       new=st.integers(1, 32))
@settings(max_examples=50, deadline=None)
def test_rebalance_keeps_global_batch(b, mb, old, new):
    from repro.configs.base import ParallelPlan
    from repro.runtime.elastic import rebalance_microbatches
    b = b * new * old  # ensure divisibility space
    plan = ParallelPlan(microbatches=mb)
    out = rebalance_microbatches(plan, b, old, new)
    per_dev = b // new
    assert per_dev % out.microbatches == 0
