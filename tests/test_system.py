"""End-to-end behaviour tests: the paper's claims, reproduced.

C1  speedup grows with node count (§5, Fig. 4);
C2  larger tiles help up to n/2, then 7n/10 collapses (§5 tile trend);
C3  simulation tracks real execution on one node (§4.2, Table 3);
C4  observed speedup is a large fraction of zero-comm theoretical
    speedup (§5.1, Table 4);
C5  the full pipeline (tile -> HEFT -> simulate -> execute) is exact on
    every benchmark program.
"""
import time

import numpy as np
import pytest

from benchmarks.cmm_suite import BENCHMARKS
from repro.core import (CMMEngine, analytic_time_model, c5_9xlarge,
                        profile_machine, simulate, tune_tile)

TM = analytic_time_model()


@pytest.mark.parametrize("name", list(BENCHMARKS))
def test_c5_every_benchmark_exact(name):
    expr = BENCHMARKS[name](64)
    eng = CMMEngine(c5_9xlarge(3), TM, tile=24)
    out = eng.run(expr, validate=False)
    np.testing.assert_allclose(out, expr.eager(), rtol=1e-8, atol=1e-8)


def test_c1_speedup_grows_with_nodes():
    n = 1024
    build = BENCHMARKS["Synth"]
    mk = {}
    for nodes in (1, 2, 4, 8):
        eng = CMMEngine(c5_9xlarge(nodes), TM, tile=3 * n // 10)
        mk[nodes] = eng.plan(build(n)).predicted_makespan
    assert mk[2] < mk[1] and mk[4] < mk[2] and mk[8] <= mk[4] * 1.02
    assert mk[1] / mk[8] > 2.0


def test_c2_tile_trend():
    """Under comm-dominant conditions bigger tiles win up to n/2, then
    7n/10 collapses (less parallelism) — the paper's tile trend, whose
    mechanism is the comm/parallelism trade-off (§3.3, §5)."""
    from dataclasses import replace
    n = 1024
    build = BENCHMARKS["Markov"]
    slow_net = replace(c5_9xlarge(8), link_bw=1e9 / 8, latency=1e-3)
    eng = CMMEngine(slow_net, TM)
    mk = {}
    for tile in (n // 10, 3 * n // 10, n // 2, 7 * n // 10):
        mk[tile] = eng.plan(build(n), tile=tile).predicted_makespan
    assert mk[n // 2] < mk[n // 10]          # bigger tiles amortise comm
    assert mk[7 * n // 10] > mk[n // 2]      # but 7n/10 starves parallelism


def _host_load_per_cpu() -> float:
    import os
    try:
        return os.getloadavg()[0] / max(1, os.cpu_count() or 1)
    except OSError:                     # pragma: no cover — non-POSIX
        return 0.0


def test_c3_sim_tracks_execution():
    """Offline-profiled sim tracks real 1-node wall time to the order of
    magnitude (the paper reports 5-30 % on dedicated hardware; this
    container is a shared ~1-real-core VM).

    Deflake policy (documented in TESTING.md): the sim-vs-wall ratio is a
    *wall-clock ratio test* and flakes under concurrent host load — the
    profiled model inflates when calibration ran loaded, and the measured
    wall inflates when execution runs loaded.  So (a) the band is wide
    (0.2x..4x — still catches a broken cost model, which is off by 10x+),
    (b) best-of-3 reps is scored (transient stalls hit single reps), and
    (c) if every rep still lands outside the band while the 1-min load
    average exceeds 1.25 per CPU, the test SKIPS instead of failing —
    a loaded host cannot measure this quantity.
    """
    from repro.core.machine import local_spec
    tm = profile_machine(sizes=(64, 128, 256), reps=2)
    n, tile = 768, 384
    expr = BENCHMARKS["Markov"](n)
    eng = CMMEngine(local_spec(1), tm, tile=tile)
    plan = eng.plan(expr)
    accs = []
    for _ in range(3):
        t0 = time.perf_counter()
        eng.run(expr, plan=plan, workers=eng.spec.worker_procs)
        wall = time.perf_counter() - t0
        acc = wall / plan.predicted_makespan
        accs.append(acc)
        if 0.2 < acc < 4.0:
            return
    load = _host_load_per_cpu()
    if load > 1.25:
        pytest.skip(f"host under load ({load:.2f}/cpu): sim-vs-wall ratio "
                    f"is not measurable here (got {accs})")
    assert False, f"sim accuracy off on an idle host: " \
                  f"{[f'{a:.2f}' for a in accs]}"


def test_c4_observed_vs_theoretical():
    n = 1024
    build = BENCHMARKS["Synth"]
    tile = 3 * n // 10
    eng1 = CMMEngine(c5_9xlarge(1), TM, tile=tile)
    base = eng1.plan(build(n)).predicted_makespan
    eng8 = CMMEngine(c5_9xlarge(8), TM, tile=tile)
    plan8 = eng8.plan(build(n))
    obs = base / plan8.predicted_makespan
    zc = simulate(plan8.program.graph, plan8.schedule, eng8.spec, TM,
                  zero_comm=True)
    theo = base / zc.makespan
    assert theo >= obs > 0.4 * theo


def test_autotune_picks_reasonable_tile():
    n = 256
    expr = BENCHMARKS["Markov"](n)
    eng = CMMEngine(c5_9xlarge(4), TM)
    result = tune_tile(eng, expr)
    assert result.best in {max(1, n * f // 10) for f in (1, 3, 5, 7)} | {n}
    # the chosen tile is at least as good as every candidate
    best_cost = result.scores[0][1]
    assert all(best_cost <= c + 1e-12 for _, c in result.scores)


def test_plan_overhead_is_small():
    """§4.2: simulation overhead is marginal (sub-seconds per plan)."""
    expr = BENCHMARKS["Markov"](512)
    eng = CMMEngine(c5_9xlarge(8), TM, tile=256)
    plan = eng.plan(expr)
    assert plan.plan_seconds < 2.0


def test_dryrun_results_if_present():
    """Sanity over the committed dry-run artifacts (if generated)."""
    from benchmarks.roofline_table import load_cells
    cells = load_cells("single_pod_16x16")
    if not cells:
        pytest.skip("dry-run results not generated")
    assert len(cells) >= 30
    for c in cells:
        assert c["chips"] == 256
        t = c["roofline"]
        assert t["compute_s"] >= 0 and t["memory_s"] > 0
        assert c["memory"]["peak_bytes"] > 0
