"""Tiling: Listing-1 semantics and task-graph structure."""
import numpy as np
import pytest

from repro.core import ClusteredMatrix as CM, TaskKind, tile_expression
from repro.core.tiling import assemble, cld, grid_of, tile_slices


def test_cld_and_slices():
    assert cld(10, 5) == 2 and cld(10, 3) == 4
    assert tile_slices(10, 3) == [(0, 3), (3, 6), (6, 9), (9, 10)]


def test_markov_task_counts():
    """Fig. 2: 10k matrix at 5k tiles -> 2x2 grid of P, 2 tiles of u."""
    P = CM.rand(10, 10, seed=0)
    u = CM.rand(10, 1, seed=1)
    prog = tile_expression((P @ P @ P) @ u, 5)
    c = prog.graph.counts()
    # P: 4 fill tiles, u: 2 fill tiles
    assert c["fill"] == 6
    # two PxP matmuls: 4 out tiles x 2-chain each; final @u: 2 out x 2-chain
    assert c["addmul"] == 2 * 4 * 2 + 2 * 2
    assert c["calloc"] == 2 * 4 + 2
    assert c["takecopy"] == 2
    prog.graph.validate()


def test_accumulation_chain_is_sequential():
    A = CM.rand(8, 8, seed=0)
    prog = tile_expression(A @ A, 4)
    g = prog.graph
    # each output tile's addmuls form a dependency chain on the same tile
    addmuls = [t for t in g if t.kind is TaskKind.ADDMUL]
    by_out = {}
    for t in addmuls:
        by_out.setdefault(t.out, []).append(t)
    for out, chain in by_out.items():
        assert len(chain) == 2
        ids = sorted(t.tid for t in chain)
        assert ids[0] in g.tasks[ids[1]].preds


def test_ragged_tiles_execute_correctly():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((11, 7))
    b = rng.standard_normal((7, 13))
    A, B = CM.from_array(a), CM.from_array(b)
    out = (A @ B).compute(tile=4)
    np.testing.assert_allclose(out, a @ b, rtol=1e-10, atol=1e-10)


def test_assemble_roundtrip():
    from repro.core.graph import TileRef
    rng = np.random.default_rng(1)
    x = rng.standard_normal((9, 5))
    tile = (4, 2)
    vals = {}
    for i, (r0, r1) in enumerate(tile_slices(9, 4)):
        for j, (c0, c1) in enumerate(tile_slices(5, 2)):
            vals[TileRef(7, i, j, (r1 - r0, c1 - c0))] = x[r0:r1, c0:c1]
    np.testing.assert_array_equal(assemble(vals, (9, 5), tile, 7), x)


@pytest.mark.parametrize("expr_fn", [
    lambda A, B: A @ B,
    lambda A, B: (A @ B) + A,
    lambda A, B: (A @ B).T,
    lambda A, B: (A - B) @ (A + B),
    lambda A, B: (A @ B).relu() @ A.T,
])
def test_tiled_execution_matches_eager(expr_fn):
    rng = np.random.default_rng(2)
    a = rng.standard_normal((12, 12))
    b = rng.standard_normal((12, 12))
    e = expr_fn(CM.from_array(a), CM.from_array(b))
    np.testing.assert_allclose(e.compute(tile=5), e.eager(),
                               rtol=1e-10, atol=1e-10)
