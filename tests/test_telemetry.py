"""Observability tier: flight recorder, metrics registry, drift report.

Contract (ISSUE: flight recorder): tracing is cheap enough to stay on by
default and NEVER perturbs numerics — a traced run is bit-identical to
an untraced one.  The recorded timeline is complete (one EXEC span per
scheduled task, one XFER span per planned cross-node movement), aligns
worker clocks onto the master timeline, exports as valid Chrome-trace
JSON, and the drift report joins it against the simulator's predicted
timeline to flag straggler nodes and mis-fitted TimeModel terms.
"""
import json
import threading

import numpy as np
import pytest

from repro.core import ClusteredMatrix as CM, CMMEngine, analytic_time_model
from repro.core.drift import drift_report
from repro.core.graph import TaskKind
from repro.core.machine import hetero_spec
from repro.core.session import CMMSession
from repro.runtime.telemetry import (MetricsRegistry, Span, Tracer,
                                     chrome_trace, estimate_clock_offset,
                                     export_chrome_trace, _Histogram)

TM = analytic_time_model()


def _synth(n=64):
    A = CM.rand(n, n, seed=0)
    B = CM.rand(n, n, seed=1)
    return (A @ B) + A


def _plan(expr, tile, spec):
    eng = CMMEngine(spec, TM, plan_cache=False)
    return eng.plan(expr, tile=tile), eng


# -- tracer units ------------------------------------------------------------

class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def test_span_records_interval():
    clk = FakeClock(10.0)
    tr = Tracer(node=3, enabled=True, clock=clk)
    with tr.span("GEMM", cat="EXEC", tid=7):
        clk.t = 10.5
    (sp,) = tr.drain()
    assert (sp.name, sp.cat, sp.node) == ("GEMM", "EXEC", 3)
    assert sp.t0 == 10.0 and sp.dur == pytest.approx(0.5)
    assert sp.args == {"tid": 7}
    assert tr.drain() == []          # drain took everything


def test_span_nesting_containment():
    clk = FakeClock(0.0)
    tr = Tracer(node=0, clock=clk)
    with tr.span("outer", cat="A"):
        clk.t = 1.0
        with tr.span("inner", cat="B"):
            clk.t = 2.0
        clk.t = 3.0
    spans = {s.name: s for s in tr.drain()}
    out, inn = spans["outer"], spans["inner"]
    # children exit (and record) first; the parent interval contains them
    assert out.t0 <= inn.t0
    assert inn.t0 + inn.dur <= out.t0 + out.dur
    assert out.lane == inn.lane      # same thread -> same lane


def test_span_recorded_on_exception():
    clk = FakeClock(0.0)
    tr = Tracer(clock=clk)
    with pytest.raises(ValueError):
        with tr.span("boom", cat="EXEC"):
            clk.t = 0.25
            raise ValueError("x")
    (sp,) = tr.drain()
    assert sp.dur == pytest.approx(0.25)


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("x", cat="EXEC", tid=1):
        pass
    tr.add(Span("y", "EXEC", 0, 0, 0.0, 1.0))
    assert tr.drain() == []
    # disabled span() returns one shared context: zero per-call allocation
    assert tr.span("a") is tr.span("b")


def test_lanes_stable_per_thread():
    tr = Tracer()
    lanes = {}
    barrier = threading.Barrier(4)     # keep all threads alive at once —
    # exited thread idents (and so lanes) are legitimately reusable

    def work(k):
        barrier.wait()
        with tr.span(f"t{k}", cat="EXEC"):
            pass
        lanes[k] = tr.lane()
        barrier.wait()

    ths = [threading.Thread(target=work, args=(k,)) for k in range(4)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    spans = {s.name: s for s in tr.drain()}
    assert len(spans) == 4
    # concurrent threads get small, dense, distinct lanes, and a span
    # records on its own thread's lane
    assert sorted(lanes.values()) == [0, 1, 2, 3]
    for k in range(4):
        assert spans[f"t{k}"].lane == lanes[k]


# -- clock-offset calibration -------------------------------------------------

def test_estimate_clock_offset_symmetric_delay():
    # worker clock runs 100s ahead; 10ms symmetric one-way delay
    ahead = 100.0
    t_send = 50.0
    t_worker = (t_send + 0.01) + ahead   # worker echoes mid-flight
    t_recv = t_send + 0.02
    off = estimate_clock_offset(t_send, t_worker, t_recv)
    assert off == pytest.approx(ahead, abs=1e-9)


def test_ingest_shifts_onto_master_timeline():
    master = Tracer(node=-1)
    # a worker whose clock is 7s ahead recorded t0=107; the event
    # happened at master time 100
    sp = Span("EXEC", "EXEC", 2, 0, 107.0, 0.5, {"tid": 1})
    master.ingest([sp], offset=7.0)
    (got,) = master.drain()
    assert got.t0 == pytest.approx(100.0)
    assert got.dur == pytest.approx(0.5)


def test_calibration_roundtrip_aligns_two_clocks():
    # two fake processes with skewed clocks; the cal handshake recovers
    # the skew exactly under symmetric delays
    skew = 3.0
    t_send = 1.0                       # master stamps
    t_worker = (t_send + 0.005) + skew  # worker echoes its clock
    t_recv = 1.01                      # master receives
    off = estimate_clock_offset(t_send, t_worker, t_recv)
    worker = Tracer(node=1, clock=FakeClock(0.0))
    worker.add(Span("EXEC", "EXEC", 1, 0, 5.0 + skew, 0.1))
    master = Tracer(node=-1)
    master.ingest(worker.drain(), off)
    (sp,) = master.drain()
    assert sp.t0 == pytest.approx(5.0, abs=1e-9)


# -- histogram ----------------------------------------------------------------

def test_histogram_summary_basics():
    h = _Histogram()
    for v in (0.001, 0.002, 0.004, 0.1):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4
    assert s["total"] == pytest.approx(0.107)
    assert s["min"] == 0.001 and s["max"] == 0.1
    # quantile returns a bucket upper edge within 2x of the true value
    assert 0.002 <= s["p50"] <= 0.008
    assert s["p99"] >= 0.1


# hypothesis property sweep (skipped where hypothesis is unavailable)
try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(xs=st.lists(st.floats(min_value=0.0, max_value=1e4,
                                 allow_nan=False), max_size=40),
           ys=st.lists(st.floats(min_value=0.0, max_value=1e4,
                                 allow_nan=False), max_size=40))
    def test_histogram_merge_property(xs, ys):
        """merge(A, B) is indistinguishable from observing A+B directly."""
        ha, hb, hall = _Histogram(), _Histogram(), _Histogram()
        for v in xs:
            ha.observe(v)
            hall.observe(v)
        for v in ys:
            hb.observe(v)
            hall.observe(v)
        ha.merge(hb)
        assert ha.buckets == hall.buckets
        assert ha.count == hall.count
        assert ha.total == pytest.approx(hall.total)
        sa, sall = ha.summary(), hall.summary()
        for k in ("min", "max", "p50", "p99"):
            assert sa[k] == pytest.approx(sall[k])
except ImportError:                    # pragma: no cover
    pass


# -- metrics registry ---------------------------------------------------------

def test_registry_inc_is_atomic_across_threads():
    reg = MetricsRegistry()
    N, T = 2000, 8

    def bump():
        for _ in range(N):
            reg.inc("hits")

    ths = [threading.Thread(target=bump) for _ in range(T)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    assert reg.get("hits") == N * T    # bare dict += would lose updates


def test_registry_frozen_view_is_readonly_dict():
    reg = MetricsRegistry()
    reg.inc("xfers", 3)
    reg.gauge("nodes", [0, 1])
    reg.observe("task_seconds", 0.5)
    view = reg.frozen_view({"extra": 7})
    assert view["xfers"] == 3 and view["extra"] == 7
    assert view.get("missing", "d") == "d"
    assert dict(view)["nodes"] == [0, 1]
    assert view["hist:task_seconds"]["count"] == 1
    with pytest.raises(TypeError):
        view["xfers"] = 9
    # the view is a snapshot: later increments don't leak into it
    reg.inc("xfers")
    assert view["xfers"] == 3


def test_registry_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.inc("n", 2)
    b.inc("n", 5)
    b.observe("lat", 0.1)
    a.merge(b)
    assert a.get("n") == 7
    assert a.histogram("lat")["count"] == 1


# -- Chrome trace export ------------------------------------------------------

def _schema_check(doc):
    assert set(doc) >= {"traceEvents"}
    for ev in doc["traceEvents"]:
        assert ev["ph"] in ("X", "M")
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        if ev["ph"] == "X":
            assert ev["ts"] >= 0.0 and ev["dur"] >= 0.0
            assert isinstance(ev["name"], str) and isinstance(ev["cat"], str)
            json.dumps(ev["args"])     # args must be JSON-serializable


def test_chrome_trace_schema_and_normalization():
    spans = [Span("GEMM", "EXEC", 0, 1, 100.0, 0.5, {"tid": 3}),
             Span("XFER", "XFER", 1, 0, 100.2, 0.1, {"nbytes": 64}),
             Span("GATHER", "GATHER", -1, 0, 101.0, 0.2)]
    doc = chrome_trace(spans)
    _schema_check(doc)
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert min(e["ts"] for e in xs) == 0.0        # normalized to run start
    names = {(e["pid"], e["args"]["name"]) for e in doc["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert (-1, "master") in names and (0, "node 0") in names


def test_export_chrome_trace_roundtrip(tmp_path):
    spans = [Span("E", "EXEC", 0, 0, 1.0, 0.5)]
    p = tmp_path / "trace.json"
    export_chrome_trace(spans, str(p))
    _schema_check(json.load(open(p)))


# -- executor integration -----------------------------------------------------

def test_cluster_trace_covers_schedule():
    """One EXEC span per scheduled task; XFER spans match the plan's
    cross-node movement table exactly (the ``xfer_index`` oracle)."""
    spec = hetero_spec((2, 2, 1))
    plan, eng = _plan(_synth(64), 32, spec)
    g = plan.program.graph
    eng.run(_synth(64), executor="cluster", plan=plan, validate=True)
    ex = [s for s in eng.last_spans if s.cat == "EXEC"]
    tids = [s.args["tid"] for s in ex]
    assert sorted(tids) == sorted(plan.schedule.placements)  # exactly once
    # every EXEC span ran on its scheduled node
    for s in ex:
        assert s.node == plan.schedule.placements[s.args["tid"]].node
    idx = plan.schedule.xfer_index(g)
    got = {(s.args["version"], s.node): s.args["nbytes"]
           for s in eng.last_spans if s.cat == "XFER"}
    assert set(got) == set(idx)
    for key, nbytes in got.items():
        assert nbytes == idx[key][1]
    assert any(s.cat == "GATHER" for s in eng.last_spans)


def test_tracing_off_is_bit_identical_and_silent():
    spec = hetero_spec((2, 2, 1))
    expr = _synth(64)
    plan, eng = _plan(expr, 32, spec)
    on = eng.run(expr, executor="cluster", plan=plan)
    assert eng.last_spans
    plan2, eng2 = _plan(expr, 32, spec)
    off = eng2.run(expr, executor="cluster", plan=plan2, trace=False)
    assert eng2.last_spans == []
    np.testing.assert_array_equal(on, off)
    # stats survive the registry migration on both legs (dict view)
    for st in (eng.last_exec_stats, eng2.last_exec_stats):
        assert st["tasks_run"] == len(plan.program.graph)
        assert "xfers" in st and "wire_bytes" in st


def test_session_trace_accumulates_and_exports(tmp_path):
    spec = hetero_spec((2, 2, 1))
    A = CM.rand(48, 48, seed=0)
    with CMMSession(CMMEngine(spec, TM), executor="elastic", tile=24) as s:
        P = s.persist(A @ A)
        one = len(s.spans)
        assert one > 0
        s.compute(P + A)
        assert len(s.spans) > one      # spans accumulate across runs
        p = tmp_path / "session_trace.json"
        n = s.dump_trace(str(p), include_predicted=True)
        doc = json.load(open(p))
        _schema_check(doc)
        assert n == len(doc["traceEvents"])
        cats = {e["cat"] for e in doc["traceEvents"] if e["ph"] == "X"}
        assert "EXEC" in cats and "PRED_EXEC" in cats
        rep = s.drift_report()
        assert {nd.node for nd in rep.nodes} >= set(range(spec.n_nodes))


def test_local_and_batched_spans():
    expr = _synth(48)
    eng = CMMEngine(tile=24)
    out1 = eng.run(expr, executor="local")
    ex = [s for s in eng.last_spans if s.cat == "EXEC"]
    assert sorted(s.args["tid"] for s in ex) == sorted(
        eng.last_plan.program.graph.tasks)
    out2 = eng.run(expr, executor="batched")
    assert eng.last_spans and all(s.args.get("batched")
                                  for s in eng.last_spans)
    np.testing.assert_array_equal(out1, out2)


# -- drift report -------------------------------------------------------------

def _spans_from_sim(plan, slow_node=None, factor=5.0):
    """Synthesize a measured timeline from the simulated one, inflating
    ``slow_node``'s task durations by ``factor``."""
    out = []
    for iv in plan.sim.intervals:
        dur = iv.end - iv.start
        if iv.node == slow_node:
            dur *= factor
        out.append(Span(iv.kind, "EXEC", iv.node, iv.slot, iv.start, dur,
                        {"tid": iv.tid, "kind": iv.kind}))
    return out


def test_drift_flags_synthetically_slow_node():
    spec = hetero_spec((2, 2, 2))
    plan, _ = _plan(_synth(96), 32, spec)
    rep = drift_report(_spans_from_sim(plan, slow_node=1), plan, tm=TM)
    nd = rep.node(1)
    assert nd.flagged and nd.samples >= 3
    assert rep.straggler_priors == [1]
    assert nd.rel == pytest.approx(5.0, rel=0.01)
    for n in (0, 2):
        assert not rep.node(n).flagged
    # a perfectly-matching run flags nothing
    clean = drift_report(_spans_from_sim(plan), plan, tm=TM)
    assert clean.straggler_priors == []
    assert not any(nd.flagged for nd in clean.nodes)
    assert clean.fleet_ratio == pytest.approx(1.0)
    # kernel_time matched the simulator exactly -> unflagged
    assert not clean.term("kernel_time").flagged


def test_drift_reports_every_requested_node():
    spec = hetero_spec((2, 2, 1))
    plan, _ = _plan(_synth(64), 32, spec)
    rep = drift_report([], plan, tm=TM)      # no spans at all
    assert [nd.node for nd in rep.nodes] == list(range(spec.n_nodes))
    assert all(nd.samples == 0 and not nd.flagged for nd in rep.nodes)


def test_drift_term_recalibration_suggestion():
    spec = hetero_spec((2, 2, 1))
    plan, _ = _plan(_synth(64), 32, spec)
    # XFERs took 4x the predicted ipc time -> bandwidth is ~4x optimistic
    from repro.runtime.wire import predicted_xfer_seconds
    spans = []
    nbytes = 1 << 25                   # bandwidth-dominated payload
    for _ in range(4):
        p = predicted_xfer_seconds(nbytes, TM)
        spans.append(Span("XFER", "XFER", 1, 0, 0.0, 4.0 * p,
                          {"nbytes": nbytes, "codec": "raw"}))
    rep = drift_report(spans, plan, tm=TM, min_samples=3)
    td = rep.term("ipc_bandwidth")
    assert td.flagged and td.ratio == pytest.approx(4.0)
    assert td.suggested == pytest.approx(TM.ipc_bandwidth / 4.0)
    # applying the suggestion collapses the drift into the band (the
    # fixed ipc_latency term keeps the residual from being exactly 1.0)
    tm2 = TM.recalibrated("ipc_bandwidth", td.ratio)
    rep2 = drift_report(spans, plan, tm=tm2, min_samples=3)
    assert rep2.term("ipc_bandwidth").ratio == pytest.approx(1.0, rel=0.05)
    assert not rep2.term("ipc_bandwidth").flagged


def test_drift_report_as_dict_json():
    spec = hetero_spec((2, 2, 1))
    plan, _ = _plan(_synth(64), 32, spec)
    rep = drift_report(_spans_from_sim(plan, slow_node=0), plan, tm=TM)
    d = json.loads(json.dumps(rep.as_dict()))
    assert d["band"] == 1.5
    assert len(d["nodes"]) == spec.n_nodes
    assert rep.summary()                     # renders without raising
