"""Time model: Table-1 feature families, OLS fit, serialisation."""
import numpy as np
import pytest

from repro.core.graph import Task, TaskKind, TileRef
from repro.core.machine import ClusterSpec, c5_9xlarge
from repro.core.profiler import profile_comm_synthetic, profile_machine
from repro.core.timemodel import (PolyModel, TimeModel, analytic_time_model,
                                  features_ewise, features_matmul)


def test_feature_vectors():
    np.testing.assert_array_equal(features_ewise((3, 4)), [1, 4, 3, 12])
    np.testing.assert_array_equal(
        features_matmul((2, 3, 4)), [1, 2, 3, 4, 6, 12, 8, 24])


def test_ols_recovers_synthetic_coefficients():
    rng = np.random.default_rng(0)
    true = np.array([1e-4, 0, 0, 0, 0, 0, 0, 2e-10])
    dims = [(m, n, k) for m in (64, 128, 256) for n in (64, 128, 256)
            for k in (64, 128, 256)]
    times = [features_matmul(d) @ true * (1 + 0.01 * rng.standard_normal())
             for d in dims]
    model = PolyModel.fit("matmul", dims, times)
    assert model.r2(dims, times) > 0.99
    pred = model.predict((512, 512, 512))
    want = features_matmul((512, 512, 512)) @ true
    assert abs(pred - want) / want < 0.1


def test_profile_machine_fits_reasonably():
    tm = profile_machine(sizes=(64, 128, 192), reps=1)
    t_small = tm.models["matmul"].predict((64, 64, 64))
    t_big = tm.models["matmul"].predict((192, 192, 192))
    assert t_big > t_small > 0


def test_serialisation_roundtrip(tmp_path):
    tm = analytic_time_model()
    p = tmp_path / "tm.json"
    tm.save(str(p))
    tm2 = TimeModel.load(str(p))
    task = Task(0, TaskKind.ADDMUL,
                (TileRef(0, 0, 0, (64, 64)), TileRef(1, 0, 0, (64, 64))),
                TileRef(2, 0, 0, (64, 64)))
    assert tm.compute_time(task) == pytest.approx(tm2.compute_time(task))


def test_comm_model_per_pair():
    spec = ClusterSpec(n_nodes=3, pair_bw=(((0, 1), 1e9), ((1, 2), 2e9)))
    assert spec.comm_time(1e9, 0, 1) > spec.comm_time(1e9, 1, 2)
    assert spec.comm_time(123, 1, 1) == 0.0


def test_comm_profile_synthetic_fit():
    spec = c5_9xlarge(3)
    fitted = profile_comm_synthetic(spec, noise=0.01)
    lat, bw = fitted[(0, 1)]
    assert abs(bw - spec.link_bw) / spec.link_bw < 0.2
    assert lat < 10 * spec.latency


def test_straggler_slowdown():
    spec = ClusterSpec(n_nodes=2, slowdown=(1.0, 2.0))
    tm = analytic_time_model()
    task = Task(0, TaskKind.ADDMUL,
                (TileRef(0, 0, 0, (256, 256)), TileRef(1, 0, 0, (256, 256))),
                TileRef(2, 0, 0, (256, 256)))
    assert tm.compute_time(task, spec, 1) == pytest.approx(
        2 * tm.compute_time(task, spec, 0))
