"""Network-path tier: lossless wire codecs, priced per-edge compression,
relay-tree broadcast, streaming gather, and the transfer-path leases.

Contract (ISSUE: network path overhaul): every byte that crosses the
wire on the TILE path is losslessly coded — a compressed run is bitwise
identical to the raw run and to the eager oracle, with compression on
and off, on every executor, under churn.  Lossy codecs (int8 gradient
quantisation) are allowed on the OPTIMIZER path only and never touch
tiles.  Leases keep bounded-arena sources pinned for exactly the life
of each copy: a consumer dying mid-copy must release, not strand, the
source pin.
"""
import numpy as np
import pytest

from repro.core import (ClusteredMatrix as CM, CMMEngine, TimeModel,
                        analytic_time_model)
from repro.core.machine import hetero_spec
from repro.core.timemodel import TimeModel as TM_cls
from repro.exec.cluster import ClusterExecutor
from repro.exec.elastic import ChaosEvent, ElasticClusterExecutor
from repro.exec.local import LocalExecutor
from repro.runtime.membership import MembershipConfig
from repro.runtime.wire import (BCAST_MIN_FANOUT, CODECS, broadcast_tree,
                                choose_wire_codec, decode_tile, encode_tile)

TM = analytic_time_model()
FAST_NET = dict(link_bw=1e12, latency=1e-6)


def _plan(expr, tile, spec):
    eng = CMMEngine(spec, TM, plan_cache=False)
    return eng.plan(expr, tile=tile)


def _synth(n=64):
    A = CM.rand(n, n, seed=0)
    B = CM.rand(n, n, seed=1)
    return (A @ B) + A


def _fanout_expr(n=96):
    """One operand feeds every output tile column — a fan-out-heavy
    program whose XFER pattern exercises relay trees for real."""
    A = CM.rand(n, n, seed=0)
    B = CM.rand(n, n, seed=1)
    return A @ B


# -- codec round trips -------------------------------------------------------

def test_codec_registry():
    assert set(CODECS) >= {"raw", "zlib"}
    with pytest.raises(ValueError, match="unknown wire codec"):
        from repro.runtime.wire import get_codec
        get_codec("lz9")


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("codec", ["raw", "zlib"])
def test_roundtrip_bit_identity_random(dtype, codec):
    rng = np.random.default_rng(0)
    a = rng.standard_normal((37, 23)).astype(dtype)
    payload = encode_tile(a, codec)
    b = decode_tile(payload, a.shape, a.dtype, codec)
    assert b.dtype == a.dtype and b.shape == a.shape
    assert a.tobytes() == b.tobytes()       # bitwise, not allclose


@pytest.mark.parametrize("codec", ["raw", "zlib"])
def test_roundtrip_bit_identity_special_values(codec):
    a = np.array([[0.0, -0.0, np.inf, -np.inf],
                  [np.nan, 1e-308, -1e308, 2.0 ** -1074]])
    payload = encode_tile(a, codec)
    b = decode_tile(payload, a.shape, a.dtype, codec)
    assert a.tobytes() == b.tobytes()


def test_zlib_compresses_structured_tiles():
    col = np.linspace(0.0, 1.0, 256)
    structured = np.outer(col, np.ones(256))       # rank 1
    payload = encode_tile(structured, "zlib")
    assert len(payload) < structured.nbytes / 2
    back = decode_tile(payload, structured.shape, structured.dtype, "zlib")
    assert structured.tobytes() == back.tobytes()


def test_noncontiguous_input_encodes_correctly():
    rng = np.random.default_rng(1)
    base = rng.standard_normal((64, 64))
    view = base[::2, ::2]                          # non-contiguous
    payload = encode_tile(view, "zlib")
    back = decode_tile(payload, view.shape, view.dtype, "zlib")
    assert np.ascontiguousarray(view).tobytes() == back.tobytes()


# hypothesis property sweep (skipped where hypothesis is unavailable)
try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:                                # pragma: no cover
    _HYP = False

if _HYP:
    @given(st.integers(1, 40), st.integers(1, 40),
           st.sampled_from(["<f4", "<f8"]),
           st.sampled_from(["raw", "zlib"]),
           st.booleans(), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_roundtrip_property(rows, cols, dts, codec, structured, seed):
        rng = np.random.default_rng(seed)
        if structured:
            a = np.outer(np.arange(rows), np.ones(cols)).astype(dts)
        else:
            a = rng.standard_normal((rows, cols)).astype(dts)
        payload = encode_tile(a, codec)
        b = decode_tile(payload, a.shape, np.dtype(dts), codec)
        assert a.tobytes() == b.tobytes()


# -- per-edge pricing --------------------------------------------------------

def test_choose_codec_defaults_to_raw():
    # an unprofiled model (compress terms at their defaults) never
    # compresses — the pre-overhaul behaviour is the fallback
    assert choose_wire_codec(1 << 20, 1e9, TM) == "raw"


def test_choose_codec_prices_the_inequality():
    tm = TM_cls(compress_bandwidth=1e9, compression_ratio_prior=4.0)
    # slow link: encode at 1 GB/s then ship a quarter of the bytes wins
    assert choose_wire_codec(1 << 22, 1e8, tm) == "zlib"
    # near-infinite link: raw transfer is already free, encoding only adds
    assert choose_wire_codec(1 << 22, 1e13, tm) == "raw"


def test_wire_time_is_min_of_raw_and_compressed():
    spec = hetero_spec((1, 1), link_bw=1e8, latency=0.0)
    tm = TM_cls(compress_bandwidth=1e9, compression_ratio_prior=4.0)
    nb = 1 << 22
    raw = spec.comm_time(nb, 0, 1)
    comp = nb / 1e9 + spec.comm_time(nb // 4, 0, 1)
    assert np.isclose(tm.wire_time(nb, 0, 1, spec), min(raw, comp))
    assert tm.wire_time(nb, 0, 0, spec) == 0.0     # same node: no wire
    # unprofiled terms leave the pricing untouched
    assert TM.wire_time(nb, 0, 1, spec) == spec.comm_time(nb, 0, 1)


def test_timemodel_json_roundtrips_compression_terms():
    import json
    tm = TM_cls(compress_bandwidth=2.5e9, compression_ratio_prior=3.5)
    d = json.loads(tm.to_json())
    assert d["compress_bandwidth"] == 2.5e9
    assert d["compression_ratio_prior"] == 3.5
    back = TM_cls.from_json(tm.to_json())
    assert back.compress_bandwidth == 2.5e9
    assert back.compression_ratio_prior == 3.5
    # plan caches key on to_json(): fitted terms must change the key
    assert TM_cls().to_json() != d


def test_calibrate_compression_fits_sane_terms():
    from repro.core.profiler import calibrate_compression
    tm = TM_cls()
    cbw, ratio = calibrate_compression(tm, nbytes=1 << 18, reps=1)
    assert tm.compress_bandwidth == cbw and cbw > 1e5
    assert tm.compression_ratio_prior == ratio and ratio > 1.0


# -- broadcast tree shape ----------------------------------------------------

def test_broadcast_tree_flat_below_fanout():
    dsts = list(range(1, BCAST_MIN_FANOUT))
    assert broadcast_tree(0, dsts) == {0: dsts}


def test_broadcast_tree_structure():
    tree = broadcast_tree(0, [1, 2, 3, 4, 5])
    # every destination appears exactly once as a child
    kids = [c for cs in tree.values() for c in cs]
    assert sorted(kids) == [1, 2, 3, 4, 5]
    # binary: nobody relays to more than 2 children; depth is log-ish
    assert all(len(cs) <= 2 for cs in tree.values())
    assert 0 in tree                                 # source is the root


def test_broadcast_tree_excludes_source_from_dsts():
    tree = broadcast_tree(2, [0, 1, 2])
    kids = [c for cs in tree.values() for c in cs]
    assert 2 not in kids and sorted(kids) == [0, 1]


# -- executor conformance: compression on the real transfer path ------------

@pytest.mark.parametrize("codec", ["raw", "zlib"])
def test_cluster_forced_codec_bit_identical(codec):
    spec = hetero_spec((2, 2, 1), **FAST_NET)
    plan = _plan(_synth(), tile=16, spec=spec)
    ref = LocalExecutor().execute(plan)
    ex = ClusterExecutor(wire_codec=codec)
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    if codec == "zlib" and ex.stats["xfers"] > 0:
        assert ex.stats["xfers_compressed"] > 0
        assert ex.stats["wire_bytes"] < ex.stats["xfer_bytes"]
    assert ex.stats["stale_leases"] == 0


@pytest.mark.parametrize("codec", ["raw", "zlib"])
def test_elastic_forced_codec_bit_identical(codec):
    spec = hetero_spec((2, 2, 1), **FAST_NET)
    plan = _plan(_synth(), tile=16, spec=spec)
    ref = LocalExecutor().execute(plan)
    ex = ElasticClusterExecutor(timemodel=TM, wire_codec=codec)
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    if codec == "zlib" and ex.stats["xfers"] > 0:
        assert ex.stats["xfers_compressed"] > 0
        assert ex.stats["wire_bytes"] < ex.stats["xfer_bytes"]
    assert ex.stats["stale_leases"] == 0
    assert ex.stats["stale_retry_entries"] == 0


def test_auto_pricing_compresses_on_slow_links_only():
    tm = analytic_time_model()
    tm.compress_bandwidth = 1e9
    tm.compression_ratio_prior = 4.0
    # a painfully slow link: the priced rule must choose zlib per edge
    slow = hetero_spec((2, 1), link_bw=1e4, latency=1e-6)
    plan = _plan(_synth(48), tile=16, spec=slow)
    ref = LocalExecutor().execute(plan)
    ex = ClusterExecutor(timemodel=tm)
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    if ex.stats["xfers"] > 0:
        assert ex.stats["xfers_compressed"] > 0
    # fat link, same model: raw wins the inequality
    fat = hetero_spec((2, 1), **FAST_NET)
    plan2 = _plan(_synth(48), tile=16, spec=fat)
    ex2 = ClusterExecutor(timemodel=tm)
    out2 = ex2.execute(plan2)
    assert np.array_equal(LocalExecutor().execute(plan2), out2)
    assert ex2.stats["xfers_compressed"] == 0


# -- broadcast + streaming gather on executors ------------------------------

def test_cluster_broadcast_relays_and_matches():
    spec = hetero_spec((1, 1, 1, 1, 1, 1), **FAST_NET)
    plan = _plan(_fanout_expr(), tile=16, spec=spec)
    ref = LocalExecutor().execute(plan)
    ex = ClusterExecutor(broadcast=True)
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    ex2 = ClusterExecutor(broadcast=False)
    out2 = ex2.execute(plan)
    assert np.array_equal(ref, out2)
    assert ex2.stats["relay_hops"] == 0


def test_cluster_stream_gather_bit_identical():
    spec = hetero_spec((2, 2), **FAST_NET)
    plan = _plan(_synth(96), tile=16, spec=spec)
    ref = LocalExecutor().execute(plan)
    on = ClusterExecutor(stream_gather=True)
    out_on = on.execute(plan)
    off = ClusterExecutor(stream_gather=False)
    out_off = off.execute(plan)
    assert np.array_equal(ref, out_on) and np.array_equal(ref, out_off)
    assert on.stats["gather_streamed_tiles"] > 0
    assert off.stats["gather_streamed_tiles"] == 0
    assert on.stats["gather_first_tile_s"] is not None
    assert on.stats["gather_full_result_s"] >= on.stats["gather_first_tile_s"]


def test_elastic_stream_gather_bit_identical():
    spec = hetero_spec((2, 2), **FAST_NET)
    plan = _plan(_synth(96), tile=16, spec=spec)
    ref = LocalExecutor().execute(plan)
    ex = ElasticClusterExecutor(timemodel=TM, stream_gather=True)
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    assert ex.stats["gather_streamed_tiles"] > 0


# -- chaos: leases and relays under churn -----------------------------------

@pytest.mark.chaos
def test_consumer_death_mid_copy_releases_source_leases():
    """Kill a throttled consumer while leased XFERs are in flight to it
    (bounded arenas force the lease path; the throttle keeps each copy
    in its hold-ack -> copy-land window).  The master must release the
    dead consumer's source pins — the run then completes bit-identically
    on the survivors with every lease closed.  Regression: the pins used
    to leak, leaving source tiles unevictable on bounded arenas."""
    n = 96
    ws = 4 * n * n * 8
    spec = hetero_spec((2, 2, 1, 1), mem_bytes=float(ws), **FAST_NET)
    plan = _plan(_fanout_expr(n), tile=16, spec=spec)
    ref = LocalExecutor().execute(plan)
    ex = ElasticClusterExecutor(
        timemodel=TM,
        membership=MembershipConfig(heartbeat_interval_s=0.05),
        chaos=[ChaosEvent(after_done=0, throttle_node=3,
                          throttle_seconds=0.4),
               ChaosEvent(after_done=10, kill_node=3)])
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    assert ex.stats["deaths"] == 1
    assert ex.stats["leases"] > 0, "bounded arenas must take leases"
    assert ex.stats["stale_leases"] == 0, "a dead consumer stranded a pin"
    assert ex.stats["stale_retry_entries"] == 0


@pytest.mark.chaos
def test_relay_node_death_rebuilds_broadcast_tree():
    """Kill a node mid-run on a fan-out-heavy workload with relaying on:
    consumers that were routed through the dead relay must re-route to a
    surviving holder (or the resurrected producer) bit-identically."""
    spec = hetero_spec((1, 1, 1, 1, 1, 1), **FAST_NET)
    plan = _plan(_fanout_expr(), tile=16, spec=spec)
    ref = LocalExecutor().execute(plan)
    ex = ElasticClusterExecutor(
        timemodel=TM, broadcast=True,
        chaos=[ChaosEvent(after_done=14, kill_node=4)])
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    assert ex.stats["deaths"] == 1
    assert ex.stats["stale_leases"] == 0


@pytest.mark.chaos
def test_compressed_xfers_survive_chaos_drops():
    """Poisoned compressed transfers must retry through a fresh lease
    (release old pin, re-pack, re-copy) and land bit-identically — and
    the recovered edges' retry budgets must reset on success."""
    spec = hetero_spec((2, 2, 1), **FAST_NET)
    plan = _plan(_synth(), tile=16, spec=spec)
    ref = LocalExecutor().execute(plan)
    ex = ElasticClusterExecutor(
        timemodel=TM, wire_codec="zlib",
        chaos=[ChaosEvent(after_done=4, drop_xfer=3)])
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    assert ex.stats["xfer_retries"] >= 1
    assert ex.stats["stale_leases"] == 0
    assert ex.stats["stale_retry_entries"] == 0, \
        "successful retries must clear their failure counts"


@pytest.mark.chaos
def test_retry_budget_resets_after_successful_retry():
    """With a retry budget of 1 per edge, more dropped XFERs than the
    budget only survive if each successful recovery resets its edge's
    count — the stale-count bug failed this run spuriously."""
    spec = hetero_spec((2, 2, 1), **FAST_NET)
    plan = _plan(_synth(), tile=16, spec=spec)
    ref = LocalExecutor().execute(plan)
    ex = ElasticClusterExecutor(
        timemodel=TM,
        membership=MembershipConfig(xfer_max_retries=2),
        chaos=[ChaosEvent(after_done=2, drop_xfer=2),
               ChaosEvent(after_done=8, drop_xfer=2)])
    out = ex.execute(plan)
    assert np.array_equal(ref, out)
    assert ex.stats["stale_retry_entries"] == 0
