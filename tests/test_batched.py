"""Wave-batched executor: wave invariants, bit-identity, arena, selection."""
import numpy as np
import pytest

from repro.core import (ClusteredMatrix as CM, CMMEngine, TimeModel,
                        analytic_time_model, c5_9xlarge)
from repro.core.graph import TaskGraph, TaskKind, TileRef
from repro.core.machine import local_spec
from repro.exec.batched import (WaveExecutor, build_waves,
                                predict_wave_makespan)
from repro.exec.local import LocalExecutor

TM = analytic_time_model()


def _plan(expr, tile, nodes=1, fuse=True):
    eng = CMMEngine(c5_9xlarge(nodes), TM, plan_cache=False, fuse=fuse)
    return eng.plan(expr, tile=tile)


def _mixed_expr(n=96, dtype=np.float64):
    A = CM.rand(n, n, seed=0, dtype=dtype)
    B = CM.rand(n, n, seed=1, dtype=dtype)
    C = CM.rand(n, n, seed=2, dtype=dtype)
    return ((A @ B).relu() * 2.0 + C).hadamard(C) - A


# -- wave partition ---------------------------------------------------------

def test_waves_partition_and_are_antichains():
    plan = _plan(_mixed_expr(), tile=16)
    g = plan.program.graph
    waves = build_waves(g)
    seen = [tid for w in waves for tid in w]
    assert sorted(seen) == sorted(g.tasks)          # exact partition
    wave_of = {tid: i for i, w in enumerate(waves) for tid in w}
    for t in g:
        for s in t.succs:
            assert wave_of[s] > wave_of[t.tid], \
                "dependency must cross waves (mutual independence)"


def test_plan_carries_waves():
    plan = _plan(_mixed_expr(), tile=32)
    assert plan.waves is not None
    assert sorted(t for w in plan.waves for t in w) == \
        sorted(plan.program.graph.tasks)
    assert plan.batched_makespan is not None and plan.batched_makespan > 0


# -- bit-identity vs the per-task executor & the eager oracle ---------------

@pytest.mark.parametrize("dtype", [np.float64, np.float32])
@pytest.mark.parametrize("tile", [16, 24, 96])
def test_batched_bit_identical_to_per_task(dtype, tile):
    plan = _plan(_mixed_expr(dtype=dtype), tile=tile)
    out_local = LocalExecutor().execute(plan)
    out_wave = WaveExecutor().execute(plan)
    assert out_local.dtype == out_wave.dtype
    assert np.array_equal(out_local, out_wave)


def test_batched_transposed_matmul_paths():
    A = CM.rand(64, 48, seed=3)
    B = CM.rand(64, 80, seed=4)
    C = CM.rand(48, 80, seed=5)
    expr = (A.T @ B) + C
    plan = _plan(expr, tile=16)
    # the optimizer folded the transpose into ADDMUL flags
    kinds = plan.program.graph.counts()
    assert "transpose" not in kinds
    out_local = LocalExecutor().execute(plan)
    out_wave = WaveExecutor().execute(plan)
    assert np.array_equal(out_local, out_wave)
    np.testing.assert_allclose(out_wave, expr.eager(), rtol=1e-9, atol=1e-9)


def test_batched_explicit_transpose_kind():
    A = CM.rand(40, 24, seed=9)
    expr = A.T + CM.rand(24, 40, seed=10)
    plan = _plan(expr, tile=8, fuse=False)    # keep the TRANSPOSE task kind
    assert "transpose" in plan.program.graph.counts()
    out_local = LocalExecutor().execute(plan)
    out_wave = WaveExecutor().execute(plan)
    assert np.array_equal(out_local, out_wave)


def test_batched_ragged_tiles():
    expr = _mixed_expr(n=100)
    plan = _plan(expr, tile=24)               # 100 = 4x24 + ragged 4
    out_local = LocalExecutor().execute(plan)
    out_wave = WaveExecutor().execute(plan)
    assert np.array_equal(out_local, out_wave)


def test_batched_input_leaves():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((48, 48))
    b = rng.standard_normal((48, 48))
    expr = (CM.from_array(a) @ CM.from_array(b)) + CM.from_array(a)
    plan = _plan(expr, tile=16)
    out_local = LocalExecutor().execute(plan)
    out_wave = WaveExecutor().execute(plan)
    assert np.array_equal(out_local, out_wave)


# -- arena / memory ---------------------------------------------------------

def test_arena_zero_copy_and_freeing():
    plan = _plan(_mixed_expr(n=128), tile=16)
    ex = WaveExecutor()
    ex.execute(plan)
    assert ex.stats["zero_copy_gathers"] > 0
    assert ex.stats["buffers_freed"] > 0
    assert ex.stats["tasks_run"] == len(plan.program.graph)
    assert ex.stats["cur_buffer_bytes"] <= ex.stats["peak_buffer_bytes"]

    # refcounted slab freeing bounds the peak by LIVE slabs: on a deep
    # unfused elementwise chain (one slab per step, freed as the next
    # step consumes it) the peak stays far below the keep-everything run
    e = CM.rand(64, 64, seed=0)
    for i in range(12):
        e = (e * 1.01 + 0.1).relu()
    plan_chain = _plan(e, tile=32, fuse=False)
    ex_free = WaveExecutor()
    out_free = ex_free.execute(plan_chain)
    ex_keep = WaveExecutor(free_buffers=False)
    out_keep = ex_keep.execute(plan_chain)
    assert np.array_equal(out_free, out_keep)
    assert ex_free.stats["peak_buffer_bytes"] < \
        ex_keep.stats["peak_buffer_bytes"]
    assert ex_free.stats["buffers_freed"] > 0


def test_arena_survives_duplicate_producers_from_regen_fills():
    """HEFT's §3.3 regeneration pass clones FILL tasks that share the
    original task's ``out`` TileRef on multi-node plans.  A ref must hold
    exactly one slab slot alive, or regenerated fills strand their slabs
    at live > 0 forever (slab-leak regression)."""
    A = CM.rand(256, 256, seed=0)
    B = CM.rand(256, 256, seed=1)
    expr = (A @ B) + CM.rand(256, 256, seed=2)
    eng = CMMEngine(c5_9xlarge(4), TM, plan_cache=False)
    plan = eng.plan(expr, tile=32)
    producers = {}
    for t in plan.program.graph:
        if t.kind is TaskKind.FILL:
            producers[t.out] = producers.get(t.out, 0) + 1
    assert max(producers.values()) > 1, \
        "expected regen-clone fills (duplicate producers) in this plan"
    ex = WaveExecutor()
    out = ex.execute(plan)
    np.testing.assert_allclose(out, expr.eager(), rtol=1e-8, atol=1e-8)
    # live at end: exactly the result tiles' slab (+ nothing stranded)
    assert ex.stats["cur_buffer_bytes"] == 256 * 256 * 8
    assert ex.stats["buffers_freed"] >= ex.stats["slabs_alloc"] - 1


# -- engine integration -----------------------------------------------------

def test_engine_batched_executor_validates():
    eng = CMMEngine(local_spec(1), TM)
    expr = _mixed_expr(n=64)
    out = eng.run(expr, tile=16, executor="batched", validate=True)
    assert eng.last_exec_stats["executor"] == "batched"
    assert out.shape == (64, 64)


def test_engine_auto_selects_by_predicted_makespan():
    expr = _mixed_expr(n=64)
    # heavy per-task dispatch, cheap batched launches -> batched wins
    tm_b = TimeModel.from_json(TM.to_json())
    tm_b.dispatch_overhead = 5e-3
    tm_b.batch_dispatch_overhead = 1e-5
    eng_b = CMMEngine(local_spec(1), tm_b, plan_cache=False)
    plan_b = eng_b.plan(expr, tile=16)
    assert plan_b.batched_makespan < plan_b.sim.makespan
    assert eng_b.choose_executor(plan_b) == "batched"
    out = eng_b.run(expr, plan=plan_b, executor="auto", validate=True)
    assert eng_b.last_exec_stats["executor"] == "batched"
    assert out.shape == (64, 64)

    # free per-task dispatch, expensive batched launches -> per-task wins
    tm_l = TimeModel.from_json(TM.to_json())
    tm_l.dispatch_overhead = 0.0
    tm_l.batch_dispatch_overhead = 10.0
    eng_l = CMMEngine(local_spec(1), tm_l, plan_cache=False)
    plan_l = eng_l.plan(expr, tile=16)
    assert eng_l.choose_executor(plan_l) == "local"
    assert plan_l.best_predicted_makespan == plan_l.sim.makespan


def test_predict_wave_makespan_prices_batch_dispatch():
    plan = _plan(_mixed_expr(n=64), tile=16)
    g = plan.program.graph
    cheap = TimeModel.from_json(TM.to_json())
    cheap.batch_dispatch_overhead = 1e-6
    dear = TimeModel.from_json(TM.to_json())
    dear.batch_dispatch_overhead = 1e-2
    spec = c5_9xlarge(1)
    t_cheap = predict_wave_makespan(g, spec, cheap, waves=plan.waves,
                                    dtypes=plan.program.dtypes)
    t_dear = predict_wave_makespan(g, spec, dear, waves=plan.waves,
                                   dtypes=plan.program.dtypes)
    assert t_dear > t_cheap


def test_batched_pallas_backend_matches_at_tolerance():
    """vmap-over-Pallas ADDMUL groups (interpret mode on CPU): float32 VMEM
    accumulation, so validated at tolerance rather than bitwise."""
    expr = (CM.rand(32, 32, seed=0) @ CM.rand(32, 32, seed=1)) + \
        CM.rand(32, 32, seed=2)
    eng = CMMEngine(local_spec(1), TM, plan_cache=False)
    out = eng.run(expr, tile=16, executor="batched-pallas")
    np.testing.assert_allclose(out, expr.eager(), rtol=1e-4, atol=1e-4)


# -- per-task executor accounting (satellite fix) ---------------------------

def test_local_executor_rebind_accounting():
    """Rebinding ``buffers[t.out]`` over a CALLOC'd allocation must release
    the old allocation's bytes (the peak_buffer_bytes drift fix)."""
    from types import SimpleNamespace

    leaf_a = CM.rand(8, 8, seed=1)
    leaf_b = CM.rand(8, 8, seed=2)
    r = TileRef(10_000, 0, 0, (8, 8))
    a = TileRef(leaf_a.uid, 0, 0, (8, 8))
    b = TileRef(leaf_b.uid, 0, 0, (8, 8))
    g = TaskGraph()
    t0 = g.add(TaskKind.CALLOC, (), r, payload=10_000)
    t1 = g.add(TaskKind.FILL, (), a, payload=leaf_a.uid)
    t2 = g.add(TaskKind.FILL, (), b, payload=leaf_b.uid)
    t3 = g.add(TaskKind.ADD, (a, b), r,          # rebinds over the CALLOC
               deps=(t0.tid, t1.tid, t2.tid))
    g.add(TaskKind.TAKECOPY, (r,), r, deps=(t3.tid,))
    g.result_tiles = [r]
    g.result_grid = (1, 1)
    g.result_shape = (8, 8)

    plan = SimpleNamespace(
        program=SimpleNamespace(graph=g, leaf_nodes={leaf_a.uid: leaf_a,
                                                     leaf_b.uid: leaf_b},
                                dtypes={10_000: np.float64}),
        tile=(8, 8),
        schedule=SimpleNamespace(order=[t.tid for t in g.topo()]),
        spec=None)
    ex = LocalExecutor(workers=1)
    out = ex.execute(plan)
    np.testing.assert_allclose(out, leaf_a.eager() + leaf_b.eager())
    tile_bytes = 8 * 8 * 8
    # live at end: just the (rebound) result tile
    assert ex.stats["cur_buffer_bytes"] == tile_bytes
    # peak: calloc + two fills (the ADD rebind nets to zero)
    assert ex.stats["peak_buffer_bytes"] == 3 * tile_bytes


# -- hypothesis property: bit-identical over randomized DAGs ----------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                     # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:
    SAFE_EWISE = ["sin", "cos", "tanh", "abs", "relu"]

    def _rand_expr(draw, depth, m, n, dtype, max_inner):
        if depth == 0:
            return CM.rand(m, n, seed=draw(st.integers(0, 50)), dtype=dtype)
        kind = draw(st.sampled_from(
            ["add", "sub", "ewmul", "matmul", "matmul_t", "scale", "ewise"]))
        if kind in ("matmul", "matmul_t"):
            k = draw(st.integers(1, max_inner))
            if kind == "matmul_t":
                # A.T @ B with A ~ (k, m): the optimizer folds the
                # transpose into ADDMUL operand flags
                a = _rand_expr(draw, depth - 1, k, m, dtype, max_inner)
                b = _rand_expr(draw, depth - 1, k, n, dtype, max_inner)
                return a.T @ b
            a = _rand_expr(draw, depth - 1, m, k, dtype, max_inner)
            b = _rand_expr(draw, depth - 1, k, n, dtype, max_inner)
            return a @ b
        if kind in ("add", "sub", "ewmul"):
            a = _rand_expr(draw, depth - 1, m, n, dtype, max_inner)
            b = _rand_expr(draw, depth - 1, m, n, dtype, max_inner)
            return {"add": a + b, "sub": a - b,
                    "ewmul": a.hadamard(b)}[kind]
        if kind == "scale":
            return _rand_expr(draw, depth - 1, m, n, dtype, max_inner) * \
                draw(st.sampled_from([0.5, 1.5, -2.0]))
        return _rand_expr(draw, depth - 1, m, n, dtype, max_inner).ewise(
            draw(st.sampled_from(SAFE_EWISE)))

    @given(st.data())
    @settings(max_examples=25, deadline=None)
    def test_batched_bit_identical_property(data):
        """Satellite: over randomized expression DAGs, tile sizes and
        dtypes (incl. FUSED regions and transposed matmuls), the batched
        executor is bit-identical to the per-task executor, and — when
        every matmul k-chain fits one tile, so tiling itself does not
        re-associate the GEMM reduction — bit-identical to
        ``ClusteredMatrix.eager()`` too."""
        dtype = data.draw(st.sampled_from([np.float64, np.float32]))
        tile = data.draw(st.integers(4, 16))
        m = data.draw(st.integers(2, 20))
        n = data.draw(st.integers(2, 20))
        depth = data.draw(st.integers(1, 3))
        # inner dims <= tile: single-k-tile GEMMs keep the reduction
        # order of the eager oracle (multi-k-tile accumulation is a
        # different float summation order by construction)
        expr = _rand_expr(data.draw, depth, m, n, dtype, max_inner=tile)
        plan = _plan(expr, tile=tile)
        out_local = LocalExecutor().execute(plan)
        out_wave = WaveExecutor().execute(plan)
        assert out_wave.dtype == out_local.dtype
        assert np.array_equal(out_local, out_wave), \
            "batched executor diverged from per-task executor"
        eager = expr.eager()
        assert np.array_equal(out_wave, eager), \
            "batched executor diverged from the eager oracle"

    @given(st.data())
    @settings(max_examples=10, deadline=None)
    def test_batched_matches_per_task_with_long_k_chains(data):
        """Multi-k-tile matmuls (tiled reduction order differs from one
        big GEMM): batched must still match the per-task executor
        bitwise, and the oracle at tolerance."""
        dtype = data.draw(st.sampled_from([np.float64, np.float32]))
        tile = data.draw(st.integers(3, 8))
        k = data.draw(st.integers(tile + 1, 3 * tile))   # forces kt > 1
        m = data.draw(st.integers(2, 12))
        n = data.draw(st.integers(2, 12))
        expr = (CM.rand(m, k, seed=0, dtype=dtype) @
                CM.rand(k, n, seed=1, dtype=dtype)).relu() + \
            CM.rand(m, n, seed=2, dtype=dtype)
        plan = _plan(expr, tile=tile)
        out_local = LocalExecutor().execute(plan)
        out_wave = WaveExecutor().execute(plan)
        assert np.array_equal(out_local, out_wave)
        tol = 1e-4 if dtype == np.float32 else 1e-9
        np.testing.assert_allclose(out_wave, expr.eager(),
                                   rtol=tol, atol=tol)
