"""HEFT scheduler: validity invariants + the paper's modifications."""
import numpy as np
import pytest

from repro.core import (ClusteredMatrix as CM, CMMEngine, NodeCache,
                        analytic_time_model, c5_9xlarge, heft_schedule,
                        tile_expression)
from repro.core.graph import TaskKind
from repro.core.heft import (_GapTimeline, _SlotTimeline, edge_bytes,
                             register_fill_origin, upward_rank)
from repro.core.lazy import Op, topo_order


def _plan(n_nodes=4, n=64, tile=16, expr=None):
    expr = expr or ((CM.rand(n, n, seed=0) @ CM.rand(n, n, seed=1))
                    @ CM.rand(n, 1, seed=2))
    eng = CMMEngine(c5_9xlarge(n_nodes), analytic_time_model(), tile=tile)
    return eng.plan(expr)


def _validate_schedule(g, sched, spec):
    # every task placed exactly once on a valid node
    assert set(sched.placements) == set(g.tasks)
    for tid, p in sched.placements.items():
        assert 0 <= p.node < spec.n_nodes
        assert p.finish >= p.start >= 0
    # dependencies respected (start after every pred's finish)
    for t in g:
        for pr in t.preds:
            assert sched.placements[pr].finish <= \
                sched.placements[t.tid].start + 1e-9, (t, pr)
    # no overlapping intervals on the same (node, slot)
    lanes = {}
    for tid, p in sched.placements.items():
        if g.tasks[tid].kind is TaskKind.CALLOC:
            continue
        lanes.setdefault((p.node, p.slot), []).append((p.start, p.finish))
    for lane in lanes.values():
        lane.sort()
        for (s1, e1), (s2, e2) in zip(lane, lane[1:]):
            assert e1 <= s2 + 1e-9


def test_schedule_valid_multi_node():
    plan = _plan(4)
    spec = c5_9xlarge(4)
    _validate_schedule(plan.program.graph, plan.schedule, spec)


def test_takecopy_on_master():
    plan = _plan(4)
    g = plan.program.graph
    for t in g:
        if t.kind is TaskKind.TAKECOPY:
            assert plan.schedule.placements[t.tid].node == 0


def test_input_fill_pinned_to_master():
    a = np.ones((32, 32))
    expr = CM.from_array(a) @ CM.from_array(a)
    plan = _plan(4, expr=expr, tile=16)
    g = plan.program.graph
    leaves = plan.program.leaf_nodes
    for t in g:
        if t.kind is TaskKind.FILL and leaves[t.payload].op is Op.INPUT:
            assert plan.schedule.placements[t.tid].node == 0


def test_cache_reduces_comm():
    """Node-level cache (§3.5): with the cache, repeated cross-node use of
    the same tile version must not be re-sent."""
    n = 64
    A = CM.rand(n, n, seed=0)
    # A reused by several consumers -> cache hits expected at >1 node
    expr = (A @ A) + (A @ A.T)
    eng = CMMEngine(c5_9xlarge(4), analytic_time_model(), tile=16)
    plan = eng.plan(expr)
    sent = [(c.src_task, c.dst) for c in plan.schedule.comms if not c.cached]
    assert len(sent) == len(set(sent)), "same tile version sent twice to a node"


def test_cache_aware_not_worse():
    n, tile = 96, 24
    expr = (CM.rand(n, n, seed=0) @ CM.rand(n, n, seed=1)) @ \
        CM.rand(n, n, seed=2)
    prog = tile_expression(expr, tile)
    tm = analytic_time_model()
    spec = c5_9xlarge(4)
    s_on = heft_schedule(prog.graph, spec, tm, cache_aware=True,
                         fill_origin={k: "local" for k in prog.leaf_nodes})
    prog2 = tile_expression(expr, tile)
    s_off = heft_schedule(prog2.graph, spec, tm, cache_aware=False,
                          fill_origin={k: "local" for k in prog2.leaf_nodes})
    assert s_on.makespan <= s_off.makespan * 1.05


def test_upward_rank_monotone_on_chains():
    expr = (CM.rand(32, 32, seed=0) @ CM.rand(32, 32, seed=1))
    prog = tile_expression(expr, 16)
    g = prog.graph
    rank = upward_rank(g, c5_9xlarge(2), analytic_time_model())
    for t in g:
        for s in t.succs:
            assert rank[t.tid] > rank[s], "rank must decrease along edges"


def test_edge_bytes_accumulation_edges():
    expr = CM.rand(8, 8, seed=0) @ CM.rand(8, 8, seed=1)
    g = tile_expression(expr, 4).graph
    for t in g:
        if t.kind is TaskKind.ADDMUL:
            for p in t.preds:
                pt = g.tasks[p]
                b = edge_bytes(g, pt, t)
                assert b > 0, "addmul inputs and C-tile edges carry data"


def test_single_node_no_comm():
    plan = _plan(1)
    assert not [c for c in plan.schedule.comms if not c.cached]


def test_fill_origin_param_isolated_between_planners():
    """Satellite: fill origins travel with the heft_schedule CALL, so two
    planners with different origin maps can interleave without clobbering
    each other (the old module-global broke concurrent planning)."""
    a = np.ones((32, 32))
    expr_in = CM.from_array(a) @ CM.from_array(a)     # INPUT: master-pinned
    expr_rnd = CM.rand(32, 32, seed=0) @ CM.rand(32, 32, seed=1)
    tm = analytic_time_model()
    spec = c5_9xlarge(4)

    prog_in = tile_expression(expr_in, 16)
    prog_rnd = tile_expression(expr_rnd, 16)
    origin_in = {k: "master" for k in prog_in.leaf_nodes}
    origin_rnd = {k: "local" for k in prog_rnd.leaf_nodes}

    # pollute the deprecated global with the WRONG origins, then schedule
    # with explicit parameters — the parameter must win
    register_fill_origin({k: "local" for k in prog_in.leaf_nodes})
    s_rnd = heft_schedule(prog_rnd.graph, spec, tm, fill_origin=origin_rnd)
    s_in = heft_schedule(prog_in.graph, spec, tm, fill_origin=origin_in)
    for t in prog_in.graph:
        if t.kind is TaskKind.FILL:
            assert s_in.placements[t.tid].node == spec.master
    # generated fills are lazily placed, not pinned to the master
    fill_nodes = {s_rnd.placements[t.tid].node
                  for t in prog_rnd.graph if t.kind is TaskKind.FILL}
    assert fill_nodes  # scheduled at all
    register_fill_origin({})


def test_fast_and_slow_planning_identical():
    """The fast path (memoized costs, gap timelines) must produce the SAME
    schedule as the naive path — it is a representation change, not a
    heuristic change."""
    n = 96
    expr = (CM.rand(n, n, seed=0) @ CM.rand(n, n, seed=1)).relu() * 2.0 + \
        CM.rand(n, n, seed=2)
    tm = analytic_time_model()
    for nodes in (1, 3):
        e_fast = CMMEngine(c5_9xlarge(nodes), tm, plan_cache=False,
                           fast_planning=True)
        e_slow = CMMEngine(c5_9xlarge(nodes), tm, plan_cache=False,
                           fast_planning=False)
        p_fast = e_fast.plan(expr, tile=16)
        p_slow = e_slow.plan(expr, tile=16)
        assert set(p_fast.schedule.placements) == \
            set(p_slow.schedule.placements)
        for tid, pf in p_fast.schedule.placements.items():
            ps = p_slow.schedule.placements[tid]
            assert (pf.node, pf.slot, pf.start, pf.finish) == \
                (ps.node, ps.slot, ps.start, ps.finish)
        assert p_fast.schedule.makespan == p_slow.schedule.makespan
        assert p_fast.sim.makespan == p_slow.sim.makespan


def test_gap_timeline_matches_interval_timeline():
    """_GapTimeline is the exact complement representation of
    _SlotTimeline: identical earliest() answers under random workloads."""
    rng = np.random.default_rng(7)
    slow, fast = _SlotTimeline(), _GapTimeline()
    for _ in range(300):
        ready = float(rng.uniform(0, 50))
        dur = float(rng.uniform(0.01, 5))
        t1 = slow.earliest(ready, dur)
        t2 = fast.earliest(ready, dur)
        assert t1 == t2, (ready, dur, t1, t2)
        if rng.random() < 0.7:      # commit the placement to both
            slow.insert(t1, dur)
            fast.insert(t1, dur)


def test_more_nodes_not_slower_on_parallel_graph():
    """C1: speedup grows with node count (parallel-friendly benchmark)."""
    n = 512
    def build():
        A = CM.rand(n, n, seed=0)
        B = CM.rand(n, n, seed=1)
        C = CM.rand(n, n, seed=2)
        D = CM.rand(n, n, seed=3)
        return (A @ B) + (C @ D)
    tm = analytic_time_model()
    mk = {}
    for nodes in (1, 2, 4):
        eng = CMMEngine(c5_9xlarge(nodes), tm, tile=n // 4)
        mk[nodes] = eng.plan(build()).predicted_makespan
    assert mk[2] < mk[1]
    assert mk[4] <= mk[2] * 1.05


def test_heterogeneous_spec_slot_bounds_and_simulation():
    """Unequal per-node worker counts: HEFT must only use slots that exist
    on each node, and the simulator must respect the same capacities."""
    from repro.core.machine import hetero_spec
    from repro.core.simulator import simulate

    spec = hetero_spec((3, 1, 2), slowdown=(1.0, 2.0, 1.3),
                       link_bw=1e12, latency=1e-6)
    assert [spec.workers_at(n) for n in range(3)] == [3, 1, 2]
    assert spec.total_workers() == 6

    tm = analytic_time_model()
    A = CM.rand(64, 64, seed=0)
    B = CM.rand(64, 64, seed=1)
    C = CM.rand(64, 64, seed=2)
    D = CM.rand(64, 64, seed=3)
    eng = CMMEngine(spec, tm, tile=16, plan_cache=False)
    plan = eng.plan((A @ B) + (C @ D))
    g, sched = plan.program.graph, plan.schedule
    for tid, p in sched.placements.items():
        assert 0 <= p.slot < spec.workers_at(p.node), \
            f"task {tid} on nonexistent slot {p.slot} of node {p.node}"
    # concurrent occupancy in the simulation never exceeds a node's slots
    sim = simulate(g, sched, spec, tm)
    events = {}
    for iv in sim.intervals:
        if iv.slot < 0:          # calloc: async, occupies no worker slot
            continue
        events.setdefault(iv.node, []).append((iv.start, 1))
        events.setdefault(iv.node, []).append((iv.end, -1))
    for n, evs in events.items():
        live = peak = 0
        for _, d in sorted(evs, key=lambda e: (e[0], e[1])):
            live += d
            peak = max(peak, live)
        assert peak <= spec.workers_at(n)
