"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweep."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

MM_SHAPES = [(128, 128, 128), (256, 384, 128), (100, 70, 130),
             (257, 129, 255), (64, 512, 192), (1, 128, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return dict(rtol=5e-2, atol=5e-2) if dt == jnp.bfloat16 \
        else dict(rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_matmul_kernel(m, k, n, dt):
    rng = np.random.default_rng(m * 7 + n)
    a = jnp.asarray(rng.standard_normal((m, k)), dt)
    b = jnp.asarray(rng.standard_normal((k, n)), dt)
    out = ops.matmul(a, b)
    want = ref.matmul(a, b)
    assert out.shape == (m, n) and out.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@pytest.mark.parametrize("m,k,n", MM_SHAPES[:4])
@pytest.mark.parametrize("dt", DTYPES)
def test_addmul_kernel(m, k, n, dt):
    rng = np.random.default_rng(m + n)
    a = jnp.asarray(rng.standard_normal((m, k)), dt)
    b = jnp.asarray(rng.standard_normal((k, n)), dt)
    c = jnp.asarray(rng.standard_normal((m, n)), dt)
    out = ops.addmul(c, a, b)
    want = ref.addmul(c, a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@pytest.mark.parametrize("block", [(64, 64, 64), (128, 128, 256)])
def test_matmul_block_sweep(block):
    bm, bn, bk = block
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((192, 320)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((320, 224)), jnp.float32)
    out = ops.matmul(a, b, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul(a, b)),
                               rtol=1e-3, atol=1e-4)


def test_addmul_matches_cmm_task_semantics():
    """The kernel implements the paper's addmul: C += A @ B."""
    rng = np.random.default_rng(3)
    c0 = rng.standard_normal((64, 64)).astype(np.float32)
    a = rng.standard_normal((64, 64)).astype(np.float32)
    b = rng.standard_normal((64, 64)).astype(np.float32)
    out = ops.addmul(jnp.asarray(c0), jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(out), c0 + a @ b,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,d", [(256, 64), (128, 32), (384, 128)])
def test_flash_attention(causal, s, d):
    rng = np.random.default_rng(s + d)
    q = jnp.asarray(rng.standard_normal((2, 3, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 3, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 3, s, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=128,
                              block_k=128)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.bfloat16)
    out = ops.flash_attention(q, k, v)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_kernel_used_by_executor():
    """kernel executor path: tiled CMM execution through Pallas addmul."""
    from repro.core import CMMEngine, ClusteredMatrix as CM, c5_9xlarge
    from repro.core import analytic_time_model
    rng = np.random.default_rng(5)
    a = rng.standard_normal((96, 96))
    A = CM.from_array(a)
    eng = CMMEngine(c5_9xlarge(1), analytic_time_model(), tile=48)
    out = eng.run(A @ A, executor="kernel")
    np.testing.assert_allclose(out, a @ a, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [16, 32])
@pytest.mark.parametrize("normalize", [True, False])
def test_gla_kernel_vs_oracle(chunk, normalize):
    """Pallas chunkwise-GLA kernel vs the jnp chunkwise oracle (which is
    itself validated against the naive recurrence in test_properties)."""
    from repro.kernels.gla import gla
    from repro.models.ssm import chunkwise_gla
    rng = np.random.default_rng(chunk)
    B, S, H, dk, dv = 2, 64, 3, 8, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, dv)), jnp.float32)
    la = jnp.asarray(-np.abs(rng.standard_normal((B, S, H))) * 0.1,
                     jnp.float32)
    y_k = gla(q, k, v, la, chunk=chunk, normalize=normalize, interpret=True)
    y_r, _ = chunkwise_gla(q, k, v, la, chunk=chunk, normalize=normalize)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=3e-4, atol=3e-4)


def test_gla_kernel_bf16():
    from repro.kernels.gla import gla
    from repro.models.ssm import chunkwise_gla
    rng = np.random.default_rng(7)
    B, S, H, dk, dv = 1, 32, 2, 8, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, dk)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, H, dk)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, H, dv)), jnp.bfloat16)
    la = jnp.asarray(-np.abs(rng.standard_normal((B, S, H))) * 0.1,
                     jnp.float32)
    y_k = gla(q, k, v, la, chunk=16, interpret=True)
    y_r, _ = chunkwise_gla(q, k, v, la, chunk=16)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=5e-2, atol=5e-2)
