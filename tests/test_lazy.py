"""ClusteredMatrix expression semantics vs NumPy."""
import numpy as np
import pytest

from repro.core import ClusteredMatrix as CM
from repro.core.lazy import Op, eager_eval, topo_order


def test_operators_build_dag():
    P = CM.rand(8, 8, seed=0)
    u = CM.rand(8, 1, seed=1)
    e = (P @ P @ P) @ u
    assert e.shape == (8, 1)
    assert e.op is Op.MATMUL
    order = topo_order(e)
    assert order[-1] is e
    assert len([n for n in order if n.op is Op.MATMUL]) == 3


def test_eager_matches_numpy():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((6, 4))
    b = rng.standard_normal((4, 5))
    A, B = CM.from_array(a), CM.from_array(b)
    np.testing.assert_allclose((A @ B).eager(), a @ b)
    np.testing.assert_allclose((A + A).eager(), a + a)
    np.testing.assert_allclose((A - A).eager(), a * 0)
    np.testing.assert_allclose((A * 2.5).eager(), a * 2.5)
    np.testing.assert_allclose((A / 2.0).eager(), a / 2)
    np.testing.assert_allclose(A.T.eager(), a.T)
    np.testing.assert_allclose(A.sin().eager(), np.sin(a))
    np.testing.assert_allclose(A.hadamard(A).eager(), a * a)


def test_scalar_operand_orderings():
    """Every scalar-matrix operator in BOTH orderings (Table 1 row 4) —
    ``2 - M`` / ``2 / M`` used to raise TypeError — plus unary ``-M``.
    Bitwise vs NumPy: these are single elementwise passes."""
    rng = np.random.default_rng(2)
    a = rng.standard_normal((5, 7)) + 2.0     # keep away from 0 for 2/M
    M = CM.from_array(a)
    np.testing.assert_array_equal((M + 2.0).eager(), a + 2.0)
    np.testing.assert_array_equal((2.0 + M).eager(), 2.0 + a)
    np.testing.assert_array_equal((M - 2.0).eager(), a - 2.0)
    np.testing.assert_array_equal((2.0 - M).eager(), 2.0 - a)
    np.testing.assert_array_equal((M * 2.0).eager(), a * 2.0)
    np.testing.assert_array_equal((2.0 * M).eager(), 2.0 * a)
    np.testing.assert_array_equal((M / 2.0).eager(), a / 2.0)
    np.testing.assert_array_equal((2.0 / M).eager(), 2.0 / a)
    np.testing.assert_array_equal((-M).eager(), -a)
    np.testing.assert_array_equal((-(-M)).eager(), a)
    with pytest.raises(TypeError):
        _ = M / M                             # matrix / matrix stays illegal


def test_reflected_and_unary_ops_through_the_engine():
    """The new SCALE kinds (rsub/rdiv) and -M survive tiling, fusion
    (FUSED regions interpret them through apply_scale) and execution."""
    rng = np.random.default_rng(3)
    a = rng.standard_normal((24, 24)) + 3.0
    M = CM.from_array(a)
    e = ((2.0 - M).relu() + (1.0 / M)) - (-M)
    out = e.compute(tile=8)
    ref = (np.maximum(2.0 - a, 0.0) + (1.0 / a)) - (-a)
    np.testing.assert_allclose(out, ref, rtol=1e-12, atol=1e-12)
    np.testing.assert_array_equal(e.eager(), ref)


def test_star_is_matmul_between_matrices():
    """Paper semantics: x between matrices is matrix multiplication."""
    rng = np.random.default_rng(1)
    a = rng.standard_normal((4, 4))
    A = CM.from_array(a)
    np.testing.assert_allclose((A * A).eager(), a @ a)


def test_shape_errors():
    A = CM.rand(4, 5)
    B = CM.rand(4, 5)
    with pytest.raises(ValueError):
        _ = A @ B
    with pytest.raises(ValueError):
        _ = A + CM.rand(5, 4)


def test_vector_promotion():
    v = CM.from_array(np.arange(5.0))
    assert v.shape == (5, 1)


def test_compute_via_engine_matches_eager():
    P = CM.rand(32, 32, seed=3)
    u = CM.rand(32, 1, seed=4)
    e = (P @ P) @ u
    out = e.compute(tile=16)
    np.testing.assert_allclose(out, e.eager(), rtol=1e-10, atol=1e-10)
