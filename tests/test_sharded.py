"""Sharded executors (SUMMA/Cannon) + small-mesh jit of the real step fns.

These need >1 device, so they run in a subprocess with
``xla_force_host_platform_device_count=8`` (the main test process must keep
seeing ONE device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

#: this container's jax does not export ``jax.shard_map``, which the
#: sharded executors / expert-parallel MoE import in their subprocess —
#: a known environment failure, not a code regression (see TESTING.md)
env_no_shard_map = pytest.mark.xfail(
    strict=False,
    reason="env: this jax version has no jax.shard_map export; the "
           "sharded-executor subprocess dies on import (see TESTING.md)")


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=420)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@env_no_shard_map
def test_summa_2d_matches_dense():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.exec.sharded import matmul_2d
        mesh = jax.make_mesh((2, 4), ("x", "y"))
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((32, 96)), jnp.float32)
        out = matmul_2d(a, b, mesh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                                   rtol=1e-4, atol=1e-4)
        print("ok")
    """)


@env_no_shard_map
def test_cannon_matches_dense():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.exec.sharded import matmul_cannon
        mesh = jax.make_mesh((2, 2), ("x", "y"))
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
        out = matmul_cannon(a, b, mesh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                                   rtol=1e-4, atol=1e-4)
        print("ok")
    """)


@env_no_shard_map
def test_reduce_scatter_matmul():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.exec.sharded import reduce_scatter_matmul
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
        out = reduce_scatter_matmul(a, b, mesh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                                   rtol=1e-4, atol=1e-4)
        print("ok")
    """)


def test_train_step_on_small_mesh():
    """The real train_step jits + runs with real shardings on a 2x4 mesh."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from dataclasses import replace
        from repro.configs.base import get_plan, get_reduced
        from repro.models import lm as M
        from repro.train.steps import make_train_step
        from repro.launch import specs as S
        from repro.data.pipeline import DataConfig, make_batch

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = replace(get_reduced("qwen3-8b"), d_ff=192)
        plan = replace(get_plan("qwen3-8b", "train_4k"), microbatches=2)
        step, init_opt = make_train_step(cfg, plan, mesh)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        p_sh = S.params_shardings(cfg, plan, mesh)
        params = {k: jax.device_put(v, p_sh[k]) for k, v in params.items()}
        opt = init_opt(params)
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8,
                          microbatches=2)
        batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, 0).items()}
        b_sh = S.batch_shardings(cfg, S.SHAPES["train_4k"], plan, mesh,
                                 train=True)
        jitted = jax.jit(step, in_shardings=(p_sh, None, None),
                         donate_argnums=(0,))
        with mesh:
            p2, o2, m = jitted(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
        print("loss", float(m["loss"]))
    """)


@env_no_shard_map
def test_decode_step_on_small_mesh():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from dataclasses import replace
        from repro.configs.base import get_plan, get_reduced
        from repro.models import lm as M
        from repro.models.decode import init_cache
        from repro.train.steps import make_decode_step, make_prefill_step

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_reduced("olmoe-1b-7b")
        plan = get_plan("olmoe-1b-7b", "decode_32k")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        pre = make_prefill_step(cfg, plan, mesh, max_len=24)
        dec = make_decode_step(cfg, plan, mesh)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
        with mesh:
            cache, lg, tok = jax.jit(pre)(params, {"tokens": toks})
            for _ in range(3):
                cache, lg, tok = jax.jit(dec)(params, cache, tok)
        assert np.isfinite(np.asarray(lg)).all()
        print("ok")
    """)


@env_no_shard_map
def test_moe_expert_parallel_matches_scatter():
    """The shard_map expert-parallel MoE (the on-mesh default) must produce
    the same outputs as the GSPMD scatter implementation."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.moe import moe_ffn
        from repro.models.moe_ep import moe_ffn_ep

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        B, S, D, E, F, K = 4, 8, 16, 8, 12, 2
        params = {
            "router": jnp.asarray(rng.standard_normal((D, E)) * 0.1,
                                  jnp.float32),
            "w1": jnp.asarray(rng.standard_normal((E, D, F)) * 0.1,
                              jnp.float32),
            "w3": jnp.asarray(rng.standard_normal((E, D, F)) * 0.1,
                              jnp.float32),
            "w2": jnp.asarray(rng.standard_normal((E, F, D)) * 0.1,
                              jnp.float32),
        }
        x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
        # high capacity -> no drops -> implementations must agree exactly
        y_ref, aux_ref = jax.jit(lambda x, p: moe_ffn(
            x, p, top_k=K, capacity_factor=8.0))(x, params)
        with mesh:
            y_ep, aux_ep = jax.jit(lambda x, p: moe_ffn_ep(
                x, p, top_k=K, capacity_factor=8.0, act=jax.nn.silu,
                mesh=mesh, batch_axes=("data",)))(x, params)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
        # aux is computed per data shard and averaged (standard DP-MoE
        # approximation): mean of per-shard f*p != global f*p exactly
        np.testing.assert_allclose(float(aux_ep), float(aux_ref),
                                   rtol=0.25)
        # grads flow through the shard_map path
        def loss(p):
            y, aux = moe_ffn_ep(x, p, top_k=K, capacity_factor=8.0,
                                act=jax.nn.silu, mesh=mesh,
                                batch_axes=("data",))
            return (y ** 2).sum() + aux
        with mesh:
            g = jax.jit(jax.grad(loss))(params)
        assert all(np.isfinite(np.asarray(v)).all()
                   for v in jax.tree.leaves(g))
        print("ok")
    """)


def test_gather_once_matches_standard_train_step():
    """gather_once restructures the grad computation; one step must match
    the standard path (bf16-accumulation tolerance)."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from dataclasses import replace
        from repro.configs.base import get_plan, get_reduced
        from repro.models import lm as M
        from repro.train.steps import make_train_step
        from repro.launch import specs as S
        from repro.data.pipeline import DataConfig, make_batch

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = replace(get_reduced("qwen3-8b"), d_ff=192)
        base_plan = replace(get_plan("qwen3-8b", "train_4k"),
                            microbatches=2)
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8,
                          microbatches=2)
        batch = {k: jnp.asarray(v) for k, v in make_batch(dcfg, 0).items()}
        params0 = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        outs = {}
        for name, plan in [("std", base_plan),
                           ("g1", replace(base_plan, gather_once=True))]:
            step, init_opt = make_train_step(cfg, plan, mesh)
            p_sh = S.params_shardings(cfg, plan, mesh)
            params = {k: jax.device_put(v, p_sh[k])
                      for k, v in params0.items()}
            opt = init_opt(params)
            with mesh:
                p2, o2, m = jax.jit(step)(params, opt, batch)
            outs[name] = (float(m["loss"]), p2)
        assert abs(outs["std"][0] - outs["g1"][0]) < 1e-4
        for k in outs["std"][1]:
            np.testing.assert_allclose(
                np.asarray(outs["std"][1][k], np.float32),
                np.asarray(outs["g1"][1][k], np.float32),
                rtol=2e-2, atol=2e-3)
        print("ok")
    """)
