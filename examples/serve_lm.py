"""Batched serving driver: continuous prefill + decode with a KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-8b --requests 6

Uses the REDUCED config of the chosen architecture (CPU container); the
same `prefill`/`decode_step` functions are what the dry-run lowers for the
full configs on the production mesh.  Exercises:
  * batched prefill of a request batch,
  * greedy decode loop with the per-family cache (KV / ring+state / GLA),
  * simple continuous-batching bookkeeping (per-sequence stop + stats).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_plan, get_reduced
from repro.models import lm as M
from repro.train.steps import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    plan = get_plan(args.arch, "decode_32k")
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    print(f"serving {cfg.name} (reduced: {M.param_count(params)/1e3:.0f}k "
          f"params), batch={args.requests}")

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab,
                           (args.requests, args.prompt_len)).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (args.requests, cfg.enc_frames, cfg.d_model)), jnp.float32)
    if cfg.vision_patches:
        batch["patches"] = jnp.asarray(rng.standard_normal(
            (args.requests, cfg.vision_patches, cfg.d_model)), jnp.float32)

    max_len = args.prompt_len + args.max_new + (cfg.vision_patches or 0)
    prefill = jax.jit(make_prefill_step(cfg, plan, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg, plan))

    t0 = time.perf_counter()
    cache, logits, tok = prefill(params, batch)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.requests}x{args.prompt_len} tokens in "
          f"{t_prefill*1e3:.0f} ms "
          f"({args.requests*args.prompt_len/t_prefill:.0f} tok/s)")

    eos = 0  # token 0 acts as EOS for the demo
    done = np.zeros(args.requests, bool)
    out_tokens = [np.asarray(tok)[:, 0]]
    t0 = time.perf_counter()
    steps = 0
    for _ in range(args.max_new - 1):
        cache, logits, tok = decode(params, cache, tok)
        steps += 1
        t = np.asarray(tok)[:, 0]
        out_tokens.append(np.where(done, eos, t))
        done |= (t == eos)
        if done.all():
            break
    dt = time.perf_counter() - t0
    gen = np.stack(out_tokens, 1)
    print(f"decode: {steps} steps x {args.requests} seqs in {dt*1e3:.0f} ms "
          f"({steps*args.requests/max(dt,1e-9):.0f} tok/s)")
    for i in range(min(3, args.requests)):
        print(f"  req{i}: prompt={prompts[i][:8].tolist()}... "
              f"-> generated={gen[i][:12].tolist()}...")
    print(f"cache position after serve: {int(cache['pos'])}")


if __name__ == "__main__":
    main()
