"""End-to-end training driver: train an LM with the full substrate —
data pipeline, AdamW, remat + grad accumulation, checkpointing + restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 300 --resume
    PYTHONPATH=src python examples/train_lm.py --scale 100m --steps 200

Default scale is a ~4M-param qwen3-style model so a few hundred steps run
on this single-core CPU container in minutes; ``--scale 100m`` selects the
~100M-param config for real hardware (same code path; on TPU also pass
--mesh to shard it).
"""
import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager, CheckpointPolicy
from repro.checkpoint.store import config_hash
from repro.configs.base import ModelConfig, ParallelPlan
from repro.data.pipeline import DataConfig, Prefetcher
from repro.models import lm as M
from repro.optim.adamw import OptConfig
from repro.train.steps import TrainHParams, make_train_step

SCALES = {
    "tiny": ModelConfig(name="tiny-lm", family="dense", n_layers=4,
                        d_model=128, n_heads=4, n_kv=2, d_ff=384,
                        vocab=4096, act="silu", qk_norm=True,
                        rope_theta=1e4),
    "100m": ModelConfig(name="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv=4, d_ff=2048,
                        vocab=32000, act="silu", qk_norm=True,
                        rope_theta=1e4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=list(SCALES), default="tiny")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/cmm_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = SCALES[args.scale]
    plan = ParallelPlan(microbatches=args.microbatches)
    hp = TrainHParams(opt=OptConfig(lr=3e-3, warmup=20,
                                    decay_steps=args.steps))
    step_fn, init_opt = make_train_step(cfg, plan, hp=hp)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    n_params = M.param_count(params)
    print(f"model: {cfg.name}  params: {n_params/1e6:.1f}M  "
          f"plan: mb={plan.microbatches} remat={plan.remat}")
    opt = init_opt(params)

    mgr = CheckpointManager(args.ckpt_dir,
                            CheckpointPolicy(every_steps=args.ckpt_every,
                                             keep=2, async_save=True))
    start = 0
    if args.resume:
        got = mgr.maybe_restore(cfg)
        if got:
            start, params, opt = got
            params = {k: jnp.asarray(v) for k, v in params.items()}
            opt = jax.tree.map(jnp.asarray, opt)
            print(f"resumed from step {start}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=1234,
                      microbatches=plan.microbatches)
    pf = Prefetcher(dcfg, start_step=start, prefetch=2)
    meta = {"config_hash": config_hash(cfg)}

    t0 = time.perf_counter()
    tokens_seen = start * args.batch * args.seq
    try:
        for i in range(start, args.steps):
            s, batch = next(pf)
            assert s == i
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, m = step_fn(params, opt, batch)
            tokens_seen += args.batch * args.seq
            mgr.step_hook(i + 1, params, opt, meta)
            if i % 10 == 0 or i == args.steps - 1:
                dt = time.perf_counter() - t0
                print(f"step {i:4d}  loss {float(m['loss']):7.4f}  "
                      f"lr {float(m['lr']):.2e}  "
                      f"|g| {float(m['grad_norm']):6.2f}  "
                      f"tok/s {tokens_seen/max(dt,1e-9):8.0f}")
    finally:
        pf.close()
        mgr.store.wait()
    print(f"done: {args.steps - start} steps in "
          f"{time.perf_counter()-t0:.1f}s; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
