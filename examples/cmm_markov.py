"""The paper's running example (Fig. 2): the Markov benchmark.

    PYTHONPATH=src python examples/cmm_markov.py [n]

Builds u' = P^3 u, shows the tiled task graph, the HEFT schedule as an
ASCII Gantt chart (Fig. 3), the tile-size sweep (§3.3), and the
theoretical-speedup experiment (Table 4).
"""
import sys

import numpy as np

from repro.core import (CMMEngine, ClusteredMatrix as CM, c5_9xlarge,
                        profile_machine, simulate)


def main(n: int = 512):
    rng = np.random.default_rng(0)
    P = CM.from_array(rng.standard_normal((n, n)) / np.sqrt(n), "P")
    u = CM.from_array(rng.standard_normal((n, 1)), "u")
    expr = (P @ P @ P) @ u                     # Fig. 2

    tm = profile_machine(sizes=(64, 128, 256), reps=2)

    print(f"=== tile sweep (simulated makespan, 8 nodes), n={n} ===")
    eng8 = CMMEngine(c5_9xlarge(8), tm)
    for tile in (n // 10, 3 * n // 10, n // 2, 7 * n // 10):
        plan = eng8.plan(expr, tile=tile)
        print(f"  tile {tile:5d}: {plan.predicted_makespan*1e3:8.1f} ms  "
              f"({len(plan.program.graph)} tasks)")

    print("\n=== schedule for 2 nodes, tile=3n/10 (cf. Fig. 3) ===")
    eng2 = CMMEngine(c5_9xlarge(2), tm, tile=3 * n // 10)
    plan2 = eng2.plan(expr)
    print(plan2.sim.gantt(96))
    print("legend: #=addmul f=fill .=calloc c=takecopy >=transfer")

    print("\n=== observed vs theoretical speedup (Table 4) ===")
    tile = n // 2
    base = CMMEngine(c5_9xlarge(1), tm, tile=tile).plan(expr).sim.makespan
    planN = CMMEngine(c5_9xlarge(8), tm, tile=tile).plan(expr)
    obs = base / planN.sim.makespan
    zc = simulate(planN.program.graph, planN.schedule,
                  c5_9xlarge(8), tm, zero_comm=True)
    theo = base / zc.makespan
    print(f"  observed {obs:.2f}x   theoretical (zero-comm) {theo:.2f}x  "
          f"({obs/theo*100:.0f}% of theoretical)")

    print("\n=== execute + validate ===")
    out = eng2.run(expr, validate=True)
    print(f"OK: result {out.shape}, validated against NumPy.")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 512)
