"""Quickstart: the CMM matrix language in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Write matrix expressions against ``ClusteredMatrix``; nothing executes
until ``.compute()``.  The engine tiles the expression, schedules it with
cache-aware HEFT under an offline-profiled time model, simulates the
schedule, and runs it — and you can ask it to validate against the eager
NumPy oracle.
"""
import numpy as np

from repro.core import (CMMEngine, ClusteredMatrix as CM, c5_9xlarge,
                        profile_machine, tune_tile)


def main():
    # 1. profile this machine once (offline, ~seconds) ---------------------
    print("profiling machine (offline)...")
    tm = profile_machine(sizes=(64, 128, 256), reps=2)
    print(f"  dispatch overhead: {tm.dispatch_overhead*1e6:.0f} us/task")

    # 2. write a lazy matrix program ---------------------------------------
    rng = np.random.default_rng(0)
    a = rng.standard_normal((512, 512))
    b = rng.standard_normal((512, 512))
    A, B = CM.from_array(a, "A"), CM.from_array(b, "B")
    expr = (A @ B).relu() @ (A - B).T          # nothing has run yet
    print(f"expression: {expr}")

    # 3. plan on an 4-node cluster model ------------------------------------
    eng = CMMEngine(c5_9xlarge(4), tm)
    best, scores = eng.autotune_tile(expr, [64, 128, 256, 512])
    print("tile autotune (simulated makespan):",
          {k: f"{v*1e3:.1f}ms" for k, v in sorted(scores.items())})
    print(f"  -> selected tile {best}")

    plan = eng.plan(expr, tile=best)
    print(f"tasks: {plan.program.graph.counts()}")
    print(f"simulated makespan: {plan.predicted_makespan*1e3:.1f} ms "
          f"(plan overhead {plan.plan_seconds*1e3:.0f} ms)")
    print(f"schedule cache hits/misses: {plan.schedule.cache_hits}/"
          f"{plan.schedule.cache_misses}")

    # 4. execute + validate against eager NumPy ------------------------------
    out = eng.run(expr, plan=plan, validate=True)
    print(f"executed OK; result shape {out.shape}, "
          f"max|out| = {np.abs(out).max():.3f}")
    print("validated against the NumPy oracle.")


if __name__ == "__main__":
    main()
