"""Durable-session recovery benchmark + smoke gate -> BENCH_recovery.json.

Measures what the durability layer (``runtime/durability.py`` +
``CMMSession(checkpoint_dir=...)``) costs in steady state and buys on
recovery:

* **overhead leg** — the k persisted chain steps of a power-iteration
  run twice, without and with checkpointing (incremental snapshots: only
  the new handle's tiles are written per step, the disk write overlaps
  the next compute and COALESCES under backpressure).  Each rep measures
  the two legs back-to-back and the gate takes the best RATIO over reps
  (wall noise on a shared host inflates both legs of a pair together).
  Gated at **< 10 %**; skipped, per the repo's wall-clock policy, while
  the 1-minute load average exceeds 1.25 per CPU — a loaded host cannot
  measure the quantity.
* **recovery leg** — time-to-recover the full residency table via
  ``CMMSession.resume``: reload-from-disk (``policy="reload"``) vs pure
  lineage recompute (``policy="recompute"``), on a chain whose recompute
  replays k GEMMs.  Wall numbers are informational; what is GATED is the
  contract: both restores are **bit-identical** to the uninterrupted
  session.
* **intact leg** — tears the newest snapshot (simulated crash mid-save)
  and demands ``resume()`` fall back to the previous intact one and
  still produce the exact bytes that snapshot held.

Exit status is non-zero on any failed check — wired into CI as the
``recovery-smoke`` job (``--smoke``: small inputs, writes
``BENCH_recovery_smoke.json`` so the committed artifact is never
clobbered, per repo convention).

    PYTHONPATH=src python benchmarks/recovery_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

from repro.core import ClusteredMatrix as CM, CMMEngine, analytic_time_model
from repro.core.machine import local_spec
from repro.core.session import CMMSession

REPS = 3          # best-of-N wall clocks (load spikes inflate, never deflate)
LOAD_BAR = 1.25   # loadavg/cpu above which wall gates are skipped


def _fresh_engine():
    return CMMEngine(local_spec(1), analytic_time_model())


def _host_load_per_cpu() -> float:
    try:
        return os.getloadavg()[0] / max(1, os.cpu_count() or 1)
    except OSError:                     # pragma: no cover — non-POSIX
        return 0.0


def _chain(s: CMMSession, n: int, k: int):
    """The benchmark workload: persist P once, chain U <- P U k times
    (full GEMMs, so per-step compute is what a checkpoint must amortise
    against; the paper's Markov chain with a matrix state)."""
    P = s.persist(CM.rand(n, n, seed=0), name="P")
    u = s.persist(CM.rand(n, n, seed=1), name="u")
    for i in range(k):
        u = s.persist(P @ u, name=f"u{i}")
    return u


def _run_chain_wall(n: int, k: int, tile: int, ckpt_dir=None):
    """Wall of the STEADY-STATE window: the k persisted chain steps.
    Session construction and the initial data-load persists are outside
    the window (their snapshots are drained before it opens) — what is
    measured is exactly the recurring per-step cost a long-running
    session pays: the synchronous tile handoff plus whatever of the
    asynchronous write the host cannot overlap."""
    with CMMSession(_fresh_engine(), executor="local", tile=tile,
                    checkpoint_dir=ckpt_dir) as s:
        P = s.persist(CM.rand(n, n, seed=0), name="P")
        u = s.persist(CM.rand(n, n, seed=1), name="u")
        if ckpt_dir is not None:
            s.flush_checkpoints()           # setup snapshots drained
        t0 = time.perf_counter()
        for i in range(k):
            u = s.persist(P @ u, name=f"u{i}")
        out = u.to_numpy()
        wall = time.perf_counter() - t0
        if ckpt_dir is not None:
            s.flush_checkpoints()
    return wall, out


def run_overhead(n: int, k: int, tile: int, gate: bool = True) -> dict:
    """Steady-state checkpoint overhead, best-of-REPS, gated < 10 %.

    ``gate=False`` (the --smoke path) reports the number but does not
    enforce the band: at smoke sizes per-step compute is too small for
    the fixed per-snapshot costs to amortise, so only the full-size
    committed artifact carries the gate.  Even when gating, the repo's
    wall-clock policy applies: skipped while the host load exceeds
    LOAD_BAR per CPU (a loaded host cannot measure the quantity)."""
    # paired reps: each rep measures plain and checkpointed back-to-back,
    # and the rep's RATIO is what matters — wall noise on a shared host
    # inflates both legs of a pair together, so min-over-pairs of the
    # ratio is far more stable than comparing two independent best-ofs
    pairs = []
    ref = got = None
    for _ in range(REPS):
        wp, ref = _run_chain_wall(n, k, tile)
        d = tempfile.mkdtemp(prefix="cmm_recovery_bench_")
        try:
            wc, got = _run_chain_wall(n, k, tile, ckpt_dir=d)
        finally:
            shutil.rmtree(d, ignore_errors=True)
        pairs.append((wc / wp, wp, wc))
    ratio, wall_plain, wall_ckpt = min(pairs)
    overhead = ratio - 1.0
    load = _host_load_per_cpu()
    skipped = (not gate) or (overhead >= 0.10 and load > LOAD_BAR)
    if not gate:
        note = "overhead gate not enforced in --smoke (workload too " \
               "small to amortise fixed snapshot costs); see the " \
               "committed BENCH_recovery.json"
    elif skipped:
        note = (f"overhead gate SKIPPED: host load {load:.2f}/cpu > "
                f"{LOAD_BAR} (wall-clock policy)")
    else:
        note = "gated < 10%"
    return {
        "case": "checkpoint_overhead", "n": n, "k": k, "tile": tile,
        "reps": REPS,
        "wall_plain_s": wall_plain,
        "wall_checkpointed_s": wall_ckpt,
        "overhead_pct": 100.0 * overhead,
        "load_per_cpu": load,
        "ok_bitident_ckpt": bool(np.array_equal(ref, got)),
        "ok_overhead_lt_10pct": True if skipped else bool(overhead < 0.10),
        "_note": note,
    }


def run_recovery(n: int, k: int, tile: int, reps: int = 1) -> dict:
    """Time-to-recover via resume(): reload vs pure lineage recompute.
    The GATE is bit-identity of both restores; the walls (and their
    ratio) are informational, so one rep suffices at full size."""
    d = tempfile.mkdtemp(prefix="cmm_recovery_bench_")
    try:
        with CMMSession(_fresh_engine(), executor="local", tile=tile,
                        checkpoint_dir=d) as s:
            u = _chain(s, n, k)
            ref = u.to_numpy()
            s.flush_checkpoints()
        walls = {}
        bitident = True
        for policy in ("reload", "recompute"):
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                with CMMSession.resume(d, _fresh_engine(), executor="local",
                                       tile=tile, policy=policy) as s2:
                    wall = time.perf_counter() - t0   # table fully rebuilt
                    got = s2.resident(f"u{k - 1}").to_numpy()
                    rep = s2.stats["resume"]
                    if policy == "reload":
                        bitident &= not rep["recomputed"]
                    else:
                        bitident &= not rep["reloaded"]
                bitident &= bool(np.array_equal(got, ref))
                best = min(best, wall)
            walls[policy] = best
        return {
            "case": "recovery_time", "n": n, "k": k, "tile": tile,
            "reps": reps,
            "recover_reload_s": walls["reload"],
            "recover_recompute_s": walls["recompute"],
            "reload_vs_recompute": walls["recompute"] /
            max(walls["reload"], 1e-12),
            "ok_bitident_resume": bool(bitident),
        }
    finally:
        shutil.rmtree(d, ignore_errors=True)


def run_intact(n: int, k: int, tile: int) -> dict:
    """Crash mid-save: tear the newest snapshot's shards, resume must
    fall back to the previous intact one — exact bytes, no hang."""
    from repro.runtime.durability import TileCheckpointStore
    d = tempfile.mkdtemp(prefix="cmm_recovery_bench_")
    try:
        with CMMSession(_fresh_engine(), executor="local", tile=tile,
                        checkpoint_dir=d) as s:
            _chain(s, n, k)
            s.flush_checkpoints()
            prior = s.resident(f"u{k - 2}").to_numpy()
            u = s.persist(s.resident("P") @ s.resident(f"u{k - 1}"),
                          name=f"u{k}")
            s.flush_checkpoints()
        st = TileCheckpointStore(d)
        newest = st.snaps()[-1]
        for f in glob.glob(os.path.join(d, f"snap_{newest}", "*.npy")):
            os.unlink(f)
        with CMMSession.resume(d, _fresh_engine(), executor="local",
                               tile=tile) as s2:
            step = s2.stats["resume"]["step"]
            fell_back = step < newest
            names = sorted(h.name for h in s2._handles.values())
            got = s2.resident(f"u{k - 2}").to_numpy()
        return {
            "case": "intact_fallback", "n": n, "k": k, "tile": tile,
            "torn_step": newest, "restored_step": step,
            "restored_handles": names,
            "ok_intact": bool(fell_back and np.array_equal(got, prior)),
        }
    finally:
        shutil.rmtree(d, ignore_errors=True)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small inputs (the CI recovery-smoke gate)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        name = "BENCH_recovery_smoke.json" if args.smoke \
            else "BENCH_recovery.json"
        args.out = os.path.join(os.path.dirname(__file__), "..", name)

    if args.smoke:
        cases = [run_overhead(256, 4, 128, gate=False),
                 run_recovery(256, 4, 128),
                 run_intact(256, 4, 128)]
    else:
        # overhead leg: per-step compute grows n^3 while checkpoint bytes
        # grow n^2 — steady state needs GEMMs big enough to cover the
        # writer's CPU share even on a host with no spare core.  The
        # intact leg is a pure correctness check, so it runs small.
        cases = [run_overhead(6144, 4, 1024),
                 run_recovery(6144, 4, 1024),
                 run_intact(1024, 4, 512)]

    ok = True
    for c in cases:
        checks = {kk: v for kk, v in c.items() if kk.startswith("ok_")}
        ok &= all(checks.values())
        line = " ".join(f"{kk}={v}" for kk, v in checks.items())
        if c["case"] == "checkpoint_overhead":
            print(f"[recovery] overhead n={c['n']} k={c['k']} "
                  f"wall {c['wall_plain_s']:.3f}s->"
                  f"{c['wall_checkpointed_s']:.3f}s "
                  f"(+{c['overhead_pct']:.1f}%) {line}")
        elif c["case"] == "recovery_time":
            print(f"[recovery] resume n={c['n']} k={c['k']} "
                  f"reload {c['recover_reload_s']:.3f}s vs recompute "
                  f"{c['recover_recompute_s']:.3f}s "
                  f"({c['reload_vs_recompute']:.2f}x) {line}")
        else:
            print(f"[recovery] intact torn_step={c['torn_step']} "
                  f"restored_step={c['restored_step']} {line}")
        if not all(checks.values()):
            print(f"[recovery] CHECK FAILED: {c['case']}", file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump({"cases": cases}, f, indent=2)
    print(f"[recovery] wrote {os.path.abspath(args.out)}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
