"""Figure 3: task schedules (Gantt) for the Markov benchmark, 1-4 nodes.

Reproduced features (checked in tests):
  * comm appears at the start (master -> workers) and end (takecopy)
    with few nodes;
  * more nodes -> more tasks (the paper counts 421/579/644 CMM tasks for
    1/2/4 worker-node networks at 3k tiles — ours counts its own tiling);
  * workers start after the master (they wait on the first transfers).
"""
from __future__ import annotations

from repro.core import CMMEngine, ClusteredMatrix as CM, c5_9xlarge, simulate
from .table3_scaling import time_model


def markov_input_pinned(n: int):
    """Markov with user-supplied (master-resident) inputs, so the initial
    master->worker communication phase of Fig. 3 is visible."""
    import numpy as np
    rng = np.random.default_rng(0)
    P = CM.from_array(rng.standard_normal((n, n)), "P")
    u = CM.from_array(rng.standard_normal((n, 1)), "u")
    return (P @ P @ P) @ u


def main(n: int = 512, nodes_list=(2, 4), width: int = 96):
    tm = time_model()
    for nodes in nodes_list:
        eng = CMMEngine(c5_9xlarge(nodes), tm, tile=max(1, 3 * n // 10))
        plan = eng.plan(markov_input_pinned(n))
        print(f"=== Markov n={n} tile={3*n//10} nodes={nodes} "
              f"tasks={len(plan.program.graph)} "
              f"makespan={plan.sim.makespan:.3f}s ===")
        print(plan.sim.gantt(width))
        print("legend: #=addmul f=fill .=calloc c=takecopy >=transfer "
              "-=sub ~=ewise t=transpose")
        print()
    return True


if __name__ == "__main__":
    main()
