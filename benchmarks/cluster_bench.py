"""Multi-process cluster executor benchmark + smoke gate -> BENCH_cluster.json.

Runs a tiled GEMM workload on a 2-node spec through the executor registry:
the multi-process ``cluster`` backend (one worker process per node, real
shared-memory XFERs) against the in-process ``local`` backend on the SAME
plan, checking:

* **oracle**: cluster output is bit-identical to the per-task executor and
  within tolerance of ``eager()`` (multi-k-tile reduction order);
* **placement**: every task ran in the worker process of its HEFT-assigned
  node (``exec_nodes`` vs ``Schedule.placements``);
* **transfers**: the schedule's cross-node edges produced real XFERs.

Exit status is non-zero on any mismatch — wired into CI as the
cluster-executor smoke gate (``--smoke``: 2-node spec, small GEMM).

    PYTHONPATH=src python benchmarks/cluster_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import CMMEngine, ClusteredMatrix as CM, analytic_time_model
from repro.core.machine import hetero_spec
from repro.exec import make_executor


def build_gemm(n: int, seed: int = 0) -> CM:
    A = CM.rand(n, n, seed=seed, name="A")
    B = CM.rand(n, n, seed=seed + 1, name="B")
    C = CM.rand(n, n, seed=seed + 2, name="C")
    return (A @ B) + C


def run_case(n: int, tile: int, node_workers, reps: int = 1) -> dict:
    from repro.core.profiler import calibrate_ipc
    spec = hetero_spec(node_workers, link_bw=1e12, latency=1e-6)
    tm = analytic_time_model()
    calibrate_ipc(tm)     # measured queue round-trip + shm copy bandwidth
    eng = CMMEngine(spec, tm, plan_cache=False)
    expr = build_gemm(n)
    plan = eng.plan(expr, tile=tile)

    results = {}
    walls = {}
    stats = {}
    for backend in ("local", "cluster"):
        ex = make_executor(backend)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = ex.execute(plan)
            best = min(best, time.perf_counter() - t0)
        results[backend] = out
        walls[backend] = best
        stats[backend] = ex.stats

    ok_bitident = bool(np.array_equal(results["local"], results["cluster"]))
    ok_oracle = bool(np.allclose(results["cluster"], expr.eager(),
                                 rtol=1e-8, atol=1e-10))
    sched_nodes = {tid: p.node
                   for tid, p in plan.schedule.placements.items()}
    ok_placement = stats["cluster"]["exec_nodes"] == sched_nodes
    n_xfer_sched = len(plan.schedule.xfers(plan.program.graph))
    return {
        "n": n, "tile": tile, "node_workers": list(node_workers),
        "tasks": len(plan.program.graph),
        "wall_local_s": walls["local"],
        "wall_cluster_s": walls["cluster"],
        "predicted_cluster_s": plan.cluster_makespan,
        "xfers": stats["cluster"]["xfers"],
        "xfers_scheduled": n_xfer_sched,
        "xfer_bytes": stats["cluster"]["xfer_bytes"],
        "peak_buffer_bytes": stats["cluster"]["peak_buffer_bytes"],
        "nodes_used": len(set(stats["cluster"]["exec_nodes"].values())),
        "ok_bitident": ok_bitident,
        "ok_oracle": ok_oracle,
        "ok_placement": ok_placement,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small 2-node GEMM, oracle-checked (the CI gate)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: BENCH_cluster.json, "
                         "or BENCH_cluster_smoke.json under --smoke so the "
                         "smoke gate never clobbers the published artifact)")
    args = ap.parse_args()
    if args.out is None:
        name = "BENCH_cluster_smoke.json" if args.smoke \
            else "BENCH_cluster.json"
        args.out = os.path.join(os.path.dirname(__file__), "..", name)

    if args.smoke:
        cases = [run_case(96, 32, (2, 1))]
    else:
        cases = [run_case(256, 64, (2, 1), reps=2),
                 run_case(384, 96, (3, 2, 1), reps=2)]

    ok = True
    for c in cases:
        ok &= c["ok_bitident"] and c["ok_oracle"] and c["ok_placement"]
        print(f"[cluster] n={c['n']} tile={c['tile']} "
              f"nodes={c['node_workers']} tasks={c['tasks']} "
              f"xfers={c['xfers']}/{c['xfers_scheduled']} "
              f"nodes_used={c['nodes_used']} "
              f"local={c['wall_local_s']:.3f}s "
              f"cluster={c['wall_cluster_s']:.3f}s "
              f"bitident={c['ok_bitident']} oracle={c['ok_oracle']} "
              f"placement={c['ok_placement']}")
        if not (c["ok_bitident"] and c["ok_oracle"] and c["ok_placement"]):
            print(f"[cluster] CHECK FAILED at n={c['n']} tile={c['tile']}",
                  file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump({"cases": cases}, f, indent=2)
    print(f"[cluster] wrote {os.path.abspath(args.out)}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
