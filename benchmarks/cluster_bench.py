"""Multi-process cluster executor benchmark + smoke gate -> BENCH_cluster.json.

Runs a tiled GEMM workload on a 2-node spec through the executor registry:
the multi-process ``cluster`` backend (one worker process per node, real
shared-memory XFERs) against the in-process ``local`` backend on the SAME
plan, checking:

* **oracle**: cluster output is bit-identical to the per-task executor and
  within tolerance of ``eager()`` (multi-k-tile reduction order);
* **placement**: every task ran in the worker process of its HEFT-assigned
  node (``exec_nodes`` vs ``Schedule.placements``);
* **transfers**: the schedule's cross-node edges produced real XFERs;
* **drift**: predicted-vs-actual makespan error is recorded per run for
  both backends (time-model drift tracking across PRs).

``--elastic`` switches to the chaos leg (-> BENCH_elastic.json): one run
SIGKILLs a worker node mid-bench (oracle-gated lineage recovery, recovery
overhead reported vs an unperturbed elastic run), and one run joins a
fresh node mid-bench and must strictly reduce the measured makespan
versus not joining.

Exit status is non-zero on any mismatch — wired into CI as the
cluster-executor smoke gate and the chaos-smoke gate (``--smoke``:
small inputs, ``BENCH_*_smoke.json`` outputs so committed artifacts are
never clobbered).

    PYTHONPATH=src python benchmarks/cluster_bench.py \\
        [--smoke] [--elastic] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import CMMEngine, ClusteredMatrix as CM, analytic_time_model
from repro.core.machine import hetero_spec
from repro.exec import make_executor


def build_gemm(n: int, seed: int = 0) -> CM:
    A = CM.rand(n, n, seed=seed, name="A")
    B = CM.rand(n, n, seed=seed + 1, name="B")
    C = CM.rand(n, n, seed=seed + 2, name="C")
    return (A @ B) + C


def run_case(n: int, tile: int, node_workers, reps: int = 1) -> dict:
    from repro.core.profiler import calibrate_ipc
    spec = hetero_spec(node_workers, link_bw=1e12, latency=1e-6)
    tm = analytic_time_model()
    calibrate_ipc(tm)     # measured queue round-trip + shm copy bandwidth
    eng = CMMEngine(spec, tm, plan_cache=False)
    expr = build_gemm(n)
    plan = eng.plan(expr, tile=tile)

    results = {}
    walls = {}
    stats = {}
    for backend in ("local", "cluster"):
        ex = make_executor(backend)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = ex.execute(plan)
            best = min(best, time.perf_counter() - t0)
        results[backend] = out
        walls[backend] = best
        stats[backend] = ex.stats

    ok_bitident = bool(np.array_equal(results["local"], results["cluster"]))
    ok_oracle = bool(np.allclose(results["cluster"], expr.eager(),
                                 rtol=1e-8, atol=1e-10))
    sched_nodes = {tid: p.node
                   for tid, p in plan.schedule.placements.items()}
    ok_placement = stats["cluster"]["exec_nodes"] == sched_nodes
    n_xfer_sched = len(plan.schedule.xfers(plan.program.graph))
    pred_local = plan.predicted_makespan
    pred_cluster = plan.cluster_makespan
    return {
        "n": n, "tile": tile, "node_workers": list(node_workers),
        "tasks": len(plan.program.graph),
        "wall_local_s": walls["local"],
        "wall_cluster_s": walls["cluster"],
        "predicted_local_s": pred_local,
        "predicted_cluster_s": pred_cluster,
        # signed relative drift of the time model: (actual - predicted)
        # / predicted, tracked per run so recalibration needs are visible
        "makespan_err_local": (walls["local"] - pred_local)
        / max(pred_local, 1e-12),
        "makespan_err_cluster": (walls["cluster"] - pred_cluster)
        / max(pred_cluster, 1e-12),
        "xfers": stats["cluster"]["xfers"],
        "xfers_scheduled": n_xfer_sched,
        "xfer_bytes": stats["cluster"]["xfer_bytes"],
        "peak_buffer_bytes": stats["cluster"]["peak_buffer_bytes"],
        "nodes_used": len(set(stats["cluster"]["exec_nodes"].values())),
        "ok_bitident": ok_bitident,
        "ok_oracle": ok_oracle,
        "ok_placement": ok_placement,
    }


def _elastic_wall(plan, tm, chaos=(), reps: int = 2,
                  blas_threads=None):
    """Best-of-``reps`` elastic wall clock + the last run's stats/output."""
    from repro.exec.elastic import ElasticClusterExecutor
    best, out, stats = float("inf"), None, None
    for _ in range(reps):
        ex = ElasticClusterExecutor(timemodel=tm, chaos=chaos,
                                    blas_threads=blas_threads)
        t0 = time.perf_counter()
        out = ex.execute(plan)
        best = min(best, time.perf_counter() - t0)
        stats = ex.stats
    return best, out, stats


def run_elastic_kill_case(n: int, tile: int, node_workers,
                          reps: int = 2) -> dict:
    """SIGKILL one worker node mid-run: lineage recovery must keep the
    result bit-identical to the per-task executor; recovery overhead is
    the chaos wall minus the unperturbed elastic wall."""
    from repro.exec.elastic import ChaosEvent
    spec = hetero_spec(node_workers, link_bw=1e12, latency=1e-6)
    tm = analytic_time_model()
    eng = CMMEngine(spec, tm, plan_cache=False)
    expr = build_gemm(n)
    plan = eng.plan(expr, tile=tile)
    ref = make_executor("local").execute(plan)

    wall_plain, out_plain, _ = _elastic_wall(plan, tm, reps=reps)
    victim = 1
    kill_at = max(1, len(plan.program.graph) // 3)
    chaos = (ChaosEvent(after_done=kill_at, kill_node=victim),)
    wall_chaos, out_chaos, st = _elastic_wall(plan, tm, chaos, reps=reps)

    return {
        "case": "elastic_kill", "n": n, "tile": tile,
        "node_workers": list(node_workers),
        "tasks": len(plan.program.graph),
        "killed_node": victim, "killed_after_done": kill_at,
        "wall_elastic_s": wall_plain,
        "wall_elastic_chaos_s": wall_chaos,
        "recovery_overhead_s": wall_chaos - wall_plain,
        "recovered_tasks": st["recovered_tasks"],
        "replans": st["replans"],
        "deaths": st["deaths"],
        "recovery_seconds": st["recovery_seconds"],
        "ok_bitident": bool(np.array_equal(ref, out_chaos)
                            and np.array_equal(ref, out_plain)),
        "ok_oracle": bool(np.allclose(out_chaos, expr.eager(),
                                      rtol=1e-8, atol=1e-10)),
        "ok_death_detected": st["deaths"] == 1,
    }


def run_elastic_join_case(n: int, tile: int, join_workers: int = 2,
                          reps: int = 2,
                          floor_s: float = 0.03) -> dict:
    """A node joining mid-run must strictly reduce the measured makespan
    versus not joining (the frontier is re-planned onto it).

    The starting node is *weak*: its machine model carries a large
    compute slowdown and fault injection enforces a matching per-task
    service-time floor (``throttle``, a sleep — deliberately not
    CPU-bound, so the signal survives CPU-starved/shared CI runners
    where two busy processes do not actually run in parallel).  When a
    fast node joins, ``replan_frontier`` prices the weak node's slowdown
    and migrates the not-yet-dispatched frontier onto the joiner, which
    must strictly beat the no-join wall clock.  Both legs run the same
    throttle; the two legs are interleaved so host drift hits both.
    """
    from repro.exec.elastic import ChaosEvent
    spec = hetero_spec((1,), slowdown=(8.0,), link_bw=2e9, latency=2e-4)
    tm = analytic_time_model()
    eng = CMMEngine(spec, tm, plan_cache=False)
    expr = build_gemm(n)
    plan = eng.plan(expr, tile=tile)
    ref = make_executor("local").execute(plan)

    throttle = ChaosEvent(after_done=0, throttle_node=0,
                          throttle_seconds=floor_s)
    chaos_nojoin = (throttle,)
    chaos_join = (throttle,
                  ChaosEvent(after_done=5, join_workers=join_workers))
    wall_nojoin, wall_join = float("inf"), float("inf")
    out_nojoin = out_join = st = None
    for _ in range(reps):
        w, out_nojoin, _st = _elastic_wall(plan, tm, chaos_nojoin, reps=1,
                                           blas_threads=1)
        wall_nojoin = min(wall_nojoin, w)
        w, out_join, st = _elastic_wall(plan, tm, chaos_join, reps=1,
                                        blas_threads=1)
        wall_join = min(wall_join, w)

    return {
        "case": "elastic_join", "n": n, "tile": tile,
        "join_workers": join_workers,
        "tasks": len(plan.program.graph),
        "wall_nojoin_s": wall_nojoin,
        "wall_join_s": wall_join,
        "join_speedup": wall_nojoin / max(wall_join, 1e-12),
        "joined_node_tasks": sum(
            1 for node in st["exec_nodes"].values() if node == 1),
        "replans": st["replans"],
        "ok_bitident": bool(np.array_equal(ref, out_join)
                            and np.array_equal(ref, out_nojoin)),
        "ok_join_used": 1 in set(st["exec_nodes"].values()),
        "ok_join_speedup": wall_join < wall_nojoin,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small inputs, oracle-checked (the CI gates)")
    ap.add_argument("--elastic", action="store_true",
                    help="chaos leg: mid-run node kill + mid-run join "
                         "through the elastic executor")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: BENCH_cluster.json / "
                         "BENCH_elastic.json, with a _smoke suffix under "
                         "--smoke so gates never clobber published "
                         "artifacts)")
    args = ap.parse_args()
    if args.out is None:
        base = "BENCH_elastic" if args.elastic else "BENCH_cluster"
        name = f"{base}_smoke.json" if args.smoke else f"{base}.json"
        args.out = os.path.join(os.path.dirname(__file__), "..", name)

    if args.elastic:
        if args.smoke:
            cases = [run_elastic_kill_case(192, 48, (2, 2)),
                     run_elastic_join_case(512, 256)]
        else:
            cases = [run_elastic_kill_case(384, 96, (2, 2), reps=3),
                     run_elastic_join_case(768, 256, reps=3)]
        ok = True
        for c in cases:
            checks = [v for k, v in c.items() if k.startswith("ok_")]
            ok &= all(checks)
            if c["case"] == "elastic_kill":
                print(f"[elastic] kill n={c['n']} tile={c['tile']} "
                      f"tasks={c['tasks']} "
                      f"plain={c['wall_elastic_s']:.3f}s "
                      f"chaos={c['wall_elastic_chaos_s']:.3f}s "
                      f"recovered={c['recovered_tasks']} "
                      f"replans={c['replans']} "
                      f"bitident={c['ok_bitident']} "
                      f"oracle={c['ok_oracle']}")
            else:
                print(f"[elastic] join n={c['n']} tile={c['tile']} "
                      f"tasks={c['tasks']} "
                      f"nojoin={c['wall_nojoin_s']:.3f}s "
                      f"join={c['wall_join_s']:.3f}s "
                      f"speedup={c['join_speedup']:.2f}x "
                      f"joined_tasks={c['joined_node_tasks']} "
                      f"bitident={c['ok_bitident']} "
                      f"speedup_ok={c['ok_join_speedup']}")
            if not all(checks):
                print(f"[elastic] CHECK FAILED: {c['case']}",
                      file=sys.stderr)
        with open(args.out, "w") as f:
            json.dump({"cases": cases}, f, indent=2)
        print(f"[elastic] wrote {os.path.abspath(args.out)}")
        return 0 if ok else 1

    if args.smoke:
        cases = [run_case(96, 32, (2, 1))]
    else:
        cases = [run_case(256, 64, (2, 1), reps=2),
                 run_case(384, 96, (3, 2, 1), reps=2)]

    ok = True
    for c in cases:
        ok &= c["ok_bitident"] and c["ok_oracle"] and c["ok_placement"]
        print(f"[cluster] n={c['n']} tile={c['tile']} "
              f"nodes={c['node_workers']} tasks={c['tasks']} "
              f"xfers={c['xfers']}/{c['xfers_scheduled']} "
              f"nodes_used={c['nodes_used']} "
              f"local={c['wall_local_s']:.3f}s "
              f"cluster={c['wall_cluster_s']:.3f}s "
              f"(err {c['makespan_err_cluster']:+.2f}) "
              f"bitident={c['ok_bitident']} oracle={c['ok_oracle']} "
              f"placement={c['ok_placement']}")
        if not (c["ok_bitident"] and c["ok_oracle"] and c["ok_placement"]):
            print(f"[cluster] CHECK FAILED at n={c['n']} tile={c['tile']}",
                  file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump({"cases": cases}, f, indent=2)
    print(f"[cluster] wrote {os.path.abspath(args.out)}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
