"""Table 4: observed vs theoretical (zero-communication) speedup.

Configuration mirrors the paper: 8 nodes, tile = n/2 (their 5 k at 10 k).
Theoretical speedup = sim(1 node) / sim(8 nodes, comm instantaneous);
observed = sim(1 node) / sim(8 nodes).  The paper's claim: observed lands
at 55-80 % of theoretical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core import CMMEngine, c5_9xlarge, simulate
from repro.core.timemodel import TimeModel

from .cmm_suite import BENCHMARKS
from .table3_scaling import time_model


@dataclass
class Row:
    name: str
    observed: float
    theoretical: float

    @property
    def fraction(self) -> float:
        return self.observed / max(self.theoretical, 1e-12)


def run(n: int = 512, nodes: int = 8,
        tm: Optional[TimeModel] = None) -> List[Row]:
    tm = tm or time_model()
    rows = []
    for name, build in BENCHMARKS.items():
        tile = max(1, n // 2)
        eng1 = CMMEngine(c5_9xlarge(1), tm, tile=tile)
        base = eng1.plan(build(n)).predicted_makespan
        engN = CMMEngine(c5_9xlarge(nodes), tm, tile=tile)
        plan = engN.plan(build(n))
        obs = base / max(plan.predicted_makespan, 1e-12)
        zc = simulate(plan.program.graph, plan.schedule, engN.spec, tm,
                      zero_comm=True)
        theo = base / max(zc.makespan, 1e-12)
        rows.append(Row(name, obs, theo))
    return rows


def render(rows: List[Row]) -> str:
    out = [f"{'bench':14s} {'observed':>9s} {'theoretical':>12s} {'frac':>6s}"]
    for r in rows:
        out.append(f"{r.name:14s} {r.observed:9.2f} {r.theoretical:12.2f} "
                   f"{r.fraction*100:5.0f}%")
    return "\n".join(out)


def main(n: int = 512):
    rows = run(n=n)
    print(render(rows))
    return rows


if __name__ == "__main__":
    main()
