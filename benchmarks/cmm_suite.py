"""The eight Cell-benchmark programs (paper §4.1), as CMM expressions.

Each builder returns the root ClusteredMatrix of a matmul-dominant
expression over n x n inputs — Julia-rewrites of the Cell Octave set
(Markov, K-Means, Hill, Leontief, DFT, Synth, Reachability, Hits),
re-expressed in this repo's ClusteredMatrix language.  (Grover is omitted —
the paper discarded it for lacking matmul content.)
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.core import ClusteredMatrix as CM


def markov(n: int, seed: int = 0) -> CM:
    """Fig. 2: u' = P^3 u (random-walk distribution after 3 steps)."""
    P = CM.rand(n, n, seed=seed, name="P")
    u = CM.rand(n, 1, seed=seed + 1, name="u")
    return (P @ P @ P) @ u


def kmeans(n: int, seed: int = 0) -> CM:
    """Distance/assignment core: E = X C^T, A = relu-threshold, C' = A^T X."""
    X = CM.rand(n, n, seed=seed, name="X")
    Ct = CM.rand(n, n, seed=seed + 1, name="Ct")
    E = X @ Ct
    A = (E - 0.5).relu()
    return A.T @ X


def hill(n: int, seed: int = 0) -> CM:
    """Hill cipher: encrypt C = K P, decrypt P' = K' C, residual P' - P."""
    K = CM.rand(n, n, seed=seed, name="K")
    Kinv = CM.rand(n, n, seed=seed + 1, name="Kinv")
    P = CM.rand(n, n, seed=seed + 2, name="P")
    C = K @ P
    P2 = Kinv @ C
    return P2 - P


def leontief(n: int, seed: int = 0) -> CM:
    """x = (I + A + A^2 + A^3) d — Neumann series for (I-A)^-1 d."""
    A = CM.rand(n, n, seed=seed, name="A") * (1.0 / n)
    d = CM.rand(n, 1, seed=seed + 1, name="d")
    A2 = A @ A
    A3 = A2 @ A
    return d + (A @ d) + (A2 @ d) + (A3 @ d)


def dft(n: int, seed: int = 0) -> CM:
    """Matrix DFT: Y = F X (+ inverse pass F' Y), F dense n x n."""
    F = CM.rand(n, n, seed=seed, name="F")
    Fi = CM.rand(n, n, seed=seed + 1, name="Fi")
    X = CM.rand(n, n, seed=seed + 2, name="X")
    Y = F @ X
    return (Fi @ Y) * (1.0 / n)


def synth(n: int, seed: int = 0) -> CM:
    """Synthetic: two independent products mixed — embarrassingly parallel
    (the paper's best-scaling benchmark)."""
    A = CM.rand(n, n, seed=seed, name="A")
    B = CM.rand(n, n, seed=seed + 1, name="B")
    C = CM.rand(n, n, seed=seed + 2, name="C")
    D = CM.rand(n, n, seed=seed + 3, name="D")
    return (A @ B) + (C @ D)


def reachability(n: int, seed: int = 0) -> CM:
    """Transitive-closure steps: R1 = sgn(A^2 + A), R2 = sgn(R1^2 + R1)."""
    A = CM.rand(n, n, seed=seed, name="A")
    R1 = ((A @ A) + A).ewise("sign")
    return ((R1 @ R1) + R1).ewise("sign")


def hits(n: int, seed: int = 0) -> CM:
    """HITS: two authority/hub iterations a = A^T(A a), h = A(A^T h)."""
    A = CM.rand(n, n, seed=seed, name="A")
    a = CM.rand(n, 1, seed=seed + 1, name="a")
    h = CM.rand(n, 1, seed=seed + 2, name="h")
    a1 = A.T @ (A @ a)
    h1 = A @ (A.T @ h)
    return (A.T @ (A @ a1)) + (A @ (A.T @ h1))


BENCHMARKS: Dict[str, Callable[..., CM]] = {
    "Markov": markov,
    "Kmeans": kmeans,
    "Hill": hill,
    "Leontief": leontief,
    "DFT": dft,
    "Synth": synth,
    "Reachability": reachability,
    "Hits": hits,
}
