"""Wave-batched execution + planning fast path benchmark -> BENCH_wave.json.

Three measurements:

* ``exec``  — per-task (``LocalExecutor``) vs wave-batched
  (``WaveExecutor``) wall-clock across tile sizes on the small-tile
  elementwise+matmul workload ``((A @ B) * 1.5 + C).relu() .hadamard(C)``
  with the matmul inner dimension equal to the tile (single-k-tile GEMMs,
  so the tiled reduction order matches the oracle's and results must be
  BIT-IDENTICAL to both the per-task executor and ``eager()``);
* ``plan_scaling`` — planning wall-clock with the fast path
  (memoized costs + gap timelines + parked-transfer simulation) on vs off,
  over growing task graphs (the >= 20k-task point is the acceptance gate);
* ``strategy`` — the calibrated time model's per-plan executor choice
  (per-task simulated makespan vs predicted wave makespan) against which
  strategy actually won.

Exit status is non-zero on any oracle mismatch — wired into CI as a
perf-path smoke gate (``--smoke``).

    PYTHONPATH=src python benchmarks/wave_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import CMMEngine, ClusteredMatrix as CM, analytic_time_model
from repro.core.machine import c5_9xlarge, local_spec
from repro.core.profiler import calibrate_batch_dispatch, calibrate_dispatch
from repro.exec.batched import WaveExecutor
from repro.exec.local import LocalExecutor


def build_smalltile(n: int, inner: int, seed: int = 0) -> CM:
    """Elementwise+matmul workload whose GEMM k-chain fits ONE tile:
    per-tile results are bit-identical to the eager oracle."""
    A = CM.rand(n, inner, seed=seed, name="A")
    B = CM.rand(inner, n, seed=seed + 1, name="B")
    C = CM.rand(n, n, seed=seed + 2, name="C")
    return ((A @ B) * 1.5 + C).relu().hadamard(C)


def build_square(n: int, seed: int = 0) -> CM:
    A = CM.rand(n, n, seed=seed)
    B = CM.rand(n, n, seed=seed + 1)
    return (A @ B).relu() * 2.0 + CM.rand(n, n, seed=seed + 2)


def _best(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_exec(n: int, tile: int, reps: int, tm) -> dict:
    expr = build_smalltile(n, tile)
    eng = CMMEngine(local_spec(1), tm, plan_cache=False)
    plan = eng.plan(expr, tile=tile)

    ex_local = LocalExecutor()
    ex_wave = WaveExecutor()
    out = {"local": None, "wave": None}

    def run_local():
        out["local"] = ex_local.execute(plan)

    def run_wave():
        out["wave"] = ex_wave.execute(plan)

    t_local = _best(run_local, reps)
    t_wave = _best(run_wave, reps)

    ref = expr.eager()
    bit_vs_per_task = bool(np.array_equal(out["local"], out["wave"]))
    bit_vs_eager = bool(np.array_equal(out["wave"], ref))
    err = float(np.abs(out["wave"] - ref).max())

    return {
        "n": n, "tile": tile,
        "tasks": len(plan.program.graph),
        "waves": ex_wave.stats["waves"],
        "batched_calls": ex_wave.stats["batched_calls"],
        "zero_copy_gathers": ex_wave.stats["zero_copy_gathers"],
        "copied_gathers": ex_wave.stats["copied_gathers"],
        "per_task_seconds": round(t_local, 6),
        "batched_seconds": round(t_wave, 6),
        "speedup": round(t_local / max(t_wave, 1e-12), 3),
        "peak_buffer_bytes_per_task": ex_local.stats["peak_buffer_bytes"],
        "peak_buffer_bytes_batched": ex_wave.stats["peak_buffer_bytes"],
        "bit_identical_vs_per_task": bit_vs_per_task,
        "bit_identical_vs_eager": bit_vs_eager,
        "max_abs_err_vs_eager": err,
        "predicted_per_task_s": round(plan.sim.makespan, 6),
        "predicted_batched_s": round(plan.batched_makespan, 6),
        "chosen_executor": plan.best_executor,
    }


def bench_plan_scaling(sizes, tm) -> list:
    rows = []
    for (n, tile) in sizes:
        expr = build_square(n)
        spec = c5_9xlarge(4)
        eng_fast = CMMEngine(spec, tm, plan_cache=False, fast_planning=True)
        eng_slow = CMMEngine(spec, tm, plan_cache=False, fast_planning=False)
        t0 = time.perf_counter()
        plan_fast = eng_fast.plan(expr, tile=tile)
        t_fast = time.perf_counter() - t0
        t0 = time.perf_counter()
        plan_slow = eng_slow.plan(expr, tile=tile)
        t_slow = time.perf_counter() - t0
        same = plan_fast.schedule.makespan == plan_slow.schedule.makespan \
            and plan_fast.sim.makespan == plan_slow.sim.makespan
        rows.append({
            "n": n, "tile": tile,
            "tasks": len(plan_fast.program.graph),
            "fast_seconds": round(t_fast, 3),
            "slow_seconds": round(t_slow, 3),
            "speedup": round(t_slow / max(t_fast, 1e-12), 2),
            "identical_schedule": bool(same),
        })
        print(f"[plan] n={n} tile={tile} tasks={rows[-1]['tasks']} "
              f"fast={t_fast:.2f}s slow={t_slow:.2f}s "
              f"({rows[-1]['speedup']}x, identical={same})")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI sanity")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: BENCH_wave.json, or "
                         "BENCH_wave_smoke.json under --smoke so the CI "
                         "gate never clobbers the published artifact)")
    args = ap.parse_args(argv)
    if args.out is None:
        args.out = "BENCH_wave_smoke.json" if args.smoke \
            else "BENCH_wave.json"

    reps = args.reps or (1 if args.smoke else 3)
    if args.smoke:
        exec_cases = [(256, 16), (256, 32)]
        plan_sizes = [(192, 16), (256, 16)]
    else:
        exec_cases = [(1024, 16), (1024, 32), (1024, 64)]
        plan_sizes = [(512, 32), (896, 32)]   # ~6k and ~27k tasks

    # calibrated dispatch terms: what the strategy selector actually weighs
    tm = analytic_time_model()
    calibrate_dispatch(tm)
    calibrate_batch_dispatch(tm)

    result = {
        "bench": "wave",
        "config": {"smoke": args.smoke, "reps": reps,
                   "cpu_count": os.cpu_count(),
                   "dispatch_overhead_s": tm.dispatch_overhead,
                   "batch_dispatch_overhead_s": tm.batch_dispatch_overhead},
        "exec": [],
        "plan_scaling": [],
    }

    ok = True
    for (n, tile) in exec_cases:
        case = bench_exec(n, tile, reps, tm)
        result["exec"].append(case)
        print(f"[exec] n={n} tile={tile} tasks={case['tasks']} "
              f"per-task={case['per_task_seconds']:.3f}s "
              f"batched={case['batched_seconds']:.3f}s "
              f"({case['speedup']}x)  "
              f"bit-identical: per-task={case['bit_identical_vs_per_task']} "
              f"eager={case['bit_identical_vs_eager']}  "
              f"chosen={case['chosen_executor']}")
        if not case["bit_identical_vs_per_task"]:
            print(f"[exec] ORACLE MISMATCH vs per-task executor at "
                  f"tile={tile}", file=sys.stderr)
            ok = False
        if not case["bit_identical_vs_eager"]:
            print(f"[exec] ORACLE MISMATCH vs eager at tile={tile}",
                  file=sys.stderr)
            ok = False

    result["plan_scaling"] = bench_plan_scaling(plan_sizes, tm)
    for row in result["plan_scaling"]:
        if not row["identical_schedule"]:
            print("[plan] fast/slow schedule divergence at "
                  f"n={row['n']}", file=sys.stderr)
            ok = False

    # headline numbers
    best_exec = max(result["exec"], key=lambda c: c["speedup"])
    big_plan = max(result["plan_scaling"], key=lambda r: r["tasks"])
    result["headline"] = {
        "best_exec_speedup": best_exec["speedup"],
        "best_exec_tile": best_exec["tile"],
        "plan_tasks": big_plan["tasks"],
        "plan_speedup": big_plan["speedup"],
    }

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}: exec {best_exec['speedup']}x @ tile "
          f"{best_exec['tile']}, plan {big_plan['speedup']}x @ "
          f"{big_plan['tasks']} tasks")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
