"""Bounded-arena spill benchmark + smoke gate -> BENCH_spill.json.

Measures what the tiered spill store (``runtime/spill.py`` + the bounded
``_NodeArena``) costs when idle and guarantees when active:

* **bit-identity leg** — the conformance program run with per-node
  ``mem_bytes`` at a third of its working set (footprint >= 3x budget)
  must spill for real (``spill_writes > 0``) and still produce the
  **exact bytes** of the unbounded oracle at the same tile size, on both
  the static cluster executor and the elastic executor.  GATED.
* **overhead leg** — the same program with a budget generous enough to
  never spill, so what is measured is pure bounded-arena bookkeeping
  (locked gets, LRU touches, byte accounting) against the unbounded
  fast path.  Paired back-to-back reps, best RATIO over reps; gated
  **< 10 %** at full size, informational in ``--smoke`` (small inputs
  cannot amortise fixed per-run costs).  Skipped, per the repo's
  wall-clock policy, while the 1-minute load average exceeds 1.25/cpu.
* **chaos leg** — ``mem_squeeze`` (shrink a node's budget mid-run) and
  ``alloc_fail`` (fail the Nth allocation) fired against the elastic
  executor: the run must complete bit-identically — the failures are
  absorbed by eviction and bounded retry, never a crash.  GATED.

Exit status is non-zero on any failed gate — wired into CI as the
``oom-smoke`` job (``--smoke``: small inputs, writes
``BENCH_spill_smoke.json`` so the committed artifact is never
clobbered, per repo convention).

    PYTHONPATH=src python benchmarks/spill_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import ClusteredMatrix as CM, CMMEngine, analytic_time_model
from repro.core.machine import hetero_spec
from repro.exec.cluster import ClusterExecutor
from repro.exec.elastic import ChaosEvent, ElasticClusterExecutor

REPS = 3          # best-of-N wall clocks (load spikes inflate, never deflate)
LOAD_BAR = 1.25   # loadavg/cpu above which wall gates are skipped

TM = analytic_time_model()
FAST_NET = dict(link_bw=1e12, latency=1e-6)


def _host_load_per_cpu() -> float:
    try:
        return os.getloadavg()[0] / max(1, os.cpu_count() or 1)
    except OSError:                     # pragma: no cover — non-POSIX
        return 0.0


def _spec(budget=None):
    return hetero_spec((3, 2, 1), mem_bytes=budget, **FAST_NET)


def _expr(n):
    A = CM.rand(n, n, seed=0)
    B = CM.rand(n, n, seed=1)
    return (A @ B) + A


def _plan(n, tile, budget=None):
    eng = CMMEngine(_spec(budget), TM, plan_cache=False)
    return eng.plan(_expr(n), tile=tile)


def _ws(n):
    return 3 * n * n * 8


def run_bit_identity(n: int, tile: int) -> dict:
    """Bounded (budget = ws/3) vs unbounded, bitwise, both executors."""
    budget = float(_ws(n) // 3)
    ref = ClusterExecutor().execute(_plan(n, tile))
    exc = ClusterExecutor()
    got_c = exc.execute(_plan(n, tile, budget))
    exe = ElasticClusterExecutor(timemodel=TM)
    got_e = exe.execute(_plan(n, tile, budget))
    return {
        "case": "spill_bit_identity", "n": n, "tile": tile,
        "budget_bytes": budget, "working_set_bytes": _ws(n),
        "cluster_spill_writes": exc.stats["spill_writes"],
        "cluster_faults": exc.stats["faults"],
        "elastic_spill_writes": exe.stats["spill_writes"],
        "elastic_faults": exe.stats["faults"],
        "ok_spilled_for_real": bool(exc.stats["spill_writes"] > 0
                                    and exe.stats["spill_writes"] > 0),
        "ok_bitident_cluster": bool(np.array_equal(ref, got_c)),
        "ok_bitident_elastic": bool(np.array_equal(ref, got_e)),
        "ok_no_leaked_spill_files": bool(
            exc.stats["leaked_spill_files"] == 0
            and exe.stats["leaked_spill_files"] == 0),
    }


def run_overhead(n: int, tile: int, gate: bool = True) -> dict:
    """Bounded-arena bookkeeping cost on a fits-in-RAM workload: the
    budget is 4x the working set, so the spill path is armed but never
    taken — the ratio isolates accounting/locking overhead.  Paired
    back-to-back reps; the rep's RATIO is what matters (wall noise on a
    shared host inflates both legs of a pair together)."""
    budget = float(4 * _ws(n))
    pairs = []
    ref = got = None
    spilled = 0
    for _ in range(REPS):
        t0 = time.perf_counter()
        ref = ClusterExecutor().execute(_plan(n, tile))
        wp = time.perf_counter() - t0
        ex = ClusterExecutor()
        t0 = time.perf_counter()
        got = ex.execute(_plan(n, tile, budget))
        wb = time.perf_counter() - t0
        spilled += ex.stats["spill_writes"]
        pairs.append((wb / wp, wp, wb))
    ratio, wall_unbounded, wall_bounded = min(pairs)
    overhead = ratio - 1.0
    load = _host_load_per_cpu()
    skipped = (not gate) or (overhead >= 0.10 and load > LOAD_BAR)
    if not gate:
        note = "overhead gate not enforced in --smoke (workload too " \
               "small to amortise fixed per-run costs); see the " \
               "committed BENCH_spill.json"
    elif skipped:
        note = (f"overhead gate SKIPPED: host load {load:.2f}/cpu > "
                f"{LOAD_BAR} (wall-clock policy)")
    else:
        note = "gated < 10%"
    return {
        "case": "bounded_arena_overhead", "n": n, "tile": tile,
        "reps": REPS,
        "budget_bytes": budget,
        "wall_unbounded_s": wall_unbounded,
        "wall_bounded_s": wall_bounded,
        "overhead_pct": 100.0 * overhead,
        "load_per_cpu": load,
        "ok_never_spilled": bool(spilled == 0),
        "ok_bitident_bounded": bool(np.array_equal(ref, got)),
        "ok_overhead_lt_10pct": True if skipped else bool(overhead < 0.10),
        "_note": note,
    }


def run_chaos(n: int, tile: int) -> dict:
    """mem_squeeze + alloc_fail against the elastic executor under a
    budget: graceful degradation (evict/retry), bit-identical result."""
    budget = float(_ws(n) // 2)
    ref = ElasticClusterExecutor(timemodel=TM).execute(_plan(n, tile))
    ex = ElasticClusterExecutor(
        timemodel=TM,
        chaos=(ChaosEvent(after_done=3, alloc_fail=0, alloc_fail_nth=2),
               ChaosEvent(after_done=5, mem_squeeze=1,
                          squeeze_bytes=int(_ws(n) // 6))))
    got = ex.execute(_plan(n, tile, budget))
    return {
        "case": "chaos_graceful_degradation", "n": n, "tile": tile,
        "budget_bytes": budget,
        "squeezes": ex.stats["squeezes"],
        "evictions": ex.stats["evictions"],
        "task_retries": ex.stats["task_retries"],
        "xfer_retries": ex.stats["xfer_retries"],
        "tiles_lost": ex.stats["tiles_lost"],
        "ok_squeeze_fired": bool(ex.stats["squeezes"] == 1),
        "ok_bitident_chaos": bool(np.array_equal(ref, got)),
        "ok_no_leaked_spill_files": bool(
            ex.stats["leaked_spill_files"] == 0),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small inputs (the CI oom-smoke gate)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        name = "BENCH_spill_smoke.json" if args.smoke \
            else "BENCH_spill.json"
        args.out = os.path.join(os.path.dirname(__file__), "..", name)

    if args.smoke:
        cases = [run_bit_identity(96, 16),
                 run_overhead(96, 16, gate=False),
                 run_chaos(96, 16)]
    else:
        # full size: big enough that per-tile compute dwarfs the
        # bounded-arena bookkeeping the overhead leg isolates
        cases = [run_bit_identity(256, 32),
                 run_overhead(512, 64),
                 run_chaos(256, 32)]

    ok = True
    for c in cases:
        checks = {k: v for k, v in c.items() if k.startswith("ok_")}
        ok &= all(checks.values())
        line = " ".join(f"{k}={v}" for k, v in checks.items())
        if c["case"] == "spill_bit_identity":
            print(f"[spill] bit-identity n={c['n']} "
                  f"budget={c['budget_bytes']:.0f}B "
                  f"(cluster {c['cluster_spill_writes']} writes/"
                  f"{c['cluster_faults']} faults, elastic "
                  f"{c['elastic_spill_writes']}/{c['elastic_faults']}) "
                  f"{line}")
        elif c["case"] == "bounded_arena_overhead":
            print(f"[spill] overhead n={c['n']} wall "
                  f"{c['wall_unbounded_s']:.3f}s->"
                  f"{c['wall_bounded_s']:.3f}s "
                  f"(+{c['overhead_pct']:.1f}%) {line}")
        else:
            print(f"[spill] chaos n={c['n']} squeezes={c['squeezes']} "
                  f"evictions={c['evictions']} "
                  f"retries={c['task_retries']}+{c['xfer_retries']} "
                  f"{line}")
        if not all(checks.values()):
            print(f"[spill] CHECK FAILED: {c['case']}", file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump({"cases": cases}, f, indent=2)
    print(f"[spill] wrote {os.path.abspath(args.out)}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
