"""Ablations of the paper's scheduler modifications (§3.3, §3.5).

Three scheduler variants over the benchmark suite at 8 nodes:
  * full      — cache-aware HEFT + lazy/clonable fills (the CMM scheduler);
  * no_cache  — node-level cache disabled (vanilla-HEFT comm costing);
  * no_lazy   — fills ranked/placed like ordinary tasks (pre-§3.3 CMM).

The paper argues both modifications are necessary; this measures how much
each contributes to the simulated makespan.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from repro.core import (CMMEngine, c5_9xlarge, simulate, tile_expression)
from repro.core.heft import heft_schedule

from .cmm_suite import BENCHMARKS
from .table3_scaling import time_model


@dataclass
class Row:
    name: str
    full: float
    no_cache: float
    no_lazy: float


def run(n: int = 1024, nodes: int = 8, tile_frac: float = 0.3,
        origin: str = "local") -> List[Row]:
    """origin='local': generated data (the lazy-fill/§3.3 regime);
    origin='master': user-supplied data resident on the master (the
    node-level-cache/§3.5 regime — tiles get re-used across nodes)."""
    tm = time_model()
    spec = c5_9xlarge(nodes)
    tile = max(1, int(n * tile_frac))
    rows = []
    for name, build in BENCHMARKS.items():
        mks = {}
        for variant, kw, sim_kw in [
                ("full", {}, {}),
                ("no_cache", {"cache_aware": False}, {"use_cache": False}),
                ("no_lazy", {"lazy_fill": False}, {})]:
            prog = tile_expression(build(n), tile)
            sched = heft_schedule(
                prog.graph, spec, tm,
                fill_origin={k: origin for k in prog.leaf_nodes}, **kw)
            mks[variant] = simulate(prog.graph, sched, spec, tm,
                                    **sim_kw).makespan
        rows.append(Row(name, mks["full"], mks["no_cache"], mks["no_lazy"]))
    return rows


def render(rows: List[Row]) -> str:
    out = [f"{'bench':14s} {'full(s)':>9s} {'no_cache':>9s} {'no_lazy':>9s} "
           f"{'cache x':>8s} {'lazy x':>7s}"]
    for r in rows:
        out.append(f"{r.name:14s} {r.full:9.3f} {r.no_cache:9.3f} "
                   f"{r.no_lazy:9.3f} {r.no_cache/max(r.full,1e-12):7.2f}x "
                   f"{r.no_lazy/max(r.full,1e-12):6.2f}x")
    return "\n".join(out)


def main(n: int = 1024):
    out = {}
    for origin in ("local", "master"):
        rows = run(n=n, origin=origin)
        print(f"--- data origin: {origin} ---")
        print(render(rows))
        print()
        out[origin] = rows
    return out


if __name__ == "__main__":
    main()
