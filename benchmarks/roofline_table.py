"""Render roofline tables.

Two modes:

* default — the §Roofline table from ``results/dryrun*/...`` JSON cells
  (``launch/dryrun.py`` must have been run).  One row per
  (arch x shape x mesh) cell.  Degrades to a hint when no results exist.
* ``--tasks`` — the analytic per-task roofline audit from
  ``core/roofline.py``: one row per distinct (task kind, tile, dtype)
  signature of a paper-suite matmul+elementwise plan, comparing the
  calibrated TimeModel's kernel time against the analytic bound.  Needs
  no prior results — it is pure planning.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

_ROOT = os.path.join(os.path.dirname(__file__), "..", "results")
#: prefer the final (optimized-plan) sweep when present
RESULTS = (os.path.join(_ROOT, "dryrun_final")
           if os.path.isdir(os.path.join(_ROOT, "dryrun_final"))
           else os.path.join(_ROOT, "dryrun"))


def load_cells(mesh: str = "single_pod_16x16") -> List[dict]:
    out = []
    base = os.path.join(RESULTS, mesh)
    if not os.path.isdir(base):
        return out
    for arch in sorted(os.listdir(base)):
        ad = os.path.join(base, arch)
        if not os.path.isdir(ad):
            continue
        for f in sorted(os.listdir(ad)):
            if f.endswith(".json"):
                try:
                    with open(os.path.join(ad, f)) as fh:
                        out.append(json.load(fh))
                except (OSError, json.JSONDecodeError) as e:
                    print(f"(skipping unreadable cell {arch}/{f}: {e})")
    return out


def render(cells: List[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'bound':>10s} {'roofl%':>7s} {'useful%':>8s} "
           f"{'peakGiB':>8s}")
    rows = [hdr, "-" * len(hdr)]
    for c in cells:
        t = c["roofline"]
        rows.append(
            f"{c['arch']:24s} {c['shape']:12s} {t['compute_s']:9.4f} "
            f"{t['memory_s']:9.4f} {t['collective_s']:9.4f} "
            f"{t['bound']:>10s} {t['roofline_fraction']*100:6.1f}% "
            f"{min(c['useful_flops_ratio'],9.99)*100:7.1f}% "
            f"{c['memory']['peak_bytes']/2**30:8.2f}")
    return "\n".join(rows)


# -- analytic per-task audit (core/roofline.py) -------------------------------

def task_audit_rows(n: int = 256, tile: int = 32,
                    dtypes=("float64", "float32")) -> List[dict]:
    """Audit rows for the paper-suite matmul+elementwise workload, per
    dtype (itemsize feeds the byte counts)."""
    import numpy as np
    from repro.core import (ClusteredMatrix as CM, CMMEngine,
                            analytic_time_model)
    tm = analytic_time_model()
    rows: List[dict] = []
    for dt in dtypes:
        npdt = np.dtype(dt)
        A = CM.rand(n, n, seed=1, dtype=npdt)
        B = CM.rand(n, n, seed=2, dtype=npdt)
        C = CM.rand(n, n, seed=3, dtype=npdt)
        eng = CMMEngine(timemodel=tm, tile=(tile, tile))
        plan = eng.plan(((A @ B) + C).relu())
        for r in eng.roofline_audit(plan, itemsize=npdt.itemsize):
            d = r.as_dict()
            d["dtype"] = dt
            rows.append(d)
    return rows


def render_task_audit(rows: List[dict]) -> str:
    hdr = (f"{'kind':10s} {'dims':16s} {'dtype':8s} {'count':>5s} "
           f"{'FLOP/B':>7s} {'model(s)':>10s} {'roofl(s)':>10s} "
           f"{'ratio':>7s} {'bound':>8s}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        out.append(
            f"{r['kind']:10s} {str(tuple(r['dims'])):16s} {r['dtype']:8s} "
            f"{r['count']:5d} {r['intensity']:7.2f} {r['model_s']:10.3e} "
            f"{r['roofline_s']:10.3e} {min(r['ratio'], 999.99):7.2f} "
            f"{r['bound']:>8s}")
    return "\n".join(out)


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", default="single_pod_16x16")
    ap.add_argument("--tasks", action="store_true",
                    help="render the analytic per-task roofline audit "
                         "(no dry-run results needed)")
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--tile", type=int, default=32)
    args = ap.parse_args(argv)

    if args.tasks:
        rows = task_audit_rows(n=args.n, tile=args.tile)
        print(render_task_audit(rows))
        return rows

    cells = load_cells(args.mesh)
    if not cells:
        print(f"(no dry-run results for {args.mesh} under {RESULTS}; run "
              f"`python -m repro.launch.dryrun --all`, or use "
              f"`--tasks` for the analytic per-task audit)")
        return []
    print(render(cells))
    return cells


if __name__ == "__main__":
    main()
