"""Render the §Roofline table from results/dryrun/*.json (launch/dryrun.py
must have been run).  One row per (arch x shape x mesh) cell."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

_ROOT = os.path.join(os.path.dirname(__file__), "..", "results")
#: prefer the final (optimized-plan) sweep when present
RESULTS = (os.path.join(_ROOT, "dryrun_final")
           if os.path.isdir(os.path.join(_ROOT, "dryrun_final"))
           else os.path.join(_ROOT, "dryrun"))


def load_cells(mesh: str = "single_pod_16x16") -> List[dict]:
    out = []
    base = os.path.join(RESULTS, mesh)
    if not os.path.isdir(base):
        return out
    for arch in sorted(os.listdir(base)):
        ad = os.path.join(base, arch)
        for f in sorted(os.listdir(ad)):
            if f.endswith(".json"):
                with open(os.path.join(ad, f)) as fh:
                    out.append(json.load(fh))
    return out


def render(cells: List[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'bound':>10s} {'roofl%':>7s} {'useful%':>8s} "
           f"{'peakGiB':>8s}")
    rows = [hdr, "-" * len(hdr)]
    for c in cells:
        t = c["roofline"]
        rows.append(
            f"{c['arch']:24s} {c['shape']:12s} {t['compute_s']:9.4f} "
            f"{t['memory_s']:9.4f} {t['collective_s']:9.4f} "
            f"{t['bound']:>10s} {t['roofline_fraction']*100:6.1f}% "
            f"{min(c['useful_flops_ratio'],9.99)*100:7.1f}% "
            f"{c['memory']['peak_bytes']/2**30:8.2f}")
    return "\n".join(rows)


def main(mesh: str = "single_pod_16x16"):
    cells = load_cells(mesh)
    if not cells:
        print(f"(no dry-run results for {mesh}; run "
              f"`python -m repro.launch.dryrun --all`)")
        return []
    print(render(cells))
    return cells


if __name__ == "__main__":
    main()
