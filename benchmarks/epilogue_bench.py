"""Fused-epilogue benchmark + smoke gate -> BENCH_epilogue.json.

Measures what matmul-epilogue fusion (``fusion.fuse_matmul_epilogues``)
buys and guarantees on the paper chain ``relu((A @ B) + C)``:

* **fusion leg** — the same expression planned with and without
  epilogue fusion.  GATED (always): the fused plan executes *strictly
  fewer* tasks, and the fused output is bit-identical to the unfused
  executor on every numpy backend (local / batched / cluster) for both
  f64 and f32 — the strict-precision tier of TESTING.md.  GATED (full
  runs): best-of-reps wall-clock speedup > 1.0x on the wave-batched
  executor at tile 16, where fusion eliminates a whole stacked-FUSED
  dispatch per wave.  Smoke runs record the ratio informationally —
  sub-second runs on shared CI hosts cannot resolve small deltas.
  Per-wave planned roofline fractions ride along informationally.
* **mixed leg** — opt-in mixed precision
  (``WaveExecutor(precision="mixed")``: f32 accumulate, bf16 store).
  GATED: output dtype is bfloat16 and values match the f64 eager oracle
  within the documented bf16 tolerance (rtol=atol=2e-2).
* **roofline leg** — a chaos-throttled node on the elastic executor
  must show up in the analytic roofline report
  (``core/roofline.py``): the throttled node is the ONLY below-band
  outlier (planned heterogeneity cancels in per-node peaks), and the
  run stays bit-identical to the local oracle.

Exit status is non-zero on any failed gate — wired into CI as the
``kernel-smoke`` job (``--smoke``: small inputs, writes
``BENCH_epilogue_smoke.json`` so the committed artifact is never
clobbered, per repo convention).

    PYTHONPATH=src python benchmarks/epilogue_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import ClusteredMatrix as CM, CMMEngine, analytic_time_model
from repro.core.machine import c5_9xlarge, hetero_spec
from repro.core.roofline import roofline_report
from repro.exec.batched import WaveExecutor
from repro.exec.cluster import ClusterExecutor
from repro.exec.elastic import ChaosEvent, ElasticClusterExecutor
from repro.exec.local import LocalExecutor
from repro.runtime.membership import MembershipConfig

TM = analytic_time_model()
FAST_NET = dict(link_bw=1e12, latency=1e-6)

SPEEDUP_GATE = 1.0                   # fused must not be slower (full runs)
BF16_TOL = 2e-2                      # documented bf16 tier (TESTING.md)


def _chain(n, dtype=np.float64):
    A = CM.rand(n, n, seed=0, dtype=dtype)
    B = CM.rand(n, n, seed=1, dtype=dtype)
    C = CM.rand(n, n, seed=2, dtype=dtype)
    return ((A @ B) + C).relu()


def _plan(expr, tile, fuse_epilogue, spec=None):
    eng = CMMEngine(spec or c5_9xlarge(2), TM, plan_cache=False,
                    fuse_epilogue=fuse_epilogue)
    return eng.plan(expr, tile=tile)


_BACKENDS = {
    "local": lambda: LocalExecutor(),
    "batched": lambda: WaveExecutor(backend="numpy"),
    "cluster": lambda: ClusterExecutor(),
}


def run_fusion(n: int, tile: int, reps: int, gate_speedup: bool) -> dict:
    """Task-count + bit-identity + wall-clock legs on relu((A@B)+C)."""
    res = {"case": "epilogue_fusion", "n": n, "tile": tile, "reps": reps}

    plan_f = _plan(_chain(n), tile, fuse_epilogue=True)
    plan_u = _plan(_chain(n), tile, fuse_epilogue=False)
    res["tasks_fused"] = len(plan_f.program.graph)
    res["tasks_unfused"] = len(plan_u.program.graph)
    res["ok_strictly_fewer_tasks"] = bool(
        res["tasks_fused"] < res["tasks_unfused"])

    # strict-precision tier: fused == unfused bitwise on numpy backends
    for dtype in (np.float64, np.float32):
        pf = _plan(_chain(n, dtype), tile, True)
        pu = _plan(_chain(n, dtype), tile, False)
        for name, mk in _BACKENDS.items():
            out_f = mk().execute(pf)
            out_u = mk().execute(pu)
            key = f"ok_bitident_{name}_{np.dtype(dtype).name}"
            res[key] = bool(np.array_equal(out_f, out_u)
                            and out_f.dtype == out_u.dtype == dtype)

    # wall-clock: paired unfused/fused wave-batched runs, back-to-back so
    # machine drift hits both legs alike; best-of-reps is the speedup
    t_f, t_u = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        WaveExecutor(backend="numpy").execute(plan_u)
        t_u.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        WaveExecutor(backend="numpy").execute(plan_f)
        t_f.append(time.perf_counter() - t0)
    res["fused_best_s"] = min(t_f)
    res["unfused_best_s"] = min(t_u)
    res["fused_all_s"] = t_f
    res["unfused_all_s"] = t_u
    res["speedup_x"] = min(t_u) / max(min(t_f), 1e-9)
    res["speedup_gated"] = bool(gate_speedup)
    if gate_speedup:
        res["ok_fused_not_slower"] = bool(res["speedup_x"] > SPEEDUP_GATE)

    # planned roofline fraction per wave (informational)
    waves = plan_f.roofline_waves(TM)
    fracs = [w["fraction"] for w in waves if w["fraction"] is not None]
    res["waves"] = len(waves)
    res["wave_fraction_median"] = (
        float(np.median(fracs)) if fracs else None)
    return res


def run_mixed(n: int, tile: int) -> dict:
    """Opt-in mixed precision: f32 accumulate, bf16 store, 2e-2 tier."""
    expr = _chain(n)
    plan = _plan(expr, tile, fuse_epilogue=True)
    out = WaveExecutor(backend="numpy", precision="mixed").execute(plan)
    ref = expr.eager()
    err = np.abs(np.asarray(out, dtype=np.float64) - ref)
    scale = np.maximum(np.abs(ref), 1.0)
    return {
        "case": "mixed_precision", "n": n, "tile": tile,
        "out_dtype": out.dtype.name,
        "tolerance": BF16_TOL,
        "max_rel_err": float((err / scale).max()),
        "ok_bf16_dtype": bool(out.dtype.name == "bfloat16"),
        "ok_within_bf16_tol": bool(np.allclose(
            np.asarray(out, dtype=np.float64), ref,
            rtol=BF16_TOL, atol=BF16_TOL)),
    }


def run_roofline_chaos(n: int, tile: int, throttle_node: int = 3,
                       throttle_seconds: float = 0.4) -> dict:
    """Throttled-node chaos run: the analytic roofline report must flag
    exactly the slowed node as the below-band outlier.  The spec plans
    nodes 2,3 as 2x slower — that *planned* heterogeneity cancels in the
    per-node peaks, so only the *unplanned* chaos throttle may flag."""
    spec = hetero_spec((2, 2, 1, 1), slowdown=(1.0, 1.0, 2.0, 2.0),
                       **FAST_NET)
    plan = _plan(_chain(n), tile, fuse_epilogue=True, spec=spec)
    ref = LocalExecutor().execute(plan)
    exe = ElasticClusterExecutor(
        timemodel=TM,
        membership=MembershipConfig(heartbeat_interval_s=0.05),
        chaos=[ChaosEvent(after_done=0, throttle_node=throttle_node,
                          throttle_seconds=throttle_seconds)])
    out = exe.execute(plan)
    rep = roofline_report(exe.spans, plan, tm=TM, band=2.0)
    return {
        "case": "roofline_chaos", "n": n, "tile": tile,
        "throttle_node": throttle_node,
        "throttle_seconds": throttle_seconds,
        "below_band": list(rep.below_band),
        "fleet_fraction": rep.fleet_fraction,
        "node_fractions": {str(nr.node): nr.fraction for nr in rep.nodes},
        "node_samples": {str(nr.node): nr.samples for nr in rep.nodes},
        "summary": rep.summary(),
        "ok_throttled_node_flagged": bool(
            throttle_node in rep.below_band),
        "ok_only_throttled_flagged": bool(
            rep.below_band == [throttle_node]),
        "ok_bitident_chaos": bool(np.array_equal(ref, out)),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small inputs (the CI kernel-smoke gate)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        name = ("BENCH_epilogue_smoke.json" if args.smoke
                else "BENCH_epilogue.json")
        args.out = os.path.join(os.path.dirname(__file__), "..", name)

    if args.smoke:
        cases = [run_fusion(96, 16, reps=2, gate_speedup=False),
                 run_mixed(64, 16),
                 run_roofline_chaos(96, 32)]
    else:
        cases = [run_fusion(256, 16, reps=3, gate_speedup=True),
                 run_mixed(128, 16),
                 run_roofline_chaos(128, 32)]

    ok = True
    for c in cases:
        checks = {k: v for k, v in c.items() if k.startswith("ok_")}
        ok &= all(checks.values())
        line = " ".join(f"{k}={v}" for k, v in checks.items())
        if c["case"] == "epilogue_fusion":
            print(f"[epi] fusion n={c['n']} tile={c['tile']} "
                  f"tasks {c['tasks_unfused']}->{c['tasks_fused']} "
                  f"fused={c['fused_best_s']:.3f}s "
                  f"unfused={c['unfused_best_s']:.3f}s "
                  f"({c['speedup_x']:.3f}x, "
                  f"{'gated' if c['speedup_gated'] else 'informational'}) "
                  f"{line}")
        elif c["case"] == "mixed_precision":
            print(f"[epi] mixed n={c['n']} dtype={c['out_dtype']} "
                  f"max_rel_err={c['max_rel_err']:.2e} {line}")
        else:
            print(f"[epi] roofline n={c['n']} "
                  f"below_band={c['below_band']} "
                  f"fractions={ {k: (None if v is None else round(v, 3)) for k, v in c['node_fractions'].items()} } "
                  f"{line}")
        if not all(checks.values()):
            print(f"[epi] CHECK FAILED: {c['case']}", file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump({"cases": cases}, f, indent=2)
    print(f"[epi] wrote {os.path.abspath(args.out)}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
