"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract:
  * us_per_call — wall-clock (exec) or simulated makespan in microseconds;
  * derived     — the table's own metric (speedup, GFLOPS, accuracy, ...).

Run: ``PYTHONPATH=src python -m benchmarks.run [--size N] [--full]``
"""
from __future__ import annotations

import argparse
import sys


def bench_table2_gflops(rows_out):
    from . import table2_gflops
    for r in table2_gflops.run():
        rows_out.append((f"table2/gflops/threads={r.threads}",
                         0.0, f"{r.gflops_real:.2f}|model="
                              f"{r.gflops_model:.2f}"))


def bench_table3_scaling(rows_out, n):
    from . import table3_scaling
    tm = table3_scaling.time_model()
    for name in table3_scaling.BENCHMARKS:
        rows = table3_scaling.run_benchmark(name, n=n, tm=tm)
        print(table3_scaling.render(rows), file=sys.stderr)
        for r in rows:
            us = (r.exec_s if r.exec_s is not None else r.sim_s) * 1e6
            acc = f"|acc={r.accuracy*100:.0f}%" if r.accuracy else ""
            rows_out.append((
                f"table3/{r.name}/n={r.nodes}/tile={r.tile}", us,
                f"speedup={r.speedup:.2f}{acc}"))


def bench_table4_theoretical(rows_out, n):
    from . import table4_theoretical
    rows = table4_theoretical.run(n=n)
    print(table4_theoretical.render(rows), file=sys.stderr)
    for r in rows:
        rows_out.append((f"table4/{r.name}", 0.0,
                         f"obs={r.observed:.2f}|theo={r.theoretical:.2f}"
                         f"|frac={r.fraction*100:.0f}%"))


def bench_fig3_schedule(rows_out, n):
    from . import fig3_schedule
    fig3_schedule.main(n=n)
    rows_out.append(("fig3/markov_gantt", 0.0, "rendered"))


def bench_ablation(rows_out, n):
    from . import ablation
    out = ablation.main(n=n)
    for origin, rows in out.items():
        for r in rows:
            rows_out.append((
                f"ablation/{origin}/{r.name}", r.full * 1e6,
                f"cache_x={r.no_cache/max(r.full,1e-12):.2f}"
                f"|lazy_x={r.no_lazy/max(r.full,1e-12):.2f}"))


def bench_roofline(rows_out):
    from . import roofline_table
    cells = roofline_table.main()
    for c in cells:
        t = c["roofline"]
        rows_out.append((
            f"roofline/{c['mesh']}/{c['arch']}/{c['shape']}",
            t["step_lower_bound_s"] * 1e6,
            f"bound={t['bound']}|roofline={t['roofline_fraction']*100:.1f}%"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=384,
                    help="matrix size for the CMM benchmarks")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on one core)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: t2,t3,t4,f3,roofline")
    args = ap.parse_args()
    n = 2048 if args.full else args.size
    only = set(args.only.split(",")) if args.only else None

    rows = []
    if not only or "t3" in only:
        bench_table3_scaling(rows, n)
    if not only or "t4" in only:
        bench_table4_theoretical(rows, n)
    if not only or "t2" in only:
        bench_table2_gflops(rows)
    if not only or "f3" in only:
        bench_fig3_schedule(rows, min(n, 512))
    if not only or "ablation" in only:
        bench_ablation(rows, max(n, 512))
    if not only or "roofline" in only:
        bench_roofline(rows)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
