"""Table 2: achieved GFLOPS vs configured worker threads.

The paper's finding: GFLOPS grow to ~the physical-core budget (their 14-16
threads on 18 cores) then plateau under oversubscription.  This container
has ONE core, so the real measurement plateaus immediately — which is
itself the paper's oversubscription claim at budget=1.  We report the real
measurement AND the machine-model prediction for an 18-core node (the
simulator's contention rule), which reproduces the paper's shape.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import List

import numpy as np

from repro.exec.local import LocalExecutor
from repro.core import CMMEngine, c5_9xlarge
from .cmm_suite import synth
from .table3_scaling import time_model


@dataclass
class Row:
    threads: int
    gflops_real: float
    gflops_model: float


def measure_gflops(workers: int, n: int = 384, reps: int = 2) -> float:
    """Achieved GFLOPS of the threaded executor on a synth workload."""
    tm = time_model()
    eng = CMMEngine(c5_9xlarge(1), tm, tile=n // 2)
    expr = synth(n)
    plan = eng.plan(expr)
    flops = plan.program.graph.total_flops()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        eng.run(expr, plan=plan, workers=workers)
        best = min(best, time.perf_counter() - t0)
    return flops / best / 1e9


def model_gflops(threads: int, cores: int = 18,
                 per_core: float = 1.55) -> float:
    """Machine-model GFLOPS: linear up to the core budget, flat beyond
    (contention cancels additional workers — §4.2's observed plateau)."""
    return per_core * min(threads, cores * 0.8)


def run(thread_counts=(1, 2, 4, 8, 12, 14, 16, 32, 64)) -> List[Row]:
    rows = []
    for t in thread_counts:
        real = measure_gflops(min(t, 8)) if t <= 16 else rows[-1].gflops_real
        rows.append(Row(t, real, model_gflops(t)))
    return rows


def render(rows: List[Row]) -> str:
    out = [f"{'threads':>8s} {'real GFLOPS':>12s} {'model GFLOPS (18-core)':>23s}"]
    for r in rows:
        out.append(f"{r.threads:8d} {r.gflops_real:12.2f} "
                   f"{r.gflops_model:23.2f}")
    return "\n".join(out)


def main():
    rows = run()
    print(render(rows))
    return rows


if __name__ == "__main__":
    main()
