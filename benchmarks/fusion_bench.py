"""Fusion + zero-copy runtime benchmark -> BENCH_fusion.json.

Measures, before/after the expression-graph optimizer:

* task count (by kind) of the tiled program,
* planning seconds (and the structural plan-cache hit on a second,
  structurally identical ``compute()``),
* end-to-end execution wall-clock,
* peak live tile-buffer bytes (reference-counted runtime),
* max |err| vs the ``eager()`` NumPy oracle.

Two programs:

* ``acceptance`` — the issue's elementwise-on-matmul program
  ``(A @ B).relu() * 2.0 + C`` (GEMM-dominant; fusion trims the tail);
* ``ewchain``    — a deep elementwise chain (30 ops) + external mix-in,
  the fusion-optimizer target workload: one FUSED task per tile replaces
  the whole chain.

    PYTHONPATH=src python benchmarks/fusion_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import CMMEngine, ClusteredMatrix as CM, analytic_time_model
from repro.core.machine import local_spec
from repro.exec.local import LocalExecutor


def build_acceptance(n: int, seed: int = 0) -> CM:
    A = CM.rand(n, n, seed=seed, name="A")
    B = CM.rand(n, n, seed=seed + 1, name="B")
    C = CM.rand(n, n, seed=seed + 2, name="C")
    return (A @ B).relu() * 2.0 + C


def build_ewchain(n: int, seed: int = 0) -> CM:
    A = CM.rand(n, n, seed=seed, name="A")
    C = CM.rand(n, n, seed=seed + 1, name="C")
    e = A
    for i in range(10):                   # 30 elementwise ops
        e = (e * (1.0 + 0.01 * (i + 1)) + 0.02).relu()
    return e.hadamard(C)


BUILDERS = {"acceptance": build_acceptance, "ewchain": build_ewchain}


def _stats(plan, ex: LocalExecutor, best: float):
    return {
        "tasks": len(plan.program.graph),
        "counts": plan.program.graph.counts(),
        "plan_seconds": round(plan.plan_seconds, 6),
        "exec_seconds": round(best, 6),
        "peak_buffer_bytes": ex.stats["peak_buffer_bytes"],
        "buffers_freed": ex.stats["buffers_freed"],
        "workers": ex.stats["workers"],
        "fusion_report": plan.fusion.as_dict() if plan.fusion else None,
    }


def bench_case(name: str, n: int, tile: int, reps: int) -> dict:
    build = BUILDERS[name]
    spec = local_spec(1)
    tm = analytic_time_model()

    eng_un = CMMEngine(spec, tm, fuse=False, plan_cache=False)
    eng_fu = CMMEngine(spec, tm, fuse=True, plan_cache=True)

    plan_un = eng_un.plan(build(n, seed=0), tile=tile)
    plan_fu = eng_fu.plan(build(n, seed=0), tile=tile)
    ex_un, ex_fu = LocalExecutor(), LocalExecutor()
    best_un = best_fu = float("inf")
    out_un = out_fu = None
    for _ in range(reps):                 # interleave: fair under load noise
        t0 = time.perf_counter()
        out_un = ex_un.execute(plan_un)
        best_un = min(best_un, time.perf_counter() - t0)
        t0 = time.perf_counter()
        out_fu = ex_fu.execute(plan_fu)
        best_fu = min(best_fu, time.perf_counter() - t0)
    un = _stats(plan_un, ex_un, best_un)
    fu = _stats(plan_fu, ex_fu, best_fu)

    # second, structurally identical compute: must hit the plan cache
    t0 = time.perf_counter()
    plan2 = eng_fu.plan(build(n, seed=77), tile=tile)
    cached_plan_seconds = time.perf_counter() - t0

    ref = build(n, seed=0).eager()
    err = float(max(np.abs(out_un - ref).max(), np.abs(out_fu - ref).max()))

    case = {
        "n": n, "tile": tile,
        "unfused": un, "fused": fu,
        "task_reduction": round(un["tasks"] / fu["tasks"], 3),
        "exec_speedup": round(un["exec_seconds"] / fu["exec_seconds"], 3),
        "peak_buffer_reduction": round(
            un["peak_buffer_bytes"] / max(fu["peak_buffer_bytes"], 1), 3),
        "plan_cache": {
            "hit": plan2.cache_hit,
            "first_plan_seconds": fu["plan_seconds"],
            "cached_plan_seconds": round(cached_plan_seconds, 6),
        },
        "max_abs_err_vs_eager": err,
    }
    return case


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI sanity (n=256, tile=128)")
    ap.add_argument("-n", type=int, default=None)
    ap.add_argument("--tile", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: BENCH_fusion.json, or "
                         "BENCH_fusion_smoke.json under --smoke so the CI "
                         "gate never clobbers the published artifact)")
    args = ap.parse_args(argv)
    if args.out is None:
        args.out = "BENCH_fusion_smoke.json" if args.smoke \
            else "BENCH_fusion.json"

    n = args.n or (256 if args.smoke else 2048)
    tile = args.tile or (128 if args.smoke else 512)
    reps = args.reps or (1 if args.smoke else 3)

    result = {
        "bench": "fusion",
        "config": {"n": n, "tile": tile, "reps": reps, "smoke": args.smoke,
                   "cpu_count": os.cpu_count()},
        "cases": {},
    }
    ok = True
    for name in BUILDERS:
        case = bench_case(name, n, tile, reps)
        result["cases"][name] = case
        print(f"[{name}] tasks {case['unfused']['tasks']} -> "
              f"{case['fused']['tasks']} ({case['task_reduction']}x)  "
              f"exec {case['unfused']['exec_seconds']:.3f}s -> "
              f"{case['fused']['exec_seconds']:.3f}s "
              f"({case['exec_speedup']}x)  "
              f"peak-buf {case['peak_buffer_reduction']}x  "
              f"cache-hit={case['plan_cache']['hit']} "
              f"(plan {case['plan_cache']['first_plan_seconds']:.3f}s -> "
              f"{case['plan_cache']['cached_plan_seconds']:.4f}s)  "
              f"err={case['max_abs_err_vs_eager']:.2e}")
        if case["max_abs_err_vs_eager"] > 1e-8:
            print(f"[{name}] VALIDATION FAILED vs eager", file=sys.stderr)
            ok = False
        if not case["plan_cache"]["hit"]:
            print(f"[{name}] plan cache MISSED on identical structure",
                  file=sys.stderr)
            ok = False

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
