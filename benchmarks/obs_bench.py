"""Observability benchmark + smoke gate -> BENCH_obs.json.

Measures what the flight recorder (``runtime/telemetry.py`` +
``core/drift.py``) costs and guarantees:

* **overhead leg** — the same cluster plan run back-to-back with
  tracing on and off, repeated; the best-of-reps wall-clock ratio is
  the tracing overhead.  GATED (full runs): overhead < 5%, which is
  the policy that justifies tracing-on-by-default.  GATED (always):
  the traced run is bit-identical to the untraced run, the trace
  carries exactly one EXEC span per scheduled task, and it exports as
  valid Chrome-trace JSON.  Smoke runs record the ratio
  informationally — sub-second runs on shared CI hosts cannot resolve
  a 5% wall-clock delta.
* **drift leg** — a chaos-throttled node on the elastic executor must
  show up in the drift report: per-node residual rows for EVERY node
  of the spec, the throttled node flagged as a straggler prior, and
  the run still bit-identical to the local oracle.  The recovered
  priors are then fed back through
  ``ElasticClusterExecutor(straggler_priors=...)`` (round-trip
  recorded informationally).

Exit status is non-zero on any failed gate — wired into CI as the
``obs-smoke`` job (``--smoke``: small inputs, writes
``BENCH_obs_smoke.json`` so the committed artifact is never clobbered,
per repo convention).

    PYTHONPATH=src python benchmarks/obs_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import ClusteredMatrix as CM, CMMEngine, analytic_time_model
from repro.core.drift import drift_report
from repro.core.machine import hetero_spec
from repro.exec.cluster import ClusterExecutor
from repro.exec.elastic import ChaosEvent, ElasticClusterExecutor
from repro.exec.local import LocalExecutor
from repro.runtime.membership import MembershipConfig
from repro.runtime.telemetry import chrome_trace

TM = analytic_time_model()
FAST_NET = dict(link_bw=1e12, latency=1e-6)

OVERHEAD_GATE = 1.05                 # tracing-on-by-default policy: < 5%


def _spec(nodes=(3, 2, 1)):
    return hetero_spec(nodes, **FAST_NET)


def _expr(n):
    A = CM.rand(n, n, seed=0)
    B = CM.rand(n, n, seed=1)
    return (A @ B) + A


def _plan(expr, tile, spec):
    eng = CMMEngine(spec, TM, plan_cache=False)
    return eng.plan(expr, tile=tile)


def _valid_chrome_trace(spans) -> bool:
    doc = chrome_trace(spans)
    try:
        json.dumps(doc)              # must be JSON-serializable end to end
    except (TypeError, ValueError):
        return False
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    return bool(xs) and all(
        e.get("ph") in ("X", "M")
        and isinstance(e.get("pid"), int) and isinstance(e.get("tid"), int)
        and (e["ph"] != "X" or (e["ts"] >= 0.0 and e["dur"] >= 0.0))
        for e in doc["traceEvents"])


def run_overhead(n: int, tile: int, reps: int, gate: bool) -> dict:
    """Paired tracing-on/off cluster runs on one plan; best-of-reps
    ratio is the overhead.  Pairs run back-to-back so machine drift
    (thermal, noisy neighbours) hits both legs alike."""
    spec = _spec()
    plan = _plan(_expr(n), tile, spec)
    t_on, t_off = [], []
    out_on = out_off = None
    spans = None
    for _ in range(reps):
        t0 = time.perf_counter()
        off = ClusterExecutor(trace=False)
        out_off = off.execute(plan)
        t_off.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        on = ClusterExecutor(trace=True)
        out_on = on.execute(plan)
        t_on.append(time.perf_counter() - t0)
        spans = on.spans
    ratio = min(t_on) / max(min(t_off), 1e-9)
    exec_tids = sorted(s.args["tid"] for s in spans if s.cat == "EXEC")
    res = {
        "case": "tracing_overhead", "n": n, "tile": tile, "reps": reps,
        "traced_best_s": min(t_on),
        "untraced_best_s": min(t_off),
        "traced_all_s": t_on,
        "untraced_all_s": t_off,
        "overhead_x": ratio,
        "overhead_gate_x": OVERHEAD_GATE,
        "overhead_gated": bool(gate),
        "spans": len(spans),
        "exec_spans": len(exec_tids),
        "ok_bitident_traced": bool(np.array_equal(out_on, out_off)),
        "ok_exec_span_per_task": bool(
            exec_tids == sorted(plan.schedule.placements)),
        "ok_valid_chrome_trace": _valid_chrome_trace(spans),
    }
    if gate:
        res["ok_overhead_lt_5pct"] = bool(ratio < OVERHEAD_GATE)
    return res


def run_drift_chaos(n: int, tile: int, throttle_node: int = 3,
                    throttle_seconds: float = 0.4) -> dict:
    """Throttled-node chaos run: the drift report must flag exactly the
    slowed node as a straggler prior, with residual rows for every node
    of the spec, and the run must stay bit-identical to the local
    oracle.  The priors then seed a fresh elastic run's membership
    detector (round-trip recorded informationally)."""
    spec = _spec((2, 2, 1, 1))
    plan = _plan(_expr(n), tile, spec)
    ref = LocalExecutor().execute(plan)
    exe = ElasticClusterExecutor(
        timemodel=TM,
        membership=MembershipConfig(heartbeat_interval_s=0.05),
        chaos=[ChaosEvent(after_done=0, throttle_node=throttle_node,
                          throttle_seconds=throttle_seconds)])
    out = exe.execute(plan)
    rep = drift_report(exe.spans, plan, tm=TM)
    rows = {nd.node: nd for nd in rep.nodes}
    flagged = rep.straggler_priors

    # round-trip: feed the recovered priors into a fresh run's detector
    rt = ElasticClusterExecutor(
        timemodel=TM,
        membership=MembershipConfig(heartbeat_interval_s=0.05),
        straggler_priors=flagged,
        chaos=[ChaosEvent(after_done=0, throttle_node=throttle_node,
                          throttle_seconds=throttle_seconds)])
    out_rt = rt.execute(plan)
    return {
        "case": "drift_chaos", "n": n, "tile": tile,
        "throttle_node": throttle_node,
        "throttle_seconds": throttle_seconds,
        "straggler_priors": list(flagged),
        "fleet_ratio": rep.fleet_ratio,
        "node_residuals": {str(nd.node): nd.ratio for nd in rep.nodes},
        "node_samples": {str(nd.node): nd.samples for nd in rep.nodes},
        "roundtrip_straggles": rt.stats["straggles"],
        "roundtrip_speculated": rt.stats["speculated"],
        "ok_throttled_node_flagged": bool(throttle_node in flagged),
        "ok_only_throttled_flagged": bool(flagged == [throttle_node]),
        "ok_row_per_spec_node": bool(
            set(rows) >= set(range(spec.n_nodes))),
        "ok_bitident_chaos": bool(np.array_equal(ref, out)),
        "ok_bitident_roundtrip": bool(np.array_equal(ref, out_rt)),
        "ok_valid_chrome_trace": _valid_chrome_trace(exe.spans),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small inputs (the CI obs-smoke gate)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        name = "BENCH_obs_smoke.json" if args.smoke else "BENCH_obs.json"
        args.out = os.path.join(os.path.dirname(__file__), "..", name)

    if args.smoke:
        cases = [run_overhead(96, 16, reps=2, gate=False),
                 run_drift_chaos(96, 32)]
    else:
        cases = [run_overhead(384, 48, reps=3, gate=True),
                 run_drift_chaos(128, 32)]

    ok = True
    for c in cases:
        checks = {k: v for k, v in c.items() if k.startswith("ok_")}
        ok &= all(checks.values())
        line = " ".join(f"{k}={v}" for k, v in checks.items())
        if c["case"] == "tracing_overhead":
            print(f"[obs] overhead n={c['n']} "
                  f"traced={c['traced_best_s']:.3f}s "
                  f"untraced={c['untraced_best_s']:.3f}s "
                  f"({c['overhead_x']:.3f}x, "
                  f"{'gated' if c['overhead_gated'] else 'informational'}) "
                  f"{c['spans']} spans {line}")
        else:
            print(f"[obs] drift n={c['n']} "
                  f"priors={c['straggler_priors']} "
                  f"residuals={ {k: round(v, 2) for k, v in c['node_residuals'].items() if v is not None} } "
                  f"{line}")
        if not all(checks.values()):
            print(f"[obs] CHECK FAILED: {c['case']}", file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump({"cases": cases}, f, indent=2)
    print(f"[obs] wrote {os.path.abspath(args.out)}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
