"""Table 3 / Figure 4: execution + simulation times, tile sweep, 1-8 nodes.

Methodology vs the paper:
  * exec time — REAL wall-clock of the threaded local executor on this
    machine (1 node; the container has one core, so absolute numbers are
    small-scale, but the exec-vs-sim accuracy comparison is live);
  * sim time — discrete-event simulation under the OFFLINE-PROFILED time
    model for 1..8 nodes (the paper's own instrument for every multi-node
    number we cannot run on one host);
  * tile sizes — n/10, 3n/10, n/2 (exec+sim) and 7n/10 (sim-only), the
    paper's 1k/3k/5k/7k at 10k scaled to the benchmark size;
  * speedup — sim(nodes)/sim(1), plus exec-based where real.

Reproduced claims (checked by benchmarks/run.py and tests):
  C1 speedup grows with node count;
  C2 tile n/2 beats n/10 on makespan at 8 nodes; 7n/10 collapses;
  C3 sim within 5-30 % of exec on 1 node;
  C4 observed 55-80 % of zero-comm theoretical speedup (Table 4).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core import (CMMEngine, ClusteredMatrix, c5_9xlarge,
                        profile_machine, simulate)
from repro.core.machine import local_spec
from repro.core.timemodel import TimeModel

from .cmm_suite import BENCHMARKS

_TM_CACHE: Optional[TimeModel] = None


def time_model(profile_sizes=(64, 128, 256, 384, 512)) -> TimeModel:
    global _TM_CACHE
    if _TM_CACHE is None:
        _TM_CACHE = profile_machine(profile_sizes)
    return _TM_CACHE


@dataclass
class Row:
    name: str
    nodes: int
    tile: int
    exec_s: Optional[float]
    sim_s: float
    accuracy: Optional[float]     # exec / sim (paper's Sim. Accuracy)
    speedup: float                # vs 1 node (sim-based)


def tile_grid(n: int) -> List[int]:
    return [max(1, n // 10), max(1, 3 * n // 10), max(1, n // 2),
            max(1, 7 * n // 10)]


def run_benchmark(name: str, n: int = 512,
                  nodes=(1, 2, 4, 6, 8),
                  exec_nodes=(1,), tm: Optional[TimeModel] = None,
                  workers: int = 3) -> List[Row]:
    tm = tm or time_model()
    build = BENCHMARKS[name]
    rows: List[Row] = []
    tiles = tile_grid(n)
    sim1 = {}
    for tile in tiles:
        eng1 = CMMEngine(c5_9xlarge(1), tm, tile=tile)
        sim1[tile] = eng1.plan(build(n)).predicted_makespan
    for nn in nodes:
        eng = CMMEngine(c5_9xlarge(nn), tm)
        for ti, tile in enumerate(tiles):
            sim_only = (ti == len(tiles) - 1)    # 7n/10: sim-only (paper)
            expr = build(n)
            plan = eng.plan(expr, tile=tile)
            sim_s = plan.predicted_makespan
            exec_s = None
            acc = None
            if nn in exec_nodes and not sim_only:
                # accuracy rows compare against THIS host's machine model
                leng = CMMEngine(local_spec(nn), tm, tile=tile)
                lplan = leng.plan(build(n), tile=tile)
                t0 = time.perf_counter()
                leng.run(expr, tile=tile, plan=lplan,
                         workers=leng.spec.worker_procs)
                exec_s = time.perf_counter() - t0
                acc = exec_s / max(lplan.predicted_makespan, 1e-12)
            rows.append(Row(name, nn, tile, exec_s, sim_s, acc,
                            sim1[tile] / max(sim_s, 1e-12)))
    return rows


def render(rows: List[Row]) -> str:
    out = [f"{'bench':14s} {'nodes':>5s} {'tile':>6s} {'exec(s)':>9s} "
           f"{'sim(s)':>9s} {'acc':>6s} {'speedup':>8s}"]
    for r in rows:
        out.append(
            f"{r.name:14s} {r.nodes:5d} {r.tile:6d} "
            f"{(f'{r.exec_s:.3f}' if r.exec_s else '-'):>9s} "
            f"{r.sim_s:9.3f} "
            f"{(f'{r.accuracy*100:.0f}%' if r.accuracy else '-'):>6s} "
            f"{r.speedup:8.2f}")
    return "\n".join(out)


def main(n: int = 512, names=None):
    tm = time_model()
    all_rows = []
    for name in (names or BENCHMARKS):
        rows = run_benchmark(name, n=n, tm=tm)
        all_rows += rows
        print(render(rows))
        print()
    return all_rows


if __name__ == "__main__":
    main()
