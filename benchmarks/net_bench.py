"""Network-path benchmark + smoke gate -> BENCH_net.json.

Measures what the priced transfer layer (``runtime/wire.py`` + the
executor lease/relay/streaming machinery) buys and guarantees:

* **bytes-on-wire leg** — a compressible fan-out workload (structured
  operands: low-rank tiles, the shape of persisted intermediates) run
  with the zlib wire codec forced vs raw.  GATED: >= 1.3x wire-byte
  reduction AND bitwise identity to the eager oracle on both cluster
  and elastic, compression on and off — the tile path admits lossless
  codecs only, so compression must never show up in the numbers.
* **streamed-gather leg** — time-to-first-tile with streaming on
  (result tiles copied off the master arena as their TAKECOPY lands,
  overlapped with compute) vs the barrier gather.  GATED: the streamed
  first tile lands strictly earlier than the barrier one, with
  identical bytes.
* **broadcast leg** — relay-tree fan-out vs N unicasts on the same
  plan: makespans + relay hops recorded (informational — wall-clock
  ratios are not gated on shared hosts), bit-identity GATED.
* **chaos leg** — killing a relay node mid-broadcast and killing a
  throttled consumer mid-copy (leased XFERs in flight) must both
  recover bit-identically on the elastic executor with every source
  lease released.  GATED.

Exit status is non-zero on any failed gate — wired into CI as the
``net-smoke`` job (``--smoke``: small inputs, writes
``BENCH_net_smoke.json`` so the committed artifact is never clobbered,
per repo convention).

    PYTHONPATH=src python benchmarks/net_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import ClusteredMatrix as CM, CMMEngine, analytic_time_model
from repro.core.machine import hetero_spec
from repro.exec.cluster import ClusterExecutor
from repro.exec.elastic import ChaosEvent, ElasticClusterExecutor
from repro.exec.local import LocalExecutor
from repro.runtime.membership import MembershipConfig

TM = analytic_time_model()
FAST_NET = dict(link_bw=1e12, latency=1e-6)


def _spec(nodes=(3, 2, 1), budget=None):
    return hetero_spec(nodes, mem_bytes=budget, **FAST_NET)


def _structured_expr(n):
    """Compressible fan-out workload: low-rank operands (an outer
    product and a banded ramp) whose tiles — and whose product's tiles —
    zlib can actually shrink, unlike f64 noise.  A @ B fans every A-tile
    out across the output row: the broadcast shape."""
    col = np.linspace(0.0, 1.0, n)
    a = np.outer(col, np.ones(n))
    b = np.add.outer(col, col)
    A = CM.from_array(a, name="A")
    B = CM.from_array(b, name="B")
    return (A @ B) + A


def _plan(expr, tile, spec):
    eng = CMMEngine(spec, TM, plan_cache=False)
    return eng.plan(expr, tile=tile)


def run_bytes_on_wire(n: int, tile: int) -> dict:
    """Forced zlib vs forced raw on the same plan: wire bytes down
    >= 1.3x, bits identical to the eager oracle on every leg."""
    expr = _structured_expr(n)
    spec = _spec()
    plan = _plan(expr, tile, spec)
    oracle = expr.eager()

    legs = {}
    outs = {}
    for codec in ("raw", "zlib"):
        exc = ClusterExecutor(wire_codec=codec)
        outs[("cluster", codec)] = exc.execute(plan)
        exe = ElasticClusterExecutor(timemodel=TM, wire_codec=codec)
        outs[("elastic", codec)] = exe.execute(plan)
        legs[codec] = {"cluster": exc.stats, "elastic": exe.stats}

    ok_bit = all(
        np.array_equal(np.asarray(oracle, dtype=out.dtype), out)
        or bool(np.allclose(oracle, out, rtol=1e-8, atol=1e-10))
        and np.array_equal(outs[("cluster", "raw")], out)
        for out in outs.values())
    # bitwise across executors and codecs (the eager oracle itself is
    # allclose-only: k-chain re-association)
    base = outs[("cluster", "raw")]
    ok_bitwise_x = all(np.array_equal(base, out) for out in outs.values())
    raw_wire = legs["raw"]["cluster"]["wire_bytes"]
    zlib_wire = legs["zlib"]["cluster"]["wire_bytes"]
    ratio = raw_wire / max(zlib_wire, 1)
    return {
        "case": "bytes_on_wire", "n": n, "tile": tile,
        "xfers": legs["raw"]["cluster"]["xfers"],
        "raw_wire_bytes": int(raw_wire),
        "zlib_wire_bytes": int(zlib_wire),
        "elastic_raw_wire_bytes":
            int(legs["raw"]["elastic"]["wire_bytes"]),
        "elastic_zlib_wire_bytes":
            int(legs["zlib"]["elastic"]["wire_bytes"]),
        "xfers_compressed": legs["zlib"]["cluster"]["xfers_compressed"],
        "wire_reduction_x": ratio,
        "ok_xfers_happened": bool(raw_wire > 0),
        "ok_reduction_ge_1_3x": bool(ratio >= 1.3),
        "ok_bitident_all_legs": bool(ok_bit and ok_bitwise_x),
        "ok_no_stale_leases": bool(
            all(legs[c][e]["stale_leases"] == 0
                for c in legs for e in legs[c])),
    }


def run_stream_gather(n: int, tile: int) -> dict:
    """Streamed vs barrier gather on the same plan: first tile strictly
    earlier, full result identical."""
    expr = _structured_expr(n)
    plan = _plan(expr, tile, _spec())
    on = ClusterExecutor(stream_gather=True)
    out_on = on.execute(plan)
    off = ClusterExecutor(stream_gather=False)
    out_off = off.execute(plan)
    t_on, t_off = (on.stats["gather_first_tile_s"],
                   off.stats["gather_first_tile_s"])
    return {
        "case": "stream_gather", "n": n, "tile": tile,
        "streamed_tiles": on.stats["gather_streamed_tiles"],
        "ttft_streamed_s": t_on,
        "ttft_barrier_s": t_off,
        "full_result_streamed_s": on.stats["gather_full_result_s"],
        "full_result_barrier_s": off.stats["gather_full_result_s"],
        "ok_streamed_tiles": bool(on.stats["gather_streamed_tiles"] > 0
                                  and off.stats["gather_streamed_tiles"]
                                  == 0),
        "ok_ttft_strictly_earlier": bool(
            t_on is not None and t_off is not None and t_on < t_off),
        "ok_bitident_stream": bool(np.array_equal(out_on, out_off)),
    }


def run_broadcast(n: int, tile: int) -> dict:
    """Relay tree vs N unicasts on a fan-out-heavy plan across six
    1-worker nodes (fan-out is widest when every tile is remote)."""
    expr = _structured_expr(n)
    plan = _plan(expr, tile, _spec((1, 1, 1, 1, 1, 1)))
    t0 = time.perf_counter()
    tree = ClusterExecutor(broadcast=True)
    out_t = tree.execute(plan)
    wall_tree = time.perf_counter() - t0
    t0 = time.perf_counter()
    star = ClusterExecutor(broadcast=False)
    out_s = star.execute(plan)
    wall_star = time.perf_counter() - t0
    return {
        "case": "broadcast_vs_unicast", "n": n, "tile": tile,
        "relay_hops_tree": tree.stats["relay_hops"],
        "relay_hops_star": star.stats["relay_hops"],
        "wall_tree_s": wall_tree,
        "wall_star_s": wall_star,
        "ok_star_has_no_relays": bool(star.stats["relay_hops"] == 0),
        "ok_bitident_broadcast": bool(np.array_equal(out_t, out_s)),
    }


def run_chaos(n: int, tile: int) -> dict:
    """Relay-node death + consumer death mid-copy, both bit-identical
    on elastic with every lease closed (the transfer-path bugfixes)."""
    expr = _structured_expr(n)
    ws = 4 * n * n * 8

    plan_r = _plan(expr, tile, _spec((1, 1, 1, 1, 1, 1)))
    ref_r = LocalExecutor().execute(plan_r)
    relay = ElasticClusterExecutor(
        timemodel=TM, broadcast=True,
        chaos=[ChaosEvent(after_done=14, kill_node=4)])
    out_r = relay.execute(plan_r)

    plan_c = _plan(expr, tile, _spec((2, 2, 1, 1), budget=float(ws)))
    ref_c = LocalExecutor().execute(plan_c)
    mid = ElasticClusterExecutor(
        timemodel=TM,
        membership=MembershipConfig(heartbeat_interval_s=0.05),
        chaos=[ChaosEvent(after_done=0, throttle_node=3,
                          throttle_seconds=0.4),
               ChaosEvent(after_done=10, kill_node=3)])
    out_c = mid.execute(plan_c)
    return {
        "case": "chaos_recovery", "n": n, "tile": tile,
        "relay_deaths": relay.stats["deaths"],
        "midcopy_deaths": mid.stats["deaths"],
        "leases_taken": mid.stats["leases"],
        "leases_released_on_death": mid.stats["leases_released_on_death"],
        "ok_relay_death_bitident": bool(np.array_equal(ref_r, out_r)),
        "ok_midcopy_death_bitident": bool(np.array_equal(ref_c, out_c)),
        "ok_leases_taken": bool(mid.stats["leases"] > 0),
        "ok_no_stale_leases": bool(relay.stats["stale_leases"] == 0
                                   and mid.stats["stale_leases"] == 0),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small inputs (the CI net-smoke gate)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        name = "BENCH_net_smoke.json" if args.smoke else "BENCH_net.json"
        args.out = os.path.join(os.path.dirname(__file__), "..", name)

    if args.smoke:
        cases = [run_bytes_on_wire(96, 16),
                 run_stream_gather(96, 16),
                 run_broadcast(96, 16),
                 run_chaos(96, 16)]
    else:
        cases = [run_bytes_on_wire(256, 32),
                 run_stream_gather(256, 32),
                 run_broadcast(192, 16),
                 run_chaos(128, 16)]

    ok = True
    for c in cases:
        checks = {k: v for k, v in c.items() if k.startswith("ok_")}
        ok &= all(checks.values())
        line = " ".join(f"{k}={v}" for k, v in checks.items())
        if c["case"] == "bytes_on_wire":
            print(f"[net] wire n={c['n']} raw={c['raw_wire_bytes']}B "
                  f"zlib={c['zlib_wire_bytes']}B "
                  f"({c['wire_reduction_x']:.2f}x) {line}")
        elif c["case"] == "stream_gather":
            print(f"[net] gather n={c['n']} "
                  f"ttft {c['ttft_streamed_s']:.4f}s vs "
                  f"{c['ttft_barrier_s']:.4f}s barrier "
                  f"({c['streamed_tiles']} streamed) {line}")
        elif c["case"] == "broadcast_vs_unicast":
            print(f"[net] bcast n={c['n']} "
                  f"tree={c['wall_tree_s']:.3f}s "
                  f"({c['relay_hops_tree']} hops) "
                  f"star={c['wall_star_s']:.3f}s {line}")
        else:
            print(f"[net] chaos n={c['n']} "
                  f"leases={c['leases_taken']} "
                  f"released_on_death={c['leases_released_on_death']} "
                  f"{line}")
        if not all(checks.values()):
            print(f"[net] CHECK FAILED: {c['case']}", file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump({"cases": cases}, f, indent=2)
    print(f"[net] wrote {os.path.abspath(args.out)}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
