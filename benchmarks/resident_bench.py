"""Session residency benchmark + smoke gate -> BENCH_resident.json.

Measures what the session engine (``core/session.py``) buys on the
paper's iterative workloads, against the one-shot engine path that
re-fills every leaf and gathers the full result on every ``compute()``:

* **power-iteration leg** — ``u <- P u`` for k steps.  The one-shot
  baseline re-FILLs P (n x n counter-based RNG generation) and gathers
  ``u`` to the master on every step, feeding it forward as a fresh INPUT
  leaf; the session persists P once and chains resident handles.
* **markov leg** — the paper's Fig. 2 ``u' = P^3 u`` executed repeatedly
  (3 chained GEMVs per call), same comparison.

Per leg it reports **executed-task counts**, **bytes gathered to the
master**, and **wall-clock**, and it GATES on the session contract:

* ``ok_bitident``   — session final result is bit-identical to the
  one-shot baseline (np.array_equal, dtype included);
* ``ok_fewer_tasks`` — the session path executes strictly fewer tasks
  per step (RESIDENT binds replace FILLs; no TAKECOPYs on persisted
  steps);
* ``ok_fewer_gather`` — strictly fewer master-gather bytes per step
  (persisted steps gather nothing).

Exit status is non-zero on any failed check — wired into CI as the
``resident-smoke`` job (``--smoke``: small inputs, writes
``BENCH_resident_smoke.json`` so the committed artifact is never
clobbered, per repo convention).

    PYTHONPATH=src python benchmarks/resident_bench.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import ClusteredMatrix as CM, CMMEngine, analytic_time_model
from repro.core.machine import local_spec
from repro.core.session import CMMSession


def _fresh_engine():
    return CMMEngine(local_spec(1), analytic_time_model())


def run_power_iteration(n: int, k: int, tile: int, steps_fn=None) -> dict:
    """``u <- P u`` for k steps: per-call gather+refill vs residency."""
    if steps_fn is None:
        steps_fn = lambda P, u: P @ u                       # noqa: E731
        case = "power_iteration"
        step_cost = 1
    else:
        case = "markov_p3u"
        step_cost = 3

    # -- one-shot baseline: refill P + gather u on every step ------------
    eng_b = _fresh_engine()
    t0 = time.perf_counter()
    u_arr = CM.rand(n, 1, seed=1).eager()
    base_tasks = 0
    base_gather = 0
    for _ in range(k):
        P = CM.rand(n, n, seed=0, name="P")
        u_arr = eng_b.run(steps_fn(P, CM.from_array(u_arr)), tile=tile)
        base_tasks += eng_b.last_exec_stats["tasks_run"]
        base_gather += eng_b.last_exec_stats["gather_bytes"]
    wall_base = time.perf_counter() - t0

    # -- session: P resident once, u fed forward as a handle -------------
    eng_s = _fresh_engine()
    t0 = time.perf_counter()
    sess_tasks = 0
    sess_gather = 0
    with CMMSession(eng_s, executor="local", tile=tile) as s:
        P = s.persist(CM.rand(n, n, seed=0, name="P"))
        u = s.persist(CM.rand(n, 1, seed=1))
        sess_setup_tasks = s.stats["last_exec"]["tasks_run"]
        for _ in range(k):
            u = s.persist(steps_fn(P, u))
            sess_tasks += s.stats["last_exec"]["tasks_run"]
            sess_gather += s.stats["last_exec"]["gather_bytes"]
        u_sess = u.to_numpy()
    wall_sess = time.perf_counter() - t0

    per_step_base_tasks = base_tasks / k
    per_step_sess_tasks = sess_tasks / k
    return {
        "case": case, "n": n, "k": k, "tile": tile,
        "matmuls_per_step": step_cost,
        "baseline_tasks_total": base_tasks,
        "session_tasks_total": sess_tasks,
        "baseline_tasks_per_step": per_step_base_tasks,
        "session_tasks_per_step": per_step_sess_tasks,
        "baseline_gather_bytes": base_gather,
        "session_gather_bytes": sess_gather,
        "baseline_gather_bytes_per_step": base_gather / k,
        "session_gather_bytes_per_step": sess_gather / k,
        "wall_oneshot_s": wall_base,
        "wall_session_s": wall_sess,
        "session_speedup": wall_base / max(wall_sess, 1e-12),
        "ok_bitident": bool(np.array_equal(u_arr, u_sess)),
        "ok_fewer_tasks": per_step_sess_tasks < per_step_base_tasks,
        "ok_fewer_gather": sess_gather / k < base_gather / k,
        "_note": f"session setup (persist P + u0): {sess_setup_tasks} "
                 f"tasks, amortised over the whole session",
    }


def run_markov(n: int, k: int, tile: int) -> dict:
    """The paper's Fig. 2 chain u' = P (P (P u)), iterated k times."""
    return run_power_iteration(
        n, k, tile, steps_fn=lambda P, u: P @ (P @ (P @ u)))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small inputs (the CI resident-smoke gate)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        name = "BENCH_resident_smoke.json" if args.smoke \
            else "BENCH_resident.json"
        args.out = os.path.join(os.path.dirname(__file__), "..", name)

    if args.smoke:
        cases = [run_power_iteration(256, 4, 128),
                 run_markov(192, 3, 96)]
    else:
        cases = [run_power_iteration(1024, 10, 512),
                 run_markov(768, 6, 384)]

    ok = True
    for c in cases:
        checks = [v for kk, v in c.items() if kk.startswith("ok_")]
        ok &= all(checks)
        print(f"[resident] {c['case']} n={c['n']} k={c['k']} "
              f"tile={c['tile']} "
              f"tasks/step {c['baseline_tasks_per_step']:.0f}->"
              f"{c['session_tasks_per_step']:.0f} "
              f"gather/step {c['baseline_gather_bytes_per_step']:.0f}->"
              f"{c['session_gather_bytes_per_step']:.0f}B "
              f"wall {c['wall_oneshot_s']:.3f}s->{c['wall_session_s']:.3f}s "
              f"({c['session_speedup']:.2f}x) "
              f"bitident={c['ok_bitident']} "
              f"fewer_tasks={c['ok_fewer_tasks']} "
              f"fewer_gather={c['ok_fewer_gather']}")
        if not all(checks):
            print(f"[resident] CHECK FAILED: {c['case']}", file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump({"cases": cases}, f, indent=2)
    print(f"[resident] wrote {os.path.abspath(args.out)}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
