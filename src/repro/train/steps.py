"""Train and serve step functions — what the launcher jits and the dry-run
lowers.

``make_train_step``: loss -> grad -> optimizer, with

* grad accumulation over microbatches (``lax.scan``; bounds live
  activations — the global batch never exists in memory at once);
* remat per layer (inside the model's layer scan);
* fp32 grad accumulation, bf16 compute;
* optional int8 error-feedback gradient compression of the accumulated
  grads before the (implicit, GSPMD-inserted) data-parallel reduction;
* z-loss and MoE aux-loss folded into the objective.

``make_prefill_step`` / ``make_decode_step``: serving path per the shape
cells (prefill_32k lowers prefill; decode_32k / long_500k lower one-token
decode against a full cache).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ParallelPlan
from ..models import decode as D
from ..models import lm as M
from ..optim.adamw import OptConfig, make_optimizer
from ..optim import compress as C


@dataclass(frozen=True)
class TrainHParams:
    opt: OptConfig = OptConfig()
    z_loss: float = 1e-4
    aux_loss: float = 1e-2


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array,
                  vocab: int, z_loss: float = 0.0
                  ) -> Tuple[jax.Array, jax.Array]:
    """Masked mean xent over valid tokens, fp32; labels >= vocab are invalid
    (padded vocab tail is never a target).  Returns (loss, denom)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / denom, denom


def _loss_fn(cfg: ModelConfig, plan: ParallelPlan, res: M.Resolver,
             hp: TrainHParams, params, batch) -> Tuple[jax.Array, Dict]:
    logits, aux, prefix = M.forward(
        cfg, plan, res, params, batch["tokens"],
        frames=batch.get("frames"), patches=batch.get("patches"),
        mode="train")
    labels = batch["labels"]
    mask = batch["mask"]
    if prefix:  # vlm: patch positions (and any pad tail) are loss-masked
        pad = logits.shape[1] - prefix - labels.shape[1]
        labels = jnp.pad(labels, ((0, 0), (prefix, pad)))
        mask = jnp.pad(mask, ((0, 0), (prefix, pad)))
    loss, denom = cross_entropy(logits, labels, mask, cfg.vocab_padded(),
                                hp.z_loss)
    loss = loss + hp.aux_loss * aux / max(cfg.n_layers, 1)
    return loss, {"loss": loss, "aux": aux, "tokens": denom}


def make_train_step(cfg: ModelConfig, plan: ParallelPlan,
                    mesh=None, hp: TrainHParams = TrainHParams()):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  batch arrays have a leading microbatch dim when
    plan.microbatches > 1: tokens (MB, B/MB, S)."""
    res = M.Resolver(plan, mesh)
    opt_cfg = OptConfig(kind=plan.optimizer, **{
        k: v for k, v in vars(hp.opt).items() if k != "kind"})
    opt_init, opt_update = make_optimizer(opt_cfg)

    # grad-sharding constraints (perf knob): pin each accumulated grad to
    # its param's sharding so cross-replica reduction lowers to
    # reduce-scatter into the FSDP shard instead of all-reduce + slice.
    gspecs = None
    if plan.grad_constraint and mesh is not None:
        from jax.sharding import NamedSharding
        gspecs = {k: NamedSharding(mesh, res.spec(axes, shape))
                  for k, (shape, axes, _) in M.param_specs(cfg).items()}

    def _pin_grads(grads):
        if gspecs is None:
            return grads
        return {k: jax.lax.with_sharding_constraint(g, gspecs[k])
                for k, g in grads.items()}

    # gather-once (CMM cache insight): re-shard FSDP-stored weights to
    # their model-sharded-only layout ONCE per step, outside the microbatch
    # scan (XLA hoists the loop-invariant all-gather; the scan transpose
    # accumulates the cotangent so the reduce-scatter also fires once).
    gather_specs = None
    if plan.gather_once and mesh is not None:
        from jax.sharding import NamedSharding
        drop = set(plan.rule("embed")) | {"pod"}
        gather_specs = {}
        for k, (shape, axes, _) in M.param_specs(cfg).items():
            spec = res.spec(axes, shape)
            parts = tuple(
                (None if p in drop else
                 (tuple(q for q in p if q not in drop) or None)
                 if isinstance(p, tuple) else p)
                for p in spec)
            gather_specs[k] = NamedSharding(mesh, jax.sharding.PartitionSpec(
                *parts))

    def _gather(params):
        if gather_specs is None:
            return params
        return {k: jax.lax.with_sharding_constraint(v, gather_specs[k])
                for k, v in params.items()}

    def train_step(params, opt_state, batch):
        loss_grad = jax.value_and_grad(
            functools.partial(_loss_fn, cfg, plan, res, hp),
            has_aux=True)

        nmb = plan.microbatches
        if gather_specs is not None and nmb > 1:
            # gather-once: the FSDP gather sits INSIDE grad but OUTSIDE the
            # microbatch scan; the scan transpose accumulates the weight
            # cotangent across microbatches (bf16) and the constraint's VJP
            # reduce-scatters it ONCE per step.
            def total_loss(p, batch):
                pu = _gather(p)
                macc0 = {"loss": jnp.zeros((), jnp.float32),
                         "aux": jnp.zeros((), jnp.float32),
                         "tokens": jnp.zeros((), jnp.float32)}

                def micro(carry, mb):
                    tot, macc = carry
                    loss, metrics = _loss_fn(cfg, plan, res, hp, pu, mb)
                    macc = {k: macc[k] + metrics[k] for k in macc}
                    return (tot + loss, macc), None

                (tot, macc), _ = jax.lax.scan(
                    micro, (jnp.zeros((), jnp.float32), macc0), batch)
                return tot / nmb, {k: v / nmb for k, v in macc.items()}

            (loss, metrics), grads = jax.value_and_grad(
                total_loss, has_aux=True)(params, batch)
            grads = _pin_grads(grads)
            grads = {k: g.astype(jnp.float32) for k, g in grads.items()}
        elif nmb > 1:
            def micro(carry, mb):
                gacc, macc = carry
                (loss, metrics), grads = loss_grad(params, mb)
                grads = _pin_grads(grads)
                gacc = {k: gacc[k] + grads[k].astype(jnp.float32)
                        for k in gacc}
                macc = {k: macc[k] + metrics[k] for k in macc}
                return (gacc, macc), None

            gacc0 = {k: jnp.zeros(v.shape, jnp.float32)
                     for k, v in params.items()}
            macc0 = {"loss": jnp.zeros((), jnp.float32),
                     "aux": jnp.zeros((), jnp.float32),
                     "tokens": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(
                micro, (gacc0, macc0), batch)
            grads = {k: g / nmb for k, g in grads.items()}
            metrics = {k: v / nmb for k, v in metrics.items()}
        else:
            (loss, metrics), grads = loss_grad(params, batch)
            grads = _pin_grads(grads)
            grads = {k: g.astype(jnp.float32) for k, g in grads.items()}

        if plan.compress_grads:
            # int8 on the DP wire; error feedback folded into opt_state
            qs, new_err = C.compress_tree(
                grads, opt_state.get("compress_err"))
            grads = C.decompress_tree(qs)
        new_params, new_opt, opt_metrics = opt_update(
            params, grads, {k: v for k, v in opt_state.items()
                            if k != "compress_err"})
        if plan.compress_grads:
            new_opt["compress_err"] = new_err
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    def init_opt(params):
        st = opt_init(params)
        if plan.compress_grads:
            st["compress_err"] = C.init_errors(params)
        return st

    return train_step, init_opt


def make_prefill_step(cfg: ModelConfig, plan: ParallelPlan, mesh=None,
                      max_len: Optional[int] = None):
    res = M.Resolver(plan, mesh)

    def prefill_step(params, batch):
        ml = max_len or batch["tokens"].shape[1]
        cache, logits = D.prefill(cfg, plan, res, params, batch["tokens"],
                                  ml, frames=batch.get("frames"),
                                  patches=batch.get("patches"))
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return cache, logits, next_tok

    return prefill_step


def make_decode_step(cfg: ModelConfig, plan: ParallelPlan, mesh=None):
    res = M.Resolver(plan, mesh)

    def decode_step(params, cache, token):
        return D.decode_step(cfg, plan, res, params, cache, token)

    return decode_step
