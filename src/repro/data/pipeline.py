"""Deterministic synthetic data pipeline with sharded, replayable batches.

Design requirements at cluster scale:

* **Determinism / replay** — every batch is a pure function of
  (seed, step, shard), so a restarted (or re-meshed) job regenerates the
  exact token stream from the checkpointed step: bitwise-reproducible
  restarts, no data-loader state to checkpoint.
* **Sharding** — each data-parallel replica materialises only its shard;
  `global_batch` never exists on one host.
* **Prefetch** — a background thread keeps `prefetch` batches ready
  (overlaps host data generation with device compute).

The generator is a structured synthetic stream (zipf-ish unigram mix with
per-document structure) rather than uniform noise, so losses move during the
e2e training examples.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    microbatches: int = 1
    # stub modality frontends
    frames: int = 0          # whisper: frame-embedding count
    d_model: int = 0
    patches: int = 0         # vlm: patch-embedding count


def _batch_rng(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    # counter-based: independent stream per (seed, step, shard)
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))


def make_batch(cfg: DataConfig, step: int, shard: int = 0,
               num_shards: int = 1) -> Dict[str, np.ndarray]:
    """One shard of the global batch at `step` (pure function)."""
    assert cfg.global_batch % num_shards == 0
    b = cfg.global_batch // num_shards
    rng = _batch_rng(cfg, step, shard)
    # zipf-ish unigram over vocab with doc-level offsets -> learnable stats
    base = rng.zipf(1.5, size=(b, cfg.seq_len + 1)) % cfg.vocab
    offs = rng.integers(0, cfg.vocab, (b, 1))
    stream = ((base + offs) % cfg.vocab).astype(np.int32)
    tokens, labels = stream[:, :-1], stream[:, 1:]
    mask = np.ones_like(labels, np.float32)
    out = {"tokens": tokens, "labels": labels, "mask": mask}
    if cfg.frames:
        out["frames"] = rng.standard_normal(
            (b, cfg.frames, cfg.d_model)).astype(np.float32)
    if cfg.patches:
        out["patches"] = rng.standard_normal(
            (b, cfg.patches, cfg.d_model)).astype(np.float32)
    if cfg.microbatches > 1:
        mb = cfg.microbatches
        assert b % mb == 0
        out = {k: v.reshape(mb, b // mb, *v.shape[1:])
               for k, v in out.items()}
    return out


class Prefetcher:
    """Background-thread prefetch of future steps (lookahead pipeline)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 shard: int = 0, num_shards: int = 1, prefetch: int = 2):
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._work, daemon=True)
        self._t.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, step, self.shard, self.num_shards)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._t.join(timeout=2.0)
