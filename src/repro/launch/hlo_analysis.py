"""HLO-text analysis for the roofline: FLOPs, HBM bytes, collective bytes —
with while-loop trip counts applied.

``compiled.cost_analysis()`` does NOT multiply while bodies by their trip
count (verified empirically: a 10-step scan of matmuls reports 1x the
flops), so every term here is derived from our own parse of
``compiled.as_text()``:

* computations are parsed into (name -> ops) with a per-op result shape and
  operand names;
* while ops carry ``backend_config={"known_trip_count":{"n":"N"}}`` for
  lax.scan — we build the computation call graph and propagate multipliers
  (a collective inside the layer scan inside the microbatch scan counts
  L x MB times);
* FLOPs: dot ops contribute 2 * prod(result) * prod(contracted dims)
  (elementwise is ignored — sub-1% for these models);
* HBM bytes: the scheduled module's top-level ops are post-fusion kernels;
  each kernel reads its operands and writes its result once.  We sum
  (result + operands) bytes over kernel ops, skipping pure-metadata ops
  (tuple/gte/parameter/constant/bitcast) and collectives (counted in their
  own term);
* collective bytes (per device): all-gather / reduce-scatter /
  all-to-all / collective-permute move ~result bytes per device;
  all-reduce moves ~2x (reduce-scatter + all-gather phases).

CPU-backend promotion correction: the host-device dry-run promotes every
bf16 reduction to f32 (``to_apply=%add.clone_promoted`` — the XLA:CPU
lowering converts operands to f32 around the all-reduce), and the f32
chains it creates drag neighbouring weight all-gathers to f32 via fused
converts.  On the TPU target these collectives are native bf16, so:
  * an all-reduce applying a ``*_promoted`` computation is counted at
    HALF its f32 size;
  * a gather-family collective with an f32 result whose producer is a
    convert-fusion is likewise counted at half (bf16 storage dtype).
The corrected and uncorrected totals are both reported.

These are estimator semantics (ring-algorithm (n-1)/n factors are folded
to 1), i.e. a slight upper bound — applied uniformly across cells, so
comparisons and hillclimbing stay valid.  See EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_CALL_ATTR_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_shape(s: str) -> Tuple[int, int]:
    """'bf16[16,512]' -> (elements, bytes). Tuples: sum of parts."""
    total_elems, total_bytes = 0, 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_elems += elems
        total_bytes += elems * _DTYPE_BYTES[dt]
    return total_elems, total_bytes


@dataclass
class Op:
    name: str
    text: str
    kind: str
    result_bytes: int
    result_elems: int
    operands: List[str]
    callees: List[str]
    trip: Optional[int] = None


@dataclass
class Computation:
    name: str
    ops: Dict[str, Op] = field(default_factory=dict)


_SKIP_KINDS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "broadcast",
    "reshape", "transpose",  # layout ops usually fused/free at this level
}


def _first_shape_span(rhs: str) -> str:
    # result type is the text before the opcode, e.g. '(f32[],...) while(...'
    # or 'bf16[16,512]{1,0} dot(...'
    return rhs


def _opcode(rhs: str) -> str:
    # rhs looks like: 'bf16[2,4]{1,0} dot(%a, %b), ...' or '(s32[],..) while(..)'
    m = re.search(r"\)?\s*([a-z][a-z0-9\-]*)\(", rhs)
    return m.group(1) if m else "unknown"


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if line and not line.startswith(" ") and line.rstrip().endswith("{"):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                cur = Computation(m.group(1))
                if line.startswith("ENTRY"):
                    comps["__entry__"] = cur
                comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        kind = _opcode(rhs)
        # result shape: everything before the opcode token
        idx = rhs.find(f" {kind}(")
        shape_txt = rhs[:idx] if idx > 0 else rhs.split(kind + "(")[0]
        elems, nbytes = parse_shape(shape_txt)
        # operand names: %refs inside the first (...) after opcode
        paren = rhs[rhs.find(kind + "(") + len(kind):]
        depth = 0
        arglist = []
        for ch_i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    arglist = re.findall(r"%([\w\.\-]+)", paren[:ch_i])
                    break
        callees = _CALL_ATTR_RE.findall(rhs)
        trip = None
        tm = _TRIP_RE.search(rhs)
        if tm:
            trip = int(tm.group(1))
        cur.ops[name] = Op(name, rhs, kind, nbytes, elems,
                           arglist, callees, trip)
    return comps


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, int]:
    """computation name -> total execution multiplier (trip products)."""
    entry = comps.get("__entry__")
    mult = {c: 0 for c in comps if c != "__entry__"}
    if entry is None:
        return {c: 1 for c in mult}
    mult[entry.name] = 1

    # propagate: repeat until fixpoint (call graphs are shallow)
    for _ in range(50):
        changed = False
        for cname, comp in comps.items():
            if cname == "__entry__" or mult.get(cname, 0) == 0:
                continue
            base = mult[cname]
            for op in comp.ops.values():
                t = op.trip if (op.kind == "while" and op.trip) else 1
                for callee in op.callees:
                    want = base * t
                    if callee in mult and mult[callee] < want:
                        mult[callee] = want
                        changed = True
        if not changed:
            break
    return mult


_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(op: Op, shapes: Dict[str, Tuple[int, int]]) -> int:
    """2 * prod(result dims) * prod(contracted dims of lhs)."""
    m = _DOT_CONTRACT_RE.search(op.text)
    if not m or not op.operands:
        return 2 * op.result_elems  # fallback
    lhs = op.operands[0]
    dims = shapes.get(lhs + "__dims__")
    if dims is None:
        return 2 * op.result_elems
    cdims = [int(x) for x in m.group(1).split(",") if x]
    csize = 1
    for c in cdims:
        if c < len(dims):
            csize *= dims[c]
    return 2 * op.result_elems * csize


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_operand_bytes(op: Op, comps: Dict[str, "Computation"],
                          dims_table: Dict[str, Tuple]) -> int:
    body = comps.get(op.callees[0])
    full = {i: dims_table.get(o, (0, 0))[1]
            for i, o in enumerate(op.operands)}
    if body is None:
        return sum(full.values())
    # map body parameter names -> operand index
    pidx = {}
    for bop in body.ops.values():
        if bop.kind == "parameter":
            m2 = _PARAM_IDX_RE.search(bop.text)
            if m2:
                pidx[bop.name] = int(m2.group(1))
    sliced_bytes: Dict[int, int] = {}
    direct_use: Dict[int, bool] = {}
    for bop in body.ops.values():
        if bop.kind == "parameter":
            continue
        for oi, oname in enumerate(bop.operands):
            if oname in pidx:
                i = pidx[oname]
                if bop.kind in ("dynamic-slice", "gather", "slice") and \
                        oi == 0:
                    sliced_bytes[i] = sliced_bytes.get(i, 0) + \
                        bop.result_bytes
                else:
                    direct_use[i] = True
    total = 0
    for i, fb in full.items():
        if i in sliced_bytes and not direct_use.get(i):
            total += min(sliced_bytes[i], fb)
        else:
            total += fb
    return total


@dataclass
class HloStats:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    per_collective: Dict[str, float]
    n_collectives: Dict[str, int]
    #: bytes before the CPU-promotion correction (see module docstring)
    collective_bytes_raw: float = 0.0

    def as_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_bytes_raw": self.collective_bytes_raw,
            "per_collective": self.per_collective,
            "n_collectives": self.n_collectives,
        }


def _promotion_factor(op: Op, comp: "Computation") -> float:
    """0.5 when this collective's f32 width is a CPU-lowering artifact."""
    if "f32[" not in op.text:
        return 1.0
    if op.kind == "all-reduce" and "_promoted" in op.text:
        return 0.5
    if op.kind in ("all-gather", "reduce-scatter", "all-to-all",
                   "collective-permute") and op.operands:
        prod = comp.ops.get(op.operands[0])
        if prod is not None and "convert" in prod.name:
            return 0.5
    return 1.0


def analyze(text: str) -> HloStats:
    comps = parse_module(text)
    mult = _multipliers(comps)

    # computations that are fusion bodies: their internal elementwise ops do
    # NOT touch HBM (the fusion kernel's own operands/result do, counted at
    # the call site); dots inside them still count for flops.
    fusion_bodies = set()
    for comp in comps.values():
        for op in comp.ops.values():
            if op.kind == "fusion":
                fusion_bodies.update(op.callees)

    flops = 0.0
    hbm = 0.0
    coll = 0.0
    coll_raw = 0.0
    per: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    cnt: Dict[str, int] = {k: 0 for k in COLLECTIVES}

    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 1) or 1
        in_fusion = cname in fusion_bodies
        # per-comp dims table
        dims_table: Dict[str, Tuple] = {}
        for op in comp.ops.values():
            sm = _SHAPE_RE.search(op.text)
            if sm and sm.group(2):
                dims_table[op.name + "__dims__"] = tuple(
                    int(x) for x in sm.group(2).split(","))
            dims_table[op.name] = (op.result_elems, op.result_bytes)
        for op in comp.ops.values():
            if op.kind in ("dot", "convolution"):
                flops += m * _dot_flops(op, dims_table)
            if op.kind in COLLECTIVES:
                factor = 2.0 if op.kind == "all-reduce" else 1.0
                raw = m * factor * op.result_bytes
                b = raw * _promotion_factor(op, comp)
                coll_raw += raw
                coll += b
                per[op.kind] += b
                cnt[op.kind] += m
                continue
            if in_fusion or op.kind in _SKIP_KINDS or \
                    op.kind in ("while", "conditional", "call"):
                continue
            # kernel-level HBM traffic: result + operands — EXCEPT sliced
            # access patterns, which only touch the slice, not the full
            # operand (the layer scan dynamic-slices its stacked params:
            # counting the whole (L, ...) array per iteration would inflate
            # the term by ~L x).
            if op.kind in ("dynamic-slice", "slice", "gather"):
                hbm += m * 2 * op.result_bytes          # read slice + write
                continue
            if op.kind == "dynamic-update-slice":
                upd = (dims_table.get(op.operands[1], (0, 0))[1]
                       if len(op.operands) > 1 else op.result_bytes)
                hbm += m * 2 * upd                      # rmw of the slice
                continue
            if op.kind == "scatter":
                upd = (dims_table.get(op.operands[2], (0, 0))[1]
                       if len(op.operands) > 2 else op.result_bytes)
                hbm += m * 3 * upd                      # read+add+write
                continue
            rb = op.result_bytes
            if op.kind == "fusion" and op.callees:
                # an operand consumed only through dynamic-slice/gather
                # inside the body is read slice-wise, not in full
                ob = _fusion_operand_bytes(op, comps, dims_table)
            else:
                ob = sum(dims_table.get(o, (0, 0))[1] for o in op.operands)
            hbm += m * (rb + ob)
    return HloStats(flops, hbm, coll, per, cnt, collective_bytes_raw=coll_raw)
