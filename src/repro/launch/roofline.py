"""Roofline terms from the dry-run artifacts (TPU v5e targets).

    compute_s    = HLO_FLOPs_per_chip / peak_FLOPs
    memory_s     = HBM_bytes_per_chip / HBM_bw
    collective_s = collective_bytes_per_chip / link_bw

The HLO stats are per-device (the compiled module is the SPMD-partitioned
per-device program) with while-loop trip counts applied by
launch/hlo_analysis.py.  The dominant term is the bottleneck the §Perf loop
iterates on; roofline fraction = compute_s / max(all terms) (how close the
cell is to being compute-bound at peak).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from .hlo_analysis import HloStats


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float     # bf16 FLOP/s per chip
    hbm_bw: float         # bytes/s per chip
    link_bw: float        # bytes/s per ICI link
    hbm_bytes: float      # capacity per chip


V5E = Hardware("tpu_v5e", 197e12, 819e9, 50e9, 16 * 2**30)


def roofline_terms(hlo: HloStats, n_chips: int,
                   hw: Hardware = V5E) -> Dict[str, float]:
    compute_s = hlo.flops / hw.peak_flops
    memory_s = hlo.hbm_bytes / hw.hbm_bw
    collective_s = hlo.collective_bytes / hw.link_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bound = max(terms, key=terms.get)
    total = max(max(terms.values()), 1e-30)
    return {
        **terms,
        "bound": bound.replace("_s", ""),
        "roofline_fraction": compute_s / total,
        "step_lower_bound_s": total,
    }
