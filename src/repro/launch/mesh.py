"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips).

    Axes: `data` = batch/FSDP, `model` = tensor/expert parallel; `pod`
    (multi-pod) is additional data parallelism across the DCN/ICI-linked
    pods.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2):
    """Tiny mesh over host devices for tests (requires
    xla_force_host_platform_device_count >= data*model in the test env)."""
    return jax.make_mesh((data, model), ("data", "model"))
