import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Per-op roofline diagnostics for one cell: top collectives and top HBM
# kernels, with trip-count multipliers.  The §Perf hypothesis loop's
# "profile" (no real hardware: the lowered IR is the profile).
#
#   python -m repro.launch.diagnose --arch qwen2.5-32b --shape prefill_32k

import argparse

import jax

from . import hlo_analysis as H
from .dryrun import build_cell
from .mesh import make_production_mesh


def dump(arch: str, shape: str, multi_pod: bool = False, top: int = 20,
         plan_override=None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg, plan, cell, jitted, args = build_cell(arch, shape, mesh,
                                               plan_override)
    with mesh:
        compiled = jitted.lower(*args).compile()
    txt = compiled.as_text()
    comps = H.parse_module(txt)
    mult = H._multipliers(comps)
    fusion_bodies = set()
    for comp in comps.values():
        for op in comp.ops.values():
            if op.kind == "fusion":
                fusion_bodies.update(op.callees)

    colls, hbms = [], []
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 1) or 1
        dims_table = {}
        for op in comp.ops.values():
            sm = H._SHAPE_RE.search(op.text)
            if sm and sm.group(2):
                dims_table[op.name + "__dims__"] = tuple(
                    int(x) for x in sm.group(2).split(","))
            dims_table[op.name] = (op.result_elems, op.result_bytes)
        in_fusion = cname in fusion_bodies
        for op in comp.ops.values():
            if op.kind in H.COLLECTIVES:
                f = 2.0 if op.kind == "all-reduce" else 1.0
                colls.append((m * f * op.result_bytes, m, op.kind, cname,
                              op.text[:150]))
            elif not in_fusion and op.kind not in H._SKIP_KINDS and \
                    op.kind not in ("while", "conditional", "call"):
                if op.kind in ("dynamic-slice", "slice", "gather"):
                    b = 2 * op.result_bytes
                elif op.kind == "dynamic-update-slice":
                    b = 2 * (dims_table.get(op.operands[1], (0, 0))[1]
                             if len(op.operands) > 1 else op.result_bytes)
                elif op.kind == "fusion":
                    b = op.result_bytes + H._fusion_operand_bytes(
                        op, comps, dims_table)
                else:
                    b = op.result_bytes + sum(
                        dims_table.get(o, (0, 0))[1] for o in op.operands)
                hbms.append((m * b, m, op.kind, cname, op.text[:120]))

    colls.sort(reverse=True)
    hbms.sort(reverse=True)
    print(f"\n==== {arch} {shape} "
          f"{'multi' if multi_pod else 'single'}-pod ====")
    st = H.analyze(txt)
    print(f"flops={st.flops:.3e} hbm={st.hbm_bytes:.3e} "
          f"coll={st.collective_bytes:.3e}")
    print(f"\n-- top {top} collectives (bytes x mult) --")
    for b, m, kind, cname, t in colls[:top]:
        print(f"{b/2**30:9.2f}GiB x{m:5d} {kind:19s} {t[:100]}")
    print(f"\n-- top {top} HBM kernels --")
    for b, m, kind, cname, t in hbms[:top]:
        print(f"{b/2**30:9.2f}GiB x{m:5d} {kind:19s} {t[:100]}")
    return compiled


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    ap.parse_args_ns = ap.parse_args()
    a = ap.parse_args_ns
    dump(a.arch, a.shape, a.multi, a.top)
