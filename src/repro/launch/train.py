"""Production training launcher: mesh + sharded step + checkpoint/restart
+ fleet monitoring, in one driver.

    # real pod (or host-mesh rehearsal with 8 placeholder devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \\
        --reduced --mesh 2x4 --steps 20

On a TPU fleet this is the per-controller entry point: the mesh comes from
`make_production_mesh()`, params/opt/batch are placed with the plan's
NamedShardings, the step is jitted with donation, and every
`--ckpt-every` steps an atomic async checkpoint is written.  On restart
(`--resume`) the newest intact checkpoint is restored — onto a *smaller*
mesh if pods were lost (runtime/elastic.py rebalances microbatches so the
global batch, and therefore the counter-based data stream, is unchanged).
"""
from __future__ import annotations

import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager, CheckpointPolicy
from ..checkpoint.store import config_hash
from ..configs.base import ARCH_IDS, SHAPES, get_config, get_plan, get_reduced
from ..data.pipeline import DataConfig, Prefetcher
from ..models import lm as M
from ..optim.adamw import OptConfig
from ..runtime.elastic import remesh_plan
from ..runtime.fault import FaultConfig, FleetMonitor, decide
from ..train.steps import TrainHParams, make_train_step
from . import specs as S
from .mesh import make_production_mesh


def build_mesh(spec: str):
    if spec == "production":
        return make_production_mesh()
    if spec == "multipod":
        return make_production_mesh(multi_pod=True)
    parts = [int(x) for x in spec.split("x")]
    names = ("data", "model")[:len(parts)]
    return jax.make_mesh(tuple(parts), names)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU/CI rehearsal)")
    ap.add_argument("--mesh", default="1x1",
                    help="'production' | 'multipod' | e.g. '2x4'")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/cmm_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    plan = get_plan(args.arch, "train_4k")
    mesh = build_mesh(args.mesh)
    dp = S.dp_size(plan, mesh)
    while args.global_batch % (dp * plan.microbatches) or \
            plan.microbatches > args.global_batch // dp:
        plan = replace(plan, microbatches=max(1, plan.microbatches - 1))
    print(f"mesh {dict(mesh.shape)}  dp={dp}  mb={plan.microbatches}")

    hp = TrainHParams(opt=OptConfig(lr=args.lr, warmup=10,
                                    decay_steps=args.steps))
    step_fn, init_opt = make_train_step(cfg, plan, mesh, hp=hp)
    p_sh = S.params_shardings(cfg, plan, mesh)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with mesh:
        params = {k: jax.device_put(v, p_sh[k]) for k, v in params.items()}
        opt = init_opt(params)

    mgr = CheckpointManager(args.ckpt_dir,
                            CheckpointPolicy(every_steps=args.ckpt_every,
                                             keep=2))
    meta = {"config_hash": config_hash(cfg)}
    start = 0
    if args.resume:
        got = mgr.maybe_restore(cfg, param_shardings=p_sh)
        if got:
            start, params, opt = got
            opt = jax.tree.map(jnp.asarray, opt)
            print(f"resumed from step {start}")

    monitor = FleetMonitor(mesh.shape.get("pod", 1))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.global_batch, seed=0,
                      microbatches=plan.microbatches)
    pf = Prefetcher(dcfg, start_step=start)
    try:
        t0 = time.perf_counter()
        for i in range(start, args.steps):
            s, batch = next(pf)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            ts = time.perf_counter()
            with mesh:
                params, opt, m = jitted(params, opt, batch)
            monitor.heartbeat(0, time.perf_counter() - ts)
            d = decide(monitor)
            if d.action not in ("continue",):
                print(f"[fleet] {d.action}: {d.reason}")
            mgr.step_hook(i + 1, params, opt, meta)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(m['loss']):7.4f} "
                      f"lr {float(m['lr']):.2e} "
                      f"{(i+1-start)*args.global_batch*args.seq/(time.perf_counter()-t0):8.0f} tok/s")
    finally:
        pf.close()
        mgr.store.wait()
    print("done")


if __name__ == "__main__":
    main()
