import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST precede every other import (including
# `from __future__`-free jax imports) — jax locks the device count at first
# init.  That is why this module has no `from __future__ import annotations`.
DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) —
the XLA_FLAGS assignment above executes before any jax import so the 512
placeholder host devices exist when jax initialises.

For each cell this produces, into ``results/dryrun/<mesh>/<arch>/<shape>.json``:
  * compiled memory_analysis (arg/output/temp/peak bytes per device),
  * compiled cost_analysis (XLA's own numbers, trip-count-naive),
  * our HLO-text analysis (flops / HBM bytes / collective bytes, with
    while-loop trip counts applied — see hlo_analysis.py),
  * the roofline terms (launch/roofline.py) and MODEL_FLOPS ratio.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --all --resume   # skip cells already done
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ARCH_IDS, SHAPES, cells
from ..train.steps import make_train_step, make_prefill_step, make_decode_step
from . import specs as S
from .mesh import make_production_mesh
from .hlo_analysis import analyze
from .roofline import roofline_terms, V5E

RESULTS_DIR = os.environ.get(
    "DRYRUN_RESULTS",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "results", "dryrun"))


def build_cell(arch: str, shape: str, mesh, plan_override=None):
    """Returns (jitted_fn, example_args_abstract) for the cell."""
    cfg, plan, cell = S.resolve_cell(arch, shape, mesh)
    if plan_override is not None:
        plan = plan_override(cfg, plan, cell)
    if cell.kind == "train":
        step, _ = make_train_step(cfg, plan, mesh)
        params = S.params_struct(cfg)
        p_sh = S.params_shardings(cfg, plan, mesh)
        opt = S.opt_struct(plan, params)
        o_sh = S.opt_shardings(cfg, plan, mesh)
        batch = S.batch_struct(cfg, cell, plan, train=True)
        b_sh = S.batch_shardings(cfg, cell, plan, mesh, train=True)
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1))
        args = (params, opt, batch)
    elif cell.kind == "prefill":
        max_len = cell.seq_len + (cfg.vision_patches or 0)
        step = make_prefill_step(cfg, plan, mesh, max_len=max_len)
        params = S.params_struct(cfg)
        p_sh = S.params_shardings(cfg, plan, mesh)
        batch = S.batch_struct(cfg, cell, plan, train=False)
        b_sh = S.batch_shardings(cfg, cell, plan, mesh, train=False)
        # pin the produced cache's sharding (seq-sharded KV etc.) — without
        # this the inferred output layout replicates the cache over `model`
        cache_abs = D_cache = S.decode_cache_struct(cfg, plan, cell)
        c_sh = S.cache_shardings(cfg, plan, mesh, cache_abs)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=(c_sh, None, None))
        args = (params, batch)
    else:  # decode
        step = make_decode_step(cfg, plan, mesh)
        params = S.params_struct(cfg)
        p_sh = S.params_shardings(cfg, plan, mesh)
        cache = S.decode_cache_struct(cfg, plan, cell)
        c_sh = S.cache_shardings(cfg, plan, mesh, cache)
        token = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
        t_sh = jax.NamedSharding(
            mesh, S.M.Resolver(plan, mesh).spec(
                ("batch", None), (cell.global_batch, 1)))
        jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                         donate_argnums=(1,))
        args = (params, cache, token)
    return cfg, plan, cell, jitted, args


def run_cell(arch: str, shape: str, mesh, mesh_name: str,
             save: bool = True, plan_override=None) -> dict:
    t0 = time.perf_counter()
    cfg, plan, cell, jitted, args = build_cell(arch, shape, mesh,
                                               plan_override)
    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = analyze(compiled.as_text())
    n_chips = mesh.devices.size
    terms = roofline_terms(hlo, n_chips, V5E)
    mf = S.model_flops(cfg, cell)
    out = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "chips": int(n_chips),
        "plan": {"name": plan.name, "microbatches": plan.microbatches,
                 "optimizer": plan.optimizer, "remat": plan.remat,
                 "kv_shard": plan.kv_shard,
                 "grad_reduce": plan.grad_reduce,
                 "compress_grads": plan.compress_grads},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.peak_memory_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "xla_cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "transcendentals")},
        "hlo": hlo.as_dict(),
        "roofline": terms,
        "model_flops": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / max(hlo.flops, 1.0),
        "timing": {"lower_s": t_lower, "compile_s": t_compile},
    }
    if save:
        d = os.path.join(RESULTS_DIR, mesh_name, arch)
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{shape}.json"), "w") as f:
            json.dump(out, f, indent=1)
    return out


def cell_done(arch, shape, mesh_name) -> bool:
    return os.path.exists(os.path.join(RESULTS_DIR, mesh_name, arch,
                                       f"{shape}.json"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    todo = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in cells(arch):
                todo.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    failures = []
    for mesh_name, mesh in meshes:
        for arch, shape in todo:
            if args.resume and cell_done(arch, shape, mesh_name):
                print(f"[skip] {mesh_name} {arch} {shape}")
                continue
            try:
                r = run_cell(arch, shape, mesh, mesh_name)
                t = r["roofline"]
                print(f"[ok] {mesh_name} {arch:24s} {shape:12s} "
                      f"compile={r['timing']['compile_s']:.1f}s "
                      f"peak={r['memory']['peak_bytes']/2**30:.2f}GiB "
                      f"comp={t['compute_s']:.4f}s mem={t['memory_s']:.4f}s "
                      f"coll={t['collective_s']:.4f}s "
                      f"bound={t['bound']}", flush=True)
            except Exception as e:
                failures.append((mesh_name, arch, shape, repr(e)))
                print(f"[FAIL] {mesh_name} {arch} {shape}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        raise SystemExit(1)
    print("\nall cells compiled")


if __name__ == "__main__":
    main()
