import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# §Perf hillclimb driver: re-lower a cell under a named plan variant and
# compare its roofline terms against the stored baseline.
#
#   python -m repro.launch.hillclimb --cell qwen2.5-32b/prefill_32k \
#       --variant ctx_parallel
#   python -m repro.launch.hillclimb --all        # run the whole ladder

import argparse
import json
from dataclasses import replace

from .dryrun import RESULTS_DIR, run_cell
from .mesh import make_production_mesh

PERF_DIR = os.path.join(os.path.dirname(RESULTS_DIR), "perf")


# ---- plan variants (the hypothesis ladder; see EXPERIMENTS.md §Perf) ------

def v_ctx_parallel(cfg, plan, cell):
    """Context parallelism: shard q-sequence + activations' seq on model.
    For heads%16!=0 archs this removes the replicated-attention partition
    GSPMD falls into (the 40 GiB f32 score all-reduces)."""
    return plan.with_rules(seq_attn=("model",), seq_act=("model",))


def v_seq_act(cfg, plan, cell):
    """Megatron-SP style: activations' sequence sharded between sublayers
    (norms/residuals compute on seq shards; boundary collectives become
    reduce-scatter/all-gather pairs)."""
    return plan.with_rules(seq_act=("model",))


def v_grad_rs(cfg, plan, cell):
    """Pin accumulated grads to param sharding inside the micro loop ->
    per-microbatch reduce-scatter instead of all-reduce."""
    return replace(plan, grad_constraint=True)


def v_moe_constraints(cfg, plan, cell):
    """Pin MoE dispatch/expert buffers to the experts axis (all-to-all
    dispatch instead of GSPMD's scatter guess)."""
    return replace(plan, moe_constraints=True)


def v_compress(cfg, plan, cell):
    """int8 error-feedback grad compression (hypothesis: reduces DP wire
    bytes — measured to check whether the quantise/dequantise pair actually
    straddles the GSPMD-inserted reduction)."""
    return replace(plan, compress_grads=True)


def chain(*fns):
    def f(cfg, plan, cell):
        for fn in fns:
            plan = fn(cfg, plan, cell)
        return plan
    f.__doc__ = " + ".join(fn.__name__ for fn in fns)
    return f


def v_chunk2k(cfg, plan, cell):
    """Double the flash KV chunk: halves (m,l,acc) carry rmw traffic."""
    return replace(plan, attn_chunk=2048)


def v_chunk4k(cfg, plan, cell):
    return replace(plan, attn_chunk=4096)


def v_gather_once(cfg, plan, cell):
    """all-gather FSDP weights once per step, reuse across microbatches
    (CMM cache insight); one reduce-scatter of the accumulated cotangent."""
    return replace(plan, gather_once=True)


def v_moe_ep(cfg, plan, cell):
    """shard_map expert parallelism: local dispatch + one psum combine
    (replaces GSPMD's fp32 flat-tensor all-reduces)."""
    return replace(plan, moe_impl="expert_parallel")


VARIANTS = {
    "ctx_parallel": v_ctx_parallel,
    "moe_ep": v_moe_ep,
    "gather_once": v_gather_once,
    "ctx_gather": chain(v_ctx_parallel, v_gather_once),
    "ctx_chunk2k": chain(v_ctx_parallel, v_chunk2k),
    "ctx_chunk4k": chain(v_ctx_parallel, v_chunk4k),
    "seq_act": v_seq_act,
    "grad_rs": v_grad_rs,
    "moe_constraints": v_moe_constraints,
    "compress": v_compress,
    "moe_all": chain(v_moe_constraints, v_grad_rs, v_seq_act),
    "dense_all": chain(v_seq_act, v_grad_rs),
    "ctx_all": chain(v_ctx_parallel, v_grad_rs),
}

#: the three hillclimb cells (worst roofline fraction / most collective-
#: bound / most technique-representative) and their variant ladders
LADDER = [
    ("qwen2.5-32b", "prefill_32k", ["ctx_parallel"]),
    ("qwen3-moe-235b-a22b", "train_4k",
     ["moe_constraints", "grad_rs", "seq_act", "moe_ep"]),
    ("nemotron-4-340b", "train_4k",
     ["seq_act", "grad_rs", "dense_all", "compress"]),
]


def baseline(arch, shape, mesh_name="single_pod_16x16"):
    p = os.path.join(RESULTS_DIR, mesh_name, arch, f"{shape}.json")
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return None


def run_variant(arch, shape, variant, mesh=None, save=True):
    mesh = mesh or make_production_mesh()
    fn = VARIANTS[variant]
    out = run_cell(arch, shape, mesh, "single_pod_16x16", save=False,
                   plan_override=fn)
    out["variant"] = variant
    if save:
        d = os.path.join(PERF_DIR, arch)
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{shape}__{variant}.json"), "w") as f:
            json.dump(out, f, indent=1)
    base = baseline(arch, shape)
    print(f"\n=== {arch} {shape} :: {variant} ===")
    if base:
        for term in ("compute_s", "memory_s", "collective_s"):
            b = base["roofline"][term]
            v = out["roofline"][term]
            d = (v - b) / max(b, 1e-12) * 100
            print(f"  {term:14s} {b:10.3f} -> {v:10.3f}  ({d:+.1f}%)")
        print(f"  bound          {base['roofline']['bound']:>10s} -> "
              f"{out['roofline']['bound']:>10s}")
        print(f"  step bound     {base['roofline']['step_lower_bound_s']:10.3f} -> "
              f"{out['roofline']['step_lower_bound_s']:10.3f}")
        print(f"  peak GiB       {base['memory']['peak_bytes']/2**30:10.2f} -> "
              f"{out['memory']['peak_bytes']/2**30:10.2f}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch/shape")
    ap.add_argument("--variant", choices=list(VARIANTS))
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    mesh = make_production_mesh()
    if args.all:
        for arch, shape, variants in LADDER:
            for v in variants:
                try:
                    run_variant(arch, shape, v, mesh)
                except Exception as e:
                    print(f"[FAIL] {arch}/{shape}/{v}: {e}")
    else:
        arch, shape = args.cell.split("/")
        run_variant(arch, shape, args.variant, mesh)


if __name__ == "__main__":
    main()
