"""Abstract input specs + shardings for every (arch x shape x mesh) cell.

Everything here is ``jax.ShapeDtypeStruct`` — the dry-run lowers and
compiles without allocating a byte (the pattern the assignment calls the
shannon/kernels pattern).  The same builders feed the real launchers
(launch/train.py, launch/serve.py), which substitute concrete arrays.
"""
from __future__ import annotations

import math
from dataclasses import replace
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import (ModelConfig, ParallelPlan, ShapeCell, SHAPES,
                            get_config, get_plan)
from ..models import lm as M
from ..models import decode as D

SDS = jax.ShapeDtypeStruct


def dp_size(plan: ParallelPlan, mesh) -> int:
    n = 1
    for a in plan.rule("batch"):
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


def effective_microbatches(plan: ParallelPlan, cell: ShapeCell,
                           mesh) -> int:
    """Largest mb <= plan.microbatches with (B/mb) % dp == 0."""
    dp = dp_size(plan, mesh)
    b = cell.global_batch
    mb = min(plan.microbatches, max(b // dp, 1))
    while mb > 1 and ((b % mb) or ((b // mb) % dp)):
        mb -= 1
    return max(mb, 1)


def resolve_cell(arch: str, shape: str, mesh) -> Tuple[ModelConfig,
                                                       ParallelPlan,
                                                       ShapeCell]:
    cfg = get_config(arch)
    plan = get_plan(arch, shape)
    cell = SHAPES[shape]
    plan = replace(plan, microbatches=effective_microbatches(
        plan, cell, mesh) if cell.kind == "train" else 1)
    return cfg, plan, cell


# -- batches ----------------------------------------------------------------


def batch_struct(cfg: ModelConfig, cell: ShapeCell, plan: ParallelPlan,
                 train: bool) -> Dict[str, SDS]:
    b, s = cell.global_batch, cell.seq_len
    dt = jnp.dtype(cfg.dtype)
    lead: Tuple[int, ...]
    if train and plan.microbatches > 1:
        lead = (plan.microbatches, b // plan.microbatches)
    else:
        lead = (b,)
    out = {"tokens": SDS(lead + (s,), jnp.int32)}
    if train:
        out["labels"] = SDS(lead + (s,), jnp.int32)
        out["mask"] = SDS(lead + (s,), jnp.float32)
    if cfg.enc_dec:
        out["frames"] = SDS(lead + (cfg.enc_frames, cfg.d_model), dt)
    if cfg.vision_patches:
        out["patches"] = SDS(lead + (cfg.vision_patches, cfg.d_model), dt)
    return out


def batch_shardings(cfg, cell, plan, mesh, train: bool,
                    res: Optional[M.Resolver] = None):
    res = res or M.Resolver(plan, mesh)
    bs = batch_struct(cfg, cell, plan, train)
    out = {}
    for k, v in bs.items():
        nlead = 2 if (train and plan.microbatches > 1) else 1
        axes = ((None,) * (nlead - 1) + ("batch",)
                + (None,) * (len(v.shape) - nlead))
        out[k] = NamedSharding(mesh, res.spec(axes, v.shape))
    return out


# -- optimizer state ----------------------------------------------------------


def opt_struct(plan: ParallelPlan, params_abs: Dict[str, SDS]
               ) -> Dict[str, Any]:
    if plan.optimizer == "adafactor":
        f = {}
        for k, v in params_abs.items():
            if len(v.shape) >= 2:
                f[k] = (SDS(v.shape[:-1], jnp.float32),
                        SDS(v.shape[:-2] + v.shape[-1:], jnp.float32))
            else:
                f[k] = (SDS(v.shape, jnp.float32), SDS((), jnp.float32))
        st: Dict[str, Any] = {"step": SDS((), jnp.int32), "f": f}
    else:
        st = {"step": SDS((), jnp.int32),
              "m": {k: SDS(v.shape, jnp.float32)
                    for k, v in params_abs.items()},
              "v": {k: SDS(v.shape, jnp.float32)
                    for k, v in params_abs.items()}}
    if plan.compress_grads:
        st["compress_err"] = {k: SDS(v.shape, jnp.float32)
                              for k, v in params_abs.items()}
    return st


def opt_shardings(cfg: ModelConfig, plan: ParallelPlan, mesh,
                  res: Optional[M.Resolver] = None) -> Dict[str, Any]:
    res = res or M.Resolver(plan, mesh)
    specs = M.param_specs(cfg)
    rep = NamedSharding(mesh, P())

    def like_param(k):
        shape, axes, _ = specs[k]
        return NamedSharding(mesh, res.spec(axes, shape))

    if plan.optimizer == "adafactor":
        f = {}
        for k, (shape, axes, _) in specs.items():
            if len(shape) >= 2:
                f[k] = (NamedSharding(mesh, res.spec(axes[:-1], shape[:-1])),
                        NamedSharding(mesh, res.spec(
                            axes[:-2] + axes[-1:], shape[:-2] + shape[-1:])))
            else:
                f[k] = (like_param(k), rep)
        st: Dict[str, Any] = {"step": rep, "f": f}
    else:
        st = {"step": rep,
              "m": {k: like_param(k) for k in specs},
              "v": {k: like_param(k) for k in specs}}
    if plan.compress_grads:
        st["compress_err"] = {k: like_param(k) for k in specs}
    return st


# -- caches -------------------------------------------------------------------


def decode_cache_struct(cfg, plan, cell: ShapeCell) -> Dict[str, SDS]:
    max_len = cell.seq_len + (cfg.vision_patches or 0)
    return D.cache_spec(cfg, plan, cell.global_batch, max_len,
                        jnp.dtype(cfg.dtype))


def cache_shardings(cfg, plan, mesh, cache_abs,
                    res: Optional[M.Resolver] = None):
    res = res or M.Resolver(plan, mesh)
    axes = D.cache_axes(cfg, plan)
    return {k: NamedSharding(mesh, res.spec(axes[k], v.shape))
            for k, v in cache_abs.items()}


# -- param shardings -----------------------------------------------------------


def params_struct(cfg: ModelConfig) -> Dict[str, SDS]:
    return M.abstract_params(cfg)


def params_shardings(cfg: ModelConfig, plan: ParallelPlan, mesh,
                     res: Optional[M.Resolver] = None):
    res = res or M.Resolver(plan, mesh)
    return M.param_shardings(cfg, res)


# -- model-level FLOPs (6ND) ---------------------------------------------------


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """MODEL_FLOPS: 6*N*D for train, 2*N*D forward-only (per step/token)."""
    counts = cfg.param_counts()
    n = counts["active"]
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * cell.global_batch
