"""Production serving launcher: sharded prefill + decode loop.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \\
        --mesh 2x4 --batch 4 --max-new 16

The full-config path on a pod uses the same functions the dry-run lowers
for decode_32k / long_500k (per-family caches, seq-sharded KV).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ARCH_IDS, get_config, get_plan, get_reduced
from ..models import lm as M
from ..train.steps import make_decode_step, make_prefill_step
from . import specs as S
from .train import build_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    plan = get_plan(args.arch, "decode_32k")
    mesh = build_mesh(args.mesh)
    p_sh = S.params_shardings(cfg, plan, mesh)

    max_len = args.prompt_len + args.max_new + (cfg.vision_patches or 0)
    prefill = jax.jit(make_prefill_step(cfg, plan, mesh, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg, plan, mesh))

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.enc_frames, cfg.d_model)), jnp.float32)
    if cfg.vision_patches:
        batch["patches"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.vision_patches, cfg.d_model)), jnp.float32)

    with mesh:
        params = {k: jax.device_put(v, p_sh[k]) for k, v in params.items()}
        t0 = time.perf_counter()
        cache, logits, tok = prefill(params, batch)
        print(f"prefill {args.batch}x{args.prompt_len}: "
              f"{(time.perf_counter()-t0)*1e3:.0f} ms")
        t0 = time.perf_counter()
        out = [np.asarray(tok)]
        for _ in range(args.max_new - 1):
            cache, logits, tok = decode(params, cache, tok)
            out.append(np.asarray(tok))
        dt = time.perf_counter() - t0
    gen = np.concatenate(out, axis=1)
    print(f"decode {args.max_new-1} steps: {dt*1e3:.0f} ms "
          f"({(args.max_new-1)*args.batch/max(dt,1e-9):.0f} tok/s)")
    print("sample:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
