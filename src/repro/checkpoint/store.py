"""Checkpoint store: sharded pytree save/restore with manifests.

Layout per checkpoint:
    <dir>/step_<N>/
        manifest.json     — step, config hash, mesh shape, param paths/shapes
        <escaped_name>.npy — one file per leaf (per-host shard on a real
                             multi-host job; full arrays in this container)

Properties needed at scale, all implemented here:
  * atomic publish — written to ``step_<N>.tmp`` then renamed, so a crash
    mid-save never corrupts the latest checkpoint;
  * fsync on manifest;
  * async save (background thread) — the training loop donates a snapshot
    (device_get) and keeps stepping;
  * **elastic restore** — arrays are stored UNSHARDED per leaf; restoring
    onto a different mesh just re-shards via the target NamedShardings
    (``restore(..., shardings=...)``), so a job can resume on fewer pods.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import numpy as np


def _device_get(arr):
    """Host-side ndarray of a (possibly device-resident) array.  jax is
    imported lazily: the tile-durability store (runtime/durability.py)
    shares this module's publication helpers and must not pay the jax
    import on the pure-NumPy session path."""
    if type(arr).__module__.startswith("numpy"):
        return np.asarray(arr)
    import jax
    return np.asarray(jax.device_get(arr))


def fsync_json(path: str, obj) -> None:
    """Write JSON with flush + fsync — the manifest durability barrier:
    once this returns, the manifest survives a crash (the rename that
    publishes it is atomic on POSIX)."""
    with open(path, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())


def atomic_publish(tmp: str, final: str) -> None:
    """Atomically publish a staged checkpoint directory.  A crash before
    the rename leaves only the ``.tmp`` directory, which readers ignore —
    the previous published checkpoint stays the newest intact one.
    Shared by this store and ``runtime/durability.py``'s tile store."""
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)


def _escape(name: str) -> str:
    return name.replace("/", "__")


def _unescape(name: str) -> str:
    return name.replace("__", "/")


def config_hash(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


class CheckpointStore:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Dict[str, Any],
             meta: Optional[dict] = None):
        """Synchronous atomic save of a flat {name: array} tree."""
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "meta": meta or {}, "leaves": {}}
        for name, arr in tree.items():
            a = _device_get(arr)
            np.save(os.path.join(tmp, _escape(name) + ".npy"), a)
            manifest["leaves"][name] = {"shape": list(a.shape),
                                        "dtype": str(a.dtype)}
        fsync_json(os.path.join(tmp, "manifest.json"), manifest)
        atomic_publish(tmp, final)

    def save_async(self, step: int, tree: Dict[str, Any],
                   meta: Optional[dict] = None):
        """Snapshot to host, then write in a background thread."""
        snap = {k: _device_get(v) for k, v in tree.items()}
        self.wait()
        self._async_thread = threading.Thread(
            target=self.save, args=(step, snap, meta), daemon=True)
        self._async_thread.start()

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    # -- restore ------------------------------------------------------------
    def steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("_", 1)[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step}",
                               "manifest.json")) as f:
            return json.load(f)

    def restore(self, step: int, shardings: Optional[Dict[str, Any]] = None,
                dtype_map: Optional[Dict[str, Any]] = None
                ) -> Dict[str, Any]:
        """Load a tree; optionally re-shard each leaf onto `shardings[name]`
        (elastic restore onto a different mesh)."""
        base = os.path.join(self.dir, f"step_{step}")
        man = self.manifest(step)
        out = {}
        for name in man["leaves"]:
            a = np.load(os.path.join(base, _escape(name) + ".npy"))
            if shardings and shardings.get(name) is not None:
                import jax
                out[name] = jax.device_put(a, shardings[name])
            else:
                out[name] = a
        return out

    # -- rotation -------------------------------------------------------------
    def rotate(self, keep: int = 3):
        for s in self.steps()[:-keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
