"""Checkpoint manager: save cadence, rotation, and restart orchestration.

``maybe_restore`` is the restart entry point: it finds the newest intact
checkpoint whose config hash matches, restores params/opt-state (re-sharded
onto the *current* mesh — which may be smaller after a slice-down), and
returns the step to resume from.  The data pipeline is counter-based
(data/pipeline.py) so resuming at step N replays the exact stream.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

from .store import CheckpointStore, config_hash


@dataclass
class CheckpointPolicy:
    every_steps: int = 100
    keep: int = 3
    async_save: bool = True


class CheckpointManager:
    def __init__(self, directory: str, policy: CheckpointPolicy = None):
        self.store = CheckpointStore(directory)
        self.policy = policy or CheckpointPolicy()

    def step_hook(self, step: int, params, opt_state, meta: dict):
        if step % self.policy.every_steps:
            return False
        tree = {f"params/{k}": v for k, v in params.items()}
        tree.update(_flatten_opt(opt_state))
        if self.policy.async_save:
            self.store.save_async(step, tree, meta)
        else:
            self.store.save(step, tree, meta)
        self.store.rotate(self.policy.keep)
        return True

    def maybe_restore(self, cfg_obj, param_shardings=None,
                      opt_shardings=None
                      ) -> Optional[Tuple[int, Dict, Dict]]:
        step = self.store.latest_step()
        if step is None:
            return None
        man = self.store.manifest(step)
        want = config_hash(cfg_obj)
        got = man["meta"].get("config_hash")
        if got is not None and got != want:
            raise ValueError(
                f"checkpoint config hash {got} != current {want}; refusing "
                "to restore a mismatched architecture")
        shardings = {}
        if param_shardings:
            shardings.update({f"params/{k}": v
                              for k, v in param_shardings.items()})
        if opt_shardings:
            shardings.update(opt_shardings)
        tree = self.store.restore(step, shardings=shardings or None)
        params = {k[len("params/"):]: v for k, v in tree.items()
                  if k.startswith("params/")}
        opt = _unflatten_opt({k: v for k, v in tree.items()
                              if not k.startswith("params/")})
        self.store.wait()
        return step, params, opt


def _flatten_opt(opt_state: dict, prefix: str = "opt") -> Dict[str, Any]:
    """Flatten the 2-level opt-state schema {top: {param_name: leaf}}.

    Param names themselves contain '/', so structure uses '|' as the
    separator: 'opt|m|layers/attn/wq', tuples as 'opt|f|name#i'.
    """
    out = {}
    for k, v in opt_state.items():
        key = f"{prefix}|{k}"
        if isinstance(v, dict):
            for pk, pv in v.items():
                if isinstance(pv, tuple):
                    for i, vi in enumerate(pv):
                        out[f"{key}|{pk}#{i}"] = vi
                else:
                    out[f"{key}|{pk}"] = pv
        else:
            out[key] = v
    return out


def _unflatten_opt(flat: Dict[str, Any]) -> dict:
    out: dict = {}
    tuples: Dict[tuple, list] = {}
    for k, v in sorted(flat.items()):
        parts = k.split("|")
        assert parts[0] == "opt"
        if len(parts) == 2:
            out[parts[1]] = v
        else:
            _, top, name = parts
            if "#" in name:
                base, idx = name.rsplit("#", 1)
                tuples.setdefault((top, base), []).append((int(idx), v))
            else:
                out.setdefault(top, {})[name] = v
    for (top, base), items in tuples.items():
        items.sort()
        out.setdefault(top, {})[base] = tuple(v for _, v in items)
    return out
