"""The LM stack: one configurable model covering all ten assigned archs.

Params are a FLAT dict ``{"path/to/param": array}``; per-layer params are
stacked on a leading L dim and applied with ``jax.lax.scan`` (+remat), so
HLO size — and dry-run compile time — is O(1) in depth.

Families (cfg.block / cfg flags):
  * ``attn``   — pre-norm GQA attention + (MoE or gated/plain) MLP;
  * ``mlstm``  — xLSTM matrix-memory block (chunkwise GLA engine);
  * ``hymba``  — parallel sliding-window attention + mamba-style GLA heads;
  * ``enc_dec``— whisper: encoder stack on stub frame embeddings + decoder
                 with self+cross attention;
  * ``vlm``    — stub patch embeddings prepended to the token sequence.

Sharding: every param carries logical axis names; ``Resolver`` maps them to
mesh axes per the ParallelPlan, dropping rules whose target doesn't divide
the dim (e.g. 20 heads on a 16-way model axis).  Activations get
``with_sharding_constraint`` at block boundaries.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ParallelPlan
from . import layers as L
from .moe import moe_ffn
from .ssm import chunkwise_gla, gla_decode_step

# ==========================================================================
# sharding resolution
# ==========================================================================


class Resolver:
    """logical axes -> PartitionSpec under (plan, mesh), with divisibility."""

    def __init__(self, plan: ParallelPlan, mesh: Optional[Mesh] = None):
        self.plan = plan
        self.mesh = mesh
        self.dropped: list = []

    def _target(self, logical: Optional[str], dim: int) -> Tuple[str, ...]:
        if logical is None or self.mesh is None:
            return ()
        want = [a for a in self.plan.rule(logical)
                if a in self.mesh.shape]
        out = []
        size = 1
        for a in want:
            size *= self.mesh.shape[a]
        if size > 1 and dim % size == 0:
            out = want
        elif want:
            self.dropped.append((logical, dim, tuple(want)))
        return tuple(out)

    def spec(self, axes: Tuple[Optional[str], ...],
             shape: Tuple[int, ...]) -> P:
        assert len(axes) == len(shape), (axes, shape)
        parts = []
        for a, d in zip(axes, shape):
            t = self._target(a, d)
            parts.append(t if len(t) > 1 else (t[0] if t else None))
        return P(*parts)

    def sharding(self, axes, shape) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(axes, shape))

    def constrain(self, x: jax.Array,
                  axes: Tuple[Optional[str], ...]) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(axes, x.shape)))


# ==========================================================================
# parameter specs
# ==========================================================================

Spec = Tuple[Tuple[int, ...], Tuple[Optional[str], ...], str]  # shape, axes, init


def _attn_specs(cfg: ModelConfig, nl: int, prefix: str,
                cross: bool = False) -> Dict[str, Spec]:
    d, hd, h, kv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv
    s: Dict[str, Spec] = {
        f"{prefix}/wq": ((nl, d, h * hd), (None, "embed", "heads"), "fan_in"),
        f"{prefix}/wk": ((nl, d, kv * hd), (None, "embed", "kv_heads"), "fan_in"),
        f"{prefix}/wv": ((nl, d, kv * hd), (None, "embed", "kv_heads"), "fan_in"),
        f"{prefix}/wo": ((nl, h * hd, d), (None, "heads", "embed"), "fan_in"),
    }
    if cfg.qkv_bias:
        s[f"{prefix}/bq"] = ((nl, h * hd), (None, "heads"), "zeros")
        s[f"{prefix}/bk"] = ((nl, kv * hd), (None, "kv_heads"), "zeros")
        s[f"{prefix}/bv"] = ((nl, kv * hd), (None, "kv_heads"), "zeros")
    if cfg.qk_norm and not cross:
        s[f"{prefix}/q_norm"] = ((nl, hd), (None, None), "ones")
        s[f"{prefix}/k_norm"] = ((nl, hd), (None, None), "ones")
    return s


def _norm_specs(cfg: ModelConfig, nl: int, name: str) -> Dict[str, Spec]:
    d = cfg.d_model
    s = {f"{name}/scale": ((nl, d), (None, None), "ones")}
    if cfg.norm == "layernorm":
        s[f"{name}/bias"] = ((nl, d), (None, None), "zeros")
    return s


def _mlp_specs(cfg: ModelConfig, nl: int, prefix: str) -> Dict[str, Spec]:
    d, ff = cfg.d_model, cfg.d_ff
    s = {
        f"{prefix}/w1": ((nl, d, ff), (None, "embed", "ff"), "fan_in"),
        f"{prefix}/w2": ((nl, ff, d), (None, "ff", "embed"), "fan_in"),
    }
    if cfg.act == "silu":   # gated
        s[f"{prefix}/w3"] = ((nl, d, ff), (None, "embed", "ff"), "fan_in")
    return s


def param_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    d, hd, h = cfg.d_model, cfg.head_dim, cfg.n_heads
    nl = cfg.n_layers
    vp = cfg.vocab_padded()
    specs: Dict[str, Spec] = {
        "embed/tokens": ((vp, d), ("vocab", "embed"), "embed"),
        "final_norm/scale": ((d,), (None,), "ones"),
    }
    if cfg.norm == "layernorm":
        specs["final_norm/bias"] = ((d,), (None,), "zeros")
    if not cfg.tie_embeddings:
        specs["lm_head"] = ((vp, d), ("vocab", "embed"), "embed")

    lp = "layers"
    specs.update(_norm_specs(cfg, nl, f"{lp}/ln1"))
    if cfg.block == "attn":
        specs.update(_attn_specs(cfg, nl, f"{lp}/attn"))
        specs.update(_norm_specs(cfg, nl, f"{lp}/ln2"))
        if cfg.is_moe:
            e, ffe = cfg.n_experts, cfg.d_ff
            specs.update({
                f"{lp}/moe/router": ((nl, d, e), (None, "embed", "experts"),
                                     "fan_in"),
                f"{lp}/moe/w1": ((nl, e, d, ffe),
                                 (None, "experts", "embed", "expert_ff"),
                                 "fan_in"),
                f"{lp}/moe/w3": ((nl, e, d, ffe),
                                 (None, "experts", "embed", "expert_ff"),
                                 "fan_in"),
                f"{lp}/moe/w2": ((nl, e, ffe, d),
                                 (None, "experts", "expert_ff", "embed"),
                                 "fan_in"),
            })
        else:
            specs.update(_mlp_specs(cfg, nl, f"{lp}/mlp"))
    elif cfg.block == "mlstm":
        di = 2 * d
        dk = di // h
        specs.update({
            f"{lp}/mlstm/w_in": ((nl, d, 2 * di), (None, "embed", None),
                                 "fan_in"),
            f"{lp}/mlstm/wq": ((nl, h, dk, dk), (None, None, "embed", None),
                               "fan_in"),
            f"{lp}/mlstm/wk": ((nl, h, dk, dk), (None, None, "embed", None),
                               "fan_in"),
            f"{lp}/mlstm/wv": ((nl, h, dk, dk),
                               (None, None, "embed", "head_dv"), "fan_in"),
            f"{lp}/mlstm/w_gate": ((nl, d, 2 * h), (None, "embed", None),
                                   "gate"),
            f"{lp}/mlstm/w_out": ((nl, di, d), (None, "head_dv", "embed"),
                                  "fan_in"),
        })
    elif cfg.block == "hymba":
        n = cfg.ssm_state
        specs.update(_attn_specs(cfg, nl, f"{lp}/attn"))
        specs.update({
            f"{lp}/ssm/w_v": ((nl, d, h * hd), (None, "embed", "heads"),
                              "fan_in"),
            f"{lp}/ssm/w_B": ((nl, d, h * n), (None, "embed", None),
                              "fan_in"),
            f"{lp}/ssm/w_C": ((nl, d, h * n), (None, "embed", None),
                              "fan_in"),
            f"{lp}/ssm/w_dt": ((nl, d, h), (None, "embed", None), "fan_in"),
            f"{lp}/ssm/dt_bias": ((nl, h), (None, None), "zeros"),
            f"{lp}/ssm/log_A": ((nl, h), (None, None), "ssm_a"),
            f"{lp}/norm_attn/scale": ((nl, h * hd), (None, "heads"), "ones"),
            f"{lp}/norm_ssm/scale": ((nl, h * hd), (None, "heads"), "ones"),
            f"{lp}/fuse/wo": ((nl, h * hd, d), (None, "heads", "embed"),
                              "fan_in"),
        })
        specs.update(_norm_specs(cfg, nl, f"{lp}/ln2"))
        specs.update(_mlp_specs(cfg, nl, f"{lp}/mlp"))
    else:
        raise ValueError(cfg.block)

    if cfg.enc_dec:
        el = cfg.enc_layers
        specs.update(_norm_specs(cfg, el, "enc/ln1"))
        specs.update(_attn_specs(cfg, el, "enc/attn"))
        specs.update(_norm_specs(cfg, el, "enc/ln2"))
        specs.update(_mlp_specs(cfg, el, "enc/mlp"))
        specs["enc/final_norm/scale"] = ((d,), (None,), "ones")
        if cfg.norm == "layernorm":
            specs["enc/final_norm/bias"] = ((d,), (None,), "zeros")
        specs.update(_norm_specs(cfg, nl, f"{lp}/ln_cross"))
        specs.update(_attn_specs(cfg, nl, f"{lp}/cross", cross=True))
    return specs


def abstract_params(cfg: ModelConfig, dtype=None) -> Dict[str, Any]:
    dt = dtype or jnp.dtype(cfg.dtype)
    return {k: jax.ShapeDtypeStruct(shape, dt)
            for k, (shape, _, _) in param_specs(cfg).items()}


def param_shardings(cfg: ModelConfig, res: Resolver) -> Dict[str, Any]:
    return {k: res.sharding(axes, shape)
            for k, (shape, axes, _) in param_specs(cfg).items()}


def init_params(cfg: ModelConfig, key, dtype=None) -> Dict[str, jax.Array]:
    dt = dtype or jnp.dtype(cfg.dtype)
    out = {}
    specs = param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    for (name, (shape, _, init)), k in zip(sorted(specs.items()), keys):
        if init == "ones":
            out[name] = jnp.ones(shape, dt)
        elif init == "zeros":
            out[name] = jnp.zeros(shape, dt)
        elif init == "embed":
            out[name] = L.trunc_normal(k, shape, dt, std=0.02)
        elif init == "gate":
            out[name] = L.trunc_normal(k, shape, dt, std=0.02)
        elif init == "ssm_a":
            # decay scale in softplus space: A ~ U[1, 8] -> log
            u = jax.random.uniform(k, shape, jnp.float32, 1.0, 8.0)
            out[name] = jnp.log(u).astype(dt)
        else:  # fan_in
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            out[name] = L.trunc_normal(k, shape, dt,
                                       std=1.0 / math.sqrt(fan_in))
    return out


def param_count(params) -> int:
    return sum(int(v.size) for v in params.values())


# ==========================================================================
# block forwards (per-layer; applied under lax.scan)
# ==========================================================================


def _norm(cfg, p, name, x):
    if cfg.norm == "layernorm":
        return L.layer_norm(x, p[f"{name}/scale"], p[f"{name}/bias"])
    return L.rms_norm(x, p[f"{name}/scale"])


def _project_qkv(cfg, p, prefix, x, xkv=None):
    """x (B,S,D) -> q (B,S,H,hd), k/v (B,Skv,KV,hd)."""
    hd, h, kv = cfg.head_dim, cfg.n_heads, cfg.n_kv
    xkv = x if xkv is None else xkv
    q = jnp.einsum("bsd,dh->bsh", x, p[f"{prefix}/wq"])
    k = jnp.einsum("bsd,dh->bsh", xkv, p[f"{prefix}/wk"])
    v = jnp.einsum("bsd,dh->bsh", xkv, p[f"{prefix}/wv"])
    if cfg.qkv_bias:
        q = q + p[f"{prefix}/bq"]
        k = k + p[f"{prefix}/bk"]
        v = v + p[f"{prefix}/bv"]
    q = q.reshape(*q.shape[:2], h, hd)
    k = k.reshape(*k.shape[:2], kv, hd)
    v = v.reshape(*v.shape[:2], kv, hd)
    if cfg.qk_norm and f"{prefix}/q_norm" in p:
        q = L.rms_norm(q, p[f"{prefix}/q_norm"])
        k = L.rms_norm(k, p[f"{prefix}/k_norm"])
    # keep the fp32 attention internals' cotangents from leaking upstream
    return (L.grad_dtype_guard(q), L.grad_dtype_guard(k),
            L.grad_dtype_guard(v))


def _gqa(cfg, q, k, v, *, causal, window=0, rope=None, q_offset=0,
         chunk_q=1024, res=None):
    """Grouped attention via kv-head broadcast; q (B,S,H,hd)."""
    if rope is not None:
        cos, sin = rope
        q = L.apply_rope(q, cos[q_offset:q_offset + q.shape[1]], sin[q_offset:q_offset + q.shape[1]])
        k = L.apply_rope(k, cos[:k.shape[1]], sin[:k.shape[1]])
    if res is not None:
        # context parallelism: k/v gathered (bf16, post-rope) across the
        # model axis — each rank attends its own q-sequence slice
        k = res.constrain(k, ("batch", None, "kv_heads", None))
        v = res.constrain(v, ("batch", None, "kv_heads", None))
    return L.attention(q, k, v, causal=causal, window=window,
                       chunk_q=chunk_q, q_offset=q_offset)


def _attn_sublayer(cfg, p, x, rope, window=0, causal=True, prefix="attn",
                   xkv=None, q_offset=0, res=None, chunk_q=1024):
    q, k, v = _project_qkv(cfg, p, prefix, x, xkv)
    if res is not None:
        # context parallelism (seq_attn rule): shard the q sequence over
        # `model` when heads cannot shard.  k/v are projected on sequence
        # SHARDS (cheap) and only gathered post-rope inside _gqa (bf16,
        # kv-head-narrow) — not the d_model-wide x.
        q = res.constrain(q, ("batch", "seq_attn", "heads", None))
        k = res.constrain(k, ("batch", "seq_attn", "kv_heads", None))
        v = res.constrain(v, ("batch", "seq_attn", "kv_heads", None))
    o = _gqa(cfg, q, k, v, causal=causal, window=window, rope=rope,
             q_offset=q_offset, res=res, chunk_q=chunk_q)
    if res is not None:
        o = res.constrain(o, ("batch", "seq_attn", "heads", None))
    o = o.reshape(*o.shape[:2], cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bsh,hd->bsd", o, p[f"{prefix}/wo"]), o


def _mlp_sublayer(cfg, p, x, prefix="mlp"):
    act = L.act_fn(cfg.act)
    h = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}/w1"])
    if f"{prefix}/w3" in p:
        h = act(h) * jnp.einsum("bsd,df->bsf", x, p[f"{prefix}/w3"])
    else:
        h = act(h)
    return jnp.einsum("bsf,fd->bsd", h, p[f"{prefix}/w2"])


def _mlstm_qkv(cfg, p, x):
    """mLSTM projections: x (B,S,D) -> q,k (B,S,H,dk), v (B,S,H,dk),
    gates log_a (B,S,H), z (B,S,di)."""
    d = cfg.d_model
    h = cfg.n_heads
    di = 2 * d
    dk = di // h
    inner = jnp.einsum("bsd,de->bse", x, p["mlstm/w_in"])
    xi, z = jnp.split(inner, 2, axis=-1)                 # (B,S,di) each
    xh = xi.reshape(*xi.shape[:2], h, dk)
    q = jnp.einsum("bshk,hkl->bshl", xh, p["mlstm/wq"])
    k = jnp.einsum("bshk,hkl->bshl", xh, p["mlstm/wk"]) / math.sqrt(dk)
    v = jnp.einsum("bshk,hkl->bshl", xh, p["mlstm/wv"])
    gates = jnp.einsum("bsd,dg->bsg", x, p["mlstm/w_gate"])
    gi, gf = jnp.split(gates, 2, axis=-1)                # (B,S,H)
    log_a = jax.nn.log_sigmoid(gf.astype(jnp.float32))
    k = k * jax.nn.sigmoid(gi.astype(jnp.float32))[..., None].astype(k.dtype)
    return (L.grad_dtype_guard(q), L.grad_dtype_guard(k),
            L.grad_dtype_guard(v), log_a, z)


def _hymba_ssm_qkv(cfg, p, x):
    """Mamba-style heads as GLA: q=C, k=B*dt(normalised), decay from A,dt."""
    h, n, hd = cfg.n_heads, cfg.ssm_state, cfg.head_dim
    v = jnp.einsum("bsd,de->bse", x, p["ssm/w_v"]).reshape(
        *x.shape[:2], h, hd)
    B_ = jnp.einsum("bsd,de->bse", x, p["ssm/w_B"]).reshape(
        *x.shape[:2], h, n)
    C_ = jnp.einsum("bsd,de->bse", x, p["ssm/w_C"]).reshape(
        *x.shape[:2], h, n)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["ssm/w_dt"]).astype(jnp.float32)
        + p["ssm/dt_bias"].astype(jnp.float32))          # (B,S,H)
    A = jnp.exp(p["ssm/log_A"].astype(jnp.float32))      # (H,)
    log_a = -dt * A                                      # per-head log decay
    k = B_ * dt[..., None].astype(B_.dtype)              # ZOH-ish input scale
    return (L.grad_dtype_guard(C_), L.grad_dtype_guard(k),
            L.grad_dtype_guard(v), log_a)


# ==========================================================================
# model forward (train / prefill)
# ==========================================================================


def _layer_stack(params: Dict[str, jax.Array], prefix: str):
    pl = len(prefix)
    return {k[pl:]: v for k, v in params.items() if k.startswith(prefix)}


def _moe_apply(cfg: ModelConfig, plan: ParallelPlan, res: "Resolver",
               p: Dict[str, jax.Array], h: jax.Array):
    """Dispatch to the configured MoE implementation (see moe_ep.py)."""
    mp = {k.split("/", 1)[1]: v for k, v in p.items()
          if k.startswith("moe/")}
    if plan.moe_impl == "expert_parallel" and res.mesh is not None and \
            "model" in res.mesh.shape:
        from .moe_ep import moe_ffn_ep
        return moe_ffn_ep(h, mp, top_k=cfg.top_k,
                          capacity_factor=cfg.moe_capacity,
                          act=L.act_fn(cfg.act), mesh=res.mesh,
                          batch_axes=plan.rule("batch"))
    return moe_ffn(h, mp, top_k=cfg.top_k,
                   capacity_factor=cfg.moe_capacity, act=L.act_fn(cfg.act),
                   constrain=(res.constrain if plan.moe_constraints
                              else None))


def _rope_for(cfg, seq):
    if cfg.pos != "rope":
        return None
    pos = jnp.arange(seq)
    return L.rope_tables(pos, cfg.head_dim, cfg.rope_theta)


def _block_fn(cfg: ModelConfig, plan: ParallelPlan, res: Resolver,
              rope, mode: str):
    """Returns block(carry, layer_params) for lax.scan over layers."""
    gla_chunk = 128 if mode != "train" else 256

    def block(carry, p):
        x, aux = carry
        x = res.constrain(x, ("batch", "seq_act", None))
        h = _norm(cfg, p, "ln1", x)
        if cfg.block == "attn":
            o, _ = _attn_sublayer(cfg, p, h, rope, res=res,
                                  chunk_q=plan.attn_chunk)
            # pin the sublayer output's layout HERE so the model-axis psum
            # of the wo/w2 contraction happens on the bf16 einsum output —
            # not after XLA fuses it past the next norm's fp32 upcast
            o = res.constrain(o, ("batch", "seq_act", None))
            x = x + o
            h2 = _norm(cfg, p, "ln2", x)
            if cfg.is_moe:
                y, al = _moe_apply(cfg, plan, res, p, h2)
                aux = aux + al
            else:
                y = _mlp_sublayer(cfg, p, h2)
            y = res.constrain(y, ("batch", "seq_act", None))
            x = x + y
        elif cfg.block == "mlstm":
            q, k, v, log_a, z = _mlstm_qkv(cfg, p, h)
            y, _ = chunkwise_gla(q, k, v, log_a, chunk=min(
                gla_chunk, q.shape[1]))
            y = y.reshape(*y.shape[:2], -1) * jax.nn.silu(z)
            x = x + jnp.einsum("bse,ed->bsd", y, p["mlstm/w_out"])
        elif cfg.block == "hymba":
            # parallel branches share the normed input; fusion is pre-wo
            q, k, v = _project_qkv(cfg, p, "attn", h)
            q = res.constrain(q, ("batch", "seq_attn", "heads", None))
            heads_attn = _gqa(cfg, q, k, v, causal=True, window=cfg.window,
                              rope=rope).reshape(*h.shape[:2], -1)
            qs, ks, vs, log_a = _hymba_ssm_qkv(cfg, p, h)
            heads_ssm, _ = chunkwise_gla(qs, ks, vs, log_a, chunk=min(
                gla_chunk, qs.shape[1]), normalize=False)
            heads_ssm = heads_ssm.reshape(*h.shape[:2], -1)
            fused = 0.5 * (L.rms_norm(heads_attn, p["norm_attn/scale"])
                           + L.rms_norm(heads_ssm, p["norm_ssm/scale"]))
            x = x + jnp.einsum("bse,ed->bsd", fused, p["fuse/wo"])
            h2 = _norm(cfg, p, "ln2", x)
            x = x + _mlp_sublayer(cfg, p, h2)
        else:
            raise ValueError(cfg.block)
        return (x, aux), None

    return block


def _run_decoder(cfg, plan, res, params, x, mode, enc_out=None):
    """Scan the decoder stack over x (B,S,D); returns (x, aux_loss)."""
    rope = _rope_for(cfg, x.shape[1])
    stack = _layer_stack(params, "layers/")
    block = _block_fn(cfg, plan, res, rope, mode)

    if cfg.enc_dec:
        # standard decoder order: self-attn -> cross-attn -> mlp
        def block_ed(carry, p):
            x, aux = carry
            x = res.constrain(x, ("batch", "seq_act", None))
            h = _norm(cfg, p, "ln1", x)
            o, _ = _attn_sublayer(cfg, p, h, rope, res=res)
            x = x + o
            hc = _norm(cfg, p, "ln_cross", x)
            o, _ = _attn_sublayer(cfg, p, hc, None, causal=False,
                                  prefix="cross", xkv=enc_out, res=res)
            x = x + o
            h2 = _norm(cfg, p, "ln2", x)
            x = x + _mlp_sublayer(cfg, p, h2)
            return (x, aux), None
        body = block_ed
    else:
        body = block
    if plan.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack)
    return x, aux


def _run_encoder(cfg, plan, res, params, frames):
    """Whisper encoder on stub frame embeddings (B, F, D)."""
    x = frames + L.sinusoidal_pos(frames.shape[1],
                                  cfg.d_model).astype(frames.dtype)
    stack = _layer_stack(params, "enc/")
    stack = {k: v for k, v in stack.items() if not k.startswith("final_norm")}

    def block(carry, p):
        x, aux = carry
        h = _norm(cfg, p, "ln1", x)
        o, _ = _attn_sublayer(cfg, p, h, None, causal=False)
        x = x + o
        h2 = _norm(cfg, p, "ln2", x)
        x = x + _mlp_sublayer(cfg, p, h2)
        return (x, aux), None

    body = jax.checkpoint(block) if plan.remat else block
    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack)
    if cfg.norm == "layernorm":
        x = L.layer_norm(x, params["enc/final_norm/scale"],
                         params["enc/final_norm/bias"])
    else:
        x = L.rms_norm(x, params["enc/final_norm/scale"])
    return x


def _embed(cfg, params, tokens):
    emb = params["embed/tokens"]
    # keep the lookup result in the model dtype: the vocab-sharded table
    # lookup lowers through a masked f32 reduction, and letting that f32
    # escape doubles every downstream collective
    return emb[tokens].astype(emb.dtype)


def _unembed(cfg, params, x):
    head = params.get("lm_head", params["embed/tokens"])
    return jnp.einsum("bsd,vd->bsv", x, head)


def forward(cfg: ModelConfig, plan: ParallelPlan, res: Resolver,
            params: Dict[str, jax.Array], tokens: jax.Array,
            frames: Optional[jax.Array] = None,
            patches: Optional[jax.Array] = None,
            mode: str = "train") -> Tuple[jax.Array, jax.Array, int]:
    """tokens (B,S) -> (logits (B,S',Vp), aux_loss, prefix_len).

    For VLM, patch embeddings are prepended: S' = n_patches + S (padded to a
    multiple of 1024 when needed — the pad tail is loss-masked upstream).
    """
    x = _embed(cfg, params, tokens)
    prefix = 0
    if cfg.vision_patches and patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        prefix = patches.shape[1]
        pad = (-x.shape[1]) % 1024 if x.shape[1] > 1024 else 0
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    if cfg.pos == "sinusoidal":
        x = x + L.sinusoidal_pos(x.shape[1], cfg.d_model).astype(x.dtype)
    enc_out = None
    if cfg.enc_dec:
        assert frames is not None, "enc-dec needs frame embeddings"
        enc_out = _run_encoder(cfg, plan, res, params, frames)
    x, aux = _run_decoder(cfg, plan, res, params, x, mode, enc_out=enc_out)
    if cfg.norm == "layernorm":
        x = L.layer_norm(x, params["final_norm/scale"],
                         params["final_norm/bias"])
    else:
        x = L.rms_norm(x, params["final_norm/scale"])
    logits = _unembed(cfg, params, x)
    logits = res.constrain(logits, ("batch", "seq", "vocab"))
    return logits, aux, prefix
