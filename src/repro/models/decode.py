"""Serving path: cache init, prefill, single-token decode for all families.

Cache layouts (leading L so the layer scan consumes dim 0):
  * attn / vlm:  k,v (L, B, Smax, KV, hd) + scalar pos.  KV cache sharding
    is plan-selected: heads on `model` when KV %16 == 0, else the SEQUENCE
    dim shards on `model` (decode softmax then reduces over the sharded seq
    axis — a psum GSPMD inserts);
  * enc-dec:     + cross k,v (L, B, F, KV, hd) precomputed from the encoder;
  * hymba:       ring k,v (L, B, W, KV, hd) (sliding window W) + GLA state
    (L, B, H, N, hd) — O(W + state) memory at any context length;
  * mlstm:       GLA state (L, B, H, dk, dv) + normaliser (L, B, H, dk) —
    O(1) in context length (why long_500k runs for this family).

`decode_step` is one fused step: embed -> layer scan (cache read/update) ->
unembed -> greedy next token.  This is the fn lowered for decode_32k /
long_500k cells.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ParallelPlan
from . import layers as L
from .lm import (Resolver, _embed, _hymba_ssm_qkv, _layer_stack,
                 _mlp_sublayer, _mlstm_qkv, _moe_apply, _norm,
                 _project_qkv, _unembed, _attn_sublayer, _run_encoder,
                 forward)
from .moe import moe_ffn
from .ssm import chunkwise_gla, gla_decode_step


def _kv_axes(cfg: ModelConfig, plan: ParallelPlan) -> Tuple:
    """Logical axes for the (B, S, KV, hd) cache dims."""
    mode = plan.kv_shard
    if mode == "auto":
        mode = "heads" if cfg.n_kv % 16 == 0 else "seq"
    if mode == "heads":
        return ("batch", None, "kv_heads", None)
    if mode == "seq":
        return ("batch", "seq_kv", None, None)
    return ("batch", None, None, None)


KV_SEQ_RULE = ("seq_kv", ("model",))  # appended to plans at resolve time


def cache_spec(cfg: ModelConfig, plan: ParallelPlan, batch: int,
               max_len: int, dtype=jnp.bfloat16) -> Dict[str, object]:
    """Abstract cache structure (ShapeDtypeStructs; no allocation)."""
    nl, kv, hd, h = cfg.n_layers, cfg.n_kv, cfg.head_dim, cfg.n_heads
    sds = jax.ShapeDtypeStruct
    c: Dict[str, object] = {"pos": sds((), jnp.int32)}
    if cfg.block == "attn":
        c["k"] = sds((nl, batch, max_len, kv, hd), dtype)
        c["v"] = sds((nl, batch, max_len, kv, hd), dtype)
        if cfg.enc_dec:
            c["ck"] = sds((nl, batch, cfg.enc_frames, kv, hd), dtype)
            c["cv"] = sds((nl, batch, cfg.enc_frames, kv, hd), dtype)
    elif cfg.block == "hymba":
        w = min(cfg.window, max_len)
        c["k"] = sds((nl, batch, w, kv, hd), dtype)
        c["v"] = sds((nl, batch, w, kv, hd), dtype)
        c["state"] = sds((nl, batch, h, cfg.ssm_state, hd), jnp.float32)
    elif cfg.block == "mlstm":
        dk = 2 * cfg.d_model // h
        c["state"] = sds((nl, batch, h, dk, dk), jnp.float32)
        c["norm"] = sds((nl, batch, h, dk), jnp.float32)
    return c


def init_cache(cfg, plan, batch, max_len, dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, plan, batch, max_len, dtype))


def cache_axes(cfg: ModelConfig, plan: ParallelPlan) -> Dict[str, Tuple]:
    kva = _kv_axes(cfg, plan)
    ax = {"pos": ()}
    if cfg.block == "attn":
        ax["k"] = (None,) + kva
        ax["v"] = (None,) + kva
        if cfg.enc_dec:
            ax["ck"] = (None, "batch", None, "kv_heads", None)
            ax["cv"] = (None, "batch", None, "kv_heads", None)
    elif cfg.block == "hymba":
        ax["k"] = (None, "batch", None, "kv_heads", None)
        ax["v"] = (None, "batch", None, "kv_heads", None)
        ax["state"] = (None, "batch", "heads", None, None)
    elif cfg.block == "mlstm":
        ax["state"] = (None, "batch", None, None, "head_dv")
        ax["norm"] = (None, "batch", None, None)
    return ax


def _decode_gqa(cfg, q, k_cache, v_cache, length) -> jax.Array:
    """Grouped decode attention without materialising repeated KV.

    q (B, 1, H, hd); cache (B, S, KV, hd); returns (B, 1, H, hd).
    """
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(hd)
    s = k_cache.shape[1]
    valid = jnp.arange(s) < length
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, 1, h, hd)


def _rope_at(cfg, pos) -> Optional[Tuple[jax.Array, jax.Array]]:
    if cfg.pos != "rope":
        return None
    return L.rope_tables(jnp.asarray(pos)[None], cfg.head_dim,
                         cfg.rope_theta)


def decode_step(cfg: ModelConfig, plan: ParallelPlan, res: Resolver,
                params: Dict[str, jax.Array], cache: Dict[str, jax.Array],
                token: jax.Array
                ) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """One token for the whole batch: (cache, token (B,1)) ->
    (new_cache, logits (B, Vp), next_token (B, 1))."""
    pos = cache["pos"]
    x = _embed(cfg, params, token)                    # (B,1,D)
    if cfg.pos == "sinusoidal":
        x = x + L.sinusoidal_pos(1, cfg.d_model, offset=pos).astype(x.dtype)
    rope = _rope_at(cfg, pos)
    stack = _layer_stack(params, "layers/")

    new_cache = dict(cache)
    if cfg.block == "attn":
        def body(x, xs):
            if cfg.enc_dec:
                p, kc, vc, cck, ccv = xs
            else:
                p, kc, vc = xs
            h = _norm(cfg, p, "ln1", x)
            q, k, v = _project_qkv(cfg, p, "attn", h)
            if rope is not None:
                q = L.apply_rope(q, *rope)
                k = L.apply_rope(k, *rope)
            kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                              (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                              (0, pos, 0, 0))
            o = _decode_gqa(cfg, q, kc, vc, pos + 1)
            o = o.reshape(*o.shape[:2], -1)
            x = x + jnp.einsum("bsh,hd->bsd", o, p["attn/wo"])
            if cfg.enc_dec:
                hcx = _norm(cfg, p, "ln_cross", x)
                qc2 = jnp.einsum("bsd,dh->bsh", hcx, p["cross/wq"])
                if cfg.qkv_bias:
                    qc2 = qc2 + p["cross/bq"]
                qc2 = qc2.reshape(*qc2.shape[:2], cfg.n_heads, cfg.head_dim)
                o2 = _decode_gqa(cfg, qc2, cck, ccv, cck.shape[1])
                o2 = o2.reshape(*o2.shape[:2], -1)
                x = x + jnp.einsum("bsh,hd->bsd", o2, p["cross/wo"])
            h2 = _norm(cfg, p, "ln2", x)
            if cfg.is_moe:
                y, _ = _moe_apply(cfg, plan, res, p, h2)
            else:
                y = _mlp_sublayer(cfg, p, h2)
            x = x + y
            return x, (kc, vc)

        if cfg.enc_dec:
            xs = (stack, cache["k"], cache["v"], cache["ck"], cache["cv"])
        else:
            xs = (stack, cache["k"], cache["v"])
        x, (nk, nv) = jax.lax.scan(body, x, xs)
        new_cache["k"], new_cache["v"] = nk, nv

    elif cfg.block == "hymba":
        w = cache["k"].shape[2]
        slot = pos % w

        def body(x, xs):
            p, kc, vc, st = xs
            h = _norm(cfg, p, "ln1", x)
            q, k, v = _project_qkv(cfg, p, "attn", h)
            if rope is not None:
                q = L.apply_rope(q, *rope)
                k = L.apply_rope(k, *rope)
            kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                              (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                              (0, slot, 0, 0))
            # ring buffer holds exactly the last min(pos+1, w) tokens
            o = _decode_gqa(cfg, q, kc, vc, jnp.minimum(pos + 1, w))
            heads_attn = o.reshape(*o.shape[:2], -1)
            qs, ks, vs, log_a = _hymba_ssm_qkv(cfg, p, h)
            y, st_new, _ = gla_decode_step(
                st, jnp.zeros(st.shape[:-1], jnp.float32),
                qs[:, 0], ks[:, 0], vs[:, 0], log_a[:, 0], normalize=False)
            heads_ssm = y.reshape(y.shape[0], 1, -1)
            fused = 0.5 * (L.rms_norm(heads_attn, p["norm_attn/scale"])
                           + L.rms_norm(heads_ssm, p["norm_ssm/scale"]))
            x = x + jnp.einsum("bse,ed->bsd", fused, p["fuse/wo"])
            h2 = _norm(cfg, p, "ln2", x)
            x = x + _mlp_sublayer(cfg, p, h2)
            return x, (kc, vc, st_new)

        x, (nk, nv, nst) = jax.lax.scan(
            body, x, (stack, cache["k"], cache["v"], cache["state"]))
        new_cache["k"], new_cache["v"], new_cache["state"] = nk, nv, nst

    elif cfg.block == "mlstm":
        def body(x, xs):
            p, st, nm = xs
            h = _norm(cfg, p, "ln1", x)
            q, k, v, log_a, z = _mlstm_qkv(cfg, p, h)
            y, st_new, nm_new = gla_decode_step(
                st, nm, q[:, 0], k[:, 0], v[:, 0], log_a[:, 0])
            y = y.reshape(y.shape[0], 1, -1) * jax.nn.silu(z)
            x = x + jnp.einsum("bse,ed->bsd", y, p["mlstm/w_out"])
            return x, (st_new, nm_new)

        x, (nst, nnm) = jax.lax.scan(
            body, x, (stack, cache["state"], cache["norm"]))
        new_cache["state"], new_cache["norm"] = nst, nnm
    else:
        raise ValueError(cfg.block)

    if cfg.norm == "layernorm":
        x = L.layer_norm(x, params["final_norm/scale"],
                         params["final_norm/bias"])
    else:
        x = L.rms_norm(x, params["final_norm/scale"])
    logits = _unembed(cfg, params, x)[:, 0]           # (B, Vp)
    logits = res.constrain(logits, ("batch", "vocab"))
    new_cache["pos"] = pos + 1
    next_tok = jnp.argmax(logits, axis=-1).astype(token.dtype)[:, None]
    return new_cache, logits, next_tok


def prefill(cfg: ModelConfig, plan: ParallelPlan, res: Resolver,
            params: Dict[str, jax.Array], tokens: jax.Array,
            max_len: int, frames: Optional[jax.Array] = None,
            patches: Optional[jax.Array] = None
            ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Run the full prompt, build the cache, return (cache, last logits).

    Implemented as a second scan over layers that also emits per-layer K/V
    (attn) or final GLA state (ssm/hybrid) as scan ys.
    """
    b, s = tokens.shape
    x = _embed(cfg, params, tokens)
    if cfg.vision_patches and patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    if cfg.pos == "sinusoidal":
        x = x + L.sinusoidal_pos(x.shape[1], cfg.d_model).astype(x.dtype)
    seq = x.shape[1]
    rope = None
    if cfg.pos == "rope":
        rope = L.rope_tables(jnp.arange(seq), cfg.head_dim, cfg.rope_theta)
    if max_len < seq:
        raise ValueError(f"cache max_len {max_len} < prompt length {seq} "
                         f"(VLM prompts include {cfg.vision_patches} patches)")
    enc_out = None
    if cfg.enc_dec:
        enc_out = _run_encoder(cfg, plan, res, params, frames)
    stack = _layer_stack(params, "layers/")
    cache = init_cache(cfg, plan, b, max_len,
                       jnp.dtype(cfg.dtype))

    if cfg.block == "attn":
        def body(x, p):
            x = res.constrain(x, ("batch", "seq_act", None))
            h = _norm(cfg, p, "ln1", x)
            q, k, v = _project_qkv(cfg, p, "attn", h)
            from .lm import _gqa
            q = res.constrain(q, ("batch", "seq_attn", "heads", None))
            k = res.constrain(k, ("batch", "seq_attn", "kv_heads", None))
            v = res.constrain(v, ("batch", "seq_attn", "kv_heads", None))
            o = _gqa(cfg, q, k, v, causal=True, rope=rope, res=res,
                     chunk_q=plan.attn_chunk)
            o = res.constrain(o, ("batch", "seq_attn", "heads", None))
            if rope is not None:
                k = L.apply_rope(k, rope[0][:k.shape[1]], rope[1][:k.shape[1]])
            o = o.reshape(*o.shape[:2], -1)
            x = x + jnp.einsum("bsh,hd->bsd", o, p["attn/wo"])
            ck = cv = jnp.zeros((0,), x.dtype)
            if cfg.enc_dec:
                hc = _norm(cfg, p, "ln_cross", x)
                o2, _ = _attn_sublayer(cfg, p, hc, None, causal=False,
                                       prefix="cross", xkv=enc_out)
                x = x + o2
                ck = jnp.einsum("bsd,dh->bsh", enc_out, p["cross/wk"])
                cv = jnp.einsum("bsd,dh->bsh", enc_out, p["cross/wv"])
                if cfg.qkv_bias:
                    ck = ck + p["cross/bk"]
                    cv = cv + p["cross/bv"]
                ck = ck.reshape(*ck.shape[:2], cfg.n_kv, cfg.head_dim)
                cv = cv.reshape(*cv.shape[:2], cfg.n_kv, cfg.head_dim)
            h2 = _norm(cfg, p, "ln2", x)
            if cfg.is_moe:
                y, _ = _moe_apply(cfg, plan, res, p, h2)
            else:
                y = _mlp_sublayer(cfg, p, h2)
            x = x + y
            return x, (k, v, ck, cv)

        if plan.remat:
            body = jax.checkpoint(body)
        x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, stack)
        pad = max_len - seq
        kc = jnp.pad(ks.astype(cache["k"].dtype),
                     ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(vs.astype(cache["v"].dtype),
                     ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["k"], cache["v"] = kc, vc
        if cfg.enc_dec:
            cache["ck"], cache["cv"] = (cks.astype(cache["ck"].dtype),
                                        cvs.astype(cache["cv"].dtype))

    elif cfg.block == "hymba":
        w = cache["k"].shape[2]

        def body(x, p):
            from .lm import _gqa
            h = _norm(cfg, p, "ln1", x)
            q, k, v = _project_qkv(cfg, p, "attn", h)
            o = _gqa(cfg, q, k, v, causal=True, window=cfg.window, rope=rope)
            heads_attn = o.reshape(*o.shape[:2], -1)
            qs, ks_, vs_, log_a = _hymba_ssm_qkv(cfg, p, h)
            yss, (st, _) = chunkwise_gla(qs, ks_, vs_, log_a,
                                         chunk=min(128, seq),
                                         normalize=False)
            heads_ssm = yss.reshape(*h.shape[:2], -1)
            fused = 0.5 * (L.rms_norm(heads_attn, p["norm_attn/scale"])
                           + L.rms_norm(heads_ssm, p["norm_ssm/scale"]))
            x = x + jnp.einsum("bse,ed->bsd", fused, p["fuse/wo"])
            h2 = _norm(cfg, p, "ln2", x)
            x = x + _mlp_sublayer(cfg, p, h2)
            if rope is not None:
                k = L.apply_rope(k, rope[0][:k.shape[1]],
                                 rope[1][:k.shape[1]])
            # ring alignment: decode writes at slot pos % w, which must hold
            # the OLDEST cached token when it gets overwritten.
            if seq >= w:
                k_c = jnp.roll(k[:, -w:], shift=seq % w, axis=1)
                v_c = jnp.roll(v[:, -w:], shift=seq % w, axis=1)
            else:
                padw = ((0, 0), (0, w - seq), (0, 0), (0, 0))
                k_c = jnp.pad(k, padw)
                v_c = jnp.pad(v, padw)
            return x, (k_c, v_c, st)

        if plan.remat:
            body = jax.checkpoint(body)
        x, (ks, vs, sts) = jax.lax.scan(body, x, stack)
        cache["k"] = ks.astype(cache["k"].dtype)
        cache["v"] = vs.astype(cache["v"].dtype)
        cache["state"] = sts

    elif cfg.block == "mlstm":
        def body(x, p):
            h = _norm(cfg, p, "ln1", x)
            q, k, v, log_a, z = _mlstm_qkv(cfg, p, h)
            y, (st, nm) = chunkwise_gla(q, k, v, log_a,
                                        chunk=min(128, seq))
            y = y.reshape(*y.shape[:2], -1) * jax.nn.silu(z)
            x = x + jnp.einsum("bse,ed->bsd", y, p["mlstm/w_out"])
            return x, (st, nm)

        if plan.remat:
            body = jax.checkpoint(body)
        x, (sts, nms) = jax.lax.scan(body, x, stack)
        cache["state"], cache["norm"] = sts, nms
    else:
        raise ValueError(cfg.block)

    if cfg.norm == "layernorm":
        x = L.layer_norm(x, params["final_norm/scale"],
                         params["final_norm/bias"])
    else:
        x = L.rms_norm(x, params["final_norm/scale"])
    logits = _unembed(cfg, params, x[:, -1:])[:, 0]
    cache["pos"] = jnp.asarray(seq, jnp.int32)
    return cache, logits
