"""SSM / linear-recurrence blocks: chunkwise GLA, mLSTM (xLSTM), Mamba-style
heads (Hymba), and the sLSTM cell.

One chunkwise gated-linear-attention engine serves both SSM families:

    state S_t (dk x dv):  S_t = a_t * S_{t-1} + k_t^T v_t
    output:               y_t = q_t S_t            (+ normaliser, optional)

* xLSTM's mLSTM is GLA with dk = dv = head_dim, sigmoid forget gate a_t,
  input-gated k, and a normaliser state n_t = a_t n_{t-1} + k_t.
* Hymba's Mamba heads are GLA with dk = ssm_state (16), dv = head_dim,
  decay a_t = exp(-softplus(dt_t) * A) (per-head, data dependent).

The chunkwise-parallel form (chunk c): intra-chunk is a (c x c)-masked
attention GEMM, inter-chunk is a dense (dk x dv) state GEMM — all MXU work,
O(S/c) sequential steps, which is the TPU-native adaptation of these
GPU-recurrent kernels (see DESIGN.md §2).  Training memory per chunk is
O(B*H*c^2 + B*H*dk*dv), not O(S^2).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def chunkwise_gla(q: jax.Array, k: jax.Array, v: jax.Array,
                  log_a: jax.Array, chunk: int = 128,
                  init_state: Optional[jax.Array] = None,
                  normalize: bool = True
                  ) -> Tuple[jax.Array, jax.Array]:
    """Gated linear attention, chunkwise-parallel.

    q, k: (B, S, H, dk); v: (B, S, H, dv); log_a: (B, S, H) per-step log
    decay (<= 0).  Returns y (B, S, H, dv) and final state (B, H, dk, dv).
    All state math in fp32.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk} != 0")
    nc = s // chunk
    f32 = jnp.float32

    def to_chunks(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lac = to_chunks(log_a.astype(f32))          # (nc, B, c, H)

    state0 = (init_state.astype(f32) if init_state is not None
              else jnp.zeros((b, h, dk, dv), f32))
    norm0 = jnp.zeros((b, h, dk), f32)

    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    @jax.checkpoint
    def body(carry, xs):
        S_prev, n_prev = carry
        qb, kb, vb, la = xs                     # (B,c,H,dk) etc.
        qb32, kb32, vb32 = qb.astype(f32), kb.astype(f32), vb.astype(f32)
        # cumulative decay within the chunk: F_i = sum_{j<=i} log a_j
        F = jnp.cumsum(la, axis=1)              # (B, c, H)
        total = F[:, -1]                        # (B, H)
        # inter-chunk: y_i += (q_i * exp(F_i)) @ S_prev
        q_dec = qb32 * jnp.exp(F)[..., None]
        y_inter = jnp.einsum("bchk,bhkv->bchv", q_dec, S_prev)
        n_inter = jnp.einsum("bchk,bhk->bch", q_dec, n_prev)
        # intra-chunk: scores_ij = (q_i . k_j) * exp(F_i - F_j), for j <= i
        qk = jnp.einsum("bchk,bdhk->bhcd", qb32, kb32)
        scores = qk * _tril_decay(F, mask)       # (B, H, c, c)
        y_intra = jnp.einsum("bhcd,bdhv->bchv", scores, vb32)
        # normaliser: q_i . n_i = n_inter + row-sum of decayed scores
        n_intra = scores.sum(-1).transpose(0, 2, 1)   # (B, c, H)
        # state update: S_new = exp(total) S_prev + sum_j exp(total - F_j) k_j v_j
        k_tail = kb32 * jnp.exp(total[:, None] - F)[..., None]
        S_new = (jnp.exp(total)[..., None, None] * S_prev
                 + jnp.einsum("bchk,bchv->bhkv", k_tail, vb32))
        n_new = (jnp.exp(total)[..., None] * n_prev
                 + jnp.sum(k_tail, axis=1))
        y = y_inter + y_intra
        if normalize:
            qn = n_inter + n_intra
            y = y / jnp.maximum(jnp.abs(qn), 1.0)[..., None]
        return (S_new, n_new), y.astype(v.dtype)

    (Sf, nf), ys = jax.lax.scan(body, (state0, norm0), (qc, kc, vc, lac))
    y = ys.swapaxes(0, 1).reshape(b, s, h, dv)
    return y, (Sf, nf)


def _tril_decay(F: jax.Array, mask: jax.Array) -> jax.Array:
    """exp(F_i - F_j) masked to j <= i; F (B, c, H) -> (B, H, c, c).

    The exponent is masked BEFORE exp: above the diagonal F_i - F_j > 0 can
    overflow, and ``where(mask, exp(d), 0)`` would still propagate inf/NaN
    through the gradient of the untaken branch.
    """
    d = F[:, :, None, :] - F[:, None, :, :]      # (B, c_i, c_j, H)
    d = d.transpose(0, 3, 1, 2)                  # (B, H, c_i, c_j)
    d = jnp.where(mask[None, None], d, -1e30)
    return jnp.exp(d)


def gla_decode_step(state: jax.Array, norm: jax.Array, q: jax.Array,
                    k: jax.Array, v: jax.Array, log_a: jax.Array,
                    normalize: bool = True
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token recurrent step.

    state (B, H, dk, dv); norm (B, H, dk); q/k (B, H, dk); v (B, H, dv);
    log_a (B, H).  Returns (y (B, H, dv), new_state, new_norm).
    """
    f32 = jnp.float32
    a = jnp.exp(log_a.astype(f32))[..., None, None]
    state = a * state.astype(f32) + jnp.einsum(
        "bhk,bhv->bhkv", k.astype(f32), v.astype(f32))
    norm = a[..., 0] * norm.astype(f32) + k.astype(f32)
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(f32), state)
    if normalize:
        qn = jnp.einsum("bhk,bhk->bh", q.astype(f32), norm)
        y = y / jnp.maximum(jnp.abs(qn), 1.0)[..., None]
    return y.astype(v.dtype), state, norm


# --------------------------------------------------------------------------
# sLSTM cell (xLSTM): scalar-memory LSTM with exponential gating
# --------------------------------------------------------------------------


def slstm_scan(x_gates: jax.Array) -> jax.Array:
    """Sequence application of the sLSTM recurrence.

    x_gates: (B, S, H, D, 4) pre-activations for (i, f, z, o) — the cell is
    applied per (head, channel) with exponential gating and the max
    stabiliser state m (xLSTM eq. 8-16, simplified: no recurrent R weights
    inside the scan; they are folded into the pre-activations upstream).
    Returns h (B, S, H, D).
    """
    b, s, h, d, _ = x_gates.shape
    f32 = jnp.float32

    def step(carry, g):
        c, n, m = carry
        gi, gf, gz, go = [g[..., j].astype(f32) for j in range(4)]
        m_new = jnp.maximum(gf + m, gi)
        i = jnp.exp(gi - m_new)
        f = jnp.exp(gf + m - m_new)
        z = jnp.tanh(gz)
        o = jax.nn.sigmoid(go)
        c = f * c + i * z
        n = f * n + i
        hval = o * c / jnp.maximum(jnp.abs(n), 1.0)
        return (c, n, m_new), hval

    zeros = jnp.zeros((b, h, d), f32)
    (_, _, _), hs = jax.lax.scan(
        step, (zeros, zeros, zeros), x_gates.swapaxes(0, 1))
    return hs.swapaxes(0, 1).astype(x_gates.dtype)
