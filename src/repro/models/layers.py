"""Shared neural-net layers for the LM stack (pure JAX, functional).

Everything is a function over explicit param pytrees; no framework objects.
Attention is implemented flash-style at the XLA level: a ``lax.scan`` over
query chunks with an online-softmax carry, each chunk rematerialised
(`jax.checkpoint`) so the S x S score matrix never outlives a chunk — this is
what makes 32 k-token prefill lowerable at sane memory, and it is the same
blocking discipline as the Pallas kernel (kernels/flash_attention.py), which
replaces it on real TPU hot paths.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# norms / activations
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 internals and a custom VJP that hands back
    cotangents in the PRIMAL dtype.  Without this, the fp32 segment inside
    the default VJP becomes the spot where GSPMD places the model-axis
    gradient psum — a full fp32 all-reduce of (B, S, D) per sublayer
    (measured; see EXPERIMENTS.md §Perf)."""
    return _rms_fwd(x, scale, eps)[0]


def _rms_fwd(x, scale, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    out = (x32 * r * scale.astype(jnp.float32)).astype(x.dtype)
    return out, (x, scale, r)


def _rms_bwd(eps, resid, g):
    x, scale, r = resid
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    s32 = scale.astype(jnp.float32)
    xhat = x32 * r
    dscale = (g32 * xhat).sum(tuple(range(g32.ndim - 1)))
    gx = g32 * s32
    d = x32.shape[-1]
    dx = r * (gx - xhat * (gx * xhat).mean(-1, keepdims=True))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


@jax.custom_vjp
def grad_dtype_guard(x: jax.Array) -> jax.Array:
    """Identity whose VJP casts the cotangent to the primal dtype.

    Attention/softmax internals run in fp32, so their VJP emits fp32
    cotangents; every einsum-VJP downstream then promotes to fp32, and all
    backward collectives (model-axis dx psums, remat FSDP weight gathers)
    travel at double width.  Placing this guard on q/k/v (and SSM inputs)
    right after the projections confines fp32 to the op that needs it —
    measured ~2x on backward collective bytes (EXPERIMENTS.md §Perf C4).
    """
    return x


def _gdg_fwd(x):
    # residuals must be jax types: carry the dtype via a zero-size array
    return x, jnp.zeros((0,), x.dtype)


def _gdg_bwd(token, g):
    return (g.astype(token.dtype),)


grad_dtype_guard.defvjp(_gdg_fwd, _gdg_bwd)


def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "squared_relu":        # nemotron-4 (arXiv:2402.16819)
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "relu":
        return jax.nn.relu
    raise ValueError(f"unknown activation {name!r}")


# --------------------------------------------------------------------------
# positions
# --------------------------------------------------------------------------


def rope_tables(positions: jax.Array, dim: int,
                theta: float = 1e6) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin tables (..., dim/2), fp32."""
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, D); cos/sin (S, D/2) or broadcastable."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:  # (S, D/2) -> broadcast over heads
        cos = cos[:, None, :]
        sin = sin[:, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, dim: int, offset=0) -> jax.Array:
    """offset may be a traced scalar (decode position)."""
    pos = jnp.arange(seq, dtype=jnp.float32) + offset
    freqs = jnp.exp(
        -math.log(10000.0) * jnp.arange(0, dim, 2, dtype=jnp.float32)
        / max(dim - 2, 1))
    ang = pos[:, None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :dim]


# --------------------------------------------------------------------------
# attention (GQA, chunked-flash at XLA level)
# --------------------------------------------------------------------------


def repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, KV, D) -> (B, S, KV*groups, D)."""
    if groups == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :],
                            (b, s, kv, groups, d)).reshape(b, s, kv * groups, d)


NEG_INF_ATTN = -1e30


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0,
              chunk_q: int = 1024, q_offset: int = 0) -> jax.Array:
    """Multi-head attention, (B, S, H, D) layout, GQA-grouped k/v.

    Flash schedule at the XLA level: an online-softmax ``lax.scan`` over KV
    chunks with (m, l, acc) carries.  The query tensor is never reshaped or
    chunked, so a sequence-sharded q (context parallelism) stays sharded —
    each device computes attention for its own q slice against replicated
    KV chunks — and peak memory is O(B*H*Sq_local*chunk) instead of
    O(B*H*S^2).  k/v arrive with KV heads (pre-GQA-expansion); the grouped
    einsum avoids materialising repeated KV.
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    chunk = min(chunk_q, sk)
    qg = q.reshape(b, sq, kvh, g, d)
    rows = q_offset + jax.lax.broadcasted_iota(jnp.int32, (sq, 1), 0)

    def block_scores(kb, col0, ck):
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (sq, ck), 1)
        mask = cols < sk                       # pad columns are invalid
        if causal:
            mask &= rows >= cols
        if window and window > 0:
            mask &= cols > rows - window
        return jnp.where(mask[None, None, None], s, -1e30)

    if sk <= chunk:
        s = block_scores(k, 0, sk)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqc,bckd->bqkgd", p.astype(v.dtype), v)
        return out.reshape(b, sq, h, d)

    nc = -(-sk // chunk)
    pad = nc * chunk - sk
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    ks = k.reshape(b, nc, chunk, kvh, d).swapaxes(0, 1)
    vs = v.reshape(b, nc, chunk, kvh, d).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        m, l, acc = carry
        ci, kb, vb = xs
        s = block_scores(kb, ci * chunk, chunk)     # (b,kv,g,sq,chunk)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, vb.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, kvh, g, sq, 1), NEG_INF_ATTN, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(nc), ks, vs))
    out = acc / jnp.maximum(l, 1e-30)
    # (b, kv, g, sq, d) -> (b, sq, h, d)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length) -> jax.Array:
    """Single-token decode: q (B, 1, H, D) vs cache (B, S, H, D).

    ``length`` masks the not-yet-written tail of the cache (int or (B,)
    array of valid lengths).
    """
    b, s, h, d = k_cache.shape
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k_cache,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(s)
    if isinstance(length, int):
        valid = pos < length
        logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    else:
        valid = pos[None, :] < length[:, None]
        logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", p.astype(v_cache.dtype), v_cache)
    return out


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def trunc_normal(key, shape, dtype, std: float = 0.02):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                              jnp.float32)).astype(dtype)


def scaled_init_std(fan_in: int) -> float:
    return 1.0 / math.sqrt(fan_in)
