"""Expert-parallel MoE via shard_map — the beyond-GSPMD schedule.

GSPMD partitions the scatter-based dispatch poorly: the flat (T*k, d)
gather/scatter tensors pick up a model-axis sharding on d and generate
repeated fp32 all-reduces (measured: 65% of the baseline collective term
for qwen3-moe train_4k; see EXPERIMENTS.md §Perf cell B).

This implementation takes manual control with shard_map:

  * tokens are data-parallel (replicated across `model`), so every model
    rank sees the same local tokens and routing — no token exchange at all;
  * each model rank owns E/16 experts and builds its own (e_loc, C, d)
    dispatch buffer with a purely LOCAL scatter (no GSPMD involvement);
  * expert GEMMs run on the local expert shard (weights enter with
    P(model, ...) specs — the FSDP'd dims are all-gathered by jit at the
    boundary, once per layer);
  * one psum over `model` combines the per-rank partial outputs.

Collectives per layer: exactly one bf16/f32 psum of the (T_loc, d) output
(+ the usual FSDP weight gathers) — versus GSPMD's five+ fp32 flat-tensor
all-reduces.  This is the CMM node-level-cache insight in SPMD form: keep
the tokens resident, move only the small thing (expert outputs), never
re-send what a rank already has.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from jax import shard_map

from .moe import load_balance_loss, router_topk


def moe_ffn_ep(x: jax.Array, params: dict, *, top_k: int,
               capacity_factor: float, act, mesh: Mesh,
               batch_axes: Tuple[str, ...] = ("pod", "data"),
               model_axis: str = "model"
               ) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (y, aux).  params as in moe.moe_ffn."""
    e = params["router"].shape[-1]
    baxes = tuple(a for a in batch_axes if a in mesh.shape)
    n_model = mesh.shape[model_axis]
    e_loc = e // n_model
    assert e_loc * n_model == e, (e, n_model)

    def inner(xl, router, w1, w3, w2):
        # xl (B_loc, S, D); router (D, E) full; w1/w3 (e_loc, D, F);
        # w2 (e_loc, F, D)
        rank = jax.lax.axis_index(model_axis)
        b, s, d = xl.shape
        t = b * s
        xf = xl.reshape(t, d)
        logits = jnp.einsum("td,de->te", xf, router,
                            preferred_element_type=jnp.float32)
        gates, idx = router_topk(logits, top_k)          # (t, k) fp32
        aux = load_balance_loss(logits, idx, e)

        cap = int(max(t * top_k * capacity_factor / e, 4.0))
        # which routing choices belong to THIS rank's experts
        lidx = idx - rank * e_loc                        # (t, k)
        local = (lidx >= 0) & (lidx < e_loc)
        lidx_c = jnp.clip(lidx, 0, e_loc - 1)
        # position within the local expert's capacity buffer
        onehot = (jax.nn.one_hot(lidx_c, e_loc, dtype=jnp.int32)
                  * local.astype(jnp.int32)[..., None])  # (t, k, e_loc)
        flat = onehot.reshape(t * top_k, e_loc)
        pos = jnp.cumsum(flat, axis=0) * flat - 1
        pos_in_e = pos.max(axis=-1).reshape(t, top_k)
        keep = local & (pos_in_e < cap) & (pos_in_e >= 0)
        gates_l = gates * keep

        # LOCAL scatter into (e_loc * cap, d)
        tok_idx = jnp.broadcast_to(jnp.arange(t)[:, None], (t, top_k))
        scat = (lidx_c * cap + jnp.clip(pos_in_e, 0, cap - 1)).reshape(-1)
        disp = jnp.zeros((e_loc * cap, d), xl.dtype).at[scat].add(
            xf[tok_idx.reshape(-1)]
            * keep.reshape(-1, 1).astype(xl.dtype),
            mode="drop").reshape(e_loc, cap, d)

        h1 = jnp.einsum("ecd,edf->ecf", disp, w1)
        if w3 is not None:
            h = act(h1) * jnp.einsum("ecd,edf->ecf", disp, w3)
        else:
            h = act(h1)
        y_e = jnp.einsum("ecf,efd->ecd", h, w2)          # (e_loc, C, D)

        # local combine, then one psum across expert ranks
        y_flat = y_e.reshape(e_loc * cap, d)[scat]       # (t*k, D)
        y = (y_flat.reshape(t, top_k, d)
             * gates_l[..., None].astype(xl.dtype)).sum(axis=1)
        y = jax.lax.psum(y, model_axis)
        aux = jax.lax.pmean(aux, baxes) if baxes else aux
        return y.reshape(b, s, d), aux

    w3 = params.get("w3")
    fn = shard_map(
        inner, mesh=mesh,
        in_specs=(P(baxes if baxes else None, None, None),
                  P(None, None),
                  P(model_axis, None, None),
                  (P(model_axis, None, None) if w3 is not None else None),
                  P(model_axis, None, None)),
        out_specs=(P(baxes if baxes else None, None, None), P()),
        check_vma=False,
    )
    return fn(x, params["router"], params["w1"], w3, params["w2"])
