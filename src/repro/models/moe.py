"""Mixture-of-Experts layer: top-k token-choice routing with capacity,
einsum dispatch/combine (GSPMD-friendly — experts shard over the ``model``
mesh axis, tokens over ``data``; the dispatch einsum lowers to an
all-to-all on TPU).

Capacity C = ceil(tokens * top_k * capacity_factor / E); overflow tokens are
dropped (their gate mass is lost, standard Switch/GShard semantics).  An
auxiliary load-balancing loss (Switch-style) is returned alongside.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def router_topk(logits: jax.Array, top_k: int
                ) -> Tuple[jax.Array, jax.Array]:
    """logits (T, E) -> gates (T, k) fp32 (softmax over chosen k, Qwen-MoE
    style norm_topk_prob), indices (T, k)."""
    gates_full = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(gates_full, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def load_balance_loss(logits: jax.Array, idx: jax.Array,
                      n_experts: int) -> jax.Array:
    """Switch aux loss: E * sum_e f_e * p_e (fp32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # (T, E)
    counts = jnp.zeros((n_experts,), jnp.float32)
    onehot = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32)   # (T,k,E)
    f = onehot.sum((0, 1)) / jnp.maximum(idx.shape[0] * idx.shape[1], 1)
    p = probs.mean(0)
    return n_experts * jnp.sum(f * p)


def moe_ffn(x: jax.Array, params: dict, *, top_k: int,
            capacity_factor: float = 1.25, act=jax.nn.silu,
            constrain=None) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (y (B, S, D), aux_loss).

    params: router (D, E), w1/w3 (E, D, F), w2 (E, F, D).
    ``constrain(tensor, logical_axes)`` (optional) pins the expert buffers
    to the `experts` mesh axis so the dispatch lowers to an all-to-all
    instead of whatever GSPMD guesses for the scatter.
    """
    b, s, d = x.shape
    e = params["router"].shape[-1]
    t = b * s
    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf, params["router"],
                        preferred_element_type=jnp.float32)
    gates, idx = router_topk(logits, top_k)          # (T,k)
    aux = load_balance_loss(logits, idx, e)

    cap = int(max(top_k * t * capacity_factor / e, 4.0))
    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)         # (T, k, E)
    flat = onehot.reshape(t * top_k, e)
    pos = jnp.cumsum(flat, axis=0) * flat - 1                # (T*k, E)
    pos_in_e = pos.max(axis=-1).reshape(t, top_k)            # (T, k)
    keep = (pos_in_e < cap) & (pos_in_e >= 0)
    gates = gates * keep

    # dispatch: (E, C, D) buffers built per routing choice (k is tiny)
    disp = jnp.zeros((e, cap, d), x.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(t)[:, None], (t, top_k))
    scat = (idx * cap + jnp.clip(pos_in_e, 0, cap - 1)).reshape(-1)
    disp = disp.reshape(e * cap, d).at[scat].add(
        (xf[tok_idx.reshape(-1)] * keep.reshape(-1, 1).astype(x.dtype)),
        mode="drop").reshape(e, cap, d)
    if constrain is not None:
        disp = constrain(disp, ("experts", None, None))

    h1 = jnp.einsum("ecd,edf->ecf", disp, params["w1"])
    if "w3" in params and params["w3"] is not None:
        h = act(h1) * jnp.einsum("ecd,edf->ecf", disp, params["w3"])
    else:
        h = act(h1)
    if constrain is not None:
        h = constrain(h, ("experts", None, "expert_ff"))
    y_e = jnp.einsum("ecf,efd->ecd", h, params["w2"])        # (E, C, D)
    if constrain is not None:
        y_e = constrain(y_e, ("experts", None, None))

    # combine: gather each kept choice back and weight by its gate
    y_flat = y_e.reshape(e * cap, d)[scat]                   # (T*k, D)
    y = (y_flat.reshape(t, top_k, d)
         * gates[..., None].astype(x.dtype)).sum(axis=1)
    return y.reshape(b, s, d), aux
