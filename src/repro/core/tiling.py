"""Automatic tiling: expression DAG -> tiled task graph (CMM §3.2, Listing 1).

A single tile size ``t`` (or ``(tm, tn)`` tuple) is applied to every matrix in
the expression, exactly like the paper (10 k matrices, 5 k tiles -> 2x2 grid;
edge tiles are ragged via ``min`` bounds as in Listing 1).  The expression DAG
is expanded node-by-node into per-tile tasks while preserving the task
dependencies; tiled matmul introduces the ``calloc`` + ``addmul``-chain
structure of Fig. 2.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .graph import (Task, TaskGraph, TaskKind, TileRef, matmul_epilogue,
                    matmul_flags)
from .lazy import ClusteredMatrix, Op, topo_order, topo_order_many


def cld(a: int, b: int) -> int:
    """Ceiling division (Julia's ``cld`` used in Listing 1)."""
    return -(-a // b)


def tile_slices(dim: int, tile: int) -> List[Tuple[int, int]]:
    """Listing 1 row/col bounds: [(start, end)] with ragged final tile."""
    n = cld(dim, tile)
    return [(tile * i, min(tile * (i + 1), dim)) for i in range(n)]


def grid_of(shape: Tuple[int, int], tile: Tuple[int, int]) -> Tuple[int, int]:
    return (cld(shape[0], tile[0]), cld(shape[1], tile[1]))


def tile_shape(shape: Tuple[int, int], tile: Tuple[int, int],
               i: int, j: int) -> Tuple[int, int]:
    rs = tile_slices(shape[0], tile[0])[i]
    cs = tile_slices(shape[1], tile[1])[j]
    return (rs[1] - rs[0], cs[1] - cs[0])


def normalize_tile(tile) -> Tuple[int, int]:
    if isinstance(tile, int):
        return (tile, tile)
    tm, tn = tile
    return (int(tm), int(tn))


@dataclass
class ResultSet:
    """One root's output tiles in the (possibly multi-root) tiled program.

    ``gather=True`` roots get TAKECOPY tasks and are assembled on the
    master; ``gather=False`` roots are session-persisted — their tiles
    stay in the executor arenas (``producers`` maps each tile to the task
    that writes its final value, whose placement is the tile's home)."""

    uid: int                              # root expr-node uid
    index: int                            # position in the roots list
    shape: Tuple[int, int]
    grid: Tuple[int, int]
    tiles: List[TileRef] = field(default_factory=list)
    producers: Dict[TileRef, int] = field(default_factory=dict)
    gather: bool = True


class TiledProgram:
    """Result of tiling: the task graph plus tile bookkeeping for execution."""

    def __init__(self, graph: TaskGraph, tile: Tuple[int, int],
                 root: ClusteredMatrix,
                 leaf_nodes: Dict[int, ClusteredMatrix],
                 dtypes: Optional[Dict[int, "object"]] = None,
                 roots: Optional[Sequence[ClusteredMatrix]] = None):
        self.graph = graph
        self.tile = tile
        self.root = root
        #: every root of the (multi-root) program, in caller order
        self.roots = list(roots) if roots is not None else [root]
        #: expr-node uid -> leaf ClusteredMatrix (for FILL materialisation)
        self.leaf_nodes = leaf_nodes
        #: expr-node uid -> np.dtype (CALLOC must allocate in the expression
        #: dtype, not float64)
        self.dtypes = dtypes or {}
        #: canonical leaf-uid order (plan-cache leaf rebinding contract)
        self.leaf_order = list(leaf_nodes)

    def rebound(self, new_leaves) -> "TiledProgram":
        """A shallow copy with FILL leaves rebound to ``new_leaves`` (same
        canonical order) — how a plan-cache hit serves a structurally equal
        DAG holding different data."""
        if len(new_leaves) != len(self.leaf_order):
            raise ValueError("leaf count mismatch on plan-cache rebind")
        leaf_nodes = dict(zip(self.leaf_order, new_leaves))
        p = TiledProgram(self.graph, self.tile, self.root, leaf_nodes,
                         self.dtypes, roots=self.roots)
        p.leaf_order = list(self.leaf_order)
        return p


def tile_expression(root: ClusteredMatrix, tile) -> TiledProgram:
    """Expand one expression DAG into a tiled TaskGraph (single-root
    wrapper over :func:`tile_expression_many`)."""
    return tile_expression_many((root,), tile)


def tile_expression_many(roots: Sequence[ClusteredMatrix], tile,
                         persist_idx: frozenset = frozenset()
                         ) -> TiledProgram:
    """Expand one or more expression DAGs into ONE tiled TaskGraph.

    Per node we keep ``producer[(i, j)]`` — the task id that last wrote tile
    ``(i, j)`` of that node's output — so consumers depend on exactly the
    right task (for matmul that is the *last* addmul of the k-chain).

    Roots whose *position* is in ``persist_idx`` are session-persisted:
    they get NO takecopy tasks — their tiles stay wherever their final
    producers ran (the ``ResultSet.producers`` map records which task that
    is per tile).  RESIDENT leaves expand to one zero-cost RESIDENT task
    per tile instead of FILLs: the tile is already bound in an executor
    arena and just re-enters this run's buffer namespace.
    """
    t = normalize_tile(tile)
    g = TaskGraph()
    # node uid -> {(i,j): (TileRef, producer_tid)}
    tiles: Dict[int, Dict[Tuple[int, int], Tuple[TileRef, int]]] = {}
    leaf_nodes: Dict[int, ClusteredMatrix] = {}
    dtypes: Dict[int, "object"] = {}

    def ref(node: ClusteredMatrix, i: int, j: int) -> TileRef:
        return TileRef(node.uid, i, j, tile_shape(node.shape, t, i, j))

    for node in topo_order_many(roots):
        gm, gn = grid_of(node.shape, t)
        entry: Dict[Tuple[int, int], Tuple[TileRef, int]] = {}
        dtypes[node.uid] = node.dtype

        if node.op is Op.RESIDENT:
            h = node.payload
            if h is None or tuple(h.tile) != t:
                raise ValueError(
                    f"resident leaf #{node.uid} holds tiles of size "
                    f"{None if h is None else h.tile}, but this program "
                    f"tiles at {t}; gather + re-ingest (the session does "
                    f"this automatically) or re-plan at the handle's tile")
            leaf_nodes[node.uid] = node
            for i in range(gm):
                for j in range(gn):
                    r = ref(node, i, j)
                    task = g.add(TaskKind.RESIDENT, (), r, payload=node.uid)
                    entry[(i, j)] = (r, task.tid)

        elif node.op in (Op.INPUT, Op.RANDOM, Op.ZEROS, Op.EYE):
            leaf_nodes[node.uid] = node
            for i in range(gm):
                for j in range(gn):
                    r = ref(node, i, j)
                    # fill = data materialisation for an input tile; the
                    # engine/scheduler delays it until just before first use
                    # (§3.3) — structurally it is a source task.
                    task = g.add(TaskKind.FILL, (), r, payload=node.uid)
                    entry[(i, j)] = (r, task.tid)

        elif node.op is Op.MATMUL:
            a, b = node.parents[:2]
            extras = node.parents[2:]      # epilogue operands
            epi = matmul_epilogue(node.payload)
            ga = tiles[a.uid]
            gb = tiles[b.uid]
            # transposed-operand flags folded in by the fusion optimizer:
            # operand tiles are indexed through the transpose instead of a
            # materialised TRANSPOSE pass (requires a square tile for ragged
            # grids to line up; the engine guarantees that)
            ta, tb = matmul_flags(node.payload)
            if (ta or tb) and t[0] != t[1]:
                raise ValueError("transposed matmul needs a square tile")
            # the inner dimension is tiled by tn on A but by tm on B; a
            # non-square tile misaligns the k-chains (silent wrong results)
            # unless the inner dim fits in a single tile both ways
            n_inner = a.shape[0] if ta else a.shape[1]
            if t[0] != t[1] and max(cld(n_inner, t[0]),
                                    cld(n_inner, t[1])) > 1:
                raise ValueError(
                    f"MATMUL inner dim {n_inner} needs a square tile, "
                    f"got {t}; use an int tile size")
            kt = grid_of(a.shape, t)[0 if ta else 1]  # inner tile count
            flags = (ta, tb) if ta or tb else None
            if epi is not None:
                # the k-chain accumulates in the *matmul* dtype; the
                # epilogue's own output dtype emerges when the last chain
                # task rebinds the tile (bit-identity with the unfused
                # CALLOC-in-matmul-dtype + separate-FUSED-task path)
                import numpy as _np
                dtypes[node.uid] = _np.promote_types(a.dtype, b.dtype)
            for i in range(gm):
                for j in range(gn):
                    r = ref(node, i, j)
                    calloc = g.add(TaskKind.CALLOC, (), r, payload=node.uid)
                    prev = calloc.tid
                    for k in range(kt):
                        ra, pa = ga[(k, i) if ta else (i, k)]
                        rb, pb = gb[(j, k) if tb else (k, j)]
                        m_ = ra.shape[1] if ta else ra.shape[0]
                        n_ = ra.shape[0] if ta else ra.shape[1]
                        k_ = rb.shape[0] if tb else rb.shape[1]
                        ins = (ra, rb)
                        deps = (prev, pa, pb)
                        payload = flags
                        flops = 2 * m_ * n_ * k_
                        if epi is not None and k == kt - 1:
                            # the LAST chain task applies the epilogue to
                            # the accumulated C tile in the same pass: its
                            # extra ins are the (i, j) tiles of the
                            # epilogue operands, its flops include the
                            # elementwise work (priced into ADDMUL)
                            from .fusion import fused_flops
                            eins = [tiles[e.uid][(i, j)] for e in extras]
                            ins += tuple(er for er, _ in eins)
                            deps += tuple(ep for _, ep in eins)
                            payload = node.payload
                            flops += fused_flops(epi, *r.shape)
                        task = g.add(TaskKind.ADDMUL, ins, r,
                                     payload=payload, flops=flops,
                                     deps=deps)
                        prev = task.tid
                    entry[(i, j)] = (r, prev)

        elif node.op in (Op.ADD, Op.SUB, Op.EWMUL):
            kind = {Op.ADD: TaskKind.ADD, Op.SUB: TaskKind.SUB,
                    Op.EWMUL: TaskKind.EWMUL}[node.op]
            a, b = node.parents
            for i in range(gm):
                for j in range(gn):
                    ra, pa = tiles[a.uid][(i, j)]
                    rb, pb = tiles[b.uid][(i, j)]
                    r = ref(node, i, j)
                    m_, n_ = r.shape
                    task = g.add(kind, (ra, rb), r, flops=m_ * n_,
                                 deps=(pa, pb))
                    entry[(i, j)] = (r, task.tid)

        elif node.op is Op.SCALE:
            (kindstr, s) = node.payload
            a = node.parents[0]
            for i in range(gm):
                for j in range(gn):
                    ra, pa = tiles[a.uid][(i, j)]
                    r = ref(node, i, j)
                    task = g.add(TaskKind.SCALE, (ra,), r,
                                 payload=(kindstr, s),
                                 flops=r.shape[0] * r.shape[1], deps=(pa,))
                    entry[(i, j)] = (r, task.tid)

        elif node.op is Op.EWISE:
            a = node.parents[0]
            for i in range(gm):
                for j in range(gn):
                    ra, pa = tiles[a.uid][(i, j)]
                    r = ref(node, i, j)
                    task = g.add(TaskKind.EWISE, (ra,), r, payload=node.payload,
                                 flops=4 * r.shape[0] * r.shape[1], deps=(pa,))
                    entry[(i, j)] = (r, task.tid)

        elif node.op is Op.FUSED:
            # one task per tile for the whole elementwise region: inputs are
            # the (i, j) tiles of every external parent
            from .fusion import fused_flops
            for i in range(gm):
                for j in range(gn):
                    ins, deps = [], []
                    for p in node.parents:
                        rp, pp = tiles[p.uid][(i, j)]
                        ins.append(rp)
                        deps.append(pp)
                    r = ref(node, i, j)
                    task = g.add(TaskKind.FUSED, ins, r, payload=node.payload,
                                 flops=fused_flops(node.payload, *r.shape),
                                 deps=deps)
                    entry[(i, j)] = (r, task.tid)

        elif node.op is Op.TRANSPOSE:
            # tile (i, j) of the transpose is the transpose of parent tile
            # (j, i) — which only lines up when the tile is square (the
            # single-tile-size design; ragged edges break otherwise)
            if t[0] != t[1]:
                raise ValueError(
                    f"TRANSPOSE needs a square tile, got {t}; "
                    f"use an int tile size")
            a = node.parents[0]
            for i in range(gm):
                for j in range(gn):
                    ra, pa = tiles[a.uid][(j, i)]
                    r = ref(node, i, j)
                    task = g.add(TaskKind.TRANSPOSE, (ra,), r,
                                 flops=r.shape[0] * r.shape[1], deps=(pa,))
                    entry[(i, j)] = (r, task.tid)

        else:  # pragma: no cover
            raise ValueError(node.op)

        tiles[node.uid] = entry

    # takecopy: gather every result tile of a non-persisted root to the
    # master node.  Each takecopy depends only on its own producer chain
    # (§3.3 optimisation: originally serialised behind *all* jobs; CMM made
    # it depend only on its subtree).  Persisted roots skip the gather —
    # their tiles are retained in place by the executor.
    g.result_sets = []
    for idx, root in enumerate(roots):
        gm, gn = grid_of(root.shape, t)
        rs = ResultSet(root.uid, idx, root.shape, (gm, gn),
                       gather=idx not in persist_idx)
        for i in range(gm):
            for j in range(gn):
                r, p = tiles[root.uid][(i, j)]
                rs.tiles.append(r)
                rs.producers[r] = p
                if rs.gather:
                    g.add(TaskKind.TAKECOPY, (r,), r, deps=(p,))
        g.result_sets.append(rs)
    # backward-compatible single-root view: the first gathered root
    first = next((rs for rs in g.result_sets if rs.gather),
                 g.result_sets[0] if g.result_sets else None)
    if first is not None:
        g.result_tiles = list(first.tiles)
        g.result_grid = first.grid
        g.result_shape = first.shape
    return TiledProgram(g, t, roots[0], leaf_nodes, dtypes, roots=roots)


def result_sets_of(g) -> List[ResultSet]:
    """The graph's per-root output sets, synthesizing the legacy single
    ``result_tiles`` view for hand-built graphs (tests, benchmarks)."""
    rs = getattr(g, "result_sets", None)
    if rs:
        return rs
    tiles = list(g.result_tiles)
    uid = tiles[0].tensor if tiles else -1
    return [ResultSet(uid, 0, g.result_shape, g.result_grid, tiles,
                      {}, True)]


def assemble(tile_values: Dict[TileRef, "object"],
             shape: Tuple[int, int], tile: Tuple[int, int],
             tensor_uid: int):
    """Reassemble a full matrix from its tile values (inverse of tiling)."""
    import numpy as np

    rows = tile_slices(shape[0], tile[0])
    cols = tile_slices(shape[1], tile[1])
    first = next(iter(tile_values.values()))
    out = np.empty(shape, dtype=np.asarray(first).dtype)
    for i, (r0, r1) in enumerate(rows):
        for j, (c0, c1) in enumerate(cols):
            key = TileRef(tensor_uid, i, j, (r1 - r0, c1 - c0))
            out[r0:r1, c0:c1] = np.asarray(tile_values[key])
    return out
