"""CMM core: the paper's contribution as a composable library."""
from .lazy import ClusteredMatrix, Op, eager_eval, topo_order  # noqa: F401
from .graph import Task, TaskGraph, TaskKind, TileRef          # noqa: F401
from .tiling import tile_expression, TiledProgram              # noqa: F401
from .machine import ClusterSpec, c5_9xlarge, tpu_v5e_pod      # noqa: F401
from .timemodel import (TimeModel, PolyModel, CostCache,       # noqa: F401
                        analytic_time_model)
from .profiler import profile_machine                          # noqa: F401
from .cache import NodeCache                                   # noqa: F401
from .heft import heft_schedule, Schedule                      # noqa: F401
from .simulator import simulate, SimResult                     # noqa: F401
from .engine import CMMEngine, Plan                            # noqa: F401
from .session import (CMMSession, ResidentHandle,              # noqa: F401
                      ResidentMatrix, ResidentTilesLost)
from .fusion import (FusionReport, eval_fused, optimize,       # noqa: F401
                     optimize_many, structural_signature)
from .autotune import tune_tile, argmin_search, tile_candidates  # noqa: F401
