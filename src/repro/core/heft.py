"""Cache-aware modified HEFT scheduler (CMM §3.6).

Two phases, as in the original HEFT:

1. *Ranking* — tasks are recursively ranked by upward rank
   ``rank_u(t) = w_avg(t) + max_succ (c_avg(t, s) + rank_u(s))`` using the
   profiled time model for ``w`` and the per-pair link model for ``c``.
2. *Placement* — in decreasing rank order, each task is assigned to the
   (node, worker-process) slot with the earliest finish time, with an
   insertion policy over per-slot busy intervals.

CMM modifications implemented here:

* **node-level cache** (§3.5): the communication cost of an edge is zero when
  the consumer's node already holds that tile version; the cache is updated
  *during* scheduling, so later placement decisions see earlier transfers.
* **per-pair connection speeds** (§3.4): comm costs come from
  ``spec.bandwidth(a, b)``.
* **pinning**: ``takecopy`` runs on the master; ``fill`` of user-supplied
  (INPUT) data originates on the master (the initial master->worker comm
  phase visible in Fig. 3); generated data (RANDOM/ZEROS/EYE) fills locally
  on whichever node the scheduler picks (§3.3 optimisation).
* ``calloc`` is free-placed and cheap (async in the engine; §3.3).

Planning fast path (default, ``fast=True``): task compute times are
memoized per unique ``(kind, operand-dims, payload-class, node)`` signature
(a tiled program has a handful of tile shapes but 10k+ tasks), the upward
rank is computed over those deduplicated costs, and each worker-slot
timeline stores its *free gaps* instead of busy intervals so the insertion
policy stops scanning O(placed tasks) per query.  Both representations are
exact — ``fast=False`` (the pre-optimization baseline, kept for plan-time
benchmarking) produces bit-identical schedules.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

from .cache import NodeCache
from .graph import Task, TaskGraph, TaskKind
from .machine import ClusterSpec
from .timemodel import CostCache, TimeModel


@dataclass
class Placement:
    node: int
    slot: int
    start: float
    finish: float


@dataclass
class CommEvent:
    """A cross-node transfer committed during scheduling."""

    src_task: int
    dst_task: int
    src: int
    dst: int
    nbytes: int
    cached: bool  # True -> satisfied by node-level cache (no transfer)


@dataclass
class Schedule:
    placements: Dict[int, Placement]
    order: List[int]                      # rank order (scheduling priority)
    comms: List[CommEvent]
    makespan: float
    cache_hits: int
    cache_misses: int

    def node_of(self, tid: int) -> int:
        return self.placements[tid].node

    def node_tasks(self) -> Dict[int, List[int]]:
        """Per-node task ids in scheduled start order — the per-node
        dispatch queues a distributed executor replays."""
        by_node: Dict[int, List[int]] = {}
        for tid in sorted(self.placements,
                          key=lambda t: (self.placements[t].start, t)):
            by_node.setdefault(self.placements[tid].node, []).append(tid)
        return by_node

    def xfers(self, g: "TaskGraph") -> List[Tuple[int, int, int, int]]:
        """The schedule's cross-node data movements, as concrete executor
        endpoints: deduplicated ``(producer tid, src node, dst node,
        nbytes)`` tuples, one per tile *version* arriving at a node (later
        consumers of the same version on that node hit the node-level
        cache, §3.5).  Derived from placements + graph edges, so it is
        authoritative even for the regenerated-fill clones the scheduler
        splices in."""
        out: List[Tuple[int, int, int, int]] = []
        seen = set()
        for tid in sorted(self.placements):
            t = g.tasks[tid]
            src = self.placements[tid].node
            for s in sorted(t.succs):
                if s not in self.placements:
                    continue
                nbytes = edge_bytes(g, t, g.tasks[s])
                dst = self.placements[s].node
                if nbytes and dst != src and (tid, dst) not in seen:
                    seen.add((tid, dst))
                    out.append((tid, src, dst, nbytes))
        return out

    def xfer_index(self, g: "TaskGraph"
                   ) -> Dict[Tuple[int, int], Tuple[int, int]]:
        """:meth:`xfers` as a join table: ``(producer tid, dst node) ->
        (src node, nbytes)``.  This is the oracle measured XFER spans
        are matched against — the flight-recorder tests assert one XFER
        span per entry, and the drift report uses it to attribute a
        span's bytes to the planned edge."""
        return {(tid, dst): (src, nbytes)
                for (tid, src, dst, nbytes) in self.xfers(g)}


def edge_bytes(g: TaskGraph, u: Task, v: Task) -> int:
    """Bytes flowing along dependency edge u->v.

    u's output tile is data for v if v reads it (in ``v.ins``) or if v
    accumulates into the same tile (addmul chains share ``out``).  Pure
    ordering edges carry no data.
    """
    if u.out is None:
        return 0
    if u.out in v.ins:
        return u.out.bytes
    if v.out is not None and u.out == v.out:
        return u.out.bytes
    return 0


def _avg_comm(nbytes: int, spec: ClusterSpec,
              tm: Optional[TimeModel] = None) -> float:
    if spec.n_nodes <= 1 or nbytes == 0:
        return 0.0
    frac = (spec.n_nodes - 1) / spec.n_nodes
    dst = 1 if spec.n_nodes > 1 else 0
    if tm is not None:
        # codec-aware edge pricing (identical to spec.comm_time while the
        # TimeModel's compression priors are unfitted)
        return frac * tm.wire_time(nbytes, 0, dst, spec)
    return frac * spec.comm_time(nbytes, 0, dst)


class DirectCost:
    """Unmemoized cost lookups — the pre-fast-path baseline semantics.
    Same interface as :class:`~repro.core.timemodel.CostCache`."""

    __slots__ = ("tm", "spec")

    def __init__(self, tm: TimeModel, spec: ClusterSpec):
        self.tm = tm
        self.spec = spec

    def time(self, task: Task, node: int = 0) -> float:
        return self.tm.compute_time(task, self.spec, node)

    def kernel(self, task: Task, node: int = 0) -> float:
        return self.tm.kernel_time(task, self.spec, node)

    def avg(self, task: Task) -> float:
        costs = [self.time(task, n) for n in range(self.spec.n_nodes)]
        return sum(costs) / len(costs)


def upward_rank(g: TaskGraph, spec: ClusterSpec, tm: TimeModel,
                cost=None) -> Dict[int, float]:
    """Upward ranks under ``tm``.

    ``cost`` (a :class:`~repro.core.timemodel.CostCache` or ``DirectCost``)
    supplies ``avg(task)``; the default memoizes per unique task signature,
    which turns the O(V x nodes) polynomial evaluations of the naive loop
    into O(unique tile shapes x nodes) — the fast-path win for big graphs.
    Ranks are bit-identical either way.
    """
    cost = cost if cost is not None else CostCache(tm, spec)
    rank: Dict[int, float] = {}
    w: Dict[int, float] = {}
    for t in g:
        if t.kind in (TaskKind.CALLOC, TaskKind.RESIDENT):
            w[t.tid] = 1e-6  # async / already-resident, near-free (§3.3)
        else:
            w[t.tid] = cost.avg(t)
    comm_memo: Dict[int, float] = {}
    for t in reversed(g.topo()):
        best = 0.0
        for s in t.succs:
            st = g.tasks[s]
            nb = edge_bytes(g, t, st)
            c = comm_memo.get(nb)
            if c is None:
                c = _avg_comm(nb, spec, tm)
                comm_memo[nb] = c
            cr = c + rank[s]
            if cr > best:
                best = cr
        rank[t.tid] = w[t.tid] + best
    return rank


class _SlotTimeline:
    """Busy intervals of one worker-process slot, for insertion policy.

    Legacy representation (``fast=False``): a sorted busy-interval list that
    ``earliest`` scans front-to-back — O(placed tasks) per query.
    """

    __slots__ = ("iv",)

    def __init__(self):
        self.iv: List[Tuple[float, float]] = []

    def earliest(self, ready: float, dur: float) -> float:
        t = ready
        for (s, e) in self.iv:
            if t + dur <= s:
                break
            t = max(t, e)
        return t

    def insert(self, start: float, dur: float):
        import bisect
        bisect.insort(self.iv, (start, start + dur))


class _GapTimeline:
    """One worker slot stored as its FREE gaps plus the free tail.

    Exact complement of ``_SlotTimeline``: ``earliest``/``insert`` return
    bit-identical results, but queries bisect into the (short, sorted) gap
    list instead of scanning every placed interval, and tail appends are
    O(1).  This is what lets HEFT placement scale to 100k-task graphs.
    """

    __slots__ = ("gs", "ge", "tail")

    def __init__(self):
        #: parallel sorted arrays: free gap i is [gs[i], ge[i]), all < tail
        self.gs: List[float] = []
        self.ge: List[float] = []
        #: everything from here on is free
        self.tail = 0.0

    def earliest(self, ready: float, dur: float) -> float:
        import bisect
        ge = self.ge
        i = bisect.bisect_right(ge, ready)   # first gap ending after `ready`
        gs = self.gs
        for i in range(i, len(gs)):
            t = gs[i] if gs[i] >= ready else ready
            if t + dur <= ge[i]:
                return t
        return self.tail if self.tail >= ready else ready

    def insert(self, start: float, dur: float):
        import bisect
        end = start + dur
        if start >= self.tail:
            if start > self.tail:
                self.gs.append(self.tail)
                self.ge.append(start)
            self.tail = end
            return
        i = bisect.bisect_right(self.gs, start) - 1
        if i < 0 or end > self.ge[i]:
            raise ValueError(
                f"insert [{start}, {end}) overlaps busy time")
        gs, ge = self.gs[i], self.ge[i]
        if gs < start and end < ge:          # split the gap in two
            self.gs[i:i + 1] = [gs, end]
            self.ge[i:i + 1] = [start, ge]
        elif gs < start:                     # trim the gap's tail
            self.ge[i] = start
        elif end < ge:                       # trim the gap's head
            self.gs[i] = end
        else:                                # exact fill
            del self.gs[i]
            del self.ge[i]


def heft_schedule(g: TaskGraph, spec: ClusterSpec, tm: TimeModel,
                  cache: Optional[NodeCache] = None,
                  cache_aware: bool = True,
                  lazy_fill: bool = True,
                  fill_origin: Optional[Mapping[int, str]] = None,
                  fast: bool = True,
                  cost: Optional[CostCache] = None,
                  pinned: Optional[Mapping[int, int]] = None) -> Schedule:
    """Schedule ``g`` on ``spec`` under time model ``tm``.

    ``cache_aware=False`` disables the node-level-cache modification (the
    vanilla-HEFT ablation baseline).

    ``lazy_fill=True`` implements the paper's §3.3 optimisation: data fills
    of *generated* inputs are NOT ranked/placed independently (which
    scatters tiles across nodes and forces large transfers); instead a fill
    is placed on the node of its first-scheduled consumer, just before that
    consumer runs ("initialize the tiles when they are allocated to the
    respective nodes ... schedule the data fill only right before the first
    tasks are executed").  Later consumers on other nodes pay the normal
    (cache-aware) transfer.

    ``fill_origin`` maps leaf expression-node uid -> ``"master"`` |
    ``"local"`` (INPUT leaves live on the master; generated leaves fill in
    place).  Passing it explicitly keeps concurrent planners isolated; when
    omitted, the deprecated module-level registry set by
    ``register_fill_origin`` is consulted for backward compatibility.

    ``fast=False`` selects the unmemoized cost path and the busy-interval
    timelines — same schedule, pre-fast-path planning time (kept as the
    benchmarking baseline).  ``cost`` lets the caller share one
    :class:`CostCache` across scheduling and simulation.

    ``pinned`` maps task id -> node for location-pinned tasks (session
    RESIDENT tasks must run on the node whose arena holds their tile;
    consumers elsewhere pay the normal cache-aware transfer).

    NOTE: ``replan_frontier`` mirrors this function's EFT-insertion
    policy (tie-break epsilon, cache accounting, CALLOC duration) —
    keep the two in sync when changing placement rules.
    """
    origin = _FILL_ORIGIN if fill_origin is None else fill_origin
    pinned = pinned or {}
    if cost is None:
        cost = CostCache(tm, spec) if fast else DirectCost(tm, spec)
    rank = upward_rank(g, spec, tm, cost=cost)
    cache = cache if cache is not None else NodeCache(spec.n_nodes)

    def is_lazy(t: Task) -> bool:
        if not lazy_fill or t.kind is not TaskKind.FILL:
            return False
        return origin.get(t.payload) != "master"   # master INPUT stays pinned

    order_all = sorted(g.tasks, key=lambda tid: (-rank[tid], tid))
    order = [tid for tid in order_all if not is_lazy(g.tasks[tid])]

    timeline_cls = _GapTimeline if fast else _SlotTimeline
    slots = {n: [timeline_cls() for _ in range(spec.workers_at(n))]
             for n in range(spec.n_nodes)}
    placements: Dict[int, Placement] = {}
    comms: List[CommEvent] = []

    #: drained nodes (0 worker slots — evicted by the elastic runtime)
    #: never receive placements
    live_nodes = spec.alive_nodes()
    if not live_nodes:
        raise ValueError("cluster spec has no live nodes to schedule on")
    if spec.master not in live_nodes:
        raise ValueError("the master node is drained; cannot schedule")

    def allowed_nodes(t: Task) -> Sequence[int]:
        pin = pinned.get(t.tid)
        if pin is not None:
            if spec.workers_at(pin) <= 0:
                raise ValueError(
                    f"task {t.tid} ({t.kind.value}) is pinned to drained "
                    f"node {pin}")
            return (pin,)
        if t.kind is TaskKind.TAKECOPY:
            return (spec.master,)
        if t.kind is TaskKind.FILL and isinstance(t.payload, int):
            if origin.get(t.payload) == "master":
                return (spec.master,)
        return live_nodes

    #: node -> {fill duration: estimated EFT}; a fill EFT estimate only
    #: changes when the node's timelines change, and a wave of consumers
    #: probes the same few fill durations over and over.  Part of the fast
    #: path (disabled with it so ``fast=False`` stays the naive baseline).
    fill_est: Optional[Dict[int, Dict[float, float]]] = \
        {n: {} for n in range(spec.n_nodes)} if fast else None

    def commit(tid: int, node: int, si: int, st: float, eft: float,
               transfers) -> None:
        t = g.tasks[tid]
        slots[node][si].insert(st, eft - st)
        if fill_est is not None:
            fill_est[node].clear()
        placements[tid] = Placement(node, si, st, eft)
        for (p, src, nbytes, hit) in transfers:
            key = (p, g.tasks[p].out.tensor)
            comms.append(CommEvent(p, tid, src, node, nbytes, hit))
            if hit:
                cache.hits += 1
            else:
                cache.misses += 1
                if cache_aware:
                    cache.put(node, key, nbytes)
        if t.out is not None:
            cache.put(node, (tid, t.out.tensor), t.out.bytes)

    def place_fill_on(fid: int, node: int) -> float:
        """Place a lazy fill on `node` at its earliest slot; returns EFT."""
        ft = g.tasks[fid]
        dur = cost.time(ft, node)
        best = None
        for si, sl in enumerate(slots[node]):
            st = sl.earliest(0.0, dur)
            if best is None or st + dur < best[0]:
                best = (st + dur, si, st)
        eft, si, st = best
        commit(fid, node, si, st, eft, [])
        return eft

    def fill_eft_estimate(fid: int, node: int) -> float:
        ft = g.tasks[fid]
        dur = cost.time(ft, node)
        if fill_est is None:
            return min(sl.earliest(0.0, dur) + dur for sl in slots[node])
        est = fill_est[node].get(dur)
        if est is None:
            est = min(sl.earliest(0.0, dur) + dur for sl in slots[node])
            fill_est[node][dur] = est
        return est

    def eval_on_node(t: Task, node: int, dur: float):
        """(eft, slot, start, transfers, lazy_fills, regen_fills)."""
        ready = 0.0
        transfers = []
        lazy_here = []
        regen_here = []
        for p in t.preds:
            pt = g.tasks[p]
            if p not in placements:
                # unplaced lazy fill: generated locally on this node
                assert is_lazy(pt), f"unplaced non-lazy pred {pt}"
                arr = fill_eft_estimate(p, node)
                lazy_here.append(p)
                ready = max(ready, arr)
                continue
            pp = placements[p]
            nbytes = edge_bytes(g, pt, t)
            arr = pp.finish
            if nbytes and pp.node != node:
                key = (p, pt.out.tensor)
                hit = cache_aware and cache.peek(node, key)
                if not hit:
                    # codec-aware per-edge pricing, mirrored in
                    # replan_frontier's eval_on
                    arr_x = pp.finish + tm.wire_time(nbytes, pp.node,
                                                     node, spec)
                    if is_lazy(pt):
                        # generated data is a pure function of (seed, tile):
                        # regenerating locally can beat transferring
                        # (§3.3 local initialisation)
                        arr_r = fill_eft_estimate(p, node)
                        if arr_r < arr_x:
                            regen_here.append(p)
                            ready = max(ready, arr_r)
                            continue
                    arr = arr_x
                transfers.append((p, pp.node, nbytes, hit))
            ready = max(ready, arr)
        best = None
        for si, sl in enumerate(slots[node]):
            st = sl.earliest(ready, dur)
            if best is None or st + dur < best[0]:
                best = (st + dur, si, st)
        eft, si, st = best
        return eft, si, st, transfers, lazy_here, regen_here

    for tid in order:
        t = g.tasks[tid]

        best = None  # (eft, node, dur)
        for node in allowed_nodes(t):
            dur = (1e-6 if t.kind in (TaskKind.CALLOC, TaskKind.RESIDENT)
                   else cost.time(t, node))
            eft, *_ = eval_on_node(t, node, dur)
            if best is None or eft < best[0] - 1e-15 or \
                    (abs(eft - best[0]) <= 1e-15 and node < best[1]):
                best = (eft, node, dur)

        _, node, dur = best
        # commit this node: place lazy/regenerated fills FIRST, then
        # re-evaluate so the consumer's slot fit sees the fills' intervals
        _, _, _, _, lazy_here, regen_here = eval_on_node(t, node, dur)
        for fid in lazy_here:
            place_fill_on(fid, node)
        for fid in regen_here:
            ft = g.tasks[fid]
            clone = g.add(TaskKind.FILL, (), ft.out, payload=ft.payload)
            g.tasks[fid].succs.discard(tid)
            t.preds.discard(fid)
            g.add_edge(clone.tid, tid)
            place_fill_on(clone.tid, node)
        eft, si, st, transfers, lazy2, regen2 = eval_on_node(t, node, dur)
        assert not lazy2 and not regen2
        commit(tid, node, si, st, eft, transfers)

    # any fill no consumer reached (dead code in the expression) — place it
    for tid in order_all:
        if tid not in placements:
            place_fill_on(tid, spec.master)

    final_order = sorted(placements, key=lambda x: (placements[x].start, x))
    makespan = max((p.finish for p in placements.values()), default=0.0)
    return Schedule(placements, final_order, comms, makespan,
                    cache.hits, cache.misses)


def replan_frontier(g: TaskGraph, spec: ClusterSpec, tm: TimeModel,
                    done: Mapping[int, Placement],
                    frontier: Sequence[int],
                    cache_aware: bool = True,
                    fill_origin: Optional[Mapping[int, str]] = None,
                    fast: bool = True,
                    cost: Optional[CostCache] = None,
                    pinned: Optional[Mapping[int, int]] = None) -> Schedule:
    """Incremental re-plan after a cluster-membership change.

    The elastic runtime calls this on node death/join/straggle: ``done``
    holds the placements that are immutable (tasks already completed or
    already dispatched to a surviving node — they are copied into the
    result verbatim), and ``frontier`` the not-yet-dispatched tasks that
    may move.  Frontier tasks are re-ranked and re-placed with the normal
    EFT insertion policy, but **only onto live nodes** of ``spec``
    (``workers_at(n) > 0`` — a dead node is drained via
    ``ClusterSpec.without_node``; a joined node appears via
    ``with_node``).  Surviving nodes' slot timelines are seeded with the
    fixed placements so new work packs around in-flight work.

    Differences from full ``heft_schedule``, both deliberate: no lazy-fill
    deferral (every frontier task is placed now — mid-run there is no
    "first consumer still unknown") and no regenerated-fill cloning (the
    task graph is never mutated while an executor is running it).

    NOTE: the EFT-insertion core below (candidate-node loop, slot
    earliest-gap search, 1e-15 tie-break, cache-aware comm accounting,
    CALLOC's 1e-6 duration) intentionally mirrors ``heft_schedule`` —
    any change to that policy there must be mirrored here, or static
    plans and elastic re-plans will place tasks under different rules.
    """
    origin = fill_origin if fill_origin is not None else {}
    pinned = pinned or {}
    if cost is None:
        cost = CostCache(tm, spec) if fast else DirectCost(tm, spec)
    live = spec.alive_nodes()
    if not live:
        raise ValueError("no live nodes to re-plan onto")
    if spec.master not in live:
        raise ValueError("the master node is drained; cannot re-plan")

    frontier_set = set(frontier)
    overlap = frontier_set & set(done)
    if overlap:
        raise ValueError(f"tasks both done and in the frontier: "
                         f"{sorted(overlap)[:5]}")

    rank = upward_rank(g, spec, tm, cost=cost)
    order = sorted(frontier_set, key=lambda tid: (-rank[tid], tid))

    timeline_cls = _GapTimeline if fast else _SlotTimeline
    slots = {n: [timeline_cls() for _ in range(spec.workers_at(n))]
             for n in live}

    # seed surviving slot timelines with the immutable placements (merged
    # per slot — placements accumulated across successive re-plans are
    # disjoint by construction, but merging keeps seeding robust)
    by_slot: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
    for tid, p in done.items():
        if p.node in slots and 0 <= p.slot < len(slots[p.node]) \
                and p.finish > p.start:
            by_slot.setdefault((p.node, p.slot), []).append(
                (p.start, p.finish))
    for (n, si), ivs in by_slot.items():
        ivs.sort()
        cur_s, cur_e = ivs[0]
        merged = []
        for (s, e) in ivs[1:]:
            if s <= cur_e:
                cur_e = max(cur_e, e)
            else:
                merged.append((cur_s, cur_e))
                cur_s, cur_e = s, e
        merged.append((cur_s, cur_e))
        for (s, e) in merged:
            slots[n][si].insert(s, e - s)

    placements: Dict[int, Placement] = dict(done)
    comms: List[CommEvent] = []
    cache = NodeCache(spec.n_nodes)

    def allowed(t: Task) -> Sequence[int]:
        pin = pinned.get(t.tid)
        if pin is not None:
            if spec.workers_at(pin) <= 0:
                raise ValueError(
                    f"task {t.tid} ({t.kind.value}) is pinned to drained "
                    f"node {pin}")
            return (pin,)
        if t.kind is TaskKind.TAKECOPY:
            return (spec.master,)
        if t.kind is TaskKind.FILL and isinstance(t.payload, int):
            if origin.get(t.payload) == "master":
                return (spec.master,)
        return live

    def eval_on(t: Task, node: int, dur: float):
        ready = 0.0
        transfers = []
        for p in t.preds:
            pp = placements.get(p)
            if pp is None:
                raise ValueError(
                    f"pred {p} of frontier task {t.tid} is neither done "
                    f"nor already re-planned (frontier not closed)")
            pt = g.tasks[p]
            nbytes = edge_bytes(g, pt, t)
            arr = pp.finish
            if nbytes and pp.node != node:
                key = (p, pt.out.tensor)
                hit = cache_aware and cache.peek(node, key)
                if not hit:
                    # codec-aware per-edge pricing, mirroring
                    # heft_schedule's eval_on_node
                    arr = pp.finish + tm.wire_time(nbytes, pp.node, node,
                                                   spec)
                transfers.append((p, pp.node, nbytes, hit))
            ready = max(ready, arr)
        best = None
        for si, sl in enumerate(slots[node]):
            st = sl.earliest(ready, dur)
            if best is None or st + dur < best[0]:
                best = (st + dur, si, st)
        eft, si, st = best
        return eft, si, st, transfers

    for tid in order:
        t = g.tasks[tid]
        best = None
        for node in allowed(t):
            dur = (1e-6 if t.kind in (TaskKind.CALLOC, TaskKind.RESIDENT)
                   else cost.time(t, node))
            eft, si, st, transfers = eval_on(t, node, dur)
            if best is None or eft < best[0] - 1e-15 or \
                    (abs(eft - best[0]) <= 1e-15 and node < best[1]):
                best = (eft, node, si, st, transfers)
        eft, node, si, st, transfers = best
        slots[node][si].insert(st, eft - st)
        placements[tid] = Placement(node, si, st, eft)
        for (p, src, nbytes, hit) in transfers:
            comms.append(CommEvent(p, tid, src, node, nbytes, hit))
            if hit:
                cache.hits += 1
            else:
                cache.misses += 1
                if cache_aware:
                    cache.put(node, (p, g.tasks[p].out.tensor), nbytes)
        if t.out is not None:
            cache.put(node, (tid, t.out.tensor), t.out.bytes)

    final_order = sorted(placements, key=lambda x: (placements[x].start, x))
    makespan = max((p.finish for p in placements.values()), default=0.0)
    return Schedule(placements, final_order, comms, makespan,
                    cache.hits, cache.misses)


#: DEPRECATED mutable fallback for callers that predate the explicit
#: ``fill_origin`` parameter.  Mutated-per-plan module state breaks
#: concurrent planning — pass ``fill_origin=`` to ``heft_schedule`` instead.
_FILL_ORIGIN: Dict[int, str] = {}


def register_fill_origin(mapping: Mapping[int, str]):
    """Deprecated: set the module-level fill-origin fallback.

    Kept for backward compatibility only; prefer
    ``heft_schedule(..., fill_origin=mapping)`` which carries the mapping
    per call and is safe under concurrent planners.
    """
    _FILL_ORIGIN.clear()
    _FILL_ORIGIN.update(mapping)


# -- static memory-residency analysis (bounded-arena admission) -------------
#
# These post-passes price a schedule's *footprint* without touching EFT
# placement (heft_schedule/replan_frontier stay byte-for-byte identical):
# the engine's admission check compares them against ClusterSpec.mem_at to
# decide fits-in-RAM / spill-executable / reject before any worker can OOM.

def _retained_keys(g: TaskGraph,
                   sched: Schedule) -> Set[Tuple[int, "TileRef"]]:
    """(node, ref) pairs that occupy *unevictable* arena bytes: RESIDENT
    session tiles and persisted (non-gather) outputs, which live in the
    retained store and are exempt from spill eviction."""
    keys: Set[Tuple[int, "TileRef"]] = set()
    for tid, t in g.tasks.items():
        p = sched.placements.get(tid)
        if p is not None and t.kind is TaskKind.RESIDENT and t.out is not None:
            keys.add((p.node, t.out))
    for rs in getattr(g, "result_sets", ()) or ():
        if getattr(rs, "gather", True):
            continue
        for r, tid in rs.producers.items():
            p = sched.placements.get(tid)
            if p is not None:
                keys.add((p.node, r))
    return keys


def _held_keys(g: TaskGraph, sched: Schedule) -> Set[Tuple[int, "TileRef"]]:
    """(node, ref) pairs held until end of run: the retained set plus
    gathered result tiles (TAKECOPY outputs awaiting master assembly —
    held, but spillable)."""
    keys = _retained_keys(g, sched)
    for tid, t in g.tasks.items():
        p = sched.placements.get(tid)
        if p is not None and t.kind is TaskKind.TAKECOPY and t.out is not None:
            keys.add((p.node, t.out))
    return keys


def peak_node_bytes(g: TaskGraph, sched: Schedule) -> Dict[int, int]:
    """Predicted peak arena bytes per node for running ``sched``.

    Walks the schedule in start order: a task's output allocates at its
    node, a cross-node input allocates its XFER copy at the consumer, and
    a (node, ref) frees after its last scheduled use — except refs held to
    the end of the run (gathered/persisted results, resident tiles).  This
    mirrors the executors' refcount freeing closely enough for admission;
    it is an upper-bound-flavoured estimate, not a simulation.
    """
    node_of = {tid: p.node for tid, p in sched.placements.items()}
    order = [tid for tid in sched.order if tid in node_of]
    held = _held_keys(g, sched)
    last: Dict[Tuple[int, "TileRef"], int] = {}
    for k, tid in enumerate(order):
        t = g.tasks[tid]
        n = node_of[tid]
        for r in t.ins:
            last[(n, r)] = k
        if t.out is not None:
            last[(n, t.out)] = k
    release_at: Dict[int, List[Tuple[int, "TileRef"]]] = {}
    for key, k in last.items():
        if key not in held:
            release_at.setdefault(k, []).append(key)
    cur: Dict[int, int] = {}
    peak: Dict[int, int] = {}
    live: Set[Tuple[int, "TileRef"]] = set()
    for k, tid in enumerate(order):
        t = g.tasks[tid]
        n = node_of[tid]
        for r in t.ins:
            if (n, r) not in live:
                live.add((n, r))
                cur[n] = cur.get(n, 0) + r.bytes
        if t.out is not None and (n, t.out) not in live:
            live.add((n, t.out))
            cur[n] = cur.get(n, 0) + t.out.bytes
        if cur.get(n, 0) > peak.get(n, 0):
            peak[n] = cur[n]
        for key in release_at.get(k, ()):
            if key in live:
                live.discard(key)
                cur[key[0]] -= key[1].bytes
    return peak


def min_resident_floor(g: TaskGraph, sched: Schedule, node: int) -> int:
    """The smallest arena ``node`` could possibly run ``sched`` with:
    its unevictable retained bytes plus the largest single-task working
    set (a task's deduplicated inputs + output must be hot at once).  A
    budget below this cannot be met by spilling — the plan must shrink
    its tile or be rejected."""
    base = sum(r.bytes for (n, r) in _retained_keys(g, sched) if n == node)
    worst = 0
    for tid, p in sched.placements.items():
        if p.node != node:
            continue
        t = g.tasks[tid]
        refs = set(t.ins)
        if t.out is not None:
            refs.add(t.out)
        s = sum(r.bytes for r in refs)
        if s > worst:
            worst = s
    return base + worst
