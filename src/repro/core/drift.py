"""Predicted-vs-actual drift analysis over flight-recorder spans.

The planner commits to a tile size and a schedule because the
simulator, driven by the fitted :class:`~repro.core.timemodel.TimeModel`,
predicted they would win (the paper's §3.4–3.6 loop).  This module
closes that loop: it joins the spans a real run recorded
(``runtime/telemetry.py``) against the HEFT/simulator predicted
timeline and answers two questions —

* **which nodes drifted?**  Per-node residual ratios
  (``median(actual / predicted)`` over that node's EXEC spans,
  normalized by the fleet median so a uniformly mis-fitted model does
  not flag everyone).  Nodes outside a configurable band become
  **straggler priors**: feed them to
  ``MembershipService.seed_straggler_priors`` and the next run's
  detector fires on its first confirming sweep instead of waiting out
  its patience budget (ROADMAP item 3).

* **which model terms drifted?**  EXEC spans evidence ``kernel_time``,
  raw XFER spans evidence ``ipc_bandwidth``, PACK (encode) spans
  ``compress_bandwidth``, SPILL / FAULTIN spans the spill write/read
  bandwidths.  A term whose pooled residual leaves the band is flagged
  for recalibration, with ``TimeModel.recalibrated(term, ratio)`` as
  the one-line fix.

The join is replanning-safe: a task that ran on its *planned* node
compares against its simulated interval; a task the elastic runtime
re-routed (death/join/straggle) is re-priced on the node it actually
ran on through :class:`~repro.core.timemodel.CostCache`, so churned
runs still produce meaningful residuals.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

from ..runtime.wire import predicted_xfer_seconds
from .timemodel import CostCache, TimeModel

__all__ = ["NodeDrift", "TermDrift", "DriftReport", "drift_report"]

#: predicted durations below this floor are noise, not evidence — a
#: ratio against a ~0 prediction would dominate every median
_MIN_PREDICTED_S = 1e-7


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


@dataclass
class NodeDrift:
    """One node's residual summary over its EXEC spans."""

    node: int
    samples: int
    actual_s: float
    predicted_s: float
    #: median(actual / predicted) over this node's tasks; None without
    #: samples
    ratio: Optional[float]
    #: ratio normalized by the fleet median ratio — the drift signal
    rel: Optional[float]
    #: outside the band (either direction) with enough samples
    flagged: bool

    def as_dict(self) -> dict:
        return {"node": self.node, "samples": self.samples,
                "actual_s": self.actual_s,
                "predicted_s": self.predicted_s,
                "ratio": self.ratio, "rel": self.rel,
                "flagged": self.flagged}


@dataclass
class TermDrift:
    """One TimeModel term's pooled residual across all its spans."""

    term: str
    samples: int
    #: median(actual / predicted) under the current term value
    ratio: Optional[float]
    flagged: bool
    #: the recalibrated value ``TimeModel.recalibrated(term, ratio)``
    #: would set (None for kernel_time, whose fix is a coefficient
    #: scale, and for unflagged/unsampled terms)
    suggested: Optional[float] = None

    def as_dict(self) -> dict:
        return {"term": self.term, "samples": self.samples,
                "ratio": self.ratio, "flagged": self.flagged,
                "suggested": self.suggested}


@dataclass
class DriftReport:
    nodes: List[NodeDrift]
    terms: List[TermDrift]
    #: nodes whose relative residual exceeded the band on the slow side
    #: — feed to ``MembershipService.seed_straggler_priors`` /
    #: ``ElasticClusterExecutor(straggler_priors=...)``
    straggler_priors: List[int]
    band: float
    #: fleet-median actual/predicted ratio (the model's uniform bias)
    fleet_ratio: Optional[float] = None

    def node(self, n: int) -> Optional[NodeDrift]:
        for nd in self.nodes:
            if nd.node == n:
                return nd
        return None

    def term(self, name: str) -> Optional[TermDrift]:
        for td in self.terms:
            if td.term == name:
                return td
        return None

    def as_dict(self) -> dict:
        return {"band": self.band,
                "fleet_ratio": self.fleet_ratio,
                "straggler_priors": list(self.straggler_priors),
                "nodes": [nd.as_dict() for nd in self.nodes],
                "terms": [td.as_dict() for td in self.terms]}

    def summary(self) -> str:
        lines = [f"drift report (band {self.band}x, fleet ratio "
                 f"{self.fleet_ratio if self.fleet_ratio is None else round(self.fleet_ratio, 3)})"]
        for nd in self.nodes:
            mark = " <-- STRAGGLER PRIOR" if nd.node in \
                self.straggler_priors else (" <-- drifted"
                                            if nd.flagged else "")
            r = "n/a" if nd.ratio is None else f"{nd.ratio:.2f}x"
            lines.append(f"  node {nd.node}: {nd.samples} tasks, "
                         f"residual {r}{mark}")
        for td in self.terms:
            if td.ratio is None:
                continue
            mark = " <-- recalibrate" if td.flagged else ""
            lines.append(f"  term {td.term}: {td.samples} samples, "
                         f"residual {td.ratio:.2f}x{mark}")
        return "\n".join(lines)


def _ratio_rows(spans, plan, tm) -> Dict[str, List[float]]:
    """actual/predicted ratio samples per evidence stream."""
    g = plan.program.graph
    spec = plan.spec
    pred_iv = {iv.tid: iv for iv in plan.sim.intervals} \
        if plan.sim is not None else {}
    cost = CostCache(tm, spec)
    rows: Dict[str, List[float]] = {
        "kernel_time": [], "ipc_bandwidth": [],
        "compress_bandwidth": [], "spill_write_bandwidth": [],
        "spill_read_bandwidth": [],
    }
    per_node: Dict[int, List[float]] = {}
    per_node_sum: Dict[int, List[float]] = {}
    for sp in spans:
        if sp.cat == "EXEC":
            tid = sp.args.get("tid")
            t = g.tasks.get(tid) if tid is not None else None
            if t is None:
                continue
            iv = pred_iv.get(tid)
            if iv is not None and iv.node == sp.node:
                p = iv.end - iv.start
            elif spec is not None and 0 <= sp.node < spec.n_nodes:
                # re-routed under churn: price on the actual node
                p = cost.time(t, sp.node)
            elif spec is not None:
                p = cost.avg(t)       # joined node outside the spec
            else:
                continue
            if p < _MIN_PREDICTED_S:
                continue
            r = sp.dur / p
            rows["kernel_time"].append(r)
            per_node.setdefault(sp.node, []).append(r)
            per_node_sum.setdefault(sp.node, []).append((sp.dur, p))
        elif sp.cat == "XFER":
            nbytes = sp.args.get("nbytes", 0)
            codec = sp.args.get("codec", "raw")
            p = predicted_xfer_seconds(
                nbytes, tm, codec, sp.args.get("comp_nbytes", 0))
            if p < _MIN_PREDICTED_S:
                continue
            term = ("ipc_bandwidth" if codec == "raw"
                    else "compress_bandwidth")
            rows[term].append(sp.dur / p)
        elif sp.cat == "PACK":
            nbytes = sp.args.get("nbytes", 0)
            cbw = getattr(tm, "compress_bandwidth", 0.0)
            if nbytes and cbw > 0:
                p = nbytes / cbw
                if p >= _MIN_PREDICTED_S:
                    rows["compress_bandwidth"].append(sp.dur / p)
        elif sp.cat == "SPILL":
            nbytes = sp.args.get("nbytes", 0)
            bw = getattr(tm, "spill_write_bandwidth", 0.0)
            if nbytes and bw > 0:
                p = nbytes / bw
                if p >= _MIN_PREDICTED_S:
                    rows["spill_write_bandwidth"].append(sp.dur / p)
        elif sp.cat == "FAULTIN":
            nbytes = sp.args.get("nbytes", 0)
            bw = getattr(tm, "spill_read_bandwidth", 0.0)
            if nbytes and bw > 0:
                p = nbytes / bw
                if p >= _MIN_PREDICTED_S:
                    rows["spill_read_bandwidth"].append(sp.dur / p)
    rows["__per_node__"] = per_node            # type: ignore[assignment]
    rows["__per_node_sum__"] = per_node_sum    # type: ignore[assignment]
    return rows


def drift_report(spans: Iterable, plan, tm: Optional[TimeModel] = None,
                 band: float = 1.5, min_samples: int = 3,
                 nodes: Optional[Iterable[int]] = None) -> DriftReport:
    """Join measured spans against the plan's predicted timeline.

    ``band`` is the residual tolerance: a node (or term) whose
    normalized residual ratio leaves ``[1/band, band]`` with at least
    ``min_samples`` samples is flagged.  ``nodes`` forces a row for
    every listed node even without samples (default: every node of
    ``plan.spec``), so the report always answers "what about node k?".
    """
    if tm is None:
        tm = getattr(plan, "timemodel", None)
    if tm is None:
        from .timemodel import analytic_time_model
        tm = analytic_time_model()
    spans = list(spans)
    rows = _ratio_rows(spans, plan, tm)
    per_node: Dict[int, List[float]] = rows.pop("__per_node__")
    per_node_sum = rows.pop("__per_node_sum__")

    if nodes is None:
        spec = plan.spec
        nodes = range(spec.n_nodes) if spec is not None else []
    all_nodes = sorted(set(int(n) for n in nodes) | set(per_node))

    node_ratio = {n: _median(per_node[n]) for n in per_node}
    fleet = _median(list(node_ratio.values())) if node_ratio else None
    node_rows: List[NodeDrift] = []
    priors: List[int] = []
    for n in all_nodes:
        samples = per_node.get(n, [])
        ratio = node_ratio.get(n)
        rel = None
        flagged = False
        if ratio is not None and fleet and fleet > 0:
            rel = ratio / fleet
            flagged = (len(samples) >= min_samples
                       and (rel > band or rel < 1.0 / band))
            if flagged and rel > band:
                priors.append(n)
        sums = per_node_sum.get(n, [])
        node_rows.append(NodeDrift(
            node=n, samples=len(samples),
            actual_s=sum(a for a, _ in sums),
            predicted_s=sum(p for _, p in sums),
            ratio=ratio, rel=rel, flagged=flagged))

    term_rows: List[TermDrift] = []
    for term, samples in rows.items():
        ratio = _median(samples) if samples else None
        flagged = (ratio is not None and len(samples) >= min_samples
                   and (ratio > band or ratio < 1.0 / band))
        suggested = None
        if flagged and term != "kernel_time":
            cur = getattr(tm, term, 0.0)
            if cur > 0:
                suggested = cur / ratio
        term_rows.append(TermDrift(term=term, samples=len(samples),
                                   ratio=ratio, flagged=flagged,
                                   suggested=suggested))

    return DriftReport(nodes=node_rows, terms=term_rows,
                       straggler_priors=priors, band=band,
                       fleet_ratio=fleet)
