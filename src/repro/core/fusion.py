"""Expression-graph optimizer (CMM §3.1: "optimize matrix operations on the
fly" before tiling and scheduling).

The engine runs these rewrite passes over the lazy expression DAG *before*
``tile_expression``, so tiling / HEFT / simulation all see the reduced graph:

* **identity folding** — ``A + zeros``, ``A - zeros``, ``A @ eye``,
  ``eye @ A``, ``A * 1.0``, ``A / 1.0``, ``(A.T).T`` collapse to ``A``
  (only when the fold preserves the result dtype);
* **transpose folding** — a ``TRANSPOSE`` operand of a ``MATMUL`` becomes a
  transposed-operand flag ``(ta, tb)`` on the MATMUL node, so no transposed
  intermediate is ever materialised (BLAS consumes the transposed view
  directly);
* **CSE** — structurally identical subexpressions (same op, canonicalised
  parents and value-relevant payload) are merged, so a shared subexpression
  is computed once;
* **elementwise fusion** — maximal connected regions of
  EWISE/SCALE/ADD/SUB/EWMUL nodes whose interior nodes have a single
  consumer collapse into one FUSED node.  A FUSED node executes as *one*
  task per tile, eliminating every interior tile buffer of the chain.
  Multi-consumer nodes are never inlined (their value is needed elsewhere);
  they can still root their own region.
* **matmul-epilogue fusion** — an elementwise node or FUSED region whose
  only use of a single-consumer MATMUL is as a same-shaped operand is
  folded INTO that matmul as an **epilogue program** on its payload
  (``graph.epilogue_payload``).  The hot shape ``relu(A@B + C)`` then
  executes as the addmul k-chain alone: the last chain task applies the
  epilogue to the accumulated ``C`` tile in one pass — no FUSED task, no
  materialised matmul intermediate.  The epilogue reuses the FUSED
  tile-program encoding with input slot 0 = the accumulator and slots
  ``1..`` = the extra operands appended to the MATMUL's parents.

The FUSED payload is a small hashable tile program — a tuple of
instructions in topological order::

    ("in", k)                   # tile of the k-th parent
    ("ewise", fn, i)            # EWISE_FNS[fn](vals[i])
    ("scale", kind, s, i)       # apply_scale(kind, vals[i], s)
    ("add"|"sub"|"ewmul", i, j) # binary elementwise

The last instruction is the output.  ``eval_fused`` interprets it over full
tiles, reusing dead interior buffers in place (``out=``) so a fused chain of
N ops allocates O(1) scratch instead of N intermediates.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .graph import epilogue_payload, matmul_epilogue, matmul_flags
from .lazy import (ClusteredMatrix, EWISE_FNS, Op, apply_scale, topo_order,
                   topo_order_many)

#: expression ops that are elementwise over same-shaped operands
ELEMENTWISE_OPS = {Op.ADD, Op.SUB, Op.EWMUL, Op.SCALE, Op.EWISE}

LEAF_OPS = {Op.INPUT, Op.RANDOM, Op.ZEROS, Op.EYE, Op.RESIDENT}


@dataclass
class FusionReport:
    """What the optimizer did — surfaced on the Plan for benchmarks/tests."""

    nodes_before: int = 0
    nodes_after: int = 0
    cse_merged: int = 0
    identities_folded: int = 0
    transposes_folded: int = 0
    fused_regions: int = 0
    fused_ops: int = 0          # elementwise nodes swallowed by FUSED regions
    epilogues_fused: int = 0    # FUSED/elementwise nodes folded into a MATMUL
    epilogue_ops: int = 0       # arithmetic instrs now running as epilogues

    def as_dict(self) -> Dict[str, int]:
        return dict(self.__dict__)


# ---------------------------------------------------------------------------
# pass 1: identity + transpose folding (single bottom-up rebuild)
# ---------------------------------------------------------------------------

def _is_zeros(n: ClusteredMatrix) -> bool:
    return n.op is Op.ZEROS


def _is_eye(n: ClusteredMatrix) -> bool:
    return n.op is Op.EYE


def fold_identities(root: ClusteredMatrix, report: FusionReport,
                    fold_transpose: bool = True) -> ClusteredMatrix:
    """Algebraic identity folding + transpose-into-matmul flag folding."""
    return fold_identities_many((root,), report,
                                fold_transpose=fold_transpose)[0]


def fold_identities_many(roots: Sequence[ClusteredMatrix],
                         report: FusionReport,
                         fold_transpose: bool = True
                         ) -> List[ClusteredMatrix]:
    """Multi-root twin of :func:`fold_identities` (shared subexpressions
    are rewritten once)."""
    new: Dict[int, ClusteredMatrix] = {}

    def rewritten(node: ClusteredMatrix) -> ClusteredMatrix:
        return new[node.uid]

    for node in topo_order_many(roots):
        parents = tuple(rewritten(p) for p in node.parents)
        out: Optional[ClusteredMatrix] = None

        if node.op is Op.ADD:
            a, b = parents
            if _is_zeros(b) and a.dtype == node.dtype:
                out = a
            elif _is_zeros(a) and b.dtype == node.dtype:
                out = b
        elif node.op is Op.SUB:
            a, b = parents
            if _is_zeros(b) and a.dtype == node.dtype:
                out = a
        elif node.op is Op.SCALE:
            kind, s = node.payload
            a = parents[0]
            if a.dtype == node.dtype and (
                    (kind in ("scale", "mul", "ewmul", "div") and s == 1.0)
                    or (kind in ("add", "sub") and s == 0.0)):
                out = a
        elif node.op is Op.TRANSPOSE:
            a = parents[0]
            if a.op is Op.TRANSPOSE:          # (A.T).T -> A
                out = a.parents[0]
        elif node.op is Op.MATMUL:
            a, b = parents[:2]
            extras = parents[2:]           # epilogue operands (re-optimize)
            epi = matmul_epilogue(node.payload)
            if epi is None and not extras and _is_eye(b) \
                    and a.dtype == node.dtype:
                out = a
            elif epi is None and not extras and _is_eye(a) \
                    and b.dtype == node.dtype:
                out = b
            else:
                flags0 = matmul_flags(node.payload)
                ta, tb = flags0
                while fold_transpose and a.op is Op.TRANSPOSE:
                    a, ta = a.parents[0], not ta
                    report.transposes_folded += 1
                while fold_transpose and b.op is Op.TRANSPOSE:
                    b, tb = b.parents[0], not tb
                    report.transposes_folded += 1
                if (a, b) != parents[:2] or (ta, tb) != flags0:
                    if epi is not None:
                        payload = epilogue_payload((ta, tb), epi)
                    else:
                        payload = (ta, tb) if ta or tb else None
                    out = ClusteredMatrix(Op.MATMUL, node.shape, node.dtype,
                                          parents=(a, b) + extras,
                                          payload=payload,
                                          name=node.name)

        if out is not None and out.op is not Op.MATMUL:
            report.identities_folded += 1
        if out is None:
            out = node if parents == node.parents else \
                ClusteredMatrix(node.op, node.shape, node.dtype,
                                parents=parents, payload=node.payload,
                                name=node.name)
        new[node.uid] = out
    return [new[r.uid] for r in roots]


# ---------------------------------------------------------------------------
# pass 2: common-subexpression elimination
# ---------------------------------------------------------------------------

def _value_payload_key(node: ClusteredMatrix):
    """Payload component of the CSE key — must distinguish different VALUES.

    INPUT data is keyed by array object identity; RANDOM by its seed.
    """
    if node.op is Op.INPUT:
        return ("input", id(node.payload))
    if node.op is Op.RANDOM:
        return ("seed", node.payload)
    if node.op is Op.RESIDENT:
        # a resident leaf's value is its handle: two uses of one handle
        # are the same tiles, two handles are distinct values
        return ("resident", node.payload.hid)
    if node.op is Op.FUSED:
        return node.payload
    if isinstance(node.payload, (str, int, float, tuple, type(None))):
        return node.payload
    return id(node.payload)


def cse(root: ClusteredMatrix, report: FusionReport) -> ClusteredMatrix:
    """Merge structurally identical subexpressions (structural hashing of
    ``(op, parents, payload)``)."""
    return cse_many((root,), report)[0]


def cse_many(roots: Sequence[ClusteredMatrix],
             report: FusionReport) -> List[ClusteredMatrix]:
    """CSE over the union DAG of several roots — the shared-CSE half of
    ``compute_many``: a subexpression common to two roots is computed
    once in the merged program."""
    canon: Dict[tuple, ClusteredMatrix] = {}
    new: Dict[int, ClusteredMatrix] = {}

    for node in topo_order_many(roots):
        parents = tuple(new[p.uid] for p in node.parents)
        key = (node.op, node.shape, str(node.dtype),
               _value_payload_key(node), tuple(p.uid for p in parents))
        hit = canon.get(key)
        if hit is not None:
            report.cse_merged += 1
            new[node.uid] = hit
            continue
        out = node if parents == node.parents else \
            ClusteredMatrix(node.op, node.shape, node.dtype, parents=parents,
                            payload=node.payload, name=node.name)
        canon[key] = out
        new[node.uid] = out
    return [new[r.uid] for r in roots]


# ---------------------------------------------------------------------------
# pass 3: elementwise-chain fusion
# ---------------------------------------------------------------------------

def _consumers(roots: Sequence[ClusteredMatrix]) -> Dict[int, Set[int]]:
    cons: Dict[int, Set[int]] = {r.uid: set() for r in roots}
    for node in topo_order_many(roots):
        cons.setdefault(node.uid, set())
        for p in node.parents:
            cons.setdefault(p.uid, set()).add(node.uid)
    return cons


def fuse_elementwise(root: ClusteredMatrix,
                     report: FusionReport) -> ClusteredMatrix:
    """Collapse single-consumer elementwise chains into FUSED nodes."""
    return fuse_elementwise_many((root,), report)[0]


def fuse_elementwise_many(roots: Sequence[ClusteredMatrix],
                          report: FusionReport) -> List[ClusteredMatrix]:
    """Multi-root elementwise fusion.  A root's value is an OUTPUT of the
    merged program, so a root node is never inlined into a consumer's
    region (it may still root its own region and swallow its upstream
    chain)."""
    order = topo_order_many(roots)
    by_uid = {n.uid: n for n in order}
    cons = _consumers(roots)
    root_uids = {r.uid for r in roots}

    # region_of[uid] = uid of the region root this node is inlined into
    region_of: Dict[int, int] = {}
    for node in reversed(order):            # root first
        if node.op not in ELEMENTWISE_OPS:
            continue
        cs = cons[node.uid]
        if len(cs) == 1 and node.uid not in root_uids:
            (c,) = cs
            if by_uid[c].op in ELEMENTWISE_OPS:
                # inline into the consumer's region
                region_of[node.uid] = region_of.get(c, c)
                continue
        region_of[node.uid] = node.uid      # roots its own region

    members: Dict[int, List[ClusteredMatrix]] = {}
    for node in order:                      # topological member order
        r = region_of.get(node.uid)
        if r is not None:
            members.setdefault(r, []).append(node)

    new: Dict[int, ClusteredMatrix] = {}
    for node in order:
        r = region_of.get(node.uid)
        if r is not None and r != node.uid:
            continue                        # interior node: no standalone copy
        if r is None or len(members[r]) == 1:
            parents = tuple(new[p.uid] for p in node.parents)
            new[node.uid] = node if parents == node.parents else \
                ClusteredMatrix(node.op, node.shape, node.dtype,
                                parents=parents, payload=node.payload,
                                name=node.name)
            continue

        # build the FUSED node for this region
        region = members[r]
        region_uids = {m.uid for m in region}
        externals: List[ClusteredMatrix] = []
        ext_slot: Dict[int, int] = {}       # resolved-external uid -> slot
        instrs: List[tuple] = []
        instr_of: Dict[int, int] = {}       # member/external uid -> instr idx

        def operand(p: ClusteredMatrix) -> int:
            if p.uid in region_uids:
                return instr_of[p.uid]
            q = new[p.uid]
            if q.uid not in ext_slot:
                ext_slot[q.uid] = len(externals)
                externals.append(q)
                instrs.append(("in", ext_slot[q.uid]))
                instr_of[q.uid] = len(instrs) - 1
            return instr_of[q.uid]

        for m in region:
            if m.op is Op.EWISE:
                ins = ("ewise", m.payload, operand(m.parents[0]))
            elif m.op is Op.SCALE:
                kind, s = m.payload
                ins = ("scale", kind, s, operand(m.parents[0]))
            else:
                opname = {Op.ADD: "add", Op.SUB: "sub",
                          Op.EWMUL: "ewmul"}[m.op]
                ins = (opname, operand(m.parents[0]), operand(m.parents[1]))
            instrs.append(ins)
            instr_of[m.uid] = len(instrs) - 1

        fused = ClusteredMatrix(Op.FUSED, node.shape, node.dtype,
                                parents=tuple(externals),
                                payload=tuple(instrs), name=node.name)
        report.fused_regions += 1
        report.fused_ops += len(region)
        new[node.uid] = fused

    return [new[r.uid] for r in roots]


# ---------------------------------------------------------------------------
# pass 4: matmul-epilogue fusion
# ---------------------------------------------------------------------------

def _as_epilogue_prog(node: ClusteredMatrix,
                      slot_of: Dict[int, int]) -> tuple:
    """Rewrite ``node`` (a FUSED region or a single elementwise op) as an
    epilogue program whose ``("in", k)`` slots follow ``slot_of`` —
    parent uid -> epilogue input slot (0 = the matmul accumulator)."""
    if node.op is Op.FUSED:
        out = []
        for ins in node.payload:
            if ins[0] == "in":
                out.append(("in", slot_of[node.parents[ins[1]].uid]))
            else:
                out.append(ins)
        return tuple(out)
    # single elementwise node: synthesize the minimal program
    slots = [slot_of[p.uid] for p in node.parents]
    instrs: List[tuple] = []
    idx_of: Dict[int, int] = {}          # input slot -> instruction index
    for s in slots:
        if s not in idx_of:
            instrs.append(("in", s))
            idx_of[s] = len(instrs) - 1
    ops = [idx_of[s] for s in slots]
    if node.op is Op.EWISE:
        instrs.append(("ewise", node.payload, ops[0]))
    elif node.op is Op.SCALE:
        kind, s = node.payload
        instrs.append(("scale", kind, s, ops[0]))
    else:
        opname = {Op.ADD: "add", Op.SUB: "sub", Op.EWMUL: "ewmul"}[node.op]
        instrs.append((opname, ops[0], ops[1]))
    return tuple(instrs)


def fuse_matmul_epilogues(root: ClusteredMatrix,
                          report: FusionReport) -> ClusteredMatrix:
    """Single-root wrapper over :func:`fuse_matmul_epilogues_many`."""
    return fuse_matmul_epilogues_many((root,), report)[0]


def fuse_matmul_epilogues_many(roots: Sequence[ClusteredMatrix],
                               report: FusionReport
                               ) -> List[ClusteredMatrix]:
    """Fold elementwise consumers of single-consumer MATMULs into the
    matmul as an epilogue program (runs after elementwise fusion, so a
    whole chain like ``relu(A@B + C)`` arrives as ONE FUSED node).

    Candidate anchor: a MATMUL parent of an elementwise/FUSED node that
    (a) has no epilogue yet, (b) is consumed ONLY by this node, (c) is not
    itself a program root, and (d) has the consumer's shape (elementwise
    ops preserve shape, so this always holds for direct operands).  The
    consumer is rewritten into the matmul: parents become
    ``(A, B, *other_operands)`` and the payload carries the epilogue
    program with slot 0 bound to the accumulated ``C`` tile.  Only ONE
    matmul is absorbed per region — other matmul operands stay
    materialised inputs (epilogue extras)."""
    order = topo_order_many(roots)
    cons = _consumers(roots)
    root_uids = {r.uid for r in roots}
    new: Dict[int, ClusteredMatrix] = {}

    for node in order:
        parents = tuple(new[p.uid] for p in node.parents)
        out: Optional[ClusteredMatrix] = None

        mi = None
        if node.op is Op.FUSED or node.op in ELEMENTWISE_OPS:
            for i, (po, pn) in enumerate(zip(node.parents, parents)):
                if (pn.op is Op.MATMUL
                        and matmul_epilogue(pn.payload) is None
                        and po.uid not in root_uids
                        and cons.get(po.uid) == {node.uid}
                        and pn.shape == node.shape):
                    mi = i
                    break
        if mi is not None:
            anchor = parents[mi]
            # epilogue input slots: 0 = accumulator; 1.. = the region's
            # other external operands, in first-use order.  Keyed by the
            # PRE-pass parent uid so a CSE-duplicated anchor operand
            # (e.g. ``M + M``) maps every occurrence to slot 0.
            extras: List[ClusteredMatrix] = []
            slot_of: Dict[int, int] = {node.parents[mi].uid: 0}
            for po, pn in zip(node.parents, parents):
                if po.uid not in slot_of:
                    slot_of[po.uid] = 1 + len(extras)
                    extras.append(pn)
            prog = _as_epilogue_prog(node, slot_of)
            out = ClusteredMatrix(
                Op.MATMUL, node.shape, node.dtype,
                parents=tuple(anchor.parents) + tuple(extras),
                payload=epilogue_payload(matmul_flags(anchor.payload), prog),
                name=node.name)
            report.epilogues_fused += 1
            report.epilogue_ops += fused_op_count(prog)

        if out is None:
            out = node if parents == node.parents else \
                ClusteredMatrix(node.op, node.shape, node.dtype,
                                parents=parents, payload=node.payload,
                                name=node.name)
        new[node.uid] = out

    return [new[r.uid] for r in roots]


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def optimize(root: ClusteredMatrix, fold_transpose: bool = True,
             fuse: bool = True, fuse_epilogue: bool = True
             ) -> Tuple[ClusteredMatrix, FusionReport]:
    """Run all rewrite passes; returns (optimized root, report).

    ``fold_transpose=False`` keeps explicit TRANSPOSE nodes (needed when the
    tile is non-square, where transposed tile indexing is ill-defined on
    ragged grids).  ``fuse_epilogue=False`` keeps elementwise consumers of
    matmuls as standalone FUSED tasks (the unfused oracle baseline).
    """
    roots, report = optimize_many((root,), fold_transpose=fold_transpose,
                                  fuse=fuse, fuse_epilogue=fuse_epilogue)
    return roots[0], report


def optimize_many(roots: Sequence[ClusteredMatrix],
                  fold_transpose: bool = True, fuse: bool = True,
                  fuse_epilogue: bool = True
                  ) -> Tuple[List[ClusteredMatrix], FusionReport]:
    """Optimize several roots as ONE program: every pass (identity folds,
    CSE, elementwise fusion, matmul-epilogue fusion) runs over the union
    DAG, so subexpressions shared *across* roots are merged — the
    ``compute_many`` shared-CSE contract."""
    report = FusionReport(nodes_before=len(topo_order_many(roots)))
    roots = fold_identities_many(roots, report,
                                 fold_transpose=fold_transpose)
    roots = cse_many(roots, report)
    if fuse:
        roots = fuse_elementwise_many(roots, report)
        if fuse_epilogue:
            roots = fuse_matmul_epilogues_many(roots, report)
    report.nodes_after = len(topo_order_many(roots))
    return list(roots), report


# ---------------------------------------------------------------------------
# FUSED program interpreter (shared by executor + eager oracle)
# ---------------------------------------------------------------------------

_UNARY_OUT = {
    "sin": np.sin, "cos": np.cos, "exp": np.exp, "tanh": np.tanh,
    "abs": np.abs, "sqrt": np.sqrt, "sign": np.sign,
}
_BIN_OUT = {"add": np.add, "sub": np.subtract, "ewmul": np.multiply}
_SCALE_OUT = {"add": np.add, "sub": np.subtract, "scale": np.multiply,
              "mul": np.multiply, "ewmul": np.multiply,
              "div": np.true_divide}


def fused_op_count(prog: Sequence[tuple]) -> int:
    """Number of arithmetic instructions in a FUSED program."""
    return sum(1 for ins in prog if ins[0] != "in")


def fused_flops(prog: Sequence[tuple], m: int, n: int) -> int:
    """Flop estimate matching the unfused per-kind accounting."""
    f = 0
    for ins in prog:
        if ins[0] == "in":
            continue
        f += (4 if ins[0] == "ewise" else 1) * m * n
    return f


def eval_fused(prog: Sequence[tuple], inputs: Sequence[np.ndarray]
               ) -> np.ndarray:
    """Interpret a FUSED tile program.

    Interior temporaries whose last use has passed are recycled as ``out=``
    buffers, so the chain runs with O(1) scratch regardless of length.
    Input buffers are never written.
    """
    n = len(prog)
    last_use = [0] * n
    is_input = [ins[0] == "in" for ins in prog]
    for idx, ins in enumerate(prog):
        for ref in ins[2:] if ins[0] == "scale" else ins[1:]:
            if isinstance(ref, int):
                last_use[ref] = idx

    vals: List[Optional[np.ndarray]] = [None] * n
    free: List[np.ndarray] = []
    # buffer recycling is only safe when ufunc output dtype == operand dtype,
    # which holds for floating inputs (ints would promote under sin/div/...)
    reuse = all(np.asarray(x).dtype.kind == "f" for x in inputs)

    def take_out(shape, dtype) -> Optional[np.ndarray]:
        if not reuse:
            return None
        for i, buf in enumerate(free):
            if buf.shape == shape and buf.dtype == dtype:
                return free.pop(i)
        return None

    def release(idx: int, at: int):
        if not is_input[idx] and last_use[idx] <= at:
            buf = vals[idx]
            if buf is not None:
                free.append(buf)
            vals[idx] = None

    for idx, ins in enumerate(prog):
        kind = ins[0]
        if kind == "in":
            vals[idx] = np.asarray(inputs[ins[1]])
            continue
        if kind == "ewise":
            fn, i = ins[1], ins[2]
            x = vals[i]
            if fn == "relu":
                rd = np.result_type(x.dtype)
                out = take_out(x.shape, rd)
                vals[idx] = np.maximum(x, 0.0, out=out) if out is not None \
                    else np.maximum(x, 0.0)
            else:
                uf = _UNARY_OUT.get(fn)
                if uf is None:              # EWISE_FNS entry without a ufunc
                    vals[idx] = EWISE_FNS[fn](x)
                else:
                    out = take_out(x.shape, np.result_type(x.dtype))
                    vals[idx] = uf(x, out=out) if out is not None else uf(x)
            release(i, idx)
        elif kind == "scale":
            sk, s, i = ins[1], ins[2], ins[3]
            x = vals[i]
            out = take_out(x.shape, x.dtype)
            uf = _SCALE_OUT.get(sk)
            if uf is not None and out is not None and \
                    np.result_type(x.dtype) == out.dtype:
                vals[idx] = uf(x, x.dtype.type(s), out=out)
            else:
                if out is not None:
                    free.append(out)
                vals[idx] = apply_scale(sk, x, s)
            release(i, idx)
        else:
            i, j = ins[1], ins[2]
            a, b = vals[i], vals[j]
            rd = np.result_type(a.dtype, b.dtype)
            out = take_out(a.shape, rd)
            uf = _BIN_OUT[kind]
            vals[idx] = uf(a, b, out=out) if out is not None else uf(a, b)
            release(i, idx)
            release(j, idx)

    return vals[n - 1]


# ---------------------------------------------------------------------------
# structural signature (plan-cache key)
# ---------------------------------------------------------------------------

def _structure_payload_key(node: ClusteredMatrix):
    """Payload component of the *structural* signature.

    Unlike the CSE key this deliberately ignores leaf VALUES (input array
    identity, random seed): the tiled program and schedule depend only on
    structure and shapes, and a cache hit rebinds the leaves.  RESIDENT
    leaves ignore the handle identity too — the *layout* (tile grid +
    per-tile home nodes) is keyed separately (``residency_layout``), so a
    power-iteration step hits the cache even though each step holds a
    fresh handle.
    """
    if node.op in (Op.INPUT, Op.RANDOM, Op.RESIDENT):
        return None
    if isinstance(node.payload, (str, int, float, tuple, type(None))):
        return node.payload
    return str(node.payload)


def structural_signature(root: ClusteredMatrix) -> tuple:
    """Canonical hashable description of the DAG's structure + shapes."""
    return structural_signature_many((root,))


def structural_signature_many(roots: Sequence[ClusteredMatrix]) -> tuple:
    """Structural signature of a multi-root program: the union DAG's
    node signature plus each root's index into it (two programs match
    only if they compute the same outputs of the same structure)."""
    index: Dict[int, int] = {}
    sig: List[tuple] = []
    for i, node in enumerate(topo_order_many(roots)):
        index[node.uid] = i
        sig.append((node.op.value, node.shape, str(node.dtype),
                    _structure_payload_key(node),
                    tuple(index[p.uid] for p in node.parents)))
    return tuple(sig) + (("roots",) + tuple(index[r.uid] for r in roots),)


def residency_layout(roots: Sequence[ClusteredMatrix]) -> tuple:
    """The plan-cache key component for resident leaves: per RESIDENT leaf
    (in topo order), its handle's tile size and per-tile home nodes.  Two
    structurally equal programs share a schedule only when their resident
    tiles sit on the same nodes — pinned placements depend on it."""
    out: List[tuple] = []
    for i, node in enumerate(topo_order_many(roots)):
        if node.op is Op.RESIDENT:
            h = node.payload
            out.append((i, h.tile, tuple(sorted(h.home.items()))))
    return tuple(out)


def leaves_in_order(root: ClusteredMatrix) -> List[ClusteredMatrix]:
    """Leaves in canonical topo order — the rebinding contract between two
    DAGs with equal structural signatures."""
    return leaves_in_order_many((root,))


def leaves_in_order_many(roots: Sequence[ClusteredMatrix]
                         ) -> List[ClusteredMatrix]:
    return [n for n in topo_order_many(roots) if n.op in LEAF_OPS]
