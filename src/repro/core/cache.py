"""Node-level cache (CMM §3.5).

When a tile produced on node A is consumed on node B, the transferred copy is
kept in B's main memory.  Subsequent consumers of the *same tile version* on B
incur zero communication.  A tile version is identified by the producer task
id — accumulation chains (addmul) create a new version per step, so stale
partial sums are never reused.

An optional byte-capacity turns the cache into an LRU (the paper's cache is
unbounded main memory; capacity is exposed for experiments).  Byte totals are
maintained incrementally — put/evict/invalidate update a running per-node
counter instead of re-summing the table — and entries the planner has
scheduled an XFER around can be ``pin``-ned eviction-exempt, mirroring the
worker-arena pinning rules.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Hashable, Optional, Tuple

Key = Tuple[int, int]  # (producer task id, tile tensor uid) — see heft.py


class NodeCache:
    def __init__(self, n_nodes: int, capacity_bytes: Optional[int] = None):
        self.n_nodes = n_nodes
        self.capacity = capacity_bytes
        self._c: Dict[int, OrderedDict] = {n: OrderedDict()
                                           for n in range(n_nodes)}
        self._bytes: Dict[int, int] = {n: 0 for n in range(n_nodes)}
        self._pins: Dict[Hashable, int] = {}
        self.hits = 0
        self.misses = 0

    def has(self, node: int, key: Hashable) -> bool:
        c = self._c[node]
        if key in c:
            c.move_to_end(key)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def peek(self, node: int, key: Hashable) -> bool:
        """has() without touching hit/miss counters or LRU order."""
        return key in self._c[node]

    def put(self, node: int, key: Hashable, nbytes: int = 0):
        c = self._c[node]
        old = c.pop(key, None)
        if old is not None:
            self._bytes[node] -= old
        c[key] = nbytes
        self._bytes[node] += nbytes
        if self.capacity is not None and self._bytes[node] > self.capacity:
            for k in list(c.keys()):
                if self._bytes[node] <= self.capacity or len(c) <= 1:
                    break
                if k == key or self._pins.get(k):
                    continue  # never evict the fresh entry or pinned ones
                self._bytes[node] -= c.pop(k)

    def pin(self, key: Hashable):
        """Exempt every node's copy of ``key`` from capacity eviction —
        used for entries a scheduled XFER was planned around.  Refcounted;
        pair with ``unpin``."""
        self._pins[key] = self._pins.get(key, 0) + 1

    def unpin(self, key: Hashable):
        n = self._pins.get(key, 0) - 1
        if n <= 0:
            self._pins.pop(key, None)
        else:
            self._pins[key] = n

    def pinned(self, key: Hashable) -> bool:
        return bool(self._pins.get(key))

    def invalidate(self, key: Hashable):
        for n, c in self._c.items():
            old = c.pop(key, None)
            if old is not None:
                self._bytes[n] -= old

    def bytes_at(self, node: int) -> int:
        return self._bytes[node]

    def snapshot(self) -> Dict[int, int]:
        return {n: len(c) for n, c in self._c.items()}

    def clone(self) -> "NodeCache":
        nc = NodeCache(self.n_nodes, self.capacity)
        for n, c in self._c.items():
            nc._c[n] = OrderedDict(c)
            nc._bytes[n] = self._bytes[n]
        nc._pins = dict(self._pins)
        return nc
