"""Node-level cache (CMM §3.5).

When a tile produced on node A is consumed on node B, the transferred copy is
kept in B's main memory.  Subsequent consumers of the *same tile version* on B
incur zero communication.  A tile version is identified by the producer task
id — accumulation chains (addmul) create a new version per step, so stale
partial sums are never reused.

An optional byte-capacity turns the cache into an LRU (the paper's cache is
unbounded main memory; capacity is exposed for experiments).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Hashable, Optional, Tuple

Key = Tuple[int, int]  # (producer task id, tile tensor uid) — see heft.py


class NodeCache:
    def __init__(self, n_nodes: int, capacity_bytes: Optional[int] = None):
        self.n_nodes = n_nodes
        self.capacity = capacity_bytes
        self._c: Dict[int, OrderedDict] = {n: OrderedDict()
                                           for n in range(n_nodes)}
        self.hits = 0
        self.misses = 0

    def has(self, node: int, key: Hashable) -> bool:
        c = self._c[node]
        if key in c:
            c.move_to_end(key)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def peek(self, node: int, key: Hashable) -> bool:
        """has() without touching hit/miss counters or LRU order."""
        return key in self._c[node]

    def put(self, node: int, key: Hashable, nbytes: int = 0):
        c = self._c[node]
        c[key] = nbytes
        c.move_to_end(key)
        if self.capacity is not None:
            total = sum(c.values())
            while total > self.capacity and len(c) > 1:
                _, evicted = c.popitem(last=False)
                total -= evicted

    def invalidate(self, key: Hashable):
        for c in self._c.values():
            c.pop(key, None)

    def bytes_at(self, node: int) -> int:
        return sum(self._c[node].values())

    def snapshot(self) -> Dict[int, int]:
        return {n: len(c) for n, c in self._c.items()}

    def clone(self) -> "NodeCache":
        nc = NodeCache(self.n_nodes, self.capacity)
        for n, c in self._c.items():
            nc._c[n] = OrderedDict(c)
        return nc
