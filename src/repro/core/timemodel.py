"""Time-prediction model (CMM §3.4, Table 1).

Each task kind has an interpolation equation — a multivariate polynomial in
the operand dimensions — whose coefficients are fitted by ordinary least
squares on offline-profiled timings:

    (n,1)  op (n,1)   +,-,x      a0 + a1*n
    (m,n)      sin,cos           a0 + a1*n + a2*m + a3*m*n
    (m,n)  op scalar  +,-,x,/    a0 + a1*n + a2*m + a3*m*n
    (m,n)  op (m,n)   +,-,x      a0 + a1*n + a2*m + a3*m*n
    (m,n)  x  (n,k)              a0 + a1*m + a2*n + a3*k + a4*mn + a5*nk
                                    + a6*mk + a7*mnk

Communication time is modelled per node pair: latency + bytes / pair
bandwidth (the paper's §3.4 fix after the one-worker-only pathology).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import Task, TaskKind
from .machine import ClusterSpec


def features_ewise(dims: Sequence[int]) -> np.ndarray:
    m, n = dims
    return np.array([1.0, n, m, m * n])


def features_matmul(dims: Sequence[int]) -> np.ndarray:
    m, n, k = dims
    return np.array([1.0, m, n, k, m * n, n * k, m * k, m * n * k])


FEATURES = {
    "ewise": features_ewise,    # all (m,n)-shaped kinds
    "matmul": features_matmul,  # (m,n)x(n,k) kinds
}

#: task kind -> feature family
KIND_FAMILY = {
    TaskKind.ADDMUL: "matmul",
    TaskKind.MATMUL: "matmul",
    TaskKind.ADD: "ewise",
    TaskKind.SUB: "ewise",
    TaskKind.EWMUL: "ewise",
    TaskKind.SCALE: "ewise",
    TaskKind.EWISE: "ewise",
    TaskKind.TRANSPOSE: "ewise",
    TaskKind.FUSED: "ewise",
    TaskKind.CALLOC: "ewise",
    TaskKind.FILL: "ewise",
    TaskKind.TAKECOPY: "ewise",
}


@dataclass
class PolyModel:
    """One fitted interpolation equation."""

    family: str
    coef: np.ndarray

    def predict(self, dims: Sequence[int]) -> float:
        x = FEATURES[self.family](dims)
        return float(max(x @ self.coef, 1e-9))

    @staticmethod
    def fit(family: str, dims_list: Sequence[Sequence[int]],
            times: Sequence[float]) -> "PolyModel":
        X = np.stack([FEATURES[family](d) for d in dims_list])
        y = np.asarray(times, dtype=np.float64)
        # OLS via lstsq (the paper's ordinary-least-squares regression)
        coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        return PolyModel(family, coef)

    def r2(self, dims_list, times) -> float:
        y = np.asarray(times)
        pred = np.array([self.predict(d) for d in dims_list])
        ss_res = float(((y - pred) ** 2).sum())
        ss_tot = float(((y - y.mean()) ** 2).sum()) or 1.0
        return 1.0 - ss_res / ss_tot


@dataclass
class TimeModel:
    """Per-kind compute models + the per-pair communication model."""

    models: Dict[str, PolyModel] = field(default_factory=dict)
    #: overhead multiplier for scheduling/dispatch (fitted or 1.0)
    dispatch_overhead: float = 0.0
    #: throughput scale observed under concurrent workers (profiling times
    #: one call at a time; real execution oversubscribes BLAS threads on a
    #: shared host — fitted by ``profiler.calibrate_contention``)
    contention: float = 1.0

    def compute_time(self, task: Task, spec: Optional[ClusterSpec] = None,
                     node: int = 0) -> float:
        kind = task.kind
        if kind in (TaskKind.SEND, TaskKind.RECV):
            raise ValueError("comm tasks are costed by comm_time()")
        family = KIND_FAMILY[kind]
        key = kind.value
        model = self.models.get(key) or self.models.get(family)
        if model is None:
            # analytic fallback: ~1 GFLOP/s effective if unprofiled
            flops = max(task.flops, int(np.prod(task.dims())))
            t = flops / 1e9
        else:
            t = model.predict(task.dims())
            if kind is TaskKind.FUSED:
                # a fused region does N elementwise passes' arithmetic in
                # one task (with better locality; the single-pass model
                # per op is a conservative upper bound)
                from .fusion import fused_op_count
                t *= max(1, fused_op_count(task.payload))
        t = t * self.contention + self.dispatch_overhead
        if spec is not None:
            t *= spec.node_slowdown(node)
        return t

    def comm_time(self, nbytes: int, src: int, dst: int,
                  spec: ClusterSpec) -> float:
        return spec.comm_time(nbytes, src, dst)

    # -- (de)serialisation --------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "dispatch_overhead": self.dispatch_overhead,
            "contention": self.contention,
            "models": {k: {"family": m.family, "coef": m.coef.tolist()}
                       for k, m in self.models.items()},
        })

    @staticmethod
    def from_json(s: str) -> "TimeModel":
        d = json.loads(s)
        return TimeModel(
            models={k: PolyModel(v["family"], np.asarray(v["coef"]))
                    for k, v in d["models"].items()},
            dispatch_overhead=d.get("dispatch_overhead", 0.0),
            contention=d.get("contention", 1.0),
        )

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())

    @staticmethod
    def load(path: str) -> "TimeModel":
        with open(path) as f:
            return TimeModel.from_json(f.read())


def analytic_time_model(gflops: float = 5.5, mem_gbs: float = 10.0,
                        base_us: float = 30.0) -> TimeModel:
    """A synthetic time model from machine constants (no profiling).

    Matches the paper's observed ~5.5 GFLOPS/worker-process plateau (Table 2).
    Used when offline profiles are unavailable (e.g. pure-simulation tests).
    """
    tm = TimeModel()
    a0 = base_us * 1e-6
    # matmul: time = flops / rate -> coefficient only on the mnk term
    c = np.zeros(8)
    c[0] = a0
    c[7] = 2.0 / (gflops * 1e9)
    tm.models["matmul"] = PolyModel("matmul", c)
    # ewise family: bandwidth-bound, 8 B/elem in + 8 out
    e = np.zeros(4)
    e[0] = a0
    e[3] = 16.0 / (mem_gbs * 1e9)
    tm.models["ewise"] = PolyModel("ewise", e)
    return tm
