"""Time-prediction model (CMM §3.4, Table 1).

Each task kind has an interpolation equation — a multivariate polynomial in
the operand dimensions — whose coefficients are fitted by ordinary least
squares on offline-profiled timings:

    (n,1)  op (n,1)   +,-,x      a0 + a1*n
    (m,n)      sin,cos           a0 + a1*n + a2*m + a3*m*n
    (m,n)  op scalar  +,-,x,/    a0 + a1*n + a2*m + a3*m*n
    (m,n)  op (m,n)   +,-,x      a0 + a1*n + a2*m + a3*m*n
    (m,n)  x  (n,k)              a0 + a1*m + a2*n + a3*k + a4*mn + a5*nk
                                    + a6*mk + a7*mnk

Communication time is modelled per node pair: latency + bytes / pair
bandwidth (the paper's §3.4 fix after the one-worker-only pathology).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import Task, TaskKind, matmul_epilogue
from .machine import ClusterSpec


def features_ewise(dims: Sequence[int]) -> np.ndarray:
    m, n = dims
    return np.array([1.0, n, m, m * n])


def features_matmul(dims: Sequence[int]) -> np.ndarray:
    m, n, k = dims
    return np.array([1.0, m, n, k, m * n, n * k, m * k, m * n * k])


FEATURES = {
    "ewise": features_ewise,    # all (m,n)-shaped kinds
    "matmul": features_matmul,  # (m,n)x(n,k) kinds
}

#: task kind -> feature family
KIND_FAMILY = {
    TaskKind.ADDMUL: "matmul",
    TaskKind.MATMUL: "matmul",
    TaskKind.ADD: "ewise",
    TaskKind.SUB: "ewise",
    TaskKind.EWMUL: "ewise",
    TaskKind.SCALE: "ewise",
    TaskKind.EWISE: "ewise",
    TaskKind.TRANSPOSE: "ewise",
    TaskKind.FUSED: "ewise",
    TaskKind.CALLOC: "ewise",
    TaskKind.FILL: "ewise",
    TaskKind.TAKECOPY: "ewise",
    TaskKind.RESIDENT: "ewise",   # backstop only: planning special-cases
                                  # RESIDENT to ~0 like CALLOC
}


@dataclass
class PolyModel:
    """One fitted interpolation equation."""

    family: str
    coef: np.ndarray

    def predict(self, dims: Sequence[int]) -> float:
        # NOTE: planning deliberately evaluates this SCALAR path (memoized
        # per unique signature in CostCache) rather than a stacked matvec —
        # BLAS matvec rounding differs from per-row dot in the last ulp,
        # which would break the bit-identical fast/slow-schedule invariant.
        x = FEATURES[self.family](dims)
        return float(max(x @ self.coef, 1e-9))

    @staticmethod
    def fit(family: str, dims_list: Sequence[Sequence[int]],
            times: Sequence[float]) -> "PolyModel":
        X = np.stack([FEATURES[family](d) for d in dims_list])
        y = np.asarray(times, dtype=np.float64)
        # OLS via lstsq (the paper's ordinary-least-squares regression)
        coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        return PolyModel(family, coef)

    def r2(self, dims_list, times) -> float:
        y = np.asarray(times)
        pred = np.array([self.predict(d) for d in dims_list])
        ss_res = float(((y - pred) ** 2).sum())
        ss_tot = float(((y - y.mean()) ** 2).sum()) or 1.0
        return 1.0 - ss_res / ss_tot


#: model terms the drift report (``core/drift.py``) can evidence from
#: measured spans: ``kernel_time`` from EXEC spans, ``ipc_bandwidth``
#: from raw XFER spans, ``compress_bandwidth`` from PACK spans, the
#: spill bandwidths from SPILL/FAULTIN spans.
DRIFT_TERMS = ("kernel_time", "ipc_bandwidth", "compress_bandwidth",
               "spill_read_bandwidth", "spill_write_bandwidth")


@dataclass
class TimeModel:
    """Per-kind compute models + the per-pair communication model."""

    models: Dict[str, PolyModel] = field(default_factory=dict)
    #: per-task scheduling/dispatch overhead, seconds (heap pop, closure,
    #: lock round-trip per submitted task — fitted by
    #: ``profiler.calibrate_dispatch``)
    dispatch_overhead: float = 0.0
    #: per-*batched-kernel-launch* overhead, seconds: one stacked call
    #: issued by the wave executor pays this ONCE per group instead of
    #: ``dispatch_overhead`` once per task (fitted by
    #: ``profiler.calibrate_batch_dispatch``)
    batch_dispatch_overhead: float = 1e-4
    #: throughput scale observed under concurrent workers (profiling times
    #: one call at a time; real execution oversubscribes BLAS threads on a
    #: shared host — fitted by ``profiler.calibrate_contention``)
    contention: float = 1.0
    #: per-task overhead of the multi-process cluster executor, seconds:
    #: one dispatch-queue round trip (pickle, pipe write, wakeup, ack) per
    #: task instead of the in-process ``dispatch_overhead`` — fitted by
    #: ``profiler.calibrate_ipc``
    process_dispatch_overhead: float = 5e-4
    #: shared-memory inter-process tile-copy throughput, bytes/s (the
    #: ClusterExecutor's XFER cost is ``ipc_latency + bytes/ipc_bandwidth``
    #: instead of the network link model — fitted by
    #: ``profiler.calibrate_ipc``)
    ipc_bandwidth: float = 2e9
    #: per-XFER message latency of the cluster executor, seconds
    ipc_latency: float = 2e-4
    #: mean time between failures of one (non-master) node, seconds — the
    #: churn model the elastic runtime prices ``auto`` selection with
    #: (``simulator.churn_adjusted_makespan``).  ``inf`` = assume a
    #: pristine cluster (the static executors' implicit assumption).
    node_mtbf: float = float("inf")
    #: fixed wall-clock cost of one recovery event, seconds: failure
    #: detection (heartbeat patience) + frontier re-plan + respawn/rewire
    respawn_overhead: float = 0.5
    #: sequential disk read bandwidth for reloading checkpointed tiles,
    #: bytes/s — prices the reload-from-disk leg of the durable session's
    #: restore path (``simulator.predict_reload_seconds``) against
    #: lineage recompute
    spill_read_bandwidth: float = 1e9
    #: sequential disk write bandwidth for evicting tiles from a bounded
    #: arena to the spill tier, bytes/s — prices out-of-core execution
    #: (``simulator.predict_spill_seconds``) so the engine's admission
    #: check can *choose* spilling over rejection
    spill_write_bandwidth: float = 1e9
    #: fixed steady-state cost one asynchronous tile snapshot adds to the
    #: session path, seconds (the writer handoff — the host-side copy is
    #: priced separately at ``spill_read_bandwidth`` and the disk write
    #: itself overlaps the next compute)
    checkpoint_write_overhead: float = 1e-3
    #: wire-codec encode throughput, bytes of *raw* tile per second
    #: (``runtime.wire`` zlib path — fitted by
    #: ``profiler.calibrate_compression``).  ``0`` = codec unprofiled/
    #: disabled: per-edge pricing always chooses ``"raw"`` and the
    #: transfer path is byte-for-byte the pre-codec one.
    compress_bandwidth: float = 0.0
    #: expected raw/compressed size ratio of a typical tile payload under
    #: the wire codec (data-dependent; fitted on a structured probe tile
    #: by ``calibrate_compression``).  ``1.0`` = assume incompressible.
    compression_ratio_prior: float = 1.0

    def _model_time(self, task: Task) -> float:
        """Raw interpolation-model prediction for one task (no contention,
        dispatch, or node slowdown applied)."""
        kind = task.kind
        if kind in (TaskKind.SEND, TaskKind.RECV):
            raise ValueError("comm tasks are costed by comm_time()")
        if kind is TaskKind.RESIDENT:
            # binding an already-resident tile is a dict lookup, not work
            return 1e-9
        family = KIND_FAMILY[kind]
        model = self.models.get(kind.value) or self.models.get(family)
        if model is None:
            # analytic fallback: ~1 GFLOP/s effective if unprofiled
            flops = max(task.flops, int(np.prod(task.dims())))
            return flops / 1e9
        t = model.predict(task.dims())
        if kind is TaskKind.FUSED:
            # a fused region does N elementwise passes' arithmetic in
            # one task (with better locality; the single-pass model
            # per op is a conservative upper bound)
            from .fusion import fused_op_count
            t *= max(1, fused_op_count(task.payload))
        elif kind in (TaskKind.ADDMUL, TaskKind.MATMUL):
            t += self._epilogue_time(task)
        return t

    def _epilogue_time(self, task: Task) -> float:
        """Extra arithmetic of a fused matmul epilogue: N elementwise
        passes over the output tile, priced with the ewise-family model
        (same accounting a standalone FUSED task would get)."""
        epi = matmul_epilogue(task.payload)
        if epi is None:
            return 0.0
        from .fusion import fused_flops, fused_op_count
        m, n, k = task.dims()
        shape = (m, k)                       # the output tile
        em = self.models.get(TaskKind.FUSED.value) or self.models.get("ewise")
        if em is None:
            return fused_flops(epi, *shape) / 1e9
        return max(1, fused_op_count(epi)) * em.predict(shape)

    def kernel_time(self, task: Task, spec: Optional[ClusterSpec] = None,
                    node: int = 0) -> float:
        """Pure arithmetic time of ``task`` — NO per-task dispatch overhead.

        This is what one slice of a batched (stacked) kernel call costs; the
        wave executor's cost model sums it per group and adds
        ``batch_dispatch_overhead`` once per launch.
        """
        t = self._model_time(task) * self.contention
        if spec is not None:
            t *= spec.node_slowdown(node)
        return t

    def compute_time(self, task: Task, spec: Optional[ClusterSpec] = None,
                     node: int = 0) -> float:
        """Per-task execution time as the per-task executor pays it:
        arithmetic + one dispatch overhead."""
        t = self._model_time(task) * self.contention + self.dispatch_overhead
        if spec is not None:
            t *= spec.node_slowdown(node)
        return t

    def comm_time(self, nbytes: int, src: int, dst: int,
                  spec: ClusterSpec) -> float:
        return spec.comm_time(nbytes, src, dst)

    def wire_time(self, nbytes: int, src: int, dst: int,
                  spec: ClusterSpec) -> float:
        """Codec-aware edge time: ``min(raw, compress_cpu + compressed
        transfer)`` under the fitted codec priors.  Degrades exactly to
        ``spec.comm_time`` while the priors are unfitted, so schedules
        and simulations are unchanged by default."""
        base = spec.comm_time(nbytes, src, dst)
        if (src == dst or nbytes <= 0 or self.compress_bandwidth <= 0.0
                or self.compression_ratio_prior <= 1.0):
            return base
        comp = (nbytes / self.compress_bandwidth
                + spec.comm_time(int(nbytes / self.compression_ratio_prior),
                                 src, dst))
        return min(base, comp)

    # -- drift recalibration ------------------------------------------------
    def recalibrated(self, term: str, ratio: float) -> "TimeModel":
        """Copy of this model with one drift term refitted by an observed
        actual/predicted time ratio (``core/drift.py``'s suggestion).

        ``kernel_time`` scales every per-kind polynomial by ``ratio``
        (work took ratio-x the predicted time); bandwidth terms divide
        by it (time is inversely proportional to throughput).  The
        original model is untouched — recalibration is an explicit new
        model, so plan caches keyed on ``to_json()`` invalidate.
        """
        if not ratio > 0.0:
            raise ValueError(f"ratio must be positive, got {ratio}")
        if term == "kernel_time":
            models = {k: PolyModel(m.family, m.coef * ratio)
                      for k, m in self.models.items()}
            return replace(self, models=models)
        if term not in DRIFT_TERMS:
            raise ValueError(f"unknown drift term {term!r}; "
                             f"known: {DRIFT_TERMS}")
        return replace(self, **{term: getattr(self, term) / ratio})

    # -- (de)serialisation --------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "dispatch_overhead": self.dispatch_overhead,
            "batch_dispatch_overhead": self.batch_dispatch_overhead,
            "contention": self.contention,
            "process_dispatch_overhead": self.process_dispatch_overhead,
            "ipc_bandwidth": self.ipc_bandwidth,
            "ipc_latency": self.ipc_latency,
            # json emits inf as the (non-standard but round-tripping)
            # Infinity literal; keep it explicit for readability
            "node_mtbf": self.node_mtbf,
            "respawn_overhead": self.respawn_overhead,
            "spill_read_bandwidth": self.spill_read_bandwidth,
            "spill_write_bandwidth": self.spill_write_bandwidth,
            "checkpoint_write_overhead": self.checkpoint_write_overhead,
            "compress_bandwidth": self.compress_bandwidth,
            "compression_ratio_prior": self.compression_ratio_prior,
            "models": {k: {"family": m.family, "coef": m.coef.tolist()}
                       for k, m in self.models.items()},
        })

    @staticmethod
    def from_json(s: str) -> "TimeModel":
        d = json.loads(s)
        return TimeModel(
            models={k: PolyModel(v["family"], np.asarray(v["coef"]))
                    for k, v in d["models"].items()},
            dispatch_overhead=d.get("dispatch_overhead", 0.0),
            batch_dispatch_overhead=d.get("batch_dispatch_overhead", 1e-4),
            contention=d.get("contention", 1.0),
            process_dispatch_overhead=d.get("process_dispatch_overhead",
                                            5e-4),
            ipc_bandwidth=d.get("ipc_bandwidth", 2e9),
            ipc_latency=d.get("ipc_latency", 2e-4),
            node_mtbf=d.get("node_mtbf", float("inf")),
            respawn_overhead=d.get("respawn_overhead", 0.5),
            spill_read_bandwidth=d.get("spill_read_bandwidth", 1e9),
            spill_write_bandwidth=d.get("spill_write_bandwidth", 1e9),
            checkpoint_write_overhead=d.get("checkpoint_write_overhead",
                                            1e-3),
            compress_bandwidth=d.get("compress_bandwidth", 0.0),
            compression_ratio_prior=d.get("compression_ratio_prior", 1.0),
        )

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())

    @staticmethod
    def load(path: str) -> "TimeModel":
        with open(path) as f:
            return TimeModel.from_json(f.read())


class CostCache:
    """Memoized task compute times for one ``(TimeModel, ClusterSpec)`` pair.

    Planning a 100k-task graph evaluates the interpolation polynomials
    O(tasks x nodes) times, but a tiled program has only a handful of
    distinct ``(kind, operand dims, payload class)`` signatures — one per
    tile shape per kind.  The cache collapses the polynomial evaluations to
    one per unique ``(signature, node)``, which is what makes the HEFT fast
    path scale (§3.6 planning at 100k tasks).

    Predictions are computed with the *scalar* ``PolyModel.predict`` so a
    cached cost is bit-identical to the uncached path — fast and slow
    planning produce identical schedules.
    """

    __slots__ = ("tm", "spec", "_time", "_kernel", "_avg")

    def __init__(self, tm: "TimeModel", spec: Optional[ClusterSpec] = None):
        self.tm = tm
        self.spec = spec
        self._time: Dict[tuple, float] = {}
        self._kernel: Dict[tuple, float] = {}
        self._avg: Dict[tuple, float] = {}

    @staticmethod
    def signature(task: Task) -> tuple:
        extra = None
        if task.kind is TaskKind.FUSED:
            from .fusion import fused_op_count
            extra = fused_op_count(task.payload)
        elif task.kind in (TaskKind.ADDMUL, TaskKind.MATMUL):
            epi = matmul_epilogue(task.payload)
            if epi is not None:
                # the pricing reads the op count (fitted-model path) and
                # the per-element flop weight (analytic fallback); key on
                # both so cached and uncached predictions always agree
                from .fusion import fused_flops, fused_op_count
                extra = ("epi", fused_op_count(epi), fused_flops(epi, 1, 1))
        return (task.kind, task.dims(), extra)

    def time(self, task: Task, node: int = 0) -> float:
        """Memoized ``tm.compute_time(task, spec, node)``."""
        key = (self.signature(task), node)
        v = self._time.get(key)
        if v is None:
            v = self.tm.compute_time(task, self.spec, node)
            self._time[key] = v
        return v

    def kernel(self, task: Task, node: int = 0) -> float:
        """Memoized ``tm.kernel_time(task, spec, node)``."""
        key = (self.signature(task), node)
        v = self._kernel.get(key)
        if v is None:
            v = self.tm.kernel_time(task, self.spec, node)
            self._kernel[key] = v
        return v

    def avg(self, task: Task) -> float:
        """Memoized average compute time over all nodes (upward-rank ``w``).

        Reproduces the exact summation order of the unmemoized
        ``sum(costs) / len(costs)`` loop so ranks are bit-identical.
        """
        sig = self.signature(task)
        v = self._avg.get(sig)
        if v is None:
            n = self.spec.n_nodes if self.spec is not None else 1
            costs = [self.time(task, i) for i in range(n)]
            v = sum(costs) / len(costs)
            self._avg[sig] = v
        return v


def analytic_time_model(gflops: float = 5.5, mem_gbs: float = 10.0,
                        base_us: float = 30.0) -> TimeModel:
    """A synthetic time model from machine constants (no profiling).

    Matches the paper's observed ~5.5 GFLOPS/worker-process plateau (Table 2).
    Used when offline profiles are unavailable (e.g. pure-simulation tests).
    """
    tm = TimeModel()
    a0 = base_us * 1e-6
    # matmul: time = flops / rate -> coefficient only on the mnk term
    c = np.zeros(8)
    c[0] = a0
    c[7] = 2.0 / (gflops * 1e9)
    tm.models["matmul"] = PolyModel("matmul", c)
    # ewise family: bandwidth-bound, 8 B/elem in + 8 out
    e = np.zeros(4)
    e[0] = a0
    e[3] = 16.0 / (mem_gbs * 1e9)
    tm.models["ewise"] = PolyModel("ewise", e)
    return tm
