"""ClusteredMatrix: the paper's lazy matrix type (CMM §3, Fig. 2).

User-level matrix expressions build an expression DAG instead of evaluating
eagerly.  ``compute()`` hands the DAG to the engine, which tiles it into a
task-dependency graph, schedules it with cache-aware HEFT, simulates the
schedule, and executes it.

The type mirrors the paper's Julia ``ClusteredMatrix``: every object has a
unique id, represents a node in the expression graph, and carries shape/dtype
metadata only — no data until materialisation (inputs hold their generator).
"""
from __future__ import annotations

import enum
import itertools
import threading
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence, Tuple

import numpy as np


class Op(enum.Enum):
    """Expression-level operators (pre-tiling)."""

    INPUT = "input"          # materialised data supplied by the user
    RANDOM = "random"        # random matrix generated from dims (paper's P, u)
    ZEROS = "zeros"
    EYE = "eye"
    ADD = "add"
    SUB = "sub"
    MATMUL = "matmul"        # the paper's ``x`` on (m,n)x(n,k)
    EWMUL = "ewmul"          # Hadamard
    SCALE = "scale"          # matrix (+,-,x,/) scalar — Table 1 row 4
    EWISE = "ewise"          # unary sin/cos/... — Table 1 row 3
    TRANSPOSE = "transpose"
    FUSED = "fused"          # optimizer-generated elementwise region
                             # (payload: instruction tuple, see core.fusion)
    RESIDENT = "resident"    # session-resident leaf: tiles already live in
                             # the executor's arenas (payload: ResidentHandle,
                             # see core.session) — no FILL, no data movement


#: unary elementwise functions supported by Op.EWISE (Table 1 row 3)
EWISE_FNS = {
    "sin": np.sin,
    "cos": np.cos,
    "exp": np.exp,
    "tanh": np.tanh,
    "abs": np.abs,
    "relu": lambda x: np.maximum(x, 0.0),
    "sqrt": np.sqrt,
    "sign": np.sign,
}

_id_counter = itertools.count()
_id_lock = threading.Lock()


def _next_id() -> int:
    with _id_lock:
        return next(_id_counter)


@dataclass
class ClusteredMatrix:
    """A lazy 2-D matrix expression node (CMM's ClusteredMatrix)."""

    op: Op
    shape: Tuple[int, int]
    dtype: np.dtype = field(default_factory=lambda: np.dtype(np.float64))
    parents: Tuple["ClusteredMatrix", ...] = ()
    #: op-specific payload: ndarray for INPUT, seed for RANDOM, fn name for
    #: EWISE, float for SCALE (+ the scalar op kind).
    payload: object = None
    name: str = ""
    uid: int = field(default_factory=_next_id)

    # -- constructors -----------------------------------------------------
    @staticmethod
    def from_array(a, name: str = "") -> "ClusteredMatrix":
        a = np.asarray(a)
        if a.ndim == 1:
            a = a.reshape(-1, 1)
        if a.ndim != 2:
            raise ValueError(f"ClusteredMatrix is 2-D, got shape {a.shape}")
        return ClusteredMatrix(Op.INPUT, a.shape, a.dtype, payload=a, name=name)

    @staticmethod
    def rand(m: int, n: int, seed: int = 0, dtype=np.float64,
             name: str = "") -> "ClusteredMatrix":
        return ClusteredMatrix(Op.RANDOM, (m, n), np.dtype(dtype),
                               payload=int(seed), name=name)

    @staticmethod
    def zeros(m: int, n: int, dtype=np.float64, name: str = "") -> "ClusteredMatrix":
        return ClusteredMatrix(Op.ZEROS, (m, n), np.dtype(dtype), name=name)

    @staticmethod
    def eye(n: int, dtype=np.float64, name: str = "") -> "ClusteredMatrix":
        return ClusteredMatrix(Op.EYE, (n, n), np.dtype(dtype), name=name)

    # -- metadata ----------------------------------------------------------
    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    def _binop(self, other: "ClusteredMatrix", op: Op) -> "ClusteredMatrix":
        if not isinstance(other, ClusteredMatrix):
            # scalar broadcast (Table 1 row 4)
            return ClusteredMatrix(Op.SCALE, self.shape, self.dtype,
                                   parents=(self,),
                                   payload=(op.value, float(other)))
        if op in (Op.ADD, Op.SUB, Op.EWMUL) and self.shape != other.shape:
            raise ValueError(f"shape mismatch {self.shape} vs {other.shape}")
        dtype = np.promote_types(self.dtype, other.dtype)
        return ClusteredMatrix(op, self.shape, dtype, parents=(self, other))

    # -- operators ----------------------------------------------------------
    def __add__(self, other):
        return self._binop(other, Op.ADD)

    def __radd__(self, other):
        return self._binop(other, Op.ADD)

    def __sub__(self, other):
        return self._binop(other, Op.SUB)

    def __mul__(self, other):
        """Paper semantics: ``x`` between matrices is matmul; with a scalar,
        elementwise scale (Table 1 rows 1/4/6)."""
        if isinstance(other, ClusteredMatrix):
            return self.__matmul__(other)
        return self._binop(other, Op.SCALE)

    def __rmul__(self, other):
        return self._binop(other, Op.SCALE)

    def __rsub__(self, other):
        """``s - M`` — scalar-minus-matrix (Table 1 row 4, reflected)."""
        if isinstance(other, ClusteredMatrix):    # pragma: no cover — __sub__
            return other._binop(self, Op.SUB)     # handles matrix - matrix
        return ClusteredMatrix(Op.SCALE, self.shape, self.dtype,
                               parents=(self,), payload=("rsub", float(other)))

    def __truediv__(self, other):
        if isinstance(other, ClusteredMatrix):
            raise TypeError("matrix / matrix is not a CMM operator")
        return ClusteredMatrix(Op.SCALE, self.shape, self.dtype,
                               parents=(self,), payload=("div", float(other)))

    def __rtruediv__(self, other):
        """``s / M`` — elementwise scalar-over-matrix."""
        if isinstance(other, ClusteredMatrix):    # pragma: no cover
            raise TypeError("matrix / matrix is not a CMM operator")
        return ClusteredMatrix(Op.SCALE, self.shape, self.dtype,
                               parents=(self,), payload=("rdiv", float(other)))

    def __neg__(self):
        """``-M`` == ``M * -1.0`` (bitwise: IEEE-754 negation is exactly a
        sign-bit flip, and so is multiplication by -1.0)."""
        return ClusteredMatrix(Op.SCALE, self.shape, self.dtype,
                               parents=(self,), payload=("scale", -1.0))

    def __matmul__(self, other: "ClusteredMatrix") -> "ClusteredMatrix":
        if not isinstance(other, ClusteredMatrix):
            raise TypeError("@ needs a ClusteredMatrix")
        if self.n != other.m:
            raise ValueError(
                f"matmul inner-dim mismatch: {self.shape} @ {other.shape}")
        dtype = np.promote_types(self.dtype, other.dtype)
        return ClusteredMatrix(Op.MATMUL, (self.m, other.n), dtype,
                               parents=(self, other))

    def hadamard(self, other: "ClusteredMatrix") -> "ClusteredMatrix":
        return self._binop(other, Op.EWMUL)

    @property
    def T(self) -> "ClusteredMatrix":
        return ClusteredMatrix(Op.TRANSPOSE, (self.n, self.m), self.dtype,
                               parents=(self,))

    def ewise(self, fn: str) -> "ClusteredMatrix":
        if fn not in EWISE_FNS:
            raise ValueError(f"unknown elementwise fn {fn!r}")
        return ClusteredMatrix(Op.EWISE, self.shape, self.dtype,
                               parents=(self,), payload=fn)

    def sin(self):
        return self.ewise("sin")

    def cos(self):
        return self.ewise("cos")

    def relu(self):
        return self.ewise("relu")

    # -- evaluation ----------------------------------------------------------
    def compute(self, engine=None, **kw) -> np.ndarray:
        """Materialise through the CMM engine (tiling + HEFT + execution)."""
        if engine is None:
            from .engine import CMMEngine  # local import to avoid cycle
            engine = CMMEngine.default()
        return engine.run(self, **kw)

    def eager(self) -> np.ndarray:
        """Reference evaluation — direct recursive NumPy (the oracle)."""
        return eager_eval(self)

    # dataclass-generated __eq__ would recurse; identity semantics instead
    def __hash__(self):
        return self.uid

    def __eq__(self, other):
        return self is other

    def __repr__(self):
        ps = ",".join(str(p.uid) for p in self.parents)
        return (f"ClusteredMatrix(#{self.uid} {self.op.value} {self.shape} "
                f"{self.dtype} parents=[{ps}] {self.name})")


def topo_order(root: ClusteredMatrix) -> Sequence[ClusteredMatrix]:
    """Deterministic post-order DFS over the expression DAG."""
    return topo_order_many((root,))


def topo_order_many(roots: Sequence[ClusteredMatrix]
                    ) -> Sequence[ClusteredMatrix]:
    """Post-order DFS over the union of several roots' DAGs (shared
    subexpressions appear once) — the multi-root ``compute_many`` order."""
    seen, order = set(), []

    def visit(node: ClusteredMatrix):
        if node.uid in seen:
            return
        seen.add(node.uid)
        for p in node.parents:
            visit(p)
        order.append(node)

    for root in roots:
        visit(root)
    return order


#: canonical RNG block edge for RANDOM leaves.  Random data is DEFINED as a
#: grid of RNG_BLOCK x RNG_BLOCK blocks, block (bi, bj) drawn from
#: ``default_rng((seed, bi, bj))`` — a counter-based scheme, so any slice of
#: the matrix can be generated standalone (per-tile FILL in the executor)
#: and is bit-identical to the full materialisation used by ``eager()``,
#: whatever the execution tile size.  128 divides the common tile sizes
#: (256/384/512/...), so aligned tiles generate no excess numbers.
RNG_BLOCK = 128


def random_slice(seed: int, shape: Tuple[int, int], dtype,
                 r0: int, r1: int, c0: int, c1: int) -> np.ndarray:
    """Generate rows ``r0:r1`` x cols ``c0:c1`` of the canonical random
    matrix ``(seed, shape)`` without materialising the rest of it."""
    out = np.empty((r1 - r0, c1 - c0), dtype=dtype)
    m, n = shape
    B = RNG_BLOCK
    for bi in range(r0 // B, -(-r1 // B)):
        br0, br1 = bi * B, min((bi + 1) * B, m)
        for bj in range(c0 // B, -(-c1 // B)):
            bc0, bc1 = bj * B, min((bj + 1) * B, n)
            rng = np.random.default_rng((seed, bi, bj))
            blk = rng.standard_normal((br1 - br0, bc1 - bc0))
            ir0, ir1 = max(r0, br0), min(r1, br1)
            ic0, ic1 = max(c0, bc0), min(c1, bc1)
            out[ir0 - r0:ir1 - r0, ic0 - c0:ic1 - c0] = \
                blk[ir0 - br0:ir1 - br0, ic0 - bc0:ic1 - bc0]
    return out


def leaf_slice(node: ClusteredMatrix, r0: int, r1: int,
               c0: int, c1: int) -> np.ndarray:
    """One tile of a leaf, generated/sliced without touching other tiles.

    INPUT returns a *view* into the user array (zero-copy); RANDOM generates
    only the covering canonical blocks; ZEROS/EYE build just the tile.
    """
    if node.op is Op.INPUT:
        a = np.asarray(node.payload)
        if a.dtype != node.dtype:
            a = a.astype(node.dtype)
        return a[r0:r1, c0:c1]
    if node.op is Op.RANDOM:
        return random_slice(node.payload, node.shape, node.dtype,
                            r0, r1, c0, c1)
    if node.op is Op.ZEROS:
        return np.zeros((r1 - r0, c1 - c0), node.dtype)
    if node.op is Op.EYE:
        t = np.zeros((r1 - r0, c1 - c0), node.dtype)
        for k in range(max(r0, c0), min(r1, c1)):
            t[k - r0, k - c0] = 1
        return t
    if node.op is Op.RESIDENT:
        # fallback path only (session-gathered value sliced); the tiled
        # pipeline never FILLs a resident leaf — tiles are arena-bound
        return np.asarray(node.to_numpy())[r0:r1, c0:c1]
    raise ValueError(f"{node.op} is not a leaf")


def materialize_leaf(node: ClusteredMatrix) -> np.ndarray:
    """Produce the full ndarray for a leaf node (INPUT/RANDOM/ZEROS/EYE)."""
    if node.op is Op.INPUT:
        return np.asarray(node.payload, dtype=node.dtype)
    if node.op is Op.RANDOM:
        return random_slice(node.payload, node.shape, node.dtype,
                            0, node.shape[0], 0, node.shape[1])
    if node.op is Op.ZEROS:
        return np.zeros(node.shape, node.dtype)
    if node.op is Op.EYE:
        return np.eye(node.shape[0], dtype=node.dtype)
    if node.op is Op.RESIDENT:
        return np.asarray(node.to_numpy())
    raise ValueError(f"{node.op} is not a leaf")


def apply_scale(kind: str, x: np.ndarray, s: float) -> np.ndarray:
    if kind in ("add",):
        return x + s
    if kind in ("sub",):
        return x - s
    if kind == "rsub":
        return s - x
    if kind in ("scale", "mul", "ewmul"):
        return x * s
    if kind == "div":
        return x / s
    if kind == "rdiv":
        return s / x
    raise ValueError(f"unknown scalar op {kind}")


def eager_eval(root: ClusteredMatrix) -> np.ndarray:
    """Pure-NumPy oracle used to validate the tiled/scheduled execution."""
    vals = {}
    for node in topo_order(root):
        if node.op in (Op.INPUT, Op.RANDOM, Op.ZEROS, Op.EYE, Op.RESIDENT):
            vals[node.uid] = materialize_leaf(node)
        elif node.op is Op.ADD:
            vals[node.uid] = vals[node.parents[0].uid] + vals[node.parents[1].uid]
        elif node.op is Op.SUB:
            vals[node.uid] = vals[node.parents[0].uid] - vals[node.parents[1].uid]
        elif node.op is Op.EWMUL:
            vals[node.uid] = vals[node.parents[0].uid] * vals[node.parents[1].uid]
        elif node.op is Op.MATMUL:
            from .graph import matmul_epilogue, matmul_flags
            a = vals[node.parents[0].uid]
            b = vals[node.parents[1].uid]
            ta, tb = matmul_flags(node.payload)  # folded-transpose flags
            a = a.T if ta else a
            b = b.T if tb else b
            c = a @ b
            epi = matmul_epilogue(node.payload)
            if epi is not None:
                from .fusion import eval_fused   # local import (cycle)
                c = eval_fused(epi, [c] + [vals[p.uid]
                                           for p in node.parents[2:]])
            vals[node.uid] = c
        elif node.op is Op.FUSED:
            from .fusion import eval_fused   # local import (cycle)
            vals[node.uid] = eval_fused(
                node.payload, [vals[p.uid] for p in node.parents])
        elif node.op is Op.SCALE:
            kind, s = node.payload
            vals[node.uid] = apply_scale(kind, vals[node.parents[0].uid], s)
        elif node.op is Op.EWISE:
            vals[node.uid] = EWISE_FNS[node.payload](vals[node.parents[0].uid])
        elif node.op is Op.TRANSPOSE:
            vals[node.uid] = vals[node.parents[0].uid].T
        else:  # pragma: no cover
            raise ValueError(node.op)
    return vals[root.uid]
