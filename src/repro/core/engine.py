"""CMM engine: expression -> optimize -> tiled DAG -> HEFT -> sim -> run.

This is the user-facing orchestration layer (Fig. 1 of the paper): a
``ClusteredMatrix.compute()`` lands here.  The engine

1. optimizes the expression DAG (``fusion.optimize``: CSE, identity folding,
   transpose-into-matmul folding, elementwise-chain fusion — the paper's
   "optimize matrix operations on the fly" step),
2. tiles the optimized expression (``tiling.tile_expression``) at the
   configured or auto-selected tile size (§3.3),
3. schedules with cache-aware HEFT under the offline-profiled time model,
4. simulates the schedule (the ~0.1 s check the paper runs before execution),
5. executes with the selected executor (local threaded / Pallas-kernel /
   sharded SUMMA) and returns the materialised ndarray.

Repeated ``compute()`` calls with the same *structure* (iterative workloads:
power iteration, the Markov example) hit a structural **plan cache** — the
tiled program + HEFT schedule are reused with the leaves rebound to the new
data, so planning is paid once per structure.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from .fusion import (FusionReport, leaves_in_order_many, optimize_many,
                     residency_layout, structural_signature_many)
from .roofline import audit_timemodel
from .graph import TaskGraph, TaskKind
from .heft import DirectCost, Schedule, heft_schedule
from .lazy import ClusteredMatrix, Op, topo_order, topo_order_many
from .machine import ClusterSpec, MemoryBudgetExceeded, c5_9xlarge
from .simulator import SimResult, simulate
from .tiling import (TiledProgram, normalize_tile, tile_expression,
                     tile_expression_many)
from .timemodel import CostCache, TimeModel, analytic_time_model


@dataclass
class Plan:
    program: TiledProgram
    schedule: Schedule
    sim: SimResult
    tile: Tuple[int, int]
    plan_seconds: float
    spec: Optional[ClusterSpec] = None
    fusion: Optional[FusionReport] = None
    cache_hit: bool = False
    #: per-run session residency view (``core.session.SessionResidency``):
    #: resident-leaf tile lookups + retention sinks.  Set by the session
    #: right before execution, never cached.
    residency: Optional[object] = None
    #: dependency levels of the task graph (wave-batched execution order)
    waves: Optional[list] = None
    #: predicted wall-clock of the wave-batched executor strategy
    batched_makespan: Optional[float] = None
    #: lazy, memoized predictor for the multi-process cluster strategy
    #: (None on single-node specs).  Pricing it re-simulates the whole
    #: schedule under the process/IPC terms, so it only runs when the
    #: prediction is actually consulted (``auto`` / ``best_*``) — plain
    #: ``plan()`` keeps the fast-path planning time.
    _cluster_pred: Optional[Callable[[], float]] = None
    #: lazy churn-priced predictor for the elastic strategy (cluster
    #: prediction + expected recovery cost under ``tm.node_mtbf``)
    _elastic_pred: Optional[Callable[[], float]] = None
    #: predicted peak arena bytes per node (admission check; None when no
    #: node carries a ``mem_bytes`` budget)
    peak_bytes: Optional[Dict[int, int]] = None
    #: predicted bytes that must round-trip the spill tier to run this
    #: plan within budget (0 = fits in RAM)
    spill_bytes: int = 0
    #: those bytes priced through the TimeModel's spill bandwidths
    spill_seconds: float = 0.0

    @property
    def cluster_makespan(self) -> Optional[float]:
        """Predicted wall-clock of the multi-process cluster executor
        (None on single-node specs; computed on first access)."""
        return self._cluster_pred() if self._cluster_pred else None

    @property
    def elastic_makespan(self) -> Optional[float]:
        """Expected wall-clock of the elastic cluster strategy once
        node-failure risk is priced in (``churn_adjusted_makespan``;
        equals ``cluster_makespan`` at the default ``node_mtbf=inf``)."""
        return self._elastic_pred() if self._elastic_pred else None

    @property
    def predicted_makespan(self) -> float:
        """Per-task (HEFT-simulated) makespan — the paper's §4.2 number."""
        return self.sim.makespan

    @property
    def best_predicted_makespan(self) -> float:
        """Cheapest predicted strategy: per-task simulation vs wave-batched
        vs multi-process cluster execution (the simulation-driven selection
        extended to executor strategy)."""
        cands = [self.sim.makespan, self.batched_makespan,
                 self.cluster_makespan]
        return min(c for c in cands if c is not None)

    @property
    def best_executor(self) -> str:
        best, t = "local", self.sim.makespan
        if self.batched_makespan is not None and self.batched_makespan < t:
            best, t = "batched", self.batched_makespan
        if self.cluster_makespan is not None and self.cluster_makespan < t:
            best, t = "cluster", self.cluster_makespan
        return best

    def roofline_waves(self, tm, **kw) -> list:
        """Per-wave roofline fractions of this plan (how close each
        wave's predicted compute sits to the analytic machine ceiling —
        :func:`repro.core.roofline.wave_roofline`)."""
        from .roofline import wave_roofline
        from ..exec.batched import build_waves
        waves = self.waves or build_waves(self.program.graph)
        return wave_roofline(self.program.graph, waves, tm,
                             spec=self.spec, **kw)


def _memo_cluster_pred(g, sched, spec, tm) -> Callable[[], float]:
    """Memoized cluster-strategy predictor, shared by a cached plan and
    every cache-hit copy so the extra simulation runs at most once per
    planned structure — **keyed on the TimeModel state + spec**, so
    recalibration (``profiler.calibrate_ipc`` mutates ``tm`` in place)
    invalidates the cached verdict instead of returning a stale
    makespan."""
    memo: Dict[str, object] = {}

    def pred() -> float:
        key = (tm.to_json(), spec)
        if memo.get("k") != key:
            from ..exec.cluster import predict_cluster_makespan
            memo["k"] = key
            memo["v"] = predict_cluster_makespan(g, sched, spec, tm)
        return memo["v"]

    return pred


def _memo_elastic_pred(g, sched, spec, tm,
                       cluster_pred: Callable[[], float]
                       ) -> Callable[[], float]:
    """Churn-priced twin of ``_memo_cluster_pred``: the cluster
    prediction inflated by expected lineage-recovery cost under
    ``tm.node_mtbf`` (same TimeModel-keyed invalidation)."""
    memo: Dict[str, object] = {}

    def pred() -> float:
        key = (tm.to_json(), spec)
        if memo.get("k") != key:
            from .simulator import churn_adjusted_makespan
            memo["k"] = key
            memo["v"] = churn_adjusted_makespan(g, sched, spec, tm,
                                                base=cluster_pred())
        return memo["v"]

    return pred


class CMMEngine:
    _default: Optional["CMMEngine"] = None

    def __init__(self, spec: Optional[ClusterSpec] = None,
                 timemodel: Optional[TimeModel] = None,
                 tile: Optional[int] = None,
                 cache_aware: bool = True,
                 fuse: bool = True,
                 fuse_epilogue: bool = True,
                 plan_cache: bool = True,
                 fast_planning: bool = True,
                 elastic: bool = False):
        self.spec = spec or c5_9xlarge(1)
        self.timemodel = timemodel or analytic_time_model()
        self.tile = tile
        self.cache_aware = cache_aware
        self.fuse = fuse
        #: fold single-consumer elementwise chains into their matmul as an
        #: epilogue program (``fusion.fuse_matmul_epilogues_many``); off =
        #: the unfused baseline (standalone FUSED tasks per tile)
        self.fuse_epilogue = fuse_epilogue
        self.plan_cache = plan_cache
        #: elastic runtime mode: multi-node execution goes through the
        #: fault-tolerant ``"elastic"`` backend and ``auto`` selection
        #: prices churn risk (``tm.node_mtbf``) into the cluster strategy
        self.elastic = elastic
        #: memoized-cost + gap-timeline HEFT (identical schedules; see
        #: ``heft.heft_schedule(fast=...)``).  ``False`` restores the
        #: pre-fast-path planner for benchmarking.
        self.fast_planning = fast_planning
        #: structural signature + tile -> (Plan, leaf uid order)
        self._plans: Dict[tuple, Plan] = {}
        self.plan_cache_hits = 0
        self.plan_cache_misses = 0
        #: how many times admission re-planned a too-big plan out-of-core
        #: at a smaller tile
        self.plan_shrinks = 0
        #: flight recorder: spans + plan of the last ``execute_plan`` call
        #: (``dump_trace`` / ``drift_report`` consume them)
        self.last_spans: list = []
        self.last_plan: Optional[Plan] = None
        self.last_exec_stats: Dict[str, object] = {}

    @classmethod
    def default(cls) -> "CMMEngine":
        if cls._default is None:
            cls._default = CMMEngine()
        return cls._default

    # -- planning -----------------------------------------------------------
    def _fill_origins(self, roots: Sequence[ClusteredMatrix]
                      ) -> Dict[int, str]:
        out = {}
        for node in topo_order_many(roots):
            if node.op is Op.INPUT:
                out[node.uid] = "master"     # user data lives on the master
            elif node.op in (Op.RANDOM, Op.ZEROS, Op.EYE):
                out[node.uid] = "local"      # generated in place (§3.3)
        return out

    @staticmethod
    def _resident_pins(prog: TiledProgram) -> Optional[Dict[int, int]]:
        """RESIDENT task -> node whose arena holds that tile (the handle's
        per-tile home) — location-pinned placement for the scheduler."""
        pins: Dict[int, int] = {}
        for t in prog.graph:
            if t.kind is TaskKind.RESIDENT:
                h = prog.leaf_nodes[t.payload].payload
                pins[t.tid] = h.home.get((t.out.i, t.out.j), 0)
        return pins or None

    def plan(self, root: ClusteredMatrix, tile=None,
             fuse: Optional[bool] = None,
             fast: Optional[bool] = None) -> Plan:
        """Plan one root (the one-shot ``compute()`` path) — a thin wrapper
        over the multi-root session planner."""
        return self.plan_many((root,), tile=tile, fuse=fuse, fast=fast)

    def plan_many(self, roots: Sequence[ClusteredMatrix], tile=None,
                  fuse: Optional[bool] = None,
                  fast: Optional[bool] = None,
                  persist: Sequence[int] = ()) -> Plan:
        """Plan a multi-root program with shared CSE.

        ``persist`` lists root *positions* whose results stay resident in
        the executor arenas (no takecopy gather); the session layer turns
        them into ``ResidentMatrix`` handles.  The plan cache key covers
        the union structure, the persist set and the **residency layout**
        (tile size + per-tile home node of every resident leaf), so an
        iterative workload re-planning the same step structure hits the
        cache even though each step consumes fresh handles.
        """
        t0 = time.perf_counter()
        roots = list(roots)
        orig_roots = roots  # pre-optimization view, for admission re-plans
        tile = normalize_tile(tile or self.tile or self._default_tile(roots))
        fuse = self.fuse if fuse is None else fuse
        fast = self.fast_planning if fast is None else fast
        persist_idx = frozenset(int(i) for i in persist)
        bad = [i for i in persist_idx if not 0 <= i < len(roots)]
        if bad:
            raise ValueError(f"persist indices {bad} out of range for "
                             f"{len(roots)} roots")
        report = None
        if fuse:
            # transposed-operand tile indexing needs a square tile on
            # ragged grids; keep explicit TRANSPOSE nodes otherwise
            roots, report = optimize_many(roots,
                                          fold_transpose=tile[0] == tile[1],
                                          fuse_epilogue=self.fuse_epilogue)

        key = None
        if self.plan_cache:
            # the TimeModel fingerprint keys the cache too: in-place
            # recalibration (calibrate_ipc/contention/...) must invalidate
            # cached schedules + auto-selection verdicts, not replay them
            key = (structural_signature_many(roots), tile, self.spec,
                   self.cache_aware, fuse, self.timemodel.to_json(),
                   persist_idx, residency_layout(roots))
            hit = self._plans.get(key)
            if hit is not None:
                self.plan_cache_hits += 1
                prog = hit.program.rebound(leaves_in_order_many(roots))
                # the cached copy dropped its roots (they would pin user
                # data); a served plan carries the CALLER's roots
                prog.roots = list(roots)
                prog.root = roots[0]
                return Plan(prog, hit.schedule, hit.sim, hit.tile,
                            time.perf_counter() - t0, spec=self.spec,
                            fusion=report, cache_hit=True, waves=hit.waves,
                            batched_makespan=hit.batched_makespan,
                            _cluster_pred=hit._cluster_pred,
                            _elastic_pred=hit._elastic_pred,
                            peak_bytes=hit.peak_bytes,
                            spill_bytes=hit.spill_bytes,
                            spill_seconds=hit.spill_seconds)
            self.plan_cache_misses += 1

        prog = tile_expression_many(roots, tile, persist_idx)
        # one cost object shared by scheduling, simulation and wave costing:
        # memoized on the fast path, direct (naive-baseline) otherwise
        cost = CostCache(self.timemodel, self.spec) if fast \
            else DirectCost(self.timemodel, self.spec)
        sched = heft_schedule(prog.graph, self.spec, self.timemodel,
                              cache_aware=self.cache_aware,
                              fill_origin=self._fill_origins(roots),
                              fast=fast, cost=cost,
                              pinned=self._resident_pins(prog))
        sim = simulate(prog.graph, sched, self.spec, self.timemodel,
                       cost=cost)
        from ..exec.batched import build_waves, predict_wave_makespan
        waves = build_waves(prog.graph)
        batched = predict_wave_makespan(prog.graph, self.spec,
                                        self.timemodel, waves=waves,
                                        dtypes=prog.dtypes, cost=cost)
        cluster_pred = None
        elastic_pred = None
        if self.spec.n_nodes > 1:
            # the multi-process strategy only exists for multi-node specs
            cluster_pred = _memo_cluster_pred(prog.graph, sched, self.spec,
                                              self.timemodel)
            elastic_pred = _memo_elastic_pred(prog.graph, sched, self.spec,
                                              self.timemodel, cluster_pred)
        plan = Plan(prog, sched, sim, tile, time.perf_counter() - t0,
                    spec=self.spec, fusion=report, waves=waves,
                    batched_makespan=batched, _cluster_pred=cluster_pred,
                    _elastic_pred=elastic_pred)

        # -- admission: price the plan's peak footprint against mem_bytes.
        # A plan that overflows a node's budget but whose minimum working
        # set fits is ACCEPTED as spill-executable (the arena runs it
        # out-of-core bit-identically, at the annotated spill price); a
        # plan whose floor overflows is re-planned at a smaller tile, or
        # rejected with a structured MemoryBudgetExceeded — never an OOM.
        budgets = {n: self.spec.mem_at(n) for n in self.spec.alive_nodes()
                   if self.spec.mem_at(n) is not None}
        if budgets:
            from .heft import min_resident_floor, peak_node_bytes
            from .simulator import predict_spill_seconds
            peaks = peak_node_bytes(prog.graph, sched)
            spill_excess = 0
            for n, b in sorted(budgets.items()):
                p = peaks.get(n, 0)
                if p <= b:
                    continue
                floor = min_resident_floor(prog.graph, sched, n)
                if floor > b:
                    # spilling cannot help: one task's working set (or the
                    # retained baseline) alone overflows.  Resident-leaf
                    # programs are tile-locked to their handles, so only
                    # fresh-leaf programs can shrink.
                    has_resident = any(t.kind is TaskKind.RESIDENT
                                       for t in prog.graph.tasks.values())
                    if tile > (1, 1) and not has_resident:
                        self.plan_shrinks += 1
                        return self.plan_many(
                            orig_roots,
                            tile=(max(1, tile[0] // 2),
                                  max(1, tile[1] // 2)),
                            fuse=fuse, fast=fast, persist=persist_idx)
                    raise MemoryBudgetExceeded(n, floor, b)
                spill_excess += p - b
            sim.peak_bytes.update(peaks)
            plan.peak_bytes = peaks
            plan.spill_bytes = spill_excess
            plan.spill_seconds = predict_spill_seconds(spill_excess,
                                                       self.timemodel)
        if key is not None:
            if len(self._plans) >= 128:      # bound cache growth (FIFO)
                self._plans.pop(next(iter(self._plans)))
            self._plans[key] = self._cache_copy(plan)
        return plan

    @staticmethod
    def _cache_copy(plan: Plan) -> Plan:
        """The cached entry must not pin user data: INPUT leaf payloads,
        RESIDENT handles (they pin arena tiles) and the expression root are
        dropped — a hit rebinds fresh leaves."""
        prog = plan.program
        stripped = []
        for uid in prog.leaf_order:
            n = prog.leaf_nodes[uid]
            if n.op in (Op.INPUT, Op.RESIDENT):
                n = ClusteredMatrix(n.op, n.shape, n.dtype, payload=None,
                                    name=n.name)
            stripped.append(n)
        p = prog.rebound(stripped)
        p.root = None
        p.roots = []
        return Plan(p, plan.schedule, plan.sim, plan.tile, plan.plan_seconds,
                    spec=plan.spec, waves=plan.waves,
                    batched_makespan=plan.batched_makespan,
                    _cluster_pred=plan._cluster_pred,
                    _elastic_pred=plan._elastic_pred,
                    peak_bytes=plan.peak_bytes,
                    spill_bytes=plan.spill_bytes,
                    spill_seconds=plan.spill_seconds)

    def _default_tile(self, roots: Sequence[ClusteredMatrix]) -> int:
        # paper finding: tile ~ n/2 is best for n=10k on 8 nodes (§3.3);
        # fall back to half the largest dimension.
        if isinstance(roots, ClusteredMatrix):
            roots = (roots,)
        dim = max(max(n.shape) for n in topo_order_many(roots))
        return max(1, dim // 2)

    def predict_recompute_seconds(self, roots: Sequence[ClusteredMatrix],
                                  tile=None) -> float:
        """Simulated wall-clock of re-deriving ``roots`` from scratch on
        the current spec — the lineage-recompute leg of the durable
        session's per-handle reload-vs-recompute pricing (the reload leg
        is ``simulator.predict_reload_seconds`` on the same TimeModel)."""
        roots = list(roots)
        plan = self.plan_many(roots, tile=tile,
                              persist=tuple(range(len(roots))))
        return plan.sim.makespan

    def autotune_tile(self, root: ClusteredMatrix,
                      candidates: Sequence[int]) -> Tuple[int, Dict[int, float]]:
        """§3.3: pick the tile size with the best *simulated* makespan,
        costing each candidate at its cheapest executor strategy."""
        scores: Dict[int, float] = {}
        for c in candidates:
            scores[c] = self.plan(root, tile=c).best_predicted_makespan
        best = min(scores, key=lambda k: (scores[k], k))
        return best, scores

    # -- execution ------------------------------------------------------------
    def run(self, root: ClusteredMatrix, tile=None, executor: str = "local",
            validate: bool = False, plan: Optional[Plan] = None,
            **exec_kw) -> np.ndarray:
        """Execute through a backend from the ``repro.exec.EXECUTORS``
        registry:

        * ``"local"``          — per-task threaded executor;
        * ``"kernel"``         — per-task with Pallas addmul tiles;
        * ``"batched"``        — wave-batched stacked-kernel executor;
        * ``"batched-pallas"`` — wave-batched, ADDMUL groups through
          ``jax.vmap`` over the Pallas blocked GEMM;
        * ``"cluster"``        — one worker process per cluster node,
          HEFT node placements executed for real;
        * ``"elastic"``        — the cluster backend under the elastic
          control plane (membership, lineage recovery, re-planning);
        * ``"auto"``           — simulation-driven choice between the
          per-task, wave-batched and cluster strategies for this plan
          (churn-priced, and routed through ``"elastic"``, when the
          engine runs with ``elastic=True``).

        ``run`` is the thin ONE-SHOT wrapper over the session execution
        path (``execute_plan``): plan, execute with an ephemeral executor,
        gather everything to the master and discard all executor state.
        Iterative workloads that want results to stay resident between
        calls should use :class:`repro.core.session.CMMSession` instead.
        """
        plan = plan or self.plan(root, tile=tile)
        out = self.execute_plan(plan, executor=executor, **exec_kw)
        if validate:
            ref = root.eager()
            np.testing.assert_allclose(out, ref, rtol=1e-8, atol=1e-8)
        return out

    def execute_plan(self, plan: Plan, executor: str = "local",
                     executor_obj=None, **exec_kw):
        """Execute a prepared plan — the engine half shared by one-shot
        ``run()`` and the session engine.  ``executor_obj`` lets a session
        pass its *long-lived* executor instance (resident arenas survive
        across calls); otherwise an ephemeral backend is built from the
        registry."""
        if executor == "auto":
            executor = self.choose_executor(plan)
        if executor in ("elastic", "cluster") and executor_obj is None \
                and "timemodel" not in exec_kw:
            # frontier re-planning inside the executor must price nodes
            # with the same model the original schedule used; the cluster
            # backends also price per-edge wire codecs against it
            exec_kw["timemodel"] = self.timemodel
        if executor_obj is None:
            from ..exec import make_executor
            executor_obj = make_executor(executor, **exec_kw)
        out = executor_obj.execute(plan)
        self.last_exec_stats = dict(executor_obj.stats)
        self.last_exec_stats["executor"] = executor
        self.last_spans = list(getattr(executor_obj, "spans", []) or [])
        self.last_plan = plan
        return out

    # -- flight recorder ----------------------------------------------------
    def dump_trace(self, path: str, include_predicted: bool = False) -> int:
        """Export the last run's spans as Chrome-trace JSON (load in
        ``chrome://tracing`` or https://ui.perfetto.dev).  With
        ``include_predicted`` the simulator's predicted timeline is
        overlaid on shifted lanes, so drift is visible in the viewer.
        Returns the number of events written."""
        spans = list(self.last_spans)
        if include_predicted and self.last_plan is not None \
                and self.last_plan.sim is not None:
            spans += self.last_plan.sim.predicted_spans()
        from ..runtime.telemetry import export_chrome_trace
        return len(export_chrome_trace(spans, path)["traceEvents"])

    def drift_report(self, **kw):
        """Predicted-vs-actual drift over the last run's spans
        (:func:`repro.core.drift.drift_report` against the last plan)."""
        if self.last_plan is None:
            raise RuntimeError("no executed plan to analyse — "
                               "run execute_plan() first")
        from .drift import drift_report
        return drift_report(self.last_spans, self.last_plan,
                            tm=self.timemodel, **kw)

    def roofline_report(self, **kw):
        """Achieved-vs-roofline analysis over the last run's spans
        (:func:`repro.core.roofline.roofline_report` against the last
        plan) — nodes far below the analytic ceiling are straggler
        priors even when the fitted model has absorbed their slowdown."""
        if self.last_plan is None:
            raise RuntimeError("no executed plan to analyse — "
                               "run execute_plan() first")
        from .roofline import roofline_report
        return roofline_report(self.last_spans, self.last_plan,
                               tm=self.timemodel, **kw)

    def roofline_audit(self, plan: Optional[Plan] = None, **kw):
        """Audit the TimeModel against the analytic roofline for a plan's
        task signatures (:func:`repro.core.roofline.audit_timemodel`)."""
        plan = plan or self.last_plan
        if plan is None:
            raise RuntimeError("no plan to audit — plan() or run() first")
        return audit_timemodel(plan.program.graph, self.timemodel,
                               spec=plan.spec, **kw)

    def choose_executor(self, plan: Plan) -> str:
        """Per-plan executor strategy from predicted makespans (§3.3's
        simulation-driven selection, extended to execution strategy).

        Under ``elastic=True`` the multi-process strategy is priced at
        its churn-adjusted makespan (expected lineage-recovery cost under
        ``tm.node_mtbf``) and executed by the fault-tolerant backend —
        an unreliable cluster can tip ``auto`` back to an in-process
        strategy even when the pristine cluster prediction wins.
        """
        if not self.elastic:
            return plan.best_executor
        best, t = "local", plan.sim.makespan
        if plan.batched_makespan is not None and plan.batched_makespan < t:
            best, t = "batched", plan.batched_makespan
        em = plan.elastic_makespan
        if em is not None and em < t:
            best, t = "elastic", em
        return best

    def theoretical_speedup(self, root: ClusteredMatrix, tile=None,
                            n_nodes: Optional[int] = None) -> float:
        """Table 4: zero-communication simulated speedup vs one node."""
        spec_n = self.spec if n_nodes is None else self.spec.with_nodes(n_nodes)
        eng_n = CMMEngine(spec_n, self.timemodel, cache_aware=self.cache_aware)
        plan_n = eng_n.plan(root, tile=tile)
        zc = simulate(plan_n.program.graph, plan_n.schedule, spec_n,
                      self.timemodel, zero_comm=True)
        eng_1 = CMMEngine(self.spec.with_nodes(1), self.timemodel,
                          cache_aware=self.cache_aware)
        plan_1 = eng_1.plan(root, tile=tile)
        return plan_1.sim.makespan / max(zc.makespan, 1e-12)
