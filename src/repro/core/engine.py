"""CMM engine: expression -> tiled DAG -> HEFT schedule -> simulation -> run.

This is the user-facing orchestration layer (Fig. 1 of the paper): a
``ClusteredMatrix.compute()`` lands here.  The engine

1. tiles the expression (``tiling.tile_expression``) at the configured or
   auto-selected tile size (§3.3),
2. schedules with cache-aware HEFT under the offline-profiled time model,
3. simulates the schedule (the ~0.1 s check the paper runs before execution),
4. executes with the selected executor (local threaded / Pallas-kernel /
   sharded SUMMA) and returns the materialised ndarray.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .graph import TaskGraph
from .heft import Schedule, heft_schedule, register_fill_origin
from .lazy import ClusteredMatrix, Op, topo_order
from .machine import ClusterSpec, c5_9xlarge
from .simulator import SimResult, simulate
from .tiling import TiledProgram, normalize_tile, tile_expression
from .timemodel import TimeModel, analytic_time_model


@dataclass
class Plan:
    program: TiledProgram
    schedule: Schedule
    sim: SimResult
    tile: Tuple[int, int]
    plan_seconds: float

    @property
    def predicted_makespan(self) -> float:
        return self.sim.makespan


class CMMEngine:
    _default: Optional["CMMEngine"] = None

    def __init__(self, spec: Optional[ClusterSpec] = None,
                 timemodel: Optional[TimeModel] = None,
                 tile: Optional[int] = None,
                 cache_aware: bool = True):
        self.spec = spec or c5_9xlarge(1)
        self.timemodel = timemodel or analytic_time_model()
        self.tile = tile
        self.cache_aware = cache_aware

    @classmethod
    def default(cls) -> "CMMEngine":
        if cls._default is None:
            cls._default = CMMEngine()
        return cls._default

    # -- planning -----------------------------------------------------------
    def _fill_origins(self, root: ClusteredMatrix) -> Dict[int, str]:
        out = {}
        for node in topo_order(root):
            if node.op is Op.INPUT:
                out[node.uid] = "master"     # user data lives on the master
            elif node.op in (Op.RANDOM, Op.ZEROS, Op.EYE):
                out[node.uid] = "local"      # generated in place (§3.3)
        return out

    def plan(self, root: ClusteredMatrix, tile=None) -> Plan:
        t0 = time.perf_counter()
        tile = normalize_tile(tile or self.tile or self._default_tile(root))
        prog = tile_expression(root, tile)
        register_fill_origin(self._fill_origins(root))
        sched = heft_schedule(prog.graph, self.spec, self.timemodel,
                              cache_aware=self.cache_aware)
        sim = simulate(prog.graph, sched, self.spec, self.timemodel)
        return Plan(prog, sched, sim, tile, time.perf_counter() - t0)

    def _default_tile(self, root: ClusteredMatrix) -> int:
        # paper finding: tile ~ n/2 is best for n=10k on 8 nodes (§3.3);
        # fall back to half the largest dimension.
        dim = max(max(n.shape) for n in topo_order(root))
        return max(1, dim // 2)

    def autotune_tile(self, root: ClusteredMatrix,
                      candidates: Sequence[int]) -> Tuple[int, Dict[int, float]]:
        """§3.3: pick the tile size with the best *simulated* makespan."""
        scores: Dict[int, float] = {}
        for c in candidates:
            scores[c] = self.plan(root, tile=c).predicted_makespan
        best = min(scores, key=lambda k: (scores[k], k))
        return best, scores

    # -- execution ------------------------------------------------------------
    def run(self, root: ClusteredMatrix, tile=None, executor: str = "local",
            validate: bool = False, plan: Optional[Plan] = None,
            **exec_kw) -> np.ndarray:
        plan = plan or self.plan(root, tile=tile)
        if executor == "local":
            from ..exec.local import LocalExecutor
            ex = LocalExecutor(**exec_kw)
        elif executor == "kernel":
            from ..exec.local import LocalExecutor
            ex = LocalExecutor(use_pallas=True, **exec_kw)
        else:
            raise ValueError(f"unknown executor {executor!r}")
        out = ex.execute(plan)
        if validate:
            ref = root.eager()
            np.testing.assert_allclose(out, ref, rtol=1e-8, atol=1e-8)
        return out

    def theoretical_speedup(self, root: ClusteredMatrix, tile=None,
                            n_nodes: Optional[int] = None) -> float:
        """Table 4: zero-communication simulated speedup vs one node."""
        spec_n = self.spec if n_nodes is None else self.spec.with_nodes(n_nodes)
        eng_n = CMMEngine(spec_n, self.timemodel, cache_aware=self.cache_aware)
        plan_n = eng_n.plan(root, tile=tile)
        zc = simulate(plan_n.program.graph, plan_n.schedule, spec_n,
                      self.timemodel, zero_comm=True)
        eng_1 = CMMEngine(self.spec.with_nodes(1), self.timemodel,
                          cache_aware=self.cache_aware)
        plan_1 = eng_1.plan(root, tile=tile)
        return plan_1.sim.makespan / max(zc.makespan, 1e-12)
