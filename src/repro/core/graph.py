"""Tiled task-dependency graph (CMM §3.1–3.2).

Task classification follows the paper exactly:

* ``calloc``  — allocation + zero-init of an output tile (paper merged
  malloc+fillzero into one async calloc task, §3.3);
* ``fill``    — materialise an input tile (data fill, scheduled just before
  first use, §3.3);
* ``addmul``  — tiled GEMM-accumulate ``C_ij += A_ik @ B_kj`` (the hot task);
* ``sub``     — tiled subtraction (paper's ``sub!``); add/ewise/scale kept as
  separate kinds with the same cost-model family;
* ``takecopy``— copy a result tile from its worker to the master node;
* ``send``/``recv`` — communication tasks, created by the scheduler when an
  edge crosses nodes (they are not part of the logical DAG).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple


class TaskKind(enum.Enum):
    CALLOC = "calloc"
    FILL = "fill"
    ADDMUL = "addmul"
    MATMUL = "matmul"      # first k-step of an accumulate chain (C = A@B)
    ADD = "add"
    SUB = "sub"
    EWMUL = "ewmul"
    SCALE = "scale"
    EWISE = "ewise"
    TRANSPOSE = "transpose"
    FUSED = "fused"        # fused elementwise region: one task per tile
    TAKECOPY = "takecopy"
    SEND = "send"
    RECV = "recv"
    RESIDENT = "resident"  # bind a session-resident tile into this run's
                           # buffer namespace (zero-cost alias, no data
                           # generation or movement; payload = leaf uid)


#: kinds that do arithmetic (appear in the compute time model)
COMPUTE_KINDS = {
    TaskKind.ADDMUL, TaskKind.MATMUL, TaskKind.ADD, TaskKind.SUB,
    TaskKind.EWMUL, TaskKind.SCALE, TaskKind.EWISE, TaskKind.TRANSPOSE,
    TaskKind.FUSED,
}


def matmul_flags(payload) -> Tuple[bool, bool]:
    """Transposed-operand flags carried by ADDMUL/MATMUL tasks (the fusion
    optimizer folds ``A.T @ B`` into flags instead of a TRANSPOSE pass).

    Understands both the bare ``(ta, tb)`` form and the epilogue-carrying
    ``("epi", (ta, tb), prog)`` form (see :func:`epilogue_payload`)."""
    if (isinstance(payload, tuple) and len(payload) == 3
            and payload[0] == "epi"):
        payload = payload[1]
    if (isinstance(payload, tuple) and len(payload) == 2
            and all(isinstance(x, bool) for x in payload)):
        return payload
    return (False, False)


def matmul_epilogue(payload) -> Optional[tuple]:
    """The fused elementwise epilogue program attached to an ADDMUL/MATMUL
    (``None`` when the task is a plain GEMM-accumulate).

    The program reuses the FUSED tile-program encoding (``core.fusion``):
    input slot 0 is the fully accumulated ``C`` tile, slots ``1..`` are the
    task's extra operand tiles ``ins[2:]`` in order.  The executor applies
    it once, after the last k-step of the accumulate chain."""
    if (isinstance(payload, tuple) and len(payload) == 3
            and payload[0] == "epi"):
        return payload[2]
    return None


def epilogue_payload(flags: Optional[Tuple[bool, bool]],
                     prog: tuple) -> tuple:
    """Build the tagged MATMUL/ADDMUL payload carrying a fused epilogue:
    ``("epi", (ta, tb), prog)`` — hashable, so CSE / plan-cache keys and
    the wave executor's group signatures work unchanged."""
    ta, tb = matmul_flags(flags)
    return ("epi", (ta, tb), tuple(prog))


@dataclass(frozen=True)
class TileRef:
    """Identity of one tile of one logical tensor.

    ``tensor`` is the ClusteredMatrix uid (or a synthesised uid for
    intermediates); ``(i, j)`` the tile grid coordinate; ``shape`` the actual
    tile shape (edge tiles may be ragged, Listing 1 uses ``min`` bounds).
    """

    tensor: int
    i: int
    j: int
    shape: Tuple[int, int]

    @property
    def bytes(self) -> int:
        return self.shape[0] * self.shape[1] * 8  # f64 default accounting

    def __repr__(self):
        return f"T{self.tensor}[{self.i},{self.j}]{self.shape}"


@dataclass
class Task:
    tid: int
    kind: TaskKind
    #: input tiles (data operands); order matters (addmul: A_ik, B_kj)
    ins: Tuple[TileRef, ...]
    #: output tile
    out: Optional[TileRef]
    #: op-specific payload (ewise fn name, scale (kind, s), leaf node uid…)
    payload: object = None
    preds: Set[int] = field(default_factory=set)
    succs: Set[int] = field(default_factory=set)
    #: floating point ops (for the time model / GFLOPS accounting)
    flops: int = 0

    def dims(self) -> Tuple[int, ...]:
        """Operand dims fed to the Table-1 interpolation equations."""
        if self.kind in (TaskKind.ADDMUL, TaskKind.MATMUL):
            ta, tb = matmul_flags(self.payload)
            sa, sb = self.ins[0].shape, self.ins[1].shape
            m, n = (sa[1], sa[0]) if ta else sa
            k = sb[0] if tb else sb[1]
            return (m, n, k)
        shp = (self.out.shape if self.out is not None else self.ins[0].shape)
        return shp

    @property
    def out_bytes(self) -> int:
        return self.out.bytes if self.out is not None else 0

    def __repr__(self):
        return (f"Task#{self.tid}:{self.kind.value}"
                f"({','.join(map(repr, self.ins))})->{self.out}")


class TaskGraph:
    """A DAG of tiled tasks with dependency edges."""

    def __init__(self):
        self.tasks: Dict[int, Task] = {}
        self._next = 0
        #: tiles of the final result, in (i, j) grid order
        self.result_tiles: List[TileRef] = []
        self.result_grid: Tuple[int, int] = (0, 0)
        self.result_shape: Tuple[int, int] = (0, 0)
        #: per-root outputs of a multi-root program (``tiling.ResultSet``);
        #: empty for hand-built graphs — executors fall back to the single
        #: result_tiles/grid/shape view above
        self.result_sets: List[object] = []

    # -- construction ------------------------------------------------------
    def add(self, kind: TaskKind, ins: Sequence[TileRef],
            out: Optional[TileRef], payload=None, flops: int = 0,
            deps: Iterable[int] = ()) -> Task:
        t = Task(self._next, kind, tuple(ins), out, payload, flops=flops)
        self._next += 1
        self.tasks[t.tid] = t
        for d in deps:
            self.add_edge(d, t.tid)
        return t

    def add_edge(self, u: int, v: int):
        if u == v:
            raise ValueError("self-edge")
        self.tasks[u].succs.add(v)
        self.tasks[v].preds.add(u)

    # -- queries -------------------------------------------------------------
    def __len__(self):
        return len(self.tasks)

    def __iter__(self):
        return iter(self.tasks.values())

    def sources(self) -> List[Task]:
        return [t for t in self.tasks.values() if not t.preds]

    def sinks(self) -> List[Task]:
        return [t for t in self.tasks.values() if not t.succs]

    def topo(self) -> List[Task]:
        """Kahn topological order; raises on cycles."""
        indeg = {tid: len(t.preds) for tid, t in self.tasks.items()}
        ready = sorted(tid for tid, d in indeg.items() if d == 0)
        out: List[Task] = []
        import heapq
        heapq.heapify(ready)
        while ready:
            tid = heapq.heappop(ready)
            out.append(self.tasks[tid])
            for s in sorted(self.tasks[tid].succs):
                indeg[s] -= 1
                if indeg[s] == 0:
                    heapq.heappush(ready, s)
        if len(out) != len(self.tasks):
            raise ValueError("task graph has a cycle")
        return out

    def validate(self):
        """Structural invariants (used by property tests)."""
        for t in self.tasks.values():
            for p in t.preds:
                assert t.tid in self.tasks[p].succs, "edge asymmetry"
            for s in t.succs:
                assert t.tid in self.tasks[s].preds, "edge asymmetry"
            if t.kind in (TaskKind.ADDMUL, TaskKind.MATMUL):
                ta, tb = matmul_flags(t.payload)
                sa = t.ins[0].shape[::-1] if ta else t.ins[0].shape
                sb = t.ins[1].shape[::-1] if tb else t.ins[1].shape
                assert sa[1] == sb[0], f"inner dim mismatch in {t}"
                assert t.out.shape == (sa[0], sb[1]), \
                    f"out shape mismatch in {t}"
                if matmul_epilogue(t.payload) is not None:
                    # epilogue extras are elementwise operands of the
                    # accumulated C tile — same shape by construction
                    for r in t.ins[2:]:
                        assert r.shape == t.out.shape, \
                            f"epilogue extra shape mismatch in {t}"
                else:
                    assert len(t.ins) == 2, \
                        f"extra ins without an epilogue in {t}"
        self.topo()  # raises on cycle

    def counts(self) -> Dict[str, int]:
        c: Dict[str, int] = {}
        for t in self.tasks.values():
            c[t.kind.value] = c.get(t.kind.value, 0) + 1
        return c

    def total_flops(self) -> int:
        return sum(t.flops for t in self.tasks.values())
