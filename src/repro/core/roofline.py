"""Analytic roofline model: FLOP/byte counts per task, node peak
estimates, and an audit of the calibrated TimeModel against the bound.

The TimeModel (§3.4) is *fitted* — OLS over profiled timings — so nothing
in the planning loop says whether its predictions are physically
plausible, or whether a node's measured throughput is anywhere near what
the machine can do.  This module supplies the missing analytic side:

* :func:`task_work` — closed-form FLOP and byte counts per
  ``(task kind, tile shape, dtype)``, using the same arithmetic
  conventions the rest of the planner prices with (``2mnk`` matmuls,
  ``fusion.fused_flops`` weights for elementwise chains and matmul
  epilogues).
* :func:`node_peaks` — per-node peak FLOP/s and memory bandwidth
  estimates *derived from the calibrated TimeModel itself* (marginal
  rate of the fitted matmul / ewise polynomials, scaled by the
  machine model's per-node slowdown), so the roofline and the planner
  price the same machine.
* :func:`audit_timemodel` — one row per distinct task signature
  comparing the model's ``kernel_time`` against the analytic roofline
  bound ``max(flops/peak, bytes/bw)``.  A ratio *below* 1 means the
  fitted polynomial claims super-roofline throughput (mis-calibration);
  a large ratio means the kernel is priced far off the bound.
* :func:`wave_roofline` — per-wave roofline fractions for a planned
  program (how close each wave's predicted time is to its bound).
* :func:`roofline_report` — joins a real run's EXEC spans (the PR-9
  flight recorder) against per-node rooflines: nodes whose achieved
  fraction falls below ``band`` x the fleet median become straggler
  priors, same contract as ``drift.DriftReport.straggler_priors``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .graph import Task, TaskKind, TileRef, matmul_epilogue
from .timemodel import CostCache, TimeModel

__all__ = ["TaskWork", "task_work", "NodePeak", "node_peaks",
           "roofline_time", "AuditRow", "audit_timemodel",
           "wave_roofline", "NodeRoofline", "RooflineReport",
           "roofline_report"]

#: spans shorter than this are timer noise, not throughput evidence
_MIN_SPAN_S = 1e-7


@dataclass(frozen=True)
class TaskWork:
    """Closed-form work of one task: arithmetic and memory traffic."""

    flops: int
    bytes: int

    @property
    def intensity(self) -> float:
        """Arithmetic intensity, FLOP/byte (inf for pure compute)."""
        if self.bytes == 0:
            return math.inf if self.flops else 0.0
        return self.flops / self.bytes


def task_work(task: Task, itemsize: int = 8) -> TaskWork:
    """FLOPs and bytes moved by one task, under the planner's conventions.

    ``itemsize`` is the element width in bytes (8 for the default f64
    tiles; pass 4/2 for f32/bf16 mixed-precision accounting).  Matmul
    counts ``2mnk``; elementwise ops count 1 FLOP/element for +,-,x and
    4 for transcendental EWISE passes — identical weights to
    ``fusion.fused_flops``, so the analytic counts agree with the flops
    the tiler prices onto tasks.
    """
    k = task.kind
    if k in (TaskKind.ADDMUL, TaskKind.MATMUL):
        m, n, kk = task.dims()
        flops = 2 * m * n * kk
        # A (m,n) + B (n,k) streamed in, C (m,k) read + written back
        nbytes = (m * n + n * kk + 2 * m * kk) * itemsize
        epi = matmul_epilogue(task.payload)
        if epi is not None:
            from .fusion import fused_flops
            flops += fused_flops(epi, m, kk)
            # epilogue runs on the in-register/VMEM accumulator: only the
            # extra operands add memory traffic, not the chain temps
            nbytes += (len(task.ins) - 2) * m * kk * itemsize
        return TaskWork(flops, nbytes)
    if k in (TaskKind.SEND, TaskKind.RECV, TaskKind.TAKECOPY,
             TaskKind.RESIDENT):
        return TaskWork(0, 0)
    dims = task.dims()
    m, n = dims if len(dims) == 2 else (dims[0], 1)
    if k is TaskKind.FUSED:
        from .fusion import fused_flops
        return TaskWork(fused_flops(task.payload, m, n),
                        (len(task.ins) + 1) * m * n * itemsize)
    if k is TaskKind.EWISE:
        return TaskWork(4 * m * n, 2 * m * n * itemsize)
    if k in (TaskKind.ADD, TaskKind.SUB, TaskKind.EWMUL):
        return TaskWork(m * n, 3 * m * n * itemsize)
    if k is TaskKind.SCALE:
        return TaskWork(m * n, 2 * m * n * itemsize)
    if k is TaskKind.TRANSPOSE:
        return TaskWork(0, 2 * m * n * itemsize)
    if k in (TaskKind.CALLOC, TaskKind.FILL):
        return TaskWork(0, m * n * itemsize)
    raise ValueError(k)  # pragma: no cover


@dataclass(frozen=True)
class NodePeak:
    """One node's estimated machine peaks (from the calibrated model)."""

    node: int
    flops_per_s: float
    bytes_per_s: float


def _probe_task(kind: TaskKind, dims: Tuple[int, ...]) -> Task:
    if kind in (TaskKind.ADDMUL, TaskKind.MATMUL):
        m, n, k = dims
        return Task(-1, kind,
                    (TileRef(-1, 0, 0, (m, n)), TileRef(-2, 0, 0, (n, k))),
                    TileRef(-3, 0, 0, (m, k)), payload=(False, False),
                    flops=2 * m * n * k)
    m, n = dims
    return Task(-1, kind, (TileRef(-1, 0, 0, (m, n)),),
                TileRef(-2, 0, 0, (m, n)), payload="exp", flops=4 * m * n)


def node_peaks(tm: TimeModel, spec=None,
               nodes: Optional[Iterable[int]] = None) -> List[NodePeak]:
    """Per-node peak estimates implied by the calibrated TimeModel.

    The peaks are the *marginal* rates of the fitted polynomials — two
    probe sizes difference out the constant launch overhead — scaled by
    each node's machine-model slowdown.  They are the model's own belief
    about the hardware ceiling, which is exactly what the audit and the
    span report need: a node achieving far below them is either
    mis-modelled (drift) or throttled (straggler).
    """
    if nodes is None:
        nodes = range(spec.n_nodes) if spec is not None else [0]
    peaks = []
    for node in nodes:
        t1 = tm.kernel_time(_probe_task(TaskKind.ADDMUL, (256, 256, 256)),
                            spec, node)
        t2 = tm.kernel_time(_probe_task(TaskKind.ADDMUL, (512, 512, 512)),
                            spec, node)
        df = 2 * (512 ** 3 - 256 ** 3)
        flops_per_s = df / max(t2 - t1, 1e-12)
        e1 = tm.kernel_time(_probe_task(TaskKind.EWISE, (512, 512)),
                            spec, node)
        e2 = tm.kernel_time(_probe_task(TaskKind.EWISE, (1024, 1024)),
                            spec, node)
        # the polynomials are fitted on f64 tiles: 2 x 8 B per element
        db = 2 * 8 * (1024 ** 2 - 512 ** 2)
        bytes_per_s = db / max(e2 - e1, 1e-12)
        peaks.append(NodePeak(node=node, flops_per_s=flops_per_s,
                              bytes_per_s=bytes_per_s))
    return peaks


def roofline_time(work: TaskWork, peak: NodePeak) -> float:
    """The roofline bound: max of compute-limited and memory-limited time."""
    tc = work.flops / peak.flops_per_s if peak.flops_per_s > 0 else 0.0
    tb = work.bytes / peak.bytes_per_s if peak.bytes_per_s > 0 else 0.0
    return max(tc, tb)


@dataclass
class AuditRow:
    """One distinct task signature: fitted model vs analytic bound."""

    kind: str
    dims: Tuple[int, ...]
    count: int
    flops: int
    bytes: int
    intensity: float
    model_s: float
    roofline_s: float
    #: model_s / roofline_s — < 1 claims super-roofline throughput
    ratio: float
    #: which roof binds: "compute" or "memory"
    bound: str

    def as_dict(self) -> dict:
        return {"kind": self.kind, "dims": list(self.dims),
                "count": self.count, "flops": self.flops,
                "bytes": self.bytes, "intensity": self.intensity,
                "model_s": self.model_s, "roofline_s": self.roofline_s,
                "ratio": self.ratio, "bound": self.bound}


def audit_timemodel(g, tm: TimeModel, spec=None, node: int = 0,
                    itemsize: int = 8) -> List[AuditRow]:
    """Audit the fitted TimeModel against the analytic roofline, one row
    per distinct task signature of graph ``g`` (priced on ``node``).

    Rows with ``ratio < 1`` deserve suspicion: the OLS fit claims the
    kernel beats the machine's own peak estimate.  Rows with very large
    ratios indicate launch-overhead-dominated tiny tiles or a stale fit
    (cross-check with the drift report's ``kernel_time`` term).
    """
    peak = node_peaks(tm, spec, nodes=[node])[0]
    rows: Dict[tuple, AuditRow] = {}
    for t in g:
        if t.kind in (TaskKind.SEND, TaskKind.RECV, TaskKind.TAKECOPY,
                      TaskKind.RESIDENT):
            continue
        sig = CostCache.signature(t)
        row = rows.get(sig)
        if row is not None:
            row.count += 1
            continue
        work = task_work(t, itemsize)
        model_s = tm.kernel_time(t, spec, node)
        roof_s = roofline_time(work, peak)
        tc = work.flops / peak.flops_per_s if peak.flops_per_s else 0.0
        rows[sig] = AuditRow(
            kind=t.kind.value, dims=t.dims(), count=1,
            flops=work.flops, bytes=work.bytes,
            intensity=work.intensity, model_s=model_s,
            roofline_s=roof_s,
            ratio=model_s / roof_s if roof_s > 0 else math.inf,
            bound="compute" if tc >= roof_s else "memory")
    return sorted(rows.values(),
                  key=lambda r: (r.kind, r.dims))


def wave_roofline(g, waves: Sequence[Sequence[int]], tm: TimeModel,
                  spec=None, node: int = 0,
                  itemsize: int = 8) -> List[dict]:
    """Per-wave roofline fractions for a planned program.

    Each wave's predicted compute (summed ``kernel_time`` of its tasks)
    is compared to the wave's aggregate roofline bound; ``fraction`` =
    bound / predicted, i.e. how close the plan thinks the wave runs to
    the machine ceiling (1.0 = at the roofline).
    """
    peak = node_peaks(tm, spec, nodes=[node])[0]
    cost = CostCache(tm, spec)
    out = []
    for wi, wave in enumerate(waves):
        flops = nbytes = 0
        model_s = 0.0
        for tid in wave:
            t = g.tasks[tid]
            if t.kind in (TaskKind.SEND, TaskKind.RECV):
                continue
            w = task_work(t, itemsize)
            flops += w.flops
            nbytes += w.bytes
            model_s += cost.kernel(t, node)
        roof_s = roofline_time(TaskWork(flops, nbytes), peak)
        out.append({"wave": wi, "tasks": len(wave), "flops": flops,
                    "bytes": nbytes, "model_s": model_s,
                    "roofline_s": roof_s,
                    "fraction": (roof_s / model_s) if model_s > 0 else None})
    return out


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


@dataclass
class NodeRoofline:
    """One node's achieved-vs-roofline summary over its EXEC spans."""

    node: int
    samples: int
    #: median(roofline bound / actual duration) over this node's tasks —
    #: 1.0 means the node ran its tasks at the machine ceiling
    fraction: Optional[float]
    #: fraction normalized by the fleet median — the straggler signal
    #: (planned heterogeneity is already priced into each node's peak)
    rel: Optional[float]
    flagged: bool
    achieved_flops_per_s: Optional[float] = None

    def as_dict(self) -> dict:
        return {"node": self.node, "samples": self.samples,
                "fraction": self.fraction, "rel": self.rel,
                "flagged": self.flagged,
                "achieved_flops_per_s": self.achieved_flops_per_s}


@dataclass
class RooflineReport:
    peaks: List[NodePeak]
    nodes: List[NodeRoofline]
    #: nodes achieving below band x fleet median — straggler priors,
    #: same contract as ``DriftReport.straggler_priors``
    below_band: List[int]
    band: float
    fleet_fraction: Optional[float] = None

    def node(self, n: int) -> Optional[NodeRoofline]:
        for nr in self.nodes:
            if nr.node == n:
                return nr
        return None

    def as_dict(self) -> dict:
        return {"band": self.band, "fleet_fraction": self.fleet_fraction,
                "below_band": list(self.below_band),
                "peaks": [{"node": p.node, "flops_per_s": p.flops_per_s,
                           "bytes_per_s": p.bytes_per_s}
                          for p in self.peaks],
                "nodes": [nr.as_dict() for nr in self.nodes]}

    def summary(self) -> str:
        ff = (None if self.fleet_fraction is None
              else round(self.fleet_fraction, 3))
        lines = [f"roofline report (band {self.band}x, "
                 f"fleet fraction {ff})"]
        for nr in self.nodes:
            mark = " <-- BELOW ROOFLINE BAND" if nr.node in \
                self.below_band else ""
            f = "n/a" if nr.fraction is None else f"{nr.fraction:.3f}"
            gf = ("" if nr.achieved_flops_per_s is None else
                  f", {nr.achieved_flops_per_s / 1e9:.2f} GFLOP/s")
            lines.append(f"  node {nr.node}: {nr.samples} tasks, "
                         f"roofline fraction {f}{gf}{mark}")
        return "\n".join(lines)


def roofline_report(spans: Iterable, plan, tm: Optional[TimeModel] = None,
                    band: float = 2.0, min_samples: int = 3,
                    nodes: Optional[Iterable[int]] = None,
                    itemsize: int = 8) -> RooflineReport:
    """Join EXEC spans against per-node rooflines; flag throttled nodes.

    For every span, the task's analytic bound on the node it actually ran
    on (per-node peaks include the machine model's planned slowdowns) is
    divided by the measured duration — the *achieved roofline fraction*.
    A node whose median fraction falls below ``band`` x the fleet median
    with at least ``min_samples`` samples lands in ``below_band``:
    an *unplanned* straggler (e.g. a chaos-throttled VM), since planned
    heterogeneity cancels in the per-node peak.  Complements the drift
    report: drift compares against the *fitted* prediction, this compares
    against the *analytic ceiling*, so they disagree exactly when the
    fitted model itself has absorbed the slowdown.
    """
    if tm is None:
        tm = getattr(plan, "timemodel", None)
    if tm is None:
        from .timemodel import analytic_time_model
        tm = analytic_time_model()
    g = plan.program.graph
    spec = plan.spec

    if nodes is None:
        nodes = range(spec.n_nodes) if spec is not None else []
    spans = list(spans)
    span_nodes = {sp.node for sp in spans if sp.cat == "EXEC"}
    all_nodes = sorted(set(int(n) for n in nodes) | span_nodes)

    peaks = node_peaks(tm, spec, nodes=all_nodes)
    peak_of = {p.node: p for p in peaks}

    per_node: Dict[int, List[float]] = {}
    per_node_flops: Dict[int, List[Tuple[int, float]]] = {}
    for sp in spans:
        if sp.cat != "EXEC":
            continue
        tid = sp.args.get("tid")
        t = g.tasks.get(tid) if tid is not None else None
        if t is None or sp.dur < _MIN_SPAN_S:
            continue
        peak = peak_of.get(sp.node)
        if peak is None:
            continue
        work = task_work(t, itemsize)
        bound = roofline_time(work, peak)
        if bound <= 0:
            continue
        per_node.setdefault(sp.node, []).append(bound / sp.dur)
        per_node_flops.setdefault(sp.node, []).append((work.flops, sp.dur))

    node_frac = {n: _median(v) for n, v in per_node.items()}
    fleet = _median(list(node_frac.values())) if node_frac else None
    rows: List[NodeRoofline] = []
    below: List[int] = []
    for n in all_nodes:
        samples = per_node.get(n, [])
        frac = node_frac.get(n)
        rel = None
        flagged = False
        if frac is not None and fleet and fleet > 0:
            rel = frac / fleet
            flagged = len(samples) >= min_samples and rel < 1.0 / band
            if flagged:
                below.append(n)
        fl = per_node_flops.get(n, [])
        tot_t = sum(d for _, d in fl)
        achieved = (sum(f for f, _ in fl) / tot_t) if tot_t > 0 else None
        rows.append(NodeRoofline(node=n, samples=len(samples),
                                 fraction=frac, rel=rel, flagged=flagged,
                                 achieved_flops_per_s=achieved))
    return RooflineReport(peaks=peaks, nodes=rows, below_band=below,
                          band=band, fleet_fraction=fleet)
