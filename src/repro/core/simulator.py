"""Discrete-event schedule simulator (CMM §3.3, §4.2).

Simulates a HEFT schedule under the profiled time model, with the machine
model's resources made explicit:

* each node has ``worker_procs`` compute slots (a task occupies one);
* each node has ``comm_procs`` communication slots — a cross-node transfer
  occupies one slot at the sender *and* one at the receiver for its duration
  (the paper's dedicated communication processes; the master has more);
* ``calloc`` is asynchronous: it does not occupy a worker slot (§3.3);
* the node-level cache absorbs repeated transfers of the same tile version
  (§3.5) — transfers in flight are joined, not duplicated;
* ``zero_comm=True`` makes communication instantaneous, which is exactly the
  paper's *theoretical speedup* condition (§5.1).

The simulator is what the engine uses for tile-size auto-selection (§3.3) and
what `benchmarks/` uses for Table 3/4 and Fig. 3.
"""
from __future__ import annotations

import heapq
import itertools
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .cache import NodeCache
from .graph import Task, TaskGraph, TaskKind
from .heft import Schedule, edge_bytes
from .machine import ClusterSpec
from .timemodel import CostCache, TimeModel
from ..runtime.wire import BCAST_MIN_FANOUT, broadcast_tree


@dataclass
class Interval:
    tid: int
    kind: str
    node: int
    slot: int
    start: float
    end: float


@dataclass
class Transfer:
    key: Tuple[int, int]
    src: int
    dst: int
    nbytes: int
    start: float = 0.0
    end: float = 0.0


@dataclass
class SimResult:
    makespan: float
    intervals: List[Interval]
    transfers: List[Transfer]
    cache_hits: int
    cache_misses: int
    spec: ClusterSpec
    #: predicted peak arena bytes per node (``heft.peak_node_bytes``);
    #: filled in by the engine's admission check when any node carries a
    #: ``mem_bytes`` budget, empty otherwise
    peak_bytes: Dict[int, int] = field(default_factory=dict)

    def stats_by_kind(self) -> Dict[str, Tuple[int, float]]:
        acc: Dict[str, List[float]] = defaultdict(list)
        for iv in self.intervals:
            acc[iv.kind].append(iv.end - iv.start)
        return {k: (len(v), sum(v)) for k, v in acc.items()}

    def node_busy_fraction(self) -> Dict[int, float]:
        busy = defaultdict(float)
        for iv in self.intervals:
            busy[iv.node] += iv.end - iv.start
        ms = max(self.makespan, 1e-12)
        return {n: busy[n] / (max(1, self.spec.workers_at(n)) * ms)
                for n in range(self.spec.n_nodes)}

    def comm_busy_seconds(self) -> float:
        return sum(t.end - t.start for t in self.transfers)

    def predicted_spans(self, lane_offset: int = 100) -> list:
        """The predicted timeline as flight-recorder spans.

        One ``PRED_EXEC`` span per simulated task interval and one
        ``PRED_XFER`` per simulated transfer, on the same node (pid)
        lanes as the measured trace but with worker-slot lanes shifted
        by ``lane_offset`` — exporting measured + predicted spans into
        one Chrome trace puts prediction directly under reality for
        eyeball drift checks, and ``core/drift.py`` computes the
        residuals the same join implies.
        """
        from ..runtime.telemetry import Span
        out = []
        for iv in self.intervals:
            out.append(Span(f"PRED {iv.kind}", "PRED_EXEC", iv.node,
                            lane_offset + iv.slot, iv.start,
                            iv.end - iv.start,
                            {"tid": iv.tid, "kind": iv.kind}))
        for t in self.transfers:
            out.append(Span("PRED xfer", "PRED_XFER", t.dst,
                            lane_offset, t.start, t.end - t.start,
                            {"tid": t.key[0], "src": t.src,
                             "nbytes": t.nbytes}))
        return out

    def gantt(self, width: int = 100) -> str:
        """ASCII Gantt chart per (node, slot) lane — the Fig. 3 artefact."""
        if not self.intervals:
            return "(empty)"
        scale = width / max(self.makespan, 1e-12)
        lanes: Dict[Tuple[int, int], List[Interval]] = defaultdict(list)
        for iv in self.intervals:
            lanes[(iv.node, iv.slot)].append(iv)
        sym = {"addmul": "#", "matmul": "#", "add": "+", "sub": "-",
               "ewmul": "*", "scale": "*", "ewise": "~", "transpose": "t",
               "fused": "F",
               "fill": "f", "calloc": ".", "takecopy": "c"}
        out = []
        for (node, slot) in sorted(lanes):
            row = [" "] * width
            for iv in lanes[(node, slot)]:
                a = min(int(iv.start * scale), width - 1)
                b = min(max(int(iv.end * scale), a + 1), width)
                for x in range(a, b):
                    row[x] = sym.get(iv.kind, "?")
            out.append(f"n{node}.w{slot} |{''.join(row)}|")
        for t in sorted(self.transfers, key=lambda t: (t.src, t.start)):
            a = min(int(t.start * scale), width - 1)
            b = min(max(int(t.end * scale), a + 1), width)
            row = [" "] * width
            for x in range(a, b):
                row[x] = ">"
            out.append(f"n{t.src}>n{t.dst} |{''.join(row)}|")
        return "\n".join(out)


def simulate(g: TaskGraph, sched: Schedule, spec: ClusterSpec, tm: TimeModel,
             zero_comm: bool = False, use_cache: bool = True,
             cost: Optional[CostCache] = None) -> SimResult:
    """``use_cache=False`` disables the node-level cache in the MACHINE
    (every consumer transfer is re-sent) — the §3.5 mechanism ablation.

    ``cost`` optionally shares a memoized :class:`CostCache` (e.g. the one
    the scheduler already filled) so task durations are not re-derived from
    the interpolation polynomials task-by-task on large graphs."""
    if zero_comm:
        spec = spec.zero_comm()
        cost = None          # cached durations were built for the real spec
    if cost is None:
        cost = CostCache(tm, spec)
    prio = {tid: i for i, tid in enumerate(sched.order)}
    node_of = {tid: p.node for tid, p in sched.placements.items()}

    cache = NodeCache(spec.n_nodes)
    free_workers = {n: spec.workers_at(n) for n in range(spec.n_nodes)}
    free_slots = {n: list(range(spec.workers_at(n)))
                  for n in range(spec.n_nodes)}
    free_comm = {n: spec.comm_procs(n) for n in range(spec.n_nodes)}

    deps_left = {t.tid: len(t.preds) for t in g}
    # (key, dst) -> list of task ids waiting for that arrival
    waiting_data: Dict[Tuple[Tuple[int, int], int], List[int]] = defaultdict(list)
    data_left = {t.tid: 0 for t in g}
    ready: Dict[int, List[Tuple[int, int]]] = {n: [] for n in range(spec.n_nodes)}
    # startable transfers as a priority heap; a transfer blocked on an
    # exhausted comm endpoint is PARKED on that node and only returns to the
    # heap when the node frees a slot — so dispatch never rescans the whole
    # pending set (the naive rescan is O(events x pending) on big graphs)
    pending_xfers: List[Tuple[int, int, Transfer]] = []  # (prio, seq, tr)
    parked_xfers: Dict[int, List[Tuple[int, int, Transfer]]] = \
        defaultdict(list)
    xseq = itertools.count()
    in_flight: Set[Tuple[Tuple[int, int], int]] = set()
    # relay plan for fan-out edges: (key, relay node) -> child nodes whose
    # hop starts when the relay's own copy lands (same deterministic tree
    # shape as the executors' broadcast path, so tree depth is priced)
    relay_children: Dict[Tuple[Tuple[int, int], int], List[int]] = {}
    relay_prio: Dict[Tuple[Tuple[int, int], int], int] = {}

    events: List[Tuple[float, int, str, object]] = []
    seq = itertools.count()
    intervals: List[Interval] = []
    transfers_done: List[Transfer] = []
    now = 0.0

    def push(t, kind, payload):
        heapq.heappush(events, (t, next(seq), kind, payload))

    def task_ready(tid: int):
        n = node_of[tid]
        heapq.heappush(ready[n], (prio[tid], tid))

    def finish_producer(tid: int):
        """Producer done: release deps, create transfers for cross-node data."""
        t = g.tasks[tid]
        src = node_of[tid]
        if t.out is not None:
            cache.put(src, (tid, t.out.tensor), t.out.bytes)
        new_dsts: List[Tuple[int, int, Tuple]] = []   # (dst, nbytes, key)
        for s in sorted(t.succs, key=lambda x: prio[x]):
            st = g.tasks[s]
            nbytes = edge_bytes(g, t, st)
            dst = node_of[s]
            if nbytes and dst != src:
                key = (tid, t.out.tensor) if use_cache \
                    else (tid, t.out.tensor, s)   # unique -> never cached
                if use_cache and cache.peek(dst, key):
                    cache.hits += 1
                else:
                    data_left[s] += 1
                    waiting_data[(key, dst)].append(s)
                    if (key, dst) not in in_flight:
                        cache.misses += 1
                        in_flight.add((key, dst))
                        # succs iterate in prio order -> first waiter is
                        # the most urgent consumer at this destination
                        relay_prio[(key, dst)] = prio[s]
                        new_dsts.append((dst, nbytes, key))
            deps_left[s] -= 1
            if deps_left[s] == 0 and data_left[s] == 0:
                task_ready(s)
        if not new_dsts:
            return
        if use_cache and len(new_dsts) >= BCAST_MIN_FANOUT:
            # fan-out edge: relay tree instead of N unicasts — only the
            # root's hops start now; deeper hops start as relays land
            key = new_dsts[0][2]
            nbytes = new_dsts[0][1]
            tree = broadcast_tree(src, [d for d, _, _ in new_dsts])
            for parent, kids in tree.items():
                if parent != src:
                    relay_children[(key, parent)] = kids
            for child in tree.get(src, []):
                heapq.heappush(
                    pending_xfers,
                    (relay_prio[(key, child)], next(xseq),
                     Transfer(key, src, child, nbytes)))
        else:
            for dst, nbytes, key in new_dsts:
                heapq.heappush(
                    pending_xfers,
                    (relay_prio[(key, dst)], next(xseq),
                     Transfer(key, src, dst, nbytes)))

    def dispatch(now: float):
        # start feasible transfers in priority order.  Starting a transfer
        # only CONSUMES comm slots, so a blocked transfer stays blocked for
        # the rest of this dispatch: it parks on its exhausted endpoint and
        # is only reconsidered once that node frees a slot.  Candidates are
        # k-way-merged in global priority order from the fresh-transfer heap
        # and the parked heaps of nodes that currently have free slots —
        # exactly the feasible subset the naive full rescan would start, at
        # O(starts + moves) instead of O(pending) per event.
        while True:
            best = pending_xfers[0] if pending_xfers else None
            best_node = -1
            for n, h in parked_xfers.items():
                if h and free_comm[n] > 0 and \
                        (best is None or h[0] < best):
                    best = h[0]
                    best_node = n
            if best is None:
                break
            src_heap = pending_xfers if best_node < 0 \
                else parked_xfers[best_node]
            item = heapq.heappop(src_heap)
            tr = item[2]
            if free_comm[tr.src] <= 0:
                heapq.heappush(parked_xfers[tr.src], item)
                continue
            if free_comm[tr.dst] <= 0:
                heapq.heappush(parked_xfers[tr.dst], item)
                continue
            free_comm[tr.src] -= 1
            free_comm[tr.dst] -= 1
            tr.start = now
            # per-edge codec-aware pricing (degrades to spec.comm_time
            # while the TimeModel's codec priors are unfitted)
            tr.end = now + tm.wire_time(tr.nbytes, tr.src, tr.dst, spec)
            push(tr.end, "xfer_done", tr)
        # start ready compute tasks
        for n in range(spec.n_nodes):
            while ready[n]:
                _, tid = ready[n][0]
                t = g.tasks[tid]
                if t.kind in (TaskKind.CALLOC, TaskKind.RESIDENT):
                    heapq.heappop(ready[n])
                    # CALLOC is async (§3.3); RESIDENT binds an
                    # already-materialised session tile — zero-cost
                    # inputs, so `auto` verdicts stay honest
                    dur = 1e-6  # no worker slot occupied
                    intervals.append(Interval(tid, t.kind.value, n, -1,
                                              now, now + dur))
                    push(now + dur, "task_done", tid)
                    continue
                if free_workers[n] <= 0:
                    break
                heapq.heappop(ready[n])
                free_workers[n] -= 1
                slot = free_slots[n].pop()
                dur = cost.time(t, n)
                intervals.append(Interval(tid, t.kind.value, n, slot,
                                          now, now + dur))
                push(now + dur, "task_done", (tid, slot))

    # seed: source tasks are immediately ready
    for t in g.sources():
        task_ready(t.tid)
    dispatch(0.0)

    while events:
        now, _, kind, payload = heapq.heappop(events)
        if kind == "task_done":
            if isinstance(payload, tuple):
                tid, slot = payload
                n = node_of[tid]
                free_workers[n] += 1
                free_slots[n].append(slot)
            else:
                tid = payload
            finish_producer(tid)
        elif kind == "xfer_done":
            tr: Transfer = payload
            free_comm[tr.src] += 1
            free_comm[tr.dst] += 1
            cache.put(tr.dst, tr.key, tr.nbytes)
            transfers_done.append(tr)
            in_flight.discard((tr.key, tr.dst))
            for s in waiting_data.pop((tr.key, tr.dst), []):
                data_left[s] -= 1
                if deps_left[s] == 0 and data_left[s] == 0:
                    task_ready(s)
            # the landed copy relays onward to its broadcast children
            for child in relay_children.pop((tr.key, tr.dst), []):
                heapq.heappush(
                    pending_xfers,
                    (relay_prio.get((tr.key, child), 0), next(xseq),
                     Transfer(tr.key, tr.dst, child, tr.nbytes)))
        dispatch(now)

    makespan = max((iv.end for iv in intervals), default=0.0)
    return SimResult(makespan, intervals, transfers_done,
                     cache.hits, cache.misses, spec)


# -- churn pricing (elastic runtime) ----------------------------------------

def predict_reload_seconds(nbytes: int, tm: TimeModel) -> float:
    """Wall-clock to reload ``nbytes`` of checkpointed tiles from disk —
    the reload leg of the durable session's per-handle reload-vs-recompute
    choice (the recompute leg is ``CMMEngine.predict_recompute_seconds``,
    simulated with the same TimeModel)."""
    return float(nbytes) / max(tm.spill_read_bandwidth, 1.0)


def predict_spill_seconds(excess_bytes: int, tm: TimeModel) -> float:
    """Wall-clock cost of running a plan out-of-core: every byte above
    the arena budget is written to the spill tier once and faulted back
    at least once, priced at the TimeModel's spill bandwidths.  Used by
    the engine's admission check to annotate spill-executable plans so
    degradation is chosen with its price known, not suffered."""
    b = float(max(0, excess_bytes))
    return (b / max(tm.spill_write_bandwidth, 1.0)
            + b / max(tm.spill_read_bandwidth, 1.0))


def predict_checkpoint_overhead(nbytes: int, tm: TimeModel) -> float:
    """Steady-state cost one asynchronous tile snapshot adds to the
    session path: the fixed writer handoff plus the host-side copy of the
    dirty tiles, priced at the spill bandwidth (the disk write itself
    overlaps the next compute)."""
    return tm.checkpoint_write_overhead + predict_reload_seconds(nbytes, tm)


def predict_recovery_cost(g: TaskGraph, sched: Schedule, spec: ClusterSpec,
                          tm: TimeModel, node: int,
                          cost: Optional[CostCache] = None,
                          checkpoint_bytes: Optional[int] = None) -> float:
    """Predicted wall-clock cost of losing ``node`` mid-run.

    The elastic runtime recovers by lineage: every tile the dead node held
    is recomputed from its producer subgraph on the survivors (no tile
    data is checkpointed), so the dominant term is re-executing the tasks
    HEFT had placed on ``node``.  A uniformly random failure time loses
    half of that work in expectation; recomputation spreads over the
    surviving compute slots.  ``tm.respawn_overhead`` adds the fixed
    detection + re-plan + rewire cost of one recovery event.

    ``checkpoint_bytes`` is the durable-session extension: when the lost
    tiles also exist as checkpoint shards of that many bytes, recovery
    takes the *cheaper* of lineage recompute and reload-from-disk — the
    same per-tile choice ``CMMSession.resume`` makes.
    """
    surv = sum(spec.workers_at(k) for k in spec.alive_nodes() if k != node)
    if surv <= 0:
        return float("inf")
    if cost is None:
        cost = CostCache(tm, spec)
    lost = sum(cost.time(g.tasks[tid], node)
               for tid, p in sched.placements.items() if p.node == node)
    recompute = 0.5 * lost / surv
    if checkpoint_bytes is not None:
        recompute = min(recompute,
                        predict_reload_seconds(checkpoint_bytes, tm))
    return tm.respawn_overhead + recompute


def churn_adjusted_makespan(g: TaskGraph, sched: Schedule, spec: ClusterSpec,
                            tm: TimeModel, base: Optional[float] = None,
                            cost: Optional[CostCache] = None) -> float:
    """Expected makespan once node-failure risk is priced in.

    ``base`` (default: the schedule's makespan) is inflated by, per
    non-master node, the probability of losing that node during the run
    (``base / tm.node_mtbf``, capped at 1) times its predicted recovery
    cost.  With the default ``node_mtbf = inf`` this is exactly ``base``,
    so pristine-cluster auto-selection is unchanged.
    """
    import math
    base = sched.makespan if base is None else base
    if not math.isfinite(tm.node_mtbf) or tm.node_mtbf <= 0:
        return base
    if cost is None:
        cost = CostCache(tm, spec)
    total = base
    for node in spec.alive_nodes():
        if node == spec.master:
            continue
        p_fail = min(1.0, base / tm.node_mtbf)
        total += p_fail * predict_recovery_cost(g, sched, spec, tm, node,
                                                cost=cost)
    return total
