"""Simulation-driven configuration search (CMM §3.3 generalised).

The paper picks tile sizes by simulating candidate schedules under the time
model and taking the argmin makespan.  This module keeps that loop generic so
the same machinery tunes (a) matrix tile sizes for the CMM engine and (b)
layout/microbatch candidates for the LM stack (where the "simulator" is the
roofline model over the compiled dry-run — see launch/roofline.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Generic, Iterable, List, Sequence, Tuple, TypeVar

C = TypeVar("C")


@dataclass
class TuneResult(Generic[C]):
    best: C
    scores: List[Tuple[C, float]]  # (candidate, predicted cost), sorted asc

    def table(self) -> str:
        rows = [f"  {c!r:>24} -> {s:.6f}" for c, s in self.scores]
        return "\n".join(rows)


def argmin_search(candidates: Iterable[C],
                  cost_fn: Callable[[C], float]) -> TuneResult:
    scored = [(c, float(cost_fn(c))) for c in candidates]
    scored.sort(key=lambda cs: cs[1])
    if not scored:
        raise ValueError("no candidates")
    return TuneResult(scored[0][0], scored)


def tile_candidates(dim: int, granularity: int = 10) -> List[int]:
    """Paper-style candidate grid: dim/10, 3dim/10, 5dim/10, 7dim/10 (+full)."""
    fracs = [1, 3, 5, 7]
    cands = sorted({max(1, dim * f // granularity) for f in fracs})
    if dim not in cands:
        cands.append(dim)
    return cands


def tune_tile(engine, root, candidates: Sequence[int] = None) -> TuneResult:
    """Tile-size selection by simulated makespan (the §3.3 loop).

    Each candidate is costed at its best predicted *strategy* (per-task
    HEFT simulation vs wave-batched execution), so the tuner can trade
    smaller tiles against batched dispatch — the paper's simulation-driven
    selection extended over executor strategy.
    """
    from .lazy import topo_order
    if candidates is None:
        dim = max(max(n.shape) for n in topo_order(root))
        candidates = tile_candidates(dim)
    return argmin_search(
        candidates,
        lambda t: engine.plan(root, tile=t).best_predicted_makespan)
