"""Cluster machine model (CMM §4.1–4.2).

The paper's ideal configuration per c5.9xlarge node: 3 worker processes
(4 BLAS threads each), 2 communication processes on workers, more on the
master; 10 Gbps shared network.  These are *model* parameters — the HEFT
scheduler and the discrete-event simulator consume them.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple


class MemoryBudgetExceeded(RuntimeError):
    """A plan's (or a running node's) memory footprint cannot fit the
    cluster's per-node ``mem_bytes`` budget even out-of-core: the minimum
    resident working set — one task's operands plus the node's retained
    session tiles — exceeds the budget, so no amount of spilling helps.
    Raised by the engine's admission check (instead of planning a run that
    would OOM) and by the executors when a worker arena overflows with
    nothing left to evict.  Carries the offending node and bytes."""

    def __init__(self, node: int, needed_bytes: int = 0,
                 budget_bytes: int = 0, msg: str = ""):
        self.node = int(node)
        self.needed_bytes = int(needed_bytes)
        self.budget_bytes = int(budget_bytes)
        super().__init__(msg or (
            f"node {self.node} needs {self.needed_bytes} resident bytes "
            f"but its memory budget is {self.budget_bytes} bytes"))


@dataclass(frozen=True)
class ClusterSpec:
    n_nodes: int = 1
    #: compute slots per node (paper: 3 worker processes x 4 BLAS threads)
    worker_procs: int = 3
    threads_per_worker: int = 4
    #: dedicated communication processes (paper: master gets more, §3.6)
    comm_procs_worker: int = 2
    comm_procs_master: int = 4
    #: link bandwidth, bytes/s (c5.9xlarge: 10 Gbps guaranteed)
    link_bw: float = 10e9 / 8
    #: per-message latency, s
    latency: float = 200e-6
    #: per-pair bandwidth overrides {(a,b): bytes/s} — the paper's fix of
    #: modelling *connection speeds between two nodes* (§3.4)
    pair_bw: Tuple[Tuple[Tuple[int, int], float], ...] = ()
    #: master node index
    master: int = 0
    #: per-node compute slowdown factors (straggler modelling, runtime/fault)
    slowdown: Tuple[float, ...] = ()
    #: per-node worker-process overrides (heterogeneous clusters: unequal
    #: slot counts per node).  Empty -> every node gets ``worker_procs``.
    node_workers: Tuple[int, ...] = ()
    #: per-node arena memory budget in bytes.  ``None`` -> unbounded (the
    #: pre-out-of-core behaviour).  When set, worker arenas spill cold
    #: unpinned tiles to disk rather than exceeding it, and the engine's
    #: admission check prices or rejects plans against it.
    mem_bytes: Optional[float] = None
    #: per-node overrides of ``mem_bytes`` (elastic ``with_mem`` deltas,
    #: mid-run ``mem_squeeze`` chaos).  Entries < 0 fall back to
    #: ``mem_bytes``; nodes beyond the tuple's length fall back too.
    node_mem: Tuple[float, ...] = ()

    def comm_procs(self, node: int) -> int:
        return self.comm_procs_master if node == self.master \
            else self.comm_procs_worker

    def workers_at(self, node: int) -> int:
        """Compute slots on ``node`` (heterogeneous-aware).

        A zero entry means the node is **drained** (evicted from the
        cluster by the elastic runtime, ``without_node``): it holds no
        compute slots and the scheduler must not place tasks there.
        """
        if self.node_workers and node < len(self.node_workers):
            return max(0, self.node_workers[node])
        return self.worker_procs

    def total_workers(self) -> int:
        return sum(self.workers_at(n) for n in range(self.n_nodes))

    def alive_nodes(self) -> Tuple[int, ...]:
        """Nodes that still hold compute slots (not drained)."""
        return tuple(n for n in range(self.n_nodes)
                     if self.workers_at(n) > 0)

    def bandwidth(self, a: int, b: int) -> float:
        for (pa, pb), bw in self.pair_bw:
            if (pa, pb) == (a, b) or (pa, pb) == (b, a):
                return bw
        return self.link_bw

    def node_slowdown(self, node: int) -> float:
        if self.slowdown and node < len(self.slowdown):
            return self.slowdown[node]
        return 1.0

    def mem_at(self, node: int) -> Optional[int]:
        """Arena byte budget of ``node``; ``None`` means unbounded."""
        if self.node_mem and node < len(self.node_mem):
            v = self.node_mem[node]
            if v >= 0:
                return int(v)
        return None if self.mem_bytes is None else int(self.mem_bytes)

    def with_mem(self, node: int, nbytes: Optional[float]) -> "ClusterSpec":
        """The spec with ``node``'s memory budget replaced — how the
        elastic runtime records a mid-run ``mem_squeeze`` so subsequent
        plans are admitted against the shrunk budget.  ``None`` lifts
        the per-node override (falling back to ``mem_bytes``)."""
        if not 0 <= node < self.n_nodes:
            raise ValueError(f"no node {node} in a {self.n_nodes}-node spec")
        nm = []
        for n in range(self.n_nodes):
            cur = self.mem_at(n)
            nm.append(-1.0 if cur is None else float(cur))
        nm[node] = -1.0 if nbytes is None else float(nbytes)
        return replace(self, node_mem=tuple(nm))

    def comm_time(self, nbytes: int, a: int, b: int) -> float:
        if a == b:
            return 0.0
        return self.latency + nbytes / self.bandwidth(a, b)

    def with_nodes(self, n: int) -> "ClusterSpec":
        return replace(self, n_nodes=n)

    # -- membership deltas (elastic runtime) --------------------------------
    def _all_workers(self) -> Tuple[int, ...]:
        return tuple(self.workers_at(n) for n in range(self.n_nodes))

    def _all_slowdowns(self) -> Tuple[float, ...]:
        return tuple(self.node_slowdown(n) for n in range(self.n_nodes))

    def without_node(self, node: int) -> "ClusterSpec":
        """The spec after ``node`` leaves the cluster (dies or is evicted).

        Node indices stay stable — the departed node is *drained* (zero
        worker slots) rather than renumbered, so placements recorded
        against the old spec remain addressable during recovery.
        """
        if node == self.master:
            raise ValueError("cannot remove the master node")
        if not 0 <= node < self.n_nodes:
            raise ValueError(f"no node {node} in a {self.n_nodes}-node spec")
        nw = list(self._all_workers())
        nw[node] = 0
        return replace(self, node_workers=tuple(nw))

    def with_node(self, workers: Optional[int] = None,
                  slowdown: float = 1.0) -> "ClusterSpec":
        """The spec after a new node joins, appended at index
        ``n_nodes`` with ``workers`` compute slots (default: the spec's
        homogeneous ``worker_procs``)."""
        w = self.worker_procs if workers is None else int(workers)
        if w <= 0:
            raise ValueError("a joining node needs at least one worker")
        return replace(self, n_nodes=self.n_nodes + 1,
                       node_workers=self._all_workers() + (w,),
                       slowdown=self._all_slowdowns() + (float(slowdown),))

    def with_slowdown(self, node: int, slowdown: float) -> "ClusterSpec":
        """The spec with ``node``'s compute slowdown factor replaced —
        how the elastic runtime re-prices an observed straggler before
        re-planning the frontier."""
        sd = list(self._all_slowdowns())
        sd[node] = float(slowdown)
        return replace(self, slowdown=tuple(sd))

    def zero_comm(self) -> "ClusterSpec":
        """Theoretical-speedup variant (§5.1): instantaneous communication."""
        return replace(self, link_bw=float("inf"), latency=0.0, pair_bw=())


def c5_9xlarge(n_nodes: int = 1, **kw) -> ClusterSpec:
    """The paper's AWS instance: 36 vCPU / 18 physical cores, 10 Gbps."""
    return ClusterSpec(n_nodes=n_nodes, **kw)


def hetero_spec(node_workers: Sequence[int],
                slowdown: Sequence[float] = (), **kw) -> ClusterSpec:
    """A heterogeneous cluster: one node per entry of ``node_workers`` with
    that many worker processes, optionally per-node compute slowdowns —
    the spec shape the multi-process ClusterExecutor exercises."""
    return ClusterSpec(n_nodes=len(node_workers),
                       node_workers=tuple(int(w) for w in node_workers),
                       slowdown=tuple(float(s) for s in slowdown), **kw)


def local_spec(n_nodes: int = 1, **kw) -> ClusterSpec:
    """Machine model matching THIS host (for sim-vs-exec accuracy runs):
    worker slots capped at the real core count — a 1-core container cannot
    run 3 BLAS workers in parallel, and the simulator must know that."""
    import os
    kw.setdefault("worker_procs", max(1, min(3, os.cpu_count() or 1)))
    return ClusterSpec(n_nodes=n_nodes, **kw)


def tpu_v5e_pod(n_nodes: int = 256, **kw) -> ClusterSpec:
    """TPU-flavoured machine model for the simulator (ICI ~50 GB/s/link).

    Used when the CMM simulator models the TPU mesh rather than the AWS
    cluster: one 'node' = one chip, comm = ICI.
    """
    kw.setdefault("worker_procs", 1)
    kw.setdefault("comm_procs_worker", 2)
    kw.setdefault("comm_procs_master", 2)
    kw.setdefault("link_bw", 50e9)
    kw.setdefault("latency", 1e-6)
    return ClusterSpec(n_nodes=n_nodes, **kw)
