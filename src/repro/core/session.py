"""CMMSession: resident distributed tiles across ``compute()`` calls.

The one-shot engine path (``CMMEngine.run``) re-fills every leaf, executes,
gathers the full ndarray to the master and discards all executor state —
so iterative workloads (power iteration, the paper's Markov chain) pay
scatter/gather and re-fill on every step that a resident cluster never
pays.  numpywren keeps intermediates in remote storage between stages and
DistStat.jl's distributed arrays stay resident across calls; this module
brings that to CMM:

* :class:`CMMSession` owns a **long-lived executor** (worker processes and
  their shared-memory arenas survive across runs for the cluster/elastic
  backends) and a **residency table** mapping handles to live tiles;
* :meth:`CMMSession.persist` computes an expression and leaves the result
  **tiled in the executor's arenas** (local slab / per-node SharedMemory),
  returning a :class:`ResidentMatrix`;
* a ``ResidentMatrix`` re-enters later expressions as a zero-cost,
  location-pinned leaf: tiling maps its tiles one-for-one onto RESIDENT
  tasks (no FILL, no gather), HEFT pins each RESIDENT task to the node
  whose arena holds the tile, and the simulator prices it at ~0 so
  ``auto`` verdicts stay honest;
* :meth:`ResidentMatrix.to_numpy` gathers on demand;
* on the **elastic** backend a resident tile lost to a node death is a
  *recomputable root*: every handle carries the expression (lineage) that
  produced it, and the session transparently re-derives lost handles from
  lineage — numpywren-style recovery extended across runs.

Bit-identity contract: a persisted k-step chain is bitwise identical to
the equivalent one-shot expression on every backend, because each step
executes the same tiled kernels on the same bits and tile movement is
bit-copying (asserted in ``tests/test_session.py``).

**Durable sessions**: constructed with ``checkpoint_dir=...`` the session
snapshots every persisted handle's tiles to disk (asynchronously,
incremental per handle — see ``runtime/durability.py``) together with its
pickled lineage, and :meth:`CMMSession.resume` rebuilds the residency
table from the newest intact snapshot after a full-cluster crash —
SIGKILL of master and every worker mid-``compute()`` included.  Restore
chooses reload-from-disk vs recompute-from-lineage per handle, priced
through the ``TimeModel`` (``spill_read_bandwidth``); a corrupt shard
degrades to lineage recompute instead of resurrecting wrong bytes.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .engine import CMMEngine, Plan
from .lazy import ClusteredMatrix, Op, topo_order_many
from .simulator import predict_reload_seconds
from .tiling import normalize_tile, grid_of, tile_slices, result_sets_of

_hid_counter = itertools.count(1)
_hid_lock = threading.Lock()


def _next_hid() -> int:
    with _hid_lock:
        return next(_hid_counter)


def _ensure_hid_floor(n: int) -> None:
    """Advance the handle-id counter to at least ``n`` — resume() restores
    handles under their checkpointed hids, and new handles made afterwards
    must not collide with them."""
    global _hid_counter
    with _hid_lock:
        cur = next(_hid_counter)
        _hid_counter = itertools.count(max(cur, n))


class ResidentTilesLost(RuntimeError):
    """Raised by an elastic executor when tiles of a resident handle were
    on a node that died (and no live copy remains).  The session catches
    it, re-derives the named handles from lineage and retries the run."""

    def __init__(self, hids: Sequence[int], msg: str = ""):
        self.hids = tuple(sorted(set(hids)))
        super().__init__(msg or f"resident tiles lost for handles "
                                f"{self.hids}")


class SessionUnrecoverable(RuntimeError):
    """The session exhausted its bounded retry budget (``max_retries``)
    re-deriving lost resident tiles, or a restore found a handle with
    neither intact shards nor lineage.  Carries the lost handle ids."""

    def __init__(self, hids: Sequence[int], msg: str = ""):
        self.hids = tuple(sorted(set(hids)))
        super().__init__(msg or f"resident handles {self.hids} are "
                                f"unrecoverable")


@dataclass
class ResidentHandle:
    """Identity + location of one persisted result's tiles.

    Pure data (no session/executor references) so it can cross a process
    boundary if it ever needs to; all tile *storage* lives in the session
    (ndarrays for in-process backends, (node, segment, dtype) triples for
    the multi-process ones).
    """

    hid: int
    shape: Tuple[int, int]
    dtype: "np.dtype"
    tile: Tuple[int, int]
    grid: Tuple[int, int]
    #: (i, j) -> node whose arena holds that tile (0 for in-process)
    home: Dict[Tuple[int, int], int] = field(default_factory=dict)
    name: str = ""
    #: the expression that produced this handle — the recompute lineage.
    #: May itself reference other ResidentMatrix leaves (lineage chains).
    lineage: Optional[ClusteredMatrix] = None
    alive: bool = True
    #: tiles lost to a node death; next use re-derives from lineage
    lost: bool = False

    def tiles(self):
        gm, gn = self.grid
        for i in range(gm):
            for j in range(gn):
                yield (i, j)


class ResidentMatrix(ClusteredMatrix):
    """A persisted result as a lazy leaf: composes with every
    ``ClusteredMatrix`` operator, but its tiles are already resident in
    the session executor's arenas — re-entering an expression costs no
    FILL and no gather."""

    def __init__(self, handle: ResidentHandle, session: "CMMSession",
                 name: str = ""):
        super().__init__(Op.RESIDENT, handle.shape, handle.dtype,
                         payload=handle, name=name or handle.name)
        self._session = session

    @property
    def handle(self) -> ResidentHandle:
        return self.payload

    def to_numpy(self) -> np.ndarray:
        """Gather the resident tiles into one ndarray (on demand — the
        only point where resident data crosses back to the master)."""
        return self._session.gather(self.handle)

    def free(self) -> None:
        """Release this handle's tiles from the executor arenas."""
        self._session.free(self.handle)


class SessionResidency:
    """Per-run residency view handed to the executor via ``plan.residency``:
    read access to resident input tiles and retention sinks for persisted
    outputs.  All storage lives on the session; this object scopes one run's
    leaf-uid / root-uid namespaces onto it."""

    def __init__(self, session: "CMMSession",
                 handles: Dict[int, ResidentHandle],
                 retain: Dict[int, ResidentHandle]):
        self._session = session
        #: leaf expr uid -> handle (resident INPUTS of this run)
        self.handles = handles
        #: root expr uid -> handle (persisted OUTPUTS of this run)
        self.retain = retain

    # -- executor read path (in-process backends) ---------------------------
    def tile(self, leaf_uid: int, i: int, j: int) -> np.ndarray:
        h = self.handles[leaf_uid]
        return self._session._tiles[(h.hid, i, j)]

    # -- executor read path (multi-process backends) ------------------------
    def seg(self, leaf_uid: int, i: int, j: int) -> Tuple[int, str, str]:
        h = self.handles[leaf_uid]
        return self._session._segs[(h.hid, i, j)]

    def resident_ids(self) -> Dict[int, int]:
        """leaf uid -> handle id (what cluster workers need to resolve a
        RESIDENT task against their retained arena store)."""
        return {uid: h.hid for uid, h in self.handles.items()}

    # -- executor retention sinks -------------------------------------------
    def retain_local(self, root_uid: int, i: int, j: int,
                     arr: np.ndarray) -> None:
        h = self.retain[root_uid]
        self._session._tiles[(h.hid, i, j)] = arr
        h.home[(i, j)] = 0

    def retain_seg(self, root_uid: int, i: int, j: int, node: int,
                   segname: str, dtype_str: str) -> None:
        h = self.retain[root_uid]
        self._session._segs[(h.hid, i, j)] = (node, segname, dtype_str)
        h.home[(i, j)] = node


#: executor registry names that run inside the master process (tile storage
#: is plain ndarrays owned by the session)
_INPROC = ("local", "kernel", "batched", "batched-pallas")


class CMMSession:
    """The session engine: plan-cache-backed compute over a long-lived
    executor whose arenas persist between calls.

    ::

        with CMMSession(engine, executor="cluster", tile=32) as s:
            P = s.persist(CM.rand(n, n, seed=0))      # tiles stay remote
            u = s.persist(CM.rand(n, 1, seed=1))
            for _ in range(k):
                u = s.persist(P @ u)                  # no gather, no refill
            result = u.to_numpy()                     # one gather, at the end

    ``executor`` is a registry name; for ``"cluster"``/``"elastic"`` the
    worker processes are spawned once and survive across runs, and
    persisted tiles live in the workers' shared-memory arenas.  ``close()``
    frees every live handle, audits the worker arenas for leaks (refcount
    audit) and shuts the workers down; the session is also a context
    manager.
    """

    def __init__(self, engine: Optional[CMMEngine] = None,
                 executor: str = "local", tile=None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1,
                 max_retries: int = 3,
                 retry_backoff_s: float = 0.05, **exec_kw):
        self.engine = engine or CMMEngine()
        self.executor = executor
        self.tile = tile if tile is not None else self.engine.tile
        self._tiles: Dict[Tuple[int, int, int], np.ndarray] = {}
        self._segs: Dict[Tuple[int, int, int], Tuple[int, str, str]] = {}
        self._handles: Dict[int, ResidentHandle] = {}
        self._closed = False
        self._closing = False
        self.stats: Dict[str, object] = {}
        #: bounded-retry policy for lost resident tiles (satellite of the
        #: durability work: the old path recursed without backoff)
        self.max_retries = max(0, int(max_retries))
        self.retry_backoff_s = float(retry_backoff_s)
        #: durability (None -> plain in-memory session, as before)
        self._store = None
        self.checkpoint_every = max(1, int(checkpoint_every))
        self._dirty: Set[int] = set()
        self._persists_since_ckpt = 0
        self._ckpt_step = 0
        #: flight recorder: spans accumulated across every run of this
        #: session (master lanes + ingested worker lanes), plus a
        #: master-side tracer for CHECKPOINT spans; ``dump_trace`` exports
        #: the whole session timeline
        from ..runtime.telemetry import Tracer
        self._trace_spans: List = []
        self._tracer = Tracer(node=-1,
                              enabled=bool(exec_kw.get("trace", True)))
        self._last_plan: Optional[Plan] = None
        if checkpoint_dir is not None:
            from ..runtime.durability import TileCheckpointStore
            self._store = TileCheckpointStore(checkpoint_dir)
            self._store.tracer = self._tracer
            # never renumber over snapshots an earlier incarnation left:
            # snap_<N> publication rmtree's an existing snap_<N>, which
            # would tear shards still referenced by newer manifests
            self._ckpt_step = max(self._store.snaps(), default=0)
        if executor in _INPROC:
            from ..exec import make_executor
            self._exec = make_executor(executor, **exec_kw)
        elif executor == "cluster":
            from ..exec.cluster import ClusterExecutor
            self._exec = ClusterExecutor(session=True, **exec_kw)
        elif executor == "elastic":
            from ..exec.elastic import ElasticClusterExecutor
            exec_kw.setdefault("timemodel", self.engine.timemodel)
            self._exec = ElasticClusterExecutor(session=True, **exec_kw)
        else:
            raise ValueError(f"unknown session executor {executor!r}")
        if self._store is not None \
                and hasattr(self._exec, "corrupt_tile_hook"):
            self._exec.corrupt_tile_hook = self._corrupt_shard

    # -- public API ----------------------------------------------------------
    def compute(self, expr: ClusteredMatrix, tile=None) -> np.ndarray:
        """Materialise one expression (resident leaves enter at zero cost)."""
        return self._run([expr], persist=(), tile=tile)[0]

    def compute_many(self, exprs: Sequence[ClusteredMatrix],
                     tile=None) -> List[np.ndarray]:
        """Materialise several roots as ONE program: subexpressions shared
        across roots are planned and executed once (shared CSE)."""
        return self._run(list(exprs), persist=(), tile=tile)

    def persist(self, expr: ClusteredMatrix, name: str = "",
                tile=None) -> ResidentMatrix:
        """Compute ``expr`` and keep the result tiled in the executor's
        arenas; returns the handle as a reusable lazy leaf."""
        if isinstance(expr, ResidentMatrix) and expr._session is self \
                and expr.handle.alive and not expr.handle.lost:
            return expr                     # already resident here
        (rm,) = self._run([expr], persist=(0,), tile=tile, names=(name,))
        return rm

    def gather(self, handle: ResidentHandle) -> np.ndarray:
        """Assemble a resident handle's tiles into one master ndarray.

        Streaming assembly: each tile is copied exactly once, straight
        from its arena segment into its slice of the output — never via
        a tile-sized staging copy (halves gather traffic and keeps peak
        memory at output + one segment mapping)."""
        self._check_handle(handle)
        if handle.lost:
            self._recompute(handle)
        rows = tile_slices(handle.shape[0], handle.tile[0])
        cols = tile_slices(handle.shape[1], handle.tile[1])
        out = np.empty(handle.shape, dtype=handle.dtype)
        for (i, j) in handle.tiles():
            key = (handle.hid, i, j)
            (r0, r1), (c0, c1) = rows[i], cols[j]
            if key in self._tiles:
                out[r0:r1, c0:c1] = self._tiles[key]
            else:
                self._attach_tile(key, out=out[r0:r1, c0:c1])
        return out

    def free(self, handle: ResidentHandle) -> None:
        """Drop a handle's tiles from the arenas (its ResidentMatrix
        leaves become unusable; dependents lose their recompute lineage)."""
        if not handle.alive:
            return
        handle.alive = False
        registered = self._handles.pop(handle.hid, None) is not None
        for (i, j) in handle.tiles():
            self._tiles.pop((handle.hid, i, j), None)
            ent = self._segs.pop((handle.hid, i, j), None)
            if ent is not None:
                self._drop_seg(handle.hid, i, j, ent)
        if registered and self._store is not None and not self._closing:
            # a freed handle must not resurrect on resume: publish a
            # snapshot without it (cheap — survivors carry over).  Only
            # for handles that made it into the table: abandoning a
            # half-retained run's outputs is not a durability event.
            self.checkpoint()

    # -- flight recorder ------------------------------------------------------
    @property
    def spans(self) -> List:
        """Every span recorded so far this session: executor spans of all
        runs plus master-side CHECKPOINT spans (async writes drained in)."""
        return list(self._trace_spans) + self._tracer.snapshot()

    def dump_trace(self, path: str, include_predicted: bool = False) -> int:
        """Export the session's accumulated timeline as Chrome-trace JSON
        (``chrome://tracing`` / https://ui.perfetto.dev).  With
        ``include_predicted`` the LAST run's simulated timeline is
        overlaid on shifted lanes.  Returns the number of events."""
        spans = self.spans
        if include_predicted and self._last_plan is not None \
                and self._last_plan.sim is not None:
            spans += self._last_plan.sim.predicted_spans()
        from ..runtime.telemetry import export_chrome_trace
        return len(export_chrome_trace(spans, path)["traceEvents"])

    def drift_report(self, **kw):
        """Predicted-vs-actual drift of the LAST run in this session
        (:func:`repro.core.drift.drift_report`): per-node residual ratios
        and TimeModel terms flagged for recalibration."""
        if self._last_plan is None:
            raise RuntimeError("no executed plan to analyse — "
                               "compute()/persist() first")
        from .drift import drift_report
        return drift_report(self.engine.last_spans, self._last_plan,
                            tm=self.engine.timemodel, **kw)

    def close(self) -> Dict[str, object]:
        """Free every live handle, audit the executor arenas for leaks and
        shut down the long-lived executor.  Raises ``RuntimeError`` if the
        refcount audit finds stranded buffers (a retained tile the session
        no longer tracks, or a run that leaked arena segments)."""
        if self._closed:
            return self.stats
        self._closing = True          # an orderly close keeps the last
        if self._store is not None:   # snapshot resumable: free() must
            self._store.wait()        # not republish without the handles
        for h in list(self._handles.values()):
            self.free(h)
        audit: Dict[str, object] = {"handles_leaked": len(self._handles),
                                    "local_tiles_leaked": len(self._tiles)}
        if hasattr(self._exec, "close_session"):
            arena_audit = self._exec.close_session()
            # the executor's spill-file sweep rides along under a string
            # key; split it out so arena stays strictly per-node
            audit["spill"] = arena_audit.pop("spill",
                                             {"leaked_spill_files": 0})
            audit["arena"] = arena_audit
        self._closed = True
        self.stats["audit"] = audit
        leaked = audit["local_tiles_leaked"] or audit["handles_leaked"]
        arena = audit.get("arena") or {}
        for node, st in arena.items():
            leaked = leaked or st.get("live_buffers", 0) \
                or st.get("retained", 0) or st.get("spill_files", 0)
        spill = audit.get("spill") or {}
        leaked = leaked or spill.get("leaked_spill_files", 0)
        if leaked:
            raise RuntimeError(f"session arena audit failed: {audit}")
        return audit

    def __enter__(self) -> "CMMSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:                    # don't mask the original error
            try:
                self.close()
            except Exception:
                pass

    # -- internals -----------------------------------------------------------
    def _sync_spec(self) -> None:
        """After an elastic run, membership may have changed (deaths drain
        nodes, joins append them).  Future plans must target the executor's
        current spec, or they would place tasks on nodes that left — and
        EVERY handle with tiles homed on a departed node is lost, not just
        the ones the failed run happened to read (their next use
        re-derives them from lineage)."""
        cur = getattr(self._exec, "current_spec", None)
        if cur is None:
            return
        if cur != self.engine.spec:
            self.engine.spec = cur
        alive = set(cur.alive_nodes())
        for h in self._handles.values():
            if not h.lost and any(n not in alive for n in h.home.values()):
                h.lost = True

    def _check_handle(self, handle: ResidentHandle) -> None:
        if not handle.alive:
            raise ValueError(f"resident handle #{handle.hid} "
                             f"({handle.name!r}) was freed")
        if handle.hid not in self._handles:
            raise ValueError(f"resident handle #{handle.hid} does not "
                             f"belong to this session")

    def _attach_tile(self, key, out: Optional[np.ndarray] = None
                     ) -> np.ndarray:
        """Read one tile out of a worker arena segment (cluster backends).
        With ``out`` the segment streams straight into the caller's
        buffer (one copy); without it a fresh tile-sized copy returns."""
        node, sname, dt = self._segs[key]
        from ..exec.cluster import _attach_shm
        hid, i, j = key
        h = self._handles[hid]
        from .tiling import tile_shape
        shp = tile_shape(h.shape, h.tile, i, j)
        seg = _attach_shm(sname)
        try:
            view = np.ndarray(shp, dtype=np.dtype(dt), buffer=seg.buf)
            if out is not None:
                np.copyto(out, view)
                return out
            return view.copy()
        finally:
            seg.close()

    def _drop_seg(self, hid: int, i: int, j: int, ent) -> None:
        """Tell the owning worker to drop a retained segment."""
        drop = getattr(self._exec, "drop_retained", None)
        if drop is not None:
            drop(ent[0], (hid, i, j))

    def _prepare(self, roots: Sequence[ClusteredMatrix], tile
                 ) -> List[ClusteredMatrix]:
        """Validate/normalise resident leaves for this run: foreign or
        freed handles are errors; lost handles are re-derived from lineage;
        a handle persisted at a different tile size is transparently
        gathered and re-enters as an INPUT leaf (correct, just not
        zero-cost)."""
        t = normalize_tile(tile)
        subst: Dict[int, ClusteredMatrix] = {}
        for node in topo_order_many(roots):
            if node.op is not Op.RESIDENT:
                continue
            if not isinstance(node, ResidentMatrix) or node._session is not \
                    self:
                raise ValueError(
                    f"resident leaf #{node.uid} does not belong to this "
                    f"session (persist() it here first)")
            h = node.handle
            self._check_handle(h)
            if h.lost:
                self._recompute(h)
            if tuple(h.tile) != t:
                subst[node.uid] = ClusteredMatrix.from_array(
                    self.gather(h), name=h.name or node.name)
        if not subst:
            return list(roots)
        new: Dict[int, ClusteredMatrix] = {}
        for node in topo_order_many(roots):
            if node.uid in subst:
                new[node.uid] = subst[node.uid]
                continue
            parents = tuple(new[p.uid] for p in node.parents)
            new[node.uid] = node if parents == node.parents else \
                ClusteredMatrix(node.op, node.shape, node.dtype,
                                parents=parents, payload=node.payload,
                                name=node.name)
        return [new[r.uid] for r in roots]

    def _tile_for(self, roots: Sequence[ClusteredMatrix], tile):
        if tile is not None:
            return normalize_tile(tile)
        if self.tile is not None:
            return normalize_tile(self.tile)
        return normalize_tile(self.engine._default_tile(roots))

    def _run(self, roots: List[ClusteredMatrix], persist: Sequence[int],
             tile=None, names: Sequence[str] = ()):
        """Bounded-retry driver around :meth:`_run_once`: each attempt that
        fails with ``ResidentTilesLost`` marks the named handles lost (the
        next attempt re-derives them from lineage inside ``_prepare``) and
        backs off exponentially; after ``max_retries + 1`` attempts the
        loss is declared :class:`SessionUnrecoverable`."""
        if self._closed:
            raise RuntimeError("session is closed")
        last: Optional[ResidentTilesLost] = None
        for attempt in range(self.max_retries + 1):
            if attempt:
                time.sleep(min(self.retry_backoff_s * (2 ** (attempt - 1)),
                               2.0))
            try:
                return self._run_once(roots, persist, tile, names)
            except ResidentTilesLost as e:
                self._sync_spec()
                for hid in e.hids:
                    h = self._handles.get(hid)
                    if h is not None:
                        h.lost = True
                last = e
        raise SessionUnrecoverable(
            last.hids,
            f"resident tiles for handles {last.hids} could not be "
            f"restored after {self.max_retries + 1} attempts: "
            f"{last}") from last

    def _run_once(self, roots: List[ClusteredMatrix],
                  persist: Sequence[int], tile=None,
                  names: Sequence[str] = ()):
        t = self._tile_for(roots, tile)
        prepared = self._prepare(roots, t)
        plan = self.engine.plan_many(prepared, tile=t, persist=persist)
        prog = plan.program

        handles: Dict[int, ResidentHandle] = {
            uid: n.payload for uid, n in prog.leaf_nodes.items()
            if n.op is Op.RESIDENT}
        retain: Dict[int, ResidentHandle] = {}
        new_handles: List[Tuple[int, ResidentHandle]] = []
        rsets = result_sets_of(prog.graph)
        for rs in rsets:
            if rs.gather:
                continue
            name = names[rs.index] if rs.index < len(names) else ""
            h = ResidentHandle(_next_hid(), rs.shape,
                               np.dtype(prog.dtypes.get(rs.uid, np.float64)),
                               t, rs.grid, name=name,
                               lineage=roots[rs.index])
            retain[rs.uid] = h
            new_handles.append((rs.index, h))

        plan.residency = SessionResidency(self, handles, retain)
        try:
            gathered = self.engine.execute_plan(plan, executor=self.executor,
                                                executor_obj=self._exec)
        except ResidentTilesLost:
            # a node died holding resident input tiles: abandon this
            # attempt's half-retained outputs and let the bounded _run
            # loop mark + re-derive the lost handles (deterministic
            # tasks -> the retry is bit-identical)
            for (_idx, h) in new_handles:
                self.free(h)
            raise
        self._sync_spec()
        self.stats["last_exec"] = dict(self._exec.stats)
        self._trace_spans.extend(self.engine.last_spans)
        self._last_plan = plan

        for (_idx, h) in new_handles:
            missing = [ij for ij in h.tiles()
                       if (h.hid,) + ij not in self._tiles
                       and (h.hid,) + ij not in self._segs]
            if missing:                       # pragma: no cover — defensive
                raise RuntimeError(f"executor retained no tile for "
                                   f"{missing[:4]} of handle #{h.hid}")
            self._handles[h.hid] = h
        if new_handles and self._store is not None:
            self._note_persisted([h.hid for _i, h in new_handles])

        # outputs in root order: gathered ndarrays for computed roots,
        # ResidentMatrix for persisted ones
        n_gather = sum(1 for rs in rsets if rs.gather)
        if gathered is None:
            garr: List[np.ndarray] = []
        elif isinstance(gathered, list):
            garr = gathered
        else:
            garr = [gathered]
        if len(garr) != n_gather:             # pragma: no cover — defensive
            raise RuntimeError(f"executor returned {len(garr)} results for "
                               f"{n_gather} gathered roots")
        out: List[object] = [None] * len(roots)
        gi = iter(garr)
        by_index = {idx: h for idx, h in new_handles}
        for rs in rsets:
            if rs.gather:
                out[rs.index] = next(gi)
            else:
                out[rs.index] = ResidentMatrix(by_index[rs.index], self)
        return out

    def _persist_into(self, handle: ResidentHandle,
                      expr: ClusteredMatrix) -> None:
        """Execute ``expr`` and retain its tiles under ``handle``'s
        EXISTING hid, rebinding residency into the current executor's
        arenas — the shared machinery of lineage recompute and
        checkpoint reload (both re-home a known handle, possibly onto a
        differently-shaped cluster).

        Drops stale locations first: surviving nodes may still hold
        retained segments of the old incarnation — tell them to release
        (a dead node's queue is gone and its segments were reaped with
        it)."""
        for (i, j) in handle.tiles():
            self._tiles.pop((handle.hid, i, j), None)
            ent = self._segs.pop((handle.hid, i, j), None)
            if ent is not None:
                self._drop_seg(handle.hid, i, j, ent)
        handle.home.clear()
        handle.lost = False                  # set before the run so nested
        prepared = self._prepare([expr], handle.tile)
        plan = self.engine.plan_many(prepared, tile=handle.tile,
                                     persist=(0,))
        prog = plan.program
        handles = {uid: n.payload for uid, n in prog.leaf_nodes.items()
                   if n.op is Op.RESIDENT}
        rs = next(r for r in result_sets_of(prog.graph) if not r.gather)
        plan.residency = SessionResidency(self, handles, {rs.uid: handle})
        self.engine.execute_plan(plan, executor=self.executor,
                                 executor_obj=self._exec)
        self._sync_spec()
        self._trace_spans.extend(self.engine.last_spans)
        self._last_plan = plan

    def _recompute(self, handle: ResidentHandle) -> None:
        """Re-derive a lost handle's tiles from its lineage expression,
        writing them back under the SAME hid so existing ResidentMatrix
        leaves stay valid."""
        if handle.lineage is None:
            raise ResidentTilesLost(
                (handle.hid,),
                f"resident handle #{handle.hid} lost its tiles and has no "
                f"lineage to recompute from")
        self._persist_into(handle, handle.lineage)
        self.stats["recomputed_handles"] = \
            self.stats.get("recomputed_handles", 0) + 1

    # -- durability ----------------------------------------------------------
    def _note_persisted(self, hids: Sequence[int]) -> None:
        """New handles entered the residency table: mark them dirty and
        snapshot once every ``checkpoint_every`` persists."""
        self._dirty.update(hids)
        self._persists_since_ckpt += 1
        if self._persists_since_ckpt >= self.checkpoint_every:
            self.checkpoint(wait=False)

    def checkpoint(self, wait: bool = True) -> None:
        """Snapshot the current residency table (asynchronously).

        Dirty or never-checkpointed handles are written fresh; clean
        handles carry over by reference.  A handle whose tiles cannot be
        read (its node died between the run and this call) is marked lost
        and skipped — durability degrades, the session keeps computing.

        ``wait=False`` (the steady-state path) never blocks on the
        writer: if the previous snapshot is still being written, this one
        is skipped and the dirty handles COALESCE into the next — the
        durability lag is bounded by one disk write, and a slow disk
        costs throughput of snapshots, not of compute."""
        if self._store is None or self._closed:
            return
        if not wait and self._store.busy():
            return                       # coalesce: dirty set stays dirty
        self._store.wait()                   # baseline = last real write
        if self._store.write_errors:
            errs = self.stats.setdefault("checkpoint_errors", [])
            errs.extend(self._store.write_errors)
            del self._store.write_errors[:]
        fresh: Dict[int, dict] = {}
        carry: List[int] = []
        for hid in sorted(self._handles):
            h = self._handles[hid]
            if h.lost:
                continue
            if hid not in self._dirty and self._store.has_entry(hid):
                carry.append(hid)
                continue
            try:
                tiles = {(i, j): self._read_tile(hid, i, j)
                         for (i, j) in h.tiles()}
            except Exception:
                h.lost = True                # next use re-derives it
                continue
            fresh[hid] = {"shape": h.shape, "dtype": h.dtype,
                          "tile": h.tile, "grid": h.grid, "name": h.name,
                          "lineage": self._pickle_lineage(h),
                          "tiles": tiles}
        if not fresh and set(carry) == self._store.baseline_hids():
            return                       # nothing changed since last snap
        self._ckpt_step += 1
        self._store.save_async(self._ckpt_step, fresh, carry)
        self._dirty.clear()
        self._persists_since_ckpt = 0

    def flush_checkpoints(self) -> None:
        """Force a snapshot of the current residency table and block until
        it is durably published; raises if the write failed."""
        if self._store is None:
            return
        self.checkpoint()
        self._store.wait()
        if self._store.write_errors:
            errs = list(self._store.write_errors)
            del self._store.write_errors[:]
            raise RuntimeError(f"checkpoint write failed:\n{errs[0]}")

    def _read_tile(self, hid: int, i: int, j: int) -> np.ndarray:
        """One resident tile as a master-side host array (checkpoint
        source).  In-process tiles are handed to the writer WITHOUT a
        copy: a registered handle's tiles are immutable for its lifetime
        (``_persist_into`` replaces the dict entries, executors allocate
        fresh outputs, ``to_numpy`` assembles into a new array) and the
        writer's reference keeps the array alive past ``free()``.
        Cluster tiles are assembled from arena segments — already fresh
        arrays."""
        key = (hid, i, j)
        if key in self._tiles:
            return self._tiles[key]
        return self._attach_tile(key)

    def _pickle_lineage(self, h: ResidentHandle) -> Optional[bytes]:
        """Session-free pickle of a handle's lineage (ResidentMatrix
        leaves carry the session — strip them down to their hid); None if
        the expression is unpicklable (the handle is then reload-only)."""
        if h.lineage is None:
            return None
        from ..runtime.durability import pickle_expr
        try:
            return pickle_expr(self._strip_lineage(h.lineage))
        except Exception:
            return None

    def _strip_lineage(self, expr: ClusteredMatrix) -> ClusteredMatrix:
        new: Dict[int, ClusteredMatrix] = {}
        for node in topo_order_many([expr]):
            if node.op is Op.RESIDENT:
                new[node.uid] = ClusteredMatrix(
                    Op.RESIDENT, node.shape, node.dtype,
                    payload=int(node.payload.hid), name=node.name)
                continue
            parents = tuple(new[p.uid] for p in node.parents)
            new[node.uid] = node if parents == node.parents else \
                ClusteredMatrix(node.op, node.shape, node.dtype,
                                parents=parents, payload=node.payload,
                                name=node.name)
        return new[expr.uid]

    def _rebuild_lineage(self, raw: bytes) -> Optional[ClusteredMatrix]:
        """Inverse of :meth:`_strip_lineage` against THIS session's
        restored handles.  Every node is rebuilt (fresh uids — unpickled
        uids could collide with this process's counter); None if a
        referenced handle did not survive the restore."""
        from ..runtime.durability import unpickle_expr
        expr = unpickle_expr(raw)
        new: Dict[int, ClusteredMatrix] = {}
        for node in topo_order_many([expr]):
            if node.op is Op.RESIDENT:
                h = self._handles.get(int(node.payload))
                if h is None or not h.alive:
                    return None
                new[node.uid] = ResidentMatrix(h, self, name=node.name)
                continue
            parents = tuple(new[p.uid] for p in node.parents)
            new[node.uid] = ClusteredMatrix(
                node.op, node.shape, node.dtype, parents=parents,
                payload=node.payload, name=node.name)
        return new[expr.uid]

    def _corrupt_shard(self, hid: int) -> str:
        """Fault-injection hook for ``ChaosEvent(corrupt_tile=hid)``:
        flips one byte in the newest on-disk shard of ``hid``."""
        if self._store is None:              # pragma: no cover — guarded
            raise RuntimeError("corrupt_tile chaos needs a durable "
                               "session (checkpoint_dir=...)")
        self._store.wait()
        return self._store.corrupt_shard(hid)

    # -- resume ---------------------------------------------------------------
    def resident(self, name: str) -> ResidentMatrix:
        """Look up a live handle by its persist-time name (how resumed
        sessions re-acquire their matrices); newest wins on duplicates."""
        matches = [h for h in self._handles.values()
                   if h.alive and h.name == name]
        if not matches:
            raise KeyError(f"no resident handle named {name!r}")
        return ResidentMatrix(max(matches, key=lambda h: h.hid), self)

    @classmethod
    def resume(cls, checkpoint_dir: str,
               engine: Optional[CMMEngine] = None,
               executor: str = "local", tile=None,
               policy: str = "price", **exec_kw) -> "CMMSession":
        """Rebuild a session from the newest intact snapshot under
        ``checkpoint_dir`` — after a crash (SIGKILL of the whole cluster
        included) or an orderly close.

        The new session may target a completely different cluster shape:
        tiles are re-homed into the fresh executor's arenas.  Per handle
        the restore chooses reload-from-disk vs recompute-from-lineage:

        * ``policy="price"`` (default) — cheaper leg per the TimeModel
          (``spill_read_bandwidth`` vs the lineage plan's simulated
          makespan);
        * ``policy="reload"`` / ``policy="recompute"`` — forced.

        A corrupt shard degrades to lineage recompute; corrupt shards of
        a lineage-less handle raise :class:`SessionUnrecoverable`.
        Restored bytes are bit-identical to what was persisted."""
        if policy not in ("price", "reload", "recompute"):
            raise ValueError(f"unknown resume policy {policy!r}")
        s = cls(engine, executor=executor, tile=tile,
                checkpoint_dir=checkpoint_dir, **exec_kw)
        try:
            s._resume_from(policy)
        except BaseException:
            try:
                s.close()
            except Exception:
                pass
            raise
        return s

    def _resume_from(self, policy: str) -> None:
        from ..runtime.durability import ShardCorrupt
        man = self._store.latest_intact()
        if man is None:
            raise RuntimeError(
                f"no intact checkpoint under {self._store.dir!r}")
        entries = {int(hid): e for hid, e in man["handles"].items()}
        # restored handles keep their checkpointed hids; hids are
        # monotonic, so lineage only references EARLIER hids — restoring
        # in sorted order makes every reference resolvable
        _ensure_hid_floor(max(entries, default=0) + 1)
        report: Dict[str, object] = {"step": int(man["step"]),
                                     "reloaded": [], "recomputed": [],
                                     "corrupt_shards": 0}
        for hid in sorted(entries):
            e = entries[hid]
            h = ResidentHandle(hid, tuple(e["shape"]),
                               np.dtype(e["dtype"]), tuple(e["tile"]),
                               tuple(e["grid"]), name=e.get("name", ""))
            self._handles[hid] = h
            lineage = self._load_lineage(man, hid)
            mode = policy
            if mode == "price" and lineage is not None:
                reload_s = predict_reload_seconds(
                    self._store.handle_bytes(man, hid),
                    self.engine.timemodel)
                recompute_s = self.engine.predict_recompute_seconds(
                    [lineage], tile=h.tile)
                mode = "reload" if reload_s <= recompute_s \
                    else "recompute"
            if lineage is None:
                mode = "reload"              # no recompute leg exists
            if mode == "reload":
                try:
                    arr = self._assemble_shards(man, hid, h)
                except ShardCorrupt as exc:
                    report["corrupt_shards"] += 1
                    if lineage is None:
                        raise SessionUnrecoverable(
                            (hid,),
                            f"checkpoint shard of handle #{hid} "
                            f"({h.name!r}) is corrupt and it has no "
                            f"lineage: {exc}") from exc
                    mode = "recompute"       # graceful degradation
                else:
                    h.lineage = lineage if lineage is not None else \
                        ClusteredMatrix.from_array(arr, name=h.name)
                    self._persist_into(
                        h, ClusteredMatrix.from_array(arr, name=h.name))
                    report["reloaded"].append(hid)
            if mode == "recompute":
                h.lineage = lineage
                self._persist_into(h, lineage)
                report["recomputed"].append(hid)
        # recomputed tiles are bit-identical to the checkpointed ones
        # (deterministic tasks), so the on-disk shards remain valid
        # carry-over references for this session's own snapshots
        self._store.adopt(man)
        self._ckpt_step = max(self._ckpt_step, int(man["step"]))
        self.stats["resume"] = report

    def _load_lineage(self, man: dict, hid: int
                      ) -> Optional[ClusteredMatrix]:
        from ..runtime.durability import ShardCorrupt
        try:
            raw = self._store.load_lineage(man, hid)
        except ShardCorrupt:
            return None                      # reload leg may still work
        if raw is None:
            return None
        try:
            return self._rebuild_lineage(raw)
        except Exception:
            return None

    def _assemble_shards(self, man: dict, hid: int,
                         h: ResidentHandle) -> np.ndarray:
        """The full checkpointed ndarray of one handle, every shard
        CRC-validated (ShardCorrupt on any mismatch)."""
        rows = tile_slices(h.shape[0], h.tile[0])
        cols = tile_slices(h.shape[1], h.tile[1])
        out = np.empty(h.shape, dtype=h.dtype)
        for (i, j) in h.tiles():
            t = self._store.load_tile(man, hid, i, j)
            (r0, r1), (c0, c1) = rows[i], cols[j]
            out[r0:r1, c0:c1] = t
        return out
