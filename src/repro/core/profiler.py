"""Offline profiling (CMM §3.4).

Runs each task family over a grid of operand sizes on the actual machine,
times it, and fits the Table-1 interpolation equations by OLS.  The fitted
``TimeModel`` is persisted to JSON and reused by the scheduler/simulator —
profiling is *offline*, scheduling uses only the model (as in the paper).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .timemodel import PolyModel, TimeModel


def _time_call(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def profile_matmul(sizes: Sequence[int], reps: int = 3,
                   rng: Optional[np.random.Generator] = None
                   ) -> Tuple[List[Tuple[int, int, int]], List[float]]:
    rng = rng or np.random.default_rng(0)
    dims_list, times = [], []
    for m in sizes:
        for k in sizes:
            a = rng.standard_normal((m, m))
            b = rng.standard_normal((m, k))
            c = np.zeros((m, k))

            def run(a=a, b=b, c=c):
                np.add(c, a @ b, out=c)  # addmul: C += A @ B

            times.append(_time_call(run, reps))
            dims_list.append((m, m, k))
    return dims_list, times


def profile_ewise(sizes: Sequence[int], reps: int = 3,
                  rng: Optional[np.random.Generator] = None
                  ) -> Tuple[List[Tuple[int, int]], List[float]]:
    rng = rng or np.random.default_rng(1)
    dims_list, times = [], []
    for m in sizes:
        for n in sizes:
            a = rng.standard_normal((m, n))
            b = rng.standard_normal((m, n))

            def run(a=a, b=b):
                np.add(a, b)

            times.append(_time_call(run, reps))
            dims_list.append((m, n))
    return dims_list, times


def profile_fill(sizes: Sequence[int], reps: int = 3
                 ) -> Tuple[List[Tuple[int, int]], List[float]]:
    """Data-generation (fill) cost: RNG-bound, much slower than memcpy.

    Times the executor's actual per-tile path (``lazy.random_slice``, the
    canonical block RNG) so the model prices what FILL tasks really do.
    """
    from .lazy import random_slice
    dims_list, times = [], []
    for m in sizes:
        for n in sizes:

            def run(m=m, n=n):
                random_slice(m * n, (m, n), np.float64, 0, m, 0, n)

            times.append(_time_call(run, reps))
            dims_list.append((m, n))
    return dims_list, times


def profile_machine(sizes: Sequence[int] = (64, 128, 256, 384, 512),
                    reps: int = 3) -> TimeModel:
    """Full offline profile -> fitted TimeModel (compute families)."""
    tm = TimeModel()
    dims, times = profile_matmul(sizes, reps)
    tm.models["matmul"] = PolyModel.fit("matmul", dims, times)
    dims_e, times_e = profile_ewise(sizes, reps)
    tm.models["ewise"] = PolyModel.fit("ewise", dims_e, times_e)
    dims_f, times_f = profile_fill(sizes, reps)
    tm.models["fill"] = PolyModel.fit("ewise", dims_f, times_f)
    calibrate_contention(tm)
    calibrate_dispatch(tm)
    calibrate_batch_dispatch(tm)
    calibrate_ipc(tm)
    calibrate_compression(tm)
    return tm


def calibrate_contention(tm: TimeModel, n: int = 768, tile: int = 384,
                         reps: int = 2) -> float:
    """Fit the concurrent-worker throughput scale (§3.4 observed-time fit).

    The family models are profiled one call at a time, but the executor runs
    ``worker_procs`` tasks concurrently, each inside multi-threaded BLAS —
    on an oversubscribed or shared host the effective per-task throughput is
    lower.  Run a GEMM-bound tiled program for real and scale the model by
    the observed wall / simulated makespan (clamped to [1, 8])."""
    import time as _time

    from .engine import CMMEngine
    from .lazy import ClusteredMatrix as CM
    from .machine import local_spec

    tm.contention = 1.0          # fit against the uncalibrated model
    eng = CMMEngine(local_spec(1), tm, tile=tile)
    P = CM.rand(n, n, seed=0)
    expr = P @ P
    plan = eng.plan(expr)
    best = float("inf")
    for _ in range(reps):
        t0 = _time.perf_counter()
        eng.run(expr, plan=plan, workers=eng.spec.worker_procs)
        best = min(best, _time.perf_counter() - t0)
    scale = best / max(plan.predicted_makespan, 1e-12)
    tm.contention = min(max(scale, 1.0), 8.0)
    return tm.contention


def calibrate_dispatch(tm: TimeModel, n: int = 256, tile: int = 64,
                       workers: int = 3) -> float:
    """Fit the per-task dispatch overhead (threadpool/GIL cost dominates
    sub-ms tiles): run a small tiled program for real and attribute the
    wall-time excess over the simulated makespan to per-task overhead."""
    import time as _time

    from .engine import CMMEngine
    from .lazy import ClusteredMatrix as CM
    from .machine import c5_9xlarge

    eng = CMMEngine(c5_9xlarge(1), tm, tile=tile)
    A = CM.rand(n, n, seed=0)
    expr = A @ A
    plan = eng.plan(expr)
    t0 = _time.perf_counter()
    eng.run(expr, plan=plan, workers=workers)
    wall = _time.perf_counter() - t0
    n_tasks = len(plan.program.graph)
    # overhead per task, serialised over `workers` lanes
    over = max(0.0, (wall - plan.predicted_makespan) * workers / n_tasks)
    tm.dispatch_overhead = min(over, 5e-3)
    return tm.dispatch_overhead


def calibrate_batch_dispatch(tm: TimeModel, tile: int = 64,
                             reps: int = 3) -> float:
    """Fit the per-*batched-launch* overhead (wave executor cost model).

    One stacked kernel call pays a fixed Python/NumPy entry cost that is
    independent of how many tiles are stacked.  Time stacked launches
    across group sizes and take the OLS intercept — that intercept is what
    a wave group costs on top of its arithmetic, and what the strategy
    selector weighs against ``dispatch_overhead`` x tasks."""
    xs, ys = [], []
    rng = np.random.default_rng(0)
    for g in (1, 2, 8, 32):
        a = rng.standard_normal((g, tile, tile))
        b = rng.standard_normal((g, tile, tile))

        def run(a=a, b=b):
            np.matmul(a, b)

        ys.append(_time_call(run, reps))
        xs.append([1.0, float(g)])
    coef, *_ = np.linalg.lstsq(np.asarray(xs), np.asarray(ys), rcond=None)
    tm.batch_dispatch_overhead = float(min(max(coef[0], 1e-6), 5e-3))
    return tm.batch_dispatch_overhead


def _ipc_echo(inq, outq):                      # pragma: no cover - subprocess
    while True:
        msg = inq.get()
        if msg is None:
            break
        outq.put(msg)


def calibrate_ipc(tm: TimeModel, nbytes: int = 1 << 22,
                  reps: int = 5) -> Tuple[float, float]:
    """Fit the cluster executor's cost terms (§3.4 applied to processes):

    * ``process_dispatch_overhead`` / ``ipc_latency`` — one dispatch-queue
      round trip to a worker process (pickle + pipe + wakeup + ack), which
      the multi-process executor pays per task (and per XFER message);
    * ``ipc_bandwidth`` — throughput of a tile copy between two
      ``SharedMemory`` arenas, the executor's actual XFER data path.
    """
    import multiprocessing as mp
    from multiprocessing import shared_memory

    ctx = mp.get_context()
    inq, outq = ctx.Queue(), ctx.Queue()
    p = ctx.Process(target=_ipc_echo, args=(inq, outq), daemon=True)
    p.start()
    try:
        inq.put(0)                    # warm the queues / process
        outq.get(timeout=30)
        best = float("inf")
        for i in range(reps):
            t0 = time.perf_counter()
            inq.put(i)
            outq.get(timeout=30)
            best = min(best, time.perf_counter() - t0)
    finally:
        inq.put(None)
        p.join(timeout=10)
        if p.is_alive():              # pragma: no cover
            p.terminate()
    tm.process_dispatch_overhead = min(max(best, 1e-6), 5e-2)
    tm.ipc_latency = tm.process_dispatch_overhead

    src = shared_memory.SharedMemory(create=True, size=nbytes)
    dst = shared_memory.SharedMemory(create=True, size=nbytes)
    try:
        a = np.ndarray((nbytes // 8,), dtype=np.float64, buffer=src.buf)
        b = np.ndarray((nbytes // 8,), dtype=np.float64, buffer=dst.buf)
        a[:] = 1.0
        copy = _time_call(lambda: np.copyto(b, a), reps)
        tm.ipc_bandwidth = float(min(max(nbytes / max(copy, 1e-9), 1e8),
                                     1e12))
    finally:
        for s in (src, dst):
            s.close()
            s.unlink()
    return tm.process_dispatch_overhead, tm.ipc_bandwidth


def calibrate_compression(tm: TimeModel, nbytes: int = 1 << 22,
                          reps: int = 3) -> Tuple[float, float]:
    """Fit the wire-codec terms the per-edge XFER pricing runs on:

    * ``compress_bandwidth`` — raw bytes/s the codec encodes at on this
      host (the ``compress_cpu`` term of the pricing inequality);
    * ``compression_ratio_prior`` — expected raw/compressed ratio.

    The probe tile is *structured* (a low-rank f64 outer product — the
    shape of persisted intermediates and generated operands), not pure
    noise: the prior should reflect payloads where the codec can win at
    all.  On incompressible data the per-edge rule still falls back to
    ``"raw"`` because the measured wire bytes, not the prior, are what
    the executors count.
    """
    from ..runtime.wire import encode_tile

    side = max(int(np.sqrt(nbytes / 8)), 16)
    col = np.linspace(0.0, 1.0, side)
    probe = np.outer(col, np.ones(side))          # rank-1: compressible
    raw = probe.nbytes
    enc = _time_call(lambda: encode_tile(probe, "zlib"), reps)
    payload = encode_tile(probe, "zlib")
    tm.compress_bandwidth = float(min(max(raw / max(enc, 1e-9), 1e6), 1e11))
    tm.compression_ratio_prior = float(
        min(max(raw / max(len(payload), 1), 1.0), 64.0))
    return tm.compress_bandwidth, tm.compression_ratio_prior


def profile_comm_synthetic(spec, sizes_bytes: Sequence[int] = None,
                           noise: float = 0.03, seed: int = 0):
    """Synthesise comm-profile observations from the machine model.

    On the real cluster this function would round-trip buffers between node
    pairs; offline here, we sample the parametric link model with noise and
    refit — exercising the same per-pair regression path the paper describes
    (§3.4: "additionally taking the connection speeds between two nodes into
    account").  Returns {(a, b): (latency, bandwidth)} fitted per pair.
    """
    rng = np.random.default_rng(seed)
    sizes_bytes = sizes_bytes or [2 ** p for p in range(12, 27, 2)]
    fitted = {}
    for a in range(spec.n_nodes):
        for b in range(spec.n_nodes):
            if a == b:
                continue
            xs, ys = [], []
            for s in sizes_bytes:
                true = spec.comm_time(s, a, b)
                obs = true * (1.0 + noise * rng.standard_normal())
                xs.append([1.0, float(s)])
                ys.append(max(obs, 0.0))
            coef, *_ = np.linalg.lstsq(np.asarray(xs), np.asarray(ys),
                                       rcond=None)
            lat = max(float(coef[0]), 0.0)
            bw = 1.0 / max(float(coef[1]), 1e-30)
            fitted[(a, b)] = (lat, bw)
    return fitted
