"""Gradient compression for the data-parallel all-reduce.

int8 quantisation with error feedback: each replica quantises its local
gradient to int8 (per-tensor scale), all-reduces the int8 payload (4x fewer
bytes on the wire), dequantises, and carries the quantisation residual into
the next step (error feedback keeps the method unbiased over time).

On an SPMD mesh this is expressed as quantise -> psum -> dequantise inside
the step function; XLA all-reduces the int32-accumulated payloads.  Enabled
per-plan (`compress_grads=True`) — a beyond-paper distributed-optimisation
trick recorded in §Perf.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x fp -> (int8 values, fp32 scale).  Symmetric per-tensor."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _error_dtype(dtype) -> "jnp.dtype":
    """Storage dtype for a parameter's error-feedback buffer: half-width
    params carry their residual at their own width (an f32 buffer would
    double the optimiser's memory for bf16/f16 trees for no benefit —
    the residual is bounded by half a quantisation step, well inside
    half-precision range); everything else accumulates in f32."""
    dt = jnp.dtype(dtype)
    if dt in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)):
        return dt
    return jnp.dtype(jnp.float32)


def compress_tree(grads: Dict[str, jax.Array],
                  errors: Optional[Dict[str, jax.Array]] = None):
    """Quantise a gradient tree with error feedback.

    The feedback accumulates in f32 regardless of storage width (adding
    a half-precision residual at half precision would lose the low bits
    the feedback exists to preserve); the residual is stored back at the
    parameter's error width (``_error_dtype``).

    Returns (quantised {name: (int8, scale)}, new_errors).
    """
    qs, new_err = {}, {}
    for k, g in grads.items():
        g32 = g.astype(jnp.float32)
        if errors is not None:
            g32 = g32 + errors[k].astype(jnp.float32)
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        new_err[k] = (g32 - deq).astype(_error_dtype(g.dtype))
        qs[k] = (q, s)
    return qs, new_err


def decompress_tree(qs) -> Dict[str, jax.Array]:
    return {k: dequantize_int8(q, s) for k, (q, s) in qs.items()}


def init_errors(grads_like: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Zero error-feedback buffers, one per parameter, allocated at each
    parameter's error width — NOT unconditionally f32 (the old behaviour
    silently doubled optimiser memory for bf16/f16 trees)."""
    return {k: jnp.zeros(v.shape, _error_dtype(v.dtype))
            for k, v in grads_like.items()}
