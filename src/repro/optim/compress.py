"""Gradient compression for the data-parallel all-reduce.

int8 quantisation with error feedback: each replica quantises its local
gradient to int8 (per-tensor scale), all-reduces the int8 payload (4x fewer
bytes on the wire), dequantises, and carries the quantisation residual into
the next step (error feedback keeps the method unbiased over time).

On an SPMD mesh this is expressed as quantise -> psum -> dequantise inside
the step function; XLA all-reduces the int32-accumulated payloads.  Enabled
per-plan (`compress_grads=True`) — a beyond-paper distributed-optimisation
trick recorded in §Perf.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x fp -> (int8 values, fp32 scale).  Symmetric per-tensor."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Dict[str, jax.Array],
                  errors: Optional[Dict[str, jax.Array]] = None):
    """Quantise a gradient tree with error feedback.

    Returns (quantised {name: (int8, scale)}, new_errors).
    """
    qs, new_err = {}, {}
    for k, g in grads.items():
        g32 = g.astype(jnp.float32)
        if errors is not None:
            g32 = g32 + errors[k]
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        new_err[k] = g32 - deq
        qs[k] = (q, s)
    return qs, new_err


def decompress_tree(qs) -> Dict[str, jax.Array]:
    return {k: dequantize_int8(q, s) for k, (q, s) in qs.items()}


def init_errors(grads_like: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    return {k: jnp.zeros(v.shape, jnp.float32)
            for k, v in grads_like.items()}
