"""Optimizers: AdamW and AdaFactor (factored second moment), hand-rolled.

AdamW keeps fp32 moments (sharded like the params).  AdaFactor stores row/
column second-moment factors — ~1 extra byte/param instead of 8 — which is
what lets nemotron-4-340b train on a single v5e pod (see EXPERIMENTS.md
§Dry-run memory notes).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) /
                    jnp.maximum(cfg.decay_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), tree), gn


# -- AdamW -----------------------------------------------------------------


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def adamw_update(cfg: OptConfig, params, grads, state):
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard LM practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    new_params, new_m, new_v = {}, {}, {}
    for k in params:
        new_params[k], new_m[k], new_v[k] = upd(
            params[k], grads[k], state["m"][k], state["v"][k])
    return new_params, {"step": step, "m": new_m, "v": new_v}, \
        {"lr": lr, "grad_norm": gn}


# -- AdaFactor --------------------------------------------------------------


def adafactor_init(params) -> Dict[str, Any]:
    def factors(p):
        if p.ndim >= 2:
            return (jnp.zeros(p.shape[:-1], jnp.float32),          # row
                    jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
        return (jnp.zeros(p.shape, jnp.float32), jnp.zeros((), jnp.float32))

    return {
        "step": jnp.zeros((), jnp.int32),
        "f": jax.tree.map(factors, params),
    }


def adafactor_update(cfg: OptConfig, params, grads, state):
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** -0.8          # Shazeer & Stern schedule

    def upd(p, g, f):
        r, c = f
        g2 = jnp.square(g) + 1e-30
        if p.ndim >= 2:
            r = beta2 * r + (1 - beta2) * g2.mean(-1)
            c = beta2 * c + (1 - beta2) * g2.mean(-2)
            rc = r / jnp.maximum(r.mean(-1, keepdims=True), 1e-30)
            v = rc[..., None] * c[..., None, :]
        else:
            r = beta2 * r + (1 - beta2) * g2
            v = r
            c = jnp.zeros((), jnp.float32)
        delta = g / jnp.sqrt(v + cfg.eps)
        # update clipping (RMS_delta <= 1), per the paper
        rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + 1e-30)
        delta = delta / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), (r, c)

    new_params, new_f = {}, {}
    for k in params:
        new_params[k], new_f[k] = upd(params[k], grads[k], state["f"][k])
    return new_params, {"step": step, "f": new_f}, \
        {"lr": lr, "grad_norm": gn}


def make_optimizer(cfg: OptConfig):
    if cfg.kind == "adamw":
        return adamw_init, functools.partial(adamw_update, cfg)
    if cfg.kind == "adafactor":
        return adafactor_init, functools.partial(adafactor_update, cfg)
    raise ValueError(cfg.kind)
