"""Flight recorder: spans, clock alignment, and a unified metrics registry.

The paper's thesis is that a fitted time model predicts cluster capacity
well enough to drive tile-size and schedule simulation — but the repo
had no way to *see* whether a real run matched its predicted timeline.
This module is the observability substrate every executor shares:

* :class:`Tracer` — per-process span recorder over the monotonic clock.
  A span is one timed region (task EXEC, wire XFER, arena SPILL /
  FAULTIN, checkpoint write, frontier REPLAN, result GATHER) tagged
  with its node and a per-thread lane.  Worker processes buffer spans
  locally and piggyback them on the done/heartbeat/stats messages they
  already send — tracing adds **no new queues and no extra wakeups**,
  which is what keeps it cheap enough to stay on by default (the
  ``obs_bench`` gate holds the paired overhead under 5%).

* **clock-offset calibration** — master and worker timestamps come from
  each process's ``time.perf_counter``.  At worker handshake the master
  sends a ``("cal", t_send)`` op and the worker echoes its own clock;
  :func:`estimate_clock_offset` is the NTP-style midpoint estimate
  ``offset = t_worker - (t_send + t_recv) / 2`` under which
  ``t_master = t_worker - offset``.  (On Linux ``perf_counter`` is the
  system-wide CLOCK_MONOTONIC, so measured offsets are ~0 — the
  machinery matters on platforms with per-process clocks, and it makes
  the alignment unit-testable with fake clocks.)

* :class:`MetricsRegistry` — counters, gauges, and bounded log-bucket
  histograms behind one lock, replacing the executors' ad-hoc ``stats``
  dicts.  ``inc`` is the *atomic* increment path every non-master-thread
  stat update must take (bare ``dict[k] += 1`` is a lost-update bug the
  moment two threads race it); ``frozen_view`` hands tests/benchmarks
  the read-only dict they always consumed.

* :func:`chrome_trace` / :func:`export_chrome_trace` — Chrome
  trace-event JSON (loads in ``chrome://tracing`` and Perfetto) with
  one process lane per node and one thread lane per worker slot, so
  compute/XFER overlap is visible exactly as numpywren's profile
  timelines render serverless runs.

The drift consumer (``core/drift.py``) joins these spans against the
HEFT/simulator predicted timeline.
"""
from __future__ import annotations

import json
import math
import threading
import time
from types import MappingProxyType
from typing import Dict, Iterable, List, Mapping, Optional

__all__ = [
    "Span", "Tracer", "NULL_TRACER", "MetricsRegistry",
    "estimate_clock_offset", "chrome_trace", "export_chrome_trace",
]


# -- spans --------------------------------------------------------------------
class Span:
    """One timed region: ``[t0, t0 + dur)`` on ``node``/``lane``.

    ``cat`` is the span's category (EXEC/XFER/SPILL/...), the join key
    for every consumer; ``name`` is the display label; ``args`` carries
    the category-specific payload (task id, bytes, codec, ...).
    Timestamps are seconds on the recording process's monotonic clock
    until the master ingests them through :meth:`Tracer.ingest`, which
    shifts them onto the master timeline.
    """

    __slots__ = ("name", "cat", "node", "lane", "t0", "dur", "args")

    def __init__(self, name: str, cat: str, node: int, lane: int,
                 t0: float, dur: float, args: Optional[dict] = None):
        self.name = name
        self.cat = cat
        self.node = node
        self.lane = lane
        self.t0 = t0
        self.dur = dur
        self.args = args or {}

    def __reduce__(self):  # __slots__ classes need explicit pickling
        return (Span, (self.name, self.cat, self.node, self.lane,
                       self.t0, self.dur, self.args))

    def __repr__(self):  # pragma: no cover — debugging aid
        return (f"Span({self.cat} {self.name!r} node={self.node} "
                f"lane={self.lane} t0={self.t0:.6f} dur={self.dur:.6f})")


class _SpanCtx:
    """Context manager recording one span on ``__exit__`` (kept as a
    tiny slotted class instead of ``contextlib`` to stay off the hot
    path's allocation budget)."""

    __slots__ = ("tr", "name", "cat", "lane", "args", "t0")

    def __init__(self, tr: "Tracer", name: str, cat: str,
                 lane: Optional[int], args: dict):
        self.tr = tr
        self.name = name
        self.cat = cat
        self.lane = lane
        self.args = args

    def __enter__(self):
        self.t0 = self.tr.clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        tr = self.tr
        t1 = tr.clock()
        lane = self.lane if self.lane is not None else tr.lane()
        sp = Span(self.name, self.cat, tr.node, lane,
                  self.t0, t1 - self.t0, self.args)
        with tr._lock:
            tr._spans.append(sp)
        return False


class _NullSpanCtx:
    """Shared no-op context for a disabled tracer (zero allocation)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_CTX = _NullSpanCtx()


class Tracer:
    """Per-process span buffer over a monotonic clock.

    Thread-safe: worker pool threads record concurrently; ``drain``
    hands the buffered spans to whoever serializes them over the
    message path.  ``enabled=False`` turns every ``span()`` into a
    shared no-op context (the tracing-off leg of the overhead gate).
    """

    def __init__(self, node: int = 0, enabled: bool = True,
                 clock=time.perf_counter):
        self.node = node
        self.enabled = enabled
        self.clock = clock
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._lanes: Dict[int, int] = {}

    # -- recording ----------------------------------------------------------
    def lane(self) -> int:
        """Small stable lane id for the calling thread (worker slot)."""
        ident = threading.get_ident()
        lane = self._lanes.get(ident)
        if lane is None:
            with self._lock:
                lane = self._lanes.setdefault(ident, len(self._lanes))
        return lane

    def span(self, name: str, cat: Optional[str] = None,
             lane: Optional[int] = None, **args):
        """``with tracer.span("EXEC", tid=7): ...`` — records on exit."""
        if not self.enabled:
            return _NULL_CTX
        return _SpanCtx(self, name, cat or name, lane, args)

    def add(self, span: Span) -> None:
        if self.enabled:
            with self._lock:
                self._spans.append(span)

    # -- transport ----------------------------------------------------------
    def drain(self) -> List[Span]:
        """Take and clear the buffered spans (piggybacked on the next
        outgoing done/heartbeat/stats message)."""
        if not self._spans:
            return []
        with self._lock:
            out, self._spans = self._spans, []
        return out

    def ingest(self, spans: Optional[Iterable[Span]],
               offset: float = 0.0) -> None:
        """Master side: adopt worker spans, shifting their timestamps
        onto this process's clock (``t_master = t_worker - offset``
        with ``offset`` from :func:`estimate_clock_offset`)."""
        if not spans:
            return
        if offset:
            for sp in spans:
                sp.t0 -= offset
        with self._lock:
            self._spans.extend(spans)

    def snapshot(self) -> List[Span]:
        with self._lock:
            return list(self._spans)


#: module-level disabled tracer for call sites without a wired recorder
NULL_TRACER = Tracer(enabled=False)


def estimate_clock_offset(t_send: float, t_worker: float,
                          t_recv: float) -> float:
    """NTP-style midpoint offset of a worker clock from the master's.

    The master stamps ``t_send``, the worker echoes its clock
    ``t_worker``, the master receives at ``t_recv``; assuming the
    one-way delays are symmetric, the worker read its clock at master
    time ``(t_send + t_recv) / 2``, so

        ``offset = t_worker - (t_send + t_recv) / 2``

    and a worker timestamp maps to the master timeline as
    ``t_master = t_worker - offset``.
    """
    return t_worker - 0.5 * (t_send + t_recv)


# -- metrics ------------------------------------------------------------------
class _Histogram:
    """Bounded log2-bucket histogram (64 buckets from 0.1µs up).

    Constant memory regardless of sample count, mergeable across
    processes, and quantile-queryable to within a 2x bucket width —
    all a drift/latency summary needs.
    """

    __slots__ = ("count", "total", "vmin", "vmax", "buckets")

    NBUCKETS = 64
    FLOOR = 1e-7

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.buckets = [0] * self.NBUCKETS

    def _index(self, value: float) -> int:
        if value <= self.FLOOR:
            return 0
        return min(self.NBUCKETS - 1,
                   1 + int(math.log2(value / self.FLOOR)))

    def observe(self, value: float) -> None:
        self.count += 1
        self.total += value
        if value < self.vmin:
            self.vmin = value
        if value > self.vmax:
            self.vmax = value
        self.buckets[self._index(value)] += 1

    def merge(self, other: "_Histogram") -> None:
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        for i, c in enumerate(other.buckets):
            self.buckets[i] += c

    def quantile(self, q: float) -> float:
        """Upper edge of the bucket holding the q-quantile sample."""
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        seen = 0
        for i, c in enumerate(self.buckets):
            seen += c
            if seen >= rank:
                return self.FLOOR * (2.0 ** i)
        return self.vmax          # pragma: no cover — rank <= count

    def summary(self) -> dict:
        return {
            "count": self.count,
            "total": self.total,
            "mean": (self.total / self.count) if self.count else 0.0,
            "min": self.vmin if self.count else 0.0,
            "max": self.vmax if self.count else 0.0,
            "p50": self.quantile(0.5),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Counters + gauges + bounded histograms behind one lock.

    ``inc`` is the atomic increment path: unlike ``d[k] += 1`` on a
    shared dict (a read-modify-write that loses updates under thread
    interleaving), every mutation here holds the registry lock.
    ``frozen_view`` materializes the read-only dict the executors'
    ``.stats`` consumers have always read.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, object] = {}
        self._hists: Dict[str, _Histogram] = {}

    # -- counters -----------------------------------------------------------
    def inc(self, key: str, n=1) -> None:
        with self._lock:
            self._counters[key] = self._counters.get(key, 0) + n

    def get(self, key: str, default=0):
        with self._lock:
            if key in self._counters:
                return self._counters[key]
            return self._gauges.get(key, default)

    # -- gauges -------------------------------------------------------------
    def gauge(self, key: str, value) -> None:
        with self._lock:
            self._gauges[key] = value

    # -- histograms ---------------------------------------------------------
    def observe(self, key: str, value: float) -> None:
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = _Histogram()
            h.observe(value)

    def histogram(self, key: str) -> Optional[dict]:
        with self._lock:
            h = self._hists.get(key)
            return None if h is None else h.summary()

    # -- aggregation --------------------------------------------------------
    def merge(self, other: "MetricsRegistry") -> None:
        with other._lock:
            counters = dict(other._counters)
            gauges = dict(other._gauges)
            hists = dict(other._hists)
        with self._lock:
            for k, v in counters.items():
                self._counters[k] = self._counters.get(k, 0) + v
            self._gauges.update(gauges)
            for k, h in hists.items():
                mine = self._hists.get(k)
                if mine is None:
                    mine = self._hists[k] = _Histogram()
                mine.merge(h)

    def snapshot(self) -> Dict[str, object]:
        """Plain-dict copy of counters + gauges (histograms summarized
        under ``hist:<key>``)."""
        with self._lock:
            out: Dict[str, object] = dict(self._counters)
            out.update(self._gauges)
            for k, h in self._hists.items():
                out[f"hist:{k}"] = h.summary()
        return out

    def frozen_view(self, extra: Optional[Mapping] = None) -> Mapping:
        """Read-only dict view (supports ``[]``, ``.get``, ``dict()``,
        iteration) of the current snapshot plus ``extra`` overrides —
        what an executor publishes as ``.stats`` so existing tests and
        benchmarks keep working unchanged while writes are rejected."""
        snap = self.snapshot()
        if extra:
            snap.update(extra)
        return MappingProxyType(snap)


# -- Chrome trace export ------------------------------------------------------
def chrome_trace(spans: Iterable[Span], normalize: bool = True) -> dict:
    """Chrome trace-event JSON object (``chrome://tracing`` / Perfetto).

    One *process* lane per node (pid), one *thread* lane per worker
    slot (tid); "X" complete events carry microsecond ts/dur.  With
    ``normalize`` the earliest span starts at ts=0 so the viewer opens
    at the run rather than at hours of monotonic-clock uptime.
    """
    spans = list(spans)
    base = min((sp.t0 for sp in spans), default=0.0) if normalize else 0.0
    events: List[dict] = []
    lanes = set()
    for sp in spans:
        lanes.add((sp.node, sp.lane))
        events.append({
            "name": sp.name,
            "cat": sp.cat,
            "ph": "X",
            "pid": sp.node,
            "tid": sp.lane,
            "ts": (sp.t0 - base) * 1e6,
            "dur": max(sp.dur, 0.0) * 1e6,
            "args": sp.args,
        })
    for node in sorted({n for n, _ in lanes}):
        events.append({
            "name": "process_name", "ph": "M", "pid": node, "tid": 0,
            "args": {"name": ("master" if node < 0 else f"node {node}")},
        })
    for node, lane in sorted(lanes):
        events.append({
            "name": "thread_name", "ph": "M", "pid": node, "tid": lane,
            "args": {"name": f"worker {lane}"},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_chrome_trace(spans: Iterable[Span], path: str,
                        normalize: bool = True) -> dict:
    """Write :func:`chrome_trace` JSON to ``path``; returns the object."""
    doc = chrome_trace(spans, normalize=normalize)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc
