"""Wire codecs + per-edge transfer pricing for the XFER path.

Every byte that crosses a node boundary flows through one priced,
instrumented path (ROADMAP item 5).  Three pieces live here:

* **Lossless wire codecs** — byte-level compression applied per-XFER.
  The repo's bitwise-identity policy is absolute on the tile path, so
  only *lossless* codecs are admissible here (``zlib`` from the stdlib;
  the lossy int8 quantizer in ``optim/compress.py`` stays
  optimizer-only and never touches tile bytes).  ``decode_tile(
  encode_tile(a)) == a`` bit-for-bit, always.

* **Per-edge pricing** — a codec is worth using on edge ``(src, dst)``
  exactly when the TimeModel predicts

      compress_cpu + compressed_bytes/bw  <  raw_bytes/bw

  with ``compress_cpu = nbytes / tm.compress_bandwidth`` and
  ``compressed_bytes = nbytes / tm.compression_ratio_prior``.  Both
  terms are fitted by the profiler (``calibrate_compression``) and
  serialized in ``TimeModel.to_json()`` so plan caches invalidate on
  recalibration.  With the default priors (``compress_bandwidth == 0``)
  the codec is disabled and every decision degrades to ``"raw"``.

* **Broadcast relay trees** — one-producer-many-consumer edges (common
  after ``persist()``) are served by a deterministic binary relay tree
  over ``[src] + sorted(dsts)`` instead of N unicasts, halving the
  source's serialized send time per doubling of fan-out.  The same
  ``broadcast_tree`` shape is used by the executors *and* the
  simulator so ``engine`` auto-selection prices what actually runs.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = [
    "WireCodec", "RawCodec", "ZlibCodec", "CODECS", "get_codec",
    "encode_tile", "decode_tile", "choose_wire_codec", "wire_seconds",
    "predicted_xfer_seconds", "broadcast_tree", "BCAST_MIN_FANOUT",
]

#: minimum cross-node destination count before a relay tree beats
#: N unicasts (at 2 destinations the tree *is* two unicasts).
BCAST_MIN_FANOUT = 3


class WireCodec:
    """Lossless byte codec interface for the tile wire path.

    ``decode(encode(b)) == b`` must hold bit-for-bit for arbitrary
    ``bytes`` — codecs that cannot guarantee that (lossy quantizers,
    float truncation) are not admissible here.
    """

    name: str = "?"

    def encode(self, raw: bytes) -> bytes:
        raise NotImplementedError

    def decode(self, payload: bytes) -> bytes:
        raise NotImplementedError


class RawCodec(WireCodec):
    """Identity codec: the uncompressed point-to-point path."""

    name = "raw"

    def encode(self, raw: bytes) -> bytes:
        return bytes(raw)

    def decode(self, payload: bytes) -> bytes:
        return bytes(payload)


class ZlibCodec(WireCodec):
    """stdlib zlib at level 1 — the speed-over-ratio end of DEFLATE,
    the right trade for a 10 Gbps-class link (lz4 is not vendored; the
    interface is the point, the codec is a plug)."""

    name = "zlib"
    level = 1

    def encode(self, raw: bytes) -> bytes:
        return zlib.compress(raw, self.level)

    def decode(self, payload: bytes) -> bytes:
        return zlib.decompress(payload)


#: codec registry — one place a wire codec is named; executors, the
#: profiler and the benchmarks resolve codec strings through here.
CODECS: Dict[str, WireCodec] = {
    RawCodec.name: RawCodec(),
    ZlibCodec.name: ZlibCodec(),
}


def get_codec(name: str) -> WireCodec:
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown wire codec {name!r}; known: {sorted(CODECS)}"
        ) from None


def encode_tile(arr: np.ndarray, codec: str) -> bytes:
    """Encode a tile's raw bytes for the wire.  Lossless by contract."""
    a = np.ascontiguousarray(arr)
    return get_codec(codec).encode(a.tobytes())


def decode_tile(payload: bytes, shape: Tuple[int, int], dtype,
                codec: str) -> np.ndarray:
    """Decode a wire payload back to the exact tile that was encoded."""
    raw = get_codec(codec).decode(payload)
    return np.frombuffer(raw, dtype=np.dtype(dtype)).reshape(shape)


def choose_wire_codec(nbytes: int, bw: float, tm) -> str:
    """Pick the codec for one edge by the TimeModel's pricing rule.

    Returns ``"zlib"`` when ``compress_cpu + compressed_bytes/bw <
    raw_bytes/bw`` under the fitted priors, else ``"raw"``.  With
    unfitted priors (``compress_bandwidth <= 0`` or ratio <= 1) the
    codec can never win and the choice is always ``"raw"`` — existing
    plans and transfers are byte-for-byte unchanged by default.
    """
    if nbytes <= 0 or bw <= 0:
        return "raw"
    cbw = getattr(tm, "compress_bandwidth", 0.0)
    ratio = getattr(tm, "compression_ratio_prior", 1.0)
    if cbw <= 0.0 or ratio <= 1.0:
        return "raw"
    raw_s = nbytes / bw
    comp_s = nbytes / cbw + (nbytes / ratio) / bw
    return "zlib" if comp_s < raw_s else "raw"


def wire_seconds(nbytes: int, src: int, dst: int, spec, tm) -> float:
    """Codec-aware seconds for ``nbytes`` over edge ``(src, dst)``.

    The single pricing helper shared by HEFT (``heft_schedule`` *and*
    ``replan_frontier`` — the two EFT policies must stay mirrored), the
    discrete-event simulator and ``predict_cluster_makespan``, so
    ``auto`` executor selection prices exactly the transfer path the
    executors run.  Identical to ``spec.comm_time`` when the codec
    priors are unfitted.
    """
    base = spec.comm_time(nbytes, src, dst)
    if src == dst or nbytes <= 0 or tm is None:
        return base
    cbw = getattr(tm, "compress_bandwidth", 0.0)
    ratio = getattr(tm, "compression_ratio_prior", 1.0)
    if cbw <= 0.0 or ratio <= 1.0:
        return base
    comp = nbytes / cbw + spec.comm_time(int(nbytes / ratio), src, dst)
    return min(base, comp)


def predicted_xfer_seconds(nbytes: int, tm, codec: str = "raw",
                           comp_nbytes: int = 0) -> float:
    """Model-predicted wall seconds for one *materialized* XFER leg.

    Unlike :func:`wire_seconds` — which prices the codec *choice*
    against the planning-level link model — this prices what the
    destination worker actually does: a shared-memory attach + copy
    (``ipc_latency + bytes / ipc_bandwidth``, the terms
    ``profiler.calibrate_ipc`` fits), plus a decode pass priced at the
    codec throughput prior when the payload came compressed.  The
    drift report compares measured XFER spans against this, so a raw
    leg evidences ``ipc_bandwidth`` and a compressed one
    ``compress_bandwidth``.
    """
    if nbytes <= 0 or tm is None:
        return 0.0
    lat = getattr(tm, "ipc_latency", 0.0)
    bw = getattr(tm, "ipc_bandwidth", 0.0)
    if codec == "raw":
        return lat + (nbytes / bw if bw > 0 else 0.0)
    cbw = getattr(tm, "compress_bandwidth", 0.0)
    payload = comp_nbytes or nbytes
    t = lat + (payload / bw if bw > 0 else 0.0)
    if cbw > 0:
        t += nbytes / cbw
    return t


def broadcast_tree(src: int, dsts: Sequence[int],
                   min_fanout: int = BCAST_MIN_FANOUT,
                   ) -> Dict[int, List[int]]:
    """Deterministic binary relay tree for one fan-out edge.

    Maps each relay node to its children over ``[src] + sorted(dsts)``
    (node at position ``i`` feeds positions ``2i+1`` and ``2i+2``).
    Below ``min_fanout`` destinations the "tree" is the flat N-unicast
    star rooted at ``src`` — a tree of depth one.  The executors follow
    this shape when routing XFERs and the simulator follows it when
    pricing them, so the model and the machine agree on every hop.
    """
    order = [src] + sorted(set(int(d) for d in dsts) - {src})
    tree: Dict[int, List[int]] = {}
    if len(order) - 1 < min_fanout:
        if len(order) > 1:
            tree[src] = order[1:]
        return tree
    for i, parent in enumerate(order):
        kids = order[2 * i + 1: 2 * i + 3]
        if kids:
            tree[parent] = kids
    return tree
