"""Tile-granular durability for CMM sessions: checkpointed resident tiles.

The elastic runtime (exec/elastic.py) survives *node* churn by lineage
recompute, but a master crash or a whole-cluster restart loses every
persisted tile — the failure mode numpywren sidesteps by keeping tile
state in a disaggregated store so workers are stateless.  This module is
that store for :class:`repro.core.session.CMMSession`: each persisted
handle's tiles are snapshotted to disk (asynchronously — the write
overlaps the next compute), and ``CMMSession.resume()`` rebuilds the
residency table from the newest intact snapshot after any crash,
including SIGKILL of the master and every worker mid-``compute()``.

It reuses ``checkpoint/store.py``'s publication idioms (stage into a
``.tmp`` dir, fsync the manifest, atomic rename) at tile granularity:

    <dir>/snap_<N>/
        manifest.json           — step + per-handle metadata and shard refs
        h<hid>_<i>_<j>.npy      — one shard per (re)written tile
        h<hid>.lineage.pkl      — pickled session-free lineage expression

Snapshots are **incremental per handle**: a handle whose tiles did not
change since the previous snapshot is carried over by reference — its
manifest entry points into the older ``snap_`` directory, nothing is
rewritten.  ``rotate()`` therefore keeps every directory still referenced
by a kept manifest.

Every shard and lineage blob carries a CRC32 (same integrity check the
hardened XFER path applies to cross-node payloads); ``load_tile`` raises
:class:`ShardCorrupt` on mismatch so the restore path can degrade to
lineage recompute instead of resurrecting wrong bytes.  A manifest is
*intact* only if it parses and every file it references exists — a crash
mid-save leaves a ``.tmp`` directory that readers never look at, so
``latest_intact()`` always falls back to the previous good snapshot.
"""
from __future__ import annotations

import os
import pickle
import shutil
import threading
import traceback
import zlib
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from ..checkpoint.store import atomic_publish, fsync_json


class ShardCorrupt(RuntimeError):
    """A checkpoint shard failed its CRC32 / load — the restore path must
    fall back to lineage recompute (or declare the handle unrecoverable)."""


def _crc(buf) -> int:
    return zlib.crc32(buf) & 0xFFFFFFFF


class TileCheckpointStore:
    """Atomic, incremental, CRC-validated snapshots of resident tiles.

    ``save()`` takes *fresh* handles (metadata + tile ndarrays, already
    master-side host copies) and *carry* handle ids whose entries are
    inherited unchanged from the last published manifest.  ``save_async``
    runs the disk write on a background thread; a failed write never
    raises into the compute path — it is recorded in ``write_errors`` and
    the previous snapshot stays the newest intact one (the same contract
    a crash mid-save has).
    """

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None
        self._last_man: Optional[dict] = None
        #: tracebacks of failed async writes (durability degrades, the
        #: session keeps computing)
        self.write_errors: List[str] = []
        #: optional flight-recorder hook (``runtime/telemetry.Tracer``):
        #: when set, every snapshot publication records a CHECKPOINT
        #: span (async saves record it on the writer thread, so the
        #: trace shows the write overlapping the next compute)
        self.tracer = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, fresh: Dict[int, dict],
             carry: Iterable[int] = ()) -> dict:
        """Synchronous atomic snapshot.

        ``fresh[hid]`` = ``{"shape", "dtype", "tile", "grid", "name",
        "lineage" (pickled bytes or None), "tiles": {(i, j): ndarray}}``.
        ``carry`` hids reuse their previous manifest entry (shards stay in
        their older ``snap_`` directory).  Returns the published manifest.
        """
        carry = tuple(carry)
        tr = self.tracer
        if tr is not None and tr.enabled:
            nbytes = sum(int(a.nbytes) for meta in fresh.values()
                         for a in meta["tiles"].values())
            with tr.span("CHECKPOINT", step=int(step), nbytes=nbytes,
                         fresh=len(fresh), carry=len(carry)):
                return self._save(step, fresh, carry)
        return self._save(step, fresh, carry)

    def _save(self, step: int, fresh: Dict[int, dict],
              carry: Iterable[int] = ()) -> dict:
        prev = self._baseline()
        tmp = os.path.join(self.dir, f"snap_{step}.tmp")
        final = os.path.join(self.dir, f"snap_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        handles: Dict[str, dict] = {}
        for hid, meta in fresh.items():
            ent = {"shape": [int(x) for x in meta["shape"]],
                   "dtype": str(np.dtype(meta["dtype"])),
                   "tile": [int(x) for x in meta["tile"]],
                   "grid": [int(x) for x in meta["grid"]],
                   "name": meta.get("name", ""),
                   "lineage": None,
                   "tiles": {}}
            for (i, j), arr in meta["tiles"].items():
                a = np.ascontiguousarray(arr)
                fn = f"h{hid}_{i}_{j}.npy"
                np.save(os.path.join(tmp, fn), a)
                ent["tiles"][f"{i},{j}"] = {
                    "path": f"snap_{step}/{fn}",
                    "crc32": _crc(a.data),
                    "nbytes": int(a.nbytes)}
            lb = meta.get("lineage")
            if lb is not None:
                fn = f"h{hid}.lineage.pkl"
                with open(os.path.join(tmp, fn), "wb") as f:
                    f.write(lb)
                ent["lineage"] = {"path": f"snap_{step}/{fn}",
                                  "crc32": _crc(lb),
                                  "nbytes": len(lb)}
            handles[str(hid)] = ent
        for hid in carry:
            if prev is None or str(hid) not in prev["handles"]:
                raise KeyError(f"carry-over handle {hid} has no entry in "
                               f"the previous manifest")
            handles[str(hid)] = prev["handles"][str(hid)]
        manifest = {"step": int(step), "handles": handles}
        fsync_json(os.path.join(tmp, "manifest.json"), manifest)
        atomic_publish(tmp, final)
        self._last_man = manifest
        return manifest

    def save_async(self, step: int, fresh: Dict[int, dict],
                   carry: Iterable[int] = ()) -> None:
        """Publish on a background thread (tile arrays in ``fresh`` must
        already be host-side copies the caller will not mutate)."""
        self.wait()
        carry = tuple(carry)

        def _write():
            try:
                self.save(step, fresh, carry)
            except BaseException:
                self.write_errors.append(traceback.format_exc())

        self._async_thread = threading.Thread(target=_write, daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def busy(self) -> bool:
        """A background write is still in flight.  The session's steady-
        state path checks this to COALESCE instead of stall: when the disk
        cannot keep up, dirty handles stay dirty and ride the next
        snapshot rather than blocking compute on the writer."""
        return self._async_thread is not None and \
            self._async_thread.is_alive()

    def _baseline(self) -> Optional[dict]:
        """The manifest carry-over entries inherit from: the last one this
        store published, else the newest intact one on disk."""
        if self._last_man is None:
            self._last_man = self.latest_intact()
        return self._last_man

    def adopt(self, manifest: dict) -> None:
        """Make ``manifest`` the carry-over baseline (the resume path calls
        this: recomputed tiles are bit-identical to the checkpointed ones —
        deterministic tasks — so the old shards stay valid references)."""
        self._last_man = manifest

    def has_entry(self, hid: int) -> bool:
        man = self._baseline()
        return man is not None and str(hid) in man["handles"]

    def baseline_hids(self) -> set:
        """Handle ids in the carry-over baseline (see ``_baseline``)."""
        man = self._baseline()
        return set() if man is None else {int(h) for h in man["handles"]}

    # -- read ---------------------------------------------------------------
    def snaps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("snap_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, d,
                                                "manifest.json")):
                try:
                    out.append(int(d.split("_", 1)[1]))
                except ValueError:          # pragma: no cover — stray dir
                    pass
        return sorted(out)

    def manifest(self, step: int) -> Optional[dict]:
        """Parse one snapshot's manifest; None if unreadable/truncated."""
        import json
        try:
            with open(os.path.join(self.dir, f"snap_{step}",
                                   "manifest.json")) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    def _complete(self, man: dict) -> bool:
        """Every file the manifest references exists on disk (a rotated or
        half-deleted snapshot is not intact)."""
        for ent in man["handles"].values():
            paths = [te["path"] for te in ent["tiles"].values()]
            if ent.get("lineage"):
                paths.append(ent["lineage"]["path"])
            for p in paths:
                if not os.path.exists(os.path.join(self.dir, p)):
                    return False
        return True

    def latest_intact(self) -> Optional[dict]:
        """The newest manifest that parses and references only existing
        files — what ``CMMSession.resume`` rebuilds from.  Corrupt or
        truncated snapshots are skipped, falling back to older ones."""
        for step in reversed(self.snaps()):
            man = self.manifest(step)
            if man is not None and self._complete(man):
                return man
        return None

    def load_tile(self, man: dict, hid: int, i: int, j: int) -> np.ndarray:
        """One shard, CRC-validated — ShardCorrupt on any mismatch."""
        ent = man["handles"][str(hid)]["tiles"][f"{i},{j}"]
        path = os.path.join(self.dir, ent["path"])
        try:
            a = np.load(path)
        except Exception as e:
            raise ShardCorrupt(f"unreadable shard {ent['path']}: "
                               f"{e}") from e
        a = np.ascontiguousarray(a)
        if _crc(a.data) != ent["crc32"]:
            raise ShardCorrupt(f"CRC32 mismatch on shard {ent['path']} "
                               f"(handle #{hid} tile ({i},{j}))")
        return a

    def load_lineage(self, man: dict, hid: int) -> Optional[bytes]:
        """The pickled lineage blob, CRC-validated; None if the handle was
        checkpointed without lineage."""
        ent = man["handles"][str(hid)].get("lineage")
        if ent is None:
            return None
        path = os.path.join(self.dir, ent["path"])
        try:
            with open(path, "rb") as f:
                raw = f.read()
        except OSError as e:
            raise ShardCorrupt(f"unreadable lineage {ent['path']}: "
                               f"{e}") from e
        if _crc(raw) != ent["crc32"]:
            raise ShardCorrupt(f"CRC32 mismatch on lineage {ent['path']} "
                               f"(handle #{hid})")
        return raw

    def handle_bytes(self, man: dict, hid: int) -> int:
        """Total checkpointed tile bytes of one handle — the numerator of
        the reload-from-disk leg in the restore path's pricing."""
        return sum(te["nbytes"]
                   for te in man["handles"][str(hid)]["tiles"].values())

    # -- rotation ------------------------------------------------------------
    def rotate(self, keep: int = 3) -> None:
        """Drop all but the newest ``keep`` snapshots — EXCEPT directories
        still referenced by a kept manifest (incremental carry-over)."""
        self.wait()
        ids = self.snaps()
        kept = set(ids[-max(1, keep):])
        referenced = {f"snap_{s}" for s in kept}
        for s in kept:
            man = self.manifest(s)
            if man is None:
                continue
            for ent in man["handles"].values():
                for te in ent["tiles"].values():
                    referenced.add(te["path"].split("/", 1)[0])
                if ent.get("lineage"):
                    referenced.add(ent["lineage"]["path"].split("/", 1)[0])
        for d in os.listdir(self.dir):
            if d.startswith("snap_") and d not in referenced:
                shutil.rmtree(os.path.join(self.dir, d),
                              ignore_errors=True)

    # -- fault injection ------------------------------------------------------
    def corrupt_shard(self, hid: int) -> str:
        """Flip one byte in the newest shard of ``hid`` (the
        ``ChaosEvent(corrupt_tile=...)`` hook): the next reload fails its
        CRC and the restore path must degrade to lineage recompute."""
        self.wait()
        man = self.latest_intact()
        if man is None or str(hid) not in man["handles"]:
            raise KeyError(f"no checkpointed shards for handle {hid}")
        ent = next(iter(man["handles"][str(hid)]["tiles"].values()))
        path = os.path.join(self.dir, ent["path"])
        with open(path, "r+b") as f:
            f.seek(-1, os.SEEK_END)
            b = f.read(1)
            f.seek(-1, os.SEEK_END)
            f.write(bytes([b[0] ^ 0xFF]))
        return path


def pickle_expr(expr) -> bytes:
    """Stable pickling for lineage expressions (one place to change the
    protocol if manifests ever need cross-version compatibility)."""
    return pickle.dumps(expr, protocol=pickle.HIGHEST_PROTOCOL)


def unpickle_expr(raw: bytes):
    return pickle.loads(raw)
