"""Master-side membership service for the elastic cluster runtime.

The paper's cluster story is dynamic — "automatic configuration of
communication and worker processes ... automatically scale up for
clusters of heterogeneous nodes" — so the control plane must treat the
node set as a *stream of membership events*, not a frozen ``ClusterSpec``.
This module is the pure-logic half of that control plane (no processes,
no queues — unit-testable with a fake clock, same style as
``runtime/fault.py`` whose EWMA/patience policy shapes it reuses):

* **liveness** — each node carries a heartbeat timestamp, refreshed by
  worker ``hb`` messages (sent over the existing per-node queues) and by
  task-completion events; a node whose heartbeat goes stale past
  ``heartbeat_timeout_s``, or whose worker process is observed dead, is
  declared DEAD exactly once;
* **stragglers** — per-node EWMA of task service time; a node whose EWMA
  exceeds ``straggler_factor`` x the live-fleet median for
  ``straggler_patience`` consecutive sweeps raises one STRAGGLE event,
  which the executor answers with frontier re-planning away from the
  node plus speculative duplicate execution of its in-flight tasks; when
  the EWMA returns under the bar a RECOVER event re-arms the detector
  and lifts the re-planning penalty;
* **elasticity** — ``add_node`` registers a node that joined mid-run
  (scale-up) and returns the JOIN event for the re-planning loop.

The master node is exempt from eviction: it hosts result gathering and
the ``takecopy`` pins, so its loss is a run failure, not a membership
event (``MembershipService.mark_dead`` refuses it).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional


@dataclass
class MembershipConfig:
    """Policy knobs — defaults sized for a real cluster; tests shrink them."""

    #: how often workers emit heartbeats (the executor forwards this to
    #: the worker loop)
    heartbeat_interval_s: float = 0.25
    #: heartbeat staleness after which a node is presumed dead
    heartbeat_timeout_s: float = 10.0
    #: EWMA smoothing for per-task service times
    ewma_alpha: float = 0.2
    #: straggler bar: EWMA > factor x live-fleet median
    straggler_factor: float = 3.0
    #: consecutive flagged sweeps before a STRAGGLE event fires
    straggler_patience: int = 8
    #: minimum seconds between straggler sweeps — ``poll()`` may be called
    #: every master-loop iteration (milliseconds apart), so patience must
    #: be counted against wall time, not call count (None: heartbeat
    #: interval)
    straggler_poll_interval_s: Optional[float] = None
    #: tasks a node must have served before its EWMA is trusted
    straggler_min_tasks: int = 5
    #: survivors required to keep running after a death
    min_nodes: int = 1
    #: bounded-retry policy for the hardened transfer/dispatch path
    #: (exec/elastic.py): attempts per failed XFER destination before the
    #: run is declared failed ...
    xfer_max_retries: int = 8
    #: ... re-dispatch attempts for a failed non-accumulating task
    #: instance (in-place accumulate chains are never blindly re-run) ...
    task_max_retries: int = 2
    #: ... and the base of the exponential backoff between attempts
    retry_backoff_s: float = 0.02


#: membership event kinds
DEATH, JOIN, STRAGGLE, RECOVER = "death", "join", "straggle", "recover"


@dataclass(frozen=True)
class ClusterEvent:
    kind: str          # death | join | straggle
    node: int
    reason: str = ""


@dataclass
class NodeHealth:
    node: int
    last_heartbeat: float = 0.0
    task_ewma: float = 0.0
    tasks_done: int = 0
    flagged: int = 0
    straggling: bool = False
    alive: bool = True


class MembershipService:
    """Tracks node liveness + service-time health, emits membership events.

    Pure bookkeeping: the caller (the elastic executor's event loop) feeds
    it heartbeats, task timings and process-liveness observations, and
    drains ``poll()`` for the DEATH/STRAGGLE events it must react to.
    """

    def __init__(self, nodes, master: int = 0,
                 cfg: Optional[MembershipConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg or MembershipConfig()
        self.master = master
        self.clock = clock
        now = clock()
        self.nodes: Dict[int, NodeHealth] = {
            int(n): NodeHealth(int(n), now) for n in nodes}
        if master not in self.nodes:
            raise ValueError(f"master node {master} not in initial set")
        self._last_sweep = now

    # -- signals -------------------------------------------------------------
    def heartbeat(self, node: int) -> None:
        st = self.nodes.get(node)
        if st is not None and st.alive:
            st.last_heartbeat = self.clock()

    def record_task(self, node: int, seconds: float) -> None:
        """A task finished on ``node`` after ``seconds`` of service time
        (doubles as a heartbeat)."""
        st = self.nodes.get(node)
        if st is None or not st.alive:
            return
        st.last_heartbeat = self.clock()
        st.tasks_done += 1
        a = self.cfg.ewma_alpha
        st.task_ewma = (seconds if st.task_ewma == 0.0
                        else a * seconds + (1 - a) * st.task_ewma)

    # -- membership changes ---------------------------------------------------
    def add_node(self, node: int) -> ClusterEvent:
        """A node joined (or re-joined after a respawn) the cluster."""
        self.nodes[node] = NodeHealth(node, self.clock())
        return ClusterEvent(JOIN, node, "node joined")

    def mark_dead(self, node: int, reason: str = "") -> Optional[ClusterEvent]:
        """Declare ``node`` dead; returns the DEATH event the first time."""
        if node == self.master:
            raise RuntimeError(
                f"master node {node} died ({reason or 'unknown'}): "
                f"the run cannot be recovered")
        st = self.nodes.get(node)
        if st is None or not st.alive:
            return None
        st.alive = False
        return ClusterEvent(DEATH, node, reason or "marked dead")

    def seed_straggler_priors(self, nodes) -> None:
        """Pre-load the straggler detector with drift-report priors.

        A node the drift analysis (``core/drift.py``) found outside the
        residual band in a *previous* run starts this run one flagged
        sweep short of its patience budget: the first sweep that
        observes it over the bar fires STRAGGLE immediately instead of
        waiting out ``straggler_patience`` sweeps, while a node whose
        drift was transient is exonerated by its first clean sweep
        (``flagged`` resets to 0) and pays nothing.  The master cannot
        be seeded — it is exempt from eviction.
        """
        for n in nodes:
            st = self.nodes.get(int(n))
            if st is None or not st.alive or st.node == self.master:
                continue
            st.flagged = max(st.flagged, self.cfg.straggler_patience - 1)

    # -- detection ------------------------------------------------------------
    def poll(self, liveness: Optional[Mapping[int, bool]] = None
             ) -> List[ClusterEvent]:
        """One detection sweep.

        ``liveness`` carries direct process observations
        (``Process.is_alive()``); a ``False`` entry declares the node dead
        immediately, heartbeat staleness catches hung-but-running workers.
        Returns each DEATH/STRAGGLE event exactly once.
        """
        out: List[ClusterEvent] = []
        now = self.clock()
        for st in list(self.nodes.values()):
            if not st.alive:
                continue
            if liveness is not None and liveness.get(st.node) is False:
                ev = self.mark_dead(st.node, "worker process exited")
                if ev:
                    out.append(ev)
                continue
            if now - st.last_heartbeat > self.cfg.heartbeat_timeout_s:
                ev = self.mark_dead(
                    st.node, f"heartbeat stale "
                    f"({now - st.last_heartbeat:.1f}s)")
                if ev:
                    out.append(ev)
        out.extend(self._poll_stragglers())
        return out

    def _poll_stragglers(self) -> List[ClusterEvent]:
        now = self.clock()
        interval = self.cfg.straggler_poll_interval_s
        if interval is None:
            interval = self.cfg.heartbeat_interval_s
        if now - self._last_sweep < interval:
            return []
        self._last_sweep = now
        live = [s for s in self.nodes.values()
                if s.alive and s.task_ewma > 0.0
                and s.tasks_done >= self.cfg.straggler_min_tasks]
        if len(live) < 2:
            return []
        times = sorted(s.task_ewma for s in live)
        # lower-middle median: on an even-sized fleet the upper-middle
        # element IS the straggler (on 2 nodes it would be compared
        # against itself and never flagged)
        median = times[(len(times) - 1) // 2]
        out = []
        for st in live:
            if st.task_ewma > self.cfg.straggler_factor * median:
                st.flagged += 1
                if st.flagged >= self.cfg.straggler_patience \
                        and not st.straggling:
                    st.straggling = True
                    out.append(ClusterEvent(
                        STRAGGLE, st.node,
                        f"EWMA {st.task_ewma:.4f}s > "
                        f"{self.cfg.straggler_factor}x median "
                        f"{median:.4f}s"))
            else:
                st.flagged = 0
                if st.straggling:
                    # back under the bar: re-arm the detector and tell
                    # the control plane to stop penalising the node
                    st.straggling = False
                    out.append(ClusterEvent(
                        RECOVER, st.node,
                        f"EWMA {st.task_ewma:.4f}s back under the "
                        f"straggler bar"))
        return out

    # -- queries --------------------------------------------------------------
    def alive_nodes(self) -> List[int]:
        return sorted(n for n, s in self.nodes.items() if s.alive)

    def is_alive(self, node: int) -> bool:
        st = self.nodes.get(node)
        return st is not None and st.alive

    def ewma(self, node: int) -> float:
        st = self.nodes.get(node)
        return st.task_ewma if st is not None else 0.0
