"""Fault tolerance: failure detection, restart policy, straggler mitigation.

On an SPMD TPU fleet the failure domain is a *slice/pod*, not a single task:
a chip failure takes its slice out, and the job either restarts on the same
topology or re-meshes onto the survivors.  This module implements the
control-plane logic (pure Python — exercised in tests by injecting
failures), wired to:

  * checkpoint/manager.py   — durable state to restart from;
  * runtime/elastic.py      — re-mesh + re-shard onto survivors;
  * data/pipeline.py        — counter-based batches => exact replay.

Straggler mitigation: at SPMD granularity a straggling slice delays every
collective.  The watchdog tracks per-step wall time and flags slices whose
EWMA exceeds `straggler_factor` x the fleet median; the policy response is
checkpoint-and-re-mesh (drop the slice) after `patience` flagged steps —
the CMM simulator's slowdown model (core/machine.py `slowdown`) is reused
in tests to quantify when dropping a straggler beats keeping it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set


@dataclass
class FaultConfig:
    heartbeat_timeout_s: float = 60.0
    straggler_factor: float = 1.5
    straggler_patience: int = 10
    max_restarts: int = 100
    min_pods: int = 1


@dataclass
class PodState:
    pod_id: int
    last_heartbeat: float = 0.0
    step_ewma: float = 0.0
    flagged: int = 0
    alive: bool = True


class FleetMonitor:
    """Tracks heartbeats + per-step timings for every pod/slice."""

    def __init__(self, n_pods: int, cfg: Optional[FaultConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        # default constructed per instance: a default in the signature
        # would be ONE shared FaultConfig across every monitor, so a
        # config mutation on one monitor would leak into all others
        self.cfg = cfg if cfg is not None else FaultConfig()
        self.clock = clock
        self.pods = {i: PodState(i, clock()) for i in range(n_pods)}
        self.restarts = 0

    # -- signals --------------------------------------------------------------
    def heartbeat(self, pod: int, step_seconds: Optional[float] = None):
        st = self.pods[pod]
        st.last_heartbeat = self.clock()
        if step_seconds is not None:
            a = 0.2
            st.step_ewma = (step_seconds if st.step_ewma == 0
                            else a * step_seconds + (1 - a) * st.step_ewma)

    def mark_failed(self, pod: int):
        self.pods[pod].alive = False

    # -- detection ------------------------------------------------------------
    def dead_pods(self) -> List[int]:
        now = self.clock()
        out = []
        for st in self.pods.values():
            if not st.alive or \
                    now - st.last_heartbeat > self.cfg.heartbeat_timeout_s:
                st.alive = False
                out.append(st.pod_id)
        return out

    def stragglers(self) -> List[int]:
        alive = [s for s in self.pods.values() if s.alive and s.step_ewma > 0]
        if len(alive) < 2:
            return []
        times = sorted(s.step_ewma for s in alive)
        median = times[len(times) // 2]
        out = []
        for st in alive:
            if st.step_ewma > self.cfg.straggler_factor * median:
                st.flagged += 1
                if st.flagged >= self.cfg.straggler_patience:
                    out.append(st.pod_id)
            else:
                st.flagged = 0
        return out

    def alive_pods(self) -> List[int]:
        return [s.pod_id for s in self.pods.values() if s.alive]


@dataclass
class RestartDecision:
    action: str                 # continue | restart_same | remesh | abort
    pods: List[int] = field(default_factory=list)
    reason: str = ""


def decide(monitor: FleetMonitor) -> RestartDecision:
    """The restart policy (pure, unit-testable)."""
    dead = monitor.dead_pods()
    alive = monitor.alive_pods()
    if not dead:
        lagging = monitor.stragglers()
        if lagging and len(alive) - len(lagging) >= monitor.cfg.min_pods:
            return RestartDecision(
                "remesh", [p for p in alive if p not in lagging],
                f"dropping stragglers {lagging}")
        return RestartDecision("continue", alive, "healthy")
    if monitor.restarts >= monitor.cfg.max_restarts:
        return RestartDecision("abort", [], "restart budget exhausted")
    if len(alive) >= monitor.cfg.min_pods:
        monitor.restarts += 1
        return RestartDecision("remesh", alive,
                               f"pods {dead} failed; continuing on {alive}")
    return RestartDecision("abort", [], "not enough survivors")
