"""Elastic re-meshing: resume a job on a different device count.

Because every param carries logical axes (models/lm.py param_specs) and
checkpoints store unsharded leaves (checkpoint/store.py), scaling down is:

    1. build the new mesh from the surviving pods/devices,
    2. re-resolve logical axes -> NamedShardings on the new mesh
       (divisibility re-checked; rules that no longer divide are dropped),
    3. restore the checkpoint with the new shardings,
    4. re-jit the step functions (shapes unchanged — global batch is kept
       constant by raising grad-accumulation microbatches: batch math in
       `rebalance_microbatches`).

Step 4's invariant — same global batch, more microbatches — keeps training
bitwise-comparable across re-meshes (the data stream is step-indexed).
"""
from __future__ import annotations

from dataclasses import replace
from typing import Optional, Tuple

import jax

from ..configs.base import ParallelPlan


def make_elastic_mesh(n_devices: int, model_parallel: int = 16,
                      axis_names=("data", "model"),
                      devices=None) -> "jax.sharding.Mesh":
    """Largest (data, model) mesh that fits the surviving devices."""
    devices = devices if devices is not None else jax.devices()
    devices = devices[:n_devices]
    mp = min(model_parallel, len(devices))
    while len(devices) % mp:
        mp -= 1
    dp = len(devices) // mp
    import numpy as np
    arr = np.array(devices[:dp * mp]).reshape(dp, mp)
    return jax.sharding.Mesh(arr, axis_names)


def rebalance_microbatches(plan: ParallelPlan, global_batch: int,
                           old_dp: int, new_dp: int) -> ParallelPlan:
    """Keep the global batch constant when data-parallel width shrinks.

    per-device batch = global / (dp * microbatches); when dp shrinks we
    raise microbatches by the same factor (rounded up to divide the batch).
    """
    scale = old_dp / new_dp
    mb = max(1, int(round(plan.microbatches * scale)))
    per_dev = max(global_batch // new_dp, 1)
    mb = min(mb, per_dev)
    while per_dev % mb:          # decrease until it divides (terminates at 1)
        mb -= 1
    return replace(plan, microbatches=mb)


def remesh_plan(plan: ParallelPlan, old_mesh, new_mesh,
                global_batch: int) -> ParallelPlan:
    old_dp = old_mesh.shape.get("data", 1) * old_mesh.shape.get("pod", 1)
    new_dp = new_mesh.shape.get("data", 1) * new_mesh.shape.get("pod", 1)
    return rebalance_microbatches(plan, global_batch, old_dp, new_dp)
