"""Disk spill tier for bounded worker arenas.

The hot tier is the per-node SharedMemory arena (`exec/cluster.py`); when a
node's ``ClusterSpec.mem_bytes`` budget is reached the arena evicts cold
unpinned tiles here and faults them back in transparently on read.  The
store reuses the durability layer's shard idioms: one ``.npy`` file per
tile, CRC32 recorded at write time and verified on every fault-in, so a
torn or bit-flipped spill file is *detected* (``SpillCorrupt``) and the
runtime degrades to lineage recompute instead of silently computing on
garbage.

The store is worker-local and unsynchronised — the owning arena serialises
access under its own lock.
"""
from __future__ import annotations

import os
import zlib
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


def spill_root() -> str:
    """Base directory for all runs' spill files (under the platform
    tempdir, mirroring where SharedMemory lives conceptually)."""
    import tempfile
    return os.path.join(tempfile.gettempdir(), "cmm_spill")


def run_spill_dir(run_prefix: str) -> str:
    """The spill directory for one executor run, derived from the same
    unique prefix that names its /dev/shm segments — so crash-path reaping
    can sweep by prefix exactly like segment reaping does."""
    return os.path.join(spill_root(), run_prefix.strip("_"))


class SpillMiss(RuntimeError):
    """Fault-in requested for a key the store has no file for (or the
    file vanished) — the cold-tier copy is gone."""


class SpillCorrupt(RuntimeError):
    """A spill file failed its CRC32 on fault-in — the cold-tier copy is
    untrustworthy and must be treated as lost."""


class SpillDataLost(RuntimeError):
    """An arena read hit a spilled tile whose cold copy is missing or
    corrupt.  Carries the tile ref so the master can drop that holding
    and degrade to lineage recompute."""

    def __init__(self, ref, cause: str):
        self.ref = ref
        super().__init__(f"spilled tile {ref} lost: {cause}")


class ArenaOverflow(RuntimeError):
    """An allocation cannot be satisfied within the arena's byte budget
    and nothing is left to evict (everything resident is pinned or
    retained).  The master surfaces this as a structured
    ``MemoryBudgetExceeded`` rather than an OOM kill."""


class AllocFailInjected(RuntimeError):
    """Chaos-injected allocation failure (``ChaosEvent.alloc_fail``):
    models a transient malloc/shm failure on the Nth fresh allocation.
    Pure tasks retry through the normal bounded-retry path."""


def _crc(buf) -> int:
    return zlib.crc32(buf) & 0xFFFFFFFF


class TileSpillStore:
    """CRC-checked ``.npy`` cold tier for one arena.

    Keys are arbitrary hashables (the arena uses ``TileRef``s); the
    key -> file mapping lives in memory, so a store instance only trusts
    files it wrote itself — stale files from a SIGKILLed predecessor
    incarnation are invisible to it (and swept by the master's reaper).
    """

    def __init__(self, directory: str, file_prefix: str):
        self.dir = directory
        self._fp = file_prefix
        self._seq = 0
        # key -> (path, crc32, nbytes)
        self._ent: Dict[object, Tuple[str, int, int]] = {}
        self.writes = 0
        self.reads = 0
        self.bytes_written = 0
        self.bytes_read = 0
        #: optional flight-recorder hook (``runtime/telemetry.Tracer``):
        #: when set, every spill / fault-in records a SPILL / FAULTIN
        #: span — the evidence the drift report prices against the
        #: TimeModel's spill bandwidths
        self.tracer = None

    # -- write / read / drop ------------------------------------------------
    def spill(self, key, arr: np.ndarray) -> int:
        """Write ``arr`` to the cold tier under ``key``; returns bytes
        written.  Overwrites any previous entry for the key."""
        tr = self.tracer
        if tr is not None and tr.enabled:
            with tr.span("SPILL", nbytes=int(arr.nbytes), key=str(key)):
                return self._spill(key, arr)
        return self._spill(key, arr)

    def _spill(self, key, arr: np.ndarray) -> int:
        os.makedirs(self.dir, exist_ok=True)
        self.drop(key)
        path = os.path.join(self.dir, f"{self._fp}_{self._seq}.npy")
        self._seq += 1
        data = np.ascontiguousarray(arr)
        with open(path, "wb") as f:
            np.save(f, data)
        nbytes = data.nbytes
        self._ent[key] = (path, _crc(data.tobytes()), nbytes)
        self.writes += 1
        self.bytes_written += nbytes
        return nbytes

    def fault_in(self, key, keep: bool = False) -> np.ndarray:
        """CRC-verified read of ``key`` back from the cold tier (see
        :meth:`_fault_in`); records a FAULTIN span when a tracer is
        wired."""
        tr = self.tracer
        if tr is not None and tr.enabled:
            with tr.span("FAULTIN", key=str(key)) as sp:
                arr = self._fault_in(key, keep)
                sp.args["nbytes"] = int(arr.nbytes)
                return arr
        return self._fault_in(key, keep)

    def _fault_in(self, key, keep: bool = False) -> np.ndarray:
        """Read ``key`` back from the cold tier, CRC-verified.  The entry
        is consumed (exclusive tiering: a tile lives in exactly one tier)
        unless ``keep`` — a caller that still has to allocate hot-tier
        space for the data passes ``keep=True`` and drops the entry only
        once the new binding exists, so an allocation failure mid-fault
        never loses the sole remaining copy."""
        ent = self._ent.get(key)
        if ent is None:
            raise SpillMiss(f"no spill entry for {key}")
        path, crc, nbytes = ent
        try:
            with open(path, "rb") as f:
                arr = np.load(f)
        except (OSError, ValueError) as e:
            raise SpillMiss(f"spill file for {key} unreadable: {e}")
        if arr.nbytes != nbytes or _crc(arr.tobytes()) != crc:
            raise SpillCorrupt(
                f"spill file {os.path.basename(path)} for {key} failed CRC")
        if not keep:
            self.drop(key)
        self.reads += 1
        self.bytes_read += arr.nbytes
        return arr

    def drop(self, key) -> None:
        ent = self._ent.pop(key, None)
        if ent is not None:
            try:
                os.unlink(ent[0])
            except OSError:
                pass

    # -- introspection ------------------------------------------------------
    def __contains__(self, key) -> bool:
        return key in self._ent

    def keys(self) -> Iterator:
        return iter(self._ent)

    @property
    def live_files(self) -> int:
        return len(self._ent)

    @property
    def live_bytes(self) -> int:
        return sum(e[2] for e in self._ent.values())

    def corrupt(self, key) -> None:
        """Test hook: flip the last byte of ``key``'s spill file so the
        next fault-in fails its CRC (mirrors durability's corrupt_shard)."""
        path = self._ent[key][0]
        with open(path, "r+b") as f:
            f.seek(-1, os.SEEK_END)
            b = f.read(1)
            f.seek(-1, os.SEEK_END)
            f.write(bytes([b[0] ^ 0xFF]))

    def destroy(self) -> int:
        """Remove every live file; returns how many entries were still
        present (a clean shutdown has zero — anything else is a leak)."""
        leftover = len(self._ent)
        for key in list(self._ent):
            self.drop(key)
        try:
            os.rmdir(self.dir)
        except OSError:
            pass
        return leftover
