"""Elastic fault-tolerant cluster executor: plan -> observe -> re-plan.

``ClusterExecutor`` (exec/cluster.py) executes a HEFT schedule across one
worker process per node, but the membership is frozen at plan time: a
dead worker hangs the run and a node joining mid-run is invisible.  This
backend is the paper's dynamic-cluster story made real — "automatic
configuration of communication and worker processes ... automatically
scale up for clusters of heterogeneous nodes" — implemented as a master
control loop over three mechanisms:

* **membership** (``runtime/membership.py``): workers heartbeat over
  their queues; process exit or heartbeat staleness raises a DEATH
  event, per-task service-time EWMAs raise STRAGGLE events (the
  ``runtime/fault.py`` policy shapes applied at node granularity).

* **lineage recovery** (numpywren-style): no tile data is ever
  checkpointed.  Every tile is a deterministic function of the task
  graph, so when a node dies the master resurrects exactly the completed
  tasks whose output values were lost with it and are still needed —
  computed as a closure over the producer subgraph — and re-executes
  them on the survivors.

* **incremental frontier re-planning** (``heft.replan_frontier``): on
  death/join/straggle the not-yet-dispatched frontier is re-HEFTed
  against the surviving (or augmented) ``ClusterSpec`` — completed and
  in-flight placements stay fixed, dead nodes are drained
  (``spec.without_node``), joined nodes appended (``spec.with_node``).

Stragglers additionally get **speculative duplicate execution**: their
in-flight tasks are duplicated onto healthy nodes, and the master's
first-writer-wins bookkeeping keeps exactly one completion per task.
Because every task kind is deterministic (same NumPy call on the same
bits), duplicate and resurrected executions produce bit-identical
tiles, so results under any failure/join/straggle interleaving are
**bit-identical to** ``LocalExecutor`` — the repo's conformance bar —
which the fault-injection tier (tests/test_elastic.py) asserts.

Unlike the static executor's pre-computed transfer plan, the elastic
master routes data dynamically: it tracks which *version* (producer
task id) of each tile ref is bound in each node's arena, and requests a
shared-memory XFER from a live holder whenever a dispatch-ready task is
missing an input at its assigned node.  Writes to one ``(node, ref)``
arena slot are serialised by a master-side write lock, so in-place
accumulate chains can never race a transfer reading the same buffer.

Fault injection for tests/benchmarks is first-class: ``ChaosEvent``\\ s
fire on task-completion counts — SIGKILL a worker process, join a new
node, throttle a node into a straggler — so churn is reproducible.
"""
from __future__ import annotations

import os
import queue as _queue
import shutil
import signal
import time
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core.graph import TaskGraph, TaskKind, TileRef
from ..core.heft import Placement, Schedule, replan_frontier
from ..core.lazy import Op
from ..core.machine import ClusterSpec, MemoryBudgetExceeded
from ..core.session import ResidentTilesLost
from ..core.timemodel import CostCache, TimeModel, analytic_time_model
from ..core.tiling import assemble, result_sets_of
from ..runtime.membership import (DEATH, RECOVER, STRAGGLE,
                                  MembershipConfig, MembershipService)
from ..runtime.spill import run_spill_dir
from ..runtime.telemetry import (MetricsRegistry, Span, Tracer,
                                 estimate_clock_offset)
from ..runtime.wire import BCAST_MIN_FANOUT, choose_wire_codec
from .cluster import _CHAIN_KINDS, _RUN_IDS, _attach_shm, _node_worker


@dataclass(frozen=True)
class ChaosEvent:
    """One injected membership change, fired when the master's completed-
    task count first reaches ``after_done`` (deterministic trigger)."""

    after_done: int
    #: SIGKILL this node's worker process (master refuses its own node)
    kill_node: Optional[int] = None
    #: spawn + join a fresh node with this many worker threads
    join_workers: Optional[int] = None
    join_slowdown: float = 1.0
    #: make this node sleep this long per task (manufactures a straggler)
    throttle_node: Optional[int] = None
    throttle_seconds: float = 0.0
    #: bypass EWMA detection latency: raise STRAGGLE for this node now
    flag_straggler: Optional[int] = None
    #: poison the next N master->worker XFER requests (each is sent with
    #: an unattachable source segment, so the destination worker reports
    #: ``xfer_fail`` — exercising the bounded-backoff retry path
    #: end-to-end on real queues)
    drop_xfer: Optional[int] = None
    #: flip a byte in the newest checkpoint shard of this resident handle
    #: id (needs a durable session — ``CMMSession(checkpoint_dir=...)``
    #: wires ``corrupt_tile_hook``): the next resume fails that shard's
    #: CRC and must degrade to lineage recompute
    corrupt_tile: Optional[int] = None
    #: SIGKILL the ENTIRE cluster — every worker, then the master process
    #: itself.  Nothing survives to clean up (that is the point: the
    #: durable session's ``resume()`` is what recovers) — only subprocess
    #: test harnesses should arm this
    kill_master: bool = False
    #: shrink this node's arena memory budget to ``squeeze_bytes``
    #: mid-run: the worker evicts cold tiles to the spill tier until it
    #: fits, and the membership-adjusted spec (``current_spec``) reflects
    #: the new budget for subsequent session plans
    mem_squeeze: Optional[int] = None
    squeeze_bytes: int = 0
    #: fail this node's Nth subsequent arena allocation with an injected
    #: ``AllocFailInjected`` — the task fails, the master retries it with
    #: backoff (the counter is consumed, so the retry allocates for real)
    alloc_fail: Optional[int] = None
    alloc_fail_nth: int = 1


class ElasticClusterExecutor:
    """Multi-process cluster executor that survives membership churn.

    Same numerics and tile runtime as ``ClusterExecutor`` (one process
    per node, shared-memory arenas, real XFER copies), plus the elastic
    control plane described in the module docstring.  ``timemodel``
    drives frontier re-planning (``CMMEngine.run`` injects its own);
    ``membership`` tunes detection latency; ``chaos`` injects
    failures/joins/stragglers for tests and the chaos benchmark;
    ``respawn_dead=True`` additionally respawns a dead node's worker
    (fresh process, empty arena) and re-admits it instead of draining
    its slots.
    """

    def __init__(self, workers_per_node: Optional[int] = None,
                 free_buffers: bool = True,
                 mp_context: Optional[str] = None,
                 timeout: float = 300.0,
                 timemodel: Optional[TimeModel] = None,
                 membership: Optional[MembershipConfig] = None,
                 chaos: Sequence[ChaosEvent] = (),
                 respawn_dead: bool = False,
                 speculate: bool = True,
                 gc_interval: int = 64,
                 blas_threads: Optional[int] = None,
                 session: bool = False,
                 wire_codec: Optional[str] = None,
                 broadcast: bool = True,
                 stream_gather: bool = True,
                 trace: bool = True,
                 straggler_priors: Sequence[int] = ()):
        self.workers_per_node = workers_per_node
        self.free_buffers = free_buffers
        self.mp_context = mp_context
        self.timeout = timeout
        self.timemodel = timemodel
        self.membership_cfg = membership
        self.chaos = tuple(sorted(chaos, key=lambda c: c.after_done))
        self.respawn_dead = respawn_dead
        self.speculate = speculate
        self.gc_interval = max(1, gc_interval)
        #: wire codec policy: None prices each cross-node edge with the
        #: TimeModel's compression terms; "raw"/"zlib" force it (tests,
        #: benchmarks).  Compressed XFERs ride the worker's pack/unpack
        #: lease, so the staged payload stays authoritative end to end.
        self.wire_codec = wire_codec
        #: cap each (holder, tile) fan-out so wide consumer sets drain as
        #: a dynamic relay tree: landed copies become sources themselves
        #: (and re-root for free when a relay node dies — the routing is
        #: re-evaluated per dispatch scan)
        self.broadcast = broadcast
        #: copy gathered result tiles off the master arena the moment
        #: their TAKECOPY lands (overlapped with the remaining compute)
        #: instead of in one barrier pass after the run
        self.stream_gather = stream_gather
        #: per-worker BLAS thread cap (machine model: threads_per_worker);
        #: None leaves the BLAS pool at its library default
        self.blas_threads = blas_threads
        #: session mode: workers + arenas + membership survive across
        #: ``execute()`` calls; resident tiles lost to churn raise
        #: ``ResidentTilesLost`` for the session's lineage-recompute path
        self.session = session
        if session and respawn_dead:
            # a respawned worker returns with an EMPTY arena but an
            # unchanged spec, which would hide retained-tile loss from
            # the session's home-vs-alive-nodes check
            raise ValueError("respawn_dead is not supported in session "
                             "mode; lost resident tiles recompute from "
                             "lineage on the survivors instead")
        #: set by a durable session (CMMSession with checkpoint_dir):
        #: called with a handle id when ChaosEvent(corrupt_tile=...) fires
        self.corrupt_tile_hook = None
        #: flight recorder: on by default (obs_bench gates the paired
        #: overhead under 5%); ``spans`` holds the last run's timeline
        #: (master + ingested worker spans) after execute()
        self.trace = trace
        #: nodes a previous run's drift report flagged slow: seeded into
        #: the membership detector so its straggler check fires on the
        #: first confirming sweep instead of waiting out the patience
        #: budget (drift_report(...).straggler_priors)
        self.straggler_priors = tuple(straggler_priors)
        self.spans: List = []
        #: per-node clock offsets from the cal handshake — persistent
        #: across runs/joins (each _spawn calibrates its incarnation)
        self._clock_offsets: Dict[int, float] = {}
        self._started = False
        self._broken = False
        self._run_msg = None
        self._ms: Optional[MembershipService] = None
        self._cur_spec: Optional[ClusterSpec] = None
        self.stats: Dict[str, object] = {}

    @property
    def current_spec(self) -> Optional[ClusterSpec]:
        """The membership-adjusted spec after the last run (session mode):
        dead nodes drained, joined nodes appended — what the session's
        engine must plan the NEXT run against."""
        return self._cur_spec

    # -- setup helpers --------------------------------------------------------
    def _derive_fill_origin(self, prog) -> Dict[int, str]:
        """INPUT leaves live on the master, generated leaves fill locally
        (mirrors ``CMMEngine._fill_origins`` without needing the root)."""
        return {uid: ("master" if n.op is Op.INPUT else "local")
                for uid, n in prog.leaf_nodes.items()}

    def _spawn(self, node: int, nthreads: int,
               mem_bytes: Optional[float] = None):
        """(Re)spawn the worker process for ``node`` under a fresh
        incarnation: fresh queues (a SIGKILLed predecessor may have died
        holding queue locks or with stale dispatches enqueued) and a
        fresh arena namespace (so leftover segments of the dead
        incarnation can never collide with new allocations)."""
        inc = next(self._incarnations)
        prefix = f"{self._prefix}i{inc}_"
        inq, outq = self._ctx.Queue(), self._ctx.Queue()
        p = self._ctx.Process(
            target=_node_worker,
            args=(node, inq, outq, self._g, self._tile, self._leaf_nodes,
                  self._dtypes, nthreads, prefix,
                  self._mcfg.heartbeat_interval_s, self.blas_threads,
                  mem_bytes, self._spill_dir, self.trace),
            daemon=True)
        p.start()
        self._procs[node] = p
        self._inqs[node] = inq
        self._outqs[node] = outq
        if self.trace:
            # calibrate this incarnation's clock against the master's
            # (the echo lands in handle()'s "cal" branch)
            inq.put(("cal", time.perf_counter()))
        if self._run_msg is not None:
            # session mode: hand the newcomer the CURRENT run's context
            # (graph + resident-leaf handle ids) — fork-inherited state
            # may predate it
            inq.put(self._run_msg)

    # -- the run --------------------------------------------------------------
    def execute(self, plan) -> np.ndarray:
        import multiprocessing as mp

        g: TaskGraph = plan.program.graph
        spec: Optional[ClusterSpec] = getattr(plan, "spec", None)
        if spec is None:
            raise ValueError("ElasticClusterExecutor needs plan.spec")
        if self.session and self._broken:
            raise RuntimeError("session elastic executor is broken "
                               "(a previous run failed); open a new session")
        if self.session and self._started and spec != self._cur_spec:
            raise ValueError(
                "session elastic executor: the plan's spec does not match "
                "the membership-adjusted current_spec; re-plan against "
                "executor.current_spec")
        residency = getattr(plan, "residency", None)
        rsets = result_sets_of(g)
        #: RESIDENT task tid -> home node (pinned placement for replans)
        #: and home coverage per handle (loss detection on node death)
        resident_pins: Dict[int, int] = {}
        if residency is not None:
            for t in g:
                if t.kind is TaskKind.RESIDENT:
                    h = residency.handles[t.payload]
                    resident_pins[t.tid] = h.home.get(
                        (t.out.i, t.out.j), 0)
        sched: Schedule = plan.schedule
        n_joins = sum(1 for c in self.chaos if c.join_workers is not None)
        for c in self.chaos:
            if c.kill_node is not None:
                if c.kill_node == spec.master:
                    raise ValueError("cannot kill the master node")
                if not 0 <= c.kill_node < spec.n_nodes + n_joins:
                    raise ValueError(
                        f"kill_node={c.kill_node} is outside the "
                        f"{spec.n_nodes}-node spec (+{n_joins} joins)")
            if c.join_workers is not None and c.join_workers <= 0:
                raise ValueError("join needs at least one worker")
            if c.mem_squeeze is not None:
                if not 0 <= c.mem_squeeze < spec.n_nodes + n_joins:
                    raise ValueError(
                        f"mem_squeeze={c.mem_squeeze} is outside the "
                        f"{spec.n_nodes}-node spec (+{n_joins} joins)")
                if c.squeeze_bytes <= 0:
                    raise ValueError("mem_squeeze needs squeeze_bytes > 0")
            if c.alloc_fail is not None:
                if not 0 <= c.alloc_fail < spec.n_nodes + n_joins:
                    raise ValueError(
                        f"alloc_fail={c.alloc_fail} is outside the "
                        f"{spec.n_nodes}-node spec (+{n_joins} joins)")
                if c.alloc_fail_nth < 1:
                    raise ValueError("alloc_fail_nth must be >= 1")
            if c.corrupt_tile is not None and self.corrupt_tile_hook is None:
                raise ValueError(
                    "ChaosEvent(corrupt_tile=...) needs a durable session "
                    "(CMMSession(checkpoint_dir=...)) whose shards it can "
                    "corrupt")

        tm = self.timemodel or analytic_time_model()
        self._mcfg = self.membership_cfg or MembershipConfig()
        if not (self.session and self._started):
            method = self.mp_context or (
                "fork" if "fork" in mp.get_all_start_methods() else "spawn")
            self._ctx = mp.get_context(method)
            self._prefix = f"cmm{os.getpid()}_{next(_RUN_IDS)}e"
            self._incarnations = iter(range(1 << 30))
            self._spill_dir = run_spill_dir(self._prefix)
        self._g, self._tile = g, plan.tile
        # RESIDENT leaves stay master-side (workers resolve them against
        # their retained arena store via handle ids)
        self._leaf_nodes = {uid: n for uid, n in
                            plan.program.leaf_nodes.items()
                            if n.op is not Op.RESIDENT}
        self._dtypes = plan.program.dtypes
        if self.session:
            self._run_msg = ("run", g, plan.tile, self._leaf_nodes,
                             self._dtypes,
                             residency.resident_ids()
                             if residency is not None else {})
        origin = self._derive_fill_origin(plan.program)

        # -- value-version canonicalisation ---------------------------------
        # the scheduler may splice in regenerated-fill clones: several FILL
        # task ids producing the SAME tile from the same leaf payload.
        # Their outputs are bit-identical by construction, so version
        # bookkeeping treats each group as one canonical version (else a
        # value-equal rebind looks like a lost value and triggers a
        # needless lineage recovery).
        canon: Dict[int, int] = {}
        vgroup: Dict[int, Tuple[int, ...]] = {}
        fill_groups: Dict[Tuple[object, TileRef], List[int]] = \
            defaultdict(list)
        for t in g:
            if t.kind is TaskKind.FILL and t.out is not None:
                fill_groups[(t.payload, t.out)].append(t.tid)
        for members in fill_groups.values():
            c = min(members)
            for m in members:
                canon[m] = c
            vgroup[c] = tuple(sorted(members))

        def canon_of(tid: int) -> int:
            return canon.get(tid, tid)

        # -- static dataflow: data needs (ref, producer-version) per task --
        needs: Dict[int, List[Tuple[TileRef, int]]] = defaultdict(list)
        for t in g:
            for p in sorted(t.preds):
                po = g.tasks[p].out
                if po is None:
                    continue
                if po in t.ins or (t.out is not None and po == t.out):
                    needs[t.tid].append((po, canon_of(p)))

        # -- mutable control-plane state ------------------------------------
        cur_spec = spec
        master = spec.master
        #: persisted output tiles of this run: ref -> owning root uid.
        #: They are kept by the GC sweep and moved into the session store
        #: (worker ``retain`` op) at the end of the run.
        retained_refs: Dict[TileRef, int] = {}
        for _rs in rsets:
            if not _rs.gather:
                for _r in _rs.tiles:
                    retained_refs[_r] = _rs.uid
        #: a resident-input loss pends an orderly abort (session retries
        #: after lineage recompute); never set outside session mode
        pending_abort: List[Optional[ResidentTilesLost]] = [None]
        assigned = {tid: p.node for tid, p in sched.placements.items()}
        missing = [tid for tid in g.tasks if tid not in assigned]
        if missing:
            raise ValueError(f"schedule misses placements for "
                             f"{missing[:5]}")
        cur_place: Dict[int, Placement] = dict(sched.placements)
        deps_left = {t.tid: len(t.preds) for t in g}
        completed: Set[int] = set()
        dispatched: Dict[int, Set[int]] = defaultdict(set)
        exec_nodes: Dict[int, int] = {}
        node_pids: Dict[int, int] = {}
        #: (node, ref) -> (version tid, segment name, dtype str): the
        #: master's view of every worker arena binding
        avail: Dict[Tuple[int, TileRef], Tuple[int, str, str]] = {}
        write_busy: Set[Tuple[int, TileRef]] = set()
        src_busy: Dict[Tuple[int, TileRef], int] = defaultdict(int)
        xfer_inflight: Dict[Tuple[int, TileRef], Tuple[int, int]] = {}
        xfer_retries: Dict[Tuple[int, int], int] = defaultdict(int)
        #: bounded retry-with-backoff for the hardened transfer path:
        #: (node, ref) / tid -> monotonic time before which no new
        #: attempt is issued (exponential in the attempt count, capped)
        xfer_retry_at: Dict[Tuple[int, TileRef], float] = {}
        task_retry_at: Dict[int, float] = {}
        task_retries: Dict[int, int] = defaultdict(int)
        #: leased transfer path (bounded-arena sources and every
        #: compressed edge): the holder pins the tile ("hold") or pins +
        #: stages the encoded payload ("pack") until the master releases
        #: it.  pending_lease holds consumers waiting on the holder's
        #: ack — one entry per request, each ack dispatches exactly one
        #: (acks and worker-side pins are one-to-one, so the release
        #: count always balances).  leases maps a dispatched XFER's
        #: destination back to the (holder, codec) pin it must release.
        pending_lease: Dict[Tuple[int, TileRef],
                            List[Tuple[int, int, str]]] = defaultdict(list)
        leases: Dict[Tuple[int, TileRef], Tuple[int, str]] = {}
        #: per-(holder, tile) concurrent-reader cap — beyond it, waiting
        #: consumers defer until a landed copy can serve as a relay source
        relay_cap = (BCAST_MIN_FANOUT - 1) if self.broadcast else (1 << 30)
        #: streamed-gather staging: result tiles copied off the master
        #: arena as their TAKECOPY lands (master arena must be unbounded —
        #: a bounded one could evict the segment mid-attach)
        gather_refs: Dict[TileRef, int] = {}
        for _rs in rsets:
            if _rs.gather:
                for _r in _rs.tiles:
                    gather_refs[_r] = _rs.uid
        gstreamed: Dict[TileRef, np.ndarray] = {}
        gather_t_first: List[Optional[float]] = [None]
        t_exec0 = time.perf_counter()
        #: remaining XFER requests to poison (ChaosEvent.drop_xfer)
        chaos_drop = [0]
        spec_pending: Dict[int, int] = {}        # speculative node per tid
        #: (node, ref) slots whose segment was evicted to the spill tier:
        #: the binding stays in ``avail`` (the VALUE is still secured by
        #: that node) but cannot serve as an XFER source until the master
        #: faults it back in
        spilled: Set[Tuple[int, TileRef]] = set()
        fault_pending: Set[Tuple[int, TileRef]] = set()
        #: retention acks in flight: (hid, i, j) -> (root uid, ref) — the
        #: worker's retain may fault the tile in from spill (fresh segment
        #: name), so the session store is only updated from the ack
        pending_retain: Dict[Tuple[int, int, int], Tuple[int, TileRef]] = {}
        ready: Set[int] = {t.tid for t in g.sources()}
        #: the sweep is O(tasks), so its cadence scales with graph size:
        #: at most ~8 periodic sweeps per run (replans add their own) —
        #: peak arena memory traded against master-loop dispatch latency
        gc_every = max(self.gc_interval, len(g) // 8)
        #: dispatched-not-done instances per node: dispatch is LATE-BOUND
        #: (a node's queue holds at most ~2x its slots) so most of the
        #: graph stays in the replannable frontier — a flooded queue
        #: would pin work to a node the moment it became ready and leave
        #: a joining node nothing to take over
        inflight: Dict[int, int] = defaultdict(int)

        def depth_cap(node: int) -> int:
            return 2 * max(1, cur_spec.workers_at(node)) + 1
        fired = [False] * len(self.chaos)
        # unified metrics registry (replaces the ad-hoc defaultdict):
        # inc() is the atomic increment path, frozen_view() the read-only
        # dict the stats consumers have always read
        cnt = MetricsRegistry()
        for _k in ("deaths", "joins", "respawns", "straggles",
                   "recoveries", "replans", "recovered_tasks",
                   "speculated", "spec_wins", "dup_done", "xfers",
                   "xfer_bytes", "wire_bytes", "xfers_compressed",
                   "relay_hops", "leases", "leases_released_on_death",
                   "xfer_retries", "task_retries", "chaos_dropped_xfers",
                   "gather_streamed_tiles", "squeezes", "tiles_lost",
                   "frees", "dup_errors", "alloc_fails_armed"):
            cnt.inc(_k, 0)
        # flight recorder: master tracer (node -1) + the persistent
        # per-incarnation clock offsets the cal handshake maintains
        tracer = Tracer(node=-1, enabled=self.trace)
        offsets = self._clock_offsets
        recovery_seconds = [0.0]
        total = len(g)

        if self.session and self._started:
            ms = self._ms
            # hand every surviving worker the new run's context; drain
            # idle-period heartbeats so they don't count as progress
            for n in ms.alive_nodes():
                if self._inqs.get(n) is not None:
                    self._inqs[n].put(self._run_msg)
            for n in ms.alive_nodes():
                q = self._outqs.get(n)
                while q is not None:
                    try:
                        msg = q.get_nowait()
                    except _queue.Empty:
                        break
                    if msg[0] == "hb":
                        ms.heartbeat(msg[1])
        else:
            ms = MembershipService(range(spec.n_nodes), master=master,
                                   cfg=self._mcfg)
            # start the resource tracker BEFORE forking workers so every
            # worker shares this process's tracker: a SIGKILLed worker's
            # segment registrations then land where the master's
            # post-mortem unregister (see _reap_segments) can retract them
            # — otherwise each worker lazily spawns its own tracker, which
            # outlives the kill and warns about "leaked" segments the
            # master already reaped
            from multiprocessing import resource_tracker
            resource_tracker.ensure_running()
            self._procs: Dict[int, object] = {}
            self._inqs: Dict[int, object] = {}
            self._outqs: Dict[int, object] = {}
            for n in range(spec.n_nodes):
                self._spawn(n, self.workers_per_node or spec.workers_at(n),
                            spec.mem_at(n))
            if self.straggler_priors:
                # a previous run's drift report flagged these nodes slow:
                # arm the detector so one confirming sweep fires STRAGGLE
                ms.seed_straggler_priors(self.straggler_priors)
            self._ms = ms
            self._started = True

        # -- control-plane actions ------------------------------------------
        def alive(n: int) -> bool:
            return ms.is_alive(n)

        def pick_holder(version: int, ref: TileRef) -> Optional[int]:
            """Deterministic live holder of this tile version whose copy
            is safe to read (no in-progress write on that arena slot and
            not currently evicted to the spill tier).  Among candidates
            the least-read one wins, so wide fan-outs spread over landed
            copies — the dynamic half of the relay tree."""
            best = None
            for k in ms.alive_nodes():
                ent = avail.get((k, ref))
                if ent is not None and ent[0] == version \
                        and (k, ref) not in write_busy \
                        and (k, ref) not in spilled:
                    load = src_busy.get((k, ref), 0)
                    if best is None or load < best[0]:
                        best = (load, k)
            return None if best is None else best[1]

        def wire_codec_for(nbytes: int, src_n: int, dst_n: int) -> str:
            """Per-edge codec choice: forced by ``wire_codec``, else
            priced against the TimeModel's fitted compression terms
            (raw unless the model predicts encode + smaller-payload
            transfer beats the raw transfer on this link)."""
            if src_n == dst_n:
                return "raw"
            if self.wire_codec is not None:
                return self.wire_codec
            return choose_wire_codec(
                nbytes, cur_spec.bandwidth(src_n, dst_n), tm)

        def release_pin(holder: int, ref: TileRef, codec: str) -> None:
            """Drop one worker-side lease pin (hold or staged pack)."""
            if ms.is_alive(holder) and self._inqs.get(holder) is not None:
                self._inqs[holder].put(("release", ref) if codec == "raw"
                                       else ("unpack", ref))

        def dispatch_leased(holder: int, ref: TileRef, ver: int,
                            dstn: int, codec: str, sname: str, sdt: str,
                            comp_nbytes: int, raw_crc) -> None:
            """The holder acked one lease pin: forward the XFER to its
            consumer — or release the pin right away if the consumer
            departed (or was re-routed) while the ack was in flight.
            That immediate release is the mid-copy-death fix: a dead
            consumer must never strand a source pin on a bounded arena."""
            if not alive(dstn) \
                    or xfer_inflight.get((dstn, ref)) != (ver, holder):
                release_pin(holder, ref, codec)
                cnt.inc("leases_released_on_death")
                return
            if chaos_drop[0] > 0:
                chaos_drop[0] -= 1
                cnt.inc("chaos_dropped_xfers")
                sname = f"{self._prefix}chaos_dropped"
            leases[(dstn, ref)] = (holder, codec)
            if codec == "raw":
                cnt.inc("wire_bytes", ref.bytes)
                self._inqs[dstn].put(("xfer", ver, ref, sname, sdt))
            else:
                cnt.inc("wire_bytes", comp_nbytes)
                cnt.inc("xfers_compressed")
                self._inqs[dstn].put(("xfer", ver, ref, sname, sdt,
                                      codec, comp_nbytes, raw_crc))

        def fail_pending_lease(n: int, ref: TileRef,
                               bump_retries: bool) -> None:
            """The holder cannot serve (hold_fail / tile_lost / death):
            un-book every waiting consumer so the dispatch scan re-routes
            them — no xfer_fail will ever arrive for these."""
            for (ver, dstn, _c) in pending_lease.pop((n, ref), []):
                write_busy.discard((dstn, ref))
                ent = xfer_inflight.get((dstn, ref))
                if ent is not None and ent[1] == n:
                    del xfer_inflight[(dstn, ref)]
                if src_busy.get((n, ref), 0) > 0:
                    src_busy[(n, ref)] -= 1
                if bump_retries:
                    xfer_retries[(ver, dstn)] += 1
                    tries = xfer_retries[(ver, dstn)]
                    cnt.inc("xfer_retries")
                    if tries > self._mcfg.xfer_max_retries:
                        raise MemoryBudgetExceeded(
                            n, 0, cur_spec.mem_at(n) or 0,
                            msg=f"node {n} could not pin {ref} for an "
                                f"XFER lease after {tries} attempts "
                                f"(arena too tight to hold the source)")
                    xfer_retry_at[(dstn, ref)] = time.monotonic() + min(
                        self._mcfg.retry_backoff_s * (2 ** (tries - 1)),
                        2.0)

        def request_fault(n: int, ref: TileRef) -> None:
            """Ask node ``n`` to fault a spilled tile back into its hot
            tier; the ``unspill`` ack restores the fresh segment name."""
            if (n, ref) not in fault_pending \
                    and self._inqs.get(n) is not None:
                fault_pending.add((n, ref))
                self._inqs[n].put(("fault", ref))

        def value_secured(v: int) -> bool:
            """Is canonical version ``v`` guaranteed to (re)appear without
            intervention?  Bound in a live arena (even mid-write), being
            produced by a live in-flight instance, or owed by a group
            member that has not run yet."""
            ref = g.tasks[v].out
            if ref is None:
                return True
            if any(avail.get((k, ref), (None,))[0] == v
                   for k in ms.alive_nodes()):
                return True
            for m in vgroup.get(v, (v,)):
                if m not in completed:
                    return True
                if any(alive(k) for k in dispatched[m]):
                    return True
            return False

        def try_dispatch(tid: int, node: int,
                         prefetch_only: bool = False) -> bool:
            """Dispatch one instance of ``tid`` on ``node`` if its inputs
            are bound there; otherwise request the missing XFERs.  Every
            write to a (node, ref) arena slot is exclusive.
            ``prefetch_only`` stages inputs without dispatching (used for
            tasks beyond the node's in-flight depth cap)."""
            t = g.tasks[tid]
            waiting = False
            for (ref, p) in needs[tid]:
                ent = avail.get((node, ref))
                if ent is not None and ent[0] == p:
                    continue
                waiting = True
                if (node, ref) in write_busy:
                    continue                  # a write is already in flight
                if time.monotonic() < xfer_retry_at.get((node, ref), 0.0):
                    continue                  # backing off a failed XFER
                holder = pick_holder(p, ref)
                if holder is None or holder == node:
                    if not value_secured(p):
                        # value lost outside a death event (defensive):
                        # recover it through the normal lineage path.
                        # (a merely write-busy holder is NOT lost — the
                        # copy becomes readable when its write completes)
                        replan({p})
                        return False
                    if holder is None:
                        # every live copy may be cold in the spill tier —
                        # fault one back in so a later scan can route it
                        for k in ms.alive_nodes():
                            e2 = avail.get((k, ref))
                            if e2 is not None and e2[0] == p \
                                    and (k, ref) in spilled:
                                request_fault(k, ref)
                                break
                    continue                  # value not yet obtainable
                if src_busy.get((holder, ref), 0) >= relay_cap:
                    # relay fan-out cap: every landed copy becomes a
                    # source, so deferring here turns an N-wide unicast
                    # burst into a tree that widens each scan
                    continue
                codec = wire_codec_for(ref.bytes, holder, node)
                if exec_nodes.get(p) not in (None, holder):
                    cnt.inc("relay_hops")
                if codec != "raw" or cur_spec.mem_at(holder) is not None:
                    # leased path: the holder pins the source (and, when
                    # compressed, stages the encoded payload) before the
                    # consumer is told where to copy from — a bounded
                    # arena can then never evict it mid-copy
                    pending_lease[(holder, ref)].append((p, node, codec))
                    self._inqs[holder].put(
                        ("pack", ref, codec) if codec != "raw"
                        else ("hold", ref))
                    cnt.inc("leases")
                else:
                    sname = avail[(holder, ref)][1]
                    sdt = avail[(holder, ref)][2]
                    if chaos_drop[0] > 0:
                        # fault injection: poison the request's source
                        # segment so the destination worker reports
                        # xfer_fail and the bounded-backoff retry
                        # re-issues it for real
                        chaos_drop[0] -= 1
                        cnt.inc("chaos_dropped_xfers")
                        sname = f"{self._prefix}chaos_dropped"
                    self._inqs[node].put(("xfer", p, ref, sname, sdt))
                    cnt.inc("wire_bytes", ref.bytes)
                write_busy.add((node, ref))
                xfer_inflight[(node, ref)] = (p, holder)
                src_busy[(holder, ref)] += 1
                cnt.inc("xfers")
                cnt.inc("xfer_bytes", ref.bytes)
            if waiting or prefetch_only:
                return False
            if t.out is not None:
                if (node, t.out) in write_busy:
                    return False
                if t.kind in _CHAIN_KINDS and \
                        src_busy.get((node, t.out), 0) > 0:
                    return False              # an XFER is reading the chain
                write_busy.add((node, t.out))
            self._inqs[node].put(("task", tid))
            dispatched[tid].add(node)
            inflight[node] += 1
            return True

        def scan_dispatch() -> None:
            for tid in sorted(ready):
                if tid in completed or dispatched[tid]:
                    ready.discard(tid)        # an instance beat us to it
                    continue
                node = assigned[tid]
                if not alive(node):
                    continue                  # replan is imminent
                if time.monotonic() < task_retry_at.get(tid, 0.0):
                    continue                  # backing off a failed dispatch
                over = inflight[node] >= depth_cap(node)
                if try_dispatch(tid, node, prefetch_only=over):
                    ready.discard(tid)
            for tid in sorted(spec_pending):
                node = spec_pending[tid]
                if tid in completed or not alive(node):
                    spec_pending.pop(tid, None)
                    continue
                if node in dispatched[tid]:
                    continue
                if inflight[node] >= depth_cap(node):
                    continue
                if try_dispatch(tid, node):
                    cnt.inc("speculated")

        def run_gc() -> None:
            """Mark-and-sweep over arena bindings: a (node, ref) binding
            stays only while some not-completed task still needs that
            version, a write/XFER is touching it, or it backs an
            ungathered result tile.  Lineage makes over-freeing safe but
            expensive — this never frees a value the current plan reads."""
            if not self.free_buffers:
                return
            live_nodes = set(ms.alive_nodes())
            keep: Set[Tuple[int, TileRef]] = set(write_busy)
            for (dst, ref), (_v, src) in xfer_inflight.items():
                keep.add((dst, ref))
                keep.add((src, ref))
            for t in g:
                # a completed task may still have a LOSING duplicate
                # instance in flight (first-writer-wins): its inputs at
                # that node must survive until the instance reports, or
                # the worker's arena lookup explodes mid-execution
                if t.tid in completed and not dispatched[t.tid]:
                    continue
                for (ref, p) in needs[t.tid]:
                    for k in live_nodes:
                        ent = avail.get((k, ref))
                        if ent is not None and ent[0] == p:
                            keep.add((k, ref))
                for n in dispatched[t.tid]:
                    if t.out is not None:
                        keep.add((n, t.out))
            # gather holds must cover EVERY gathered root of a multi-root
            # program (g.result_tiles is only the first one)
            for rs_ in rsets:
                if not rs_.gather:
                    continue
                for r in rs_.tiles:
                    for k in live_nodes:
                        if (k, r) in avail:
                            keep.add((k, r))
            for r in retained_refs:
                # persisted outputs: every live copy survives until the
                # end-of-run retention picks its home
                for k in live_nodes:
                    if (k, r) in avail:
                        keep.add((k, r))
            for key in [k for k in avail if k not in keep]:
                n, ref = key
                del avail[key]
                if alive(n):
                    self._inqs[n].put(("free", ref))
                    cnt.inc("frees")

        def replan(resurrect_seed: Set[int] = frozenset()) -> None:
            """Resurrection closure + incremental frontier re-plan —
            the observe->re-plan half of the loop, run on every
            membership event (and on a detected lost value)."""
            t0 = time.perf_counter()
            resurrected: Set[int] = set()

            def ensure(v: int) -> None:
                """Canonical version ``v`` must be obtainable: if every
                producer ran and no live copy/instance remains, the
                canonical producer is resurrected — and its own inputs
                secured transitively (the lineage closure)."""
                if v in resurrected or value_secured(v):
                    return
                completed.discard(v)
                resurrected.add(v)
                for (_ref, q) in needs[v]:
                    ensure(q)

            for v in sorted(resurrect_seed):
                ensure(v)
            for tid in [t.tid for t in g if t.tid not in completed]:
                for (_ref, p) in needs[tid]:
                    ensure(p)
            cnt.inc("recovered_tasks", len(resurrected))

            for tid in g.tasks:
                if tid not in completed:
                    deps_left[tid] = sum(1 for p in g.tasks[tid].preds
                                         if p not in completed)
            live_disp = {tid for tid, insts in dispatched.items()
                         if tid not in completed
                         and any(alive(k) for k in insts)}
            frontier = [tid for tid in g.tasks
                        if tid not in completed and tid not in live_disp]
            done_pl: Dict[int, Placement] = {}
            for tid in g.tasks:
                if tid in completed or tid in live_disp:
                    p = cur_place[tid]
                    out = g.tasks[tid].out
                    if tid in completed and out is not None \
                            and not alive(p.node):
                        holder = pick_holder(canon_of(tid), out)
                        if holder is not None:
                            p = Placement(holder, 0, p.start, p.finish)
                    done_pl[tid] = p
            if frontier:
                new_sched = replan_frontier(
                    g, cur_spec, tm, done_pl, frontier,
                    fill_origin=origin, cost=CostCache(tm, cur_spec),
                    pinned=resident_pins or None)
                for tid in frontier:
                    cur_place[tid] = new_sched.placements[tid]
                    assigned[tid] = new_sched.placements[tid].node
            ready.clear()
            ready.update(tid for tid in frontier if deps_left[tid] == 0)
            cnt.inc("replans")
            run_gc()
            recovery_seconds[0] += time.perf_counter() - t0
            if self.trace:
                tracer.add(Span("REPLAN", "REPLAN", -1, 0, t0,
                                time.perf_counter() - t0,
                                {"resurrected": len(resurrected),
                                 "frontier": len(frontier)}))

        def on_death(n: int) -> None:
            nonlocal cur_spec
            cnt.inc("deaths")
            survivors = ms.alive_nodes()
            if not self.respawn_dead and \
                    len(survivors) < self._mcfg.min_nodes:
                raise RuntimeError(
                    f"node {n} died leaving {len(survivors)} node(s), "
                    f"below the configured floor "
                    f"min_nodes={self._mcfg.min_nodes}; aborting the run")
            proc = self._procs.get(n)
            if proc is not None and proc.is_alive():
                proc.terminate()
            # the master's view of node n is gone: arena bindings, write
            # locks, transfers to/from it
            for key in [k for k in avail if k[0] == n]:
                del avail[key]
            for key in [k for k in write_busy if k[0] == n]:
                write_busy.discard(key)
            for key in [k for k in src_busy if k[0] == n]:
                del src_busy[key]
            for (dst, ref) in list(xfer_inflight):
                ver, src = xfer_inflight[(dst, ref)]
                if dst == n:
                    del xfer_inflight[(dst, ref)]
                    if (src, ref) in src_busy:
                        src_busy[(src, ref)] -= 1
                # src == n: the destination worker reports xfer_fail and
                # the retry path re-routes from a surviving holder
            # the dead consumer's leased XFERs will never ack: release
            # their source pins NOW or the holders' bounded arenas keep
            # the tiles unevictable forever (the mid-copy-death leak)
            for (dst, ref) in [k for k in leases if k[0] == n]:
                holder, codec = leases.pop((dst, ref))
                if alive(holder):
                    release_pin(holder, ref, codec)
                    cnt.inc("leases_released_on_death")
            for key in [k for k in leases if leases[k][0] == n]:
                del leases[key]   # holder died: its pins died with it
            # pending leases ON the dead holder get no ack and no
            # xfer_fail — un-book their waiters so the scan re-routes
            for (hn, ref) in [k for k in pending_lease if k[0] == n]:
                fail_pending_lease(hn, ref, bump_retries=False)
            for tid in list(dispatched):
                dispatched[tid].discard(n)
            inflight[n] = 0
            for tid in [t for t, k in spec_pending.items() if k == n]:
                del spec_pending[tid]
            for key in [k for k in spilled if k[0] == n]:
                spilled.discard(key)
            for key in [k for k in fault_pending if k[0] == n]:
                fault_pending.discard(key)
            # the dead node's failure episodes end with it: drop its
            # retry counts/backoffs (no future attempt targets it)
            for key in [k for k in xfer_retries if k[1] == n]:
                del xfer_retries[key]
            for key in [k for k in xfer_retry_at if k[0] == n]:
                del xfer_retry_at[key]
            self._reap_segments(n)
            self._procs[n] = None
            self._inqs[n] = None
            self._outqs[n] = None
            if self.respawn_dead:
                self._spawn(n, self.workers_per_node
                            or cur_spec.workers_at(n), cur_spec.mem_at(n))
                ms.add_node(n)
                cnt.inc("respawns")
            else:
                cur_spec = cur_spec.without_node(n)
            # resident-input tiles homed on the dead node are gone (a
            # respawned worker comes back with an EMPTY arena): they are
            # not recomputable within THIS graph — they are its *inputs* —
            # but they ARE recomputable roots of their own lineage.  Abort
            # the run in an orderly way; the session re-derives the lost
            # handles from lineage and retries (bit-identical, tasks are
            # deterministic).
            if residency is not None:
                lost = {h.hid for h in residency.handles.values()
                        if any(home == n for home in h.home.values())}
                if pending_abort[0] is not None:
                    lost |= set(pending_abort[0].hids)
                if lost:
                    pending_abort[0] = ResidentTilesLost(
                        sorted(lost),
                        f"node {n} died holding resident tiles of "
                        f"handles {sorted(lost)}")
                    return
            replan()

        def on_join(workers: int, slowdown: float) -> None:
            nonlocal cur_spec
            node = cur_spec.n_nodes
            cur_spec = cur_spec.with_node(workers, slowdown)
            base_slowdown[node] = float(slowdown)
            self._spawn(node, self.workers_per_node or workers,
                        cur_spec.mem_at(node))
            ms.add_node(node)
            cnt.inc("joins")
            replan()

        #: each node's un-penalised slowdown, for idempotent straggler
        #: re-pricing (bump to base*factor, restore to base on recovery
        #: — never compound across repeated STRAGGLE events)
        base_slowdown = {n: spec.node_slowdown(n)
                         for n in range(spec.n_nodes)}

        def on_straggle(n: int) -> None:
            nonlocal cur_spec
            cnt.inc("straggles")
            if self.speculate:
                others = [k for k in ms.alive_nodes() if k != n]
                if others:
                    load = {k: sum(1 for s in dispatched.values()
                                   if k in s) for k in others}
                    for tid in sorted(t for t, insts in dispatched.items()
                                      if n in insts and t not in completed):
                        if g.tasks[tid].kind is TaskKind.TAKECOPY:
                            continue          # pinned to the master
                        tgt = min(others, key=lambda k: (load[k], k))
                        spec_pending[tid] = tgt
                        load[tgt] += 1
            # reprice the straggler so the frontier drains away from it
            cur_spec = cur_spec.with_slowdown(
                n, base_slowdown.get(n, 1.0) * self._mcfg.straggler_factor)
            replan()

        def on_recover(n: int) -> None:
            nonlocal cur_spec
            if not alive(n):
                return
            cnt.inc("recoveries")
            cur_spec = cur_spec.with_slowdown(n, base_slowdown.get(n, 1.0))
            replan()

        def fire_chaos() -> None:
            nonlocal cur_spec
            for i, c in enumerate(self.chaos):
                if fired[i] or len(completed) < c.after_done:
                    continue
                if c.kill_node is not None:
                    proc = self._procs.get(c.kill_node)
                    if proc is None or not proc.pid:
                        # target not spawned yet (kill of a node whose
                        # join has not fired) — stay armed, retry on the
                        # next completion instead of dropping the kill
                        continue
                fired[i] = True
                if c.kill_node is not None:
                    proc = self._procs.get(c.kill_node)
                    if proc is not None and proc.pid:
                        os.kill(proc.pid, signal.SIGKILL)
                if c.throttle_node is not None \
                        and alive(c.throttle_node):
                    self._inqs[c.throttle_node].put(
                        ("throttle", c.throttle_seconds))
                if c.join_workers is not None:
                    on_join(c.join_workers, c.join_slowdown)
                if c.flag_straggler is not None \
                        and alive(c.flag_straggler):
                    on_straggle(c.flag_straggler)
                if c.drop_xfer is not None:
                    # poison the source segment name of the next N
                    # cross-node transfers: the destination worker fails
                    # to attach, reports xfer_fail, and the bounded
                    # retry path re-requests the tile for real
                    chaos_drop[0] += int(c.drop_xfer)
                if c.mem_squeeze is not None and alive(c.mem_squeeze):
                    # shrink the node's arena budget mid-run: the worker
                    # evicts down to it; the spec change flows to the
                    # session's next plan via current_spec
                    self._inqs[c.mem_squeeze].put(
                        ("squeeze", int(c.squeeze_bytes)))
                    cur_spec = cur_spec.with_mem(
                        c.mem_squeeze, float(c.squeeze_bytes))
                    cnt.inc("squeezes")
                if c.alloc_fail is not None and alive(c.alloc_fail):
                    self._inqs[c.alloc_fail].put(
                        ("alloc_fail", int(c.alloc_fail_nth)))
                    cnt.inc("alloc_fails_armed")
                if c.corrupt_tile is not None:
                    self.corrupt_tile_hook(c.corrupt_tile)
                if c.kill_master:
                    # full-cluster crash: SIGKILL every worker FIRST
                    # (they are daemonic children — a parent SIGKILL
                    # alone leaves them running), then the master
                    # itself; nothing gets to flush or clean up, which
                    # is exactly the failure durable sessions recover
                    for proc in self._procs.values():
                        if proc is not None and proc.pid \
                                and proc.is_alive():
                            os.kill(proc.pid, signal.SIGKILL)
                    os.kill(os.getpid(), signal.SIGKILL)

        def handle(msg) -> bool:
            """Process one worker message; returns True when it counts
            as forward progress (heartbeats do NOT — a wedged run with
            idle-but-alive workers must still trip the stall watchdog)."""
            kind = msg[0]
            if kind == "done":
                _, n, tid, seg, dt, pid, dur, *_rest = msg
                if len(_rest) > 1:
                    tracer.ingest(_rest[1], offsets.get(n, 0.0))
                cnt.observe("task_seconds", dur)
                ms.record_task(n, dur)
                node_pids[n] = pid
                t = g.tasks[tid]
                if t.out is not None:
                    write_busy.discard((n, t.out))
                    if seg is not None:
                        avail[(n, t.out)] = (canon_of(tid), seg, dt)
                dispatched[tid].discard(n)
                inflight[n] -= 1
                if tid in completed:
                    cnt.inc("dup_done")      # first-writer-wins: a late
                    return True               # duplicate only adds a copy
                completed.add(tid)
                exec_nodes[tid] = n
                # a successful completion ends this task's failure
                # episode: reset its retry budget so a LATER unrelated
                # fault gets the full allowance again
                task_retries.pop(tid, None)
                task_retry_at.pop(tid, None)
                if t.kind is TaskKind.TAKECOPY and n == master \
                        and seg is not None and t.out in gather_refs \
                        and t.out not in gstreamed and self.stream_gather \
                        and cur_spec.mem_at(master) is None \
                        and (master, t.out) not in spilled:
                    # streamed gather: assemble the result while the rest
                    # of the run still computes (unbounded master arena
                    # only — a bounded one could evict mid-attach)
                    try:
                        sh = _attach_shm(seg)
                        try:
                            view = np.ndarray(t.out.shape,
                                              dtype=np.dtype(dt),
                                              buffer=sh.buf)
                            gstreamed[t.out] = view.copy()
                        finally:
                            sh.close()
                        cnt.inc("gather_streamed_tiles")
                        if gather_t_first[0] is None:
                            gather_t_first[0] = \
                                time.perf_counter() - t_exec0
                    except FileNotFoundError:  # pragma: no cover — the
                        pass                   # barrier pass still runs
                if spec_pending.pop(tid, None) == n:
                    cnt.inc("spec_wins")
                for s in sorted(t.succs):
                    deps_left[s] -= 1
                    if deps_left[s] == 0 and s not in completed \
                            and not dispatched[s]:
                        ready.add(s)
                if len(completed) % gc_every == 0:
                    run_gc()
                fire_chaos()
            elif kind == "xfer_done":
                _, n, version, ref, seg, dt, *_rest = msg
                if len(_rest) > 1:
                    tracer.ingest(_rest[1], offsets.get(n, 0.0))
                write_busy.discard((n, ref))
                ent = xfer_inflight.pop((n, ref), None)
                if ent is not None and (ent[1], ref) in src_busy:
                    src_busy[(ent[1], ref)] -= 1
                lease = leases.pop((n, ref), None)
                if lease is not None:
                    release_pin(lease[0], ref, lease[1])
                # the copy landed: close this edge's failure episode so
                # the NEXT fault on it starts from a fresh retry budget
                xfer_retries.pop((version, n), None)
                xfer_retry_at.pop((n, ref), None)
                avail[(n, ref)] = (version, seg, dt)
            elif kind == "xfer_fail":
                _, n, version, ref, tb = msg
                write_busy.discard((n, ref))
                ent = xfer_inflight.pop((n, ref), None)
                if ent is not None and (ent[1], ref) in src_busy:
                    src_busy[(ent[1], ref)] -= 1
                lease = leases.pop((n, ref), None)
                if lease is not None:
                    # drop the pin BEFORE the retry re-requests: the
                    # redispatch takes a fresh lease (possibly from a
                    # different holder), so the old one must not linger
                    release_pin(lease[0], ref, lease[1])
                xfer_retries[(version, n)] += 1
                tries = xfer_retries[(version, n)]
                cnt.inc("xfer_retries")
                if tries > self._mcfg.xfer_max_retries:
                    if "ArenaOverflow" in tb:
                        raise MemoryBudgetExceeded(
                            n, 0, cur_spec.mem_at(n) or 0,
                            msg=f"node {n} arena overflow receiving XFER "
                                f"of {ref} after {tries} attempts:\n{tb}")
                    raise RuntimeError(
                        f"XFER of {ref} (version {version}) to node {n} "
                        f"failed {tries} times (xfer_max_retries="
                        f"{self._mcfg.xfer_max_retries}):\n{tb}")
                # bounded exponential backoff before the dispatch scan
                # re-requests the tile — from ANY live holder, so a
                # vanished or corrupted source re-routes instead of
                # hammering the same copy
                xfer_retry_at[(n, ref)] = time.monotonic() + min(
                    self._mcfg.retry_backoff_s * (2 ** (tries - 1)), 2.0)
            elif kind == "held":
                # one lease pin granted (the hold may have faulted the
                # tile hot under a fresh segment name — rebind it)
                _, n, ref, seg, dt, *_rest = msg
                ent0 = avail.get((n, ref))
                if ent0 is not None:
                    avail[(n, ref)] = (ent0[0], seg, dt)
                spilled.discard((n, ref))
                entries = pending_lease.get((n, ref))
                if entries:
                    ver, dstn, _c = entries.pop(0)
                    if not entries:
                        del pending_lease[(n, ref)]
                    dispatch_leased(n, ref, ver, dstn, "raw", seg, dt,
                                    0, None)
                else:
                    # every waiter failed over while this ack was in
                    # flight — the pin has no consumer, drop it
                    release_pin(n, ref, "raw")
            elif kind == "packed":
                _, n, ref, sname, sdt, codec, comp_nbytes, raw_crc = msg
                entries = pending_lease.get((n, ref))
                if entries:
                    ver, dstn, _c = entries.pop(0)
                    if not entries:
                        del pending_lease[(n, ref)]
                    dispatch_leased(n, ref, ver, dstn, codec, sname, sdt,
                                    comp_nbytes, raw_crc)
                else:
                    release_pin(n, ref, codec)
            elif kind == "hold_fail":
                # the holder's arena is too tight to pin the source right
                # now (no pin was taken): back the waiters off and let
                # the dispatch scan re-route them
                _, n, ref = msg
                fail_pending_lease(n, ref, bump_retries=True)
            elif kind == "spill":
                spilled.add((msg[1], msg[2]))
            elif kind == "unspill":
                _, n, ref, sname, dt, *_rest = msg
                ent = avail.get((n, ref))
                if ent is not None:
                    # the fault-in rebinds under a fresh segment name;
                    # the version is unchanged (spill is bit-copying)
                    avail[(n, ref)] = (ent[0], sname, dt)
                spilled.discard((n, ref))
                fault_pending.discard((n, ref))
            elif kind == "tile_lost":
                # a spill-tier miss or CRC failure destroyed this copy;
                # degrade to lineage recompute instead of failing the run
                _, n, ref, tb = msg
                spilled.discard((n, ref))
                fault_pending.discard((n, ref))
                fail_pending_lease(n, ref, bump_retries=False)
                ent = avail.pop((n, ref), None)
                cnt.inc("tiles_lost")
                if ent is not None and not value_secured(ent[0]):
                    replan({ent[0]})
            elif kind == "retained":
                _, n, key, sname, dt = msg
                ent = pending_retain.pop(key, None)
                if ent is not None and residency is not None:
                    uid, r = ent
                    residency.retain_seg(uid, r.i, r.j, n, sname, dt)
            elif kind == "hb":
                ms.heartbeat(msg[1])
                node_pids.setdefault(msg[1], msg[2])
                if len(msg) > 3:
                    # idle-period span flush piggybacked on the heartbeat
                    tracer.ingest(msg[3], offsets.get(msg[1], 0.0))
                return False
            elif kind == "cal":
                # worker clock echo: NTP-style midpoint offset, mapping
                # that incarnation's span timestamps onto the master clock
                offsets[msg[1]] = estimate_clock_offset(
                    msg[2], msg[3], time.perf_counter())
                return False
            elif kind == "error":
                if msg[2] in completed:
                    # a losing duplicate instance crashed after the
                    # winner already produced the value — the run does
                    # not depend on it
                    lt = g.tasks[msg[2]]
                    if lt.out is not None:
                        write_busy.discard((msg[1], lt.out))
                    dispatched[msg[2]].discard(msg[1])
                    inflight[msg[1]] -= 1
                    cnt.inc("dup_errors")
                    return True
                tid = msg[2]
                t = g.tasks.get(tid)
                task_retries[tid] += 1
                tries = task_retries[tid]
                # in-place accumulate chains (ADDMUL/...) mutate their
                # output buffer as they run: a crashed instance may have
                # landed a partial update, so blindly re-running would
                # double-accumulate — those stay fatal; pure tasks are
                # retried with bounded exponential backoff.  SpillDataLost
                # and ArenaOverflow are the chain-safe exceptions: both
                # can only be raised while *fetching/allocating* inputs,
                # strictly before the in-place update touches the output
                # buffer (an overflow is often transient — concurrent
                # tasks' pinned inputs drain — so it retries too)
                retryable = t is not None and (
                    t.kind not in _CHAIN_KINDS
                    or "SpillDataLost" in msg[3]
                    or "ArenaOverflow" in msg[3])
                if not retryable or tries > self._mcfg.task_max_retries:
                    if "ArenaOverflow" in msg[3]:
                        # nothing left to evict under the budget even
                        # after backoff: structured failure naming the
                        # node, never an OOM kill
                        raise MemoryBudgetExceeded(
                            msg[1], 0, cur_spec.mem_at(msg[1]) or 0,
                            msg=f"node {msg[1]} arena overflow (budget "
                                f"{cur_spec.mem_at(msg[1])} bytes, "
                                f"nothing left to evict) running task "
                                f"{tid}, attempt {tries}:\n{msg[3]}")
                    raise RuntimeError(
                        f"elastic task failed on node {msg[1]} "
                        f"(task {tid}, attempt {tries}):\n{msg[3]}")
                if t.out is not None:
                    write_busy.discard((msg[1], t.out))
                dispatched[tid].discard(msg[1])
                inflight[msg[1]] -= 1
                cnt.inc("task_retries")
                task_retry_at[tid] = time.monotonic() + min(
                    self._mcfg.retry_backoff_s * (2 ** (tries - 1)), 2.0)
                if deps_left[tid] == 0 and not dispatched[tid]:
                    ready.add(tid)
            elif kind == "stats":
                self._node_stats[msg[1]] = msg[2]
                if len(msg) > 4:
                    tracer.ingest(msg[4], offsets.get(msg[1], 0.0))
            return True

        # -- master event loop ----------------------------------------------
        self._node_stats: Dict[int, Dict[str, int]] = {}
        last_progress = time.monotonic()

        def wait_for_events(timeout: float) -> None:
            """Block on the live workers' queue pipes (not a sleep poll —
            a timer-sleeping master loses its sleeper credit and gets
            starved for 100ms+ once workers oversubscribe the host,
            which turns every dispatch round trip into idle worker
            time).  Falls back to a short sleep if the queue internals
            are unavailable."""
            conns = []
            for n in ms.alive_nodes():
                q = self._outqs.get(n)
                r = getattr(q, "_reader", None) if q is not None else None
                if r is not None:
                    conns.append(r)
            if not conns:
                time.sleep(0.002)
                return
            try:
                from multiprocessing.connection import wait as conn_wait
                conn_wait(conns, timeout)
            except OSError:             # pragma: no cover — racing a death
                time.sleep(0.002)

        def abandon_run() -> None:
            """Orderly abort for a resident-tile loss: drain in-flight
            worker activity (so stale `done` messages can't corrupt the
            session's NEXT run), release this run's arena bindings, then
            raise — workers stay alive for the retry."""
            exc = pending_abort[0]
            deadline = time.monotonic() + min(self.timeout, 30.0)
            while (sum(inflight[k] for k in ms.alive_nodes())
                   or any(k[0] in ms.alive_nodes()
                          for k in xfer_inflight)) \
                    and time.monotonic() < deadline:
                moved = False
                for n in list(ms.alive_nodes()):
                    q = self._outqs.get(n)
                    if q is None:
                        continue
                    try:
                        msg = q.get_nowait()
                    except _queue.Empty:
                        continue
                    moved = True
                    k = msg[0]
                    if k == "done":
                        t = g.tasks[msg[2]]
                        if t.out is not None and msg[3] is not None:
                            avail[(msg[1], t.out)] = \
                                (canon_of(msg[2]), msg[3], msg[4])
                        dispatched[msg[2]].discard(msg[1])
                        inflight[msg[1]] -= 1
                    elif k in ("xfer_done", "xfer_fail"):
                        xfer_inflight.pop((msg[1], msg[3]), None)
                        lease = leases.pop((msg[1], msg[3]), None)
                        if lease is not None:
                            release_pin(lease[0], msg[3], lease[1])
                        if k == "xfer_done":
                            avail[(msg[1], msg[3])] = \
                                (msg[2], msg[4], msg[5])
                    elif k in ("held", "packed"):
                        # aborting: each ack is one pin — drop it and
                        # un-book its waiters (the retry run takes fresh
                        # leases of its own)
                        fail_pending_lease(msg[1], msg[2],
                                           bump_retries=False)
                        release_pin(msg[1], msg[2],
                                    "raw" if k == "held" else msg[5])
                    elif k == "hb":
                        ms.heartbeat(msg[1])
                    elif k == "error":
                        t = g.tasks[msg[2]] if msg[2] in g.tasks else None
                        dispatched[msg[2]].discard(msg[1])
                        inflight[msg[1]] -= 1
                if not moved:
                    liveness = {n: self._procs[n].is_alive()
                                for n in ms.alive_nodes()
                                if self._procs.get(n) is not None}
                    for ev in ms.poll(liveness):
                        if ev.kind == DEATH:
                            inflight[ev.node] = 0
                            for (dst, ref) in list(xfer_inflight):
                                if dst == ev.node:
                                    del xfer_inflight[(dst, ref)]
                            for key in [key for key in avail
                                        if key[0] == ev.node]:
                                del avail[key]
                    wait_for_events(0.02)
            # any lease still open past the drain deadline must not
            # outlive this run (workers survive for the session retry)
            for (_dstn, ref), (holder, codec) in list(leases.items()):
                release_pin(holder, ref, codec)
            leases.clear()
            if self.free_buffers:
                for (n, ref) in list(avail):
                    del avail[(n, ref)]
                    if ms.is_alive(n) and self._inqs.get(n) is not None:
                        self._inqs[n].put(("free", ref))
            raise exc

        try:
            fire_chaos()                      # after_done=0 chaos
            scan_dispatch()
            while len(completed) < total:
                processed = 0
                for n in list(ms.alive_nodes()):
                    q = self._outqs.get(n)
                    if q is None:
                        continue
                    for _ in range(256):
                        try:
                            msg = q.get_nowait()
                        except _queue.Empty:
                            break
                        if handle(msg):
                            processed += 1
                liveness = {n: self._procs[n].is_alive()
                            for n in ms.alive_nodes()
                            if self._procs.get(n) is not None}
                for ev in ms.poll(liveness):
                    processed += 1
                    if ev.kind == DEATH:
                        on_death(ev.node)
                    elif ev.kind == STRAGGLE:
                        on_straggle(ev.node)
                    elif ev.kind == RECOVER:
                        on_recover(ev.node)
                if pending_abort[0] is not None:
                    abandon_run()
                scan_dispatch()
                now = time.monotonic()
                if processed:
                    last_progress = now
                elif now - last_progress > self.timeout:
                    raise RuntimeError(
                        f"elastic execution stalled: no progress within "
                        f"timeout={self.timeout}s "
                        f"({len(completed)}/{total} tasks, "
                        f"ready={sorted(ready)[:8]})")
                else:
                    wait_for_events(0.05)

            def pump_until(pred, what: str) -> None:
                """Drain worker messages through ``handle`` until ``pred``
                holds (used post-run: gather fault-ins, retention acks)."""
                deadline = time.monotonic() + min(self.timeout, 30.0)
                while not pred():
                    got = False
                    for n2 in list(ms.alive_nodes()):
                        q2 = self._outqs.get(n2)
                        if q2 is None:
                            continue
                        try:
                            m2 = q2.get_nowait()
                        except _queue.Empty:
                            continue
                        handle(m2)
                        got = True
                    if pred():
                        return
                    if time.monotonic() > deadline:
                        raise RuntimeError(f"timed out waiting for {what}")
                    if not got:
                        wait_for_events(0.02)

            # -- gather result tiles of non-persisted roots -----------------
            outs: List[np.ndarray] = []
            gather_bytes = 0
            gather_span_t0 = time.perf_counter()
            for rs in rsets:
                if not rs.gather:
                    continue
                vals: Dict[TileRef, np.ndarray] = {}
                for r in rs.tiles:
                    streamed = gstreamed.pop(r, None)
                    if streamed is not None:
                        # already copied out when its TAKECOPY landed
                        vals[r] = streamed
                        gather_bytes += r.bytes
                        continue
                    for _attempt in range(5):
                        ent = avail.get((master, r))
                        if ent is None:  # pragma: no cover — takecopy pins
                            raise RuntimeError(f"result tile {r} missing "
                                               f"from the master arena")
                        if (master, r) in spilled:
                            request_fault(master, r)
                            pump_until(
                                lambda: (master, r) not in spilled,
                                f"fault-in of result tile {r}")
                            ent = avail.get((master, r))
                            if ent is None:   # lost + lineage recompute
                                raise RuntimeError(
                                    f"result tile {r} lost from the "
                                    f"spill tier during gather")
                        try:
                            seg = _attach_shm(ent[1])
                        except FileNotFoundError:
                            # evicted between unspill and attach — retry
                            spilled.add((master, r))
                            continue
                        try:
                            view = np.ndarray(r.shape,
                                              dtype=np.dtype(ent[2]),
                                              buffer=seg.buf)
                            vals[r] = view.copy()
                            if gather_t_first[0] is None:
                                gather_t_first[0] = \
                                    time.perf_counter() - t_exec0
                        finally:
                            seg.close()
                        break
                    else:
                        raise RuntimeError(
                            f"could not gather result tile {r}: segment "
                            f"kept vanishing under memory pressure")
                    gather_bytes += r.bytes
                outs.append(assemble(vals, rs.shape, plan.tile, rs.uid))
            gather_t_full = time.perf_counter() - t_exec0
            if self.trace:
                tracer.add(Span("GATHER", "GATHER", -1, 0, gather_span_t0,
                                time.perf_counter() - gather_span_t0,
                                {"bytes": gather_bytes}))

            # -- retention: persisted tiles into the session store ----------
            # a tile's home is wherever its (canonical) value actually
            # lives — under churn that may differ from the planned node.
            # The worker's retain op faults a spilled tile back in (fresh
            # segment name), so the session store is updated from the ack
            retained_count = 0
            for rs in rsets:
                if rs.gather:
                    continue
                h = residency.retain[rs.uid]
                for r in rs.tiles:
                    v = canon_of(rs.producers[r])
                    holder = exec_nodes.get(rs.producers[r])
                    if holder is None or not alive(holder) or \
                            avail.get((holder, r), (None,))[0] != v:
                        holder = next(
                            (k for k in ms.alive_nodes()
                             if avail.get((k, r), (None,))[0] == v), None)
                    if holder is None:  # pragma: no cover — defensive
                        raise RuntimeError(
                            f"retention: no live holder for {r} "
                            f"(version {v})")
                    avail.pop((holder, r))
                    spilled.discard((holder, r))
                    pending_retain[(h.hid, r.i, r.j)] = (rs.uid, r)
                    self._inqs[holder].put(("retain", r,
                                            (h.hid, r.i, r.j)))
                    retained_count += 1
            if pending_retain:
                pump_until(lambda: not pending_retain, "retention acks")

            # -- release every remaining binding before shutdown ------------
            if self.free_buffers:
                for (n, ref) in list(avail):
                    del avail[(n, ref)]
                    if alive(n) and self._inqs.get(n) is not None:
                        self._inqs[n].put(("free", ref))

            # -- orderly shutdown + per-node stats (one-shot mode only) -----
            if not self.session:
                expect = [n for n in ms.alive_nodes()
                          if self._inqs.get(n) is not None]
                for n in expect:
                    self._inqs[n].put(("stop",))
                deadline = time.monotonic() + min(self.timeout, 30.0)
                while len(self._node_stats) < len(expect) \
                        and time.monotonic() < deadline:
                    got = False
                    for n in expect:
                        try:
                            msg = self._outqs[n].get_nowait()
                        except _queue.Empty:
                            continue
                        if msg[0] == "stats":
                            self._node_stats[msg[1]] = msg[2]
                            node_pids.setdefault(msg[1], msg[3])
                            if len(msg) > 4:
                                tracer.ingest(msg[4],
                                              offsets.get(msg[1], 0.0))
                        got = True
                    if not got:
                        time.sleep(0.005)
                for n in expect:
                    p = self._procs.get(n)
                    if p is not None:
                        p.join(timeout=5)
        except ResidentTilesLost:
            # orderly abort: workers (and their retained arenas) survive
            # for the session's lineage recompute + retry
            if not self.session:        # pragma: no cover — defensive
                self._terminate_all()
            raise
        except BaseException:
            self._broken = True
            self._terminate_all()
            shutil.rmtree(self._spill_dir, ignore_errors=True)
            raise
        finally:
            self._cur_spec = cur_spec
            if not self.session or self._broken:
                for p in self._procs.values():
                    if p is not None and p.is_alive():  # pragma: no cover
                        p.terminate()
                        p.join(timeout=5)

        leaked_spill = 0
        if not self.session:
            # after a clean one-shot run every spill file must be gone;
            # leftovers are leaks (counted, then reaped)
            try:
                leaked_spill = len(os.listdir(self._spill_dir))
            except OSError:
                pass
            shutil.rmtree(self._spill_dir, ignore_errors=True)
        # the registry's frozen_view IS the stats dict consumers always
        # read — counters stay inside the registry, run-shaped facts ride
        # along as extras
        self.spans = tracer.drain()
        self.stats = cnt.frozen_view({
            "tasks_run": total,
            "nodes_initial": spec.n_nodes,
            "nodes_final": len(ms.alive_nodes()),
            "workers": sum(cur_spec.workers_at(n)
                           for n in cur_spec.alive_nodes()),
            "exec_nodes": exec_nodes,
            "node_pids": node_pids,
            "recovery_seconds": recovery_seconds[0],
            # hygiene audits — both must be 0 after a clean run: an open
            # lease is a stranded source pin; a surviving retry entry
            # means a recovered edge/task kept its failure count and
            # would exhaust its budget early on the NEXT fault
            "stale_leases": len(leases) + sum(len(v) for v
                                              in pending_lease.values()),
            "stale_retry_entries": len(xfer_retries) + len(task_retries),
            "gather_bytes": gather_bytes,
            "gather_first_tile_s": gather_t_first[0],
            "gather_full_result_s": gather_t_full,
            "retained_tiles": retained_count,
            "buffers_freed": sum(s["buffers_freed"]
                                 for s in self._node_stats.values()),
            "peak_buffer_bytes": sum(s["peak_buffer_bytes"]
                                     for s in self._node_stats.values()),
            "cur_buffer_bytes": sum(s["cur_buffer_bytes"]
                                    for s in self._node_stats.values()),
            "evictions": sum(s.get("evictions", 0)
                             for s in self._node_stats.values()),
            "faults": sum(s.get("faults", 0)
                          for s in self._node_stats.values()),
            "spill_writes": sum(s.get("spill_writes", 0)
                                for s in self._node_stats.values()),
            "spill_reads": sum(s.get("spill_reads", 0)
                               for s in self._node_stats.values()),
            "spilled_bytes": sum(s.get("spilled_bytes", 0)
                                 for s in self._node_stats.values()),
            "leaked_spill_files": leaked_spill,
        })
        if not outs:
            return None
        return outs[0] if len(outs) == 1 else outs

    # -- session lifecycle ----------------------------------------------------
    def drop_retained(self, node: int, key) -> None:
        """Session free path: drop one retained tile from ``node``'s
        arena (no-op for nodes that already left the cluster)."""
        if self._broken or self._ms is None:
            return
        if self._ms.is_alive(node) and self._inqs.get(node) is not None:
            self._inqs[node].put(("drop", key))

    def close_session(self) -> Dict[int, Dict[str, int]]:
        """Stop the long-lived workers; returns per-node arena stats
        collected at shutdown (live/retained counts — the refcount-audit
        input; dead nodes are absent)."""
        audit: Dict[int, Dict[str, int]] = {}
        if not self._started:
            return audit
        if not self._broken and self._ms is not None:
            expect = [n for n in self._ms.alive_nodes()
                      if self._inqs.get(n) is not None]
            for n in expect:
                self._inqs[n].put(("stop",))
            deadline = time.monotonic() + min(self.timeout, 30.0)
            while len(audit) < len(expect) and \
                    time.monotonic() < deadline:
                got = False
                for n in expect:
                    q = self._outqs.get(n)
                    if q is None:
                        continue
                    try:
                        msg = q.get_nowait()
                    except _queue.Empty:
                        continue
                    got = True
                    if msg[0] == "stats":
                        audit[msg[1]] = msg[2]
                if not got:
                    time.sleep(0.005)
        self._terminate_all()
        self._started = False
        # spill-file leak sweep: a clean shutdown leaves the run's spill
        # directory empty — report leftovers so the session audit can fail
        sd = getattr(self, "_spill_dir", None)
        if sd:
            try:
                leaked = len(os.listdir(sd))
            except OSError:
                leaked = 0
            shutil.rmtree(sd, ignore_errors=True)
            audit["spill"] = {"leaked_spill_files": leaked}
        return audit

    # -- cleanup --------------------------------------------------------------
    def _reap_segments(self, node: Optional[int] = None) -> None:
        """Best-effort unlink of shm segments left behind by dead
        incarnations (a SIGKILLed worker never unlinks its arena),
        found via the run-scoped name prefix."""
        from multiprocessing import resource_tracker
        if not os.path.isdir("/dev/shm"):       # pragma: no cover
            return
        reaped = []
        for f in os.listdir("/dev/shm"):
            if not f.startswith(self._prefix):
                continue
            if node is not None and f"n{node}_" not in f:
                continue
            try:
                # plain unlink (= shm_unlink): attaching would fail on a
                # segment whose creator was SIGKILLed mid-create (zero
                # size), and existing mappings survive the unlink anyway
                os.unlink(os.path.join("/dev/shm", f))
                reaped.append(f)
            except OSError:
                pass
        # the dead worker registered its creates with the (shared) tracker
        # process and died before unlinking; retract the stale entries or
        # the tracker warns about leaks at exit.  register-then-unregister
        # nets to removal whether or not the registration arrived before
        # the SIGKILL (the tracker cache is a set — bpo-39959)
        for f in reaped:
            try:
                resource_tracker.register("/" + f, "shared_memory")
                resource_tracker.unregister("/" + f, "shared_memory")
            except Exception:       # pragma: no cover
                pass
        # a SIGKILLed worker also strands its spill-tier files; same
        # per-node sweep over the run's spill directory.  The node=None
        # (terminate-all) case deliberately leaves files in place so the
        # close_session leak audit can count them first
        sd = getattr(self, "_spill_dir", None)
        if node is not None and sd and os.path.isdir(sd):
            for f in os.listdir(sd):
                if f"n{node}_" not in f:
                    continue
                try:
                    os.unlink(os.path.join(sd, f))
                except OSError:     # pragma: no cover
                    pass

    def _terminate_all(self) -> None:
        for p in self._procs.values():
            if p is not None and p.is_alive():
                p.terminate()
        for p in self._procs.values():
            if p is not None:
                p.join(timeout=5)
        self._reap_segments()
