"""Wave-batched executor: one stacked kernel call per wave group.

At the tile sizes the autotuner favors, the per-task executor
(``exec/local.py``) pays one Python dispatch — heap pop, closure, lock
round-trip — per tile task; at 10k+ tasks that overhead dominates the BLAS
time the plan was optimized for (the numpywren fine-grained-task wall).
This backend removes it by *batching*:

1. the scheduled task graph is partitioned into **waves** — antichains of
   mutually independent tasks (longest-path levels, so every dependency
   crosses waves);
2. each wave is grouped by ``(kind, tile shape, dtype, payload class)``;
3. each group executes as ONE stacked call — ``np.matmul`` over 3-D stacked
   operands for ADDMUL/MATMUL, one vectorized ufunc application over a
   stacked slab for ADD/SUB/EWMUL/SCALE/EWISE, ``fusion.eval_fused`` over
   stacked inputs for FUSED — or, with ``backend="pallas"``, a
   ``jax.vmap``-over-Pallas blocked GEMM (``kernels/matmul.py``) jit-cached
   per group signature.

Buffer arena: every group's output tiles live in ONE stacked slab
``(group, tm, tn)``; each tile buffer is a zero-copy view ``slab[i]``.
When a later group's inputs are exactly a contiguous run of a slab, the
gather is a zero-copy slice (the common case for elementwise chains and
the C-accumulator of addmul k-chains); otherwise tiles are stacked into a
scratch copy.  Slabs are reference-counted like the per-task runtime: a
slab is freed when the last reader of its last live tile finishes, so peak
memory stays bounded by live *slabs* (wave-granular, vs tile-granular for
the per-task executor — the throughput/peak trade-off of batching).

Numerics: the NumPy backend is bit-identical to ``LocalExecutor`` — a 3-D
``np.matmul`` issues the same BLAS GEMM per slice as the per-task ``@``,
and NumPy ufuncs are elementwise-deterministic under stacking.  The Pallas
backend accumulates in float32 VMEM on TPU and is validated at tolerance
instead.

``predict_wave_makespan`` is the executor-strategy leg of the paper's
simulation-driven selection: the engine compares it against the per-task
simulated makespan (which prices ``TimeModel.dispatch_overhead`` per task)
and picks the cheaper strategy per plan.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.fusion import eval_fused
from ..core.graph import (Task, TaskGraph, TaskKind, TileRef,
                          matmul_epilogue, matmul_flags)
from ..core.lazy import EWISE_FNS, Op, apply_scale, leaf_slice
from ..core.machine import ClusterSpec
from ..core.timemodel import CostCache, TimeModel
from ..core.tiling import assemble, result_sets_of, tile_slices
from ..runtime.telemetry import NULL_TRACER, Tracer


def build_waves(g: TaskGraph) -> List[List[int]]:
    """Partition ``g`` into dependency levels (waves).

    ``wave[t] = 1 + max(wave[p] for p in preds)`` — tasks in one wave are
    mutually independent (any edge strictly increases the level), so a wave
    can execute as a set of batched kernels with no intra-wave ordering.
    Within a wave, tasks are ordered by output tile ``(tensor, i, j)`` so
    group gathers line up with slab layout (maximising zero-copy runs).
    """
    level: Dict[int, int] = {}
    for t in g.topo():
        level[t.tid] = 1 + max((level[p] for p in t.preds), default=-1)
    n_waves = max(level.values(), default=-1) + 1
    waves: List[List[int]] = [[] for _ in range(n_waves)]
    for tid, lv in level.items():
        waves[lv].append(tid)

    def order_key(tid: int):
        t = g.tasks[tid]
        if t.out is not None:
            return (0, t.out.tensor, t.out.i, t.out.j, tid)
        return (1, 0, 0, 0, tid)

    for wave in waves:
        wave.sort(key=order_key)
    return waves


def _group_key(t: Task, dtypes: Dict[int, object]) -> tuple:
    """Batching signature: tasks with equal keys stack into one kernel."""
    dt = lambda ref: str(dtypes.get(ref.tensor, np.float64))  # noqa: E731
    k = t.kind
    if k in (TaskKind.ADDMUL, TaskKind.MATMUL):
        key = (k, matmul_flags(t.payload), t.ins[0].shape, t.ins[1].shape,
               t.out.shape, dt(t.ins[0]), dt(t.ins[1]), dt(t.out))
        epi = matmul_epilogue(t.payload)
        if epi is not None:
            # epilogued chain tails batch separately from plain chain
            # steps: the stacked eval_fused needs matching programs and
            # matching extra-operand shapes/dtypes across the group
            key += (epi, tuple(r.shape for r in t.ins[2:]),
                    tuple(dt(r) for r in t.ins[2:]))
        return key
    if k is TaskKind.CALLOC:
        return (k, t.out.shape, dt(t.out))
    if k is TaskKind.FILL:
        return (k, t.out.shape, dt(t.out))
    if k is TaskKind.RESIDENT:
        return (k, t.out.shape, dt(t.out))
    if k in (TaskKind.ADD, TaskKind.SUB, TaskKind.EWMUL):
        return (k, t.out.shape, dt(t.ins[0]), dt(t.ins[1]))
    if k in (TaskKind.SCALE, TaskKind.EWISE):
        return (k, t.payload, t.out.shape, dt(t.ins[0]))
    if k is TaskKind.FUSED:
        return (k, t.payload, tuple(r.shape for r in t.ins),
                tuple(dt(r) for r in t.ins))
    if k is TaskKind.TRANSPOSE:
        return (k, t.ins[0].shape, dt(t.ins[0]))
    if k is TaskKind.TAKECOPY:
        return (k,)
    raise ValueError(k)  # pragma: no cover


def group_wave(g: TaskGraph, wave: Sequence[int],
               dtypes: Dict[int, object]) -> List[Tuple[tuple, List[Task]]]:
    """Group one wave's tasks by batching signature (insertion-ordered)."""
    groups: Dict[tuple, List[Task]] = {}
    for tid in wave:
        t = g.tasks[tid]
        groups.setdefault(_group_key(t, dtypes), []).append(t)
    return list(groups.items())


class _Slab:
    """One stacked allocation holding a wave group's output tiles."""

    __slots__ = ("arr", "live", "nbytes")

    def __init__(self, arr: np.ndarray, live: int):
        self.arr = arr
        self.live = live
        self.nbytes = arr.nbytes


class WaveArena:
    """Stacked tile storage with slab-granular refcounted freeing."""

    def __init__(self):
        #: TileRef -> (slab, index within slab)
        self._of: Dict[TileRef, Tuple[_Slab, int]] = {}
        self.cur_bytes = 0
        self.peak_bytes = 0
        self.slabs_alloc = 0
        self.slabs_freed = 0

    def register(self, refs: Sequence[TileRef], arr: np.ndarray,
                 extra_live: int = 0) -> _Slab:
        """Adopt ``arr`` (leading axis = tiles in ``refs`` order) as a slab.

        A tile ref can be produced twice — HEFT's §3.3 regeneration pass
        clones a FILL onto another node, and both tasks share the original
        ``out`` ref.  A ref holds exactly ONE slab slot alive at a time:
        re-registering releases the previous hold, so duplicate producers
        cannot strand a slab at ``live > 0`` forever.
        """
        slab = _Slab(arr, live=len(refs) + extra_live)
        self.cur_bytes += slab.nbytes
        self.peak_bytes = max(self.peak_bytes, self.cur_bytes)
        self.slabs_alloc += 1
        for i, r in enumerate(refs):
            if r in self._of:
                self.release_tile(r)
            self._of[r] = (slab, i)
        return slab

    def contiguous_run(self, refs: Sequence[TileRef]) -> Optional[np.ndarray]:
        """Zero-copy stacked view if ``refs`` are one ascending slab run."""
        first = self._of.get(refs[0])
        if first is None:
            return None
        slab, start = first
        for k, r in enumerate(refs[1:], 1):
            ent = self._of.get(r)
            if ent is None or ent[0] is not slab or ent[1] != start + k:
                return None
        return slab.arr[start:start + len(refs)]

    def release_tile(self, ref: TileRef) -> bool:
        """Drop one live count of the tile's slab; True if the slab died."""
        ent = self._of.get(ref)
        if ent is None:
            return False
        slab, _ = ent
        slab.live -= 1
        if slab.live == 0:
            self.cur_bytes -= slab.nbytes
            self.slabs_freed += 1
            slab.arr = None
            return True
        return False


class WaveExecutor:
    """Executes a planned tiled program wave-by-wave with batched kernels.

    ``backend="numpy"`` (default) issues stacked BLAS/ufunc calls and is
    bit-identical to ``LocalExecutor``; ``backend="pallas"`` routes ADDMUL
    groups through ``jax.vmap`` over the Pallas blocked GEMM (interpret
    mode on CPU, compiled on TPU), jit-cached per group signature.

    ``free_buffers=False`` keeps every slab alive (debugging / benchmarks).
    """

    def __init__(self, backend: str = "numpy", free_buffers: bool = True,
                 trace: bool = True, precision: str = "strict"):
        if backend not in ("numpy", "pallas"):
            raise ValueError(f"unknown wave backend {backend!r}")
        if precision not in ("strict", "mixed"):
            raise ValueError(f"unknown precision mode {precision!r}")
        self.backend = backend
        #: ``"strict"`` (default) keeps the bit-identity contract with
        #: LocalExecutor.  ``"mixed"`` is the opt-in numerics gate: matmul
        #: accumulators CALLOC in float32, operands are cast to float32
        #: for the multiply, and epilogued chain outputs are stored as
        #: bfloat16 — validated by allclose tolerance, never bitwise
        #: (see TESTING.md, numerics tiers).
        self.precision = precision
        self.free_buffers = free_buffers
        #: flight recorder: one EXEC span per batched group call (node 0,
        #: lane 0 — waves are sequential in this process)
        self.trace = trace
        self.spans: List = []
        self.stats: Dict[str, int] = {}

    # -- gather helpers ----------------------------------------------------
    def _gather(self, refs, buffers, arena) -> np.ndarray:
        if len(refs) == 1:
            self.stats["zero_copy_gathers"] += 1
            return buffers[refs[0]][None]
        run = arena.contiguous_run(refs)
        if run is not None and run.shape[0] == len(refs):
            self.stats["zero_copy_gathers"] += 1
            return run
        self.stats["copied_gathers"] += 1
        return np.stack([buffers[r] for r in refs])

    # -- group kernels -----------------------------------------------------
    def _run_group(self, kind: TaskKind, tasks: List[Task], buffers, arena,
                   leaf_nodes, dtypes, tile, residency=None) -> None:
        self.stats["batched_calls"] += 1
        outs = [t.out for t in tasks]

        if kind is TaskKind.TAKECOPY:
            return

        if kind is TaskKind.RESIDENT:
            # session-resident tiles: zero-copy aliases into this run's
            # buffer namespace; NOT registered in the arena (session-owned)
            for t in tasks:
                buffers[t.out] = residency.tile(t.payload, t.out.i, t.out.j)
            return

        if kind is TaskKind.CALLOC:
            dt = dtypes.get(tasks[0].payload, np.float64)
            if self.precision == "mixed":
                # CALLOCs are matmul accumulators: f32 accumulate
                dt = np.float32
            slab = np.zeros((len(tasks),) + outs[0].shape, dtype=dt)
            arena.register(outs, slab)
            for i, t in enumerate(tasks):
                buffers[t.out] = slab[i]
            return

        if kind is TaskKind.FILL:
            self._run_fill(tasks, buffers, arena, leaf_nodes, tile)
            return

        if kind in (TaskKind.ADDMUL, TaskKind.MATMUL):
            self._run_matmul(kind, tasks, buffers, arena, dtypes)
            return

        # elementwise families: one vectorized call over stacked operands
        ins0 = self._gather([t.ins[0] for t in tasks], buffers, arena)
        if kind in (TaskKind.ADD, TaskKind.SUB, TaskKind.EWMUL):
            ins1 = self._gather([t.ins[1] for t in tasks], buffers, arena)
            ufunc = {TaskKind.ADD: np.add, TaskKind.SUB: np.subtract,
                     TaskKind.EWMUL: np.multiply}[kind]
            slab = ufunc(ins0, ins1)
        elif kind is TaskKind.SCALE:
            skind, s = tasks[0].payload
            slab = apply_scale(skind, ins0, s)
        elif kind is TaskKind.EWISE:
            slab = EWISE_FNS[tasks[0].payload](ins0)
        elif kind is TaskKind.FUSED:
            stacks = [self._gather([t.ins[j] for t in tasks], buffers, arena)
                      for j in range(len(tasks[0].ins))]
            slab = eval_fused(tasks[0].payload, stacks)
        elif kind is TaskKind.TRANSPOSE:
            slab = np.ascontiguousarray(ins0.transpose(0, 2, 1))
        else:  # pragma: no cover
            raise ValueError(kind)
        arena.register(outs, slab)
        for i, t in enumerate(tasks):
            buffers[t.out] = slab[i]

    def _run_fill(self, tasks, buffers, arena, leaf_nodes, tile) -> None:
        node = leaf_nodes[tasks[0].payload]
        if node.op is Op.INPUT and \
                all(leaf_nodes[t.payload].op is Op.INPUT for t in tasks):
            # zero-copy views into the user array, exactly like exec/local
            for t in tasks:
                n = leaf_nodes[t.payload]
                rs = tile_slices(n.shape[0], tile[0])[t.out.i]
                cs = tile_slices(n.shape[1], tile[1])[t.out.j]
                buffers[t.out] = leaf_slice(n, rs[0], rs[1], cs[0], cs[1])
            return
        slab = np.empty((len(tasks),) + tasks[0].out.shape, dtype=node.dtype)
        for i, t in enumerate(tasks):
            n = leaf_nodes[t.payload]
            rs = tile_slices(n.shape[0], tile[0])[t.out.i]
            cs = tile_slices(n.shape[1], tile[1])[t.out.j]
            slab[i] = leaf_slice(n, rs[0], rs[1], cs[0], cs[1])
            buffers[t.out] = slab[i]
        arena.register([t.out for t in tasks], slab)

    def _epilogue_store_dtype(self):
        if self.precision != "mixed":
            return None
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)

    def _apply_epilogue(self, epi, tasks, c3, buffers, arena) -> None:
        """Stacked epilogue over the accumulated C slab; rebinds outputs.

        Runs the same ``eval_fused`` program as the unfused FUSED task
        would, on the same accumulated values, so strict-precision wave
        execution stays bit-identical to the per-task executors.
        """
        nin = len(tasks[0].ins)
        stacks = [self._gather([t.ins[j] for t in tasks], buffers, arena)
                  for j in range(2, nin)]
        slab = eval_fused(epi, [c3] + stacks)
        store_dt = self._epilogue_store_dtype()
        if store_dt is not None:
            slab = slab.astype(store_dt)
        outs = [t.out for t in tasks]
        arena.register(outs, slab)
        for i, t in enumerate(tasks):
            buffers[t.out] = slab[i]

    def _run_matmul(self, kind, tasks, buffers, arena, dtypes) -> None:
        ta, tb = matmul_flags(tasks[0].payload)
        epi = matmul_epilogue(tasks[0].payload)
        a3 = self._gather([t.ins[0] for t in tasks], buffers, arena)
        b3 = self._gather([t.ins[1] for t in tasks], buffers, arena)
        if ta:
            a3 = a3.transpose(0, 2, 1)
        if tb:
            b3 = b3.transpose(0, 2, 1)
        if self.precision == "mixed":
            a3 = a3.astype(np.float32, copy=False)
            b3 = b3.astype(np.float32, copy=False)

        if kind is TaskKind.MATMUL:
            slab = np.matmul(a3, b3)
            if epi is not None:
                self._apply_epilogue(epi, tasks, slab, buffers, arena)
                return
            arena.register([t.out for t in tasks], slab)
            for i, t in enumerate(tasks):
                buffers[t.out] = slab[i]
            return

        # ADDMUL: C += A @ B, accumulating into the CALLOC'd tile buffers
        outs = [t.out for t in tasks]
        crun = arena.contiguous_run(outs) if len(outs) > 1 else None
        if self.backend == "pallas":
            from ..kernels import ops as kops
            c3 = crun if crun is not None else \
                np.stack([buffers[t.out] for t in tasks])
            if epi is not None:
                # true fused kernel: accumulator -> epilogue -> store
                stacks = [self._gather([t.ins[j] for t in tasks],
                                       buffers, arena)
                          for j in range(2, len(tasks[0].ins))]
                store_dt = self._epilogue_store_dtype()
                slab = np.asarray(kops.addmul_batched(
                    np.ascontiguousarray(c3), np.ascontiguousarray(a3),
                    np.ascontiguousarray(b3),
                    epilogue=epi,
                    extras=[np.ascontiguousarray(s) for s in stacks],
                    out_dtype=store_dt))
                if store_dt is None:
                    # strict mode: keep the wave pipeline's dtype contract
                    # (jax may compute in f32; the plain path casts back
                    # to the accumulator dtype the same way)
                    slab = slab.astype(np.result_type(
                        c3.dtype, *[s.dtype for s in stacks]), copy=False)
                arena.register(outs, slab)
                for i, t in enumerate(tasks):
                    buffers[t.out] = slab[i]
                return
            out = np.asarray(kops.addmul_batched(
                np.ascontiguousarray(c3), np.ascontiguousarray(a3),
                np.ascontiguousarray(b3)), dtype=c3.dtype)
            if crun is not None:
                np.copyto(crun, out)
            else:
                for i, t in enumerate(tasks):
                    np.copyto(buffers[t.out], out[i])
            return
        prod = np.matmul(a3, b3)
        if crun is not None:
            crun += prod
        else:
            for i, t in enumerate(tasks):
                buffers[t.out] += prod[i]
        if epi is not None:
            # tail of the k-chain: apply the fused epilogue over the
            # fully-accumulated C tiles in one stacked pass
            c3 = crun if crun is not None else \
                np.stack([buffers[t.out] for t in tasks])
            self._apply_epilogue(epi, tasks, c3, buffers, arena)

    # -- driver ------------------------------------------------------------
    def execute(self, plan) -> np.ndarray:
        g: TaskGraph = plan.program.graph
        tile = plan.tile
        leaf_nodes = plan.program.leaf_nodes
        dtypes = plan.program.dtypes
        residency = getattr(plan, "residency", None)
        rsets = result_sets_of(g)
        waves = getattr(plan, "waves", None) or build_waves(g)

        buffers: Dict[TileRef, np.ndarray] = {}
        arena = WaveArena()
        self.stats = {"zero_copy_gathers": 0, "copied_gathers": 0,
                      "batched_calls": 0}

        # readers per tile (+1 keeps result tiles alive for assembly and
        # persisted tiles alive for session retention — retained tiles are
        # excluded from slab refcount freeing)
        refcnt: Dict[TileRef, int] = {}
        for t in g:
            for r in t.ins:
                refcnt[r] = refcnt.get(r, 0) + 1
        for rs in rsets:
            for r in rs.tiles:
                refcnt[r] = refcnt.get(r, 0) + 1
        # an ADDMUL chain rewrites its C tile: every chain step after the
        # slab's CALLOC holds the tile alive even though it is not in `ins`
        for t in g:
            if t.kind in (TaskKind.ADDMUL, TaskKind.MATMUL) and \
                    t.out is not None:
                refcnt[t.out] = refcnt.get(t.out, 0) + 1

        tracer = Tracer(node=0, enabled=self.trace) if self.trace \
            else NULL_TRACER
        tasks_run = 0
        for wi, wave in enumerate(waves):
            for (key, tasks) in group_wave(g, wave, dtypes):
                with tracer.span(key[0].name, cat="EXEC", wave=wi,
                                 tasks=len(tasks), batched=True):
                    self._run_group(key[0], tasks, buffers, arena,
                                    leaf_nodes, dtypes, tile,
                                    residency=residency)
                tasks_run += len(tasks)
                if not self.free_buffers:
                    continue
                for t in tasks:
                    reads = list(t.ins)
                    if t.kind in (TaskKind.ADDMUL, TaskKind.MATMUL):
                        reads.append(t.out)   # release the chain's hold
                    for r in reads:
                        refcnt[r] -= 1
                        if refcnt[r] == 0:
                            # result tiles hold an extra assembly ref, so
                            # they can never reach zero here
                            arena.release_tile(r)
                            buffers.pop(r, None)

        # retention: persisted roots' tiles move to the session store.
        # Wave tiles are views into per-wave SLABS — retaining the view
        # would pin the whole slab (every same-wave tile) for the
        # handle's lifetime, and INPUT-leaf views alias the user's array
        # — so view-backed tiles are copied out; only standalone arrays
        # transfer zero-copy.
        retained = 0
        outs = []
        gather_bytes = 0
        for rs in rsets:
            if rs.gather:
                vals = {r: buffers[r] for r in rs.tiles}
                gather_bytes += sum(r.bytes for r in rs.tiles)
                outs.append(assemble(vals, rs.shape, tile, rs.uid))
            else:
                for r in rs.tiles:
                    buf = buffers[r]
                    if buf.base is not None:
                        buf = np.ascontiguousarray(buf)
                    residency.retain_local(rs.uid, r.i, r.j, buf)
                    retained += 1

        self.spans = tracer.drain()
        self.stats.update({
            "peak_buffer_bytes": arena.peak_bytes,
            "cur_buffer_bytes": arena.cur_bytes,
            "slabs_alloc": arena.slabs_alloc,
            "buffers_freed": arena.slabs_freed,
            "tasks_run": tasks_run,
            "waves": len(waves),
            "gather_bytes": gather_bytes,
            "retained_tiles": retained,
        })
        if not outs:
            return None
        return outs[0] if len(outs) == 1 else outs


def predict_wave_makespan(g: TaskGraph, spec: ClusterSpec, tm: TimeModel,
                          waves: Optional[List[List[int]]] = None,
                          dtypes: Optional[Dict[int, object]] = None,
                          cost: Optional[CostCache] = None) -> float:
    """Predicted wall-clock of wave-batched execution under ``tm``.

    Waves run back-to-back; each group costs one
    ``tm.batch_dispatch_overhead`` plus its summed per-slice kernel time
    spread over the node's worker parallelism (stacked BLAS keeps every
    core busy).  Compare with the per-task simulated makespan — which pays
    ``tm.dispatch_overhead`` per task — to pick an executor strategy.
    """
    waves = waves or build_waves(g)
    dtypes = dtypes or {}
    cost = cost or CostCache(tm, spec)
    # the wave executor runs in ONE process: its parallelism is the widest
    # node's worker count (equals ``worker_procs`` on homogeneous specs;
    # heterogeneous specs must not be priced at the default 3)
    par = max(1, max(spec.workers_at(n) for n in range(spec.n_nodes)))
    total = 0.0
    for wave in waves:
        for (key, tasks) in group_wave(g, wave, dtypes):
            kind = key[0]
            if kind is TaskKind.TAKECOPY:
                continue
            if kind in (TaskKind.CALLOC, TaskKind.RESIDENT):
                total += 1e-6      # calloc slab / resident bind: near-free
                continue
            kern = sum(cost.kernel(t) for t in tasks)
            total += tm.batch_dispatch_overhead + kern / par
    return total
