"""Sharded tiled-GEMM executors — the TPU-native analogue of CMM's schedule.

On a MIMD cluster CMM materialises the tiled matmul as addmul tasks plus
send/recv pairs.  On an SPMD TPU mesh the same tiling becomes a *static*
collective schedule.  Two classic schedules are provided, both built with
``shard_map`` so the collectives are explicit (not left to GSPMD):

* ``matmul_2d`` — broadcast-panel 2-D algorithm: each device all-gathers its
  A-block row panel along the mesh columns and its B-block column panel along
  the mesh rows, then does one local GEMM.  One all-gather per operand; the
  gathered panels are the SPMD incarnation of CMM's *node-level cache* (each
  device keeps the gathered panel resident and reuses it for every local
  k-step instead of re-receiving per addmul).

* ``matmul_cannon`` — Cannon's systolic ring: blocks circulate with
  ``ppermute`` while partial products accumulate, overlapping communication
  with compute; requires a square mesh.  This is the minimal-resident-memory
  schedule (one block of A and B live per device).

Both are validated against ``jnp.dot`` on a host-device mesh in tests.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def matmul_2d(a: jax.Array, b: jax.Array, mesh: Mesh,
              axes: Tuple[str, str] = ("x", "y"),
              precision=None) -> jax.Array:
    """C = A @ B with A sharded P(x, y), B sharded P(x, y), C sharded P(x, y).

    comm volume per device: |A|/r + |B|/c (the 2-D algorithm's lower bound
    shape); local compute: (m/r) x n x (k/c) GEMM.
    """
    ax_r, ax_c = axes

    def body(ab, bb):
        # ab: (m/r, n/c); gather k-panels of A along mesh columns
        a_row = jax.lax.all_gather(ab, ax_c, axis=1, tiled=True)  # (m/r, n)
        b_col = jax.lax.all_gather(bb, ax_r, axis=0, tiled=True)  # (n, k/c)
        return jnp.dot(a_row, b_col, precision=precision,
                       preferred_element_type=jnp.float32).astype(ab.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(ax_r, ax_c), P(ax_r, ax_c)),
        out_specs=P(ax_r, ax_c),
    )(a, b)


def matmul_cannon(a: jax.Array, b: jax.Array, mesh: Mesh,
                  axes: Tuple[str, str] = ("x", "y")) -> jax.Array:
    """Cannon's algorithm on a square (p x p) mesh with ppermute rings.

    Initial skew: A block-row i rotated left by i, B block-col j rotated up
    by j; then p steps of (local GEMM-accumulate, rotate A left, rotate B up).
    The rotate of step t+1 overlaps with the GEMM of step t on real hardware
    (XLA latency-hiding) — the compute/comm overlap CMM gets from dedicated
    comm processes.
    """
    ax_r, ax_c = axes
    p_r = mesh.shape[ax_r]
    p_c = mesh.shape[ax_c]
    if p_r != p_c:
        raise ValueError(f"Cannon needs a square mesh, got {p_r}x{p_c}")
    p = p_r

    def shift(x, axis_name, by):
        n = p
        perm = [(i, (i - by) % n) for i in range(n)]
        return jax.lax.ppermute(x, axis_name, perm)

    def body(ab, bb):
        i = jax.lax.axis_index(ax_r)
        j = jax.lax.axis_index(ax_c)
        # skew: A_ij <- A_i,(j+i);  B_ij <- B_(i+j),j  -- realised as rotation
        # by the *row/col index*, done with a log-free loop of ppermutes is
        # data-dependent; instead use the standard trick: rotate row i left
        # by i via a single ppermute with per-device permutation.
        perm_a = []
        for ii in range(p):
            for jj in range(p):
                src = ii * p + jj
                dst = ii * p + ((jj - ii) % p)
                perm_a.append((src, dst))
        perm_b = []
        for ii in range(p):
            for jj in range(p):
                src = ii * p + jj
                dst = ((ii - jj) % p) * p + jj
                perm_b.append((src, dst))
        flat = (ax_r, ax_c)
        ab = jax.lax.ppermute(ab, flat, perm_a)
        bb = jax.lax.ppermute(bb, flat, perm_b)

        def step(carry, _):
            ab, bb, acc = carry
            acc = acc + jnp.dot(ab, bb,
                                preferred_element_type=jnp.float32)
            ab = shift(ab, ax_c, 1)   # rotate A blocks left
            bb = shift(bb, ax_r, 1)   # rotate B blocks up
            return (ab, bb, acc), ()

        acc0 = jnp.zeros((ab.shape[0], bb.shape[1]), jnp.float32)
        # mark the carry as device-varying so the scan carry types match
        # after the ppermutes (JAX >= 0.8 varying-manual-axes check)
        if hasattr(jax.lax, "pcast"):
            acc0 = jax.lax.pcast(acc0, (ax_r, ax_c), to="varying")
        elif hasattr(jax.lax, "pvary"):
            acc0 = jax.lax.pvary(acc0, (ax_r, ax_c))
        (_, _, acc), _ = jax.lax.scan(step, (ab, bb, acc0), None, length=p)
        return acc.astype(a.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(ax_r, ax_c), P(ax_r, ax_c)),
        out_specs=P(ax_r, ax_c),
    )(a, b)


def reduce_scatter_matmul(a: jax.Array, b: jax.Array, mesh: Mesh,
                          axis: str = "model") -> jax.Array:
    """k-sharded GEMM: A P(None, axis), B P(axis, None) -> C via psum_scatter.

    The tensor-parallel contraction used by the LM stack's MLP second matmul:
    each device holds a k-slice, computes a partial C, and the partials are
    reduce-scattered (half the bytes of an all-reduce; the 'keep the result
    sharded' trick — beyond-paper optimisation recorded in §Perf).
    """
    def body(ab, bb):
        part = jnp.dot(ab, bb, preferred_element_type=jnp.float32)
        out = jax.lax.psum_scatter(part, axis, scatter_dimension=1,
                                   tiled=True)
        return out.astype(a.dtype)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(None, axis), P(axis, None)),
                     out_specs=P(None, axis))(a, b)
